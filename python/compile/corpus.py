"""Deterministic synthetic corpus + tokenizer vocabulary.

The paper evaluates C4 perplexity on pretrained LLMs; neither the corpus nor
the checkpoints are available here (see DESIGN.md §2). This module
synthesizes a pseudo-English corpus with the statistical structure a small
LM can learn — Zipfian word frequencies, sentence templates with
agreement-like constraints, topic locality — so that perplexity *degradation
under communication quantization* is measurable and ordered, which is the
reproduced quantity.

The corpus is fully determined by SEED: every `make artifacts` run and every
rust-side consumer sees identical tokens.
"""

import struct

import numpy as np

SEED = 0xF1A5C011
MAGIC = 0xC0A9
PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3

# Template grammar: S -> NP VP [CONJ NP VP] '.' with topic-conditioned
# vocabulary pools. Words are abstract ids; surface strings never matter.
_POOL_SIZES = {
    "det": 8,
    "adj": 96,
    "noun": 384,
    "verb": 256,
    "adv": 64,
    "prep": 16,
    "conj": 8,
    "punct": 4,
}


def vocab_layout(vocab_size: int):
    """Assign contiguous id ranges per part-of-speech pool.

    The pools are scaled to fill `vocab_size - N_SPECIAL` ids.
    """
    total = sum(_POOL_SIZES.values())
    avail = vocab_size - N_SPECIAL
    layout = {}
    cursor = N_SPECIAL
    for i, (pos, base) in enumerate(_POOL_SIZES.items()):
        n = max(2, base * avail // total)
        if i == len(_POOL_SIZES) - 1:
            n = vocab_size - cursor  # absorb rounding
        layout[pos] = (cursor, n)
        cursor += n
    assert cursor == vocab_size, (cursor, vocab_size)
    return layout


def _zipf_draw(rng: np.random.Generator, n: int, a: float = 1.3) -> int:
    """Zipf-distributed index in [0, n)."""
    # Bounded inverse-CDF draw (numpy's zipf is unbounded).
    u = rng.random()
    t = 1.0 - a
    h = (n ** t - 1.0) / t
    x = (1.0 + u * h * t) ** (1.0 / t) - 1.0
    return min(int(x), n - 1)


def generate_tokens(vocab_size: int, n_tokens: int, seed: int = SEED) -> np.ndarray:
    """Generate `n_tokens` of template-grammar text as uint16 ids."""
    assert vocab_size <= 65536
    rng = np.random.default_rng(seed)
    layout = vocab_layout(vocab_size)

    def draw(pos: str, topic: int) -> int:
        start, n = layout[pos]
        if pos in ("noun", "verb", "adj"):
            # Topic locality: each topic prefers a contiguous half-pool.
            half = n // 2
            off = (topic * 97) % max(1, n - half)
            return start + off + _zipf_draw(rng, half)
        return start + _zipf_draw(rng, n)

    out = np.empty(n_tokens, dtype=np.uint16)
    i = 0
    topic = 0
    out[i] = BOS
    i += 1
    while i < n_tokens:
        if rng.random() < 0.05:
            topic = int(rng.integers(0, 16))
        # NP: det [adj] noun
        sentence = [draw("det", topic)]
        if rng.random() < 0.5:
            sentence.append(draw("adj", topic))
        subj = draw("noun", topic)
        sentence.append(subj)
        # VP: verb [adv] [prep NP]
        # Agreement-like constraint: verb pool offset depends on the subject,
        # giving the model a learnable conditional structure.
        vstart, vn = layout["verb"]
        half = vn // 2
        voff = (subj % 7) * max(1, (vn - half) // 7)
        sentence.append(vstart + voff + _zipf_draw(rng, half))
        if rng.random() < 0.3:
            sentence.append(draw("adv", topic))
        if rng.random() < 0.4:
            sentence.append(draw("prep", topic))
            sentence.append(draw("det", topic))
            sentence.append(draw("noun", topic))
        pstart, _ = layout["punct"]
        sentence.append(pstart)
        if rng.random() < 0.02:
            sentence.append(EOS)
            sentence.append(BOS)
        take = min(len(sentence), n_tokens - i)
        out[i : i + take] = sentence[:take]
        i += take
    return out


def write_corpus(path: str, tokens: np.ndarray, vocab_size: int) -> None:
    """Binary corpus format shared with rust (model/corpus.rs):

    u16 magic | u16 version | u32 vocab_size | u64 n_tokens | u16 tokens[]
    (little-endian).
    """
    with open(path, "wb") as f:
        f.write(struct.pack("<HHIQ", MAGIC, 1, vocab_size, len(tokens)))
        f.write(tokens.astype("<u2").tobytes())


def read_corpus(path: str):
    with open(path, "rb") as f:
        magic, version, vocab, n = struct.unpack("<HHIQ", f.read(16))
        assert magic == MAGIC and version == 1
        tokens = np.frombuffer(f.read(2 * n), dtype="<u2")
    return tokens, vocab


def train_eval_split(tokens: np.ndarray, eval_fraction: float = 0.05):
    """Deterministic head/tail split (eval = final fraction)."""
    n_eval = max(1, int(len(tokens) * eval_fraction))
    return tokens[:-n_eval], tokens[-n_eval:]
