"""AOT compiler: lowers every L1/L2 artifact to HLO *text* and writes the
runtime data files (init weights, corpus, manifest).

HLO text — NOT `.serialize()` — is the interchange format: the image's
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo and README gotchas.

Run via `make artifacts` (no-op if outputs are newer than inputs):

    cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus as corpus_mod
from . import model as M

WEIGHTS_MAGIC = 0xF1A5
EVAL_BATCH = 4
TRAIN_BATCH = 4
CAPACITY = 128  # fixed-capacity expert batch (tokens/rank/expert, padded)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> None:
    specs = [
        jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype) for a in example_args
    ]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) // 1024} KiB)")


def write_weights(path: str, params: dict) -> None:
    """Binary tensor bundle shared with rust model/weights.rs:

    u32 magic | u32 version | u32 n_tensors
    per tensor: u32 name_len | name | u8 ndim | u32 dims[] | f32 data[] (LE)
    """
    with open(path, "wb") as f:
        f.write(struct.pack("<III", WEIGHTS_MAGIC, 1, len(params)))
        for name, value in params.items():
            v = np.ascontiguousarray(value, dtype="<f4")
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", v.ndim))
            for d in v.shape:
                f.write(struct.pack("<I", d))
            f.write(v.tobytes())


def read_weights(path: str) -> dict:
    """Inverse of write_weights (used by tests)."""
    out = {}
    with open(path, "rb") as f:
        magic, version, n = struct.unpack("<III", f.read(12))
        assert magic == WEIGHTS_MAGIC and version == 1
        for _ in range(n):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode()
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            count = int(np.prod(dims)) if ndim else 1
            out[name] = np.frombuffer(f.read(4 * count), dtype="<f4").reshape(dims)
    return out


def lower_qdq_kernels(out_dir: str, manifest: list) -> None:
    """Standalone L1 QDQ kernels — the rust codec cross-validates against
    these exact lowered graphs (runtime integration tests)."""
    from .kernels.quant import rtn_qdq
    from .kernels.spike import spike_qdq

    shape = (4096,)
    x = np.zeros(shape, np.float32)
    for bits, gs in [(8, 128), (5, 128), (4, 32), (2, 32)]:
        name = f"qdq_rtn_b{bits}_gs{gs}"
        lower_to_file(
            lambda v, b=bits, g=gs: (rtn_qdq(v, bits=b, group_size=g),),
            [x],
            os.path.join(out_dir, f"{name}.hlo.txt"),
        )
        manifest.append(f"artifact {name} kind=qdq n=4096 bits={bits} gs={gs} scheme=rtn")
    for bits, gs in [(2, 32), (3, 32)]:
        name = f"qdq_spike_b{bits}_gs{gs}"
        lower_to_file(
            lambda v, b=bits, g=gs: (spike_qdq(v, bits=b, group_size=g),),
            [x],
            os.path.join(out_dir, f"{name}.hlo.txt"),
        )
        manifest.append(f"artifact {name} kind=qdq n=4096 bits={bits} gs={gs} scheme=spike")


def flat_args_placeholder(cfg, params):
    return [params[n] for n, _ in cfg.param_specs()]


def lower_config(cfg: M.ModelConfig, tp: int, out_dir: str, manifest: list) -> None:
    print(f"config {cfg.name}: {cfg.n_params()} params, tp={tp}")
    params = M.init_params(cfg, seed=42)
    write_weights(os.path.join(out_dir, f"{cfg.name}_init_weights.bin"), params)

    b, s, d = EVAL_BATCH, cfg.seq_len, cfg.d_model
    tokens = np.zeros((b, s), np.int32)
    targets = np.zeros((b, s), np.int32)
    h = np.zeros((b, s, d), np.float32)

    def art(name):
        return os.path.join(out_dir, f"{cfg.name}_{name}.hlo.txt")

    # --- TP inference pieces ---
    lower_to_file(lambda t, e: (M.embed(t, e),), [tokens, params["embed"]], art("embed"))
    dh = d // tp
    heads_shard = cfg.n_heads // tp
    wq_s = np.zeros((d, dh), np.float32)
    wo_s = np.zeros((dh, d), np.float32)
    g1 = np.zeros((d,), np.float32)
    lower_to_file(
        lambda hh, g, bb, q, k, v, o: (
            M.attn_part(hh, g, bb, q, k, v, o, n_heads_shard=heads_shard),
        ),
        [h, g1, g1, wq_s, wq_s, wq_s, wo_s],
        art(f"attn_part_tp{tp}"),
    )
    w1_s = np.zeros((d, cfg.d_ff // tp), np.float32)
    w2_s = np.zeros((cfg.d_ff // tp, d), np.float32)
    lower_to_file(
        lambda hh, g, bb, w1, w2: (M.mlp_part(hh, g, bb, w1, w2),),
        [h, g1, g1, w1_s, w2_s],
        art(f"mlp_part_tp{tp}"),
    )
    lower_to_file(
        lambda hh, g, bb, e, t: M.head_nll(hh, g, bb, e, t),
        [h, g1, g1, params["embed"], targets],
        art("head_nll"),
    )
    lower_to_file(
        lambda hh, g, bb, e, t: M.head_acc(hh, g, bb, e, t),
        [h, g1, g1, params["embed"], targets],
        art("head_acc"),
    )
    manifest.append(
        f"config {cfg.name} vocab={cfg.vocab} d_model={cfg.d_model} "
        f"n_layers={cfg.n_layers} n_heads={cfg.n_heads} d_ff={cfg.d_ff} "
        f"seq_len={cfg.seq_len} n_experts={cfg.n_experts} d_expert={cfg.d_expert} "
        f"moe_every={cfg.moe_every} tp={tp} eval_batch={EVAL_BATCH} "
        f"train_batch={TRAIN_BATCH} capacity={CAPACITY} n_params={cfg.n_params()}"
    )
    for piece in ["embed", f"attn_part_tp{tp}", f"mlp_part_tp{tp}", "head_nll", "head_acc"]:
        manifest.append(f"artifact {cfg.name}_{piece} kind=piece config={cfg.name}")

    # --- MoE pieces ---
    if cfg.n_experts > 0:
        lower_to_file(
            lambda hh, g, bb, r: M.router_logits(hh, g, bb, r),
            [h, g1, g1, np.zeros((d, cfg.n_experts), np.float32)],
            art("router"),
        )
        xc_ = np.zeros((CAPACITY, d), np.float32)
        lower_to_file(
            lambda x, w1, w2: (M.expert_mlp(x, w1, w2),),
            [xc_, np.zeros((d, cfg.d_expert), np.float32),
             np.zeros((cfg.d_expert, d), np.float32)],
            art("expert"),
        )
        manifest.append(f"artifact {cfg.name}_router kind=piece config={cfg.name}")
        manifest.append(f"artifact {cfg.name}_expert kind=piece config={cfg.name}")

    # --- clean whole-graph eval (trainer's held-out perplexity) ---
    lower_to_file(M.make_eval_nll(cfg), flat_args_placeholder(cfg, params) + [tokens, targets],
                  art("eval_nll"))
    manifest.append(f"artifact {cfg.name}_eval_nll kind=eval config={cfg.name}")

    # --- training graphs ---
    tt = np.zeros((TRAIN_BATCH, s), np.int32)
    flat = [params[n] for n, _ in cfg.param_specs()]
    lower_to_file(M.make_grad_step(cfg), flat + [tt, tt], art("grad_step"))
    zeros = [np.zeros_like(p) for p in flat]
    step = np.zeros((), np.float32)
    lower_to_file(
        M.make_adamw_update(cfg), [step] + flat + zeros + zeros + zeros, art("adamw")
    )
    manifest.append(f"artifact {cfg.name}_grad_step kind=train config={cfg.name}")
    manifest.append(f"artifact {cfg.name}_adamw kind=train config={cfg.name}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,moe-tiny",
                    help="comma-separated: tiny,small,100m,moe-tiny")
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--corpus-tokens", type=int, default=600_000)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest: list = ["# flashcomm artifact manifest (generated by compile.aot)"]
    lower_qdq_kernels(args.out_dir, manifest)

    vocabs = set()
    for name in args.configs.split(","):
        cfg = M.CONFIGS[name.strip()]
        lower_config(cfg, args.tp, args.out_dir, manifest)
        vocabs.add(cfg.vocab)

    for vocab in sorted(vocabs):
        path = os.path.join(args.out_dir, f"corpus_v{vocab}.bin")
        tokens = corpus_mod.generate_tokens(vocab, args.corpus_tokens)
        corpus_mod.write_corpus(path, tokens, vocab)
        manifest.append(f"corpus vocab={vocab} file=corpus_v{vocab}.bin "
                        f"tokens={len(tokens)}")
        # Part-of-speech pool ranges: the rust Table 7 harness groups
        # prediction accuracy by these (the synthetic "downstream tasks").
        for pos, (start, n) in corpus_mod.vocab_layout(vocab).items():
            manifest.append(f"pool {pos} vocab={vocab} start={start} n={n}")
        print(f"  wrote {path} ({len(tokens)} tokens)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest) - 1} entries")


if __name__ == "__main__":
    main()
