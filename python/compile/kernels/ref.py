"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness references: pytest/hypothesis asserts the Pallas
kernels (quant.py, spike.py) match these (allclose), and the rust codec is
cross-validated against the lowered HLO of these functions.

All QDQ functions are *fused quantize-dequantize*: they return what a tensor
looks like after crossing the quantized wire — the exact transformation the
communication path applies (Fig. 5).
"""

import jax.numpy as jnp


def to_bf16(x):
    """Round f32 to bf16 precision and widen back (wire metadata precision)."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def _grouped(x, group_size):
    n = x.shape[-1]
    assert n % group_size == 0, f"length {n} not divisible by group {group_size}"
    return x.reshape(*x.shape[:-1], n // group_size, group_size)


def rtn_qdq(x, bits: int, group_size: int):
    """Group-wise asymmetric RTN quantize-dequantize (paper baseline).

    scale/zero travel in BF16, matching rust quant::rtn.
    """
    g = _grouped(x, group_size)
    qmax = float(2**bits - 1)
    mn = jnp.min(g, axis=-1, keepdims=True)
    mx = jnp.max(g, axis=-1, keepdims=True)
    rng = mx - mn
    scale = to_bf16(jnp.where(rng > 0, rng / qmax, 1.0))
    zero = to_bf16(mn)
    q = jnp.clip(jnp.floor((g - zero) / scale + 0.5), 0.0, qmax)
    return (q * scale + zero).reshape(x.shape)


def spike_qdq(x, bits: int, group_size: int):
    """Spike-reserving QDQ: min & max of each group survive at BF16; the
    rest is RTN-quantized in the shrunken [2nd-min, 2nd-max] range."""
    g = _grouped(x, group_size)
    qmax = float(2**bits - 1)
    sorted_g = jnp.sort(g, axis=-1)
    mn, mx = sorted_g[..., :1], sorted_g[..., -1:]
    mn2, mx2 = sorted_g[..., 1:2], sorted_g[..., -2:-1]
    rng = mx2 - mn2
    scale = to_bf16(jnp.where(rng > 0, rng / qmax, 1.0))
    zero = to_bf16(mn2)
    q = jnp.clip(jnp.floor((g - zero) / scale + 0.5), 0.0, qmax)
    deq = q * scale + zero
    # Restore the first occurrence of min / max at bf16 precision.
    is_min = g == mn
    first_min = is_min & (jnp.cumsum(is_min, axis=-1) == 1)
    is_max = g == mx
    first_max = is_max & (jnp.cumsum(is_max, axis=-1) == 1)
    deq = jnp.where(first_max, to_bf16(mx), deq)
    deq = jnp.where(first_min, to_bf16(mn), deq)
    return deq.reshape(x.shape)


def _fwht(g, group_size):
    """Normalized fast Walsh-Hadamard transform over the last axis."""
    shape = g.shape
    v = g
    step = 1
    while step < group_size:
        v = v.reshape(*shape[:-1], group_size // (2 * step), 2, step)
        a = v[..., 0, :]
        b = v[..., 1, :]
        v = jnp.concatenate([a + b, a - b], axis=-1).reshape(shape)
        step *= 2
    return v / jnp.sqrt(float(group_size))


def hadamard_qdq(x, bits: int, group_size: int):
    """Hadamard-rotated RTN baseline (Table 3)."""
    assert group_size & (group_size - 1) == 0, "power-of-two groups"
    g = _grouped(x, group_size)
    h = _fwht(g, group_size)
    deq = rtn_qdq(h.reshape(x.shape), bits, group_size)
    # Inverse = same transform (orthonormal involution).
    g2 = _grouped(deq, group_size)
    return _fwht(g2, group_size).reshape(x.shape)


def logfmt_qdq(x, bits: int, group_size: int):
    """LogFMT baseline: sign + log-domain linear quantization (Table 3)."""
    g = _grouped(x, group_size)
    mag = jnp.abs(g)
    nz = mag > 1e-30
    levels = 2 ** (bits - 1) - 1  # magnitude codes 1..levels; 0 = zero
    loge = jnp.log2(jnp.where(nz, mag, 1.0))
    emin = to_bf16(jnp.min(jnp.where(nz, loge, jnp.inf), axis=-1, keepdims=True))
    emax = to_bf16(jnp.max(jnp.where(nz, loge, -jnp.inf), axis=-1, keepdims=True))
    all_zero = ~jnp.any(nz, axis=-1, keepdims=True)
    emin = jnp.where(all_zero, 0.0, emin)
    emax = jnp.where(all_zero, 0.0, emax)
    span = jnp.maximum(emax - emin, 1e-6)
    if levels > 1:
        q = jnp.round((loge - emin) / span * (levels - 1))
        q = jnp.clip(q, 0, levels - 1)
        e = emin + q * span / (levels - 1)
    else:
        e = jnp.broadcast_to(emin, loge.shape)
    deq = jnp.where(nz, jnp.sign(g) * jnp.exp2(e), 0.0)
    return deq.reshape(x.shape)


def qdq_by_name(name: str):
    """Scheme registry used by tests, model.py and aot.py."""
    return {
        "rtn": rtn_qdq,
        "spike": spike_qdq,
        "hadamard": hadamard_qdq,
        "logfmt": logfmt_qdq,
    }[name]
