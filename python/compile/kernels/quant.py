"""L1 Pallas kernel: fused group-wise RTN quantize-dequantize.

This is the compute hot-spot of the paper's fused communication kernel
(§Experiments: one 4096-value chunk per CUDA block, 48 SMs). TPU adaptation
(DESIGN.md §Hardware-Adaptation): one grid step processes a
`(block_rows, row_len)` tile resident in VMEM; the per-group min/max
reduction, scale/zero computation (BF16-rounded, exactly the wire metadata
precision) and the quantize+dequantize all happen in a single pass over the
tile — one HBM read, one HBM write, like the fused CUDA kernel.

Must run with `interpret=True` on CPU: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows (groups-of-`group_size` runs) per VMEM tile. 64 rows x 128 lanes x 4B
# = 32 KiB in, well under VMEM; sized so the f32 tile + metadata fit with
# double-buffering room.
BLOCK_ROWS = 64


def _rtn_tile_kernel(x_ref, o_ref, *, bits: int, group_size: int):
    """One VMEM tile: rows of `row_len` split into groups of `group_size`."""
    x = x_ref[...]  # (rows, row_len) f32, one HBM->VMEM read
    rows, row_len = x.shape
    g = x.reshape(rows * (row_len // group_size), group_size)
    qmax = float(2**bits - 1)
    # Per-group reduction on the VPU (lane-aligned for gs in {32, 128}).
    mn = jnp.min(g, axis=-1, keepdims=True)
    mx = jnp.max(g, axis=-1, keepdims=True)
    rng = mx - mn
    scale = jnp.where(rng > 0, rng / qmax, 1.0)
    # Wire metadata is BF16: round scale/zero exactly like the rust codec.
    scale = scale.astype(jnp.bfloat16).astype(jnp.float32)
    zero = mn.astype(jnp.bfloat16).astype(jnp.float32)
    q = jnp.clip(jnp.floor((g - zero) / scale + 0.5), 0.0, qmax)
    o_ref[...] = (q * scale + zero).reshape(rows, row_len)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def rtn_qdq(x, bits: int, group_size: int):
    """Fused RTN QDQ over the last axis of `x` (any leading shape).

    Equivalent to `ref.rtn_qdq`; the Pallas grid walks row-tiles.
    """
    orig_shape = x.shape
    row_len = orig_shape[-1]
    assert row_len % group_size == 0, f"{row_len} % {group_size}"
    rows = x.size // row_len
    xr = x.reshape(rows, row_len)
    block_rows = min(BLOCK_ROWS, rows)
    # Pad rows to a multiple of the tile height.
    pad = (-rows) % block_rows
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    grid = (xr.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rtn_tile_kernel, bits=bits, group_size=group_size),
        out_shape=jax.ShapeDtypeStruct(xr.shape, jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, row_len), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, row_len), lambda i: (i, 0)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xr.astype(jnp.float32))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
