"""L1 Pallas kernel: fused spike-reserving quantize-dequantize (Fig. 5).

Same tile structure as quant.py, plus the spike machinery: per group the
kernel finds min/max (the spikes), re-reduces over the remaining elements
for the shrunken range, quantizes everything, and scatters the spikes back
at BF16 precision — all in one pass over the VMEM tile. The argmin/argmax
"first occurrence" tie-break matches the rust codec and ref.py exactly.

interpret=True — see quant.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import BLOCK_ROWS


def _bf16(v):
    return v.astype(jnp.bfloat16).astype(jnp.float32)


def _spike_tile_kernel(x_ref, o_ref, *, bits: int, group_size: int):
    x = x_ref[...]
    rows, row_len = x.shape
    g = x.reshape(rows * (row_len // group_size), group_size)
    qmax = float(2**bits - 1)

    # Spikes: first-occurrence min and max per group.
    mn = jnp.min(g, axis=-1, keepdims=True)
    mx = jnp.max(g, axis=-1, keepdims=True)
    is_min = g == mn
    first_min = is_min & (jnp.cumsum(is_min.astype(jnp.int32), axis=-1) == 1)
    is_max = g == mx
    first_max = is_max & (jnp.cumsum(is_max.astype(jnp.int32), axis=-1) == 1)
    spike = first_min | first_max

    # Shrunken range over the non-spike body.
    big = jnp.float32(3.4e38)
    mn2 = jnp.min(jnp.where(spike, big, g), axis=-1, keepdims=True)
    mx2 = jnp.max(jnp.where(spike, -big, g), axis=-1, keepdims=True)
    empty = mn2 > mx2  # group of <= 2 distinct elements: all spikes
    mn2 = jnp.where(empty, 0.0, mn2)
    mx2 = jnp.where(empty, 0.0, mx2)

    rng = mx2 - mn2
    scale = _bf16(jnp.where(rng > 0, rng / qmax, 1.0))
    zero = _bf16(mn2)
    q = jnp.clip(jnp.floor((g - zero) / scale + 0.5), 0.0, qmax)
    deq = q * scale + zero
    # Restore spikes at BF16 (the metadata precision of Fig. 5c).
    deq = jnp.where(first_max, _bf16(mx), deq)
    deq = jnp.where(first_min, _bf16(mn), deq)
    o_ref[...] = deq.reshape(rows, row_len)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def spike_qdq(x, bits: int, group_size: int):
    """Fused spike-reserving QDQ over the last axis (any leading shape)."""
    orig_shape = x.shape
    row_len = orig_shape[-1]
    assert row_len % group_size == 0, f"{row_len} % {group_size}"
    rows = x.size // row_len
    xr = x.reshape(rows, row_len)
    block_rows = min(BLOCK_ROWS, rows)
    pad = (-rows) % block_rows
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    grid = (xr.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_spike_tile_kernel, bits=bits, group_size=group_size),
        out_shape=jax.ShapeDtypeStruct(xr.shape, jnp.float32),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, row_len), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, row_len), lambda i: (i, 0)),
        interpret=True,
    )(xr.astype(jnp.float32))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
