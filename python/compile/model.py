"""L2: the JAX model — a TP-sharded decoder-only transformer plus a
mixture-of-experts variant, written as *pieces* that end exactly where the
paper's communication happens.

Tensor-parallel layout (Megatron-style):
  - attention: wq/wk/wv column-parallel (head blocks), wo row-parallel
    => `attn_part` returns a PARTIAL output that needs an AllReduce.
  - MLP: w1 column-parallel, w2 row-parallel
    => `mlp_part` returns a PARTIAL output that needs an AllReduce.

The rust coordinator (L3) executes one `attn_part`/`mlp_part` HLO per shard
and runs the real quantized collective between pieces; residual adds are
cheap element-wise ops done in rust. `qdq_eval_model` additionally bakes the
L1 Pallas QDQ kernels into a single-process eval graph (used for kernel
integration tests and the in-graph accuracy path).

Training uses whole-graph `grad_step` (fwd+bwd) and `adamw_update`; the DP
trainer in rust AllReduces the gradients between the two.
"""

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 1024
    seq_len: int = 128
    # MoE (0 experts = dense).
    n_experts: int = 0
    d_expert: int = 512
    moe_every: int = 2  # MoE replaces the MLP every k-th layer

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def is_moe_layer(self, layer: int) -> bool:
        return self.n_experts > 0 and layer % self.moe_every == 1

    def param_specs(self):
        """Ordered (name, shape) list — the flat parameter layout shared
        with rust (model/weights.rs reads the same order)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        specs = [("embed", (v, d))]
        for l in range(self.n_layers):
            specs += [
                (f"l{l}.ln1_g", (d,)),
                (f"l{l}.ln1_b", (d,)),
                (f"l{l}.wq", (d, d)),
                (f"l{l}.wk", (d, d)),
                (f"l{l}.wv", (d, d)),
                (f"l{l}.wo", (d, d)),
                (f"l{l}.ln2_g", (d,)),
                (f"l{l}.ln2_b", (d,)),
            ]
            if self.is_moe_layer(l):
                specs += [
                    (f"l{l}.router", (d, self.n_experts)),
                    (f"l{l}.we1", (self.n_experts, d, self.d_expert)),
                    (f"l{l}.we2", (self.n_experts, self.d_expert, d)),
                ]
            else:
                specs += [(f"l{l}.w1", (d, f)), (f"l{l}.w2", (f, d))]
        specs += [("lnf_g", (d,)), ("lnf_b", (d,))]
        return specs

    def n_params(self) -> int:
        return sum(int(np.prod(s)) for _, s in self.param_specs())


CONFIGS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(),
    "small": ModelConfig(
        name="small", vocab=4096, d_model=384, n_layers=6, n_heads=8, d_ff=1536
    ),
    "100m": ModelConfig(
        name="100m", vocab=8192, d_model=768, n_layers=12, n_heads=12, d_ff=3072,
        seq_len=256,
    ),
    "moe-tiny": ModelConfig(
        name="moe-tiny", vocab=2048, d_model=256, n_layers=4, n_heads=8, d_ff=1024,
        n_experts=8, d_expert=512,
    ),
}


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic scaled-normal init, returned as an ordered dict."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in cfg.param_specs():
        if name.endswith("_g"):
            params[name] = np.ones(shape, np.float32)
        elif name.endswith("_b"):
            params[name] = np.zeros(shape, np.float32)
        else:
            fan_in = shape[0] if len(shape) == 1 else int(np.prod(shape[:-1]))
            std = 0.02 if name == "embed" else 1.0 / np.sqrt(max(1, fan_in))
            params[name] = (rng.standard_normal(shape) * std).astype(np.float32)
    return params


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _causal_attention(q, k, v):
    """q,k,v: [B,S,H,hd] -> [B,S,H,hd]."""
    s = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


# ---------------------------------------------------------------------------
# TP pieces (one HLO per piece; weights are inputs, shared across shards).
# ---------------------------------------------------------------------------

def embed(tokens, emb):
    """tokens [B,S] i32, emb [V,D] -> h [B,S,D]."""
    return jnp.take(emb, tokens, axis=0)


def attn_part(h, ln_g, ln_b, wq, wk, wv, wo, *, n_heads_shard: int):
    """One TP shard of the attention block.

    h [B,S,D]; wq/wk/wv [D, Dh]; wo [Dh, D] with Dh = D/tp.
    Returns the PARTIAL pre-residual output [B,S,D] (needs AllReduce).
    """
    b, s, _ = h.shape
    x = layer_norm(h, ln_g, ln_b)
    q = (x @ wq).reshape(b, s, n_heads_shard, -1)
    k = (x @ wk).reshape(b, s, n_heads_shard, -1)
    v = (x @ wv).reshape(b, s, n_heads_shard, -1)
    o = _causal_attention(q, k, v).reshape(b, s, -1)
    return o @ wo


def mlp_part(h, ln_g, ln_b, w1, w2):
    """One TP shard of the MLP: w1 [D, F/tp], w2 [F/tp, D].

    Returns the PARTIAL pre-residual output (needs AllReduce).
    """
    x = layer_norm(h, ln_g, ln_b)
    return jax.nn.gelu(x @ w1) @ w2


def head_nll(h, lnf_g, lnf_b, emb, targets):
    """Final piece: per-token negative log-likelihood [B,S] + mean loss.

    Output-embedding tied to the input embedding.
    """
    x = layer_norm(h, lnf_g, lnf_b)
    logits = x @ emb.T
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll, jnp.mean(nll)


def head_acc(h, lnf_g, lnf_b, emb, targets):
    """Final piece for the downstream-accuracy suite (Table 7): returns
    (per-token top-1 correctness [B,S], predicted ids [B,S]) as f32 — the
    ids let the rust harness score pool-match (syntactic) tasks too."""
    x = layer_norm(h, lnf_g, lnf_b)
    logits = x @ emb.T
    pred = jnp.argmax(logits, axis=-1)
    return (pred == targets).astype(jnp.float32), pred.astype(jnp.float32)


def router_logits(h, ln_g, ln_b, router):
    """MoE router piece: returns (expert logits [B,S,E], normalized h).

    The normalized activations are the All2All *dispatch volume* — exactly
    what the paper quantizes (DeepSeek-V3 style) — so the rust EP engine
    gets both routing decisions and the payload from one piece."""
    x = layer_norm(h, ln_g, ln_b)
    return x @ router, x


def expert_mlp(x, w1, w2):
    """One expert on a fixed-capacity token batch [C,D]."""
    return jax.nn.gelu(x @ w1) @ w2


# ---------------------------------------------------------------------------
# Whole-graph forward (training / single-process eval).
# ---------------------------------------------------------------------------

def _moe_ffn_dense(x, router, we1, we2, n_experts):
    """Dense (one-hot) top-1 MoE used for training: every expert sees every
    token, masked by the routing decision. Mathematically identical to
    dispatch/combine EP, without ragged shapes."""
    logits = x @ router  # [B,S,E]
    gates = jax.nn.softmax(logits, axis=-1)
    top = jnp.argmax(gates, axis=-1)  # [B,S]
    onehot = jax.nn.one_hot(top, n_experts, dtype=x.dtype)  # [B,S,E]
    gate_val = jnp.sum(gates * onehot, axis=-1, keepdims=True)  # [B,S,1]
    expert_out = jnp.einsum(
        "bsd,edf->bsef", x, we1
    )
    expert_out = jax.nn.gelu(expert_out)
    expert_out = jnp.einsum("bsef,efd->bsed", expert_out, we2)
    mixed = jnp.einsum("bsed,bse->bsd", expert_out, onehot)
    # Load-balancing auxiliary loss (Switch-style).
    density = jnp.mean(onehot, axis=(0, 1))
    density_proxy = jnp.mean(gates, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * n_experts
    return mixed * gate_val, aux


def forward(cfg: ModelConfig, params: Dict[str, jax.Array], tokens,
            qdq: Optional[Callable] = None, moe_qdq: Optional[Callable] = None):
    """Full forward pass -> h before the head.

    `qdq(x)` is applied to each partial output before the residual add —
    simulating the TP AllReduce quantization exactly where the wire sits.
    `moe_qdq(x)` is applied to the MoE FFN input (the All2All dispatch
    volume, DeepSeek-V3 style: dispatch only).
    """
    h = embed(tokens, params["embed"])
    for l in range(cfg.n_layers):
        p = lambda k: params[f"l{l}.{k}"]  # noqa: E731
        a = attn_part(
            h, p("ln1_g"), p("ln1_b"), p("wq"), p("wk"), p("wv"), p("wo"),
            n_heads_shard=cfg.n_heads,
        )
        if qdq is not None:
            a = qdq(a)
        h = h + a
        if cfg.is_moe_layer(l):
            x = layer_norm(h, p("ln2_g"), p("ln2_b"))
            if moe_qdq is not None:
                x = moe_qdq(x)  # quantized dispatch volume
            m, _aux = _moe_ffn_dense(x, p("router"), p("we1"), p("we2"), cfg.n_experts)
        else:
            m = mlp_part(h, p("ln2_g"), p("ln2_b"), p("w1"), p("w2"))
            if qdq is not None:
                m = qdq(m)
        h = h + m
    return h


def loss_fn(cfg: ModelConfig, params, tokens, targets):
    h = forward(cfg, params, tokens)
    _, loss = head_nll(h, params["lnf_g"], params["lnf_b"], params["embed"], targets)
    if cfg.n_experts > 0:
        # Recompute aux losses (cheap at these sizes) for load balancing.
        aux = 0.0
        hh = embed(tokens, params["embed"])
        for l in range(cfg.n_layers):
            p = lambda k: params[f"l{l}.{k}"]  # noqa: E731
            a = attn_part(hh, p("ln1_g"), p("ln1_b"), p("wq"), p("wk"), p("wv"),
                          p("wo"), n_heads_shard=cfg.n_heads)
            hh = hh + a
            if cfg.is_moe_layer(l):
                x = layer_norm(hh, p("ln2_g"), p("ln2_b"))
                m, a_l = _moe_ffn_dense(x, p("router"), p("we1"), p("we2"), cfg.n_experts)
                aux = aux + a_l
            else:
                m = mlp_part(hh, p("ln2_g"), p("ln2_b"), p("w1"), p("w2"))
            hh = hh + m
        loss = loss + 0.01 * aux
    return loss


def make_grad_step(cfg: ModelConfig):
    """grad_step(params..., tokens, targets) -> (loss, grads...).

    Positional flat signature so the rust runtime can feed Literals.
    """
    names = [n for n, _ in cfg.param_specs()]

    def grad_step(*args):
        ps = dict(zip(names, args[: len(names)]))
        tokens, targets = args[len(names)], args[len(names) + 1]
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens, targets)
        )(ps)
        return (loss,) + tuple(grads[n] for n in names)

    return grad_step


def make_adamw_update(cfg: ModelConfig, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8,
                      wd=0.01):
    """adamw(step, params..., grads..., m..., v...) -> (params', m', v')."""
    names = [n for n, _ in cfg.param_specs()]
    k = len(names)

    def update(*args):
        step = args[0]
        ps, gs, ms, vs = (args[1:1 + k], args[1 + k:1 + 2 * k],
                          args[1 + 2 * k:1 + 3 * k], args[1 + 3 * k:1 + 4 * k])
        t = step.astype(jnp.float32) + 1.0
        outs_p, outs_m, outs_v = [], [], []
        for name, p, g, m, v in zip(names, ps, gs, ms, vs):
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** t)
            vhat = v2 / (1 - b2 ** t)
            decay = 0.0 if name.endswith(("_g", "_b")) else wd
            p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + decay * p)
            outs_p.append(p2)
            outs_m.append(m2)
            outs_v.append(v2)
        return tuple(outs_p) + tuple(outs_m) + tuple(outs_v)

    return update


def make_eval_nll(cfg: ModelConfig, scheme: Optional[str] = None,
                  bits: int = 8, group_size: int = 128,
                  target: str = "allreduce", use_pallas: bool = False):
    """eval_nll(params..., tokens, targets) -> (sum_nll, count).

    `scheme` in {None, 'rtn', 'spike', 'hadamard', 'logfmt'} applies QDQ at
    the TP AllReduce boundary (`target='allreduce'`) or at the MoE dispatch
    (`target='dispatch'`). `use_pallas=True` routes RTN/spike through the L1
    Pallas kernels instead of the jnp reference (identical numerics —
    asserted by tests)."""
    from .kernels import ref as ref_k

    names = [n for n, _ in cfg.param_specs()]
    qdq = None
    if scheme is not None:
        if use_pallas and scheme == "rtn":
            from .kernels.quant import rtn_qdq as fn
        elif use_pallas and scheme == "spike":
            from .kernels.spike import spike_qdq as fn
        else:
            fn = ref_k.qdq_by_name(scheme)
        qdq = functools.partial(fn, bits=bits, group_size=group_size)

    def eval_nll(*args):
        ps = dict(zip(names, args[: len(names)]))
        tokens, targets = args[len(names)], args[len(names) + 1]
        ar_qdq = qdq if target == "allreduce" else None
        moe_qdq = qdq if target == "dispatch" else None
        h = forward(cfg, ps, tokens, qdq=ar_qdq, moe_qdq=moe_qdq)
        nll, _ = head_nll(h, ps["lnf_g"], ps["lnf_b"], ps["embed"], targets)
        return jnp.sum(nll), jnp.float32(nll.size)

    return eval_nll


def shard_param(name: str, value: np.ndarray, tp: int, shard: int) -> np.ndarray:
    """TP weight slicing, mirrored by rust model/weights.rs.

    Column-parallel (wq/wk/wv/w1): split last axis. Row-parallel (wo/w2):
    split first axis. Everything else is replicated."""
    base = name.split(".")[-1]
    if base in ("wq", "wk", "wv", "w1"):
        cols = value.shape[-1] // tp
        return value[..., shard * cols:(shard + 1) * cols]
    if base in ("wo", "w2"):
        rows = value.shape[0] // tp
        return value[shard * rows:(shard + 1) * rows]
    return value
