"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, bit widths and group sizes; assert_allclose
against ref.py is the core correctness signal for the kernel layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant import rtn_qdq
from compile.kernels.spike import spike_qdq

BITS = st.sampled_from([2, 3, 4, 5, 6, 8])
GS = st.sampled_from([32, 128])


def activations(rng: np.random.Generator, shape) -> np.ndarray:
    """Heavy-tailed activation-like data with rare massive outliers."""
    x = rng.standard_t(4, size=shape).astype(np.float32)
    mask = rng.random(shape) < 1e-3
    x = np.where(mask, np.float32(40.0) * np.sign(x), x)
    return x


@settings(max_examples=40, deadline=None)
@given(
    bits=BITS,
    gs=GS,
    rows=st.integers(1, 70),
    groups_per_row=st.integers(1, 4),
    seed=st.integers(0, 2**32 - 1),
)
def test_pallas_rtn_matches_ref(bits, gs, rows, groups_per_row, seed):
    rng = np.random.default_rng(seed)
    x = activations(rng, (rows, groups_per_row * gs))
    got = rtn_qdq(jnp.asarray(x), bits=bits, group_size=gs)
    want = ref.rtn_qdq(jnp.asarray(x), bits, gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4]),
    gs=GS,
    rows=st.integers(1, 70),
    seed=st.integers(0, 2**32 - 1),
)
def test_pallas_spike_matches_ref(bits, gs, rows, seed):
    rng = np.random.default_rng(seed)
    x = activations(rng, (rows, gs))
    got = spike_qdq(jnp.asarray(x), bits=bits, group_size=gs)
    want = ref.spike_qdq(jnp.asarray(x), bits, gs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(bits=BITS, seed=st.integers(0, 2**32 - 1))
def test_rtn_error_bounded_by_half_step(bits, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((8, 128)).astype(np.float32)
    y = np.asarray(rtn_qdq(jnp.asarray(x), bits=bits, group_size=32))
    for r in range(8):
        for g in range(4):
            grp = x[r, g * 32:(g + 1) * 32]
            step = (grp.max() - grp.min()) / (2**bits - 1)
            bound = 0.5 * step + np.abs(grp).max() / 128.0 + 1e-6
            err = np.abs(y[r, g * 32:(g + 1) * 32] - grp).max()
            assert err <= bound, (bits, r, g, err, bound)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_spike_preserves_extrema(seed):
    rng = np.random.default_rng(seed)
    x = activations(rng, (16, 32))
    y = np.asarray(spike_qdq(jnp.asarray(x), bits=2, group_size=32))
    for r in range(16):
        for (f, g) in [(np.min, "min"), (np.max, "max")]:
            want = f(x[r])
            got = f(y[r])
            assert abs(got - want) <= abs(want) / 128.0 + 1e-6, (g, r, want, got)


def test_spike_shrinks_range_fig4():
    rng = np.random.default_rng(7)
    x = activations(rng, (64, 32))
    rtn = np.asarray(rtn_qdq(jnp.asarray(x), bits=2, group_size=32))
    sr = np.asarray(spike_qdq(jnp.asarray(x), bits=2, group_size=32))
    assert np.mean((sr - x) ** 2) < 0.6 * np.mean((rtn - x) ** 2)


def test_scheme_ordering_at_int2():
    """Table 3's ordering on heavy-tailed data: SR best, LogFMT collapses."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(activations(rng, (256, 128)))
    mse = {
        name: float(jnp.mean((ref.qdq_by_name(name)(x, 2, 32) - x) ** 2))
        for name in ["rtn", "spike", "hadamard", "logfmt"]
    }
    assert mse["spike"] < mse["rtn"], mse
    assert mse["spike"] < mse["hadamard"], mse
    assert mse["logfmt"] > mse["spike"] * 2, mse


def test_monotone_in_bits():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32))
    prev = np.inf
    for bits in [2, 3, 4, 5, 6, 8]:
        m = float(jnp.mean((rtn_qdq(x, bits=bits, group_size=128) - x) ** 2))
        assert m < prev, (bits, m, prev)
        prev = m


def test_constant_and_zero_groups():
    x = jnp.concatenate([jnp.full((1, 32), 5.0), jnp.zeros((1, 32))], axis=0)
    for f in (rtn_qdq, spike_qdq):
        y = np.asarray(f(x, bits=2, group_size=32))
        np.testing.assert_allclose(y[0], 5.0, atol=0.05)
        np.testing.assert_allclose(y[1], 0.0, atol=1e-6)


def test_odd_leading_shapes():
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal((3, 5, 128)).astype(np.float32))
    y = rtn_qdq(x, bits=4, group_size=32)
    assert y.shape == x.shape
    w = ref.rtn_qdq(x, 4, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(w), atol=1e-6)
