"""L2 model tests: TP shard consistency, QDQ-at-the-boundary ordering,
training step sanity, MoE routing."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def tiny():
    cfg = M.CONFIGS["tiny"]
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed=1).items()}
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, cfg.seq_len), dtype=np.int32))
    return cfg, params, tokens


def test_param_specs_order_is_stable(tiny):
    cfg, params, _ = tiny
    names = [n for n, _ in cfg.param_specs()]
    assert names[0] == "embed" and names[-1] == "lnf_b"
    assert list(params.keys()) == names


def test_forward_shapes(tiny):
    cfg, params, tokens = tiny
    h = M.forward(cfg, params, tokens)
    assert h.shape == (2, cfg.seq_len, cfg.d_model)
    nll, loss = M.head_nll(h, params["lnf_g"], params["lnf_b"], params["embed"],
                           tokens)
    assert nll.shape == (2, cfg.seq_len)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("tp", [2, 4, 8])
def test_tp_shards_sum_to_full_attention(tiny, tp):
    """The core TP invariant: per-shard partial outputs sum to the
    unsharded block output — what the rust engine's AllReduce computes."""
    cfg, params, tokens = tiny
    h = M.embed(tokens, params["embed"])
    p = lambda k: params[f"l0.{k}"]  # noqa: E731
    full = M.attn_part(h, p("ln1_g"), p("ln1_b"), p("wq"), p("wk"), p("wv"),
                       p("wo"), n_heads_shard=cfg.n_heads)
    acc = jnp.zeros_like(full)
    for shard in range(tp):
        sh = {
            w: jnp.asarray(M.shard_param(f"l0.{w}", np.asarray(p(w)), tp, shard))
            for w in ["wq", "wk", "wv", "wo"]
        }
        acc = acc + M.attn_part(h, p("ln1_g"), p("ln1_b"), sh["wq"], sh["wk"],
                                sh["wv"], sh["wo"],
                                n_heads_shard=cfg.n_heads // tp)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full), atol=2e-4)


@pytest.mark.parametrize("tp", [2, 4])
def test_tp_shards_sum_to_full_mlp(tiny, tp):
    cfg, params, tokens = tiny
    h = M.embed(tokens, params["embed"])
    p = lambda k: params[f"l0.{k}"]  # noqa: E731
    full = M.mlp_part(h, p("ln2_g"), p("ln2_b"), p("w1"), p("w2"))
    acc = jnp.zeros_like(full)
    for shard in range(tp):
        w1 = jnp.asarray(M.shard_param("l0.w1", np.asarray(p("w1")), tp, shard))
        w2 = jnp.asarray(M.shard_param("l0.w2", np.asarray(p("w2")), tp, shard))
        acc = acc + M.mlp_part(h, p("ln2_g"), p("ln2_b"), w1, w2)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full), atol=2e-4)


def test_qdq_eval_ordering(tiny):
    """Lower communication bits => higher NLL, and INT8 ≈ clean (Table 1)."""
    cfg, params, tokens = tiny
    targets = jnp.roll(tokens, -1, axis=1)
    flat = [params[n] for n, _ in cfg.param_specs()]

    def nll(scheme, bits, gs):
        fn = M.make_eval_nll(cfg, scheme, bits, gs)
        s, c = fn(*flat, tokens, targets)
        return float(s) / float(c)

    clean = nll(None, 0, 0)
    int8 = nll("rtn", 8, 128)
    int2 = nll("rtn", 2, 32)
    int2_sr = nll("spike", 2, 32)
    assert abs(int8 - clean) < 0.05 * abs(clean) + 0.05, (clean, int8)
    assert int2 > int8, (int8, int2)
    assert int2_sr < int2, (int2_sr, int2)


def test_grad_step_improves_loss():
    cfg = M.CONFIGS["tiny"]
    params = M.init_params(cfg, seed=3)
    names = [n for n, _ in cfg.param_specs()]
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab, (4, cfg.seq_len), dtype=np.int32)
    tgts = np.roll(toks, -1, axis=1).astype(np.int32)
    grad_step = jax.jit(M.make_grad_step(cfg))
    flat = [jnp.asarray(params[n]) for n in names]
    out = grad_step(*flat, jnp.asarray(toks), jnp.asarray(tgts))
    loss0, grads = float(out[0]), out[1:]
    # Two SGD steps on the same batch must reduce the loss.
    lr = 0.05
    for _ in range(2):
        out = grad_step(*flat, jnp.asarray(toks), jnp.asarray(tgts))
        grads = out[1:]
        flat = [p - lr * g for p, g in zip(flat, grads)]
    loss1 = float(grad_step(*flat, jnp.asarray(toks), jnp.asarray(tgts))[0])
    assert loss1 < loss0 - 0.05, (loss0, loss1)


def test_adamw_update_shapes_and_step():
    cfg = M.CONFIGS["tiny"]
    params = M.init_params(cfg, seed=5)
    names = [n for n, _ in cfg.param_specs()]
    flat = [jnp.asarray(params[n]) for n in names]
    zeros = [jnp.zeros_like(p) for p in flat]
    ones_grads = [jnp.ones_like(p) * 0.1 for p in flat]
    update = jax.jit(M.make_adamw_update(cfg))
    out = update(jnp.float32(0), *flat, *ones_grads, *zeros, *zeros)
    k = len(names)
    assert len(out) == 3 * k
    for p0, p1 in zip(flat, out[:k]):
        assert p1.shape == p0.shape
        assert float(jnp.max(jnp.abs(p1 - p0))) > 0  # moved
        assert float(jnp.max(jnp.abs(p1 - p0))) < 0.01  # but boundedly


def test_moe_forward_and_grads():
    cfg = M.CONFIGS["moe-tiny"]
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, seed=6).items()}
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, cfg.seq_len), dtype=np.int32))
    h = M.forward(cfg, params, toks)
    assert h.shape == (2, cfg.seq_len, cfg.d_model)
    loss = M.loss_fn(cfg, params, toks, jnp.roll(toks, -1, axis=1))
    assert np.isfinite(float(loss))
    # Router must receive gradient (load-balancing aux ensures it).
    g = jax.grad(lambda p: M.loss_fn(cfg, p, toks, jnp.roll(toks, -1, axis=1)))(params)
    assert float(jnp.max(jnp.abs(g["l1.router"]))) > 0


def test_moe_dense_equals_capacity_dispatch():
    """The dense one-hot MoE (training path) equals explicit top-1
    dispatch/combine (what the rust EP engine does), per token."""
    cfg = M.CONFIGS["moe-tiny"]
    params = M.init_params(cfg, seed=8)
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)).astype(np.float32))
    router = jnp.asarray(params["l1.router"])
    we1 = jnp.asarray(params["l1.we1"])
    we2 = jnp.asarray(params["l1.we2"])
    dense, _ = M._moe_ffn_dense(x, router, we1, we2, cfg.n_experts)
    # Explicit dispatch.
    logits = x @ router
    gates = jax.nn.softmax(logits, axis=-1)
    top = np.asarray(jnp.argmax(gates, axis=-1))[0]
    out = np.zeros_like(np.asarray(dense))
    for t in range(16):
        e = int(top[t])
        y = M.expert_mlp(x[0, t][None], we1[e], we2[e])[0]
        out[0, t] = np.asarray(y) * float(gates[0, t, e])
    np.testing.assert_allclose(out, np.asarray(dense), atol=1e-4)


def test_shard_param_roundtrip():
    cfg = M.CONFIGS["tiny"]
    params = M.init_params(cfg, seed=10)
    for name in ["l0.wq", "l0.w1"]:
        full = params[name]
        shards = [M.shard_param(name, full, 4, k) for k in range(4)]
        np.testing.assert_array_equal(np.concatenate(shards, axis=-1), full)
    for name in ["l0.wo", "l0.w2"]:
        full = params[name]
        shards = [M.shard_param(name, full, 4, k) for k in range(4)]
        np.testing.assert_array_equal(np.concatenate(shards, axis=0), full)
    np.testing.assert_array_equal(M.shard_param("embed", params["embed"], 4, 2),
                                  params["embed"])
