"""AOT pipeline tests: weights format round-trip, HLO text lowering."""

import os
import tempfile

import numpy as np
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_weights_roundtrip():
    cfg = M.CONFIGS["tiny"]
    params = M.init_params(cfg, seed=0)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "w.bin")
        aot.write_weights(p, params)
        back = aot.read_weights(p)
        assert list(back.keys()) == list(params.keys())
        for k in params:
            np.testing.assert_array_equal(back[k], params[k])


def test_lower_produces_parseable_hlo_text():
    import jax.numpy as jnp
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "f.hlo.txt")
        aot.lower_to_file(lambda x: (x * 2.0 + 1.0,), [np.zeros((4,), np.float32)], p)
        text = open(p).read()
        assert text.startswith("HloModule"), text[:80]
        assert "ENTRY" in text


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.txt")),
                    reason="run `make artifacts` first")
def test_built_artifacts_manifest_consistent():
    lines = open(os.path.join(ART, "manifest.txt")).read().strip().splitlines()
    arts = [l.split()[1] for l in lines if l.startswith("artifact ")]
    assert len(arts) >= 15
    for a in arts:
        path = os.path.join(ART, f"{a}.hlo.txt")
        assert os.path.exists(path), a
        head = open(path).read(32)
        assert head.startswith("HloModule"), (a, head)
    cfgs = [l for l in lines if l.startswith("config ")]
    assert any("tiny" in c for c in cfgs)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "corpus_v2048.bin")),
                    reason="run `make artifacts` first")
def test_built_corpus_loads():
    from compile import corpus as C
    toks, vocab = C.read_corpus(os.path.join(ART, "corpus_v2048.bin"))
    assert vocab == 2048
    assert len(toks) == 600_000
    assert toks.max() < 2048
