"""Corpus generator tests: determinism, structure, format round-trip."""

import os
import tempfile

import numpy as np

from compile import corpus as C


def test_deterministic():
    a = C.generate_tokens(2048, 10_000)
    b = C.generate_tokens(2048, 10_000)
    np.testing.assert_array_equal(a, b)
    c = C.generate_tokens(2048, 10_000, seed=1)
    assert not np.array_equal(a, c)


def test_tokens_in_vocab():
    t = C.generate_tokens(1024, 20_000)
    assert t.min() >= 0 and t.max() < 1024
    assert t.dtype == np.uint16


def test_zipfian_head():
    t = C.generate_tokens(2048, 100_000)
    counts = np.bincount(t, minlength=2048)[C.N_SPECIAL:]
    counts.sort()
    top = counts[-50:].sum()
    assert top > 0.25 * counts.sum(), "frequency head too flat"
    assert (counts > 0).sum() > 500, "vocabulary coverage too small"


def test_structure_is_learnable():
    # Bigram entropy must sit well below unigram entropy: the grammar has
    # learnable conditional structure (what the LM trains on).
    t = C.generate_tokens(2048, 200_000).astype(np.int64)
    uni = np.bincount(t, minlength=2048).astype(float)
    pu = uni / uni.sum()
    hu = -(pu[pu > 0] * np.log(pu[pu > 0])).sum()
    pairs = t[:-1] * 2048 + t[1:]
    bi = np.bincount(pairs, minlength=2048 * 2048).astype(float)
    pb = bi / bi.sum()
    hb = -(pb[pb > 0] * np.log(pb[pb > 0])).sum()
    cond = hb - hu  # H(next | prev)
    assert cond < hu - 0.5, (hu, cond)


def test_format_roundtrip():
    t = C.generate_tokens(512, 5_000)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "c.bin")
        C.write_corpus(p, t, 512)
        back, vocab = C.read_corpus(p)
        assert vocab == 512
        np.testing.assert_array_equal(back, t)


def test_split():
    t = C.generate_tokens(512, 10_000)
    train, ev = C.train_eval_split(t, 0.1)
    assert len(train) + len(ev) == len(t)
    assert len(ev) == 1000
