//! Expert-parallel dispatch over the real All2All fabric.
//!
//! ```sh
//! cargo run --release --example moe_dispatch -- [codec] [steps]
//! ```
//!
//! Demonstrates the full EP round trip the MoE engine models, but with the
//! *actual thread-fabric All2All* (`Communicator::all2all`) carrying the tokens:
//!
//! 1. rust router: top-1 expert per token from the `router` HLO piece,
//! 2. tokens grouped per destination rank (1 expert per rank, EP=8),
//! 3. quantized dispatch All2All across 8 rank threads,
//! 4. each rank runs its expert's HLO on the received (padded) batch,
//! 5. BF16 combine All2All back to the owners.
//!
//! Verifies the fabric path produces the same expert outputs as the local
//! MoE engine's computation (within wire precision), and reports dispatch
//! volumes per codec.

use flashcomm::comm::{fabric, Communicator};
use flashcomm::coordinator::pretrain::{ensure_trained, TEST_STEPS};
use flashcomm::model::{Corpus, Sampler};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, tokens_literal, Runtime, Tensor};
use flashcomm::topo::{presets, Topology};
use flashcomm::util::stats::sqnr_db;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let codec = Codec::parse(argv.first().map(|s| s.as_str()).unwrap_or("int4@32"))?;
    let steps: usize = argv.get(1).map(|s| s.parse()).transpose()?.unwrap_or(TEST_STEPS);

    let (cfg, weights, _) = ensure_trained("moe-tiny", steps)?;
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let batch = &Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len)[0];
    let mut rt = Runtime::open(default_artifacts_dir())?;

    // Run embed + layer-0 attention path quickly to get realistic hidden
    // states, then route at the first MoE layer (layer 1).
    let layer = 1usize;
    let toks = tokens_literal(&batch.tokens, &[batch.batch, batch.seq])?;
    let emb = weights.get("embed")?.to_literal()?;
    let h = rt
        .execute_t(&cfg.art("embed"), &[toks, emb])?
        .into_iter()
        .next()
        .unwrap();
    let d = cfg.d_model;
    let n_tokens = h.len() / d;

    // Router piece: logits + normalized activations (the dispatch volume).
    let router_args = vec![
        h.to_literal()?,
        weights.get(&format!("l{layer}.ln2_g"))?.to_literal()?,
        weights.get(&format!("l{layer}.ln2_b"))?.to_literal()?,
        weights.get(&format!("l{layer}.router"))?.to_literal()?,
    ];
    let out = rt.execute_t(&cfg.art("router"), &router_args)?;
    let (logits, xnorm) = (&out[0], &out[1]);
    let e = cfg.n_experts;
    let mut dest = vec![0usize; n_tokens];
    for t in 0..n_tokens {
        let row = &logits.data[t * e..(t + 1) * e];
        dest[t] = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
    }
    let mut counts = vec![0usize; e];
    for &x in &dest {
        counts[x] += 1;
    }
    println!("routed {n_tokens} tokens to {e} experts: {counts:?} (capacity {})", cfg.capacity);

    // Group payloads per destination rank (expert x lives on rank x).
    let sends: Vec<Vec<Vec<f32>>> = (0..e)
        .map(|src_rank| {
            // EP: every rank owns an equal slice of the tokens.
            let lo = src_rank * n_tokens / e;
            let hi = (src_rank + 1) * n_tokens / e;
            let mut per_dst = vec![Vec::new(); e];
            for t in lo..hi {
                per_dst[dest[t]].extend_from_slice(&xnorm.data[t * d..(t + 1) * d]);
            }
            per_dst
        })
        .collect();

    // Reference: what the experts see with a BF16 (lossless-ish) wire.
    let topo = Topology::new(presets::h800(), e);
    let run = |codec: Codec| {
        let sends = &sends;
        let (results, counters) = fabric::run_ranks(&topo, move |hnd| {
            let mut comm = Communicator::from_handle(hnd);
            let received =
                comm.all2all(&sends[comm.rank()], &codec).expect("dispatch all2all failed");
            // Expert rank: concatenate everything it received (its expert's
            // token batch) — returned for verification.
            received.concat()
        });
        (results, counters.total_bytes())
    };
    let (reference, _) = run(Codec::Bf16);
    let (quantized, wire) = run(codec);

    println!("\ndispatch codec {}: total wire {} bytes", codec.name(), wire);
    for x in 0..e {
        if reference[x].is_empty() {
            continue;
        }
        let s = sqnr_db(&reference[x], &quantized[x]);
        println!("  expert {x}: {:>6} values, dispatch SQNR {s:>7.2} dB", reference[x].len());
    }

    // Run one expert HLO on its (capacity-padded) received batch, proving
    // the dispatch payload composes with the compute piece.
    let x = counts.iter().enumerate().max_by_key(|(_, c)| **c).unwrap().0;
    let cap = cfg.capacity;
    let mut padded = vec![0f32; cap * d];
    let take = quantized[x].len().min(cap * d);
    padded[..take].copy_from_slice(&quantized[x][..take]);
    let we1 = weights.get(&format!("l{layer}.we1"))?;
    let we2 = weights.get(&format!("l{layer}.we2"))?;
    let f = cfg.d_expert;
    let w1 = Tensor::new(vec![d, f], we1.data[x * d * f..(x + 1) * d * f].to_vec());
    let w2 = Tensor::new(vec![f, d], we2.data[x * d * f..(x + 1) * d * f].to_vec());
    let y = rt.execute_t(
        &cfg.art("expert"),
        &[Tensor::new(vec![cap, d], padded).to_literal()?, w1.to_literal()?, w2.to_literal()?],
    )?;
    println!(
        "\nexpert {x} executed on {} tokens (padded to capacity {}), output shape {:?}",
        take / d,
        cap,
        y[0].shape
    );
    println!("combine direction would All2All these back at BF16 (dispatch-only quantization).");
    Ok(())
}
