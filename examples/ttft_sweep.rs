//! TTFT sweep (Fig. 2) over prompt lengths and devices.
//!
//! ```sh
//! cargo run --release --example ttft_sweep -- [batch]
//! ```
//!
//! Shows where communication quantization pays: the comm share of TTFT on
//! each device, and the crossover where the QDQ tax eats the volume win.

use flashcomm::coordinator::ttft::{algo_for, ttft_s, PrefillWorkload};
use flashcomm::quant::Codec;
use flashcomm::topo::{presets, Topology};

fn main() -> anyhow::Result<()> {
    let batch: usize =
        std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(1);
    let specs = ["bf16", "int8", "int5", "int4@32", "int2-sr@32"];
    for prompt in [256usize, 1024, 4096] {
        println!("=== prompt {prompt}, batch {batch}, TP=8, Llama-3-8B-class ===");
        print!("{:>6}", "GPU");
        for s in specs {
            print!(" {:>16}", s);
        }
        println!();
        for dev in presets::all() {
            let name = dev.name;
            let topo = Topology::new(dev, 8);
            let wl = PrefillWorkload { prompt_len: prompt, batch, ..Default::default() };
            let base = ttft_s(&topo, &wl, &Codec::Bf16, algo_for(&topo, &wl, &Codec::Bf16));
            print!("{name:>6}");
            for s in specs {
                let codec = if s == "bf16" { Codec::Bf16 } else { Codec::parse(s)? };
                let t = ttft_s(&topo, &wl, &codec, algo_for(&topo, &wl, &codec));
                print!(" {:>9.1}ms {:>4.2}x", t * 1e3, base / t);
            }
            println!();
        }
        println!();
    }
    println!("shape (paper Fig. 2): L40 gains most (hier+PP), H800/A100 moderate, H20 ~none");
    Ok(())
}
