//! Tensor-parallel inference under communication quantization.
//!
//! ```sh
//! cargo run --release --example tp_inference -- [ckpt.bin] [batches]
//! ```
//!
//! Loads a checkpoint (training one briefly if none is given), shards it
//! Megatron-style across TP=4 ranks, and serves eval batches through the
//! per-shard HLO pieces with the paper's quantized AllReduce between
//! pieces — comparing the two-step and hierarchical QDQ chains, plus wire
//! volume per token.

use flashcomm::comm::{Algo, AlgoPolicy};
use flashcomm::coordinator::pretrain::{ensure_trained, ACCURACY_STEPS};
use flashcomm::coordinator::TpEngine;
use flashcomm::model::{Corpus, Sampler, Weights};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, Runtime};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let n_batches: usize = argv.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4);

    let (cfg, weights) = match argv.first() {
        Some(p) if p != "-" => {
            let rt = Runtime::open(default_artifacts_dir())?;
            let cfg = flashcomm::model::ModelConfig::from_record(rt.manifest.config("tiny")?)?;
            (cfg, Weights::load(p)?)
        }
        _ => {
            let (cfg, w, _) = ensure_trained("tiny", ACCURACY_STEPS)?;
            (cfg, w)
        }
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let batches: Vec<_> = Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len)
        .into_iter()
        .take(n_batches)
        .collect();

    let rt = Runtime::open(default_artifacts_dir())?;
    let mut engine = TpEngine::new(
        rt,
        cfg.clone(),
        &weights,
        Codec::Bf16,
        AlgoPolicy::Fixed(Algo::TwoStep),
    )?;

    let tokens_per_batch = cfg.eval_batch * cfg.seq_len;
    // Per-token AllReduce volume: 2 boundaries x n_layers x d_model floats.
    let floats_per_token = 2 * cfg.n_layers * cfg.d_model;
    println!(
        "TP={} inference, {} eval batches ({} tokens each), {} AllReduce floats/token",
        cfg.tp,
        batches.len(),
        tokens_per_batch,
        floats_per_token
    );
    println!(
        "{:<14} {:>12} {:>12} {:>14}",
        "wire codec", "ppl 2-step", "ppl hier", "wire B/token"
    );
    for spec in ["bf16", "int8", "int6", "int5", "int4@32", "int3@32", "int3-sr@32",
                 "int2@32", "int2-sr@32", "int2-sr@32!"] {
        let codec = Codec::parse(spec)?;
        engine.set_codec(codec, AlgoPolicy::Fixed(Algo::TwoStep))?;
        let two = engine.perplexity(&batches)?;
        engine.set_codec(codec, AlgoPolicy::Fixed(Algo::Hier))?;
        let hier = engine.perplexity(&batches)?;
        let wire = codec.wire_len(floats_per_token);
        println!("{spec:<14} {two:>12.3} {hier:>12.3} {wire:>14}");
    }
    println!("\nINT5 retains BF16-level quality at ~1/3 the wire volume — the");
    println!("paper's 'any-bit' motivation; SR rescues INT3/INT2 (Tables 1/3).");
    Ok(())
}
