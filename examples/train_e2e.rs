//! End-to-end driver: train a transformer from scratch through the full
//! three-layer stack, then evaluate it under communication quantization.
//!
//! ```sh
//! cargo run --release --example train_e2e -- [steps] [dp] [codec]
//! # default: 300 steps, dp=4, int8 gradient AllReduce
//! ```
//!
//! Every optimizer step: 4 DP ranks execute the AOT `grad_step` HLO
//! (fwd+bwd, lowered from JAX; the Pallas QDQ kernels live in the same
//! artifact set), the gradients cross the real thread fabric through the
//! paper's quantized two-step AllReduce, and one `adamw` HLO execution
//! updates the replicated parameters. Python is never invoked.
//!
//! The run logs the loss curve (recorded in EXPERIMENTS.md) and finishes
//! with a TP-engine perplexity sweep across wire codecs on the trained
//! checkpoint — Tables 1/3 in miniature.

use flashcomm::comm::{Algo, AlgoPolicy};
use flashcomm::coordinator::pretrain::checkpoints_dir;
use flashcomm::coordinator::{TpEngine, TrainOptions, Trainer};
use flashcomm::model::{Corpus, ModelConfig, Sampler, Weights};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, Runtime};

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = argv.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let dp: usize = argv.get(1).map(|s| s.parse()).transpose()?.unwrap_or(4);
    let codec = Codec::parse(argv.get(2).map(|s| s.as_str()).unwrap_or("int8"))?;

    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config("tiny")?)?;
    let init =
        Weights::load(default_artifacts_dir().join("tiny_init_weights.bin"))?;
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (train, eval) = corpus.split();
    let eval_batches = Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len);

    println!(
        "=== e2e: training `tiny` ({} params) for {steps} steps, dp={dp}, grads over {} ===",
        cfg.n_params,
        codec.name()
    );
    let mut sampler = Sampler::new(train, 7);
    let mut trainer = Trainer::new(rt, cfg.clone(), &init)?;
    let opts = TrainOptions {
        steps,
        dp,
        codec,
        algo: AlgoPolicy::Fixed(Algo::TwoStep),
        log_every: 10,
        eval_every: 50,
        eval_batches: 8,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let recs = trainer.train(&mut sampler, &eval_batches, &opts)?;
    let wall = t0.elapsed().as_secs_f64();
    let tokens = (steps * dp * cfg.train_batch * cfg.seq_len) as f64;
    println!(
        "\ntrained {:.0} tokens in {:.1}s ({:.0} tok/s); loss {:.4} -> {:.4}",
        tokens,
        wall,
        tokens / wall,
        recs.first().unwrap().loss,
        recs.last().unwrap().loss
    );
    let ppl = trainer.eval_ppl(&eval_batches[..8.min(eval_batches.len())])?;
    println!("held-out perplexity (clean comm): {ppl:.3}");
    let ckpt = checkpoints_dir().join("tiny_e2e.bin");
    let weights = trainer.export_weights()?;
    weights.save(&ckpt)?;
    println!("checkpoint: {ckpt:?}");

    println!("\n=== TP inference on the trained model across wire codecs ===");
    let rt = Runtime::open(default_artifacts_dir())?;
    let mut engine = TpEngine::new(
        rt,
        cfg.clone(),
        &weights,
        Codec::Bf16,
        AlgoPolicy::Fixed(Algo::TwoStep),
    )?;
    let batches = &eval_batches[..4.min(eval_batches.len())];
    println!("{:<14} {:>10}", "wire codec", "ppl");
    for spec in ["bf16", "int8", "int6", "int5", "int4@32", "int3@32", "int3-sr@32",
                 "int2@32", "int2-sr@32"] {
        engine.set_codec(Codec::parse(spec)?, AlgoPolicy::Fixed(Algo::TwoStep))?;
        println!("{:<14} {:>10.3}", spec, engine.perplexity(batches)?);
    }
    println!("\n(loss curve + this sweep are recorded in EXPERIMENTS.md)");
    Ok(())
}
