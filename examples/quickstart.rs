//! Quickstart: the FlashCommunication V2 codec + collectives in 60 lines.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Quantize an activation tensor at several bit widths (bit splitting),
//! 2. run a real quantized AllReduce across 8 in-process ranks,
//! 3. show the accuracy/volume trade-off and the spike-reserving rescue.

use flashcomm::comm::{fabric, Algo, AlgoPolicy, Communicator};
use flashcomm::quant::Codec;
use flashcomm::topo::{presets, Topology};
use flashcomm::util::stats::sqnr_db;
use flashcomm::util::Prng;

fn main() -> anyhow::Result<()> {
    // Heavy-tailed "activation-like" data (what TP AllReduce carries).
    let mut rng = Prng::new(42);
    let mut x = vec![0f32; 1 << 16];
    rng.fill_activations(&mut x, 1.0);

    println!("--- codec roundtrip: 64K activations ---");
    println!("{:<14} {:>10} {:>8} {:>9}", "codec", "wire", "ratio", "SQNR dB");
    for spec in ["bf16", "int8", "int6", "int5", "int4@32", "int3@32", "int2@32", "int2-sr@32",
                 "int2-sr@32!"] {
        let codec = Codec::parse(spec)?;
        let wire = codec.encode(&x);
        let mut back = vec![0f32; x.len()];
        Codec::decode(&wire, &mut back)?;
        println!(
            "{:<14} {:>10} {:>7.1}% {:>9.2}",
            spec,
            wire.len(),
            100.0 * wire.len() as f64 / (2 * x.len()) as f64,
            sqnr_db(&x, &back)
        );
    }

    println!("\n--- quantized two-step AllReduce across 8 ranks ---");
    let topo = Topology::new(presets::h800(), 8);
    for spec in ["bf16", "int8", "int5", "int2@32", "int2-sr@32"] {
        let codec = Codec::parse(spec)?;
        let inputs: Vec<Vec<f32>> = (0..8)
            .map(|r| {
                let mut rng = Prng::new(100 + r);
                let mut v = vec![0f32; 8192];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect();
        let mut expected = vec![0f32; 8192];
        for v in &inputs {
            for (e, a) in expected.iter_mut().zip(v) {
                *e += a;
            }
        }
        let inputs = &inputs;
        let (results, counters) = fabric::run_ranks(&topo, |h| {
            let mut comm = Communicator::from_handle(h);
            let mut data = inputs[comm.rank()].clone();
            comm.allreduce(&mut data, &codec, AlgoPolicy::Fixed(Algo::TwoStep))
                .expect("collective failed");
            data
        });
        println!(
            "{:<12} SQNR {:>7.2} dB   wire {:>9} bytes   all ranks agree: {}",
            spec,
            sqnr_db(&expected, &results[0]),
            counters.total_bytes(),
            results.iter().all(|r| r == &results[0]),
        );
    }
    println!("\nnote how INT2 collapses but INT2+SpikeReserving stays usable —");
    println!("that is the paper's core accuracy claim (Table 3), on real bytes.");
    Ok(())
}
