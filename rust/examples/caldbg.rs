// debug calibration
use flashcomm::quant::Codec;
use flashcomm::sim::{self, Algo};
use flashcomm::topo::{presets, Topology};

fn main() {
    let m = 64.0 * 1024.0 * 1024.0;
    let specs = ["bf16","int8","int6","int5","int4@32","int3@32","int2-sr@32"];
    for dev in presets::all() {
        let topo = Topology::new(dev.clone(), 8);
        print!("{:>6}", dev.name);
        for s in specs {
            let c = Codec::parse(s).unwrap();
            let algo = if dev.is_numa() { Algo::TwoStep } else { Algo::TwoStep };
            let c2 = if s == "bf16" { Codec::Bf16 } else { c };
            let algo = if s == "bf16" { Algo::Ring } else { algo };
            let t = sim::allreduce_time(&topo, algo, &c2, m);
            print!(" {:>7.2}", sim::algbw_gbps(m, &t));
        }
        println!();
        if dev.is_numa() {
            for algo in [Algo::Hier, Algo::HierPipelined] {
                print!("{:>6}", if algo==Algo::Hier {"hier"} else {"hpp"});
                for s in specs.iter().skip(1) {
                    let c = Codec::parse(s).unwrap();
                    let t = sim::allreduce_time(&topo, algo, &c, m);
                    print!(" {:>7.2}", sim::algbw_gbps(m, &t));
                }
                println!();
            }
        }
    }
    println!("--- all2all h800/h20/a100 ---");
    for dev in [presets::a100(), presets::h800(), presets::h20()] {
        let topo = Topology::new(dev.clone(), 8);
        print!("{:>6}", dev.name);
        for s in specs {
            let c = Codec::parse(s).unwrap();
            let t = flashcomm::sim::all2all::all2all_time(&topo, &c, m);
            print!(" {:>7.2}", flashcomm::sim::all2all::algbw_gbps(m, &t));
        }
        println!();
    }
}
