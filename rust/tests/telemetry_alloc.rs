//! Allocation pin for the telemetry hot path. Lives alone in its own
//! integration-test binary: the counting allocator is process-global, so
//! any sibling test running on another thread would pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use flashcomm::record;
use flashcomm::telemetry::{AlgoTag, ClockSync, Op, ProbeSample, Recorder, Stage, MAX_PROBES};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn recording_hot_path_never_allocates() {
    // Disabled recorder: the record! macro must compile down to one
    // untaken branch — the common case for every collective in the tree.
    let rec: Option<&Recorder> = None;
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        record!(rec, start Op::Encode, i);
        record!(rec, end Op::Encode, i);
    }
    assert_eq!(ALLOCS.load(Ordering::Relaxed), before, "disabled recorder allocated");

    // Enabled recorder: Recorder::record is atomic stores into the ring
    // pre-allocated at construction — no allocation even while the ring
    // wraps (10k events through 64 slots) or the context words change.
    let recorder = Recorder::new(0, 64);
    recorder.set_plan(0xfeed_beef, AlgoTag::Hier);
    let rec = Some(&recorder);
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        recorder.set_stage(Stage::ReduceScatter, 0x2004);
        recorder.set_chunk(i as u32);
        record!(rec, start Op::Encode, i);
        record!(rec, end Op::Encode, i);
    }
    assert_eq!(ALLOCS.load(Ordering::Relaxed), before, "enabled recorder allocated");
    assert_eq!(recorder.total_recorded(), 20_000);

    // The link-stamped variant (per-link send/recv ordinals) shares the
    // same pre-allocated slots — the extra word is just one more store.
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        recorder.record_link(flashcomm::telemetry::Kind::Start, Op::Send, i, 1, i);
        recorder.record_link(flashcomm::telemetry::Kind::End, Op::Send, i, 1, i);
    }
    assert_eq!(ALLOCS.load(Ordering::Relaxed), before, "record_link allocated");

    // One test binary, one #[test]: a sibling test on another thread
    // would pollute the process-global counter, so the clock pin runs
    // here rather than in its own function.
    clock_probe_path_never_allocates();
}

fn clock_probe_path_never_allocates() {
    // Everything a `sync_clocks` exchange touches on the estimating side
    // — timestamping, accumulating probe samples into the fixed array,
    // the min-RTT estimate, installing the result — must stay off the
    // allocator: the probes run inside session establish and between
    // collective iterations, where a hidden allocation would skew the
    // very RTTs being measured.
    let recorder = Recorder::new(1, 64);
    let before = ALLOCS.load(Ordering::Relaxed);
    let mut sync = ClockSync::new();
    for k in 0..(2 * MAX_PROBES as u64) {
        let t1 = recorder.now_nanos();
        let sample =
            ProbeSample { t1, t2: t1 + 40 + k, t3: t1 + 45 + k, t4: recorder.now_nanos() + 90 };
        sync.add(sample);
    }
    let (offset, rtt) = sync.estimate().expect("samples were added");
    recorder.set_clock(offset, rtt, sync.len() as u64);
    let stats = sync.stats(1).expect("non-empty sync");
    assert_eq!(ALLOCS.load(Ordering::Relaxed), before, "clock probe path allocated");
    assert_eq!(stats.rank, 1);
    assert_eq!(recorder.clock(), (offset, rtt, MAX_PROBES as u64));
}
