//! Allocation pin for the telemetry hot path. Lives alone in its own
//! integration-test binary: the counting allocator is process-global, so
//! any sibling test running on another thread would pollute the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use flashcomm::record;
use flashcomm::telemetry::{AlgoTag, Op, Recorder, Stage};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn recording_hot_path_never_allocates() {
    // Disabled recorder: the record! macro must compile down to one
    // untaken branch — the common case for every collective in the tree.
    let rec: Option<&Recorder> = None;
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        record!(rec, start Op::Encode, i);
        record!(rec, end Op::Encode, i);
    }
    assert_eq!(ALLOCS.load(Ordering::Relaxed), before, "disabled recorder allocated");

    // Enabled recorder: Recorder::record is atomic stores into the ring
    // pre-allocated at construction — no allocation even while the ring
    // wraps (10k events through 64 slots) or the context words change.
    let recorder = Recorder::new(0, 64);
    recorder.set_plan(0xfeed_beef, AlgoTag::Hier);
    let rec = Some(&recorder);
    let before = ALLOCS.load(Ordering::Relaxed);
    for i in 0..10_000u64 {
        recorder.set_stage(Stage::ReduceScatter, 0x2004);
        recorder.set_chunk(i as u32);
        record!(rec, start Op::Encode, i);
        record!(rec, end Op::Encode, i);
    }
    assert_eq!(ALLOCS.load(Ordering::Relaxed), before, "enabled recorder allocated");
    assert_eq!(recorder.total_recorded(), 20_000);
}
