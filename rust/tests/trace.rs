//! Fabric-trace integration tests: clock-aligned merge determinism, the
//! NTP-style offset bound over a real (in-process) transport, and the
//! acceptance fixture — a deliberately delayed rank must be named in the
//! straggler report with the right stage, the merged Chrome trace must
//! render the delay as a span at least as long as the injected sleep,
//! and the fabric-median recalibration must beat the pooled per-rank
//! estimate that the straggler poisons (DESIGN.md §15).

use std::time::{Duration, Instant};

use flashcomm::comm::{fabric, Algo, AlgoPolicy, Communicator};
use flashcomm::quant::Codec;
use flashcomm::session;
use flashcomm::session::fault::{wrap_mesh, Fault};
use flashcomm::telemetry::{self, RankTrace, Stage};
use flashcomm::topo::{presets, Topology};
use flashcomm::transport::inproc;
use flashcomm::util::Prng;

fn inputs(n: usize, len: usize, salt: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|r| {
            let mut rng = Prng::new(salt + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect()
}

fn hier() -> AlgoPolicy {
    AlgoPolicy::Fixed(Algo::Hier)
}

/// Run one recorded hier AllReduce on a 4-rank / 2-group box with the
/// given per-rank faults; returns each rank's (trace, trace JSON, raw
/// events). All four recorders share one clock origin, so the traces are
/// aligned by construction (offset 0 — exactly what `sync_clocks`
/// establishes for real processes).
fn recorded_run(faults: Vec<Fault>) -> Vec<(RankTrace, String, Vec<telemetry::Event>)> {
    let topo = Topology::try_with_groups(presets::l40(), 4, 2).unwrap();
    let codec = Codec::parse("int4@32").unwrap();
    let ins = inputs(4, 1024, 77);
    let ins = &ins;
    let origin = Instant::now();
    let endpoints = wrap_mesh(inproc::mesh(4), faults, Duration::from_secs(30));
    let (out, _) = fabric::run_ranks_with(endpoints, &topo, move |h| {
        let mut c = Communicator::from_handle(h);
        c.enable_recording_from(4096, origin);
        let mut d = ins[c.rank()].clone();
        c.allreduce(&mut d, &codec, hier()).unwrap();
        let trace = c.rank_trace().unwrap();
        let json = c.trace_json().unwrap();
        let events = c.recorder().unwrap().events();
        (trace, json, events)
    });
    out
}

/// Largest `"dur"` value (microseconds) in a merged Chrome-trace JSON.
fn max_dur_us(merged_json: &str) -> f64 {
    let mut max = 0f64;
    let mut rest = merged_json;
    while let Some(i) = rest.find("\"dur\":") {
        rest = &rest[i + 6..];
        let end = rest.find(|c: char| c != '-' && c != '.' && !c.is_ascii_digit()).unwrap();
        let v: f64 = rest[..end].parse().unwrap();
        max = max.max(v);
    }
    max
}

#[test]
fn a_clean_run_reports_no_stragglers_and_merges_with_flow_arrows() {
    let out = recorded_run(vec![Fault::None; 4]);
    let traces: Vec<RankTrace> = out.iter().map(|(t, _, _)| t.clone()).collect();
    let report = telemetry::analyze(&traces);
    assert!(
        report.is_clean(),
        "no fault was injected, yet: {:?}",
        report.stragglers
    );
    let merged = telemetry::merge_traces(&traces).unwrap();
    assert!(merged.warnings.is_empty(), "{:?}", merged.warnings);
    assert_eq!(merged.ranks, 4);
    assert!(merged.flows > 0, "a hier collective must draw send->recv flow arrows");
}

#[test]
fn the_merge_is_byte_deterministic_through_the_file_round_trip() {
    let out = recorded_run(vec![Fault::None; 4]);
    let direct: Vec<RankTrace> = out.iter().map(|(t, _, _)| t.clone()).collect();
    // Round-trip each rank through the on-disk representation (what
    // `flashcomm trace merge` consumes) and require the merged JSON to be
    // byte-identical to merging the in-memory traces — twice, for the
    // determinism of the merge itself.
    let reparsed: Vec<RankTrace> =
        out.iter().map(|(_, json, _)| telemetry::parse_trace(json).unwrap()).collect();
    let a = telemetry::merge_traces(&direct).unwrap();
    let b = telemetry::merge_traces(&reparsed).unwrap();
    let c = telemetry::merge_traces(&reparsed).unwrap();
    assert_eq!(a.json, b.json, "file round-trip changed the merged trace");
    assert_eq!(b.json, c.json, "merging the same traces twice diverged");
}

/// The acceptance fixture: rank 3 sleeps 80 ms inside its first send (the
/// intra reduce-scatter on a 4-rank / 2-group hier schedule), so the
/// fabric critical path must (1) name rank 3 at stage `rs` with roughly
/// the injected excess, (2) render a >= 80 ms span in the merged Chrome
/// trace, and (3) recalibrate from per-tier medians that shrug the
/// straggler off while the pooled per-rank estimate eats it.
#[test]
fn a_delayed_rank_is_named_with_its_stage_and_the_gap_is_visible() {
    const DELAY: Duration = Duration::from_millis(80);
    let faults = vec![
        Fault::None,
        Fault::None,
        Fault::None,
        Fault::Delay { nth: 0, by: DELAY },
    ];
    let out = recorded_run(faults);
    let traces: Vec<RankTrace> = out.iter().map(|(t, _, _)| t.clone()).collect();

    let report = telemetry::analyze(&traces);
    assert!(!report.is_clean(), "an 80 ms stall must clear the straggler floor");
    let top = &report.stragglers[0];
    assert_eq!(top.rank, 3, "the delayed sender is the straggler: {report:?}");
    assert_eq!(top.stage, Stage::ReduceScatter, "send 0 is the intra reduce-scatter");
    assert!(
        top.excess_ms >= 60.0,
        "excess {} ms does not reflect the 80 ms sleep",
        top.excess_ms
    );

    let merged = telemetry::merge_traces(&traces).unwrap();
    let longest = max_dur_us(&merged.json);
    assert!(
        longest >= 80_000.0,
        "the merged trace must render the 80 ms stall as a span (longest: {longest} us)"
    );

    // Fabric recalibration: the per-tier medians ignore the one poisoned
    // span; the pooled estimate (what a single rank's recorder distills)
    // divides the same bytes by 80 ms of sleep.
    let all_events: Vec<telemetry::Event> =
        out.iter().flat_map(|(_, _, ev)| ev.iter().copied()).collect();
    let pooled = telemetry::distill_profile(&all_events);
    let fabric = telemetry::distill_fabric_profile(&traces);
    let (f, p) = (fabric.intra_bw.unwrap(), pooled.intra_bw.unwrap());
    assert!(
        f > 2.0 * p,
        "fabric medians ({f:.3e} B/s) must beat the straggler-poisoned pooled \
         estimate ({p:.3e} B/s)"
    );
}

#[test]
fn sync_clocks_holds_the_ntp_bound_over_a_two_rank_mesh() {
    // Both ranks share one Instant epoch; rank 1's closure fakes a clock
    // running 3 ms ahead. The offset maps local onto the reference clock
    // (`t_ref ≈ t_local + offset`), so a clock running ahead must come
    // back with offset ≈ −SKEW, within half the winning probe's RTT
    // (DESIGN.md §15 offset formula).
    const SKEW: i64 = 3_000_000;
    let mut mesh = inproc::mesh(2);
    let t1 = mesh.pop().unwrap();
    let t0 = mesh.pop().unwrap();
    let base = Instant::now();
    let h = std::thread::spawn(move || {
        let now = move || (base.elapsed().as_nanos() as i64 + SKEW) as u64;
        session::sync_clocks(&t1, 0, 8, &now).unwrap()
    });
    let now0 = move || base.elapsed().as_nanos() as u64;
    let s0 = session::sync_clocks(&t0, 0, 8, &now0).unwrap();
    let s1 = h.join().unwrap();
    assert_eq!((s0.rank, s0.offset_nanos, s0.rtt_nanos), (0, 0, 0), "rank 0 is the reference");
    assert_eq!(s1.rank, 1);
    assert!(s1.probes >= 1 && s1.rtt_nanos > 0);
    let err = (s1.offset_nanos + SKEW).abs() as u64;
    assert!(
        err <= s1.rtt_nanos / 2 + 1,
        "offset {} vs true {}: error {err} exceeds rtt/2 = {}",
        s1.offset_nanos,
        -SKEW,
        s1.rtt_nanos / 2
    );
}
