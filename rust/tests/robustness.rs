//! Robustness and failure-injection tests: corrupted payloads, degenerate
//! sizes, format stability. None of these need artifacts.

use flashcomm::comm::{fabric, Algo, AlgoPolicy, Communicator};
use flashcomm::quant::{Codec, CodecBuffers};
use flashcomm::topo::{presets, Topology};
use flashcomm::util::proptest::cases;
use flashcomm::util::Prng;

/// The decoder must never panic on corrupted bytes: either a clean error
/// or a (garbage) decode, but no UB/panic/overrun.
#[test]
fn decoder_survives_fuzzed_corruption() {
    cases(9001, 300, |rng| {
        let n = 1 + rng.below(2000);
        let mut data = vec![0f32; n];
        rng.fill_normal(&mut data, 0.0, 3.0);
        let specs = ["int8", "int5", "int4@32", "int2-sr@32", "int2-sr@32!", "int3-log@32"];
        let codec = Codec::parse(specs[rng.below(specs.len())]).unwrap();
        let mut wire = codec.encode(&data);
        // Corrupt 1-8 random bytes anywhere (including the header).
        for _ in 0..1 + rng.below(8) {
            let i = rng.below(wire.len());
            wire[i] ^= rng.next_u32() as u8;
        }
        let mut out = vec![0f32; n];
        let _ = Codec::decode(&wire, &mut out); // must simply not panic
    });
}

/// Truncation at every prefix length must be a clean error (never panic).
#[test]
fn decoder_survives_all_truncations() {
    let data: Vec<f32> = (0..257).map(|i| (i as f32).sin()).collect();
    let codec = Codec::parse("int2-sr@32!").unwrap();
    let wire = codec.encode(&data);
    let mut out = vec![0f32; 257];
    for cut in 0..wire.len() {
        assert!(Codec::decode(&wire[..cut], &mut out).is_err(), "cut {cut} should error");
    }
}

/// Wire-format golden stability: the exact bytes for a fixed input must
/// never change silently (cross-version compatibility of the fabric).
#[test]
fn wire_format_golden() {
    let data: Vec<f32> = (0..64).map(|i| ((i * 37) % 64) as f32 / 8.0 - 4.0).collect();
    let golden: &[(&str, usize, u64)] = &[
        // (codec, wire_len, FNV-1a hash of the payload)
        ("int8", 84, 0xdf323d3d3d0578a5),
        ("int5", 60, 0x16d61d9fd3f839f0),
        ("int2-sr@32", 56, 0x9dcc3f14729cde04),
        ("int2-sr@32!", 48, 0x31600c2bcf19f3b0),
    ];
    for (spec, want_len, want_hash) in golden {
        let wire = Codec::parse(spec).unwrap().encode(&data);
        let mut h: u64 = 0xcbf29ce484222325;
        for &b in &wire {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        assert_eq!(wire.len(), *want_len, "{spec}: wire length changed");
        assert_eq!(h, *want_hash, "{spec}: wire bytes changed (hash {h:#x})");
    }
}

/// Collectives on awkward sizes: shorter than the rank count, exactly one
/// element, prime lengths.
#[test]
fn collectives_handle_degenerate_lengths() {
    let topo = Topology::new(presets::h800(), 8);
    let l40 = Topology::new(presets::l40(), 8);
    for len in [1usize, 3, 7, 8, 9, 63] {
        for which in 0..4 {
            let inputs: Vec<Vec<f32>> =
                (0..8).map(|r| vec![r as f32 + 1.0; len]).collect();
            let expected: f32 = (1..=8).map(|x| x as f32).sum();
            let inputs = &inputs;
            let t = if which >= 2 { &l40 } else { &topo };
            let (results, _) = fabric::run_ranks(t, |h| {
                let mut c = Communicator::from_handle(h);
                let mut d = inputs[c.rank()].clone();
                match which {
                    0 => {
                        c.allreduce(&mut d, &Codec::Bf16, AlgoPolicy::Fixed(Algo::Ring))
                            .map(|_| ())
                    }
                    1 => {
                        c.allreduce(&mut d, &Codec::Bf16, AlgoPolicy::Fixed(Algo::TwoStep))
                            .map(|_| ())
                    }
                    2 => {
                        c.allreduce(&mut d, &Codec::Bf16, AlgoPolicy::Fixed(Algo::Hier))
                            .map(|_| ())
                    }
                    _ => c.allreduce_chunked(&mut d, &Codec::Bf16, 4),
                }
                .unwrap();
                d
            });
            for r in &results {
                for &x in r.iter() {
                    assert!((x - expected).abs() < 0.5, "len {len} which {which}: {x}");
                }
            }
        }
    }
}

/// Quantized collectives with a group size larger than the chunk: the
/// codec must still roundtrip (tail-group handling through the stack).
#[test]
fn quantized_collective_with_tiny_chunks() {
    let topo = Topology::new(presets::h800(), 8);
    let codec = Codec::parse("int8@128").unwrap(); // chunks of 2 elements
    let inputs: Vec<Vec<f32>> = (0..8)
        .map(|r| {
            let mut rng = Prng::new(50 + r as u64);
            let mut v = vec![0f32; 17];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect();
    let mut expected = vec![0f32; 17];
    for v in &inputs {
        for (e, x) in expected.iter_mut().zip(v) {
            *e += x;
        }
    }
    let inputs = &inputs;
    let (results, _) = fabric::run_ranks(&topo, |h| {
        let mut c = Communicator::from_handle(h);
        let mut d = inputs[c.rank()].clone();
        c.allreduce(&mut d, &codec, AlgoPolicy::Fixed(Algo::TwoStep)).unwrap();
        d
    });
    for (a, b) in results[0].iter().zip(&expected) {
        assert!((a - b).abs() < 0.5, "{a} vs {b}");
    }
}

/// Extreme-but-bf16-representable inputs must round-trip finite (values
/// beyond bf16's max, like f32::MAX, legitimately saturate to inf on a
/// bf16 wire — same as the BF16 passthrough itself).
#[test]
fn encode_clamps_extremes() {
    let data = vec![1e38f32, f32::MIN_POSITIVE, -1e38, 1e-38, 0.0, 1.0];
    for spec in ["int8", "int2@32", "int2-sr@32"] {
        let codec = Codec::parse(spec).unwrap();
        let wire = codec.encode(&data);
        let mut out = vec![0f32; 6];
        Codec::decode(&wire, &mut out).unwrap();
        assert!(out.iter().all(|x| x.is_finite()), "{spec}: {out:?}");
    }
}

/// decode_sum must leave the accumulator untouched on header errors.
#[test]
fn decode_sum_error_leaves_accumulator() {
    let mut bufs = CodecBuffers::default();
    let mut acc = vec![1.0f32; 8];
    let garbage = vec![0u8; 40];
    assert!(Codec::decode_sum_with(&garbage, &mut bufs, &mut acc).is_err());
    assert!(acc.iter().all(|&x| x == 1.0));
}
