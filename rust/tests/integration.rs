//! End-to-end integration: PJRT artifacts + coordinator engines + real
//! quantized collectives, composed exactly as the examples use them.
//!
//! These tests need `make artifacts` to have run; they skip (with a note)
//! otherwise so `cargo test` stays green on a fresh checkout.

use flashcomm::comm::{Algo, AlgoPolicy};
use flashcomm::coordinator::{MoeEngine, TpEngine, TrainOptions, Trainer};
use flashcomm::model::{Corpus, ModelConfig, Sampler, Weights};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, Runtime};

const TWOSTEP: AlgoPolicy = AlgoPolicy::Fixed(Algo::TwoStep);

fn open_runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping integration test: run `make artifacts`");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

fn load_cfg(rt: &Runtime, name: &str) -> ModelConfig {
    ModelConfig::from_record(rt.manifest.config(name).unwrap()).unwrap()
}

fn load_corpus(cfg: &ModelConfig) -> Corpus {
    Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab))).unwrap()
}

#[test]
fn tp_engine_quantization_ordering() {
    let Some(rt) = open_runtime() else { return };
    // Quantization error only shows on a model with structure: use the
    // cached short-trained checkpoint (trains once, then reused).
    let (cfg, weights, _) =
        flashcomm::coordinator::pretrain::ensure_trained("tiny",
            flashcomm::coordinator::pretrain::TEST_STEPS).unwrap();
    let corpus = load_corpus(&cfg);
    let (_, eval) = corpus.split();
    let batches = Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len);
    let batch = &batches[0];

    let mut engine = TpEngine::new(rt, cfg, &weights, Codec::Bf16, TWOSTEP).unwrap();
    let nll = |e: &mut TpEngine, spec: &str| {
        e.set_codec(Codec::parse(spec).unwrap(), TWOSTEP).unwrap();
        let (s, c) = e.eval_nll(batch).unwrap();
        s / c as f64
    };
    let bf16 = nll(&mut engine, "bf16");
    let int8 = nll(&mut engine, "int8");
    let int2 = nll(&mut engine, "int2@32");
    let int2sr = nll(&mut engine, "int2-sr@32");
    assert!(bf16.is_finite() && bf16 > 0.0);
    // Table 1 shape on the real engine: INT8 ~ clean, INT2 worse, SR helps.
    assert!((int8 - bf16).abs() < 0.1 * bf16 + 0.05, "bf16 {bf16} int8 {int8}");
    assert!(int2 > int8, "int8 {int8} int2 {int2}");
    assert!(int2sr < int2, "int2 {int2} int2_sr {int2sr}");
}

#[test]
fn tp_engine_hier_close_to_twostep() {
    let Some(rt) = open_runtime() else { return };
    let cfg = load_cfg(&rt, "tiny");
    let weights =
        Weights::load(default_artifacts_dir().join("tiny_init_weights.bin")).unwrap();
    let corpus = load_corpus(&cfg);
    let (_, eval) = corpus.split();
    let batch = &Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len)[0];
    let codec = Codec::parse("int5").unwrap();
    let mut e = TpEngine::new(rt, cfg, &weights, codec, TWOSTEP).unwrap();
    let (s2, c) = e.eval_nll(batch).unwrap();
    e.set_codec(codec, AlgoPolicy::Fixed(Algo::Hier)).unwrap();
    let (s3, _) = e.eval_nll(batch).unwrap();
    let (a, b) = (s2 / c as f64, s3 / c as f64);
    assert!((a - b).abs() < 0.05 * a + 0.02, "two-step {a} vs hier {b}");
}

#[test]
fn trainer_reduces_loss_with_quantized_grads() {
    let Some(rt) = open_runtime() else { return };
    let cfg = load_cfg(&rt, "tiny");
    let weights =
        Weights::load(default_artifacts_dir().join("tiny_init_weights.bin")).unwrap();
    let corpus = load_corpus(&cfg);
    let (train, _) = corpus.split();
    let mut sampler = Sampler::new(train, 42);
    let mut trainer = Trainer::new(rt, cfg, &weights).unwrap();
    let opts = TrainOptions {
        steps: 8,
        dp: 2,
        codec: Codec::parse("int8").unwrap(),
        algo: TWOSTEP,
        log_every: 0,
        ..Default::default()
    };
    let recs = trainer.train(&mut sampler, &[], &opts).unwrap();
    assert_eq!(recs.len(), 8);
    let first = recs[0].loss;
    let last = recs.last().unwrap().loss;
    assert!(last < first - 0.3, "loss {first} -> {last} after 8 steps");
    assert!(recs.iter().all(|r| r.loss.is_finite()));
    assert!(recs[0].grad_wire_bytes > 0);
    // Checkpoint round-trip.
    let w = trainer.export_weights().unwrap();
    assert_eq!(w.n_params(), 3_674_624);
}

#[test]
fn quantized_grads_track_bf16_training() {
    // INT8 gradient AllReduce must track BF16 closely over a few steps
    // (ZeRO++-style claim), and hierarchical must match two-step.
    let Some(rt) = open_runtime() else { return };
    let cfg = load_cfg(&rt, "tiny");
    let weights =
        Weights::load(default_artifacts_dir().join("tiny_init_weights.bin")).unwrap();
    let corpus = load_corpus(&cfg);
    let (train, _) = corpus.split();

    let run = |spec: &str, algo: AlgoPolicy| {
        let rt = Runtime::open(default_artifacts_dir()).unwrap();
        let mut sampler = Sampler::new(train, 11);
        let mut trainer = Trainer::new(rt, cfg.clone(), &weights).unwrap();
        let opts = TrainOptions {
            steps: 5,
            dp: 2,
            codec: Codec::parse(spec).unwrap(),
            algo,
            log_every: 0,
            ..Default::default()
        };
        trainer.train(&mut sampler, &[], &opts).unwrap().last().unwrap().loss
    };
    let bf16 = run("bf16", TWOSTEP);
    let int8 = run("int8", TWOSTEP);
    let hier = run("int8", AlgoPolicy::Fixed(Algo::Hier));
    assert!((int8 - bf16).abs() < 0.15, "bf16 {bf16} vs int8 {int8}");
    assert!((hier - int8).abs() < 0.15, "two-step {int8} vs hier {hier}");
}

#[test]
fn moe_engine_dispatch_quantization_ordering() {
    let Some(rt) = open_runtime() else { return };
    let (cfg, weights, _) =
        flashcomm::coordinator::pretrain::ensure_trained("moe-tiny",
            flashcomm::coordinator::pretrain::TEST_STEPS).unwrap();
    let corpus = load_corpus(&cfg);
    let (_, eval) = corpus.split();
    let batches: Vec<_> =
        Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len).into_iter().take(1).collect();
    let mut engine =
        MoeEngine::new(rt, cfg, &weights, Codec::Bf16, Codec::Bf16).unwrap();
    let mut ppl = |spec: &str, e: &mut MoeEngine| {
        e.set_dispatch_codec(Codec::parse(spec).unwrap());
        e.perplexity(&batches).unwrap()
    };
    // Dispatch-only quantization perturbs just the expert path; at this
    // model scale the ppl deltas sit at the noise floor (see Table 8 note
    // in EXPERIMENTS.md — the payload-level SQNR ordering is asserted with
    // margin in comm::all2all tests). What IS guaranteed here:
    //   1. quantized dispatch is *safe*: ppl within a tight band of bf16,
    //   2. the wire actually carries fewer bytes at lower widths,
    //   3. QDQ is demonstrably active (ppl not bit-identical to bf16).
    let bf16 = ppl("bf16", &mut engine);
    let w_bf16 = engine.dispatch_wire_bytes;
    let int8 = ppl("int8", &mut engine);
    let w_int8 = engine.dispatch_wire_bytes - w_bf16;
    let int2 = ppl("int2@32", &mut engine);
    let w_int2 = engine.dispatch_wire_bytes - w_bf16 - w_int8;
    let int2sr = ppl("int2-sr@32", &mut engine);
    assert!(bf16.is_finite() && int8.is_finite() && int2.is_finite() && int2sr.is_finite());
    assert!((int8 - bf16).abs() < 0.005 * bf16, "INT8 dispatch ~lossless: {bf16} vs {int8}");
    assert!((int2 - bf16).abs() < 0.03 * bf16, "INT2 dispatch bounded: {bf16} vs {int2}");
    assert!((int2sr - bf16).abs() < 0.03 * bf16, "SR bounded: {bf16} vs {int2sr}");
    assert!(int2 != bf16 && int8 != bf16, "QDQ must be active");
    assert!(w_int2 * 2 < w_int8, "INT2 wire {w_int2} must be far below INT8 {w_int8}");
    assert!(w_bf16 > 0);
}
