//! Collective-API property tests (no artifacts needed): every algorithm ×
//! codec combination, driven through the public `Communicator` front door,
//! must (1) leave all ranks bit-identical and (2) land within the codec's
//! error bound of the exact serial sum — with the ring's quantized variant
//! allowed its documented N−1 error compounding. Plus policy determinism
//! end-to-end.

use flashcomm::comm::{fabric, Algo, AlgoPolicy, Communicator};
use flashcomm::quant::Codec;
use flashcomm::topo::{presets, Topology};
use flashcomm::util::proptest::cases;
use flashcomm::util::Prng;

/// Relative L2 error of `got` vs `exact`.
fn rel_l2(exact: &[f32], got: &[f32]) -> f64 {
    let mut num = 0f64;
    let mut den = 0f64;
    for (e, g) in exact.iter().zip(got) {
        num += ((e - g) as f64).powi(2);
        den += (*e as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

/// Error bound for one collective, per (algorithm family, codec). One-shot
/// algorithms see each contribution quantized once plus one re-quantization
/// of the sum; the ring compounds one QDQ per hop (N−1 of them), so its
/// quantized bounds are deliberately loose — that compounding is exactly
/// why Auto never picks a quantized ring.
fn error_bound(algo: Algo, spec: &str) -> f64 {
    let one_shot = match spec {
        "bf16" => 0.02,
        "int8" => 0.10,
        "int4@32" => 0.35,
        "int2-sr@32!" => 0.80,
        other => panic!("no bound for {other}"),
    };
    match algo {
        Algo::Ring if spec != "bf16" => (3.0 * one_shot).min(1.6),
        _ => one_shot,
    }
}

#[test]
fn prop_every_algo_codec_bit_identical_and_bounded() {
    // 4-rank topologies: flat NVLink for ring/two-step, 2×2 NUMA for the
    // hierarchical family. Lengths are random multiples of 128 so every
    // chunk split stays group-aligned and the bound is meaningful.
    let h800 = Topology::new(presets::h800(), 4);
    let l40 = Topology::new(presets::l40(), 4);
    cases(0xC0DE, 8, |rng| {
        let len = 128 * (2 + rng.below(16));
        let inputs: Vec<Vec<f32>> = (0..4)
            .map(|r| {
                let mut prng = Prng::new(rng.next_u64() ^ (r as u64) << 32);
                let mut v = vec![0f32; len];
                prng.fill_activations(&mut v, 1.0);
                v
            })
            .collect();
        let mut exact = vec![0f32; len];
        for v in &inputs {
            for (e, x) in exact.iter_mut().zip(v) {
                *e += *x;
            }
        }
        for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier, Algo::HierPipelined] {
            let topo = match algo {
                Algo::Hier | Algo::HierPipelined => &l40,
                _ => &h800,
            };
            for spec in ["bf16", "int8", "int4@32", "int2-sr@32!"] {
                let codec = Codec::parse(spec).unwrap();
                let inputs_ref = &inputs;
                let (results, _) = fabric::run_ranks(topo, |h| {
                    let mut c = Communicator::from_handle(h);
                    let mut d = inputs_ref[c.rank()].clone();
                    c.allreduce(&mut d, &codec, AlgoPolicy::Fixed(algo)).unwrap();
                    d
                });
                let bits0: Vec<u32> = results[0].iter().map(|x| x.to_bits()).collect();
                for (r, res) in results.iter().enumerate() {
                    let bits: Vec<u32> = res.iter().map(|x| x.to_bits()).collect();
                    assert_eq!(bits, bits0, "{algo:?}/{spec} len {len}: rank {r} diverges");
                }
                assert!(
                    results[0].iter().all(|x| x.is_finite()),
                    "{algo:?}/{spec}: non-finite output"
                );
                let err = rel_l2(&exact, &results[0]);
                let bound = error_bound(algo, spec);
                assert!(
                    err < bound,
                    "{algo:?}/{spec} len {len}: rel L2 {err:.4} exceeds bound {bound}"
                );
            }
        }
    });
}

#[test]
fn degenerate_shapes_across_every_algo_and_topology() {
    // len == 0 (nothing to reduce), len < n_ranks (empty chunks out of
    // chunk_range), and a prime sliver — across every algorithm, codec,
    // and the G ∈ {1, 2, 4} topologies. Every admissible combination must
    // complete with bit-identical ranks and exact small sums; hierarchical
    // algorithms on the flat G=1 node must fail with a clean Topology
    // error, never a panic.
    let flat = Topology::new(presets::h800(), 4); // G = 1
    let numa2 = Topology::new(presets::l40(), 4); // G = 2, s = 2
    let numa4 = Topology::with_groups(presets::l40(), 8, 4); // G = 4, s = 2
    for topo in [&flat, &numa2, &numa4] {
        let n = topo.n_gpus;
        for len in [0usize, 1, 3] {
            for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier, Algo::HierPipelined] {
                for spec in ["bf16", "int4@32", "int2-sr@32!"] {
                    let codec = Codec::parse(spec).unwrap();
                    let hier_family = matches!(algo, Algo::Hier | Algo::HierPipelined);
                    let inputs: Vec<Vec<f32>> =
                        (0..n).map(|r| vec![r as f32 + 1.0; len]).collect();
                    let expected: f32 = (1..=n).map(|x| x as f32).sum();
                    let inputs = &inputs;
                    let (results, _) = fabric::run_ranks(topo, |h| {
                        let mut c = Communicator::from_handle(h);
                        let mut d = inputs[c.rank()].clone();
                        let r = c.allreduce(&mut d, &codec, AlgoPolicy::Fixed(algo));
                        (r.map(|_| ()).map_err(|e| e.to_string()), d)
                    });
                    let ctx = format!(
                        "{algo:?}/{spec} len {len} on {}x{}",
                        topo.spec.name, topo.numa_groups
                    );
                    if hier_family && topo.numa_groups < 2 {
                        for (r, _) in &results {
                            let e = r.as_ref().unwrap_err();
                            assert!(e.contains("cannot run on this topology"), "{ctx}: {e}");
                        }
                        continue;
                    }
                    let bits0: Vec<u32> =
                        results[0].1.iter().map(|x| x.to_bits()).collect();
                    for (rank, (r, d)) in results.iter().enumerate() {
                        assert!(r.is_ok(), "{ctx} rank {rank}: {r:?}");
                        assert_eq!(d.len(), len, "{ctx} rank {rank}: length changed");
                        let bits: Vec<u32> = d.iter().map(|x| x.to_bits()).collect();
                        assert_eq!(bits, bits0, "{ctx} rank {rank}: ranks diverge");
                        // Constant inputs stay exact through any codec that
                        // can represent small integers; bf16 is always
                        // exact here, quantized codecs stay within 10%.
                        for &x in d.iter() {
                            assert!(
                                (x - expected).abs() <= 0.1 * expected + 1e-6,
                                "{ctx} rank {rank}: {x} vs {expected}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn auto_policy_end_to_end_is_deterministic_and_correct() {
    // Repeated Auto runs over the same (topology, codec, size) resolve to
    // the same algorithm and the same bits.
    let topo = Topology::new(presets::l40(), 4);
    let codec = Codec::parse("int4@32").unwrap();
    let inputs: Vec<Vec<f32>> = (0..4)
        .map(|r| {
            let mut rng = Prng::new(42 + r as u64);
            let mut v = vec![0f32; 4096];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect();
    let inputs_ref = &inputs;
    let mut first: Option<(Algo, Vec<u32>)> = None;
    for _ in 0..3 {
        let (results, _) = fabric::run_ranks(&topo, |h| {
            let mut c = Communicator::from_handle(h);
            let mut d = inputs_ref[c.rank()].clone();
            let used = c.allreduce(&mut d, &codec, AlgoPolicy::Auto).unwrap();
            (used, d)
        });
        let algo = results[0].0;
        let bits: Vec<u32> = results[0].1.iter().map(|x| x.to_bits()).collect();
        for (used, _) in &results {
            assert_eq!(*used, algo, "ranks resolved different algorithms");
        }
        match &first {
            None => first = Some((algo, bits)),
            Some((a, b)) => {
                assert_eq!(*a, algo, "Auto resolved differently across runs");
                assert_eq!(*b, bits, "Auto produced different bits across runs");
            }
        }
    }
}
