//! Kernel-equivalence pins for the fused codec (tentpole safety net).
//!
//! The fused single-pass kernels (quantize→plane-scatter on encode, SWAR
//! plane-gather→dequantize(-accumulate) on decode, optional chunk
//! parallelism) must be indistinguishable from the retained scalar
//! reference path (`flashcomm::quant::reference`):
//!
//! - **wire bytes** bit-identical for every codec spec,
//! - **decoded f32** bit-identical (`to_bits`),
//! - **decode-sum** bit-identical to reference decode + elementwise add,
//! - all of the above for every thread count at lengths straddling
//!   plane-word (8), group, and parallel-chunk boundaries.

use flashcomm::quant::{reference, Codec, CodecBuffers};
use flashcomm::util::Prng;

/// Every scheme family × metadata mode × a few group shapes, including
/// non-multiple-of-8 and boundary group sizes.
const SPECS: &[&str] = &[
    "bf16",
    "int1@40",
    "int2@32",
    "int3@32",
    "int4@32",
    "int5",
    "int5@128!",
    "int6",
    "int7@96",
    "int8",
    "int2-sr@32",
    "int3-sr@32",
    "int2-sr@32!",
    "int2-sr@7",
    "int2-sr@256",
    "int4-had@32",
    "int6-had@128",
    "int3-log@32",
    "int2-log@32",
];

/// Lengths straddling plane-word (8), group, and chunk boundaries for a
/// given group size.
fn interesting_lengths(gs: usize) -> Vec<usize> {
    let mut ns = vec![1, 2, 7, 8, 9, 31, 32, 33, 255, 256, 257];
    if gs > 1 {
        ns.extend_from_slice(&[gs - 1, gs, gs + 1, 2 * gs + 3, 7 * gs + 5]);
    }
    ns.sort_unstable();
    ns.dedup();
    ns
}

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn check_equivalence(spec: &str, n: usize, rng: &mut Prng, threads: &[usize]) {
    let codec = Codec::parse(spec).unwrap();
    let mut data = vec![0f32; n];
    rng.fill_activations(&mut data, 1.0);
    let mut bufs = CodecBuffers::default();

    // Fused encode == scalar reference encode, byte for byte.
    let mut wire = Vec::new();
    codec.encode_with(&data, &mut bufs, &mut wire).unwrap();
    let ref_wire = reference::encode(&codec, &data);
    assert_eq!(wire, ref_wire, "{spec} n={n}: fused wire bytes != reference");

    // Fused decode == scalar reference decode, bit for bit.
    let mut out = vec![0f32; n];
    Codec::decode_with(&wire, &mut bufs, &mut out).unwrap();
    let ref_out = reference::decode(&wire).unwrap();
    assert_eq!(bits_of(&out), bits_of(&ref_out), "{spec} n={n}: fused decode != reference");

    // Fused decode-sum == reference decode + add, bit for bit, from a
    // non-trivial accumulator.
    let mut base = vec![0f32; n];
    rng.fill_normal(&mut base, 0.5, 2.0);
    let mut acc = base.clone();
    Codec::decode_sum_with(&wire, &mut bufs, &mut acc).unwrap();
    let mut ref_acc = base.clone();
    reference::decode_sum(&wire, &mut ref_acc).unwrap();
    assert_eq!(bits_of(&acc), bits_of(&ref_acc), "{spec} n={n}: fused decode_sum != reference");

    // Thread-count invariance: same wire bytes, same decodes, for every
    // worker count (exercised for real above the parallel threshold, and
    // as a no-op below it — both must hold).
    for &t in threads {
        let mut w2 = Vec::new();
        codec.encode_with_threads(&data, &mut bufs, &mut w2, t).unwrap();
        assert_eq!(w2, wire, "{spec} n={n} threads={t}: parallel encode differs");
        let mut o2 = vec![0f32; n];
        Codec::decode_with_threads(&wire, &mut bufs, &mut o2, t).unwrap();
        assert_eq!(bits_of(&o2), bits_of(&out), "{spec} n={n} threads={t}: parallel decode");
        let mut a2 = base.clone();
        Codec::decode_sum_with_threads(&wire, &mut bufs, &mut a2, t).unwrap();
        assert_eq!(
            bits_of(&a2),
            bits_of(&acc),
            "{spec} n={n} threads={t}: parallel decode_sum"
        );
    }
}

#[test]
fn fused_kernels_match_scalar_reference_at_boundary_lengths() {
    let mut rng = Prng::new(0xF05ED);
    for spec in SPECS {
        let codec = Codec::parse(spec).unwrap();
        let gs = codec.group_size();
        for n in interesting_lengths(gs.max(1)) {
            check_equivalence(spec, n, &mut rng, &[2, 3]);
        }
    }
}

#[test]
fn fused_kernels_match_reference_across_parallel_chunk_boundaries() {
    // Above PAR_MIN_ELEMS (64Ki) the chunk-parallel path actually engages;
    // lengths sit at ±1 around the threshold and around chunk multiples so
    // worker seams land mid-plane-word if the alignment logic is wrong.
    let mut rng = Prng::new(0xC0FFEE);
    let base = 1 << 16;
    for spec in ["int5@128!", "int2-sr@32", "int4-had@32", "int3-log@32", "int7@96"] {
        for n in [base - 1, base, base + 1, base + 32 * 3 + 17] {
            check_equivalence(spec, n, &mut rng, &[2, 4, 7]);
        }
    }
}

#[test]
fn fused_kernels_random_property_sweep() {
    // Random lengths × random specs, single- and dual-thread.
    let mut rng = Prng::new(0xFACADE);
    for _ in 0..60 {
        let spec = SPECS[rng.below(SPECS.len())];
        let n = 1 + rng.below(3000);
        check_equivalence(spec, n, &mut rng, &[2]);
    }
}

#[test]
fn qdq_is_allocation_free_after_warmup() {
    // Satellite pin: the TP engine's per-layer QDQ reuses the wire buffer
    // owned by CodecBuffers — zero allocations after the first call.
    let mut rng = Prng::new(7);
    let mut data = vec![0f32; 4096];
    rng.fill_activations(&mut data, 1.0);
    for spec in ["int8", "int4@32", "int2-sr@32", "int2-sr@32!", "int4-had@32", "int3-log@32"] {
        let codec = Codec::parse(spec).unwrap();
        let mut bufs = CodecBuffers::default();
        let mut d = data.clone();
        codec.qdq(&mut d, &mut bufs);
        let warm = bufs.capacity_bytes();
        assert!(warm >= codec.wire_len(4096), "{spec}: wire image must be retained");
        for _ in 0..4 {
            let mut d = data.clone();
            codec.qdq(&mut d, &mut bufs);
            assert_eq!(bufs.capacity_bytes(), warm, "{spec}: warm QDQ must not allocate");
        }
    }
}

#[test]
fn reduce_step_scratch_is_group_bounded_for_all_schemes() {
    // Tentpole acceptance: decode_sum is fused for every scheme — scratch
    // is per-group metadata (plus one group-sized rotation buffer for
    // Hadamard), never a payload-sized buffer.
    let n = 1 << 14;
    let mut rng = Prng::new(8);
    let mut data = vec![0f32; n];
    rng.fill_activations(&mut data, 1.0);
    for spec in ["int8", "int2-sr@32", "int2-sr@32!", "int4-had@32", "int3-log@32"] {
        let codec = Codec::parse(spec).unwrap();
        let wire = codec.encode(&data);
        let mut bufs = CodecBuffers::default();
        let mut acc = vec![0f32; n];
        Codec::decode_sum_with(&wire, &mut bufs, &mut acc).unwrap();
        let cap = bufs.capacity_bytes();
        assert!(
            cap < n,
            "{spec}: reduce-step scratch ({cap} B) must stay far below the payload ({n} elems)"
        );
        Codec::decode_sum_with(&wire, &mut bufs, &mut acc).unwrap();
        assert_eq!(bufs.capacity_bytes(), cap, "{spec}: repeat reduce must not grow scratch");
    }
}

#[test]
fn spike_group_size_cap_is_enforced_end_to_end() {
    // Regression for the spike-index wire bug: with bf16 metadata the
    // indices cannot represent values above 256 exactly (and IntLog carries
    // them as u8), so group sizes above 256 must be rejected — at parse
    // time and when arriving in a wire header.
    assert!(Codec::parse("int2-sr@257").is_err());
    assert!(Codec::parse("int2-sr@512").is_err());
    assert!(Codec::parse("int2-sr@300!").is_err());
    let ok = Codec::parse("int2-sr@256").unwrap();
    let mut rng = Prng::new(9);
    let mut data = vec![0f32; 600];
    rng.fill_activations(&mut data, 1.0);
    // gs=256 round-trips with exact spike restoration in both modes.
    for spec in ["int2-sr@256", "int2-sr@256!"] {
        let codec = Codec::parse(spec).unwrap();
        let wire = codec.encode(&data);
        let mut out = vec![0f32; 600];
        Codec::decode(&wire, &mut out).unwrap();
        for (xs, rec) in data.chunks(256).zip(out.chunks(256)) {
            let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let rmx = rec.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(
                (rmx - mx).abs() <= mx.abs() / 128.0 + 1e-6,
                "{spec}: group max {mx} lost ({rmx})"
            );
        }
    }
    // A forged header claiming spike gs=300 is a clean decode error.
    let mut wire = ok.encode(&data);
    wire[6..8].copy_from_slice(&300u16.to_le_bytes());
    let mut out = vec![0f32; 600];
    assert!(Codec::decode(&wire, &mut out).is_err());
}
