//! Session-fabric integration tests: deterministic peer-loss injection
//! through the whole collective stack, and degraded-membership re-planning
//! after a loss. Everything here runs in-process through
//! [`flashcomm::session::fault::FaultInjector`] — no sockets, no signals —
//! so the kill matrix is exact and repeatable (the real-wire equivalents
//! live in the CI worker drills: `--kill-rank` and `--rejoin-rank`).

use std::time::{Duration, Instant};

use flashcomm::comm::{fabric, Algo, AlgoPolicy, CommError, Communicator};
use flashcomm::plan;
use flashcomm::quant::Codec;
use flashcomm::session::fault::{wrap_mesh, Fault};
use flashcomm::session::{find_peer_lost, survivor_topology, PeerState, SessionConfig};
use flashcomm::topo::{presets, Topology};
use flashcomm::transport::{inproc, udp, Transport};
use flashcomm::util::Prng;

fn inputs(n: usize, len: usize, salt: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|r| {
            let mut rng = Prng::new(salt + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect()
}

fn hier() -> AlgoPolicy {
    AlgoPolicy::Fixed(Algo::Hier)
}

/// The no-fault control run: a mesh of `Fault::None` injectors must be
/// fully transparent — bit-identical to the plain in-process mesh on the
/// same inputs (the wrapper may not perturb ordering or payloads).
#[test]
fn no_fault_control_run_is_bit_identical_to_the_plain_mesh() {
    let topo = Topology::try_with_groups(presets::l40(), 4, 2).unwrap();
    let codec = Codec::parse("int4@32").unwrap();
    let ins = inputs(4, 1024, 300);
    let ins = &ins;
    let (plain, _) = fabric::run_ranks(&topo, |h| {
        let mut c = Communicator::from_handle(h);
        let mut d = ins[c.rank()].clone();
        c.allreduce(&mut d, &codec, hier()).unwrap();
        d
    });
    let wrapped = wrap_mesh(inproc::mesh(4), vec![Fault::None; 4], Duration::from_secs(5));
    let (injected, _) = fabric::run_ranks_with(wrapped, &topo, |h| {
        let mut c = Communicator::from_handle(h);
        let mut d = ins[c.rank()].clone();
        c.allreduce(&mut d, &codec, hier()).unwrap();
        d
    });
    for (rank, (a, b)) in plain.iter().zip(&injected).enumerate() {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "rank {rank} diverges at element {i}");
        }
    }
}

/// The kill matrix: kill each rank at each stage of the hierarchical
/// schedule (the per-endpoint send counter addresses the stages: send 0 is
/// the intra reduce-scatter, send 1 the cross exchange, send 2 the intra
/// allgather on a 4-rank / 2-group box). Whatever the timing, every rank —
/// victim included — must surface a typed [`CommError::PeerLost`] naming
/// the victim: a late kill can let a distant rank finish the in-flight
/// collective (real fabrics allow that too), so each rank chases it with a
/// second collective, which can never complete without the dead rank.
#[test]
fn kill_matrix_every_rank_x_every_stage_surfaces_typed_peer_lost() {
    let topo = Topology::try_with_groups(presets::l40(), 4, 2).unwrap();
    let codec = Codec::parse("int4@32").unwrap();
    let ins = inputs(4, 2048, 800);
    let ins = &ins;
    for victim in 0..4usize {
        for nth in [0usize, 1, 2] {
            let faults: Vec<Fault> = (0..4)
                .map(|r| if r == victim { Fault::KillAtSend { nth } } else { Fault::None })
                .collect();
            let endpoints = wrap_mesh(inproc::mesh(4), faults, Duration::from_secs(30));
            let (results, _) = fabric::run_ranks_with(endpoints, &topo, |h| {
                let rank = h.rank;
                let mut c = Communicator::from_handle(h);
                let mut d = ins[rank].clone();
                let res = c.allreduce(&mut d, &codec, hier()).and_then(|_| {
                    let mut d2 = ins[rank].clone();
                    c.allreduce(&mut d2, &codec, hier()).map(|_| ())
                });
                let health = c.transport().health();
                (rank, res, health)
            });
            for (rank, res, health) in results {
                let err = match res {
                    Err(e) => e,
                    Ok(()) => panic!(
                        "rank {rank} completed both collectives although rank {victim} \
                         died at send {nth}"
                    ),
                };
                match err {
                    CommError::PeerLost { rank: lost, epoch } => {
                        assert_eq!(
                            (lost, epoch),
                            (victim, 0),
                            "rank {rank} (victim {victim}, send {nth}) blamed the wrong peer"
                        );
                    }
                    other => panic!(
                        "rank {rank} (victim {victim}, send {nth}): expected a typed \
                         PeerLost, got: {other}"
                    ),
                }
                assert_eq!(
                    health[victim],
                    PeerState::Lost,
                    "rank {rank}: the mesh health view must show rank {victim} as lost"
                );
            }
        }
    }
}

/// The PR 7 kill matrix over real UDP datagram endpoints: the injector is
/// transport-generic, so killing each rank at each stage of the
/// hierarchical schedule must surface the same typed
/// [`CommError::PeerLost`] it does over InProc — the datagram recovery
/// machinery (NACKs, probes, redundancy) may never convert a death into a
/// hang or a wrong-peer blame.
#[test]
fn udp_kill_matrix_every_rank_x_every_stage_surfaces_typed_peer_lost() {
    let topo = Topology::try_with_groups(presets::l40(), 4, 2).unwrap();
    let codec = Codec::parse("int4@32").unwrap();
    let ins = inputs(4, 2048, 800);
    let ins = &ins;
    for victim in 0..4usize {
        for nth in [0usize, 1, 2] {
            let faults: Vec<Fault> = (0..4)
                .map(|r| if r == victim { Fault::KillAtSend { nth } } else { Fault::None })
                .collect();
            let endpoints =
                wrap_mesh(udp::local_mesh(4).unwrap(), faults, Duration::from_secs(30));
            let (results, _) = fabric::run_ranks_with(endpoints, &topo, |h| {
                let rank = h.rank;
                let mut c = Communicator::from_handle(h);
                let mut d = ins[rank].clone();
                let res = c.allreduce(&mut d, &codec, hier()).and_then(|_| {
                    let mut d2 = ins[rank].clone();
                    c.allreduce(&mut d2, &codec, hier()).map(|_| ())
                });
                let health = c.transport().health();
                (rank, res, health)
            });
            for (rank, res, health) in results {
                let err = res.expect_err(&format!(
                    "rank {rank} completed both collectives although rank {victim} died \
                     at send {nth} (udp)"
                ));
                match err {
                    CommError::PeerLost { rank: lost, epoch } => {
                        assert_eq!(
                            (lost, epoch),
                            (victim, 0),
                            "rank {rank} (victim {victim}, send {nth}, udp) blamed the \
                             wrong peer"
                        );
                    }
                    other => panic!(
                        "rank {rank} (victim {victim}, send {nth}, udp): expected a typed \
                         PeerLost, got: {other}"
                    ),
                }
                assert_eq!(health[victim], PeerState::Lost, "rank {rank} (udp)");
            }
        }
    }
}

/// The real-silence half of the matrix, on real sockets: a peer that
/// simply stops emitting datagrams (endpoint dropped — no FIN, no RST,
/// nothing for the survivor to react to except absence) must surface a
/// typed [`PeerLost`] within twice the session deadline on every
/// survivor, stay sticky, and must not leave the engine busy-NACKing a
/// corpse.
#[test]
fn udp_silent_peer_past_deadline_yields_typed_peer_lost_on_every_survivor() {
    let deadline = Duration::from_millis(250);
    let config = SessionConfig::from_millis(25, 250).unwrap();
    let mut endpoints = udp::local_mesh_with(3, &config).unwrap();
    let t2 = endpoints.pop().unwrap();
    let t1 = endpoints.pop().unwrap();
    let t0 = endpoints.pop().unwrap();
    // Rank 2 goes silent: its engine (heartbeats included) stops cold.
    drop(t2);
    for (survivor, t) in [(0usize, &t0), (1usize, &t1)] {
        let start = Instant::now();
        let err = t.recv(2).unwrap_err();
        let lost = find_peer_lost(&err)
            .unwrap_or_else(|| panic!("survivor {survivor}: expected typed PeerLost, got {err}"));
        assert_eq!((lost.rank, lost.epoch), (2, 0), "survivor {survivor}");
        assert!(
            start.elapsed() < 2 * deadline,
            "survivor {survivor}: loss took {:?}, deadline is {deadline:?}",
            start.elapsed()
        );
        assert_eq!(t.session_stats().unwrap().losses, 1, "survivor {survivor}");
    }
    // The surviving link still works, and the loss verdict is sticky.
    t0.send(1, vec![11, 22]).unwrap();
    assert_eq!(t1.recv(0).unwrap(), vec![11, 22]);
    assert!(find_peer_lost(&t0.send(2, vec![1]).unwrap_err()).is_some(), "sticky on send");
    // No busy NACK loop against the corpse: recovery state for rank 2 was
    // torn down at the loss, so the NACK counter stays flat afterwards.
    let nacks_then = t0.stats().nacks_sent;
    std::thread::sleep(Duration::from_millis(120));
    assert_eq!(t0.stats().nacks_sent, nacks_then, "NACKs must stop once the peer is lost");
}

/// Degraded-membership continuation, end to end: 6 ranks in 2 groups run
/// one full collective, ranks 1 and 4 "die" (one per group — the uniform
/// loss keeps the group structure), and the survivors continue through
/// [`Communicator::into_degraded`]. The degraded AllReduce must be
/// bit-identical to a fresh 4-rank mesh over the same survivor inputs —
/// the dense renumbering and the re-planned schedule are invisible to the
/// data path.
#[test]
fn degraded_replan_after_losses_matches_a_fresh_survivor_mesh() {
    let orig = Topology::try_with_groups(presets::l40(), 6, 2).unwrap();
    let lost = [1usize, 4];
    let survivors = survivor_topology(&orig, &lost).unwrap();
    assert_eq!((survivors.n_gpus, survivors.numa_groups), (4, 2));
    assert_ne!(survivors.fingerprint(), orig.fingerprint());

    let codec = Codec::parse("int4@32").unwrap();
    let ins = inputs(6, 1536, 40);
    let ins = &ins;
    let lost = &lost[..];
    let survivors_fp = survivors.fingerprint();
    let (results, _) = fabric::run_ranks(&orig, |h| {
        let rank = h.rank;
        let mut c = Communicator::from_handle(h);
        let mut d = ins[rank].clone();
        c.allreduce(&mut d, &codec, hier()).unwrap();
        if lost.contains(&rank) {
            // This rank "dies" after the first collective: its endpoint
            // drops here and it never joins the degraded membership.
            return None;
        }
        let mut c = c.into_degraded(lost).unwrap();
        assert_eq!(
            c.topo().fingerprint(),
            survivors_fp,
            "into_degraded must re-plan over the survivor topology"
        );
        let mut d2 = ins[rank].clone();
        c.allreduce(&mut d2, &codec, hier()).unwrap();
        Some(d2)
    });

    // Reference: a fresh mesh of exactly the survivors, fed the same
    // inputs in degraded (dense) rank order.
    let dense: Vec<Vec<f32>> = [0usize, 2, 3, 5].iter().map(|&r| ins[r].clone()).collect();
    let dense = &dense;
    let (fresh, _) = fabric::run_ranks(&survivors, |h| {
        let mut c = Communicator::from_handle(h);
        let mut d = dense[c.rank()].clone();
        c.allreduce(&mut d, &codec, hier()).unwrap();
        d
    });
    let degraded: Vec<Vec<f32>> = results.into_iter().flatten().collect();
    assert_eq!(degraded.len(), 4, "exactly the survivors return degraded results");
    for (i, (a, b)) in degraded.iter().zip(&fresh).enumerate() {
        assert_eq!(a.len(), b.len());
        for (j, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "survivor {i}: degraded mesh diverges from the fresh mesh at element {j}"
            );
        }
    }
}

/// [`plan::compile_degraded`] is exactly [`plan::compile`] over the
/// survivor topology — the degraded re-plan path cannot drift from the
/// healthy compiler.
#[test]
fn compile_degraded_plans_over_the_survivor_topology() {
    let orig = Topology::try_with_groups(presets::l40(), 8, 2).unwrap();
    let base = Codec::parse("int4@32").unwrap();
    let (plan, survivors) = plan::compile_degraded(&orig, &[3, 7], 65536, &base).unwrap();
    assert_eq!((survivors.n_gpus, survivors.numa_groups), (6, 2));
    let direct = plan::compile(&survivors, 65536, &base);
    assert_eq!(plan, direct, "degraded compile == compile over the survivor topology");
    plan.validate(&survivors).unwrap();
    // Hostile losses stay typed errors at this layer too.
    assert!(matches!(
        plan::compile_degraded(&orig, &[42], 65536, &base).unwrap_err(),
        CommError::Shape { .. }
    ));
}
