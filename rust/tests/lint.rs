//! flashlint rule tests: true positives and negatives per rule over
//! inline fixtures, the allow-directive grammar, `#[cfg(test)]` scoping,
//! and the zero-findings gate over the real tree.
//!
//! Fixture paths follow the scanner's `src/`-relative convention, so
//! scoping (`transport/` vs `model/`, `frame.rs` exemption) is exercised
//! exactly as in a real run.

use flashcomm::lint::{run, run_on_sources, Finding, Rule};

fn count(findings: &[Finding], rule: Rule) -> usize {
    findings.iter().filter(|f| f.rule == rule).count()
}

// ---------------------------------------------------------------- R1 wire

#[test]
fn wire_rule_flags_drifted_constants() {
    let src = "\
pub fn encode(buf: &mut [u8], wire_flags: u8) -> bool {
    let magic = b\"FCT2\";
    let hdr = &buf[0..4];
    let is_heartbeat = wire_flags & 0x01 != 0;
    magic[0] == hdr[0] && is_heartbeat
}
";
    let findings = run_on_sources(&[("transport/udp.rs", src)]);
    assert_eq!(count(&findings, Rule::Wire), 3, "{findings:?}");
}

#[test]
fn wire_rule_flags_segment_subheader_ranges() {
    let src = "\
pub fn parse(buf: &[u8]) {
    let seq = &buf[12..16];
    let crc = &buf[20..24];
    let _ = (seq, crc);
}
";
    let findings = run_on_sources(&[("session/rejoin.rs", src)]);
    assert_eq!(count(&findings, Rule::Wire), 2, "{findings:?}");
}

#[test]
fn wire_rule_exempts_frame_rs_comments_and_unrelated_hex() {
    let frame = ("transport/frame.rs", "pub const HEARTBEAT: u8 = 0x01; // the flag bits\n");
    let no_flag_word = ("transport/udp.rs", "const RETRY_MASK: u8 = 0x04;\n");
    let comment_only = ("comm/ring.rs", "// the magic FCT2 and range [0..4] live in frame.rs\n");
    let unpinned_range = ("comm/ring.rs", "pub fn f(b: &[u8]) -> &[u8] {\n    &b[1..3]\n}\n");
    let findings = run_on_sources(&[frame, no_flag_word, comment_only, unpinned_range]);
    assert_eq!(count(&findings, Rule::Wire), 0, "{findings:?}");
}

#[test]
fn wire_rule_skips_test_code() {
    let src = "\
#[cfg(test)]
mod tests {
    #[test]
    fn golden() {
        let buf = [0u8; 28];
        assert_eq!(&buf[0..4], b\"FCT2\");
    }
}
";
    let findings = run_on_sources(&[("transport/udp.rs", src)]);
    assert_eq!(count(&findings, Rule::Wire), 0, "{findings:?}");
}

// --------------------------------------------------------------- R2 panic

#[test]
fn panic_rule_flags_unwraps_and_macros() {
    let src = "\
pub fn f(x: Option<u8>) -> u8 {
    let v = x.unwrap();
    if v > 9 {
        panic!(\"out of range\");
    }
    v
}
";
    let findings = run_on_sources(&[("quant/codec.rs", src)]);
    assert_eq!(count(&findings, Rule::Panic), 2, "{findings:?}");
}

#[test]
fn panic_rule_flags_literal_slice_ranges_and_byte_ctors() {
    let src = "\
pub fn g(b: &[u8]) -> u16 {
    let _ = &b[4..6];
    u16::from_le_bytes([b[0], b[1]])
}
";
    let findings = run_on_sources(&[("plan/compiler.rs", src)]);
    assert_eq!(count(&findings, Rule::Panic), 2, "{findings:?}");
}

#[test]
fn panic_rule_ignores_out_of_scope_and_benign_tokens() {
    let out_of_scope = ("model/weights.rs", "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n");
    let adapters =
        ("quant/codec.rs", "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap_or_else(|| 0)\n}\n");
    let array_literal = ("quant/codec.rs", "pub fn z() -> [u8; 4] {\n    [0u8; 4]\n}\n");
    let doc_comment = ("plan/sim.rs", "/// Panics: calls .unwrap() when empty.\npub fn d() {}\n");
    let findings = run_on_sources(&[out_of_scope, adapters, array_literal, doc_comment]);
    assert_eq!(count(&findings, Rule::Panic), 0, "{findings:?}");
}

#[test]
fn panic_rule_skips_test_code_but_not_production_code_in_the_same_file() {
    let src = "\
pub fn f(x: Option<u8>) -> u8 {
    x.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        assert_eq!(super::f(Some(1)), Some(1).unwrap());
    }
}
";
    let findings = run_on_sources(&[("quant/codec.rs", src)]);
    assert_eq!(count(&findings, Rule::Panic), 1, "{findings:?}");
    assert_eq!(findings[0].line, 2);
}

// ---------------------------------------------------------------- R3 lock

#[test]
fn lock_rule_flags_blocking_calls_under_a_live_guard() {
    let src = "\
impl X {
    fn io_under_guard(&self) {
        let mut w = self.window.lock().unwrap();
        w.clear();
        let _ = self.stream.write_all(b\"frame\");
    }
    fn sleep_under_guard(&self) {
        let g = self.state.lock().unwrap();
        std::thread::sleep(self.period);
        drop(g);
    }
}
";
    let findings = run_on_sources(&[("transport/x.rs", src)]);
    assert_eq!(count(&findings, Rule::Lock), 2, "{findings:?}");
    let lock_lines: Vec<usize> =
        findings.iter().filter(|f| f.rule == Rule::Lock).map(|f| f.line).collect();
    assert_eq!(lock_lines, vec![5, 9]);
}

#[test]
fn lock_rule_respects_scopes_drops_and_temporaries() {
    let src = "\
impl X {
    fn scoped(&self) {
        {
            let mut w = self.window.lock().unwrap();
            w.clear();
        }
        let _ = self.stream.write_all(b\"frame\");
    }
    fn dropped(&self) {
        let g = self.state.lock().unwrap();
        drop(g);
        let _ = self.sock.send_to(b\"x\", self.addr);
    }
    fn temporary(&self) {
        self.queue.lock().unwrap().push(1);
        let _ = self.stream.write_all(b\"frame\");
    }
    fn mpsc_send_is_fine(&self) {
        let g = self.state.lock().unwrap();
        let _ = self.tx.send(1);
        drop(g);
    }
}
";
    let findings = run_on_sources(&[("session/s.rs", src)]);
    assert_eq!(count(&findings, Rule::Lock), 0, "{findings:?}");
}

// -------------------------------------------------------------- R4 unsafe

#[test]
fn unsafe_rule_requires_a_safety_comment() {
    let bare = ("model/a.rs", "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n");
    let wrong_comment_src = "\
pub fn g(p: *const u8) -> u8 {
    // reads a byte
    unsafe { *p }
}
";
    let findings = run_on_sources(&[bare, ("runtime/b.rs", wrong_comment_src)]);
    assert_eq!(count(&findings, Rule::Unsafe), 2, "{findings:?}");
}

#[test]
fn unsafe_rule_accepts_safety_comments_tests_and_strings() {
    let above_src = "\
pub fn f(p: *const u8) -> u8 {
    // SAFETY: p is valid
    unsafe { *p }
}
";
    let same_line_src = "\
pub fn g(p: *const u8) -> u8 {
    unsafe { *p } // SAFETY: p is valid
}
";
    let in_test_src = "\
#[cfg(test)]
mod tests {
    fn t(p: *const u8) -> u8 {
        unsafe { *p }
    }
}
";
    let in_string = ("model/d.rs", "pub fn h() -> &'static str {\n    \"unsafe\"\n}\n");
    let findings = run_on_sources(&[
        ("model/a.rs", above_src),
        ("model/b.rs", same_line_src),
        ("model/c.rs", in_test_src),
        in_string,
    ]);
    assert_eq!(count(&findings, Rule::Unsafe), 0, "{findings:?}");
}

// ----------------------------------------------------------------- R5 obs

#[test]
fn obs_rule_flags_counters_missing_from_the_export() {
    let transport = (
        "transport/mod.rs",
        "pub struct TransportStats {\n    pub messages: u64,\n    pub orphans: u64,\n}\n",
    );
    let session =
        ("session/mod.rs", "pub struct SessionStats {\n    pub heartbeats_sent: u64,\n}\n");
    let registry =
        ("telemetry/registry.rs", "pub const KEYS: &[&str] = &[\"messages\"];\n");
    let findings = run_on_sources(&[transport, session, registry]);
    assert_eq!(count(&findings, Rule::Obs), 2, "{findings:?}");
}

#[test]
fn obs_rule_accepts_exported_counters_in_either_quote_form() {
    let transport = (
        "transport/mod.rs",
        "pub struct TransportStats {\n    pub messages: u64,\n    pub wire_bytes: u64,\n}\n",
    );
    let registry_src = "\
pub fn export() -> String {
    let head = \"messages\";
    format!(\"{{\\\"wire_bytes\\\":0}}\", head.len())
}
";
    let findings = run_on_sources(&[transport, ("telemetry/registry.rs", registry_src)]);
    assert_eq!(count(&findings, Rule::Obs), 0, "{findings:?}");
}

#[test]
fn obs_rule_covers_the_clock_and_straggler_structs() {
    let trace = (
        "telemetry/trace.rs",
        "pub struct ClockSyncStats {\n    pub rank: u16,\n    pub offset_nanos: i64,\n}\n",
    );
    let analyze =
        ("telemetry/analyze.rs", "pub struct StragglerReport {\n    pub excess_ms: f64,\n}\n");
    let registry = ("telemetry/registry.rs", "pub const KEYS: &[&str] = &[\"rank\"];\n");
    let findings = run_on_sources(&[trace, analyze, registry]);
    assert_eq!(count(&findings, Rule::Obs), 2, "offset_nanos and excess_ms unexported: {findings:?}");
}

#[test]
fn obs_rule_is_skipped_without_a_registry_source() {
    let transport = (
        "transport/mod.rs",
        "pub struct TransportStats {\n    pub messages: u64,\n}\n",
    );
    let findings = run_on_sources(&[transport]);
    assert_eq!(count(&findings, Rule::Obs), 0, "{findings:?}");
}

// -------------------------------------------------------- allow directives

#[test]
fn allow_on_the_same_line_suppresses() {
    let src = "\
pub fn f(x: Option<u8>) -> u8 {
    x.unwrap() // lint: allow(panic, \"checked by the caller\")
}
";
    let findings = run_on_sources(&[("quant/codec.rs", src)]);
    assert_eq!(count(&findings, Rule::Panic), 0, "{findings:?}");
}

#[test]
fn allow_on_the_preceding_comment_line_suppresses() {
    let src = "\
pub fn f(x: Option<u8>) -> u8 {
    // lint: allow(panic, \"checked by the caller\")
    x.unwrap()
}
";
    let findings = run_on_sources(&[("quant/codec.rs", src)]);
    assert_eq!(count(&findings, Rule::Panic), 0, "{findings:?}");
}

#[test]
fn malformed_or_mismatched_allows_suppress_nothing() {
    let no_reason = "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap() // lint: allow(panic)\n}\n";
    let wrong_rule =
        "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap() // lint: allow(lock, \"nope\")\n}\n";
    let unknown_rule =
        "pub fn f(x: Option<u8>) -> u8 {\n    x.unwrap() // lint: allow(bogus, \"nope\")\n}\n";
    let too_far = "\
pub fn f(x: Option<u8>) -> u8 {
    // lint: allow(panic, \"not adjacent\")
    let y = x;
    y.unwrap()
}
";
    for (i, src) in [no_reason, wrong_rule, unknown_rule, too_far].into_iter().enumerate() {
        let findings = run_on_sources(&[("quant/codec.rs", src)]);
        assert_eq!(count(&findings, Rule::Panic), 1, "fixture {i}: {findings:?}");
    }
}

#[test]
fn allow_in_a_string_literal_does_not_suppress() {
    let src = "\
pub fn f(x: Option<u8>) -> u8 {
    let _msg = \"lint: allow(panic, \\\"in a string\\\")\";
    x.unwrap()
}
";
    let findings = run_on_sources(&[("quant/codec.rs", src)]);
    assert_eq!(count(&findings, Rule::Panic), 1, "{findings:?}");
}

// ---------------------------------------------------------- corpus + tree

/// One mixed fixture corpus with a known per-rule census — the shape the
/// CI gate sees when something regresses.
#[test]
fn fixture_corpus_has_the_expected_per_rule_counts() {
    let udp_src = "\
pub fn f(buf: &[u8], wire_flags: u8) -> bool {
    let m = &buf[0..4];
    m[0] == 1 && wire_flags & 0x02 != 0
}
";
    let session_src = "\
impl X {
    fn h(&self) {
        let g = self.state.lock().unwrap();
        let _ = self.stream.write_all(b\"x\");
        drop(g);
    }
}
";
    let corpus: &[(&str, &str)] = &[
        ("transport/udp.rs", udp_src),
        ("quant/codec.rs", "pub fn g(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\n"),
        ("session/s.rs", session_src),
        ("model/m.rs", "pub fn u(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n"),
        (
            "transport/mod.rs",
            "pub struct TransportStats {\n    pub messages: u64,\n    pub orphans: u64,\n}\n",
        ),
        ("telemetry/registry.rs", "pub const KEYS: &[&str] = &[\"messages\"];\n"),
    ];
    let findings = run_on_sources(corpus);
    assert_eq!(count(&findings, Rule::Wire), 2, "{findings:?}"); // range + flag hex
    // udp range is also a panic-index; session lock().unwrap() is a panic.
    assert_eq!(count(&findings, Rule::Panic), 3, "{findings:?}");
    assert_eq!(count(&findings, Rule::Lock), 1, "{findings:?}");
    assert_eq!(count(&findings, Rule::Unsafe), 1, "{findings:?}");
    assert_eq!(count(&findings, Rule::Obs), 1, "{findings:?}");
    assert_eq!(findings.len(), 8, "{findings:?}");
}

/// The real tree must be clean — this is the same gate CI runs via
/// `flashcomm lint`.
#[test]
fn the_real_tree_has_zero_findings() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = run(root).expect("lint run over the real tree");
    assert!(report.files > 30, "suspiciously few files scanned: {}", report.files);
    assert!(report.findings.is_empty(), "\n{}", report.render_text());
}
