//! Integration pins for the flight-recorder telemetry layer, through the
//! public [`LocalGroup`] API only: exact closed-form event counts for the
//! hierarchical family, ring wraparound keeping the newest events, and
//! recording being a pure observer (bit-identical results on and off).

use flashcomm::comm::{Algo, AlgoPolicy, LocalGroup};
use flashcomm::quant::Codec;
use flashcomm::telemetry::Op;
use flashcomm::topo::{presets, Topology};
use flashcomm::util::Prng;

fn inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|r| {
            let mut rng = Prng::new(seed + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect()
}

/// Per-rank events for one hierarchical AllReduce on `g` groups of `s`
/// ranks with `c` micro-chunks. Each chunk records `7(s-1) + 3g + 1`
/// spans — reduce-scatter: `(s-1)` encode + `(s-1)` send + `(s-1)` recv
/// + `(s-1)` decode-sum; cross-group: 1 encode + `(g-1)` send + `(g-1)`
/// recv + `g` decode-sum; all-gather: 1 encode + `(s-1)` send + `(s-1)`
/// recv + `s` decode — at 2 events (Start, End) per span, plus the
/// enclosing Collective span.
fn hier_events_per_rank(s: usize, g: usize, c: usize) -> u64 {
    (2 * c * (7 * (s - 1) + 3 * g + 1) + 2) as u64
}

#[test]
fn hier_event_counts_match_the_closed_form_exactly() {
    // presets::l40() is a NUMA spec: 8 ranks split into 2 groups of 4.
    let topo = Topology::new(presets::l40(), 8);
    let codec = Codec::parse("int4@32").unwrap();
    // Staged hier is the C = 1 case; hierpp defaults to 8 micro-chunks.
    for (algo, chunks) in [(Algo::Hier, 1usize), (Algo::HierPipelined, 8)] {
        let mut group = LocalGroup::new(&topo, AlgoPolicy::Fixed(algo)).unwrap();
        group.enable_recording(4096);
        let mut data = inputs(8, 8192, 42);
        group.allreduce(&mut data, &codec).unwrap();
        let want = hier_events_per_rank(4, 2, chunks);
        for c in group.ranks() {
            let rec = c.recorder().unwrap();
            assert_eq!(rec.total_recorded(), want, "{algo:?} rank {}", c.rank());
            assert_eq!(rec.events().len() as u64, want, "{algo:?}: ring must hold them all");
        }
    }
}

#[test]
fn ring_wraparound_keeps_the_newest_events_over_the_public_api() {
    let topo = Topology::new(presets::l40(), 8);
    let mut group = LocalGroup::new(&topo, AlgoPolicy::Fixed(Algo::Hier)).unwrap();
    // One staged-hier call records 58 events per rank — far over capacity.
    group.enable_recording(16);
    let mut data = inputs(8, 4096, 7);
    group.allreduce(&mut data, &Codec::parse("int8").unwrap()).unwrap();
    let want_total = hier_events_per_rank(4, 2, 1);
    for c in group.ranks() {
        let rec = c.recorder().unwrap();
        assert_eq!(rec.total_recorded(), want_total, "wrapping must not lose the count");
        let ev = rec.events();
        assert_eq!(ev.len(), 16, "ring holds exactly its capacity");
        let seqs: Vec<u64> = ev.iter().map(|e| e.seq).collect();
        let want: Vec<u64> = (want_total - 16..want_total).collect();
        assert_eq!(seqs, want, "newest events survive, oldest are overwritten");
        let last = ev.last().unwrap();
        assert_eq!(last.op, Op::Collective, "the closing Collective End is the newest event");
    }
}

#[test]
fn recording_never_changes_the_numerics() {
    let topo = Topology::new(presets::l40(), 8);
    let codec = Codec::parse("int2-sr@32!").unwrap();
    for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier, Algo::HierPipelined] {
        let run = |record: bool| -> Vec<Vec<u32>> {
            let mut group = LocalGroup::new(&topo, AlgoPolicy::Fixed(algo)).unwrap();
            if record {
                // Deliberately tiny: wrapping mid-collective must also be
                // invisible to the data path.
                group.enable_recording(64);
            }
            let mut data = inputs(8, 3000, 99);
            group.allreduce(&mut data, &codec).unwrap();
            data.into_iter()
                .map(|rank| rank.into_iter().map(f32::to_bits).collect())
                .collect()
        };
        assert_eq!(run(true), run(false), "{algo:?}: recording must be a pure observer");
    }
}
