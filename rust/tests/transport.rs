//! Transport-subsystem integration tests: the framed wire protocol through
//! its public API, and backend equivalence — the same quantized collective
//! must produce bit-identical results whether ranks are threads over mpsc
//! channels (InProc), endpoints of a real TCP mesh, or endpoints of a UDP
//! datagram mesh (including one running under an injected 5% wire-fault
//! program: drop + duplicate + corrupt + reorder).

use flashcomm::comm::{fabric, Algo, AlgoPolicy, Communicator};
use flashcomm::quant::Codec;
use flashcomm::session::SessionConfig;
use flashcomm::topo::{presets, Topology};
use flashcomm::transport::{frame, inproc, tcp, udp, Transport};
use flashcomm::util::Prng;

// ---------------------------------------------------------------- frame --

#[test]
fn frame_roundtrip() {
    let payload: Vec<u8> = (0..=255).collect();
    let framed = frame::encode(2, 7, 3, 99, &payload);
    assert_eq!(framed.len(), frame::FRAME_HEADER_LEN + payload.len());
    let (hdr, got) = frame::decode(framed).unwrap();
    assert_eq!((hdr.src, hdr.dst, hdr.epoch, hdr.seq, hdr.len), (2, 7, 3, 99, 256));
    assert_eq!(got, payload);
}

#[test]
fn frame_truncation_rejected() {
    let framed = frame::encode(0, 1, 0, 0, b"some quantized bytes");
    for cut in 0..framed.len() {
        assert!(frame::decode(framed[..cut].to_vec()).is_err(), "cut {cut}");
    }
}

#[test]
fn frame_bad_crc_rejected() {
    let mut framed = frame::encode(0, 1, 0, 0, b"some quantized bytes");
    let last = framed.len() - 1;
    framed[last] ^= 0x10;
    let err = frame::decode(framed).unwrap_err();
    assert!(err.to_string().contains("CRC"), "{err}");
}

#[test]
fn frame_version_mismatch_rejected() {
    let mut framed = frame::encode(0, 1, 0, 0, b"some quantized bytes");
    framed[4] = frame::FRAME_VERSION + 1;
    let err = frame::decode(framed).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");
}

// ---------------------------------------------------- backend equivalence --

/// Per-rank heavy-tailed inputs, deterministic in the rank only (the same
/// convention the comm test harness and the `worker` CLI use).
fn inputs(n: usize, len: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|r| {
            let mut rng = Prng::new(1000 + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// One rank's collective, generic over the backend; returns the algorithm
/// the policy resolved to alongside the reduced payload.
fn allreduce_rank_with<T: Transport>(
    h: fabric::RankHandle<T>,
    d: &[Vec<f32>],
    codec: &Codec,
    policy: AlgoPolicy,
) -> (Algo, Vec<f32>) {
    let mut comm = Communicator::from_handle(h);
    let mut v = d[comm.rank()].clone();
    let used = comm.allreduce(&mut v, codec, policy).unwrap();
    (used, v)
}

/// Fixed-algorithm variant returning just the payload.
fn allreduce_rank<T: Transport>(
    h: fabric::RankHandle<T>,
    d: &[Vec<f32>],
    codec: &Codec,
    algo: Algo,
) -> Vec<f32> {
    allreduce_rank_with(h, d, codec, AlgoPolicy::Fixed(algo)).1
}

#[test]
fn tcp_and_inproc_hier_allreduce_bit_identical() {
    // The acceptance pair: bit-split w4 and spike-reserved w2.
    let n = 4;
    let topo = Topology::new(presets::l40(), n);
    let data = inputs(n, 3000);
    for spec in ["int4@32", "int2-sr@32"] {
        let codec = Codec::parse(spec).unwrap();
        let d = &data;
        let (ip, ip_counters) =
            fabric::run_ranks(&topo, |h| allreduce_rank(h, d, &codec, Algo::Hier));
        let (tc, tc_counters) = fabric::run_ranks_with(tcp::local_mesh(n).unwrap(), &topo, |h| {
            allreduce_rank(h, d, &codec, Algo::Hier)
        });
        for r in 0..n {
            assert_eq!(bits(&ip[r]), bits(&tc[r]), "{spec}: rank {r} diverges across backends");
        }
        // Identical payload traffic too: same messages, same bytes.
        assert_eq!(ip_counters.snapshot(), tc_counters.snapshot(), "{spec}: traffic differs");
    }
}

#[test]
fn tcp_and_inproc_twostep_allreduce_bit_identical() {
    let n = 4;
    let topo = Topology::new(presets::h800(), n);
    let data = inputs(n, 2048);
    let codec = Codec::parse("int2-sr@32!").unwrap();
    let d = &data;
    let (ip, _) = fabric::run_ranks(&topo, |h| allreduce_rank(h, d, &codec, Algo::TwoStep));
    let (tc, _) = fabric::run_ranks_with(tcp::local_mesh(n).unwrap(), &topo, |h| {
        allreduce_rank(h, d, &codec, Algo::TwoStep)
    });
    for r in 0..n {
        assert_eq!(bits(&ip[r]), bits(&tc[r]), "rank {r}");
    }
}

#[test]
fn tcp_and_inproc_agree_under_auto_policy() {
    // Auto resolves from (topology, codec, size) only, so both backends
    // select the same algorithm and stay bit-identical.
    let n = 4;
    let topo = Topology::new(presets::l40(), n);
    let data = inputs(n, 2048);
    let codec = Codec::parse("int4@32").unwrap();
    let d = &data;
    let (ip, _) =
        fabric::run_ranks(&topo, |h| allreduce_rank_with(h, d, &codec, AlgoPolicy::Auto));
    let (tc, _) = fabric::run_ranks_with(tcp::local_mesh(n).unwrap(), &topo, |h| {
        allreduce_rank_with(h, d, &codec, AlgoPolicy::Auto)
    });
    for r in 0..n {
        assert_eq!(ip[r].0, tc[r].0, "rank {r}: algorithms diverge");
        assert_eq!(bits(&ip[r].1), bits(&tc[r].1), "rank {r}: payloads diverge");
    }
}

#[test]
fn inproc_mesh_usable_via_run_ranks_with() {
    // run_ranks is sugar for run_ranks_with(inproc::mesh(n), ..): both
    // paths must behave identically.
    let n = 4;
    let topo = Topology::new(presets::h800(), n);
    let data = inputs(n, 513);
    let codec = Codec::parse("int8").unwrap();
    let d = &data;
    let (a, _) = fabric::run_ranks(&topo, |h| allreduce_rank(h, d, &codec, Algo::TwoStep));
    let (b, _) = fabric::run_ranks_with(inproc::mesh(n), &topo, |h| {
        allreduce_rank(h, d, &codec, Algo::TwoStep)
    });
    assert_eq!(a, b);
}

#[test]
fn transport_stats_visible_through_rank_handle() {
    let n = 2;
    let topo = Topology::new(presets::h800(), n);
    let (stats, counters) = fabric::run_ranks_with(tcp::local_mesh(n).unwrap(), &topo, |h| {
        if h.rank == 0 {
            h.send(1, vec![7u8; 50]).unwrap();
        } else {
            assert_eq!(h.recv(0).unwrap(), vec![7u8; 50]);
        }
        h.transport().stats()
    });
    // TCP stats are per-endpoint: rank 0 sent one message, rank 1 none.
    assert_eq!(stats[0].messages, 1);
    assert_eq!(stats[0].payload_bytes, 50);
    assert_eq!(stats[0].wire_bytes, 50 + frame::FRAME_HEADER_LEN as u64);
    assert_eq!(stats[1].messages, 0);
    assert_eq!(counters.total_bytes(), 50);
}

// ------------------------------------------------------------ udp matrix --

#[test]
fn udp_and_inproc_bit_identical_across_every_algo_and_codec() {
    // A clean (fault-free) UDP mesh: every algorithm × the acceptance
    // codecs must match InProc bit-for-bit, with identical payload-level
    // traffic (segmentation/redundancy live below the payload counters).
    let n = 4;
    let flat = Topology::new(presets::h800(), n);
    let grouped = Topology::new(presets::l40(), n);
    let data = inputs(n, 3000);
    for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier, Algo::HierPipelined] {
        let topo = match algo {
            Algo::Hier | Algo::HierPipelined => &grouped,
            _ => &flat,
        };
        for spec in ["bf16", "int4@32", "int2-sr@32!"] {
            let codec = Codec::parse(spec).unwrap();
            let d = &data;
            let (ip, ip_counters) =
                fabric::run_ranks(topo, |h| allreduce_rank(h, d, &codec, algo));
            let (ud, ud_counters) =
                fabric::run_ranks_with(udp::local_mesh(n).unwrap(), topo, |h| {
                    allreduce_rank(h, d, &codec, algo)
                });
            for r in 0..n {
                assert_eq!(
                    bits(&ip[r]),
                    bits(&ud[r]),
                    "{algo:?}/{spec}: rank {r} diverges across backends"
                );
            }
            assert_eq!(
                ip_counters.snapshot(),
                ud_counters.snapshot(),
                "{algo:?}/{spec}: payload traffic differs"
            );
        }
    }
}

#[test]
fn udp_under_5pct_chaos_bit_identical_to_inproc() {
    // The acceptance drill: 5% drop + duplicate + corrupt + reorder on
    // every endpoint's outgoing datagrams. NACK reassembly, the probe
    // retransmit path, and tail redundancy must deliver every frame
    // exactly once and intact — the collective stays bit-identical to
    // InProc for every algorithm × codec.
    let n = 4;
    let flat = Topology::new(presets::h800(), n);
    let grouped = Topology::new(presets::l40(), n);
    let data = inputs(n, 3000);
    for algo in [Algo::Ring, Algo::TwoStep, Algo::Hier, Algo::HierPipelined] {
        let topo = match algo {
            Algo::Hier | Algo::HierPipelined => &grouped,
            _ => &flat,
        };
        for (i, spec) in ["bf16", "int4@32", "int2-sr@32!"].iter().enumerate() {
            let codec = Codec::parse(spec).unwrap();
            let d = &data;
            let seed = 0xFC_0205 + i as u64; // deterministic per-cell chaos
            let (ip, _) = fabric::run_ranks(topo, |h| allreduce_rank(h, d, &codec, algo));
            let mesh =
                udp::local_mesh_faulty(n, &SessionConfig::disabled(), seed, 0.05).unwrap();
            let (ud, _) =
                fabric::run_ranks_with(mesh, topo, |h| allreduce_rank(h, d, &codec, algo));
            for r in 0..n {
                assert_eq!(
                    bits(&ip[r]),
                    bits(&ud[r]),
                    "{algo:?}/{spec}: rank {r} diverges under 5% wire chaos"
                );
            }
        }
    }
}

#[test]
fn udp_chaos_run_reports_recovery_in_transport_stats() {
    // The robustness counters must show the machinery actually fired
    // during a chaos collective: retransmits or NACKs on some endpoint,
    // redundancy bytes everywhere, and the payload accounting intact.
    let n = 4;
    let topo = Topology::new(presets::h800(), n);
    let data = inputs(n, 4096);
    let codec = Codec::parse("int4@32").unwrap();
    let d = &data;
    let mesh = udp::local_mesh_faulty(n, &SessionConfig::disabled(), 77, 0.05).unwrap();
    let (stats, _) = fabric::run_ranks_with(mesh, &topo, |h| {
        allreduce_rank(h, d, &codec, Algo::TwoStep);
        h.transport().stats()
    });
    let total_retx: u64 = stats.iter().map(|s| s.retransmitted_chunks).sum();
    let total_nacks: u64 = stats.iter().map(|s| s.nacks_sent).sum();
    assert!(
        total_retx + total_nacks > 0,
        "5% chaos must exercise the recovery path: {stats:?}"
    );
    for (r, s) in stats.iter().enumerate() {
        assert!(s.redundancy_bytes > 0, "rank {r}: tail redundancy always ships: {s:?}");
        assert!(s.payload_bytes > 0 && s.messages > 0, "rank {r}: {s:?}");
    }
}
