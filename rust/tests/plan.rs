//! Plan-layer property tests (no artifacts needed): mixed-stage
//! [`CommPlan`]s driven through the public `Communicator` front door must
//! leave all ranks bit-identical on every backend and at every admissible
//! G; the plan compiler must be deterministic and honor the acceptance
//! crossover (aggressive cross-group codec on the tier-asymmetric
//! dual-NVLink cluster, uniform on the balanced L40 box); and the plan
//! cache must recompile nothing after warmup.

use flashcomm::comm::{fabric, Algo, Communicator, LocalGroup};
use flashcomm::plan::{compile, CommPlan, PlanCacheStats, PlanPolicy, StageCodecs};
use flashcomm::quant::Codec;
use flashcomm::topo::{presets, Topology};
use flashcomm::transport::tcp;
use flashcomm::util::Prng;

fn codec(s: &str) -> Codec {
    Codec::parse(s).unwrap()
}

fn rank_inputs(n: usize, len: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|r| {
            let mut rng = Prng::new(4200 + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Run `plan` over an in-process mesh; returns per-rank results.
fn run_inproc(topo: &Topology, inputs: &[Vec<f32>], plan: &CommPlan) -> Vec<Vec<f32>> {
    let (results, _) = fabric::run_ranks(topo, |h| {
        let mut c = Communicator::from_handle(h);
        let mut d = inputs[c.rank()].clone();
        c.allreduce_plan(&mut d, plan).unwrap();
        d
    });
    results
}

/// Run `plan` over a real TCP loopback mesh; returns per-rank results.
fn run_tcp(topo: &Topology, inputs: &[Vec<f32>], plan: &CommPlan) -> Vec<Vec<f32>> {
    let endpoints = tcp::local_mesh(topo.n_gpus).expect("tcp mesh bootstrap");
    let (results, _) = fabric::run_ranks_with(endpoints, topo, |h| {
        let mut c = Communicator::from_handle(h);
        let mut d = inputs[c.rank()].clone();
        c.allreduce_plan(&mut d, plan).unwrap();
        d
    });
    results
}

/// The admissible mixed-stage plan space the property test sweeps: both
/// hierarchical algorithms × distinct (intra, cross) pairs × chunk/window
/// variations. Every entry has differing stage codecs.
fn mixed_plans() -> Vec<CommPlan> {
    let pairs = [
        ("int8", "int4@32"),
        ("int4@32", "int2-sr@32!"),
        ("int8", "int2-sr@32"),
        ("bf16", "int8"),
    ];
    let mut plans = Vec::new();
    for (intra, cross) in pairs {
        let stages = StageCodecs::with_cross(codec(intra), codec(cross));
        assert!(!stages.is_uniform());
        plans.push(CommPlan {
            algo: Algo::Hier,
            stage_codecs: stages,
            chunks: 1,
            send_window: 1,
            codec_threads: 0,
        });
        for (chunks, window) in [(3, 1), (8, 2), (5, 4)] {
            plans.push(CommPlan {
                algo: Algo::HierPipelined,
                stage_codecs: stages,
                chunks,
                send_window: window,
                codec_threads: 0,
            });
        }
    }
    plans
}

#[test]
fn prop_mixed_stage_plans_bit_identical_across_ranks_at_g2_and_g4() {
    // Every admissible mixed-stage plan × G ∈ {2, 4} over InProc: all
    // ranks of all groups must agree bitwise, and the result must carry
    // signal (correlate with the exact sum).
    for topo in [Topology::with_groups(presets::l40(), 8, 2), presets::four_group_pcie(8).unwrap()]
    {
        let inputs = rank_inputs(8, 1536);
        let mut exact = vec![0f32; 1536];
        for v in &inputs {
            for (e, x) in exact.iter_mut().zip(v) {
                *e += *x;
            }
        }
        for plan in mixed_plans() {
            plan.validate(&topo).unwrap();
            let results = run_inproc(&topo, &inputs, &plan);
            for r in &results {
                assert_eq!(
                    bits(r),
                    bits(&results[0]),
                    "{plan} on G={}: ranks diverge",
                    topo.numa_groups
                );
            }
            let s = flashcomm::util::stats::sqnr_db(&exact, &results[0]);
            assert!(s > 4.0, "{plan} G={}: SQNR {s} dB", topo.numa_groups);
        }
    }
}

#[test]
fn prop_mixed_stage_plans_bit_identical_across_backends() {
    // TCP must deliver exactly the bits InProc computes for mixed-stage
    // plans, at G = 2 and G = 4 (a slice of the plan space — TCP meshes
    // are expensive to bootstrap).
    for topo in [Topology::with_groups(presets::l40(), 8, 2), presets::four_group_pcie(8).unwrap()]
    {
        let inputs = rank_inputs(8, 768);
        for plan in [
            CommPlan {
                algo: Algo::Hier,
                stage_codecs: StageCodecs::with_cross(codec("int4@32"), codec("int2-sr@32!")),
                chunks: 1,
                send_window: 1,
                codec_threads: 0,
            },
            CommPlan {
                algo: Algo::HierPipelined,
                stage_codecs: StageCodecs::with_cross(codec("int8"), codec("int4@32")),
                chunks: 4,
                send_window: 3,
                codec_threads: 0,
            },
        ] {
            let inproc = run_inproc(&topo, &inputs, &plan);
            let over_tcp = run_tcp(&topo, &inputs, &plan);
            for r in 0..8 {
                assert_eq!(
                    bits(&inproc[r]),
                    bits(&over_tcp[r]),
                    "{plan} G={}: TCP diverges from InProc at rank {r}",
                    topo.numa_groups
                );
            }
        }
    }
}

#[test]
fn compiler_is_deterministic_across_repeats_and_clones() {
    let topos = [
        Topology::new(presets::l40(), 8),
        presets::dual_nvlink_node(8).unwrap(),
        Topology::new(presets::h800(), 8),
    ];
    for topo in &topos {
        for spec in ["bf16", "int8", "int4@32"] {
            for elems in [512usize, 262_144, 8 << 20] {
                let first = compile(topo, elems, &codec(spec));
                for _ in 0..5 {
                    assert_eq!(compile(topo, elems, &codec(spec)), first, "{spec}@{elems}");
                    assert_eq!(compile(&topo.clone(), elems, &codec(spec)), first);
                }
            }
        }
    }
}

#[test]
fn pinned_crossover_duo_mixes_l40_stays_uniform() {
    // The acceptance crossover, end to end through Auto: the
    // dual-NVLink-node cluster compiles an aggressive cross-group codec
    // for >= 1 MB payloads; the balanced L40 box compiles uniform plans
    // at every size.
    let duo = presets::dual_nvlink_node(8).unwrap();
    let base = codec("int4@32");
    let mb_elems = 512 * 1024; // 1 MB of BF16 payload
    for elems in [mb_elems, 8 * mb_elems] {
        let plan = compile(&duo, elems, &base);
        assert!(matches!(plan.algo, Algo::Hier | Algo::HierPipelined), "{plan}");
        assert!(plan.cross_no_less_aggressive(), "{plan}");
        assert!(
            plan.stage_codecs.cross.asymptotic_wire_ratio()
                < plan.stage_codecs.intra_rs.asymptotic_wire_ratio(),
            "duo @ {elems} elems must mix: {plan}"
        );
    }
    let l40 = Topology::new(presets::l40(), 8);
    for elems in [8192usize, mb_elems, 8 * mb_elems] {
        let plan = compile(&l40, elems, &base);
        assert!(plan.stage_codecs.is_uniform(), "l40 @ {elems} elems must stay uniform: {plan}");
    }
}

#[test]
fn auto_plans_are_bit_identical_across_backends_on_the_duo() {
    // Acceptance pin: PlanPolicy::Auto on the dual-NVLink cluster — the
    // mixed-plan regime — resolves the same plan and the same bits over
    // InProc and TCP.
    let duo = presets::dual_nvlink_node(8).unwrap();
    let base = codec("int4@32");
    let len = 600_000; // >= 1 MB of BF16 payload: the mixed regime
    let inputs = rank_inputs(8, len);
    let policy = PlanPolicy::auto();
    let expected_plan = compile(&duo, len, &base);
    assert!(!expected_plan.stage_codecs.is_uniform(), "{expected_plan}");

    let ir = &inputs;
    let run = |endpoints: Option<Vec<tcp::TcpTransport>>| match endpoints {
        Some(eps) => {
            fabric::run_ranks_with(eps, &duo, |h| {
                let mut c = Communicator::from_handle(h);
                let mut d = ir[c.rank()].clone();
                let plan = c.allreduce_planned(&mut d, &base, &policy).unwrap();
                (plan, d)
            })
            .0
        }
        None => {
            fabric::run_ranks(&duo, |h| {
                let mut c = Communicator::from_handle(h);
                let mut d = ir[c.rank()].clone();
                let plan = c.allreduce_planned(&mut d, &base, &policy).unwrap();
                (plan, d)
            })
            .0
        }
    };
    let inproc = run(None);
    let over_tcp = run(Some(tcp::local_mesh(8).unwrap()));
    for r in 0..8 {
        assert_eq!(inproc[r].0, expected_plan, "rank {r} resolved a different plan");
        assert_eq!(over_tcp[r].0, expected_plan, "TCP rank {r} resolved a different plan");
        assert_eq!(
            bits(&inproc[r].1),
            bits(&over_tcp[r].1),
            "rank {r}: TCP diverges from InProc under Auto"
        );
    }
}

#[test]
fn warm_plan_cache_recompiles_nothing() {
    // Acceptance pin: repeated (topo, n, codec) calls hit the cache —
    // exactly one miss per rank per distinct shape, zero recompiles after
    // warmup, observable through the public hit/miss counters.
    let mut group = LocalGroup::new_planned(
        &presets::dual_nvlink_node(8).unwrap(),
        PlanPolicy::auto(),
    )
    .unwrap();
    let base = codec("int4@32");
    let n = 8;
    let mut data = rank_inputs(n, 4096);
    group.allreduce(&mut data, &base).unwrap();
    let warm = group.plan_cache_stats();
    assert_eq!(
        warm,
        PlanCacheStats { hits: 0, misses: n as u64, evictions: 0 },
        "warmup: one compile per rank"
    );
    for round in 1..=4 {
        let mut data = rank_inputs(n, 4096);
        group.allreduce(&mut data, &base).unwrap();
        let s = group.plan_cache_stats();
        assert_eq!(s.misses, n as u64, "round {round}: a warm cache must not recompile");
        assert_eq!(s.hits, (round * n) as u64, "round {round}");
    }
    // A new shape compiles once more per rank, then is warm too.
    let mut data = rank_inputs(n, 8192);
    group.allreduce(&mut data, &base).unwrap();
    assert_eq!(group.plan_cache_stats().misses, 2 * n as u64);
    let mut data = rank_inputs(n, 8192);
    group.allreduce(&mut data, &base).unwrap();
    assert_eq!(group.plan_cache_stats().misses, 2 * n as u64);
}

#[test]
fn fixed_mixed_plan_equals_auto_when_auto_compiles_it() {
    // Sanity on the two policy arms: running Auto's compiled plan as a
    // Fixed plan produces identical bits (resolution and execution are
    // cleanly separated).
    let duo = presets::dual_nvlink_node(8).unwrap();
    let base = codec("int4@32");
    let len = 600_000;
    let inputs = rank_inputs(8, len);
    let compiled = compile(&duo, len, &base);
    let via_fixed = run_inproc(&duo, &inputs, &compiled);
    let ir = &inputs;
    let (via_auto, _) = fabric::run_ranks(&duo, |h| {
        let mut c = Communicator::from_handle(h);
        let mut d = ir[c.rank()].clone();
        c.allreduce_planned(&mut d, &base, &PlanPolicy::auto()).unwrap();
        d
    });
    for r in 0..8 {
        assert_eq!(bits(&via_fixed[r]), bits(&via_auto[r]), "rank {r}");
    }
}
