//! Offline stub of the `xla` (xla-rs) API surface that flashcomm uses.
//!
//! The real crate binds the XLA C++ extension (PJRT CPU client, HLO text
//! parsing, device buffers). That native library is not part of the offline
//! toolchain, so this stub keeps the crate building and the non-PJRT test
//! suite running:
//!
//! - [`Literal`] is a fully functional host-side typed buffer — creation,
//!   shape queries, and `to_vec` round-trips work exactly like the real
//!   crate, so `runtime::Tensor` conversions and their tests pass.
//! - Anything that would compile or execute HLO ([`PjRtClient::cpu`],
//!   [`HloModuleProto::from_text_file`], …) returns a clear runtime error.
//!   Callers already gate those paths on the artifacts directory existing.
//!
//! Swap the `vendor/xla` path dependency in `Cargo.toml` for the real
//! `xla` crate (plus an `xla_extension` install) to run artifact-backed
//! integration paths; no source change is needed — the signatures match.

use std::fmt;
use std::path::Path;

/// Error type matching the real crate's position in `?`-conversions: it is
/// `std::error::Error + Send + Sync + 'static`, so it lifts into
/// `anyhow::Error` at every call site.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT unavailable: flashcomm was built against the stub `xla` crate \
     (rust/vendor/xla). Install xla_extension and point Cargo at the real \
     xla-rs crate to compile/execute HLO artifacts";

/// Element dtypes flashcomm materializes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 => 4,
        }
    }
}

/// Host native types that can view a [`Literal`]'s storage.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_ne_bytes4(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_ne_bytes4(b: [u8; 4]) -> f32 {
        f32::from_ne_bytes(b)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_ne_bytes4(b: [u8; 4]) -> i32 {
        i32::from_ne_bytes(b)
    }
}

/// Array shape (dims in elements), as returned by [`Literal::array_shape`].
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-side typed buffer. Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product();
        if elems * ty.byte_size() != data.len() {
            return Err(Error::new(format!(
                "shape {dims:?} ({elems} elems of {ty:?}) does not match {} data bytes",
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::new(format!(
                "literal holds {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .data
            .chunks_exact(4)
            .map(|c| T::from_ne_bytes4([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Explode a tuple literal. Tuples only come out of PJRT execution,
    /// which the stub cannot perform.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::new("array literal is not a tuple (and the stub cannot execute HLO)"))
    }
}

/// Device buffer handle produced by execution (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::new(STUB_MSG))
    }
}

/// PJRT client. Construction fails in the stub with a clear message.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::new(STUB_MSG))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(STUB_MSG))
    }
}

/// Compiled executable handle (unreachable in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(STUB_MSG))
    }
}

/// Parsed HLO module (parsing needs the native text parser).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::new(format!(
            "cannot parse HLO text {:?}: {STUB_MSG}",
            path.as_ref()
        )))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.array_shape().unwrap().dims(), &[3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err(), "dtype mismatch must error");
    }

    #[test]
    fn literal_rejects_shape_mismatch() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 4])
            .is_err());
    }

    #[test]
    fn pjrt_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
