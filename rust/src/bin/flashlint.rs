//! Standalone flashlint driver.
//!
//! ```text
//! flashlint [--root DIR] [--json]
//! ```
//!
//! Lints the crate rooted at `--root` (default `.`, must contain `src/`)
//! with the five repo-native rules (DESIGN.md §14). Exits 0 when clean,
//! 1 on findings, 2 on usage/IO errors. `--json` swaps the human listing
//! for the machine-readable report CI uploads as an artifact.

use std::path::PathBuf;
use std::process::ExitCode;

use flashcomm::lint;

const USAGE: &str = "\
flashlint — repo-native static analysis (wire, panic, lock, unsafe, obs)

usage: flashlint [--root DIR] [--json]
  --root DIR   crate root holding src/ (default .)
  --json       machine-readable report on stdout
";

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => {
                    eprintln!("flashlint: --root needs a value");
                    return ExitCode::from(2);
                }
            },
            "--json" => json = true,
            "--help" | "-h" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("flashlint: unknown argument '{other}'");
                return ExitCode::from(2);
            }
        }
    }
    let report = match lint::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("flashlint: {e:#}");
            return ExitCode::from(2);
        }
    };
    if json {
        print!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
