//! Fabric-wide tracing: clock sync, trace parsing, and the merge into
//! one Perfetto-viewable Chrome-trace-event JSON (DESIGN.md §15).
//!
//! Per-rank [`Recorder`] timelines each start at an arbitrary process
//! instant, so they are not directly comparable. Three pieces fix that:
//!
//! - [`ClockSync`] — NTP-style offset estimation from a handful of probe
//!   round-trips (`session::sync_clocks` runs the exchange over the live
//!   transport; this module owns the math). For probe timestamps
//!   `t1` (request sent, requester clock), `t2` (request received,
//!   reference clock), `t3` (reply sent, reference clock), `t4` (reply
//!   received, requester clock):
//!   `offset = ((t2 − t1) + (t3 − t4)) / 2`, `rtt = (t4 − t1) − (t3 − t2)`,
//!   and the estimate from the minimum-RTT probe is wrong by at most
//!   `rtt / 2`. Fixed-capacity sample store — the probe path allocates
//!   nothing (pinned in `tests/telemetry_alloc.rs`).
//! - [`RankTrace`] / [`parse_trace`] — one rank's trace, either straight
//!   off a live recorder or parsed back from the `--trace-out` JSON via
//!   the hand-rolled parser (no serde in the dependency set).
//! - [`merge_traces`] — pairs each rank's events into spans, aligns them
//!   with the clock offsets, matches send→recv edges via the per-link
//!   message ordinals the fabric stamps ([`Event::link`]), and emits one
//!   deterministic Chrome-trace JSON: one track per rank, spans named
//!   `algo/stage/codec`, flow arrows per matched edge (named after the
//!   stage), instant markers for session point events. Byte-identical
//!   output for identical inputs — pinned in `tests/trace.rs`.

use anyhow::{anyhow, bail, Context, Result};

use super::codec_tag_name;
use super::recorder::{AlgoTag, Event, Kind, Op, Recorder, Stage};

/// Most probe round-trips one [`ClockSync`] keeps (more add nothing: the
/// estimate uses the minimum-RTT sample).
pub const MAX_PROBES: usize = 16;

/// One NTP-style probe round-trip. `t1`/`t4` are on the requester's
/// recorder clock, `t2`/`t3` on the reference (rank 0) recorder clock.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ProbeSample {
    pub t1: u64,
    pub t2: u64,
    pub t3: u64,
    pub t4: u64,
}

impl ProbeSample {
    /// Estimated offset of the requester clock to the reference clock
    /// (`t_ref ≈ t_local + offset`): `((t2 − t1) + (t3 − t4)) / 2`.
    pub fn offset_nanos(self) -> i64 {
        let a = self.t2 as i128 - self.t1 as i128;
        let b = self.t3 as i128 - self.t4 as i128;
        ((a + b) / 2) as i64
    }

    /// Round-trip time net of the reference's service time:
    /// `(t4 − t1) − (t3 − t2)`. The offset error bound is `rtt / 2`.
    pub fn rtt_nanos(self) -> u64 {
        let rtt = (self.t4 as i128 - self.t1 as i128) - (self.t3 as i128 - self.t2 as i128);
        rtt.max(0) as u64
    }
}

/// Fixed-capacity NTP-style offset estimator — see the module docs for
/// the formulas. Allocation-free by construction.
#[derive(Debug, Clone, Copy, Default)]
pub struct ClockSync {
    samples: [ProbeSample; MAX_PROBES],
    len: usize,
}

impl ClockSync {
    pub fn new() -> ClockSync {
        ClockSync::default()
    }

    /// Record one probe round-trip. Returns `false` (sample ignored) once
    /// [`MAX_PROBES`] are held.
    pub fn add(&mut self, sample: ProbeSample) -> bool {
        if self.len == MAX_PROBES {
            return false;
        }
        self.samples[self.len] = sample;
        self.len += 1;
        true
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// `(offset_nanos, rtt_nanos)` from the minimum-RTT sample — the
    /// probe least disturbed by queueing, hence the tightest error bound.
    /// `None` until at least one sample is held.
    pub fn estimate(&self) -> Option<(i64, u64)> {
        let best = self.samples[..self.len].iter().min_by_key(|s| s.rtt_nanos())?;
        Some((best.offset_nanos(), best.rtt_nanos()))
    }

    /// The estimate as exportable stats for `rank`.
    pub fn stats(&self, rank: u16) -> Option<ClockSyncStats> {
        let (offset_nanos, rtt_nanos) = self.estimate()?;
        Some(ClockSyncStats { rank, offset_nanos, rtt_nanos, probes: self.len as u64 })
    }
}

/// One rank's clock-sync result, exported through the metrics registry
/// (flashlint R5 keeps every field in the export honest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClockSyncStats {
    /// The synced rank (rank 0, the reference, reports offset 0).
    pub rank: u16,
    /// Offset to the reference clock: `t_ref ≈ t_local + offset`.
    pub offset_nanos: i64,
    /// Minimum probe RTT behind the estimate (error bound `rtt / 2`).
    pub rtt_nanos: u64,
    /// Probe round-trips the estimate was picked from.
    pub probes: u64,
}

impl ClockSyncStats {
    /// The reference rank's trivial self-estimate.
    pub fn reference(rank: u16) -> ClockSyncStats {
        ClockSyncStats { rank, offset_nanos: 0, rtt_nanos: 0, probes: 0 }
    }
}

/// One event of a [`RankTrace`]: the schema of the trace JSON, with the
/// codec as its display name (the packed tag does not travel through the
/// JSON) and enums decoded.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub seq: u64,
    pub t_nanos: u64,
    pub kind: Kind,
    pub op: Op,
    pub stage: Stage,
    pub algo: AlgoTag,
    pub rank: u16,
    pub codec: String,
    pub plan_fp: u64,
    pub bytes: u64,
    pub chunk: u32,
    /// `(peer, per-direction ordinal)` for fabric send/recv events.
    pub link: Option<(u16, u64)>,
}

impl TraceEvent {
    pub fn from_event(e: &Event) -> TraceEvent {
        TraceEvent {
            seq: e.seq,
            t_nanos: e.t_nanos,
            kind: e.kind,
            op: e.op,
            stage: e.stage,
            algo: e.algo,
            rank: e.rank,
            codec: codec_tag_name(e.codec_tag),
            plan_fp: e.plan_fp,
            bytes: e.bytes,
            chunk: e.chunk,
            link: e.link,
        }
    }
}

/// One rank's trace: the header fields of the trace JSON plus the decoded
/// events, in sequence order. Built either live ([`RankTrace::from_recorder`])
/// or from a `--trace-out` file ([`parse_trace`]).
#[derive(Debug, Clone, PartialEq)]
pub struct RankTrace {
    pub rank: u16,
    pub capacity: u64,
    pub recorded: u64,
    pub dropped_events: u64,
    pub clock_offset_nanos: i64,
    pub clock_rtt_nanos: u64,
    pub clock_probes: u64,
    pub events: Vec<TraceEvent>,
}

impl RankTrace {
    pub fn from_recorder(rec: &Recorder) -> RankTrace {
        let (clock_offset_nanos, clock_rtt_nanos, clock_probes) = rec.clock();
        RankTrace {
            rank: rec.rank() as u16,
            capacity: rec.capacity() as u64,
            recorded: rec.total_recorded(),
            dropped_events: rec.dropped_events(),
            clock_offset_nanos,
            clock_rtt_nanos,
            clock_probes,
            events: rec.events().iter().map(TraceEvent::from_event).collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Trace JSON parsing (hand-rolled: the dependency set has no serde, and
// the input is this crate's own `trace_json` output).

#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get<'a>(&'a self, key: &str) -> Option<&'a Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            _ => None,
        }
    }

    fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(v) if *v >= 0 => Some(*v as u64),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> JsonParser<'a> {
    fn new(text: &'a str) -> JsonParser<'a> {
        JsonParser { bytes: text.as_bytes(), pos: 0 }
    }

    fn error(&self, what: &str) -> anyhow::Error {
        anyhow!("trace JSON: {what} at byte {}", self.pos)
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.error(&format!("expected '{}'", b as char)))
        }
    }

    fn eat_lit(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.error(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.error("unexpected end of input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.eat_lit("true", Json::Bool(true)),
            b'f' => self.eat_lit("false", Json::Bool(false)),
            b'n' => self.eat_lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.error(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.error("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.error("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek().ok_or_else(|| self.error("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek().ok_or_else(|| self.error("unterminated escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let end = self.pos + 4;
                            let hex = self
                                .bytes
                                .get(self.pos..end)
                                .ok_or_else(|| self.error("truncated \\u escape"))?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| self.error("non-UTF8 \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.error("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.error("bad \\u code point"))?,
                            );
                            self.pos = end;
                        }
                        _ => return Err(self.error("unknown escape")),
                    }
                }
                _ => {
                    // Multi-byte UTF-8: copy the whole code point.
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    let end = start + width;
                    let chunk = self
                        .bytes
                        .get(start..end)
                        .ok_or_else(|| self.error("truncated UTF-8"))?;
                    let s =
                        std::str::from_utf8(chunk).map_err(|_| self.error("invalid UTF-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut float = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.error("non-UTF8 number"))?;
        if float {
            text.parse::<f64>().map(Json::Float).map_err(|_| self.error("bad number"))
        } else {
            text.parse::<i64>().map(Json::Int).map_err(|_| self.error("bad integer"))
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn req_u64(obj: &Json, key: &str) -> Result<u64> {
    obj.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("trace JSON: missing or non-integer \"{key}\""))
}

fn opt_u64(obj: &Json, key: &str) -> u64 {
    obj.get(key).and_then(Json::as_u64).unwrap_or(0)
}

fn req_name<'a>(obj: &'a Json, key: &str) -> Result<&'a str> {
    obj.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("trace JSON: missing or non-string \"{key}\""))
}

/// Parse one per-rank trace file (the output of
/// [`trace_json`](super::trace_json)) back into a [`RankTrace`]. Header
/// fields older traces lack (`dropped_events`, the clock block) default
/// to 0, so pre-clock-sync traces still merge.
pub fn parse_trace(text: &str) -> Result<RankTrace> {
    let mut parser = JsonParser::new(text);
    let root = parser.value()?;
    parser.skip_ws();
    if parser.pos != parser.bytes.len() {
        bail!("trace JSON: trailing garbage at byte {}", parser.pos);
    }
    let rank = req_u64(&root, "rank")? as u16;
    let events_json = match root.get("events") {
        Some(Json::Arr(items)) => items,
        _ => bail!("trace JSON: missing \"events\" array"),
    };
    let mut events = Vec::with_capacity(events_json.len());
    for (i, e) in events_json.iter().enumerate() {
        let event = parse_event(e).with_context(|| format!("event {i} of rank {rank}"))?;
        events.push(event);
    }
    Ok(RankTrace {
        rank,
        capacity: req_u64(&root, "capacity")?,
        recorded: req_u64(&root, "recorded")?,
        dropped_events: opt_u64(&root, "dropped_events"),
        clock_offset_nanos: root
            .get("clock_offset_nanos")
            .and_then(Json::as_i64)
            .unwrap_or(0),
        clock_rtt_nanos: opt_u64(&root, "clock_rtt_nanos"),
        clock_probes: opt_u64(&root, "clock_probes"),
        events,
    })
}

fn parse_event(e: &Json) -> Result<TraceEvent> {
    let kind = Kind::from_name(req_name(e, "kind")?)
        .ok_or_else(|| anyhow!("unknown event kind"))?;
    let op = Op::from_name(req_name(e, "op")?).ok_or_else(|| anyhow!("unknown event op"))?;
    let stage =
        Stage::from_name(req_name(e, "stage")?).ok_or_else(|| anyhow!("unknown event stage"))?;
    let algo =
        AlgoTag::from_name(req_name(e, "algo")?).ok_or_else(|| anyhow!("unknown event algo"))?;
    let fp_text = req_name(e, "plan_fp")?;
    let plan_fp = u64::from_str_radix(fp_text.trim_start_matches("0x"), 16)
        .map_err(|_| anyhow!("bad plan_fp {fp_text:?}"))?;
    let link = match (e.get("peer"), e.get("link_seq")) {
        (Some(p), Some(q)) => Some((
            p.as_u64().ok_or_else(|| anyhow!("bad peer"))? as u16,
            q.as_u64().ok_or_else(|| anyhow!("bad link_seq"))?,
        )),
        (None, None) => None,
        _ => bail!("peer and link_seq must appear together"),
    };
    Ok(TraceEvent {
        seq: req_u64(e, "seq")?,
        t_nanos: req_u64(e, "t_nanos")?,
        kind,
        op,
        stage,
        algo,
        rank: req_u64(e, "rank")? as u16,
        codec: req_name(e, "codec")?.to_string(),
        plan_fp,
        bytes: req_u64(e, "bytes")?,
        chunk: req_u64(e, "chunk")? as u32,
        link,
    })
}

// ---------------------------------------------------------------------------
// Span pairing and the Chrome-trace merge.

/// One paired span of a rank's trace, on the fabric-aligned clock
/// (`start_nanos` includes the rank's clock offset, so spans of different
/// ranks are directly comparable).
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub rank: u16,
    pub op: Op,
    pub stage: Stage,
    pub algo: AlgoTag,
    pub codec: String,
    /// Aligned start time (local `t_nanos` + the rank's clock offset —
    /// may be negative for a rank whose clock runs ahead of rank 0's).
    pub start_nanos: i128,
    pub dur_nanos: u64,
    /// The Start event's byte word (element count for codec spans,
    /// payload length for sends).
    pub start_bytes: u64,
    /// The End event's byte word (bytes on the wire).
    pub end_bytes: u64,
    pub chunk: u32,
    /// The Start event's recorder sequence number (trace-order tiebreak).
    pub seq: u64,
    pub plan_fp: u64,
    pub link: Option<(u16, u64)>,
}

impl Span {
    pub fn end_nanos(&self) -> i128 {
        self.start_nanos + self.dur_nanos as i128
    }
}

/// Point events (peer loss, epoch bumps, rejoins) surfaced as instants.
#[derive(Debug, Clone, PartialEq)]
pub struct Instant {
    pub rank: u16,
    pub op: Op,
    pub t_nanos: i128,
    pub bytes: u64,
    pub seq: u64,
}

/// Pair one rank's events into aligned spans, innermost-first per
/// `(algo, stage, op, codec)` like the metrics registry. Returns
/// `(spans, instants, unpaired_event_count)`; unpaired events (a Start
/// whose End was overwritten, or vice versa) are counted, never invented.
pub fn paired_spans(trace: &RankTrace) -> (Vec<Span>, Vec<Instant>, usize) {
    let offset = trace.clock_offset_nanos as i128;
    let mut open: std::collections::BTreeMap<(u8, u8, u8, &str), Vec<&TraceEvent>> =
        std::collections::BTreeMap::new();
    let mut spans = Vec::new();
    let mut instants = Vec::new();
    let mut unpaired = 0usize;
    for e in &trace.events {
        if matches!(e.op, Op::PeerLost | Op::EpochBump | Op::Rejoin) {
            instants.push(Instant {
                rank: e.rank,
                op: e.op,
                t_nanos: e.t_nanos as i128 + offset,
                bytes: e.bytes,
                seq: e.seq,
            });
            continue;
        }
        let key = (e.algo as u8, e.stage as u8, e.op as u8, e.codec.as_str());
        match e.kind {
            Kind::Start => open.entry(key).or_default().push(e),
            Kind::End => {
                let Some(start) = open.get_mut(&key).and_then(|v| v.pop()) else {
                    unpaired += 1;
                    continue;
                };
                spans.push(Span {
                    rank: start.rank,
                    op: start.op,
                    stage: start.stage,
                    algo: start.algo,
                    codec: start.codec.clone(),
                    start_nanos: start.t_nanos as i128 + offset,
                    dur_nanos: e.t_nanos.saturating_sub(start.t_nanos),
                    start_bytes: start.bytes,
                    end_bytes: e.bytes,
                    chunk: start.chunk,
                    seq: start.seq,
                    plan_fp: start.plan_fp,
                    link: start.link,
                });
            }
        }
    }
    unpaired += open.values().map(Vec::len).sum::<usize>();
    spans.sort_by_key(|s| (s.start_nanos, s.seq));
    (spans, instants, unpaired)
}

/// The merged fabric trace: Chrome-trace-event JSON plus merge
/// diagnostics. `json` is deterministic — identical inputs merge to
/// byte-identical output.
#[derive(Debug, Clone)]
pub struct MergedTrace {
    /// Chrome-trace-event JSON (open in Perfetto / `chrome://tracing`).
    pub json: String,
    /// Gap and mismatch warnings (wraparound losses, unmatched edges).
    pub warnings: Vec<String>,
    pub ranks: usize,
    pub spans: usize,
    /// Matched send→recv flow arrows.
    pub flows: usize,
}

/// Microseconds with fixed 3-decimal nanosecond precision — Chrome trace
/// `ts`/`dur` are in µs; fixed formatting keeps the merge deterministic.
fn fmt_us(nanos: i128) -> String {
    let (sign, n) = if nanos < 0 { ("-", -nanos) } else { ("", nanos) };
    format!("{sign}{}.{:03}", n / 1000, n % 1000)
}

/// Merge per-rank traces into one fabric-wide Chrome-trace JSON. Input
/// order does not matter (tracks sort by rank); ranks must be unique.
/// See the module docs for the event mapping; warnings flag wrapped
/// (lossy) inputs and send/recv edges whose other side is missing.
pub fn merge_traces(traces: &[RankTrace]) -> Result<MergedTrace> {
    if traces.is_empty() {
        bail!("nothing to merge: no rank traces given");
    }
    let mut order: Vec<&RankTrace> = traces.iter().collect();
    order.sort_by_key(|t| t.rank);
    for pair in order.windows(2) {
        if pair[0].rank == pair[1].rank {
            bail!("duplicate trace for rank {}", pair[0].rank);
        }
    }

    let mut warnings = Vec::new();
    let mut all_spans: Vec<Span> = Vec::new();
    let mut all_instants: Vec<Instant> = Vec::new();
    for t in &order {
        if t.dropped_events > 0 {
            warnings.push(format!(
                "rank {}: ring wrapped, {} events dropped — trace has gaps \
                 (raise --trace-capacity)",
                t.rank, t.dropped_events
            ));
        }
        let (spans, instants, unpaired) = paired_spans(t);
        if unpaired > 0 {
            warnings.push(format!(
                "rank {}: {unpaired} events had no span partner (wrapped mid-span?)",
                t.rank
            ));
        }
        all_spans.extend(spans);
        all_instants.extend(instants);
    }

    // Send→recv edges: a send's (src → dst, ordinal) matches the dst's
    // recv (src → dst, ordinal) — the per-link FIFO contract makes the
    // ordinals line up.
    let mut sends: std::collections::BTreeMap<(u16, u16, u64), usize> =
        std::collections::BTreeMap::new();
    let mut recvs: std::collections::BTreeMap<(u16, u16, u64), usize> =
        std::collections::BTreeMap::new();
    for (i, s) in all_spans.iter().enumerate() {
        if let Some((peer, ordinal)) = s.link {
            match s.op {
                Op::Send => {
                    sends.insert((s.rank, peer, ordinal), i);
                }
                Op::Recv => {
                    recvs.insert((peer, s.rank, ordinal), i);
                }
                _ => {}
            }
        }
    }
    let mut flows: Vec<(usize, usize)> = Vec::new();
    let mut unmatched = 0usize;
    for (key, send_idx) in &sends {
        match recvs.get(key) {
            Some(recv_idx) => flows.push((*send_idx, *recv_idx)),
            None => unmatched += 1,
        }
    }
    unmatched += recvs.keys().filter(|k| !sends.contains_key(*k)).count();
    if unmatched > 0 {
        warnings.push(format!(
            "{unmatched} send/recv edges missing their other side (wrapped or lost peer)"
        ));
    }

    // Normalize to the earliest aligned instant so `ts` starts near 0.
    let t0 = all_spans
        .iter()
        .map(|s| s.start_nanos)
        .chain(all_instants.iter().map(|i| i.t_nanos))
        .min()
        .unwrap_or(0);

    let mut events: Vec<String> = Vec::new();
    events.push(
        "{\"ph\":\"M\",\"pid\":1,\"tid\":0,\"name\":\"process_name\",\
         \"args\":{\"name\":\"flashcomm fabric\"}}"
            .to_string(),
    );
    for t in &order {
        events.push(format!(
            "{{\"ph\":\"M\",\"pid\":1,\"tid\":{},\"name\":\"thread_name\",\
             \"args\":{{\"name\":\"rank {}\"}}}}",
            t.rank, t.rank
        ));
    }
    for s in &all_spans {
        events.push(format!(
            "{{\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{},\"dur\":{},\
             \"name\":\"{}/{}/{}\",\"cat\":\"{}\",\"args\":{{\"op\":\"{}\",\"bytes\":{},\
             \"chunk\":{},\"seq\":{},\"plan_fp\":\"{:#018x}\"}}}}",
            s.rank,
            fmt_us(s.start_nanos - t0),
            fmt_us(s.dur_nanos as i128),
            s.algo.name(),
            s.stage.name(),
            s.codec,
            s.op.name(),
            s.op.name(),
            s.end_bytes,
            s.chunk,
            s.seq,
            s.plan_fp
        ));
    }
    all_instants.sort_by_key(|i| (i.t_nanos, i.rank, i.seq));
    for i in &all_instants {
        events.push(format!(
            "{{\"ph\":\"i\",\"pid\":1,\"tid\":{},\"ts\":{},\"s\":\"g\",\"name\":\"{}\",\
             \"args\":{{\"bytes\":{}}}}}",
            i.rank,
            fmt_us(i.t_nanos - t0),
            i.op.name(),
            i.bytes
        ));
    }
    flows.sort_by_key(|&(s, r)| {
        (all_spans[s].start_nanos, all_spans[s].rank, all_spans[s].seq, r)
    });
    for (id, &(send_idx, recv_idx)) in flows.iter().enumerate() {
        let (send, recv) = (&all_spans[send_idx], &all_spans[recv_idx]);
        events.push(format!(
            "{{\"ph\":\"s\",\"pid\":1,\"tid\":{},\"ts\":{},\"id\":{},\"name\":\"{}\",\
             \"cat\":\"flow\"}}",
            send.rank,
            fmt_us(send.start_nanos - t0),
            id + 1,
            send.stage.name()
        ));
        events.push(format!(
            "{{\"ph\":\"f\",\"pid\":1,\"tid\":{},\"ts\":{},\"id\":{},\"name\":\"{}\",\
             \"cat\":\"flow\",\"bp\":\"e\"}}",
            recv.rank,
            fmt_us(recv.end_nanos() - t0),
            id + 1,
            recv.stage.name()
        ));
    }

    let mut json = String::with_capacity(128 + events.iter().map(String::len).sum::<usize>());
    json.push_str(&format!(
        "{{\"displayTimeUnit\":\"ms\",\"otherData\":{{\"ranks\":{},\"spans\":{},\
         \"flows\":{}}},\"traceEvents\":[",
        order.len(),
        all_spans.len(),
        flows.len()
    ));
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            json.push(',');
        }
        json.push('\n');
        json.push_str(e);
    }
    json.push_str("\n]}\n");

    Ok(MergedTrace {
        json,
        warnings,
        ranks: order.len(),
        spans: all_spans.len(),
        flows: flows.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::trace_json;

    #[test]
    fn offset_math_matches_the_ntp_formulas() {
        // Requester clock runs 1000 ns behind the reference; one-way
        // delays 300 ns out, 500 ns back; service time 100 ns.
        let s = ProbeSample { t1: 0, t2: 1300, t3: 1400, t4: 900 };
        assert_eq!(s.rtt_nanos(), 800, "(t4-t1) - (t3-t2)");
        let offset = s.offset_nanos();
        assert_eq!(offset, 900, "((t2-t1)+(t3-t4))/2 under asymmetric delay");
        // The bound holds: |est - true| = |900 - 1000| = 100 <= rtt/2.
        assert!((offset - 1000).unsigned_abs() <= s.rtt_nanos() / 2);
    }

    #[test]
    fn estimate_picks_the_min_rtt_probe_and_caps_samples() {
        let mut cs = ClockSync::new();
        assert!(cs.estimate().is_none());
        // Symmetric probe (100 ns each way, 100 ns service), requester
        // 500 ns behind the reference: offset exactly 500, rtt 200.
        cs.add(ProbeSample { t1: 0, t2: 600, t3: 700, t4: 300 });
        // Noisy probe: huge rtt, skewed offset — must lose.
        cs.add(ProbeSample { t1: 1000, t2: 9000, t3: 9100, t4: 11_000 });
        let (offset, rtt) = cs.estimate().unwrap();
        assert_eq!((offset, rtt), (500, 200));
        let stats = cs.stats(3).unwrap();
        assert_eq!(stats, ClockSyncStats { rank: 3, offset_nanos: 500, rtt_nanos: 200, probes: 2 });
        for _ in 0..MAX_PROBES {
            cs.add(ProbeSample::default());
        }
        assert_eq!(cs.len(), MAX_PROBES, "sample store is capped");
        assert!(!cs.add(ProbeSample::default()));
    }

    fn recorded_trace() -> RankTrace {
        let rec = Recorder::new(2, 64);
        rec.set_plan(0xabc, AlgoTag::Hier);
        rec.set_stage(Stage::ReduceScatter, 0x2004);
        rec.record_link(Kind::Start, Op::Send, 100, 3, 0);
        rec.record_link(Kind::End, Op::Send, 100, 3, 0);
        rec.record(Kind::Start, Op::Encode, 256);
        rec.record(Kind::End, Op::Encode, 64);
        rec.set_clock(-250, 1000, 8);
        RankTrace::from_recorder(&rec)
    }

    #[test]
    fn trace_json_round_trips_through_the_parser() {
        let rec = Recorder::new(2, 64);
        rec.set_plan(0xabc, AlgoTag::Hier);
        rec.set_stage(Stage::ReduceScatter, 0x2004);
        rec.record_link(Kind::Start, Op::Send, 100, 3, 7);
        rec.record_link(Kind::End, Op::Send, 100, 3, 7);
        rec.set_clock(-250, 1000, 8);
        let direct = RankTrace::from_recorder(&rec);
        let parsed = parse_trace(&trace_json(&rec)).unwrap();
        assert_eq!(parsed, direct, "parse(serialize(x)) == x");
        assert_eq!(parsed.clock_offset_nanos, -250);
        assert_eq!(parsed.events[0].link, Some((3, 7)));
    }

    #[test]
    fn parser_rejects_garbage_loudly() {
        assert!(parse_trace("").is_err());
        assert!(parse_trace("{\"rank\":0}").is_err(), "missing events");
        assert!(parse_trace("[1,2,3]").is_err(), "not a trace object");
        let ok = "{\"rank\":0,\"capacity\":4,\"recorded\":0,\"events\":[]}";
        assert!(parse_trace(ok).is_ok(), "legacy headers without clock fields parse");
        assert!(parse_trace(&format!("{ok}x")).is_err(), "trailing garbage");
    }

    #[test]
    fn spans_pair_with_aligned_starts_and_link_identity() {
        let t = recorded_trace();
        let (spans, instants, unpaired) = paired_spans(&t);
        assert_eq!((spans.len(), instants.len(), unpaired), (2, 0, 0));
        let send = spans.iter().find(|s| s.op == Op::Send).unwrap();
        assert_eq!(send.link, Some((3, 0)));
        assert_eq!(send.stage, Stage::ReduceScatter);
        // Aligned: local t_nanos plus the -250 offset.
        let raw = t.events.iter().find(|e| e.op == Op::Send).unwrap().t_nanos;
        assert_eq!(send.start_nanos, raw as i128 - 250);
    }

    #[test]
    fn merge_draws_flow_arrows_and_is_deterministic() {
        // Two ranks, one matched edge: rank 0 sends (0→1, ordinal 0),
        // rank 1 receives it.
        let a = Recorder::new(0, 16);
        a.record_link(Kind::Start, Op::Send, 64, 1, 0);
        a.record_link(Kind::End, Op::Send, 64, 1, 0);
        let b = Recorder::new(1, 16);
        b.record_link(Kind::Start, Op::Recv, 0, 0, 0);
        b.record_link(Kind::End, Op::Recv, 64, 0, 0);
        let traces = [RankTrace::from_recorder(&a), RankTrace::from_recorder(&b)];
        let merged = merge_traces(&traces).unwrap();
        assert_eq!((merged.ranks, merged.spans, merged.flows), (2, 2, 1));
        assert!(merged.warnings.is_empty(), "{:?}", merged.warnings);
        assert!(merged.json.contains("\"ph\":\"s\""), "flow start");
        assert!(merged.json.contains("\"ph\":\"f\""), "flow finish");
        assert!(merged.json.contains("\"name\":\"rank 1\""));
        let again = merge_traces(&traces).unwrap();
        assert_eq!(merged.json, again.json, "same inputs, byte-identical output");
    }

    #[test]
    fn merge_warns_on_gaps_and_rejects_duplicate_ranks() {
        let tiny = Recorder::new(0, 1);
        for _ in 0..3 {
            tiny.record(Kind::Start, Op::Send, 1);
        }
        let t = RankTrace::from_recorder(&tiny);
        let merged = merge_traces(&[t.clone()]).unwrap();
        assert!(
            merged.warnings.iter().any(|w| w.contains("2 events dropped")),
            "{:?}",
            merged.warnings
        );
        assert!(merge_traces(&[t.clone(), t]).is_err(), "duplicate rank must fail");
        assert!(merge_traces(&[]).is_err(), "empty input must fail");
    }

    #[test]
    fn microsecond_formatting_is_exact() {
        assert_eq!(fmt_us(0), "0.000");
        assert_eq!(fmt_us(1), "0.001");
        assert_eq!(fmt_us(1_234_567), "1234.567");
        assert_eq!(fmt_us(-1_500), "-1.500");
    }
}
