//! Critical-path and straggler analysis over clock-aligned fabric traces
//! (DESIGN.md §15).
//!
//! A single rank's flight recorder cannot tell a slow wire from a slow
//! peer: both look like a long `Recv` span. Once traces are merged onto
//! one clock ([`super::trace`]), the send→recv edges disambiguate —
//! for each matched edge, the time a receiver spent blocked *before the
//! sender's data could possibly have arrived* is wait caused by the
//! sender, and we charge it to the sender's account:
//!
//! ```text
//! charged_wait = max(0, min(send_end, recv_end) − recv_start)
//! ```
//!
//! on aligned clocks. Summing charges per (sender rank, stage) and
//! comparing each rank against the per-stage median across ranks names
//! stragglers: a rank is reported when its charged wait exceeds twice
//! the median *and* clears an absolute floor
//! ([`STRAGGLER_FLOOR_NANOS`]) — the floor keeps scheduler jitter on a
//! clean run out of the report, which CI asserts stays empty.
//!
//! [`distill_fabric_profile`] is the fabric-wide counterpart of
//! [`super::distill_profile`]: instead of pooling `Σ bytes / Σ seconds`
//! (where one stalled sender drags the whole tier's effective rate
//! toward zero), it takes the **median of per-span rates** across every
//! rank. Recalibration fed by the median prices the fabric the
//! non-straggling majority actually delivers — the straggler shows up
//! in the [`FabricReport`], not as a corrupted bandwidth estimate.

use super::recorder::{Op, Stage};
use super::trace::{paired_spans, RankTrace, Span};
use crate::sim::MeasuredProfile;

/// Charged wait below this absolute excess is never reported as a
/// straggler (10 ms) — keeps scheduler jitter out of clean-run reports.
pub const STRAGGLER_FLOOR_NANOS: u64 = 10_000_000;

/// A rank whose sends made the rest of the fabric wait. Exported through
/// the metrics registry (flashlint R5 keeps every field in the export
/// honest).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StragglerReport {
    /// The slow rank (the *sender* the wait was charged to).
    pub rank: u16,
    /// The collective stage whose edges carried the excess wait.
    pub stage: Stage,
    /// Charged wait beyond the per-stage median across ranks, ms.
    pub excess_ms: f64,
    /// The per-stage median charged wait across ranks, ms.
    pub median_ms: f64,
}

impl StragglerReport {
    /// Human-readable one-liner for log output.
    pub fn line(&self) -> String {
        format!(
            "straggler: rank {} stage {} excess {:.3} ms (median {:.3} ms)",
            self.rank,
            self.stage.name(),
            self.excess_ms,
            self.median_ms
        )
    }
}

/// Where one rank's wall time went, on the fabric clock.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RankAttribution {
    pub rank: u16,
    /// QDQ compute: `Encode` + `Decode` + `DecodeSum` span time.
    pub compute_nanos: u64,
    /// Intra-group `Send` span time (rs/ag/single stages).
    pub intra_send_nanos: u64,
    /// Cross-group `Send` span time.
    pub cross_send_nanos: u64,
    /// Peer wait this rank *caused* (charged over send→recv edges).
    pub charged_wait_nanos: u64,
}

/// The fabric-wide critical-path breakdown.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FabricReport {
    /// Earliest span start to latest span end across all ranks, aligned.
    pub total_wall_nanos: u64,
    /// Per-rank attribution, sorted by rank.
    pub per_rank: Vec<RankAttribution>,
    /// Ranks whose charged wait cleared the threshold, worst first.
    pub stragglers: Vec<StragglerReport>,
}

impl FabricReport {
    pub fn is_clean(&self) -> bool {
        self.stragglers.is_empty()
    }

    /// Log-friendly breakdown, one line per rank plus one per straggler.
    pub fn summary_lines(&self) -> Vec<String> {
        let ms = |n: u64| n as f64 / 1e6;
        let mut lines = vec![format!("fabric wall time: {:.3} ms", ms(self.total_wall_nanos))];
        for a in &self.per_rank {
            lines.push(format!(
                "rank {}: compute {:.3} ms, intra send {:.3} ms, cross send {:.3} ms, \
                 charged wait {:.3} ms",
                a.rank,
                ms(a.compute_nanos),
                ms(a.intra_send_nanos),
                ms(a.cross_send_nanos),
                ms(a.charged_wait_nanos)
            ));
        }
        lines.extend(self.stragglers.iter().map(StragglerReport::line));
        lines
    }
}

/// Walk the aligned spans of every rank, attribute wall time, and name
/// stragglers. Infallible: empty input yields an empty report.
pub fn analyze(traces: &[RankTrace]) -> FabricReport {
    let mut spans: Vec<Span> = Vec::new();
    for t in traces {
        spans.extend(paired_spans(t).0);
    }
    if spans.is_empty() {
        return FabricReport::default();
    }

    let mut ranks: Vec<u16> = traces.iter().map(|t| t.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    let slot = |rank: u16| ranks.binary_search(&rank).ok();

    let mut per_rank: Vec<RankAttribution> = ranks
        .iter()
        .map(|&rank| RankAttribution { rank, ..Default::default() })
        .collect();
    for s in &spans {
        let Some(i) = slot(s.rank) else { continue };
        match s.op {
            Op::Encode | Op::Decode | Op::DecodeSum => per_rank[i].compute_nanos += s.dur_nanos,
            Op::Send if s.stage == Stage::CrossGroup => {
                per_rank[i].cross_send_nanos += s.dur_nanos
            }
            Op::Send => per_rank[i].intra_send_nanos += s.dur_nanos,
            _ => {}
        }
    }

    // Send→recv edges, keyed like the merge: (src, dst, link ordinal).
    let mut sends: std::collections::BTreeMap<(u16, u16, u64), &Span> =
        std::collections::BTreeMap::new();
    for s in &spans {
        if s.op == Op::Send {
            if let Some((dst, q)) = s.link {
                sends.insert((s.rank, dst, q), s);
            }
        }
    }
    // wait[stage][rank slot] = charged wait, nanos.
    let mut wait = vec![vec![0u64; ranks.len()]; 4];
    for r in &spans {
        if r.op != Op::Recv {
            continue;
        }
        let Some((src, q)) = r.link else { continue };
        let Some(send) = sends.get(&(src, r.rank, q)) else { continue };
        let Some(i) = slot(send.rank) else { continue };
        let charged = (send.end_nanos().min(r.end_nanos()) - r.start_nanos).max(0) as u64;
        wait[send.stage as usize][i] += charged;
        per_rank[i].charged_wait_nanos += charged;
    }

    let mut stragglers = Vec::new();
    for (stage_idx, waits) in wait.iter().enumerate() {
        let mut sorted = waits.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let median = (sorted[(n - 1) / 2] + sorted[n / 2]) / 2;
        for (i, &w) in waits.iter().enumerate() {
            let excess = w.saturating_sub(median);
            if w > 2 * median && excess > STRAGGLER_FLOOR_NANOS {
                let Some(stage) = Stage::from_u8(stage_idx as u8) else { continue };
                stragglers.push(StragglerReport {
                    rank: ranks[i],
                    stage,
                    excess_ms: excess as f64 / 1e6,
                    median_ms: median as f64 / 1e6,
                });
            }
        }
    }
    stragglers.sort_by(|a, b| b.excess_ms.total_cmp(&a.excess_ms));

    let start = spans.iter().map(|s| s.start_nanos).min().unwrap_or(0);
    let end = spans.iter().map(Span::end_nanos).max().unwrap_or(0);
    FabricReport { total_wall_nanos: (end - start).max(0) as u64, per_rank, stragglers }
}

/// Fabric-wide profile distillation: the **median of per-span rates**
/// across every rank, per tier. Robust to stragglers where the pooled
/// [`super::distill_profile`] is not — one sender stalled for 80 ms
/// drags a pooled `Σ bytes / Σ seconds` toward zero but barely moves
/// the median, so recalibration keeps pricing the fabric the healthy
/// majority delivers (pinned in `tests/trace.rs`).
pub fn distill_fabric_profile(traces: &[RankTrace]) -> MeasuredProfile {
    let (mut intra, mut inter, mut qdq) = (Vec::new(), Vec::new(), Vec::new());
    for t in traces {
        for s in paired_spans(t).0 {
            let rate = |units: u64| {
                (units > 0 && s.dur_nanos > 0)
                    .then(|| units as f64 / (s.dur_nanos as f64 * 1e-9))
            };
            match s.op {
                Op::Send => {
                    let tier =
                        if s.stage == Stage::CrossGroup { &mut inter } else { &mut intra };
                    tier.extend(rate(s.end_bytes));
                }
                Op::Encode | Op::Decode | Op::DecodeSum => qdq.extend(rate(s.start_bytes)),
                _ => {}
            }
        }
    }
    MeasuredProfile {
        intra_bw: median(&mut intra),
        inter_bw: median(&mut inter),
        qdq_pass_rate: median(&mut qdq),
    }
}

fn median(rates: &mut [f64]) -> Option<f64> {
    if rates.is_empty() {
        return None;
    }
    rates.sort_by(f64::total_cmp);
    let n = rates.len();
    Some((rates[(n - 1) / 2] + rates[n / 2]) / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::distill_profile;
    use crate::telemetry::recorder::{AlgoTag, Event, Kind};
    use crate::telemetry::trace::TraceEvent;

    fn ev(
        rank: u16,
        seq: u64,
        t_nanos: u64,
        kind: Kind,
        op: Op,
        stage: Stage,
        bytes: u64,
        link: Option<(u16, u64)>,
    ) -> TraceEvent {
        TraceEvent {
            seq,
            t_nanos,
            kind,
            op,
            stage,
            algo: AlgoTag::Hier,
            rank,
            codec: "INT4".to_string(),
            plan_fp: 0xabc,
            bytes,
            chunk: 0,
            link,
        }
    }

    fn trace(rank: u16, offset: i64, events: Vec<TraceEvent>) -> RankTrace {
        RankTrace {
            rank,
            capacity: 4096,
            recorded: events.len() as u64,
            dropped_events: 0,
            clock_offset_nanos: offset,
            clock_rtt_nanos: 0,
            clock_probes: 0,
            events,
        }
    }

    const MS: u64 = 1_000_000;

    /// 4-rank ring at the rs stage: rank 3's send takes 100 ms, everyone
    /// else's 1 ms; each rank receives from its predecessor.
    fn ring_with_straggler() -> Vec<RankTrace> {
        let n = 4u16;
        let slow = 3u16;
        (0..n)
            .map(|r| {
                let dst = (r + 1) % n;
                let src = (r + n - 1) % n;
                let send_ms = if r == slow { 100 } else { 1 };
                let wait_ms = if src == slow { 100 } else { 1 };
                trace(
                    r,
                    0,
                    vec![
                        ev(r, 0, 0, Kind::Start, Op::Send, Stage::ReduceScatter, 4096,
                            Some((dst, 0))),
                        ev(r, 1, send_ms * MS, Kind::End, Op::Send, Stage::ReduceScatter,
                            4096, Some((dst, 0))),
                        ev(r, 2, 0, Kind::Start, Op::Recv, Stage::ReduceScatter, 0,
                            Some((src, 0))),
                        ev(r, 3, (wait_ms + 1) * MS, Kind::End, Op::Recv,
                            Stage::ReduceScatter, 4096, Some((src, 0))),
                    ],
                )
            })
            .collect()
    }

    #[test]
    fn the_delayed_sender_is_named_with_the_right_stage() {
        let report = analyze(&ring_with_straggler());
        assert_eq!(report.stragglers.len(), 1, "{:?}", report.stragglers);
        let s = report.stragglers[0];
        assert_eq!((s.rank, s.stage), (3, Stage::ReduceScatter));
        assert!(s.excess_ms > 90.0, "{s:?}");
        assert!(s.median_ms < 2.0, "{s:?}");
        assert!(s.line().contains("rank 3 stage rs"), "{}", s.line());
        // The wait was charged to the slow *sender*, not its receiver.
        let slow = &report.per_rank[3];
        assert!(slow.charged_wait_nanos >= 99 * MS, "{slow:?}");
        assert!(report.per_rank[1].charged_wait_nanos <= 2 * MS);
        assert!(report.total_wall_nanos >= 100 * MS);
    }

    #[test]
    fn a_clean_fabric_reports_no_stragglers() {
        let mut traces = ring_with_straggler();
        // Make rank 3 as fast as everyone else.
        for e in &mut traces[3].events {
            if e.op == Op::Send && e.kind == Kind::End {
                e.t_nanos = MS;
            }
        }
        for e in &mut traces[0].events {
            if e.op == Op::Recv && e.kind == Kind::End {
                e.t_nanos = 2 * MS;
            }
        }
        let report = analyze(&traces);
        assert!(report.is_clean(), "{:?}", report.stragglers);
        // Sub-floor skew (the 2 ms recv tail) never triggers a report.
        assert!(report.summary_lines()[0].starts_with("fabric wall time:"));
    }

    #[test]
    fn clock_offsets_shift_spans_before_edges_are_walked() {
        // Rank 1's clock runs 5 ms behind; with the offset applied its
        // 1 ms recv wait stays tiny instead of reading as negative/huge.
        let mut traces = ring_with_straggler();
        for e in &mut traces[1].events {
            e.t_nanos += 5 * MS;
        }
        traces[1].clock_offset_nanos = -(5 * MS as i64);
        let shifted = analyze(&traces);
        let baseline = analyze(&ring_with_straggler());
        assert_eq!(
            shifted.per_rank[0].charged_wait_nanos,
            baseline.per_rank[0].charged_wait_nanos,
            "aligned clocks make the charge offset-invariant"
        );
    }

    #[test]
    fn fabric_median_shrugs_off_the_straggler_the_pooled_distill_eats() {
        let traces = ring_with_straggler();
        let fabric = distill_fabric_profile(&traces);
        // Pooled baseline over the same events (local view: every span
        // of every rank thrown into one Σbytes/Σseconds pool).
        let events: Vec<Event> = traces
            .iter()
            .flat_map(|t| {
                t.events.iter().map(|e| Event {
                    seq: e.seq,
                    t_nanos: e.t_nanos,
                    kind: e.kind,
                    op: e.op,
                    stage: e.stage,
                    algo: e.algo,
                    rank: e.rank,
                    codec_tag: 1,
                    plan_fp: e.plan_fp,
                    bytes: e.bytes,
                    chunk: e.chunk,
                    link: e.link,
                })
            })
            .collect();
        let pooled = distill_profile(&events);
        let (f, p) = (fabric.intra_bw.unwrap(), pooled.intra_bw.unwrap());
        // Median rate = the healthy 4096 B / 1 ms; pooled is dragged
        // toward the straggler's 100 ms span.
        assert!(
            f > 10.0 * p,
            "fabric median {f:.0} B/s should dwarf pooled {p:.0} B/s"
        );
    }

    #[test]
    fn empty_and_linkless_traces_are_harmless() {
        assert_eq!(analyze(&[]), FabricReport::default());
        let t = trace(
            0,
            0,
            vec![
                ev(0, 0, 0, Kind::Start, Op::Encode, Stage::Single, 256, None),
                ev(0, 1, 1000, Kind::End, Op::Encode, Stage::Single, 64, None),
            ],
        );
        let report = analyze(&[t.clone()]);
        assert!(report.is_clean());
        assert_eq!(report.per_rank[0].compute_nanos, 1000);
        let profile = distill_fabric_profile(&[t]);
        assert!(profile.intra_bw.is_none());
        assert!(profile.qdq_pass_rate.is_some());
    }
}
