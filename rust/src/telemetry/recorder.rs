//! The flight recorder: a lock-free, fixed-capacity ring buffer of typed
//! events, one per rank.
//!
//! Design (in the spirit of embedded flight recorders like hubris's
//! `ringbuf`): recording must be cheap enough to leave on in production,
//! so [`Recorder::record`] is a handful of relaxed atomic stores into a
//! pre-allocated slot — no locks, no allocation, no formatting. The ring
//! holds the *newest* [`Recorder::capacity`] events; older events are
//! overwritten in place. Each slot is a fixed set of `u64` words
//! (see [`Event`]), so the whole recorder is a flat
//! `capacity × 56 bytes` block — the default 4096-slot ring costs 224 KiB
//! per rank, bounded for the process lifetime. Overwritten (dropped)
//! events are counted, not hidden: [`Recorder::dropped_events`] feeds the
//! trace header and the metrics snapshot so a wrapped trace is visibly
//! lossy.
//!
//! Concurrency contract: `record` may be called from the rank's collective
//! thread while *other* threads hold clones of the `Arc<Recorder>`; the
//! per-slot sequence word is published with `Release` ordering so a reader
//! that observes it sees the rest of the slot. [`Recorder::events`] is
//! only guaranteed torn-free when called *at rest* (no collective in
//! flight), which is how every caller in this crate uses it — the
//! possibility of a mid-flight reader observing a half-overwritten slot is
//! accepted and such slots are skipped, never mis-decoded into a panic.
//!
//! The ambient-context words (`stage`, `chunk`, `codec`, `algo`, plan
//! fingerprint) are single-writer: only the rank's own collective thread
//! calls the `set_*` methods, so they are plain load/store, no RMW.

use std::sync::atomic::{AtomicI64, AtomicU64, AtomicUsize, Ordering};
use std::time::Instant;

/// Default ring capacity: 4096 events ≈ 192 KiB per rank.
pub const DEFAULT_CAPACITY: usize = 4096;

/// Whether an event opens or closes a span.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Kind {
    /// Span opened. For codec ops, `bytes` carries the element count
    /// (the cost model's "passes × elements" unit); for sends, the
    /// payload length.
    Start = 0,
    /// Span closed. `bytes` carries the bytes put on (or taken off) the
    /// wire, 0 where no payload is involved.
    End = 1,
}

impl Kind {
    pub fn name(self) -> &'static str {
        match self {
            Kind::Start => "start",
            Kind::End => "end",
        }
    }

    pub fn from_u8(v: u8) -> Option<Kind> {
        match v {
            0 => Some(Kind::Start),
            1 => Some(Kind::End),
            _ => None,
        }
    }

    /// Inverse of [`Kind::name`], for the trace JSON parser.
    pub fn from_name(v: &str) -> Option<Kind> {
        match v {
            "start" => Some(Kind::Start),
            "end" => Some(Kind::End),
            _ => None,
        }
    }
}

/// What the span timed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Op {
    /// Quantize + pack one payload into wire bytes.
    Encode = 0,
    /// Hand one payload to the transport (recorded by the fabric layer).
    Send = 1,
    /// Block until one payload arrives (recorded by the fabric layer).
    Recv = 2,
    /// Unpack + dequantize + accumulate into the partial sum.
    DecodeSum = 3,
    /// Unpack + dequantize (no accumulate).
    Decode = 4,
    /// One whole collective call, wrapped by the communicator front door.
    Collective = 5,
    /// A peer was declared lost by the session fabric (point event; the
    /// `bytes` field carries the lost rank).
    PeerLost = 6,
    /// The session epoch was bumped for a rejoin (point event).
    EpochBump = 7,
    /// A previously lost rank re-rendezvoused under the bumped epoch
    /// (point event; `bytes` carries the rejoined rank).
    Rejoin = 8,
}

impl Op {
    pub fn name(self) -> &'static str {
        match self {
            Op::Encode => "encode",
            Op::Send => "send",
            Op::Recv => "recv",
            Op::DecodeSum => "decode_sum",
            Op::Decode => "decode",
            Op::Collective => "collective",
            Op::PeerLost => "peer_lost",
            Op::EpochBump => "epoch_bump",
            Op::Rejoin => "rejoin",
        }
    }

    pub fn from_u8(v: u8) -> Option<Op> {
        match v {
            0 => Some(Op::Encode),
            1 => Some(Op::Send),
            2 => Some(Op::Recv),
            3 => Some(Op::DecodeSum),
            4 => Some(Op::Decode),
            5 => Some(Op::Collective),
            6 => Some(Op::PeerLost),
            7 => Some(Op::EpochBump),
            8 => Some(Op::Rejoin),
            _ => None,
        }
    }

    /// Inverse of [`Op::name`], for the trace JSON parser.
    pub fn from_name(v: &str) -> Option<Op> {
        match v {
            "encode" => Some(Op::Encode),
            "send" => Some(Op::Send),
            "recv" => Some(Op::Recv),
            "decode_sum" => Some(Op::DecodeSum),
            "decode" => Some(Op::Decode),
            "collective" => Some(Op::Collective),
            "peer_lost" => Some(Op::PeerLost),
            "epoch_bump" => Some(Op::EpochBump),
            "rejoin" => Some(Op::Rejoin),
            _ => None,
        }
    }
}

/// Which phase of the collective the event belongs to. Flat algorithms
/// (ring, all2all, broadcast) run entirely in [`Stage::Single`]; the
/// two-step and hierarchical algorithms tag their reduce-scatter /
/// cross-group / all-gather phases so per-link-tier bandwidth can be
/// distilled from the trace ([`crate::telemetry::distill_profile`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Stage {
    /// No stage structure (ring / all2all / broadcast / whole-collective).
    Single = 0,
    /// Reduce-scatter phase (intra-group for the hierarchical algorithms).
    ReduceScatter = 1,
    /// Cross-group column-ring reduce — the inter-tier link.
    CrossGroup = 2,
    /// All-gather phase (intra-group for the hierarchical algorithms).
    AllGather = 3,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Single => "single",
            Stage::ReduceScatter => "rs",
            Stage::CrossGroup => "cross",
            Stage::AllGather => "ag",
        }
    }

    pub fn from_u8(v: u8) -> Option<Stage> {
        match v {
            0 => Some(Stage::Single),
            1 => Some(Stage::ReduceScatter),
            2 => Some(Stage::CrossGroup),
            3 => Some(Stage::AllGather),
            _ => None,
        }
    }

    /// Inverse of [`Stage::name`], for the trace JSON parser.
    pub fn from_name(v: &str) -> Option<Stage> {
        match v {
            "single" => Some(Stage::Single),
            "rs" => Some(Stage::ReduceScatter),
            "cross" => Some(Stage::CrossGroup),
            "ag" => Some(Stage::AllGather),
            _ => None,
        }
    }
}

/// Which collective algorithm the events were recorded under. Mirrors
/// `comm::Algo` (plus `None` for traffic outside a planned collective)
/// without depending on it, so the telemetry layer stays reusable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum AlgoTag {
    None = 0,
    Ring = 1,
    TwoStep = 2,
    Hier = 3,
    HierPipelined = 4,
}

impl AlgoTag {
    pub fn name(self) -> &'static str {
        match self {
            AlgoTag::None => "none",
            AlgoTag::Ring => "ring",
            AlgoTag::TwoStep => "twostep",
            AlgoTag::Hier => "hier",
            AlgoTag::HierPipelined => "hier_pipelined",
        }
    }

    pub fn from_u8(v: u8) -> Option<AlgoTag> {
        match v {
            0 => Some(AlgoTag::None),
            1 => Some(AlgoTag::Ring),
            2 => Some(AlgoTag::TwoStep),
            3 => Some(AlgoTag::Hier),
            4 => Some(AlgoTag::HierPipelined),
            _ => None,
        }
    }

    /// Inverse of [`AlgoTag::name`], for the trace JSON parser.
    pub fn from_name(v: &str) -> Option<AlgoTag> {
        match v {
            "none" => Some(AlgoTag::None),
            "ring" => Some(AlgoTag::Ring),
            "twostep" => Some(AlgoTag::TwoStep),
            "hier" => Some(AlgoTag::Hier),
            "hier_pipelined" => Some(AlgoTag::HierPipelined),
            _ => None,
        }
    }
}

/// One decoded recorder event. The in-ring representation is seven `u64`
/// words per slot; this is the materialized view [`Recorder::events`]
/// returns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Monotone per-recorder sequence number (0-based, never reused).
    pub seq: u64,
    /// Nanoseconds since the recorder was created (or last cleared).
    pub t_nanos: u64,
    pub kind: Kind,
    pub op: Op,
    pub stage: Stage,
    pub algo: AlgoTag,
    /// Recording rank.
    pub rank: u16,
    /// Packed codec identity — see [`crate::telemetry::codec_tag`].
    pub codec_tag: u16,
    /// Fingerprint of the `CommPlan` in effect (0 outside a planned call).
    pub plan_fp: u64,
    /// Start: element count for codec ops / payload length for sends.
    /// End: bytes on the wire (0 where no payload is involved).
    pub bytes: u64,
    /// Pipeline chunk index (0 for unchunked collectives).
    pub chunk: u32,
    /// Link identity for fabric `Send`/`Recv` events: `(peer rank,
    /// per-direction message ordinal)`. The ordinal mirrors the per-link
    /// FIFO frame order every transport guarantees, so a send's
    /// `(self → peer, n)` matches the peer's recv `(self → peer, n)` —
    /// the edge the trace merge draws flow arrows along. `None` for
    /// every event recorded outside the fabric send/recv path.
    pub link: Option<(u16, u64)>,
}

impl Event {
    /// One JSON object for the trace export. Hand-rolled (no serde in the
    /// dependency set); `plan_fp` travels as a hex string so 64-bit values
    /// survive JSON consumers that parse numbers as doubles.
    pub fn to_json(&self) -> String {
        let link = match self.link {
            Some((peer, seq)) => format!(",\"peer\":{peer},\"link_seq\":{seq}"),
            None => String::new(),
        };
        format!(
            "{{\"seq\":{},\"t_nanos\":{},\"kind\":\"{}\",\"op\":\"{}\",\"stage\":\"{}\",\
             \"algo\":\"{}\",\"rank\":{},\"codec\":\"{}\",\"plan_fp\":\"{:#018x}\",\
             \"bytes\":{},\"chunk\":{}{}}}",
            self.seq,
            self.t_nanos,
            self.kind.name(),
            self.op.name(),
            self.stage.name(),
            self.algo.name(),
            self.rank,
            super::codec_tag_name(self.codec_tag),
            self.plan_fp,
            self.bytes,
            self.chunk,
            link
        )
    }
}

/// One ring slot: seven atomic words. `seq1` stores `seq + 1` and is
/// written last with `Release`; 0 means the slot was never written.
#[derive(Default)]
struct Slot {
    seq1: AtomicU64,
    t_nanos: AtomicU64,
    /// kind | op<<8 | stage<<16 | algo<<24 | rank<<32 | codec_tag<<48.
    meta: AtomicU64,
    plan_fp: AtomicU64,
    bytes: AtomicU64,
    chunk: AtomicU64,
    /// `LINK_VALID | peer | ordinal<<16`, or 0 for non-fabric events.
    link: AtomicU64,
}

/// High bit of the slot `link` word: distinguishes "link `(peer 0, seq 0)`"
/// from "no link identity recorded".
const LINK_VALID: u64 = 1 << 63;

/// Per-rank flight recorder. See the module docs for the concurrency
/// contract.
pub struct Recorder {
    rank: u16,
    epoch: Instant,
    head: AtomicUsize,
    /// Ambient context: stage | algo<<8 | codec_tag<<16 | chunk<<32.
    ctx: AtomicU64,
    plan_fp: AtomicU64,
    /// Estimated offset of this recorder's clock to the fabric reference
    /// clock (rank 0's recorder), in nanos: `t_ref ≈ t_local + offset`.
    /// Installed by the session clock sync; 0 until then (and forever on
    /// rank 0, the reference).
    clock_offset_nanos: AtomicI64,
    /// Min round-trip of the probes behind the offset estimate — the
    /// alignment error bound is `rtt / 2`.
    clock_rtt_nanos: AtomicU64,
    /// Probe exchanges behind the estimate (0 = never synced).
    clock_probes: AtomicU64,
    slots: Box<[Slot]>,
}

impl Recorder {
    /// A recorder for `rank` holding the newest `capacity` events
    /// (clamped to at least 1). The timebase starts now; ranks that share
    /// a process should prefer [`Recorder::with_origin`] so their
    /// timelines need no clock sync at all.
    pub fn new(rank: usize, capacity: usize) -> Recorder {
        Recorder::with_origin(rank, capacity, Instant::now())
    }

    /// A recorder whose `t_nanos` timebase starts at `origin`. In-process
    /// rank groups pass one shared origin to every rank, making their
    /// timelines directly comparable (offset 0 by construction).
    pub fn with_origin(rank: usize, capacity: usize, origin: Instant) -> Recorder {
        let capacity = capacity.max(1);
        Recorder {
            rank: rank as u16,
            epoch: origin,
            head: AtomicUsize::new(0),
            ctx: AtomicU64::new(0),
            plan_fp: AtomicU64::new(0),
            clock_offset_nanos: AtomicI64::new(0),
            clock_rtt_nanos: AtomicU64::new(0),
            clock_probes: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Slot::default()).collect(),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank as usize
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Total events ever recorded (≥ the number still in the ring).
    pub fn total_recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed) as u64
    }

    /// Events lost to newest-wins wraparound: everything recorded beyond
    /// what the ring can hold. 0 means the trace is complete.
    pub fn dropped_events(&self) -> u64 {
        self.total_recorded().saturating_sub(self.slots.len() as u64)
    }

    /// Nanoseconds on this recorder's clock right now — the timestamp a
    /// `record` call at this instant would carry. The clock-sync probes
    /// read it on both sides of the exchange so the estimated offsets
    /// relate *recorder* timelines, not arbitrary process clocks.
    pub fn now_nanos(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Install the clock-sync result (single-writer, like the `set_*`
    /// context methods): offset to the reference clock, min probe RTT
    /// (error bound `rtt / 2`), and how many probes backed the estimate.
    pub fn set_clock(&self, offset_nanos: i64, rtt_nanos: u64, probes: u64) {
        self.clock_offset_nanos.store(offset_nanos, Ordering::Relaxed);
        self.clock_rtt_nanos.store(rtt_nanos, Ordering::Relaxed);
        self.clock_probes.store(probes, Ordering::Relaxed);
    }

    /// The installed clock-sync state: `(offset_nanos, rtt_nanos, probes)`.
    /// All zero until [`Recorder::set_clock`] runs.
    pub fn clock(&self) -> (i64, u64, u64) {
        (
            self.clock_offset_nanos.load(Ordering::Relaxed),
            self.clock_rtt_nanos.load(Ordering::Relaxed),
            self.clock_probes.load(Ordering::Relaxed),
        )
    }

    /// Set the stage + codec ambient context (single-writer: the rank's
    /// collective thread). The chunk and algo context are preserved.
    pub fn set_stage(&self, stage: Stage, codec_tag: u16) {
        let prev = self.ctx.load(Ordering::Relaxed);
        let next = (prev & !0xffff_00ffu64)
            | stage as u64
            | (codec_tag as u64) << 16;
        self.ctx.store(next, Ordering::Relaxed);
    }

    /// Set the pipeline chunk ambient context (single-writer).
    pub fn set_chunk(&self, chunk: u32) {
        let prev = self.ctx.load(Ordering::Relaxed);
        self.ctx.store((prev & 0xffff_ffff) | (chunk as u64) << 32, Ordering::Relaxed);
    }

    /// Set the plan fingerprint + algorithm ambient context
    /// (single-writer). Stage and chunk context are reset to
    /// `Single`/0 — a new collective starts from a clean frame.
    pub fn set_plan(&self, plan_fp: u64, algo: AlgoTag) {
        self.plan_fp.store(plan_fp, Ordering::Relaxed);
        self.ctx.store((algo as u64) << 8, Ordering::Relaxed);
    }

    /// Record one event. Lock-free, allocation-free: one `fetch_add` to
    /// claim a slot plus seven stores. Callers gate on an
    /// `Option<&Recorder>` (see the `record!` macro), so the disabled
    /// path is a single untaken branch.
    pub fn record(&self, kind: Kind, op: Op, bytes: u64) {
        self.record_raw(kind, op, bytes, 0);
    }

    /// [`Recorder::record`] with a link identity attached: `peer` is the
    /// other end of the transfer, `link_seq` the per-direction message
    /// ordinal the fabric maintains. Only the fabric send/recv path calls
    /// this — the merge pass matches a send's `(dst, n)` against the
    /// peer's recv `(src, n)` to draw flow arrows and charge waits.
    pub fn record_link(&self, kind: Kind, op: Op, bytes: u64, peer: u16, link_seq: u64) {
        // 47 bits of ordinal; the valid bit must survive any count.
        let ordinal = link_seq & ((1 << 47) - 1);
        self.record_raw(kind, op, bytes, LINK_VALID | peer as u64 | (ordinal << 16));
    }

    fn record_raw(&self, kind: Kind, op: Op, bytes: u64, link: u64) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed) as u64;
        let slot = &self.slots[(seq as usize) % self.slots.len()];
        let ctx = self.ctx.load(Ordering::Relaxed);
        let meta = kind as u64
            | (op as u64) << 8
            | (ctx & 0xff) << 16                // stage
            | ((ctx >> 8) & 0xff) << 24         // algo
            | (self.rank as u64) << 32
            | ((ctx >> 16) & 0xffff) << 48; // codec_tag
        slot.t_nanos.store(self.epoch.elapsed().as_nanos() as u64, Ordering::Relaxed);
        slot.meta.store(meta, Ordering::Relaxed);
        slot.plan_fp.store(self.plan_fp.load(Ordering::Relaxed), Ordering::Relaxed);
        slot.bytes.store(bytes, Ordering::Relaxed);
        slot.chunk.store(ctx >> 32, Ordering::Relaxed);
        slot.link.store(link, Ordering::Relaxed);
        slot.seq1.store(seq + 1, Ordering::Release);
    }

    /// Materialize the ring's current contents, oldest surviving event
    /// first. Torn-free only at rest (see module docs); slots that decode
    /// to an unknown kind/op/stage (possible only under a mid-flight torn
    /// read) are skipped.
    pub fn events(&self) -> Vec<Event> {
        let mut out: Vec<Event> = Vec::with_capacity(self.slots.len());
        for slot in self.slots.iter() {
            let seq1 = slot.seq1.load(Ordering::Acquire);
            if seq1 == 0 {
                continue;
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let (kind, op, stage, algo) = match (
                Kind::from_u8(meta as u8),
                Op::from_u8((meta >> 8) as u8),
                Stage::from_u8((meta >> 16) as u8),
                AlgoTag::from_u8((meta >> 24) as u8),
            ) {
                (Some(k), Some(o), Some(s), Some(a)) => (k, o, s, a),
                _ => continue,
            };
            let link_word = slot.link.load(Ordering::Relaxed);
            let link = if link_word & LINK_VALID != 0 {
                Some((link_word as u16, (link_word >> 16) & ((1 << 47) - 1)))
            } else {
                None
            };
            out.push(Event {
                seq: seq1 - 1,
                t_nanos: slot.t_nanos.load(Ordering::Relaxed),
                kind,
                op,
                stage,
                algo,
                rank: (meta >> 32) as u16,
                codec_tag: (meta >> 48) as u16,
                plan_fp: slot.plan_fp.load(Ordering::Relaxed),
                bytes: slot.bytes.load(Ordering::Relaxed),
                chunk: slot.chunk.load(Ordering::Relaxed) as u32,
                link,
            });
        }
        out.sort_by_key(|e| e.seq);
        out
    }

    /// Drop every recorded event and restart the clock and sequence
    /// numbers. Only meaningful at rest.
    pub fn clear(&self) {
        for slot in self.slots.iter() {
            slot.seq1.store(0, Ordering::Relaxed);
        }
        self.head.store(0, Ordering::Relaxed);
        self.ctx.store(0, Ordering::Relaxed);
        self.plan_fp.store(0, Ordering::Relaxed);
    }
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Recorder")
            .field("rank", &self.rank)
            .field("capacity", &self.slots.len())
            .field("total_recorded", &self.total_recorded())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_come_back_in_order_with_context() {
        let r = Recorder::new(3, 16);
        r.set_plan(0xdead_beef, AlgoTag::Hier);
        r.set_stage(Stage::ReduceScatter, 0x1004);
        r.set_chunk(2);
        r.record(Kind::Start, Op::Encode, 128);
        r.record(Kind::End, Op::Encode, 99);
        let ev = r.events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].seq, 0);
        assert_eq!(ev[0].kind, Kind::Start);
        assert_eq!(ev[0].op, Op::Encode);
        assert_eq!(ev[0].stage, Stage::ReduceScatter);
        assert_eq!(ev[0].algo, AlgoTag::Hier);
        assert_eq!(ev[0].rank, 3);
        assert_eq!(ev[0].codec_tag, 0x1004);
        assert_eq!(ev[0].plan_fp, 0xdead_beef);
        assert_eq!(ev[0].bytes, 128);
        assert_eq!(ev[0].chunk, 2);
        assert_eq!(ev[1].kind, Kind::End);
        assert!(ev[0].t_nanos <= ev[1].t_nanos);
        assert_eq!(r.total_recorded(), 2);
    }

    #[test]
    fn wraparound_keeps_the_newest_events() {
        let r = Recorder::new(0, 8);
        for i in 0..20u64 {
            r.record(Kind::Start, Op::Send, i);
        }
        let ev = r.events();
        assert_eq!(ev.len(), 8, "ring holds exactly its capacity");
        let seqs: Vec<u64> = ev.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (12..20).collect::<Vec<u64>>(), "newest 8 survive");
        assert_eq!(ev[0].bytes, 12);
        assert_eq!(ev[7].bytes, 19);
        assert_eq!(r.total_recorded(), 20);
    }

    #[test]
    fn set_plan_resets_stage_and_chunk_context() {
        let r = Recorder::new(1, 4);
        r.set_stage(Stage::AllGather, 7);
        r.set_chunk(5);
        r.set_plan(1, AlgoTag::Ring);
        r.record(Kind::Start, Op::Collective, 0);
        let e = r.events()[0];
        assert_eq!(e.stage, Stage::Single);
        assert_eq!(e.chunk, 0);
        assert_eq!(e.codec_tag, 0);
        assert_eq!(e.algo, AlgoTag::Ring);
        assert_eq!(e.plan_fp, 1);
    }

    #[test]
    fn clear_restarts_the_ring() {
        let r = Recorder::new(0, 4);
        r.record(Kind::Start, Op::Send, 1);
        r.record(Kind::End, Op::Send, 1);
        r.clear();
        assert!(r.events().is_empty());
        assert_eq!(r.total_recorded(), 0);
        r.record(Kind::Start, Op::Recv, 2);
        assert_eq!(r.events()[0].seq, 0, "sequence numbers restart");
    }

    #[test]
    fn capacity_is_clamped_to_at_least_one() {
        let r = Recorder::new(0, 0);
        assert_eq!(r.capacity(), 1);
        r.record(Kind::Start, Op::Send, 1);
        r.record(Kind::End, Op::Send, 2);
        assert_eq!(r.events().len(), 1);
        assert_eq!(r.events()[0].bytes, 2, "newest event wins");
    }

    #[test]
    fn link_identity_survives_the_ring_and_plain_events_have_none() {
        let r = Recorder::new(1, 8);
        r.record_link(Kind::Start, Op::Send, 64, 3, 0);
        r.record_link(Kind::End, Op::Send, 64, 3, 0);
        r.record(Kind::Start, Op::Encode, 10);
        let ev = r.events();
        assert_eq!(ev[0].link, Some((3, 0)), "ordinal 0 is a valid link");
        assert_eq!(ev[1].link, Some((3, 0)));
        assert_eq!(ev[2].link, None, "non-fabric events carry no link");
        let row = ev[0].to_json();
        assert!(row.contains("\"peer\":3"), "{row}");
        assert!(row.contains("\"link_seq\":0"), "{row}");
        assert!(!ev[2].to_json().contains("peer"), "no link keys on plain events");
    }

    #[test]
    fn link_slots_are_reset_on_reuse() {
        // A wrapped slot that once held a link must not leak it into the
        // plain event that overwrites it.
        let r = Recorder::new(0, 1);
        r.record_link(Kind::Start, Op::Send, 1, 2, 9);
        r.record(Kind::Start, Op::Encode, 1);
        assert_eq!(r.events()[0].link, None);
    }

    #[test]
    fn dropped_events_counts_wraparound_losses() {
        let r = Recorder::new(0, 8);
        for i in 0..6u64 {
            r.record(Kind::Start, Op::Send, i);
        }
        assert_eq!(r.dropped_events(), 0, "under capacity nothing dropped");
        for i in 0..14u64 {
            r.record(Kind::Start, Op::Send, i);
        }
        assert_eq!(r.total_recorded(), 20);
        assert_eq!(r.dropped_events(), 12, "everything beyond capacity is lost");
    }

    #[test]
    fn shared_origin_recorders_share_a_timebase_and_clock_state_installs() {
        let origin = Instant::now();
        let a = Recorder::with_origin(0, 4, origin);
        let b = Recorder::with_origin(1, 4, origin);
        let (t_a, t_b) = (a.now_nanos(), b.now_nanos());
        assert!(t_b >= t_a, "same origin: later reads are later nanos");
        assert_eq!(a.clock(), (0, 0, 0), "unsynced clock state is all zero");
        b.set_clock(-1500, 3000, 8);
        assert_eq!(b.clock(), (-1500, 3000, 8));
    }

    #[test]
    fn json_row_has_the_schema_fields() {
        let r = Recorder::new(2, 4);
        r.set_plan(0x10, AlgoTag::TwoStep);
        r.record(Kind::End, Op::Recv, 64);
        let row = r.events()[0].to_json();
        for field in
            ["\"seq\":", "\"t_nanos\":", "\"kind\":\"end\"", "\"op\":\"recv\"",
             "\"stage\":\"single\"", "\"algo\":\"twostep\"", "\"rank\":2",
             "\"plan_fp\":\"0x0000000000000010\"", "\"bytes\":64", "\"chunk\":0"]
        {
            assert!(row.contains(field), "{row} missing {field}");
        }
    }
}
