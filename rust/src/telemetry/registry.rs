//! The metrics registry: one aggregation + export path for everything the
//! system measures.
//!
//! The registry is *offline*: nothing on the hot path touches it. Raw
//! measurements stay where they are cheap — recorder events
//! ([`super::Recorder`]), fabric byte counters
//! ([`crate::comm::fabric::ByteCounters`]), transport counters
//! ([`crate::transport::TransportStats`]), plan-cache hit/miss counters
//! ([`crate::plan::PlanCacheStats`]) — and are absorbed into a registry
//! only when a snapshot is wanted (CLI `flashcomm metrics`, `--trace-out`,
//! tests). Span events are paired Start→End per
//! (rank, algo, stage, op, codec) and folded into counters plus
//! log₂-bucketed latency histograms keyed per (algo, stage, op, codec).

use std::collections::{BTreeMap, HashMap};

use super::recorder::{AlgoTag, Event, Kind, Op, Stage};
use crate::comm::fabric::CountersSnapshot;
use crate::plan::PlanCacheStats;
use crate::session::SessionStats;
use crate::transport::TransportStats;

/// Number of log₂ latency buckets: bucket `i` holds spans with
/// `2^i <= nanos < 2^(i+1)` (bucket 0 also holds 0–1 ns; bucket 31 holds
/// everything ≥ 2³¹ ns ≈ 2.1 s).
pub const HIST_BUCKETS: usize = 32;

/// A log₂-bucketed latency histogram over span durations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histogram {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub total_nanos: u64,
    pub max_nanos: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; HIST_BUCKETS], count: 0, total_nanos: 0, max_nanos: 0 }
    }
}

impl Histogram {
    /// Bucket index for a duration: `floor(log2(nanos))` clamped to the
    /// bucket range (0 ns lands in bucket 0).
    pub fn bucket_of(nanos: u64) -> usize {
        if nanos == 0 {
            0
        } else {
            ((63 - nanos.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        }
    }

    pub fn observe(&mut self, nanos: u64) {
        self.buckets[Self::bucket_of(nanos)] += 1;
        self.count += 1;
        self.total_nanos += nanos;
        self.max_nanos = self.max_nanos.max(nanos);
    }

    /// Mean span duration in nanoseconds (0 when empty).
    pub fn mean_nanos(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.total_nanos / self.count
        }
    }
}

/// One aggregated series: every span sharing (algo, stage, op, codec).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Series {
    /// Completed Start→End pairs folded in.
    pub spans: u64,
    /// Sum of the End events' byte payloads (wire bytes for codec/send
    /// ops).
    pub bytes: u64,
    pub hist: Histogram,
}

/// A fully resolved series key, decoded for display/export.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SeriesKey {
    pub algo: AlgoTag,
    pub stage: Stage,
    pub op: Op,
    pub codec_tag: u16,
}

/// The offline aggregator. Build one, absorb whatever sources exist, then
/// [`MetricsRegistry::snapshot`].
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    series: BTreeMap<(u8, u8, u8, u16), Series>,
    /// Events that could not be paired (End with no Start, Start with no
    /// End) — nonzero when the ring wrapped mid-span.
    unpaired: u64,
    fabric: Option<CountersSnapshot>,
    transport: Option<TransportStats>,
    session: Option<SessionStats>,
    plan_cache: Option<PlanCacheStats>,
    last_plan: Option<(String, u64)>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Fold one rank's recorded events in. Events must be in recording
    /// order (as [`super::Recorder::events`] returns them); spans are
    /// paired per (rank, algo, stage, op, codec) so interleaved chunks and
    /// the enclosing `Collective` span pair correctly.
    pub fn absorb_events(&mut self, events: &[Event]) {
        // Open-span stack per pairing key: (rank, algo, stage, op, codec).
        let mut open: HashMap<(u16, u8, u8, u8, u16), Vec<u64>> = HashMap::new();
        for e in events {
            let key = (e.rank, e.algo as u8, e.stage as u8, e.op as u8, e.codec_tag);
            match e.kind {
                Kind::Start => open.entry(key).or_default().push(e.t_nanos),
                Kind::End => match open.get_mut(&key).and_then(|v| v.pop()) {
                    Some(t0) => {
                        let s = self
                            .series
                            .entry((e.algo as u8, e.stage as u8, e.op as u8, e.codec_tag))
                            .or_default();
                        s.spans += 1;
                        s.bytes += e.bytes;
                        s.hist.observe(e.t_nanos.saturating_sub(t0));
                    }
                    None => self.unpaired += 1,
                },
            }
        }
        self.unpaired += open.values().map(|v| v.len() as u64).sum::<u64>();
    }

    /// Attach (or accumulate) a fabric byte-counter snapshot.
    pub fn absorb_fabric(&mut self, s: CountersSnapshot) {
        self.fabric = Some(match self.fabric {
            Some(prev) => CountersSnapshot {
                total: prev.total + s.total,
                cross_numa: prev.cross_numa + s.cross_numa,
                messages: prev.messages + s.messages,
            },
            None => s,
        });
    }

    /// Attach (or accumulate) a transport counter snapshot.
    pub fn absorb_transport(&mut self, s: TransportStats) {
        self.transport = Some(match self.transport {
            Some(prev) => TransportStats {
                payload_bytes: prev.payload_bytes + s.payload_bytes,
                wire_bytes: prev.wire_bytes + s.wire_bytes,
                messages: prev.messages + s.messages,
                buffered_bytes: prev.buffered_bytes + s.buffered_bytes,
                peak_buffered_bytes: prev.peak_buffered_bytes.max(s.peak_buffered_bytes),
                nacks_sent: prev.nacks_sent + s.nacks_sent,
                nacks_received: prev.nacks_received + s.nacks_received,
                retransmitted_chunks: prev.retransmitted_chunks + s.retransmitted_chunks,
                duplicate_drops: prev.duplicate_drops + s.duplicate_drops,
                reorder_events: prev.reorder_events + s.reorder_events,
                corrupt_drops: prev.corrupt_drops + s.corrupt_drops,
                stale_epoch_drops: prev.stale_epoch_drops + s.stale_epoch_drops,
                redundancy_bytes: prev.redundancy_bytes + s.redundancy_bytes,
                paced_stalls: prev.paced_stalls + s.paced_stalls,
            },
            None => s,
        });
    }

    /// Attach (or accumulate) session-fabric counters. Epochs across
    /// endpoints of one job agree by construction (the rendezvous rejects
    /// conflicts), so accumulation keeps the max.
    pub fn absorb_session(&mut self, s: SessionStats) {
        self.session = Some(match self.session {
            Some(prev) => SessionStats {
                epoch: prev.epoch.max(s.epoch),
                heartbeats_sent: prev.heartbeats_sent + s.heartbeats_sent,
                heartbeats_received: prev.heartbeats_received + s.heartbeats_received,
                suspects: prev.suspects + s.suspects,
                losses: prev.losses + s.losses,
                epoch_bumps: prev.epoch_bumps + s.epoch_bumps,
            },
            None => s,
        });
    }

    /// Attach (or accumulate) plan-cache hit/miss/eviction counters.
    pub fn absorb_plan_cache(&mut self, s: PlanCacheStats) {
        self.plan_cache = Some(match self.plan_cache {
            Some(prev) => PlanCacheStats {
                hits: prev.hits + s.hits,
                misses: prev.misses + s.misses,
                evictions: prev.evictions + s.evictions,
            },
            None => s,
        });
    }

    /// Record the resolved plan of the most recent collective (display
    /// form + fingerprint).
    pub fn set_last_plan(&mut self, display: String, fingerprint: u64) {
        self.last_plan = Some((display, fingerprint));
    }

    /// Materialize everything absorbed so far.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            series: self
                .series
                .iter()
                .filter_map(|(&(algo, stage, op, codec_tag), &s)| {
                    Some((
                        SeriesKey {
                            algo: AlgoTag::from_u8(algo)?,
                            stage: Stage::from_u8(stage)?,
                            op: Op::from_u8(op)?,
                            codec_tag,
                        },
                        s,
                    ))
                })
                .collect(),
            unpaired: self.unpaired,
            fabric: self.fabric,
            transport: self.transport,
            session: self.session,
            plan_cache: self.plan_cache,
            last_plan: self.last_plan.clone(),
        }
    }
}

/// A point-in-time export of the registry: what `flashcomm metrics`
/// prints and tests assert on.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub series: Vec<(SeriesKey, Series)>,
    pub unpaired: u64,
    pub fabric: Option<CountersSnapshot>,
    pub transport: Option<TransportStats>,
    /// Session-fabric counters, when a live session ran (TCP with
    /// heartbeats, or a fault-injected mesh).
    pub session: Option<SessionStats>,
    pub plan_cache: Option<PlanCacheStats>,
    /// Display form + fingerprint of the last resolved `CommPlan`.
    pub last_plan: Option<(String, u64)>,
}

impl MetricsSnapshot {
    /// Hand-rolled JSON export (no serde in the dependency set).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"series\":[");
        for (i, (k, s)) in self.series.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let nonzero: Vec<String> = s
                .hist
                .buckets
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(b, &c)| format!("[{b},{c}]"))
                .collect();
            out.push_str(&format!(
                "{{\"algo\":\"{}\",\"stage\":\"{}\",\"op\":\"{}\",\"codec\":\"{}\",\
                 \"spans\":{},\"bytes\":{},\"mean_nanos\":{},\"max_nanos\":{},\
                 \"hist_log2\":[{}]}}",
                k.algo.name(),
                k.stage.name(),
                k.op.name(),
                super::codec_tag_name(k.codec_tag),
                s.spans,
                s.bytes,
                s.hist.mean_nanos(),
                s.hist.max_nanos,
                nonzero.join(",")
            ));
        }
        out.push_str(&format!("],\"unpaired\":{}", self.unpaired));
        if let Some(f) = self.fabric {
            out.push_str(&format!(
                ",\"fabric\":{{\"total_bytes\":{},\"cross_numa_bytes\":{},\"messages\":{}}}",
                f.total, f.cross_numa, f.messages
            ));
        }
        if let Some(t) = self.transport {
            out.push_str(&format!(
                ",\"transport\":{{\"payload_bytes\":{},\"wire_bytes\":{},\"messages\":{},\
                 \"buffered_bytes\":{},\"peak_buffered_bytes\":{},\"nacks_sent\":{},\
                 \"nacks_received\":{},\"retransmitted_chunks\":{},\"duplicate_drops\":{},\
                 \"reorder_events\":{},\"corrupt_drops\":{},\"stale_epoch_drops\":{},\
                 \"redundancy_bytes\":{},\"paced_stalls\":{}}}",
                t.payload_bytes,
                t.wire_bytes,
                t.messages,
                t.buffered_bytes,
                t.peak_buffered_bytes,
                t.nacks_sent,
                t.nacks_received,
                t.retransmitted_chunks,
                t.duplicate_drops,
                t.reorder_events,
                t.corrupt_drops,
                t.stale_epoch_drops,
                t.redundancy_bytes,
                t.paced_stalls
            ));
        }
        if let Some(s) = self.session {
            out.push_str(&format!(
                ",\"session\":{{\"epoch\":{},\"heartbeats_sent\":{},\"heartbeats_received\":{},\
                 \"suspects\":{},\"losses\":{},\"epoch_bumps\":{}}}",
                s.epoch,
                s.heartbeats_sent,
                s.heartbeats_received,
                s.suspects,
                s.losses,
                s.epoch_bumps
            ));
        }
        if let Some(p) = self.plan_cache {
            out.push_str(&format!(
                ",\"plan_cache\":{{\"hits\":{},\"misses\":{},\"evictions\":{}}}",
                p.hits, p.misses, p.evictions
            ));
        }
        if let Some((plan, fp)) = &self.last_plan {
            out.push_str(&format!(",\"last_plan\":{{\"plan\":\"{plan}\",\"fp\":\"{fp:#018x}\"}}"));
        }
        out.push('}');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        seq: u64,
        t: u64,
        kind: Kind,
        op: Op,
        stage: Stage,
        bytes: u64,
    ) -> Event {
        Event {
            seq,
            t_nanos: t,
            kind,
            op,
            stage,
            algo: AlgoTag::Hier,
            rank: 0,
            codec_tag: 0x1004,
            plan_fp: 7,
            bytes,
            chunk: 0,
        }
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(1024), 10);
        assert_eq!(Histogram::bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn spans_pair_and_aggregate() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            ev(0, 100, Kind::Start, Op::Encode, Stage::ReduceScatter, 64),
            ev(1, 300, Kind::End, Op::Encode, Stage::ReduceScatter, 40),
            ev(2, 400, Kind::Start, Op::Encode, Stage::ReduceScatter, 64),
            ev(3, 500, Kind::End, Op::Encode, Stage::ReduceScatter, 40),
        ]);
        let snap = reg.snapshot();
        assert_eq!(snap.series.len(), 1);
        let (k, s) = snap.series[0];
        assert_eq!(k.op, Op::Encode);
        assert_eq!(k.stage, Stage::ReduceScatter);
        assert_eq!(s.spans, 2);
        assert_eq!(s.bytes, 80, "bytes come from End events");
        assert_eq!(s.hist.count, 2);
        assert_eq!(s.hist.total_nanos, 300);
        assert_eq!(s.hist.max_nanos, 200);
        assert_eq!(snap.unpaired, 0);
    }

    #[test]
    fn wraparound_orphans_are_counted_not_mispaired() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            // End whose Start was overwritten by ring wraparound…
            ev(10, 900, Kind::End, Op::Send, Stage::CrossGroup, 8),
            // …and a Start whose End never came.
            ev(11, 950, Kind::Start, Op::Recv, Stage::CrossGroup, 0),
        ]);
        let snap = reg.snapshot();
        assert!(snap.series.is_empty());
        assert_eq!(snap.unpaired, 2);
    }

    #[test]
    fn collective_span_pairs_around_nested_ops() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            ev(0, 0, Kind::Start, Op::Collective, Stage::Single, 0),
            ev(1, 10, Kind::Start, Op::Send, Stage::ReduceScatter, 8),
            ev(2, 20, Kind::End, Op::Send, Stage::ReduceScatter, 8),
            ev(3, 50, Kind::End, Op::Collective, Stage::Single, 0),
        ]);
        let snap = reg.snapshot();
        assert_eq!(snap.series.len(), 2);
        assert_eq!(snap.unpaired, 0);
    }

    #[test]
    fn session_counters_accumulate_and_export() {
        let mut reg = MetricsRegistry::new();
        assert!(reg.snapshot().session.is_none(), "no session absorbed, no block");
        reg.absorb_session(SessionStats {
            epoch: 1,
            heartbeats_sent: 10,
            heartbeats_received: 9,
            suspects: 1,
            losses: 1,
            epoch_bumps: 1,
        });
        reg.absorb_session(SessionStats {
            epoch: 1,
            heartbeats_sent: 5,
            heartbeats_received: 6,
            suspects: 0,
            losses: 0,
            epoch_bumps: 0,
        });
        let snap = reg.snapshot();
        let s = snap.session.unwrap();
        assert_eq!((s.epoch, s.heartbeats_sent, s.heartbeats_received), (1, 15, 15));
        assert_eq!((s.suspects, s.losses, s.epoch_bumps), (1, 1, 1));
        let json = snap.to_json();
        for field in [
            "\"session\":{",
            "\"epoch\":1",
            "\"heartbeats_sent\":15",
            "\"heartbeats_received\":15",
            "\"suspects\":1",
            "\"losses\":1",
            "\"epoch_bumps\":1",
        ] {
            assert!(json.contains(field), "{json} missing {field}");
        }
    }

    #[test]
    fn json_export_carries_every_absorbed_source() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            ev(0, 0, Kind::Start, Op::Send, Stage::Single, 4),
            ev(1, 5, Kind::End, Op::Send, Stage::Single, 4),
        ]);
        reg.absorb_fabric(CountersSnapshot { total: 100, cross_numa: 40, messages: 3 });
        reg.absorb_fabric(CountersSnapshot { total: 1, cross_numa: 1, messages: 1 });
        reg.absorb_plan_cache(PlanCacheStats { hits: 5, misses: 2, evictions: 0 });
        reg.set_last_plan("hierpp".into(), 0xab);
        let json = reg.snapshot().to_json();
        for field in [
            "\"series\":[",
            "\"op\":\"send\"",
            "\"spans\":1",
            "\"total_bytes\":101",
            "\"messages\":4",
            "\"hits\":5",
            "\"last_plan\"",
            "\"fp\":\"0x00000000000000ab\"",
        ] {
            assert!(json.contains(field), "{json} missing {field}");
        }
    }

    #[test]
    fn transport_block_accumulates_and_exports_robustness_counters() {
        let mut reg = MetricsRegistry::new();
        let mut a = TransportStats {
            payload_bytes: 1000,
            wire_bytes: 1100,
            messages: 2,
            nacks_sent: 3,
            nacks_received: 1,
            retransmitted_chunks: 4,
            duplicate_drops: 5,
            reorder_events: 6,
            corrupt_drops: 7,
            stale_epoch_drops: 8,
            redundancy_bytes: 90,
            paced_stalls: 2,
            ..TransportStats::default()
        };
        reg.absorb_transport(a);
        a.peak_buffered_bytes = 512;
        reg.absorb_transport(a);
        let t = reg.snapshot().transport.unwrap();
        assert_eq!(t.payload_bytes, 2000, "sums across absorbs");
        assert_eq!(t.nacks_sent, 6);
        assert_eq!(t.retransmitted_chunks, 8);
        assert_eq!(t.peak_buffered_bytes, 512, "peak is a max, not a sum");
        let json = reg.snapshot().to_json();
        for field in [
            "\"transport\":{",
            "\"nacks_sent\":6",
            "\"nacks_received\":2",
            "\"retransmitted_chunks\":8",
            "\"duplicate_drops\":10",
            "\"reorder_events\":12",
            "\"corrupt_drops\":14",
            "\"stale_epoch_drops\":16",
            "\"redundancy_bytes\":180",
            "\"paced_stalls\":4",
        ] {
            assert!(json.contains(field), "{json} missing {field}");
        }
    }
}
