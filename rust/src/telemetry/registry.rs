//! The metrics registry: one aggregation + export path for everything the
//! system measures.
//!
//! The registry is *offline*: nothing on the hot path touches it. Raw
//! measurements stay where they are cheap — recorder events
//! ([`super::Recorder`]), fabric byte counters
//! ([`crate::comm::fabric::ByteCounters`]), transport counters
//! ([`crate::transport::TransportStats`]), plan-cache hit/miss counters
//! ([`crate::plan::PlanCacheStats`]) — and are absorbed into a registry
//! only when a snapshot is wanted (CLI `flashcomm metrics`, `--trace-out`,
//! tests). Span events are paired Start→End per
//! (rank, algo, stage, op, codec) and folded into counters plus
//! log₂-bucketed latency histograms keyed per (algo, stage, op, codec).

use std::collections::{BTreeMap, HashMap};

use super::analyze::StragglerReport;
use super::recorder::{AlgoTag, Event, Kind, Op, Recorder, Stage};
use super::trace::ClockSyncStats;
use crate::comm::fabric::CountersSnapshot;
use crate::plan::PlanCacheStats;
use crate::session::SessionStats;
use crate::transport::TransportStats;

/// Number of log₂ latency buckets: bucket `i` holds spans with
/// `2^i <= nanos < 2^(i+1)` (bucket 0 also holds 0–1 ns; bucket 31 holds
/// everything ≥ 2³¹ ns ≈ 2.1 s).
pub const HIST_BUCKETS: usize = 32;

/// A log₂-bucketed latency histogram over span durations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histogram {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub total_nanos: u64,
    pub max_nanos: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; HIST_BUCKETS], count: 0, total_nanos: 0, max_nanos: 0 }
    }
}

impl Histogram {
    /// Bucket index for a duration: `floor(log2(nanos))` clamped to the
    /// bucket range (0 ns lands in bucket 0).
    pub fn bucket_of(nanos: u64) -> usize {
        if nanos == 0 {
            0
        } else {
            ((63 - nanos.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        }
    }

    pub fn observe(&mut self, nanos: u64) {
        self.buckets[Self::bucket_of(nanos)] += 1;
        self.count += 1;
        self.total_nanos += nanos;
        self.max_nanos = self.max_nanos.max(nanos);
    }

    /// Mean span duration in nanoseconds (0 when empty).
    pub fn mean_nanos(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.total_nanos / self.count
        }
    }
}

/// One aggregated series: every span sharing (algo, stage, op, codec).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Series {
    /// Completed Start→End pairs folded in.
    pub spans: u64,
    /// Sum of the End events' byte payloads (wire bytes for codec/send
    /// ops).
    pub bytes: u64,
    pub hist: Histogram,
}

/// A fully resolved series key, decoded for display/export.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct SeriesKey {
    pub algo: AlgoTag,
    pub stage: Stage,
    pub op: Op,
    pub codec_tag: u16,
}

/// The offline aggregator. Build one, absorb whatever sources exist, then
/// [`MetricsRegistry::snapshot`].
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    series: BTreeMap<(u8, u8, u8, u16), Series>,
    /// Events that could not be paired (End with no Start, Start with no
    /// End) — nonzero when the ring wrapped mid-span.
    unpaired: u64,
    /// Events lost to ring wraparound across absorbed recorders
    /// (newest-wins overwrite; see [`Recorder::dropped_events`]).
    dropped_events: u64,
    /// Per-rank clock-sync estimates (one entry per synced recorder).
    clock: Vec<ClockSyncStats>,
    /// Fabric critical-path straggler findings ([`super::analyze`]).
    stragglers: Vec<StragglerReport>,
    fabric: Option<CountersSnapshot>,
    transport: Option<TransportStats>,
    session: Option<SessionStats>,
    plan_cache: Option<PlanCacheStats>,
    last_plan: Option<(String, u64)>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Fold one rank's recorded events in. Events must be in recording
    /// order (as [`super::Recorder::events`] returns them); spans are
    /// paired per (rank, algo, stage, op, codec) so interleaved chunks and
    /// the enclosing `Collective` span pair correctly.
    pub fn absorb_events(&mut self, events: &[Event]) {
        // Open-span stack per pairing key: (rank, algo, stage, op, codec).
        let mut open: HashMap<(u16, u8, u8, u8, u16), Vec<u64>> = HashMap::new();
        for e in events {
            let key = (e.rank, e.algo as u8, e.stage as u8, e.op as u8, e.codec_tag);
            match e.kind {
                Kind::Start => open.entry(key).or_default().push(e.t_nanos),
                Kind::End => match open.get_mut(&key).and_then(|v| v.pop()) {
                    Some(t0) => {
                        let s = self
                            .series
                            .entry((e.algo as u8, e.stage as u8, e.op as u8, e.codec_tag))
                            .or_default();
                        s.spans += 1;
                        s.bytes += e.bytes;
                        s.hist.observe(e.t_nanos.saturating_sub(t0));
                    }
                    None => self.unpaired += 1,
                },
            }
        }
        self.unpaired += open.values().map(|v| v.len() as u64).sum::<u64>();
    }

    /// Fold one rank's recorder health in: ring-wraparound losses
    /// ([`Recorder::dropped_events`]) and, when the rank ran
    /// [`crate::session::sync_clocks`], its clock estimate. Call next to
    /// [`absorb_events`](MetricsRegistry::absorb_events) — the event fold
    /// deliberately cannot see what the ring already overwrote.
    pub fn absorb_recorder(&mut self, rec: &Recorder) {
        self.dropped_events += rec.dropped_events();
        let (offset_nanos, rtt_nanos, probes) = rec.clock();
        if probes > 0 {
            self.clock.push(ClockSyncStats {
                rank: rec.rank() as u16,
                offset_nanos,
                rtt_nanos,
                probes,
            });
        }
    }

    /// Attach straggler findings from the fabric critical-path analysis
    /// ([`super::analyze`]).
    pub fn absorb_stragglers(&mut self, stragglers: &[StragglerReport]) {
        self.stragglers.extend_from_slice(stragglers);
    }

    /// Attach (or accumulate) a fabric byte-counter snapshot.
    pub fn absorb_fabric(&mut self, s: CountersSnapshot) {
        self.fabric = Some(match self.fabric {
            Some(prev) => CountersSnapshot {
                total: prev.total + s.total,
                cross_numa: prev.cross_numa + s.cross_numa,
                messages: prev.messages + s.messages,
            },
            None => s,
        });
    }

    /// Attach (or accumulate) a transport counter snapshot.
    pub fn absorb_transport(&mut self, s: TransportStats) {
        self.transport = Some(match self.transport {
            Some(prev) => TransportStats {
                payload_bytes: prev.payload_bytes + s.payload_bytes,
                wire_bytes: prev.wire_bytes + s.wire_bytes,
                messages: prev.messages + s.messages,
                buffered_bytes: prev.buffered_bytes + s.buffered_bytes,
                peak_buffered_bytes: prev.peak_buffered_bytes.max(s.peak_buffered_bytes),
                nacks_sent: prev.nacks_sent + s.nacks_sent,
                nacks_received: prev.nacks_received + s.nacks_received,
                retransmitted_chunks: prev.retransmitted_chunks + s.retransmitted_chunks,
                duplicate_drops: prev.duplicate_drops + s.duplicate_drops,
                reorder_events: prev.reorder_events + s.reorder_events,
                corrupt_drops: prev.corrupt_drops + s.corrupt_drops,
                stale_epoch_drops: prev.stale_epoch_drops + s.stale_epoch_drops,
                redundancy_bytes: prev.redundancy_bytes + s.redundancy_bytes,
                paced_stalls: prev.paced_stalls + s.paced_stalls,
            },
            None => s,
        });
    }

    /// Attach (or accumulate) session-fabric counters. Epochs across
    /// endpoints of one job agree by construction (the rendezvous rejects
    /// conflicts), so accumulation keeps the max.
    pub fn absorb_session(&mut self, s: SessionStats) {
        self.session = Some(match self.session {
            Some(prev) => SessionStats {
                epoch: prev.epoch.max(s.epoch),
                heartbeats_sent: prev.heartbeats_sent + s.heartbeats_sent,
                heartbeats_received: prev.heartbeats_received + s.heartbeats_received,
                suspects: prev.suspects + s.suspects,
                losses: prev.losses + s.losses,
                epoch_bumps: prev.epoch_bumps + s.epoch_bumps,
            },
            None => s,
        });
    }

    /// Attach (or accumulate) plan-cache hit/miss/eviction counters.
    pub fn absorb_plan_cache(&mut self, s: PlanCacheStats) {
        self.plan_cache = Some(match self.plan_cache {
            Some(prev) => PlanCacheStats {
                hits: prev.hits + s.hits,
                misses: prev.misses + s.misses,
                evictions: prev.evictions + s.evictions,
            },
            None => s,
        });
    }

    /// Record the resolved plan of the most recent collective (display
    /// form + fingerprint).
    pub fn set_last_plan(&mut self, display: String, fingerprint: u64) {
        self.last_plan = Some((display, fingerprint));
    }

    /// Materialize everything absorbed so far.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            series: self
                .series
                .iter()
                .filter_map(|(&(algo, stage, op, codec_tag), &s)| {
                    Some((
                        SeriesKey {
                            algo: AlgoTag::from_u8(algo)?,
                            stage: Stage::from_u8(stage)?,
                            op: Op::from_u8(op)?,
                            codec_tag,
                        },
                        s,
                    ))
                })
                .collect(),
            unpaired: self.unpaired,
            dropped_events: self.dropped_events,
            clock: self.clock.clone(),
            stragglers: self.stragglers.clone(),
            fabric: self.fabric,
            transport: self.transport,
            session: self.session,
            plan_cache: self.plan_cache,
            last_plan: self.last_plan.clone(),
        }
    }
}

/// A point-in-time export of the registry: what `flashcomm metrics`
/// prints and tests assert on.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub series: Vec<(SeriesKey, Series)>,
    pub unpaired: u64,
    /// Events lost to ring wraparound across absorbed recorders.
    pub dropped_events: u64,
    /// Per-rank clock-sync estimates (empty when no rank probed).
    pub clock: Vec<ClockSyncStats>,
    /// Fabric straggler findings (empty on a clean run).
    pub stragglers: Vec<StragglerReport>,
    pub fabric: Option<CountersSnapshot>,
    pub transport: Option<TransportStats>,
    /// Session-fabric counters, when a live session ran (TCP with
    /// heartbeats, or a fault-injected mesh).
    pub session: Option<SessionStats>,
    pub plan_cache: Option<PlanCacheStats>,
    /// Display form + fingerprint of the last resolved `CommPlan`.
    pub last_plan: Option<(String, u64)>,
}

impl MetricsSnapshot {
    /// Hand-rolled JSON export (no serde in the dependency set).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"series\":[");
        for (i, (k, s)) in self.series.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let nonzero: Vec<String> = s
                .hist
                .buckets
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(b, &c)| format!("[{b},{c}]"))
                .collect();
            out.push_str(&format!(
                "{{\"algo\":\"{}\",\"stage\":\"{}\",\"op\":\"{}\",\"codec\":\"{}\",\
                 \"spans\":{},\"bytes\":{},\"mean_nanos\":{},\"max_nanos\":{},\
                 \"hist_log2\":[{}]}}",
                k.algo.name(),
                k.stage.name(),
                k.op.name(),
                super::codec_tag_name(k.codec_tag),
                s.spans,
                s.bytes,
                s.hist.mean_nanos(),
                s.hist.max_nanos,
                nonzero.join(",")
            ));
        }
        out.push_str(&format!(
            "],\"unpaired\":{},\"dropped_events\":{}",
            self.unpaired, self.dropped_events
        ));
        out.push_str(",\"clock\":[");
        for (i, c) in self.clock.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"rank\":{},\"offset_nanos\":{},\"rtt_nanos\":{},\"probes\":{}}}",
                c.rank, c.offset_nanos, c.rtt_nanos, c.probes
            ));
        }
        out.push_str("],\"stragglers\":[");
        for (i, s) in self.stragglers.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"rank\":{},\"stage\":\"{}\",\"excess_ms\":{:.3},\"median_ms\":{:.3}}}",
                s.rank,
                s.stage.name(),
                s.excess_ms,
                s.median_ms
            ));
        }
        out.push(']');
        if let Some(f) = self.fabric {
            out.push_str(&format!(
                ",\"fabric\":{{\"total_bytes\":{},\"cross_numa_bytes\":{},\"messages\":{}}}",
                f.total, f.cross_numa, f.messages
            ));
        }
        if let Some(t) = self.transport {
            out.push_str(&format!(
                ",\"transport\":{{\"payload_bytes\":{},\"wire_bytes\":{},\"messages\":{},\
                 \"buffered_bytes\":{},\"peak_buffered_bytes\":{},\"nacks_sent\":{},\
                 \"nacks_received\":{},\"retransmitted_chunks\":{},\"duplicate_drops\":{},\
                 \"reorder_events\":{},\"corrupt_drops\":{},\"stale_epoch_drops\":{},\
                 \"redundancy_bytes\":{},\"paced_stalls\":{}}}",
                t.payload_bytes,
                t.wire_bytes,
                t.messages,
                t.buffered_bytes,
                t.peak_buffered_bytes,
                t.nacks_sent,
                t.nacks_received,
                t.retransmitted_chunks,
                t.duplicate_drops,
                t.reorder_events,
                t.corrupt_drops,
                t.stale_epoch_drops,
                t.redundancy_bytes,
                t.paced_stalls
            ));
        }
        if let Some(s) = self.session {
            out.push_str(&format!(
                ",\"session\":{{\"epoch\":{},\"heartbeats_sent\":{},\"heartbeats_received\":{},\
                 \"suspects\":{},\"losses\":{},\"epoch_bumps\":{}}}",
                s.epoch,
                s.heartbeats_sent,
                s.heartbeats_received,
                s.suspects,
                s.losses,
                s.epoch_bumps
            ));
        }
        if let Some(p) = self.plan_cache {
            out.push_str(&format!(
                ",\"plan_cache\":{{\"hits\":{},\"misses\":{},\"evictions\":{}}}",
                p.hits, p.misses, p.evictions
            ));
        }
        if let Some((plan, fp)) = &self.last_plan {
            out.push_str(&format!(",\"last_plan\":{{\"plan\":\"{plan}\",\"fp\":\"{fp:#018x}\"}}"));
        }
        out.push('}');
        out
    }

    /// Prometheus text-exposition export for `flashcomm metrics --serve`.
    /// Zero-dependency: the format is plain text, one sample per line,
    /// `# HELP` / `# TYPE` headers per family
    /// (<https://prometheus.io/docs/instrumenting/exposition_formats/>).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        out.push_str("# HELP flashcomm_spans_total Completed telemetry spans per series.\n");
        out.push_str("# TYPE flashcomm_spans_total counter\n");
        for (k, s) in &self.series {
            out.push_str(&format!(
                "flashcomm_spans_total{{algo=\"{}\",stage=\"{}\",op=\"{}\",codec=\"{}\"}} {}\n",
                k.algo.name(),
                k.stage.name(),
                k.op.name(),
                super::codec_tag_name(k.codec_tag),
                s.spans
            ));
        }
        out.push_str("# HELP flashcomm_span_bytes_total Bytes carried by completed spans.\n");
        out.push_str("# TYPE flashcomm_span_bytes_total counter\n");
        for (k, s) in &self.series {
            out.push_str(&format!(
                "flashcomm_span_bytes_total{{algo=\"{}\",stage=\"{}\",op=\"{}\",codec=\"{}\"}} {}\n",
                k.algo.name(),
                k.stage.name(),
                k.op.name(),
                super::codec_tag_name(k.codec_tag),
                s.bytes
            ));
        }
        out.push_str("# HELP flashcomm_span_mean_nanos Mean span duration per series.\n");
        out.push_str("# TYPE flashcomm_span_mean_nanos gauge\n");
        for (k, s) in &self.series {
            out.push_str(&format!(
                "flashcomm_span_mean_nanos{{algo=\"{}\",stage=\"{}\",op=\"{}\",codec=\"{}\"}} {}\n",
                k.algo.name(),
                k.stage.name(),
                k.op.name(),
                super::codec_tag_name(k.codec_tag),
                s.hist.mean_nanos()
            ));
        }
        out.push_str("# HELP flashcomm_unpaired_events_total Events with no matching Start/End.\n");
        out.push_str("# TYPE flashcomm_unpaired_events_total counter\n");
        out.push_str(&format!("flashcomm_unpaired_events_total {}\n", self.unpaired));
        out.push_str("# HELP flashcomm_dropped_events_total Events lost to recorder ring wraparound.\n");
        out.push_str("# TYPE flashcomm_dropped_events_total counter\n");
        out.push_str(&format!("flashcomm_dropped_events_total {}\n", self.dropped_events));
        if !self.clock.is_empty() {
            out.push_str("# HELP flashcomm_clock_offset_nanos Estimated clock offset vs rank 0.\n");
            out.push_str("# TYPE flashcomm_clock_offset_nanos gauge\n");
            for c in &self.clock {
                out.push_str(&format!(
                    "flashcomm_clock_offset_nanos{{rank=\"{}\"}} {}\n",
                    c.rank, c.offset_nanos
                ));
            }
            out.push_str("# HELP flashcomm_clock_rtt_nanos Probe round-trip of the winning sample.\n");
            out.push_str("# TYPE flashcomm_clock_rtt_nanos gauge\n");
            for c in &self.clock {
                out.push_str(&format!(
                    "flashcomm_clock_rtt_nanos{{rank=\"{}\"}} {}\n",
                    c.rank, c.rtt_nanos
                ));
            }
        }
        if !self.stragglers.is_empty() {
            out.push_str("# HELP flashcomm_straggler_excess_ms Wait charged beyond the fabric median.\n");
            out.push_str("# TYPE flashcomm_straggler_excess_ms gauge\n");
            for s in &self.stragglers {
                out.push_str(&format!(
                    "flashcomm_straggler_excess_ms{{rank=\"{}\",stage=\"{}\"}} {:.3}\n",
                    s.rank,
                    s.stage.name(),
                    s.excess_ms
                ));
            }
        }
        if let Some(f) = self.fabric {
            out.push_str("# HELP flashcomm_fabric_bytes_total Payload bytes moved through the fabric.\n");
            out.push_str("# TYPE flashcomm_fabric_bytes_total counter\n");
            out.push_str(&format!("flashcomm_fabric_bytes_total {}\n", f.total));
            out.push_str(&format!("flashcomm_fabric_cross_numa_bytes_total {}\n", f.cross_numa));
            out.push_str(&format!("flashcomm_fabric_messages_total {}\n", f.messages));
        }
        if let Some(t) = self.transport {
            out.push_str("# HELP flashcomm_transport_wire_bytes_total Bytes on the wire incl. framing.\n");
            out.push_str("# TYPE flashcomm_transport_wire_bytes_total counter\n");
            out.push_str(&format!("flashcomm_transport_payload_bytes_total {}\n", t.payload_bytes));
            out.push_str(&format!("flashcomm_transport_wire_bytes_total {}\n", t.wire_bytes));
            out.push_str(&format!("flashcomm_transport_messages_total {}\n", t.messages));
            out.push_str(&format!("flashcomm_transport_nacks_sent_total {}\n", t.nacks_sent));
            out.push_str(&format!(
                "flashcomm_transport_retransmitted_chunks_total {}\n",
                t.retransmitted_chunks
            ));
            out.push_str(&format!("flashcomm_transport_corrupt_drops_total {}\n", t.corrupt_drops));
        }
        if let Some(s) = self.session {
            out.push_str("# HELP flashcomm_session_epoch Current session epoch.\n");
            out.push_str("# TYPE flashcomm_session_epoch gauge\n");
            out.push_str(&format!("flashcomm_session_epoch {}\n", s.epoch));
            out.push_str(&format!("flashcomm_session_losses_total {}\n", s.losses));
            out.push_str(&format!("flashcomm_session_epoch_bumps_total {}\n", s.epoch_bumps));
        }
        if let Some(p) = self.plan_cache {
            out.push_str("# HELP flashcomm_plan_cache_hits_total Plan cache hits.\n");
            out.push_str("# TYPE flashcomm_plan_cache_hits_total counter\n");
            out.push_str(&format!("flashcomm_plan_cache_hits_total {}\n", p.hits));
            out.push_str(&format!("flashcomm_plan_cache_misses_total {}\n", p.misses));
            out.push_str(&format!("flashcomm_plan_cache_evictions_total {}\n", p.evictions));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        seq: u64,
        t: u64,
        kind: Kind,
        op: Op,
        stage: Stage,
        bytes: u64,
    ) -> Event {
        Event {
            seq,
            t_nanos: t,
            kind,
            op,
            stage,
            algo: AlgoTag::Hier,
            rank: 0,
            codec_tag: 0x1004,
            plan_fp: 7,
            bytes,
            chunk: 0,
            link: None,
        }
    }

    #[test]
    fn bucket_edges() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 0);
        assert_eq!(Histogram::bucket_of(2), 1);
        assert_eq!(Histogram::bucket_of(3), 1);
        assert_eq!(Histogram::bucket_of(1024), 10);
        assert_eq!(Histogram::bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn spans_pair_and_aggregate() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            ev(0, 100, Kind::Start, Op::Encode, Stage::ReduceScatter, 64),
            ev(1, 300, Kind::End, Op::Encode, Stage::ReduceScatter, 40),
            ev(2, 400, Kind::Start, Op::Encode, Stage::ReduceScatter, 64),
            ev(3, 500, Kind::End, Op::Encode, Stage::ReduceScatter, 40),
        ]);
        let snap = reg.snapshot();
        assert_eq!(snap.series.len(), 1);
        let (k, s) = snap.series[0];
        assert_eq!(k.op, Op::Encode);
        assert_eq!(k.stage, Stage::ReduceScatter);
        assert_eq!(s.spans, 2);
        assert_eq!(s.bytes, 80, "bytes come from End events");
        assert_eq!(s.hist.count, 2);
        assert_eq!(s.hist.total_nanos, 300);
        assert_eq!(s.hist.max_nanos, 200);
        assert_eq!(snap.unpaired, 0);
    }

    #[test]
    fn wraparound_orphans_are_counted_not_mispaired() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            // End whose Start was overwritten by ring wraparound…
            ev(10, 900, Kind::End, Op::Send, Stage::CrossGroup, 8),
            // …and a Start whose End never came.
            ev(11, 950, Kind::Start, Op::Recv, Stage::CrossGroup, 0),
        ]);
        let snap = reg.snapshot();
        assert!(snap.series.is_empty());
        assert_eq!(snap.unpaired, 2);
    }

    #[test]
    fn collective_span_pairs_around_nested_ops() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            ev(0, 0, Kind::Start, Op::Collective, Stage::Single, 0),
            ev(1, 10, Kind::Start, Op::Send, Stage::ReduceScatter, 8),
            ev(2, 20, Kind::End, Op::Send, Stage::ReduceScatter, 8),
            ev(3, 50, Kind::End, Op::Collective, Stage::Single, 0),
        ]);
        let snap = reg.snapshot();
        assert_eq!(snap.series.len(), 2);
        assert_eq!(snap.unpaired, 0);
    }

    #[test]
    fn session_counters_accumulate_and_export() {
        let mut reg = MetricsRegistry::new();
        assert!(reg.snapshot().session.is_none(), "no session absorbed, no block");
        reg.absorb_session(SessionStats {
            epoch: 1,
            heartbeats_sent: 10,
            heartbeats_received: 9,
            suspects: 1,
            losses: 1,
            epoch_bumps: 1,
        });
        reg.absorb_session(SessionStats {
            epoch: 1,
            heartbeats_sent: 5,
            heartbeats_received: 6,
            suspects: 0,
            losses: 0,
            epoch_bumps: 0,
        });
        let snap = reg.snapshot();
        let s = snap.session.unwrap();
        assert_eq!((s.epoch, s.heartbeats_sent, s.heartbeats_received), (1, 15, 15));
        assert_eq!((s.suspects, s.losses, s.epoch_bumps), (1, 1, 1));
        let json = snap.to_json();
        for field in [
            "\"session\":{",
            "\"epoch\":1",
            "\"heartbeats_sent\":15",
            "\"heartbeats_received\":15",
            "\"suspects\":1",
            "\"losses\":1",
            "\"epoch_bumps\":1",
        ] {
            assert!(json.contains(field), "{json} missing {field}");
        }
    }

    #[test]
    fn json_export_carries_every_absorbed_source() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            ev(0, 0, Kind::Start, Op::Send, Stage::Single, 4),
            ev(1, 5, Kind::End, Op::Send, Stage::Single, 4),
        ]);
        reg.absorb_fabric(CountersSnapshot { total: 100, cross_numa: 40, messages: 3 });
        reg.absorb_fabric(CountersSnapshot { total: 1, cross_numa: 1, messages: 1 });
        reg.absorb_plan_cache(PlanCacheStats { hits: 5, misses: 2, evictions: 0 });
        reg.set_last_plan("hierpp".into(), 0xab);
        let json = reg.snapshot().to_json();
        for field in [
            "\"series\":[",
            "\"op\":\"send\"",
            "\"spans\":1",
            "\"total_bytes\":101",
            "\"messages\":4",
            "\"hits\":5",
            "\"last_plan\"",
            "\"fp\":\"0x00000000000000ab\"",
        ] {
            assert!(json.contains(field), "{json} missing {field}");
        }
    }

    #[test]
    fn recorder_health_and_stragglers_flow_into_both_exports() {
        let mut reg = MetricsRegistry::new();
        let rec = Recorder::new(3, 4);
        for _ in 0..6 {
            rec.record(Kind::Start, Op::Send, 8);
        }
        rec.set_clock(-2500, 900, 4);
        reg.absorb_recorder(&rec);
        reg.absorb_stragglers(&[StragglerReport {
            rank: 3,
            stage: Stage::ReduceScatter,
            excess_ms: 80.125,
            median_ms: 1.0,
        }]);
        let snap = reg.snapshot();
        assert_eq!(snap.dropped_events, 2, "6 recorded into a 4-slot ring");
        assert_eq!(snap.clock.len(), 1);
        assert_eq!(snap.clock[0].rank, 3);
        assert_eq!(snap.stragglers.len(), 1);
        let json = snap.to_json();
        for field in [
            "\"dropped_events\":2",
            "\"clock\":[{\"rank\":3,\"offset_nanos\":-2500,\"rtt_nanos\":900,\"probes\":4}]",
            "\"stragglers\":[{\"rank\":3,\"stage\":\"rs\",\"excess_ms\":80.125,\"median_ms\":1.000}]",
        ] {
            assert!(json.contains(field), "{json} missing {field}");
        }
        let prom = snap.to_prometheus();
        for line in [
            "flashcomm_dropped_events_total 2",
            "flashcomm_clock_offset_nanos{rank=\"3\"} -2500",
            "flashcomm_clock_rtt_nanos{rank=\"3\"} 900",
            "flashcomm_straggler_excess_ms{rank=\"3\",stage=\"rs\"} 80.125",
        ] {
            assert!(prom.contains(line), "{prom} missing {line}");
        }
    }

    #[test]
    fn an_unsynced_recorder_contributes_no_clock_row() {
        let mut reg = MetricsRegistry::new();
        let rec = Recorder::new(0, 8);
        rec.record(Kind::Start, Op::Send, 8);
        rec.record(Kind::End, Op::Send, 8);
        reg.absorb_recorder(&rec);
        let snap = reg.snapshot();
        assert_eq!(snap.dropped_events, 0);
        assert!(snap.clock.is_empty(), "probes == 0 means no estimate");
        assert!(snap.to_json().contains("\"clock\":[]"));
    }

    #[test]
    fn prometheus_export_covers_series_and_counter_blocks() {
        let mut reg = MetricsRegistry::new();
        reg.absorb_events(&[
            ev(0, 0, Kind::Start, Op::Send, Stage::Single, 4),
            ev(1, 5, Kind::End, Op::Send, Stage::Single, 4),
        ]);
        reg.absorb_fabric(CountersSnapshot { total: 100, cross_numa: 40, messages: 3 });
        reg.absorb_plan_cache(PlanCacheStats { hits: 5, misses: 2, evictions: 0 });
        let prom = reg.snapshot().to_prometheus();
        for line in [
            "# TYPE flashcomm_spans_total counter",
            "op=\"send\"",
            "flashcomm_unpaired_events_total 0",
            "flashcomm_fabric_bytes_total 100",
            "flashcomm_plan_cache_misses_total 2",
        ] {
            assert!(prom.contains(line), "{prom} missing {line}");
        }
        assert!(
            !prom.contains("flashcomm_session_epoch "),
            "no session absorbed, no session family"
        );
    }

    #[test]
    fn transport_block_accumulates_and_exports_robustness_counters() {
        let mut reg = MetricsRegistry::new();
        let mut a = TransportStats {
            payload_bytes: 1000,
            wire_bytes: 1100,
            messages: 2,
            nacks_sent: 3,
            nacks_received: 1,
            retransmitted_chunks: 4,
            duplicate_drops: 5,
            reorder_events: 6,
            corrupt_drops: 7,
            stale_epoch_drops: 8,
            redundancy_bytes: 90,
            paced_stalls: 2,
            ..TransportStats::default()
        };
        reg.absorb_transport(a);
        a.peak_buffered_bytes = 512;
        reg.absorb_transport(a);
        let t = reg.snapshot().transport.unwrap();
        assert_eq!(t.payload_bytes, 2000, "sums across absorbs");
        assert_eq!(t.nacks_sent, 6);
        assert_eq!(t.retransmitted_chunks, 8);
        assert_eq!(t.peak_buffered_bytes, 512, "peak is a max, not a sum");
        let json = reg.snapshot().to_json();
        for field in [
            "\"transport\":{",
            "\"nacks_sent\":6",
            "\"nacks_received\":2",
            "\"retransmitted_chunks\":8",
            "\"duplicate_drops\":10",
            "\"reorder_events\":12",
            "\"corrupt_drops\":14",
            "\"stale_epoch_drops\":16",
            "\"redundancy_bytes\":180",
            "\"paced_stalls\":4",
        ] {
            assert!(json.contains(field), "{json} missing {field}");
        }
    }
}
