//! Flight-recorder telemetry: per-stage tracing, a metrics registry, and
//! the trace→profile distillation that feeds plan recalibration.
//!
//! Three pieces (DESIGN.md §11):
//!
//! - [`Recorder`] — a lock-free, fixed-capacity per-rank ring buffer of
//!   typed [`Event`]s, written via the zero-alloc [`record!`] macro. The
//!   fabric layer records `Send`/`Recv` spans automatically; the
//!   collectives add `Encode`/`Decode`/`DecodeSum` spans plus stage and
//!   chunk context; the communicator wraps each call in a `Collective`
//!   span carrying the resolved plan fingerprint. Disabled (the default)
//!   it is one untaken `Option` branch on the hot path.
//! - [`MetricsRegistry`] — the offline aggregation/export path: recorder
//!   spans folded into per-(algo, stage, op, codec) counters and log₂
//!   latency histograms, alongside the fabric byte counters, transport
//!   counters, and plan-cache statistics that used to be separate
//!   test-only surfaces.
//! - [`distill_profile`] — turns recorded per-stage wall times into a
//!   [`MeasuredProfile`] (effective intra/inter bandwidth, QDQ pass rate)
//!   that `plan::compile_profiled` prices candidates against, closing the
//!   measure→tune loop the paper's co-design section calls for.
//!
//! The fabric-wide layer on top (DESIGN.md §15): [`trace`] aligns
//! per-rank timelines via NTP-style clock sync and merges them into one
//! Chrome-trace-event JSON; [`analyze`] walks the matched send→recv edges
//! of the merged view to attribute wall time per rank, name stragglers
//! ([`StragglerReport`]), and distill a straggler-robust fabric profile.

pub mod analyze;
pub mod recorder;
pub mod registry;
pub mod trace;

pub use analyze::{
    analyze, distill_fabric_profile, FabricReport, RankAttribution, StragglerReport,
};
pub use recorder::{AlgoTag, Event, Kind, Op, Recorder, Stage, DEFAULT_CAPACITY};
pub use registry::{
    Histogram, MetricsRegistry, MetricsSnapshot, Series, SeriesKey, HIST_BUCKETS,
};
pub use trace::{
    merge_traces, parse_trace, ClockSync, ClockSyncStats, MergedTrace, ProbeSample,
    RankTrace, TraceEvent, MAX_PROBES,
};

use crate::quant::Codec;
use crate::sim::MeasuredProfile;

/// Record one event through an `Option<&Recorder>` — the hot-path entry
/// point. With the recorder disabled (`None`) this is a single untaken
/// branch; enabled it is [`Recorder::record`]: atomic stores into a
/// pre-allocated slot, never an allocation.
///
/// ```ignore
/// record!(rec, start Op::Encode, data.len() as u64);
/// let wire = encode(...)?;
/// record!(rec, end Op::Encode, wire.len() as u64);
/// ```
#[macro_export]
macro_rules! record {
    ($rec:expr, start $op:expr) => {
        if let Some(__r) = $rec {
            __r.record($crate::telemetry::Kind::Start, $op, 0);
        }
    };
    ($rec:expr, start $op:expr, $bytes:expr) => {
        if let Some(__r) = $rec {
            __r.record($crate::telemetry::Kind::Start, $op, $bytes);
        }
    };
    ($rec:expr, end $op:expr, $bytes:expr) => {
        if let Some(__r) = $rec {
            __r.record($crate::telemetry::Kind::End, $op, $bytes);
        }
    };
}

/// Pack a codec's identity into the 16-bit tag events carry:
/// scheme in bits 15..13, integer-metadata mode in bit 11, quantization
/// bits in the low byte. Group size is deliberately dropped — the
/// registry keys series by *scheme family*, and the full codec identity
/// is recoverable from the plan fingerprint when needed. Tag 0 is
/// reserved for "no codec context".
pub fn codec_tag(codec: &Codec) -> u16 {
    use crate::quant::ScaleMode;
    let (scheme, bits, mode): (u16, u8, u16) = match *codec {
        Codec::Bf16 => (0, 16, 0),
        Codec::Rtn { bits, scale_mode, .. } => (1, bits, (scale_mode == ScaleMode::IntLog) as u16),
        Codec::Spike { bits, scale_mode, .. } => {
            (2, bits, (scale_mode == ScaleMode::IntLog) as u16)
        }
        Codec::Hadamard { bits, .. } => (3, bits, 0),
        Codec::LogFmt { bits, .. } => (4, bits, 0),
    };
    (scheme + 1) << 12 | mode << 11 | bits as u16
}

/// Paper-style display name for a [`codec_tag`] (`"INT2_SR"`, `"BF16"`,
/// `"none"` for tag 0), mirroring `Codec::name`.
pub fn codec_tag_name(tag: u16) -> String {
    if tag == 0 {
        return "none".into();
    }
    let bits = tag & 0xff;
    match tag >> 12 {
        1 => "BF16".into(),
        2 => format!("INT{bits}"),
        3 => format!("INT{bits}_SR"),
        4 => format!("INT{bits}_HAD"),
        5 => format!("INT{bits}_LOG"),
        _ => format!("tag{tag:#06x}"),
    }
}

/// The [`AlgoTag`] recorded events carry for a comm-layer algorithm.
pub fn algo_tag(algo: crate::comm::Algo) -> AlgoTag {
    match algo {
        crate::comm::Algo::Ring => AlgoTag::Ring,
        crate::comm::Algo::TwoStep => AlgoTag::TwoStep,
        crate::comm::Algo::Hier => AlgoTag::Hier,
        crate::comm::Algo::HierPipelined => AlgoTag::HierPipelined,
    }
}

/// One rank's recorded trace as a JSON object (DESIGN.md §11/§15):
/// `{"rank": R, "capacity": C, "recorded": N, "dropped_events": D,
/// "clock_offset_nanos": O, "clock_rtt_nanos": T, "clock_probes": P,
/// "events": [...]}` — `recorded` is the total ever recorded and
/// `dropped_events` what wraparound lost, so a consumer sees a wrapped
/// trace (the newest tail) for what it is. The clock fields carry the
/// session clock-sync estimate the merge pass aligns timelines with
/// (all zero when never synced — e.g. in-process shared-origin groups).
pub fn trace_json(rec: &Recorder) -> String {
    let events = rec.events();
    let (offset, rtt, probes) = rec.clock();
    let mut out = String::with_capacity(160 + events.len() * 192);
    out.push_str(&format!(
        "{{\"rank\":{},\"capacity\":{},\"recorded\":{},\"dropped_events\":{},\
         \"clock_offset_nanos\":{},\"clock_rtt_nanos\":{},\"clock_probes\":{},\"events\":[",
        rec.rank(),
        rec.capacity(),
        rec.total_recorded(),
        rec.dropped_events(),
        offset,
        rtt,
        probes
    ));
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&e.to_json());
    }
    out.push_str("]}");
    out
}

/// Distill a [`MeasuredProfile`] from recorded events (typically the
/// concatenation of every rank's [`Recorder::events`]).
///
/// The mapping onto `sim` cost-model terms (DESIGN.md §11):
///
/// - **link bandwidth** — each completed `Send` span moved its End-event
///   bytes in its wall time, so the effective rate per tier is
///   `Σ bytes / Σ seconds` over the tier's sends: `cross`-stage sends
///   measure the inter-group link, every other stage measures the
///   intra-group link. `Recv` spans are excluded — their wall time is
///   dominated by waiting for the peer, not by the wire.
/// - **QDQ pass rate** — each codec span (`Encode`/`Decode`/`DecodeSum`)
///   is one pass over its Start-event element count, so the effective
///   rate is `Σ elements / Σ seconds`, directly comparable to
///   `GpuSpec::qdq_pass_rate`.
///
/// Tiers or terms with no completed spans (or zero measured time) stay
/// `None` and leave the static calibration untouched.
pub fn distill_profile(events: &[Event]) -> MeasuredProfile {
    // Open Send/codec spans per (rank, algo, stage, op, codec): t_start
    // and the Start-event byte word.
    let mut open: std::collections::HashMap<(u16, u8, u8, u8, u16), Vec<(u64, u64)>> =
        std::collections::HashMap::new();
    // (bytes or elements, nanos) accumulators.
    let (mut intra, mut inter, mut qdq) = ((0u64, 0u64), (0u64, 0u64), (0u64, 0u64));
    for e in events {
        if !matches!(e.op, Op::Send | Op::Encode | Op::Decode | Op::DecodeSum) {
            continue;
        }
        let key = (e.rank, e.algo as u8, e.stage as u8, e.op as u8, e.codec_tag);
        match e.kind {
            Kind::Start => open.entry(key).or_default().push((e.t_nanos, e.bytes)),
            Kind::End => {
                let Some((t0, start_bytes)) = open.get_mut(&key).and_then(|v| v.pop()) else {
                    continue;
                };
                let nanos = e.t_nanos.saturating_sub(t0);
                match e.op {
                    Op::Send => {
                        let cross = e.stage == Stage::CrossGroup;
                        let acc = if cross { &mut inter } else { &mut intra };
                        acc.0 += e.bytes;
                        acc.1 += nanos;
                    }
                    _ => {
                        qdq.0 += start_bytes;
                        qdq.1 += nanos;
                    }
                }
            }
        }
    }
    let rate = |(units, nanos): (u64, u64)| {
        (units > 0 && nanos > 0).then(|| units as f64 / (nanos as f64 * 1e-9))
    };
    MeasuredProfile {
        intra_bw: rate(intra),
        inter_bw: rate(inter),
        qdq_pass_rate: rate(qdq),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_tags_are_distinct_and_named() {
        let cases = [
            (Codec::Bf16, "BF16"),
            (Codec::parse("int4@32").unwrap(), "INT4"),
            (Codec::parse("int2-sr@32").unwrap(), "INT2_SR"),
            (Codec::parse("int2-sr@32!").unwrap(), "INT2_SR"),
            (Codec::parse("int4-had@32").unwrap(), "INT4_HAD"),
            (Codec::parse("int3-log@32").unwrap(), "INT3_LOG"),
        ];
        let mut seen = std::collections::HashSet::new();
        for (codec, name) in cases {
            let tag = codec_tag(&codec);
            assert_ne!(tag, 0, "tag 0 is reserved for 'no codec'");
            assert!(seen.insert(tag), "collision for {codec:?}");
            assert_eq!(codec_tag_name(tag), name);
        }
        assert_eq!(codec_tag_name(0), "none");
    }

    fn send_span(stage: Stage, t0: u64, t1: u64, bytes: u64) -> [Event; 2] {
        let base = Event {
            seq: 0,
            t_nanos: t0,
            kind: Kind::Start,
            op: Op::Send,
            stage,
            algo: AlgoTag::Hier,
            rank: 0,
            codec_tag: 1,
            plan_fp: 0,
            bytes,
            chunk: 0,
            link: None,
        };
        [base, Event { t_nanos: t1, kind: Kind::End, ..base }]
    }

    #[test]
    fn distills_per_tier_bandwidth_and_pass_rate() {
        let mut events = Vec::new();
        // Intra: 1000 bytes over 500 ns = 2 GB/s.
        events.extend(send_span(Stage::ReduceScatter, 0, 250, 500));
        events.extend(send_span(Stage::AllGather, 300, 550, 500));
        // Inter: 400 bytes over 800 ns = 0.5 GB/s.
        events.extend(send_span(Stage::CrossGroup, 600, 1400, 400));
        // QDQ: 2048 elements over 1024 ns = 2 Gpass/s.
        let enc = Event {
            seq: 0,
            t_nanos: 2000,
            kind: Kind::Start,
            op: Op::Encode,
            stage: Stage::ReduceScatter,
            algo: AlgoTag::Hier,
            rank: 0,
            codec_tag: 1,
            plan_fp: 0,
            bytes: 2048,
            chunk: 0,
            link: None,
        };
        events.push(enc);
        events.push(Event { t_nanos: 3024, kind: Kind::End, bytes: 512, ..enc });
        let p = distill_profile(&events);
        assert!((p.intra_bw.unwrap() - 2e9).abs() < 1e3, "{p:?}");
        assert!((p.inter_bw.unwrap() - 0.5e9).abs() < 1e3, "{p:?}");
        assert!((p.qdq_pass_rate.unwrap() - 2e9).abs() < 1e3, "{p:?}");
    }

    #[test]
    fn trace_json_wraps_the_event_rows() {
        let rec = Recorder::new(5, 8);
        rec.record(Kind::Start, Op::Send, 10);
        rec.record(Kind::End, Op::Send, 10);
        let json = trace_json(&rec);
        assert!(json.starts_with(
            "{\"rank\":5,\"capacity\":8,\"recorded\":2,\"dropped_events\":0,\
             \"clock_offset_nanos\":0,\"clock_rtt_nanos\":0,\"clock_probes\":0,\"events\":["
        ));
        assert!(json.ends_with("]}"));
        assert_eq!(json.matches("\"seq\":").count(), 2);
        let empty = trace_json(&Recorder::new(0, 4));
        assert_eq!(
            empty,
            "{\"rank\":0,\"capacity\":4,\"recorded\":0,\"dropped_events\":0,\
             \"clock_offset_nanos\":0,\"clock_rtt_nanos\":0,\"clock_probes\":0,\"events\":[]}"
        );
        let synced = Recorder::new(1, 4);
        synced.set_clock(-42, 900, 8);
        assert!(trace_json(&synced).contains(
            "\"clock_offset_nanos\":-42,\"clock_rtt_nanos\":900,\"clock_probes\":8"
        ));
    }

    #[test]
    fn algo_tags_mirror_comm_algos() {
        use crate::comm::Algo;
        for (a, t) in [
            (Algo::Ring, AlgoTag::Ring),
            (Algo::TwoStep, AlgoTag::TwoStep),
            (Algo::Hier, AlgoTag::Hier),
            (Algo::HierPipelined, AlgoTag::HierPipelined),
        ] {
            assert_eq!(algo_tag(a), t);
        }
    }

    #[test]
    fn unpaired_or_empty_traces_distill_to_nothing() {
        assert!(distill_profile(&[]).is_empty());
        let [start, _] = send_span(Stage::CrossGroup, 0, 100, 64);
        assert!(distill_profile(&[start]).is_empty(), "orphan Start contributes nothing");
    }
}
