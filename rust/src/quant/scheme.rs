//! The unified codec: one type that every collective and engine component
//! uses to turn activations/gradients into wire payloads and back.
//!
//! A [`Codec`] pairs a quantization scheme (BF16 passthrough, RTN, spike
//! reserving, Hadamard, LogFMT) with its parameters (bits, group size,
//! metadata mode) and produces self-describing payloads in the
//! [`wire`](super::wire) format. Decoding dispatches on the wire header, so
//! a rank can decode any payload the fabric delivers.

use anyhow::{bail, ensure, Result};

use super::bitsplit;
use super::fused;
use super::logfmt::LogMeta;
use super::rtn::{self, GroupMeta};
use super::spike::{self, ScaleMode, SpikeMeta};
use super::wire::{self, Header, SectionSizes, WireScheme, HEADER_LEN};
use crate::util::bf16::{self, Bf16};

/// The largest payload (in f32 elements) one wire message can carry: the
/// self-describing header stores the element count as a `u32`
/// ([`wire::Header::n`]). Encoding anything longer is rejected up front
/// ([`Codec::validate_len`]) — a silently truncated count would desync
/// every decoder downstream.
pub const MAX_WIRE_ELEMS: usize = u32::MAX as usize;

/// A fully parameterized quantization scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Codec {
    /// No quantization: BF16 on the wire (the paper's NCCL baseline volume).
    Bf16,
    /// Group-wise asymmetric round-to-nearest.
    Rtn { bits: u8, group_size: u16, scale_mode: ScaleMode },
    /// RTN over the spike-shrunken range, spikes reserved exactly.
    Spike { bits: u8, group_size: u16, scale_mode: ScaleMode },
    /// Hadamard-rotated RTN baseline.
    Hadamard { bits: u8, group_size: u16 },
    /// Log-domain quantization baseline.
    LogFmt { bits: u8, group_size: u16 },
}

/// Reusable scratch to keep the hot path allocation-free.
///
/// Ownership contract (see DESIGN.md §8): the fused kernels treat every
/// field as *theirs between calls* — per-group metadata (`metas`,
/// `spikes`, `logmetas`) is rebuilt by each encode/decode, `scratch` holds
/// at most `workers × group_size` f32 for Hadamard rotation, and `wire` is
/// the reusable QDQ wire image. No field ever grows with the payload
/// beyond the group count, which is why the INT2_SR reduce step needs no
/// payload-sized scratch at all.
#[derive(Default)]
pub struct CodecBuffers {
    pub(crate) metas: Vec<GroupMeta>,
    pub(crate) spikes: Vec<SpikeMeta>,
    pub(crate) logmetas: Vec<LogMeta>,
    pub(crate) scratch: Vec<f32>,
    /// Reusable wire buffer for [`Codec::qdq`] (encode-then-decode without
    /// a per-call `Vec` allocation).
    pub(crate) wire: Vec<u8>,
}

impl CodecBuffers {
    /// Bytes of owned capacity across all scratch buffers. Used by the
    /// collective layer to assert the hot path reuses (rather than regrows)
    /// its scratch after warmup.
    pub fn capacity_bytes(&self) -> usize {
        self.metas.capacity() * std::mem::size_of::<GroupMeta>()
            + self.spikes.capacity() * std::mem::size_of::<SpikeMeta>()
            + self.logmetas.capacity() * std::mem::size_of::<LogMeta>()
            + self.scratch.capacity() * 4
            + self.wire.capacity()
    }
}

impl Codec {
    /// Parse shorthand like `bf16`, `int8`, `int5`, `int2-sr`, `int4-had`,
    /// `int3-log`, with optional `@gs` suffix (`int2-sr@32`) and `!` for
    /// integer metadata (`int2-sr@32!`).
    pub fn parse(s: &str) -> Result<Codec> {
        let s = s.trim().to_ascii_lowercase();
        if s == "bf16" || s == "fp16" {
            return Ok(Codec::Bf16);
        }
        let (body, gs) = match s.split_once('@') {
            Some((b, g)) => (b.to_string(), g.to_string()),
            None => (s.clone(), String::new()),
        };
        let intlog = gs.ends_with('!') || body.ends_with('!');
        let gs = gs.trim_end_matches('!');
        let body = body.trim_end_matches('!');
        let (bits_part, kind) = match body.split_once('-') {
            Some((b, k)) => (b, k),
            None => (body, "rtn"),
        };
        ensure!(bits_part.starts_with("int"), "unrecognized codec '{s}'");
        let bits: u8 = bits_part[3..].parse()?;
        ensure!((1..=8).contains(&bits), "bits out of range in '{s}'");
        let default_gs: u16 = if bits <= 4 { 32 } else { 128 };
        let group_size: u16 = if gs.is_empty() { default_gs } else { gs.parse()? };
        let scale_mode = if intlog { ScaleMode::IntLog } else { ScaleMode::Bf16 };
        let codec = match kind {
            "rtn" => Codec::Rtn { bits, group_size, scale_mode },
            "sr" => Codec::Spike { bits, group_size, scale_mode },
            "had" => Codec::Hadamard { bits, group_size },
            "log" => Codec::LogFmt { bits, group_size },
            other => bail!("unknown scheme '{other}' in '{s}'"),
        };
        codec.validate()?;
        Ok(codec)
    }

    /// Structural constraints the wire header cannot express, checked both
    /// at parse time and when reconstructing a codec from a received header
    /// ([`codec_from_header`]), so hostile headers fail cleanly instead of
    /// silently corrupting or panicking:
    ///
    /// - spike reserving needs `2 <= group_size <= 256`: indices travel as
    ///   BF16 (exact only for integers up to 256) or u8 — larger groups
    ///   would silently corrupt spike positions on the wire;
    /// - Hadamard needs a power-of-two group for the FWHT butterfly;
    /// - LogFMT needs `bits >= 2` (a sign bit plus at least one magnitude
    ///   bit).
    pub fn validate(&self) -> Result<()> {
        match *self {
            Codec::Bf16 => {}
            Codec::Rtn { group_size, .. } => {
                ensure!(group_size >= 1, "rtn needs group_size >= 1");
            }
            Codec::Spike { group_size, .. } => {
                ensure!(
                    group_size >= 2,
                    "spike reserving needs groups of >= 2 (got {group_size})"
                );
                ensure!(
                    group_size as usize <= spike::MAX_GROUP,
                    "spike reserving caps group_size at {}: spike indices travel as bf16 \
                     (exact only up to 256) or u8, so group_size {group_size} would silently \
                     corrupt spike positions",
                    spike::MAX_GROUP
                );
            }
            Codec::Hadamard { group_size, .. } => {
                ensure!(
                    group_size.is_power_of_two(),
                    "hadamard needs a power-of-two group_size (got {group_size})"
                );
            }
            Codec::LogFmt { bits, group_size } => {
                ensure!(bits >= 2, "logfmt needs a sign bit plus >= 1 magnitude bit");
                ensure!(group_size >= 1, "logfmt needs group_size >= 1");
            }
        }
        Ok(())
    }

    /// Whether a payload of `n` values fits the wire format: `Header.n` is
    /// `u32`, so anything beyond [`MAX_WIRE_ELEMS`] must be rejected at
    /// encode time (chunk it across messages instead).
    pub fn validate_len(&self, n: usize) -> Result<()> {
        ensure!(
            n <= MAX_WIRE_ELEMS,
            "payload of {n} elements exceeds the wire header's u32 element count \
             (max {MAX_WIRE_ELEMS}); split it across messages"
        );
        Ok(())
    }

    /// The parseable spec token for this codec — the inverse of
    /// [`Codec::parse`]: `Codec::parse(&c.spec()).unwrap() == c`. Used by
    /// the plan layer to display and round-trip per-stage codecs.
    pub fn spec(&self) -> String {
        let bang = |m: ScaleMode| if m == ScaleMode::IntLog { "!" } else { "" };
        match *self {
            Codec::Bf16 => "bf16".into(),
            Codec::Rtn { bits, group_size, scale_mode } => {
                format!("int{bits}@{group_size}{}", bang(scale_mode))
            }
            Codec::Spike { bits, group_size, scale_mode } => {
                format!("int{bits}-sr@{group_size}{}", bang(scale_mode))
            }
            Codec::Hadamard { bits, group_size } => format!("int{bits}-had@{group_size}"),
            Codec::LogFmt { bits, group_size } => format!("int{bits}-log@{group_size}"),
        }
    }

    /// Wire bytes per value relative to BF16 in the large-payload limit
    /// (the per-message header amortized away). This is the
    /// "aggressiveness" total order the plan compiler uses: codec A is at
    /// least as aggressive as B iff `A.asymptotic_wire_ratio() <=
    /// B.asymptotic_wire_ratio()`.
    pub fn asymptotic_wire_ratio(&self) -> f64 {
        const N: usize = 1 << 20;
        (self.wire_len(N) - HEADER_LEN) as f64 / (2.0 * N as f64)
    }

    /// Paper-style display name (`INT2_SR`, `INT5`, `BF16`, …).
    pub fn name(&self) -> String {
        match *self {
            Codec::Bf16 => "BF16".into(),
            Codec::Rtn { bits, .. } => format!("INT{bits}"),
            Codec::Spike { bits, .. } => format!("INT{bits}_SR"),
            Codec::Hadamard { bits, .. } => format!("INT{bits}_HAD"),
            Codec::LogFmt { bits, .. } => format!("INT{bits}_LOG"),
        }
    }

    pub fn bits(&self) -> u8 {
        match *self {
            Codec::Bf16 => 16,
            Codec::Rtn { bits, .. }
            | Codec::Spike { bits, .. }
            | Codec::Hadamard { bits, .. }
            | Codec::LogFmt { bits, .. } => bits,
        }
    }

    pub fn group_size(&self) -> usize {
        match *self {
            Codec::Bf16 => 0,
            Codec::Rtn { group_size, .. }
            | Codec::Spike { group_size, .. }
            | Codec::Hadamard { group_size, .. }
            | Codec::LogFmt { group_size, .. } => group_size as usize,
        }
    }

    pub(crate) fn header(&self, n: usize) -> Header {
        let mode = |m: ScaleMode| if m == ScaleMode::IntLog { 1u8 } else { 0 };
        let (scheme, bits, scale_mode, group_size) = match *self {
            Codec::Bf16 => (WireScheme::Bf16, 16, 0, 0),
            Codec::Rtn { bits, group_size, scale_mode } => {
                (WireScheme::Rtn, bits, mode(scale_mode), group_size)
            }
            Codec::Spike { bits, group_size, scale_mode } => {
                (WireScheme::SpikeReserve, bits, mode(scale_mode), group_size)
            }
            Codec::Hadamard { bits, group_size } => (WireScheme::Hadamard, bits, 0, group_size),
            Codec::LogFmt { bits, group_size } => (WireScheme::LogFmt, bits, 0, group_size),
        };
        Header { scheme, bits, scale_mode, group_size, n: n as u32 }
    }

    /// Section byte sizes for a payload of `n` values (Table 4).
    pub fn sections(&self, n: usize) -> SectionSizes {
        let header = HEADER_LEN;
        match *self {
            Codec::Bf16 => {
                SectionSizes { header, quantized: 2 * n, scale_zero: 0, spikes: 0 }
            }
            Codec::Rtn { bits, group_size, scale_mode }
            | Codec::Spike { bits, group_size, scale_mode } => {
                let g = rtn::num_groups(n, group_size as usize);
                let mode = if scale_mode == ScaleMode::IntLog { 1 } else { 0 };
                let spikes = if matches!(self, Codec::Spike { .. }) {
                    g * wire::spike_bytes_per_group(mode)
                } else {
                    0
                };
                SectionSizes {
                    header,
                    quantized: bitsplit::packed_len(bits, n),
                    scale_zero: g * wire::scale_zero_bytes_per_group(mode),
                    spikes,
                }
            }
            Codec::Hadamard { bits, group_size } => {
                let g = rtn::num_groups(n, group_size as usize);
                SectionSizes {
                    header,
                    quantized: bitsplit::packed_len(bits, n),
                    scale_zero: g * wire::scale_zero_bytes_per_group(0),
                    spikes: 0,
                }
            }
            Codec::LogFmt { bits, group_size } => {
                let g = rtn::num_groups(n, group_size as usize);
                SectionSizes {
                    header,
                    quantized: bitsplit::packed_len(bits, n),
                    scale_zero: g * 4, // emin/emax bf16
                    spikes: 0,
                }
            }
        }
    }

    /// Total wire bytes for `n` values.
    pub fn wire_len(&self, n: usize) -> usize {
        self.sections(n).total()
    }

    /// Wire volume as a fraction of the BF16 baseline (2 bytes/value).
    pub fn compression_ratio(&self, n: usize) -> f64 {
        self.wire_len(n) as f64 / (2.0 * n as f64)
    }

    /// Encode `data` into `out` (appended), reusing `bufs` for scratch.
    ///
    /// §Perf: quantization and bit-split packing are fused — one pass over
    /// `data` scatters code bits straight into the plane regions of `out`,
    /// with no intermediate byte-per-value codes buffer (see
    /// `quant::fused`). Errors when the payload exceeds [`MAX_WIRE_ELEMS`]
    /// (the header's `u32` count would truncate — see
    /// [`Codec::validate_len`]); panics on a structurally invalid codec
    /// (see [`Codec::validate`]) — parsed codecs are always valid.
    pub fn encode_with(
        &self,
        data: &[f32],
        bufs: &mut CodecBuffers,
        out: &mut Vec<u8>,
    ) -> Result<()> {
        self.encode_with_threads(data, bufs, out, 1)
    }

    /// [`encode_with`](Codec::encode_with), chunked over up to `threads`
    /// scoped worker threads for large payloads. The wire bytes are
    /// identical for every thread count (chunks are cut at
    /// `lcm(group_size, 8)` element boundaries, so plane bytes and group
    /// metadata never straddle workers).
    pub fn encode_with_threads(
        &self,
        data: &[f32],
        bufs: &mut CodecBuffers,
        out: &mut Vec<u8>,
        threads: usize,
    ) -> Result<()> {
        self.validate()
            // lint: allow(panic, "encoding with an invalid codec would corrupt the wire; die loudly")
            .unwrap_or_else(|e| panic!("refusing to encode with invalid codec {self:?}: {e}"));
        let n = data.len();
        self.validate_len(n)?;
        let start = out.len();
        self.header(n).write(out);
        match *self {
            Codec::Bf16 => bf16::encode_slice(data, out),
            _ => fused::encode_body(self, data, bufs, out, threads),
        }
        debug_assert_eq!(out.len() - start, self.wire_len(n), "wire_len mismatch for {self:?}");
        Ok(())
    }

    /// Convenience: encode into a fresh Vec. Panics on a payload beyond
    /// [`MAX_WIRE_ELEMS`] — test/tool sugar; the collective layer uses the
    /// fallible [`Codec::encode_with_threads`].
    pub fn encode(&self, data: &[f32]) -> Vec<u8> {
        let mut bufs = CodecBuffers::default();
        let mut out = Vec::with_capacity(self.wire_len(data.len()));
        // lint: allow(panic, "validate_len passed in encode_with; the header always fits")
        self.encode_with(data, &mut bufs, &mut out).expect("payload fits the wire header");
        out
    }

    /// Decode a payload into `out` (length must equal the payload's `n`).
    ///
    /// §Perf: fused — a SWAR plane gather streams codes straight into the
    /// per-group dequantizer; no codes buffer is materialized.
    pub fn decode_with(wire_bytes: &[u8], bufs: &mut CodecBuffers, out: &mut [f32]) -> Result<()> {
        Self::decode_with_threads(wire_bytes, bufs, out, 1)
    }

    /// [`decode_with`](Codec::decode_with), chunked over up to `threads`
    /// scoped worker threads for large payloads.
    pub fn decode_with_threads(
        wire_bytes: &[u8],
        bufs: &mut CodecBuffers,
        out: &mut [f32],
        threads: usize,
    ) -> Result<()> {
        let h = Header::parse(wire_bytes)?;
        let n = h.n as usize;
        ensure!(out.len() == n, "decode output length {} != payload n {}", out.len(), n);
        let codec = codec_from_header(&h)?;
        ensure!(
            wire_bytes.len() == codec.wire_len(n),
            "payload length {} != expected {}",
            wire_bytes.len(),
            codec.wire_len(n)
        );
        let body = &wire_bytes[HEADER_LEN..];
        match codec {
            Codec::Bf16 => {
                bf16::decode_slice(body, out);
                Ok(())
            }
            _ => fused::decode_body(&codec, n, body, bufs, out, threads, false),
        }
    }

    /// Convenience decode.
    pub fn decode(wire_bytes: &[u8], out: &mut [f32]) -> Result<()> {
        let mut bufs = CodecBuffers::default();
        Self::decode_with(wire_bytes, &mut bufs, out)
    }

    /// Decode and accumulate into `acc` (the reduce step of a collective).
    ///
    /// §Perf: fused for *every* scheme — plane gather feeding straight into
    /// dequantize-accumulate per group, so the reduce step of a collective
    /// is allocation- and scratch-free (Hadamard uses one group-sized
    /// rotation buffer owned by `bufs`; nothing scales with the payload).
    /// On error the accumulator is left untouched.
    pub fn decode_sum_with(
        wire_bytes: &[u8],
        bufs: &mut CodecBuffers,
        acc: &mut [f32],
    ) -> Result<()> {
        Self::decode_sum_with_threads(wire_bytes, bufs, acc, 1)
    }

    /// [`decode_sum_with`](Codec::decode_sum_with), chunked over up to
    /// `threads` scoped worker threads for large payloads.
    pub fn decode_sum_with_threads(
        wire_bytes: &[u8],
        bufs: &mut CodecBuffers,
        acc: &mut [f32],
        threads: usize,
    ) -> Result<()> {
        let h = Header::parse(wire_bytes)?;
        let n = h.n as usize;
        ensure!(acc.len() == n, "decode_sum output length {} != payload n {}", acc.len(), n);
        let codec = codec_from_header(&h)?;
        ensure!(
            wire_bytes.len() == codec.wire_len(n),
            "payload length {} != expected {}",
            wire_bytes.len(),
            codec.wire_len(n)
        );
        let body = &wire_bytes[HEADER_LEN..];
        match codec {
            Codec::Bf16 => {
                // Accumulate straight out of the wire bytes — same values
                // as decode-then-add, without the scratch image.
                for (i, a) in acc.iter_mut().enumerate() {
                    let raw = u16::from_le_bytes([body[2 * i], body[2 * i + 1]]);
                    *a += Bf16(raw).to_f32();
                }
                Ok(())
            }
            _ => fused::decode_body(&codec, n, body, bufs, acc, threads, true),
        }
    }

    /// Quantize-dequantize in place: what the tensor "experiences" crossing
    /// the wire. Used by accuracy experiments and the TP engine. Reuses the
    /// wire buffer owned by `bufs`, so repeated same-shape calls are
    /// allocation-free after the first.
    pub fn qdq(&self, data: &mut [f32], bufs: &mut CodecBuffers) {
        if matches!(self, Codec::Bf16) {
            for x in data.iter_mut() {
                *x = crate::util::bf16::bf16_round(*x);
            }
            return;
        }
        let mut wire = std::mem::take(&mut bufs.wire);
        wire.clear();
        wire.reserve(self.wire_len(data.len()));
        // lint: allow(panic, "validate_len passed in encode_with; the header always fits")
        self.encode_with(data, bufs, &mut wire).expect("payload fits the wire header");
        let r = Self::decode_with(&wire, bufs, data);
        bufs.wire = wire;
        // lint: allow(panic, "a payload we just encoded must decode; anything else is a codec bug")
        r.expect("own payload must decode");
    }
}

/// Reconstruct the codec described by a wire header. Applies
/// [`Codec::validate`], so a header describing a structurally impossible
/// codec (e.g. spike reserving with a group size its index encoding cannot
/// represent) is a clean error, not silent corruption downstream.
pub fn codec_from_header(h: &Header) -> Result<Codec> {
    let scale_mode = if h.scale_mode == 1 { ScaleMode::IntLog } else { ScaleMode::Bf16 };
    let codec = match h.scheme {
        WireScheme::Bf16 => Codec::Bf16,
        WireScheme::Rtn => Codec::Rtn { bits: h.bits, group_size: h.group_size, scale_mode },
        WireScheme::SpikeReserve => {
            Codec::Spike { bits: h.bits, group_size: h.group_size, scale_mode }
        }
        WireScheme::Hadamard => Codec::Hadamard { bits: h.bits, group_size: h.group_size },
        WireScheme::LogFmt => Codec::LogFmt { bits: h.bits, group_size: h.group_size },
    };
    codec.validate()?;
    Ok(codec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{arb_tensor, cases};
    use crate::util::stats::sqnr_db;
    use crate::util::Prng;

    const ALL: &[&str] = &[
        "bf16", "int8", "int6", "int5", "int4", "int3", "int2", "int2-sr@32", "int3-sr@32",
        "int2-sr@32!", "int4-had@32", "int3-log@32", "int5@128!",
    ];

    #[test]
    fn parse_and_name() {
        assert_eq!(Codec::parse("bf16").unwrap(), Codec::Bf16);
        assert_eq!(
            Codec::parse("int5").unwrap(),
            Codec::Rtn { bits: 5, group_size: 128, scale_mode: ScaleMode::Bf16 }
        );
        assert_eq!(
            Codec::parse("int2-sr@32!").unwrap(),
            Codec::Spike { bits: 2, group_size: 32, scale_mode: ScaleMode::IntLog }
        );
        assert_eq!(Codec::parse("int2-sr@32").unwrap().name(), "INT2_SR");
        assert!(Codec::parse("int9").is_err());
        assert!(Codec::parse("float7").is_err());
    }

    #[test]
    fn spec_roundtrips_through_parse() {
        for spec in ALL {
            let c = Codec::parse(spec).unwrap();
            assert_eq!(Codec::parse(&c.spec()).unwrap(), c, "{spec} -> {}", c.spec());
        }
        assert_eq!(Codec::Bf16.spec(), "bf16");
        assert_eq!(Codec::parse("int2-sr@32!").unwrap().spec(), "int2-sr@32!");
    }

    #[test]
    fn asymptotic_ratio_orders_aggressiveness() {
        let mut prev = f64::INFINITY;
        for spec in ["bf16", "int8", "int5", "int4", "int3", "int2"] {
            let r = Codec::parse(spec).unwrap().asymptotic_wire_ratio();
            assert!(r < prev, "{spec} {r} !< {prev}");
            prev = r;
        }
        assert!((Codec::Bf16.asymptotic_wire_ratio() - 1.0).abs() < 1e-9);
        // The compiler's canonical mixed pair: int2-sr@32! at least as
        // aggressive as int4@32.
        let sr = Codec::parse("int2-sr@32!").unwrap().asymptotic_wire_ratio();
        let i4 = Codec::parse("int4@32").unwrap().asymptotic_wire_ratio();
        assert!(sr < i4, "{sr} vs {i4}");
    }

    #[test]
    fn wire_len_matches_encode_for_all_schemes() {
        let mut rng = Prng::new(51);
        for spec in ALL {
            let c = Codec::parse(spec).unwrap();
            for n in [1usize, 31, 32, 100, 4096] {
                let mut data = vec![0f32; n];
                rng.fill_activations(&mut data, 1.0);
                let wire = c.encode(&data);
                assert_eq!(wire.len(), c.wire_len(n), "{spec} n={n}");
            }
        }
    }

    #[test]
    fn roundtrip_all_schemes_bounded_error() {
        cases(500, 60, |rng| {
            let data = arb_tensor(rng, 700);
            for spec in ALL {
                let c = Codec::parse(spec).unwrap();
                let wire = c.encode(&data);
                let mut out = vec![0f32; data.len()];
                Codec::decode(&wire, &mut out).unwrap();
                // Universal sanity: outputs finite, and BF16 mode is tight.
                assert!(out.iter().all(|x| x.is_finite()), "{spec}");
                if *spec == "bf16" {
                    for (a, b) in data.iter().zip(&out) {
                        assert!((a - b).abs() <= a.abs() / 256.0 + 1e-30);
                    }
                }
            }
        });
    }

    #[test]
    fn table4_int2_sr_totals() {
        // 4096 BF16 values = 8192 bytes raw. Paper Table 4: 2560 (bf16 meta)
        // and 2048 (integer meta), excluding our 16-byte header.
        let bf = Codec::parse("int2-sr@32").unwrap().sections(4096);
        assert_eq!(bf.quantized, 1024);
        assert_eq!(bf.scale_zero, 512);
        assert_eq!(bf.spikes, 1024);
        assert_eq!(bf.total() - HEADER_LEN, 2560);
        let il = Codec::parse("int2-sr@32!").unwrap().sections(4096);
        assert_eq!(il.scale_zero, 256);
        assert_eq!(il.spikes, 768);
        assert_eq!(il.total() - HEADER_LEN, 2048);
    }

    #[test]
    fn compression_ratio_ordering() {
        let n = 1 << 20;
        let mut prev = f64::INFINITY;
        for spec in ["bf16", "int8", "int6", "int5", "int4", "int3", "int2"] {
            let r = Codec::parse(spec).unwrap().compression_ratio(n);
            assert!(r < prev, "{spec} ratio {r} !< {prev}");
            prev = r;
        }
        // INT5 reduces >30% versus INT8 wire (paper's motivation).
        let r8 = Codec::parse("int8").unwrap().wire_len(n) as f64;
        let r5 = Codec::parse("int5").unwrap().wire_len(n) as f64;
        assert!(r5 / r8 < 0.70, "INT5/INT8 = {}", r5 / r8);
    }

    #[test]
    fn decode_sum_accumulates() {
        let mut rng = Prng::new(52);
        let mut a = vec![0f32; 512];
        let mut b = vec![0f32; 512];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        let c = Codec::parse("int8").unwrap();
        let (wa, wb) = (c.encode(&a), c.encode(&b));
        let mut bufs = CodecBuffers::default();
        let mut acc = vec![0f32; 512];
        Codec::decode_sum_with(&wa, &mut bufs, &mut acc).unwrap();
        Codec::decode_sum_with(&wb, &mut bufs, &mut acc).unwrap();
        for i in 0..512 {
            assert!((acc[i] - (a[i] + b[i])).abs() < 0.1, "i={i}");
        }
    }

    #[test]
    fn qdq_fidelity_ordering_on_activations() {
        // SQNR must degrade monotonically with bits, and SR at INT2 must
        // beat RTN at INT2 (the paper's central accuracy claim).
        let mut rng = Prng::new(53);
        let mut data = vec![0f32; 1 << 15];
        rng.fill_activations(&mut data, 1.0);
        let mut bufs = CodecBuffers::default();
        let q = |spec: &str, bufs: &mut CodecBuffers| {
            let mut d = data.clone();
            Codec::parse(spec).unwrap().qdq(&mut d, bufs);
            sqnr_db(&data, &d)
        };
        let s8 = q("int8@32", &mut bufs);
        let s5 = q("int5@32", &mut bufs);
        let s4 = q("int4@32", &mut bufs);
        let s2 = q("int2@32", &mut bufs);
        let s2sr = q("int2-sr@32", &mut bufs);
        assert!(s8 > s5 && s5 > s4 && s4 > s2, "{s8} {s5} {s4} {s2}");
        assert!(s2sr > s2 + 6.0, "SR {s2sr} vs RTN {s2}");
    }

    #[test]
    fn invalid_codecs_rejected_at_parse_and_header() {
        // Spike indices travel as bf16 (exact only up to 256) or u8:
        // group_size > 256 would silently corrupt spike positions.
        assert!(Codec::parse("int2-sr@300").is_err());
        assert!(Codec::parse("int2-sr@257!").is_err());
        assert!(Codec::parse("int2-sr@1").is_err());
        assert!(Codec::parse("int2-sr@256").is_ok(), "256 is exactly representable");
        assert!(Codec::parse("int4-had@24").is_err(), "FWHT needs a power-of-two group");
        assert!(Codec::parse("int1-log").is_err(), "logfmt needs a sign + magnitude bit");
        // A hostile header describing an impossible codec is a clean error.
        let h = Header {
            scheme: WireScheme::SpikeReserve,
            bits: 2,
            scale_mode: 0,
            group_size: 300,
            n: 600,
        };
        assert!(codec_from_header(&h).is_err());
    }

    #[test]
    #[should_panic(expected = "refusing to encode")]
    fn encode_rejects_oversized_spike_groups() {
        let c = Codec::Spike { bits: 2, group_size: 512, scale_mode: ScaleMode::Bf16 };
        let mut bufs = CodecBuffers::default();
        let mut out = Vec::new();
        let data = vec![0f32; 512];
        let _ = c.encode_with(&data, &mut bufs, &mut out);
    }

    #[test]
    fn oversized_payloads_rejected_at_encode_time() {
        // Header.n is u32: one element past MAX_WIRE_ELEMS must be a clean
        // error (a truncated count would desync the decoder), checked
        // without materializing a 16 GiB buffer.
        for spec in ["bf16", "int8", "int4@32", "int2-sr@32!"] {
            let c = Codec::parse(spec).unwrap();
            assert!(c.validate_len(MAX_WIRE_ELEMS).is_ok(), "{spec}: boundary is legal");
            let err = c.validate_len(MAX_WIRE_ELEMS + 1).unwrap_err();
            assert!(err.to_string().contains("u32"), "{spec}: {err}");
        }
    }

    #[test]
    fn hostile_header_at_the_u32_boundary_is_a_clean_error() {
        // A wire header *claiming* u32::MAX elements (the value a 2^32+k
        // payload would silently truncate to is also reachable by
        // corruption) must fail decode cleanly — length cross-check, no
        // allocation of the claimed size, accumulator untouched.
        let c = Codec::parse("int4@32").unwrap();
        let data = vec![1.0f32; 64];
        let mut wire = c.encode(&data);
        wire[8..12].copy_from_slice(&u32::MAX.to_le_bytes()); // Header.n
        let mut out = vec![0f32; 64];
        assert!(Codec::decode(&wire, &mut out).is_err());
        let mut bufs = CodecBuffers::default();
        let mut acc = vec![1.0f32; 64];
        assert!(Codec::decode_sum_with(&wire, &mut bufs, &mut acc).is_err());
        assert!(acc.iter().all(|&x| x == 1.0), "accumulator must be untouched");
    }

    #[test]
    fn decode_sum_needs_no_payload_sized_scratch() {
        // Acceptance pin: the INT2_SR reduce step is fused — its scratch is
        // per-group metadata only, never a payload-sized codes/f32 buffer.
        let n = 8192;
        let c = Codec::parse("int2-sr@32").unwrap();
        let mut rng = Prng::new(54);
        let mut data = vec![0f32; n];
        rng.fill_activations(&mut data, 1.0);
        let wire = c.encode(&data);
        let mut bufs = CodecBuffers::default();
        let mut acc = vec![0f32; n];
        Codec::decode_sum_with(&wire, &mut bufs, &mut acc).unwrap();
        let cap = bufs.capacity_bytes();
        assert!(cap > 0, "group metadata must be retained");
        assert!(cap < n, "scratch {cap} B must stay far below the {n}-element payload");
        Codec::decode_sum_with(&wire, &mut bufs, &mut acc).unwrap();
        assert_eq!(bufs.capacity_bytes(), cap, "repeat calls must not grow scratch");
    }

    #[test]
    fn qdq_reuses_wire_buffer() {
        let c = Codec::parse("int4@32").unwrap();
        let mut bufs = CodecBuffers::default();
        let mut rng = Prng::new(55);
        let mut data = vec![0f32; 1024];
        rng.fill_activations(&mut data, 1.0);
        c.qdq(&mut data, &mut bufs);
        let warm = bufs.capacity_bytes();
        assert!(warm >= c.wire_len(1024), "the QDQ wire image must be retained for reuse");
        for _ in 0..3 {
            c.qdq(&mut data, &mut bufs);
            assert_eq!(bufs.capacity_bytes(), warm, "warm QDQ must be allocation-free");
        }
    }

    #[test]
    fn decoder_rejects_truncated_payloads() {
        let c = Codec::parse("int4@32").unwrap();
        let data = vec![1.0f32; 64];
        let wire = c.encode(&data);
        let mut out = vec![0f32; 64];
        for cut in [0usize, 5, HEADER_LEN, wire.len() - 1] {
            assert!(Codec::decode(&wire[..cut], &mut out).is_err(), "cut={cut}");
        }
        assert!(Codec::decode(&wire, &mut vec![0f32; 63]).is_err(), "wrong n");
    }
}
