//! The unified codec: one type that every collective and engine component
//! uses to turn activations/gradients into wire payloads and back.
//!
//! A [`Codec`] pairs a quantization scheme (BF16 passthrough, RTN, spike
//! reserving, Hadamard, LogFMT) with its parameters (bits, group size,
//! metadata mode) and produces self-describing payloads in the
//! [`wire`](super::wire) format. Decoding dispatches on the wire header, so
//! a rank can decode any payload the fabric delivers.

use anyhow::{bail, ensure, Result};

use super::bitsplit;
use super::hadamard;
use super::logfmt::{self, LogMeta};
use super::rtn::{self, GroupMeta};
use super::spike::{self, ScaleMode, SpikeMeta};
use super::wire::{self, Header, SectionSizes, WireScheme, HEADER_LEN};
use crate::util::bf16::{self, Bf16};

/// A fully parameterized quantization scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Codec {
    /// No quantization: BF16 on the wire (the paper's NCCL baseline volume).
    Bf16,
    /// Group-wise asymmetric round-to-nearest.
    Rtn { bits: u8, group_size: u16, scale_mode: ScaleMode },
    /// RTN over the spike-shrunken range, spikes reserved exactly.
    Spike { bits: u8, group_size: u16, scale_mode: ScaleMode },
    /// Hadamard-rotated RTN baseline.
    Hadamard { bits: u8, group_size: u16 },
    /// Log-domain quantization baseline.
    LogFmt { bits: u8, group_size: u16 },
}

/// Reusable scratch to keep the hot path allocation-free.
#[derive(Default)]
pub struct CodecBuffers {
    codes: Vec<u8>,
    metas: Vec<GroupMeta>,
    spikes: Vec<SpikeMeta>,
    logmetas: Vec<LogMeta>,
    scratch: Vec<f32>,
}

impl CodecBuffers {
    /// Bytes of owned capacity across all scratch buffers. Used by the
    /// collective layer to assert the hot path reuses (rather than regrows)
    /// its scratch after warmup.
    pub fn capacity_bytes(&self) -> usize {
        self.codes.capacity()
            + self.metas.capacity() * std::mem::size_of::<GroupMeta>()
            + self.spikes.capacity() * std::mem::size_of::<SpikeMeta>()
            + self.logmetas.capacity() * std::mem::size_of::<LogMeta>()
            + self.scratch.capacity() * 4
    }
}

impl Codec {
    /// Parse shorthand like `bf16`, `int8`, `int5`, `int2-sr`, `int4-had`,
    /// `int3-log`, with optional `@gs` suffix (`int2-sr@32`) and `!` for
    /// integer metadata (`int2-sr@32!`).
    pub fn parse(s: &str) -> Result<Codec> {
        let s = s.trim().to_ascii_lowercase();
        if s == "bf16" || s == "fp16" {
            return Ok(Codec::Bf16);
        }
        let (body, gs) = match s.split_once('@') {
            Some((b, g)) => (b.to_string(), g.to_string()),
            None => (s.clone(), String::new()),
        };
        let intlog = gs.ends_with('!') || body.ends_with('!');
        let gs = gs.trim_end_matches('!');
        let body = body.trim_end_matches('!');
        let (bits_part, kind) = match body.split_once('-') {
            Some((b, k)) => (b, k),
            None => (body, "rtn"),
        };
        ensure!(bits_part.starts_with("int"), "unrecognized codec '{s}'");
        let bits: u8 = bits_part[3..].parse()?;
        ensure!((1..=8).contains(&bits), "bits out of range in '{s}'");
        let default_gs: u16 = if bits <= 4 { 32 } else { 128 };
        let group_size: u16 = if gs.is_empty() { default_gs } else { gs.parse()? };
        let scale_mode = if intlog { ScaleMode::IntLog } else { ScaleMode::Bf16 };
        Ok(match kind {
            "rtn" => Codec::Rtn { bits, group_size, scale_mode },
            "sr" => Codec::Spike { bits, group_size, scale_mode },
            "had" => Codec::Hadamard { bits, group_size },
            "log" => Codec::LogFmt { bits, group_size },
            other => bail!("unknown scheme '{other}' in '{s}'"),
        })
    }

    /// Paper-style display name (`INT2_SR`, `INT5`, `BF16`, …).
    pub fn name(&self) -> String {
        match *self {
            Codec::Bf16 => "BF16".into(),
            Codec::Rtn { bits, .. } => format!("INT{bits}"),
            Codec::Spike { bits, .. } => format!("INT{bits}_SR"),
            Codec::Hadamard { bits, .. } => format!("INT{bits}_HAD"),
            Codec::LogFmt { bits, .. } => format!("INT{bits}_LOG"),
        }
    }

    pub fn bits(&self) -> u8 {
        match *self {
            Codec::Bf16 => 16,
            Codec::Rtn { bits, .. }
            | Codec::Spike { bits, .. }
            | Codec::Hadamard { bits, .. }
            | Codec::LogFmt { bits, .. } => bits,
        }
    }

    pub fn group_size(&self) -> usize {
        match *self {
            Codec::Bf16 => 0,
            Codec::Rtn { group_size, .. }
            | Codec::Spike { group_size, .. }
            | Codec::Hadamard { group_size, .. }
            | Codec::LogFmt { group_size, .. } => group_size as usize,
        }
    }

    fn header(&self, n: usize) -> Header {
        let mode = |m: ScaleMode| if m == ScaleMode::IntLog { 1u8 } else { 0 };
        let (scheme, bits, scale_mode, group_size) = match *self {
            Codec::Bf16 => (WireScheme::Bf16, 16, 0, 0),
            Codec::Rtn { bits, group_size, scale_mode } => {
                (WireScheme::Rtn, bits, mode(scale_mode), group_size)
            }
            Codec::Spike { bits, group_size, scale_mode } => {
                (WireScheme::SpikeReserve, bits, mode(scale_mode), group_size)
            }
            Codec::Hadamard { bits, group_size } => (WireScheme::Hadamard, bits, 0, group_size),
            Codec::LogFmt { bits, group_size } => (WireScheme::LogFmt, bits, 0, group_size),
        };
        Header { scheme, bits, scale_mode, group_size, n: n as u32 }
    }

    /// Section byte sizes for a payload of `n` values (Table 4).
    pub fn sections(&self, n: usize) -> SectionSizes {
        let header = HEADER_LEN;
        match *self {
            Codec::Bf16 => {
                SectionSizes { header, quantized: 2 * n, scale_zero: 0, spikes: 0 }
            }
            Codec::Rtn { bits, group_size, scale_mode }
            | Codec::Spike { bits, group_size, scale_mode } => {
                let g = rtn::num_groups(n, group_size as usize);
                let mode = if scale_mode == ScaleMode::IntLog { 1 } else { 0 };
                let spikes = if matches!(self, Codec::Spike { .. }) {
                    g * wire::spike_bytes_per_group(mode)
                } else {
                    0
                };
                SectionSizes {
                    header,
                    quantized: bitsplit::packed_len(bits, n),
                    scale_zero: g * wire::scale_zero_bytes_per_group(mode),
                    spikes,
                }
            }
            Codec::Hadamard { bits, group_size } => {
                let g = rtn::num_groups(n, group_size as usize);
                SectionSizes {
                    header,
                    quantized: bitsplit::packed_len(bits, n),
                    scale_zero: g * wire::scale_zero_bytes_per_group(0),
                    spikes: 0,
                }
            }
            Codec::LogFmt { bits, group_size } => {
                let g = rtn::num_groups(n, group_size as usize);
                SectionSizes {
                    header,
                    quantized: bitsplit::packed_len(bits, n),
                    scale_zero: g * 4, // emin/emax bf16
                    spikes: 0,
                }
            }
        }
    }

    /// Total wire bytes for `n` values.
    pub fn wire_len(&self, n: usize) -> usize {
        self.sections(n).total()
    }

    /// Wire volume as a fraction of the BF16 baseline (2 bytes/value).
    pub fn compression_ratio(&self, n: usize) -> f64 {
        self.wire_len(n) as f64 / (2.0 * n as f64)
    }

    /// Encode `data` into `out` (appended), reusing `bufs` for scratch.
    pub fn encode_with(&self, data: &[f32], bufs: &mut CodecBuffers, out: &mut Vec<u8>) {
        let n = data.len();
        let start = out.len();
        self.header(n).write(out);
        match *self {
            Codec::Bf16 => bf16::encode_slice(data, out),
            Codec::Rtn { bits, group_size, scale_mode } => {
                quantize_rtn_mode(data, bits, group_size as usize, scale_mode, bufs);
                bitsplit::pack(&bufs.codes, bits, out);
                write_group_metas(&bufs.metas, scale_mode, out);
            }
            Codec::Spike { bits, group_size, scale_mode } => {
                spike::quantize(
                    data,
                    bits,
                    group_size as usize,
                    scale_mode,
                    &mut bufs.codes,
                    &mut bufs.metas,
                    &mut bufs.spikes,
                );
                bitsplit::pack(&bufs.codes, bits, out);
                write_group_metas(&bufs.metas, scale_mode, out);
                write_spikes(&bufs.spikes, scale_mode, out);
            }
            Codec::Hadamard { bits, group_size } => {
                hadamard::quantize(data, bits, group_size as usize, &mut bufs.codes, &mut bufs.metas);
                bitsplit::pack(&bufs.codes, bits, out);
                write_group_metas(&bufs.metas, ScaleMode::Bf16, out);
            }
            Codec::LogFmt { bits, group_size } => {
                logfmt::quantize(data, bits, group_size as usize, &mut bufs.codes, &mut bufs.logmetas);
                bitsplit::pack(&bufs.codes, bits, out);
                for m in &bufs.logmetas {
                    out.extend_from_slice(&Bf16::from_f32(m.emin).0.to_le_bytes());
                }
                for m in &bufs.logmetas {
                    out.extend_from_slice(&Bf16::from_f32(m.emax).0.to_le_bytes());
                }
            }
        }
        debug_assert_eq!(out.len() - start, self.wire_len(n), "wire_len mismatch for {self:?}");
    }

    /// Convenience: encode into a fresh Vec.
    pub fn encode(&self, data: &[f32]) -> Vec<u8> {
        let mut bufs = CodecBuffers::default();
        let mut out = Vec::with_capacity(self.wire_len(data.len()));
        self.encode_with(data, &mut bufs, &mut out);
        out
    }

    /// Decode a payload into `out` (length must equal the payload's `n`).
    pub fn decode_with(wire_bytes: &[u8], bufs: &mut CodecBuffers, out: &mut [f32]) -> Result<()> {
        let h = Header::parse(wire_bytes)?;
        let n = h.n as usize;
        ensure!(out.len() == n, "decode output length {} != payload n {}", out.len(), n);
        let codec = codec_from_header(&h)?;
        ensure!(
            wire_bytes.len() == codec.wire_len(n),
            "payload length {} != expected {}",
            wire_bytes.len(),
            codec.wire_len(n)
        );
        let body = &wire_bytes[HEADER_LEN..];
        match codec {
            Codec::Bf16 => bf16::decode_slice(body, out),
            Codec::Rtn { bits, group_size, scale_mode } => {
                let gs = group_size as usize;
                let g = rtn::num_groups(n, gs);
                let qlen = bitsplit::packed_len(bits, n);
                bitsplit::unpack(&body[..qlen], bits, n, &mut bufs.codes);
                read_group_metas(&body[qlen..], g, scale_mode, &mut bufs.metas)?;
                rtn::dequantize(&bufs.codes, &bufs.metas, gs, out);
            }
            Codec::Spike { bits, group_size, scale_mode } => {
                let gs = group_size as usize;
                let g = rtn::num_groups(n, gs);
                let qlen = bitsplit::packed_len(bits, n);
                bitsplit::unpack(&body[..qlen], bits, n, &mut bufs.codes);
                let mode = if scale_mode == ScaleMode::IntLog { 1 } else { 0 };
                let sz = g * wire::scale_zero_bytes_per_group(mode);
                read_group_metas(&body[qlen..qlen + sz], g, scale_mode, &mut bufs.metas)?;
                read_spikes(&body[qlen + sz..], g, scale_mode, &mut bufs.spikes)?;
                spike::dequantize(&bufs.codes, &bufs.metas, &bufs.spikes, gs, out);
            }
            Codec::Hadamard { bits, group_size } => {
                let gs = group_size as usize;
                let g = rtn::num_groups(n, gs);
                let qlen = bitsplit::packed_len(bits, n);
                bitsplit::unpack(&body[..qlen], bits, n, &mut bufs.codes);
                read_group_metas(&body[qlen..], g, ScaleMode::Bf16, &mut bufs.metas)?;
                hadamard::dequantize(&bufs.codes, &bufs.metas, gs, out);
            }
            Codec::LogFmt { bits, group_size } => {
                let gs = group_size as usize;
                let g = rtn::num_groups(n, gs);
                let qlen = bitsplit::packed_len(bits, n);
                bitsplit::unpack(&body[..qlen], bits, n, &mut bufs.codes);
                let meta = &body[qlen..];
                ensure!(meta.len() == 4 * g, "logfmt meta length");
                bufs.logmetas.clear();
                for i in 0..g {
                    let emin = Bf16(u16::from_le_bytes([meta[2 * i], meta[2 * i + 1]])).to_f32();
                    let j = 2 * g + 2 * i;
                    let emax = Bf16(u16::from_le_bytes([meta[j], meta[j + 1]])).to_f32();
                    bufs.logmetas.push(LogMeta { emin, emax });
                }
                logfmt::dequantize(&bufs.codes, &bufs.logmetas, bits, gs, out);
            }
        }
        Ok(())
    }

    /// Convenience decode.
    pub fn decode(wire_bytes: &[u8], out: &mut [f32]) -> Result<()> {
        let mut bufs = CodecBuffers::default();
        Self::decode_with(wire_bytes, &mut bufs, out)
    }

    /// Decode and accumulate into `acc` (the reduce step of a collective).
    ///
    /// §Perf: the RTN path (what the collectives move) is fused — unpack
    /// once, then dequantize-accumulate per group in a single pass, with
    /// no scratch buffer or extra memory traffic. Other schemes fall back
    /// to decode-then-add.
    pub fn decode_sum_with(
        wire_bytes: &[u8],
        bufs: &mut CodecBuffers,
        acc: &mut [f32],
    ) -> Result<()> {
        let h = Header::parse(wire_bytes)?;
        let n = h.n as usize;
        ensure!(acc.len() == n, "decode_sum output length {} != payload n {}", acc.len(), n);
        if h.scheme == WireScheme::Rtn {
            let codec = codec_from_header(&h)?;
            ensure!(
                wire_bytes.len() == codec.wire_len(n),
                "payload length {} != expected {}",
                wire_bytes.len(),
                codec.wire_len(n)
            );
            let (bits, gs, scale_mode) = match codec {
                Codec::Rtn { bits, group_size, scale_mode } => {
                    (bits, group_size as usize, scale_mode)
                }
                _ => unreachable!(),
            };
            let body = &wire_bytes[HEADER_LEN..];
            let g = rtn::num_groups(n, gs);
            let qlen = bitsplit::packed_len(bits, n);
            bitsplit::unpack(&body[..qlen], bits, n, &mut bufs.codes);
            read_group_metas(&body[qlen..], g, scale_mode, &mut bufs.metas)?;
            for ((cs, &meta), xs) in
                bufs.codes.chunks(gs).zip(bufs.metas.iter()).zip(acc.chunks_mut(gs))
            {
                rtn::dequantize_group_acc(cs, meta, xs);
            }
            return Ok(());
        }
        bufs.scratch.clear();
        bufs.scratch.resize(acc.len(), 0.0);
        let mut scratch = std::mem::take(&mut bufs.scratch);
        let r = Self::decode_with(wire_bytes, bufs, &mut scratch);
        for (a, s) in acc.iter_mut().zip(&scratch) {
            *a += *s;
        }
        bufs.scratch = scratch;
        r
    }

    /// Quantize-dequantize in place: what the tensor "experiences" crossing
    /// the wire. Used by accuracy experiments and the TP engine.
    pub fn qdq(&self, data: &mut [f32], bufs: &mut CodecBuffers) {
        if matches!(self, Codec::Bf16) {
            for x in data.iter_mut() {
                *x = crate::util::bf16::bf16_round(*x);
            }
            return;
        }
        let mut out = Vec::with_capacity(self.wire_len(data.len()));
        self.encode_with(data, bufs, &mut out);
        Self::decode_with(&out, bufs, data).expect("own payload must decode");
    }
}

/// Reconstruct the codec described by a wire header.
pub fn codec_from_header(h: &Header) -> Result<Codec> {
    let scale_mode = if h.scale_mode == 1 { ScaleMode::IntLog } else { ScaleMode::Bf16 };
    Ok(match h.scheme {
        WireScheme::Bf16 => Codec::Bf16,
        WireScheme::Rtn => Codec::Rtn { bits: h.bits, group_size: h.group_size, scale_mode },
        WireScheme::SpikeReserve => {
            Codec::Spike { bits: h.bits, group_size: h.group_size, scale_mode }
        }
        WireScheme::Hadamard => Codec::Hadamard { bits: h.bits, group_size: h.group_size },
        WireScheme::LogFmt => Codec::LogFmt { bits: h.bits, group_size: h.group_size },
    })
}

/// RTN with the metadata rounded to the requested wire mode.
fn quantize_rtn_mode(
    data: &[f32],
    bits: u8,
    gs: usize,
    mode: ScaleMode,
    bufs: &mut CodecBuffers,
) {
    match mode {
        ScaleMode::Bf16 => rtn::quantize(data, bits, gs, &mut bufs.codes, &mut bufs.metas),
        ScaleMode::IntLog => {
            bufs.codes.clear();
            bufs.codes.resize(data.len(), 0);
            bufs.metas.clear();
            for (xs, cs) in data.chunks(gs).zip(bufs.codes.chunks_mut(gs)) {
                let mut mn = f32::INFINITY;
                let mut mx = f32::NEG_INFINITY;
                for &x in xs {
                    mn = mn.min(x);
                    mx = mx.max(x);
                }
                let meta =
                    spike::meta_through_wire(rtn::meta_from_minmax(mn, mx, bits), mode);
                rtn::quantize_group_with_meta(xs, bits, meta, cs);
                bufs.metas.push(meta);
            }
        }
    }
}

/// Serialize group metas: scales contiguous, then zeros (vectorized access).
fn write_group_metas(metas: &[GroupMeta], mode: ScaleMode, out: &mut Vec<u8>) {
    match mode {
        ScaleMode::Bf16 => {
            for m in metas {
                out.extend_from_slice(&Bf16::from_f32(m.scale).0.to_le_bytes());
            }
            for m in metas {
                out.extend_from_slice(&Bf16::from_f32(m.zero).0.to_le_bytes());
            }
        }
        ScaleMode::IntLog => {
            for m in metas {
                out.push(spike::scale_to_int(m.scale) as u8);
            }
            for m in metas {
                // zero-point: zero = -zp * scale (see spike.rs docs).
                let zp = (-m.zero / m.scale).round().max(-128.0).min(127.0) as i8;
                out.push(zp as u8);
            }
        }
    }
}

fn read_group_metas(
    bytes: &[u8],
    g: usize,
    mode: ScaleMode,
    metas: &mut Vec<GroupMeta>,
) -> Result<()> {
    metas.clear();
    match mode {
        ScaleMode::Bf16 => {
            ensure!(bytes.len() >= 4 * g, "scale/zero section too short");
            for i in 0..g {
                let scale = Bf16(u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]])).to_f32();
                let j = 2 * g + 2 * i;
                let zero = Bf16(u16::from_le_bytes([bytes[j], bytes[j + 1]])).to_f32();
                metas.push(GroupMeta { scale, zero });
            }
        }
        ScaleMode::IntLog => {
            ensure!(bytes.len() >= 2 * g, "int scale/zero section too short");
            for i in 0..g {
                let scale = spike::scale_from_int(bytes[i] as i8);
                let zp = bytes[g + i] as i8;
                metas.push(GroupMeta { scale, zero: -(zp as f32) * scale });
            }
        }
    }
    Ok(())
}

/// Serialize spikes: min values, max values, then the two index arrays.
fn write_spikes(spikes: &[SpikeMeta], mode: ScaleMode, out: &mut Vec<u8>) {
    for s in spikes {
        out.extend_from_slice(&Bf16::from_f32(s.min_val).0.to_le_bytes());
    }
    for s in spikes {
        out.extend_from_slice(&Bf16::from_f32(s.max_val).0.to_le_bytes());
    }
    match mode {
        ScaleMode::Bf16 => {
            for s in spikes {
                out.extend_from_slice(&Bf16::from_f32(s.min_idx as f32).0.to_le_bytes());
            }
            for s in spikes {
                out.extend_from_slice(&Bf16::from_f32(s.max_idx as f32).0.to_le_bytes());
            }
        }
        ScaleMode::IntLog => {
            for s in spikes {
                out.push(s.min_idx as u8);
            }
            for s in spikes {
                out.push(s.max_idx as u8);
            }
        }
    }
}

fn read_spikes(bytes: &[u8], g: usize, mode: ScaleMode, spikes: &mut Vec<SpikeMeta>) -> Result<()> {
    spikes.clear();
    let need = g * wire::spike_bytes_per_group(if mode == ScaleMode::IntLog { 1 } else { 0 });
    ensure!(bytes.len() >= need, "spike section too short: {} < {need}", bytes.len());
    let rd16 = |o: usize| Bf16(u16::from_le_bytes([bytes[o], bytes[o + 1]])).to_f32();
    for i in 0..g {
        let min_val = rd16(2 * i);
        let max_val = rd16(2 * g + 2 * i);
        let (min_idx, max_idx) = match mode {
            ScaleMode::Bf16 => (rd16(4 * g + 2 * i) as u16, rd16(6 * g + 2 * i) as u16),
            ScaleMode::IntLog => (bytes[4 * g + i] as u16, bytes[5 * g + i] as u16),
        };
        spikes.push(SpikeMeta { min_val, max_val, min_idx, max_idx });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{arb_tensor, cases};
    use crate::util::stats::sqnr_db;
    use crate::util::Prng;

    const ALL: &[&str] = &[
        "bf16", "int8", "int6", "int5", "int4", "int3", "int2", "int2-sr@32", "int3-sr@32",
        "int2-sr@32!", "int4-had@32", "int3-log@32", "int5@128!",
    ];

    #[test]
    fn parse_and_name() {
        assert_eq!(Codec::parse("bf16").unwrap(), Codec::Bf16);
        assert_eq!(
            Codec::parse("int5").unwrap(),
            Codec::Rtn { bits: 5, group_size: 128, scale_mode: ScaleMode::Bf16 }
        );
        assert_eq!(
            Codec::parse("int2-sr@32!").unwrap(),
            Codec::Spike { bits: 2, group_size: 32, scale_mode: ScaleMode::IntLog }
        );
        assert_eq!(Codec::parse("int2-sr@32").unwrap().name(), "INT2_SR");
        assert!(Codec::parse("int9").is_err());
        assert!(Codec::parse("float7").is_err());
    }

    #[test]
    fn wire_len_matches_encode_for_all_schemes() {
        let mut rng = Prng::new(51);
        for spec in ALL {
            let c = Codec::parse(spec).unwrap();
            for n in [1usize, 31, 32, 100, 4096] {
                let mut data = vec![0f32; n];
                rng.fill_activations(&mut data, 1.0);
                let wire = c.encode(&data);
                assert_eq!(wire.len(), c.wire_len(n), "{spec} n={n}");
            }
        }
    }

    #[test]
    fn roundtrip_all_schemes_bounded_error() {
        cases(500, 60, |rng| {
            let data = arb_tensor(rng, 700);
            for spec in ALL {
                let c = Codec::parse(spec).unwrap();
                let wire = c.encode(&data);
                let mut out = vec![0f32; data.len()];
                Codec::decode(&wire, &mut out).unwrap();
                // Universal sanity: outputs finite, and BF16 mode is tight.
                assert!(out.iter().all(|x| x.is_finite()), "{spec}");
                if *spec == "bf16" {
                    for (a, b) in data.iter().zip(&out) {
                        assert!((a - b).abs() <= a.abs() / 256.0 + 1e-30);
                    }
                }
            }
        });
    }

    #[test]
    fn table4_int2_sr_totals() {
        // 4096 BF16 values = 8192 bytes raw. Paper Table 4: 2560 (bf16 meta)
        // and 2048 (integer meta), excluding our 16-byte header.
        let bf = Codec::parse("int2-sr@32").unwrap().sections(4096);
        assert_eq!(bf.quantized, 1024);
        assert_eq!(bf.scale_zero, 512);
        assert_eq!(bf.spikes, 1024);
        assert_eq!(bf.total() - HEADER_LEN, 2560);
        let il = Codec::parse("int2-sr@32!").unwrap().sections(4096);
        assert_eq!(il.scale_zero, 256);
        assert_eq!(il.spikes, 768);
        assert_eq!(il.total() - HEADER_LEN, 2048);
    }

    #[test]
    fn compression_ratio_ordering() {
        let n = 1 << 20;
        let mut prev = f64::INFINITY;
        for spec in ["bf16", "int8", "int6", "int5", "int4", "int3", "int2"] {
            let r = Codec::parse(spec).unwrap().compression_ratio(n);
            assert!(r < prev, "{spec} ratio {r} !< {prev}");
            prev = r;
        }
        // INT5 reduces >30% versus INT8 wire (paper's motivation).
        let r8 = Codec::parse("int8").unwrap().wire_len(n) as f64;
        let r5 = Codec::parse("int5").unwrap().wire_len(n) as f64;
        assert!(r5 / r8 < 0.70, "INT5/INT8 = {}", r5 / r8);
    }

    #[test]
    fn decode_sum_accumulates() {
        let mut rng = Prng::new(52);
        let mut a = vec![0f32; 512];
        let mut b = vec![0f32; 512];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        let c = Codec::parse("int8").unwrap();
        let (wa, wb) = (c.encode(&a), c.encode(&b));
        let mut bufs = CodecBuffers::default();
        let mut acc = vec![0f32; 512];
        Codec::decode_sum_with(&wa, &mut bufs, &mut acc).unwrap();
        Codec::decode_sum_with(&wb, &mut bufs, &mut acc).unwrap();
        for i in 0..512 {
            assert!((acc[i] - (a[i] + b[i])).abs() < 0.1, "i={i}");
        }
    }

    #[test]
    fn qdq_fidelity_ordering_on_activations() {
        // SQNR must degrade monotonically with bits, and SR at INT2 must
        // beat RTN at INT2 (the paper's central accuracy claim).
        let mut rng = Prng::new(53);
        let mut data = vec![0f32; 1 << 15];
        rng.fill_activations(&mut data, 1.0);
        let mut bufs = CodecBuffers::default();
        let q = |spec: &str, bufs: &mut CodecBuffers| {
            let mut d = data.clone();
            Codec::parse(spec).unwrap().qdq(&mut d, bufs);
            sqnr_db(&data, &d)
        };
        let s8 = q("int8@32", &mut bufs);
        let s5 = q("int5@32", &mut bufs);
        let s4 = q("int4@32", &mut bufs);
        let s2 = q("int2@32", &mut bufs);
        let s2sr = q("int2-sr@32", &mut bufs);
        assert!(s8 > s5 && s5 > s4 && s4 > s2, "{s8} {s5} {s4} {s2}");
        assert!(s2sr > s2 + 6.0, "SR {s2sr} vs RTN {s2}");
    }

    #[test]
    fn decoder_rejects_truncated_payloads() {
        let c = Codec::parse("int4@32").unwrap();
        let data = vec![1.0f32; 64];
        let wire = c.encode(&data);
        let mut out = vec![0f32; 64];
        for cut in [0usize, 5, HEADER_LEN, wire.len() - 1] {
            assert!(Codec::decode(&wire[..cut], &mut out).is_err(), "cut={cut}");
        }
        assert!(Codec::decode(&wire, &mut vec![0f32; 63]).is_err(), "wrong n");
    }
}
