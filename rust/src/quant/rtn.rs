//! Group-wise asymmetric Round-To-Nearest (RTN) quantization — the baseline
//! scheme of the paper (Tables 1, 2) and the inner primitive reused by spike
//! reserving, Hadamard and LogFMT.
//!
//! Per group of `group_size` values: `scale = (max - min) / (2^bits - 1)`,
//! `zero = min`, `q = clamp(round((x - zero) / scale), 0, 2^bits - 1)`.
//! Scale and zero travel on the wire as BF16 (the paper's metadata format),
//! so quantization is performed against the *wire-rounded* scale/zero — the
//! encoder and decoder then agree bit-exactly.

use crate::util::bf16::bf16_round;

/// Per-group dequantization metadata.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupMeta {
    /// Quantization step (wire precision).
    pub scale: f32,
    /// Asymmetric offset = group minimum (wire precision).
    pub zero: f32,
}

impl GroupMeta {
    pub const IDENTITY: GroupMeta = GroupMeta { scale: 1.0, zero: 0.0 };
}

/// Number of groups covering `n` values at `group_size` (tail group included).
#[inline]
pub fn num_groups(n: usize, group_size: usize) -> usize {
    n.div_ceil(group_size)
}

/// Largest representable code for a bit width.
#[inline(always)]
pub fn qmax(bits: u8) -> u32 {
    debug_assert!((1..=8).contains(&bits));
    (1u32 << bits) - 1
}

/// Compute wire-precision meta for one group given its (min, max).
///
/// The range is computed in f64 and clamped so extreme inputs (±f32::MAX)
/// cannot overflow the scale to infinity and poison the dequant with NaNs.
#[inline]
pub fn meta_from_minmax(min: f32, max: f32, bits: u8) -> GroupMeta {
    let range = (max as f64 - min as f64).min(f32::MAX as f64 / 2.0) as f32;
    let scale = if range > 0.0 { range / qmax(bits) as f32 } else { 1.0 };
    GroupMeta { scale: bf16_round(scale), zero: bf16_round(min) }
}

/// Quantize one group into `codes` (one code per input, values < 2^bits).
///
/// Returns the group meta. `codes` must be the same length as `xs`.
pub fn quantize_group(xs: &[f32], bits: u8, codes: &mut [u8]) -> GroupMeta {
    debug_assert_eq!(xs.len(), codes.len());
    debug_assert!(xs.iter().all(|x| x.is_finite()), "RTN requires finite inputs");
    if xs.is_empty() {
        return GroupMeta::IDENTITY;
    }
    let (min, max) = minmax(xs);
    let meta = meta_from_minmax(min, max, bits);
    quantize_group_with_meta(xs, bits, meta, codes);
    meta
}

/// Quantize against an externally chosen meta (used by spike reserving,
/// which shrinks the range before calling this).
///
/// Hot path (§Perf): rust's saturating float→int cast replaces the
/// floor/max/min chain — one fma-able multiply-add, one min, one cast.
#[inline]
pub fn quantize_group_with_meta(xs: &[f32], bits: u8, meta: GroupMeta, codes: &mut [u8]) {
    let inv = 1.0 / meta.scale;
    let qm = qmax(bits) as f32;
    for (c, &x) in codes.iter_mut().zip(xs) {
        // `as u8` saturates (negatives -> 0), and truncation == floor for
        // the non-negative in-range values; min() caps the top.
        *c = ((x - meta.zero) * inv + 0.5).min(qm) as u8;
    }
}

/// Min/max of a slice without NaN-handling branches (auto-vectorizable).
#[inline]
pub(crate) fn minmax(xs: &[f32]) -> (f32, f32) {
    let mut mn = f32::INFINITY;
    let mut mx = f32::NEG_INFINITY;
    for &x in xs {
        mn = if x < mn { x } else { mn };
        mx = if x > mx { x } else { mx };
    }
    (mn, mx)
}

/// Dequantize one group: `x̂ = q * scale + zero`.
#[inline]
pub fn dequantize_group(codes: &[u8], meta: GroupMeta, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (x, &c) in out.iter_mut().zip(codes) {
        *x = c as f32 * meta.scale + meta.zero;
    }
}

/// Dequantize-and-accumulate (the reduce step of a quantized collective).
#[inline]
pub fn dequantize_group_acc(codes: &[u8], meta: GroupMeta, acc: &mut [f32]) {
    debug_assert_eq!(codes.len(), acc.len());
    for (x, &c) in acc.iter_mut().zip(codes) {
        *x += c as f32 * meta.scale + meta.zero;
    }
}

/// Quantize a full tensor group-by-group.
///
/// `codes` is resized to `data.len()`; `metas` to the group count.
pub fn quantize(
    data: &[f32],
    bits: u8,
    group_size: usize,
    codes: &mut Vec<u8>,
    metas: &mut Vec<GroupMeta>,
) {
    assert!(group_size > 0);
    codes.clear();
    codes.resize(data.len(), 0);
    metas.clear();
    metas.reserve(num_groups(data.len(), group_size));
    for (xs, cs) in data.chunks(group_size).zip(codes.chunks_mut(group_size)) {
        metas.push(quantize_group(xs, bits, cs));
    }
}

/// Dequantize a full tensor group-by-group into `out` (same length as codes).
pub fn dequantize(codes: &[u8], metas: &[GroupMeta], group_size: usize, out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    assert_eq!(metas.len(), num_groups(codes.len(), group_size));
    for ((cs, &meta), xs) in
        codes.chunks(group_size).zip(metas).zip(out.chunks_mut(group_size))
    {
        dequantize_group(cs, meta, xs);
    }
}

/// Worst-case absolute reconstruction error for a group quantized with
/// `meta`: half a step, plus the bf16 rounding of scale (over the range)
/// and of zero. Used by property tests.
pub fn error_bound(meta: GroupMeta, _bits: u8, min: f32, max: f32) -> f32 {
    let step = meta.scale;
    // bf16 relative error <= 2^-8 on scale (amplified by qmax) and zero.
    let bf16_eps = 1.0 / 256.0;
    0.5 * step + bf16_eps * (max - min).abs() + bf16_eps * min.abs() + 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{arb_tensor, cases};
    use crate::util::stats::sqnr_db;

    fn roundtrip(data: &[f32], bits: u8, gs: usize) -> Vec<f32> {
        let mut codes = Vec::new();
        let mut metas = Vec::new();
        quantize(data, bits, gs, &mut codes, &mut metas);
        let mut out = vec![0f32; data.len()];
        dequantize(&codes, &metas, gs, &mut out);
        out
    }

    #[test]
    fn exact_for_constant_group() {
        let data = vec![3.5f32; 64];
        let out = roundtrip(&data, 4, 32);
        for &x in &out {
            assert_eq!(x, 3.5);
        }
    }

    #[test]
    fn zeros_stay_zero() {
        let data = vec![0f32; 100];
        assert!(roundtrip(&data, 2, 32).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn int8_on_linear_ramp_is_tight() {
        let data: Vec<f32> = (0..128).map(|i| i as f32 / 127.0).collect();
        let out = roundtrip(&data, 8, 128);
        for (a, b) in data.iter().zip(&out) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
    }

    #[test]
    fn endpoints_are_representable() {
        // min and max of each group must reconstruct within bf16 meta error.
        let data = vec![-7.0f32, 1.0, 2.0, 13.0];
        let out = roundtrip(&data, 2, 4);
        assert!((out[0] + 7.0).abs() < 0.1, "min endpoint {}", out[0]);
        assert!((out[3] - 13.0).abs() < 0.1, "max endpoint {}", out[3]);
    }

    #[test]
    fn sqnr_improves_with_bits() {
        let mut rng = crate::util::Prng::new(11);
        let mut data = vec![0f32; 4096];
        rng.fill_normal(&mut data, 0.0, 1.0);
        let mut prev = -100.0;
        for bits in [2u8, 3, 4, 5, 6, 8] {
            let s = sqnr_db(&data, &roundtrip(&data, bits, 128));
            assert!(s > prev + 3.0, "bits={bits}: {s} !> {prev}+3");
            prev = s;
        }
    }

    #[test]
    fn finer_groups_help_on_heavy_tails() {
        let mut rng = crate::util::Prng::new(12);
        let mut data = vec![0f32; 8192];
        rng.fill_activations(&mut data, 1.0);
        let s128 = sqnr_db(&data, &roundtrip(&data, 3, 128));
        let s32 = sqnr_db(&data, &roundtrip(&data, 3, 32));
        assert!(s32 > s128, "gs32 {s32} should beat gs128 {s128}");
    }

    #[test]
    fn tail_group_handled() {
        let data: Vec<f32> = (0..37).map(|i| i as f32).collect();
        let out = roundtrip(&data, 8, 32);
        assert_eq!(out.len(), 37);
        for (a, b) in data.iter().zip(&out) {
            assert!((a - b).abs() < 0.3);
        }
    }

    #[test]
    fn property_error_bounded_all_bits() {
        cases(100, 128, |rng| {
            let data = arb_tensor(rng, 600);
            let bits = [2u8, 3, 4, 5, 6, 7, 8][rng.below(7)];
            let gs = [32usize, 128][rng.below(2)];
            let out = roundtrip(&data, bits, gs);
            for (g, (xs, rec)) in data.chunks(gs).zip(out.chunks(gs)).enumerate() {
                let min = xs.iter().cloned().fold(f32::INFINITY, f32::min);
                let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let meta = meta_from_minmax(min, max, bits);
                let bound = error_bound(meta, bits, min, max);
                for (a, b) in xs.iter().zip(rec) {
                    assert!(
                        (a - b).abs() <= bound,
                        "group {g} bits {bits} gs {gs}: |{a} - {b}| > {bound}"
                    );
                }
            }
        });
    }

    #[test]
    fn codes_respect_bit_width() {
        cases(101, 64, |rng| {
            let data = arb_tensor(rng, 300);
            let bits = [2u8, 3, 5, 7][rng.below(4)];
            let mut codes = Vec::new();
            let mut metas = Vec::new();
            quantize(&data, bits, 32, &mut codes, &mut metas);
            for &c in &codes {
                assert!((c as u32) <= qmax(bits));
            }
        });
    }

    #[test]
    fn dequant_acc_equals_dequant_plus_add() {
        let mut rng = crate::util::Prng::new(13);
        let mut data = vec![0f32; 256];
        rng.fill_normal(&mut data, 0.0, 2.0);
        let mut codes = Vec::new();
        let mut metas = Vec::new();
        quantize(&data, 4, 32, &mut codes, &mut metas);
        let mut plain = vec![0f32; 256];
        dequantize(&codes, &metas, 32, &mut plain);
        let mut acc = vec![1.0f32; 256];
        for (cs, &m) in codes.chunks(32).zip(&metas) {
            let off = (cs.as_ptr() as usize - codes.as_ptr() as usize) / std::mem::size_of::<u8>();
            dequantize_group_acc(cs, m, &mut acc[off..off + cs.len()]);
        }
        for i in 0..256 {
            assert!((acc[i] - (1.0 + plain[i])).abs() < 1e-6);
        }
    }
}
