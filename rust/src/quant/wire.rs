//! Self-describing wire format for quantized communication payloads
//! (Fig. 5c memory layout, Table 4 footprint accounting).
//!
//! ```text
//! ┌──────────────── header, 16 B ────────────────┐
//! │ magic u16 | ver u8 | scheme u8 | bits u8     │
//! │ scale_mode u8 | group_size u16 | n u32 | rsv │
//! ├──────────── quantized data planes ───────────┤   bit-split planes,
//! │ plane(4b) … plane(2b) … plane(1b) …          │   each byte-padded
//! ├──────────────── scales & zeros ──────────────┤   bf16×2 or i8×2 / group
//! ├──────────────── spikes (SR only) ────────────┤   {min,max,idx,idx}
//! └───────────────────────────────────────────────┘
//! ```
//!
//! Everything little-endian. The header makes payloads self-describing so a
//! receiving rank can decode without out-of-band agreement (and so tests can
//! fuzz the decoder against corrupted headers).

use anyhow::{bail, ensure, Result};

use super::logfmt::LogMeta;
use super::rtn::GroupMeta;
use super::spike::{self, ScaleMode, SpikeMeta};
use crate::util::bf16::Bf16;

pub const MAGIC: u16 = 0xFC02;
pub const VERSION: u8 = 1;
pub const HEADER_LEN: usize = 16;

/// Scheme discriminants on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum WireScheme {
    Bf16 = 0,
    Rtn = 1,
    SpikeReserve = 2,
    Hadamard = 3,
    LogFmt = 4,
}

impl WireScheme {
    pub fn from_u8(v: u8) -> Result<Self> {
        Ok(match v {
            0 => WireScheme::Bf16,
            1 => WireScheme::Rtn,
            2 => WireScheme::SpikeReserve,
            3 => WireScheme::Hadamard,
            4 => WireScheme::LogFmt,
            _ => bail!("unknown wire scheme {v}"),
        })
    }
}

/// Parsed wire header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub scheme: WireScheme,
    pub bits: u8,
    /// 0 = bf16 metadata, 1 = integer (Eq. 1) metadata.
    pub scale_mode: u8,
    pub group_size: u16,
    pub n: u32,
}

impl Header {
    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.push(VERSION);
        out.push(self.scheme as u8);
        out.push(self.bits);
        out.push(self.scale_mode);
        out.extend_from_slice(&self.group_size.to_le_bytes());
        out.extend_from_slice(&self.n.to_le_bytes());
        out.extend_from_slice(&[0u8; 4]); // reserved
        debug_assert_eq!(out.len() % HEADER_LEN, 0);
    }

    pub fn parse(wire: &[u8]) -> Result<Header> {
        if wire.len() < HEADER_LEN {
            bail!("wire too short for header: {} bytes", wire.len());
        }
        // lint: allow(panic, "length checked against HEADER_LEN above")
        let magic = u16::from_le_bytes([wire[0], wire[1]]);
        if magic != MAGIC {
            bail!("bad magic {magic:#x}");
        }
        if wire[2] != VERSION {
            bail!("unsupported version {}", wire[2]);
        }
        let h = Header {
            scheme: WireScheme::from_u8(wire[3])?,
            bits: wire[4],
            scale_mode: wire[5],
            // lint: allow(panic, "length checked against HEADER_LEN above")
            group_size: u16::from_le_bytes([wire[6], wire[7]]),
            // lint: allow(panic, "length checked against HEADER_LEN above")
            n: u32::from_le_bytes([wire[8], wire[9], wire[10], wire[11]]),
        };
        if h.scheme != WireScheme::Bf16 {
            if !(1..=8).contains(&h.bits) {
                bail!("bad bits {}", h.bits);
            }
            if h.group_size == 0 {
                bail!("zero group size");
            }
        }
        Ok(h)
    }
}

/// Per-section byte accounting for a payload (Table 4 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SectionSizes {
    pub header: usize,
    /// Bit-split quantized planes (or raw bf16 data for passthrough).
    pub quantized: usize,
    pub scale_zero: usize,
    pub spikes: usize,
}

impl SectionSizes {
    pub fn total(&self) -> usize {
        self.header + self.quantized + self.scale_zero + self.spikes
    }

    /// Metadata (everything but the quantized planes), the paper's "Meta".
    pub fn meta(&self) -> usize {
        self.scale_zero + self.spikes
    }
}

/// Scale/zero bytes per group for a metadata mode.
pub fn scale_zero_bytes_per_group(scale_mode: u8) -> usize {
    match scale_mode {
        0 => 4, // bf16 scale + bf16 zero
        _ => 2, // i8 scale_int + i8 zero-point (Eq. 1)
    }
}

/// Spike bytes per group for a metadata mode.
pub fn spike_bytes_per_group(scale_mode: u8) -> usize {
    match scale_mode {
        0 => 8, // bf16 min,max + bf16 min_idx,max_idx
        _ => 6, // bf16 min,max + u8 min_idx,max_idx
    }
}

// --- Metadata section (de)serializers ------------------------------------
//
// Shared by the fused kernels ([`super::fused`]) and the scalar reference
// codec ([`super::reference`]): the two paths differ only in how the
// quantized planes are produced, never in the metadata byte layout.

/// Serialize group metas: scales contiguous, then zeros (vectorized access).
pub(crate) fn write_group_metas(metas: &[GroupMeta], mode: ScaleMode, out: &mut Vec<u8>) {
    match mode {
        ScaleMode::Bf16 => {
            for m in metas {
                out.extend_from_slice(&Bf16::from_f32(m.scale).0.to_le_bytes());
            }
            for m in metas {
                out.extend_from_slice(&Bf16::from_f32(m.zero).0.to_le_bytes());
            }
        }
        ScaleMode::IntLog => {
            for m in metas {
                out.push(spike::scale_to_int(m.scale) as u8);
            }
            for m in metas {
                // zero-point: zero = -zp * scale (see spike.rs docs).
                let zp = (-m.zero / m.scale).round().max(-128.0).min(127.0) as i8;
                out.push(zp as u8);
            }
        }
    }
}

pub(crate) fn read_group_metas(
    bytes: &[u8],
    g: usize,
    mode: ScaleMode,
    metas: &mut Vec<GroupMeta>,
) -> Result<()> {
    metas.clear();
    match mode {
        ScaleMode::Bf16 => {
            ensure!(bytes.len() >= 4 * g, "scale/zero section too short");
            for i in 0..g {
                let scale = Bf16(u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]])).to_f32();
                let j = 2 * g + 2 * i;
                let zero = Bf16(u16::from_le_bytes([bytes[j], bytes[j + 1]])).to_f32();
                metas.push(GroupMeta { scale, zero });
            }
        }
        ScaleMode::IntLog => {
            ensure!(bytes.len() >= 2 * g, "int scale/zero section too short");
            for i in 0..g {
                let scale = spike::scale_from_int(bytes[i] as i8);
                let zp = bytes[g + i] as i8;
                metas.push(GroupMeta { scale, zero: -(zp as f32) * scale });
            }
        }
    }
    Ok(())
}

/// Serialize spikes: min values, max values, then the two index arrays.
pub(crate) fn write_spikes(spikes: &[SpikeMeta], mode: ScaleMode, out: &mut Vec<u8>) {
    for s in spikes {
        out.extend_from_slice(&Bf16::from_f32(s.min_val).0.to_le_bytes());
    }
    for s in spikes {
        out.extend_from_slice(&Bf16::from_f32(s.max_val).0.to_le_bytes());
    }
    match mode {
        ScaleMode::Bf16 => {
            for s in spikes {
                out.extend_from_slice(&Bf16::from_f32(s.min_idx as f32).0.to_le_bytes());
            }
            for s in spikes {
                out.extend_from_slice(&Bf16::from_f32(s.max_idx as f32).0.to_le_bytes());
            }
        }
        ScaleMode::IntLog => {
            for s in spikes {
                out.push(s.min_idx as u8);
            }
            for s in spikes {
                out.push(s.max_idx as u8);
            }
        }
    }
}

pub(crate) fn read_spikes(
    bytes: &[u8],
    g: usize,
    mode: ScaleMode,
    spikes: &mut Vec<SpikeMeta>,
) -> Result<()> {
    spikes.clear();
    let need = g * spike_bytes_per_group(if mode == ScaleMode::IntLog { 1 } else { 0 });
    ensure!(bytes.len() >= need, "spike section too short: {} < {need}", bytes.len());
    let rd16 = |o: usize| Bf16(u16::from_le_bytes([bytes[o], bytes[o + 1]])).to_f32();
    for i in 0..g {
        let min_val = rd16(2 * i);
        let max_val = rd16(2 * g + 2 * i);
        let (min_idx, max_idx) = match mode {
            ScaleMode::Bf16 => (rd16(4 * g + 2 * i) as u16, rd16(6 * g + 2 * i) as u16),
            ScaleMode::IntLog => (bytes[4 * g + i] as u16, bytes[5 * g + i] as u16),
        };
        spikes.push(SpikeMeta { min_val, max_val, min_idx, max_idx });
    }
    Ok(())
}

/// Serialize LogFMT metas: all emin values (bf16), then all emax values.
pub(crate) fn write_log_metas(metas: &[LogMeta], out: &mut Vec<u8>) {
    for m in metas {
        out.extend_from_slice(&Bf16::from_f32(m.emin).0.to_le_bytes());
    }
    for m in metas {
        out.extend_from_slice(&Bf16::from_f32(m.emax).0.to_le_bytes());
    }
}

pub(crate) fn read_log_metas(bytes: &[u8], g: usize, metas: &mut Vec<LogMeta>) -> Result<()> {
    ensure!(bytes.len() == 4 * g, "logfmt meta length");
    metas.clear();
    for i in 0..g {
        let emin = Bf16(u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]])).to_f32();
        let j = 2 * g + 2 * i;
        let emax = Bf16(u16::from_le_bytes([bytes[j], bytes[j + 1]])).to_f32();
        metas.push(LogMeta { emin, emax });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = Header {
            scheme: WireScheme::SpikeReserve,
            bits: 2,
            scale_mode: 1,
            group_size: 32,
            n: 4096,
        };
        let mut buf = Vec::new();
        h.write(&mut buf);
        assert_eq!(buf.len(), HEADER_LEN);
        assert_eq!(Header::parse(&buf).unwrap(), h);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Header::parse(&[]).is_err());
        assert!(Header::parse(&[0u8; 16]).is_err()); // bad magic
        let h = Header { scheme: WireScheme::Rtn, bits: 9, scale_mode: 0, group_size: 32, n: 1 };
        let mut buf = Vec::new();
        h.write(&mut buf);
        assert!(Header::parse(&buf).is_err(), "bits=9 must be rejected");
    }

    #[test]
    fn rejects_version_and_scheme_mismatch() {
        let h = Header { scheme: WireScheme::Rtn, bits: 4, scale_mode: 0, group_size: 32, n: 8 };
        let mut buf = Vec::new();
        h.write(&mut buf);
        let mut v = buf.clone();
        v[2] = 9; // version
        assert!(Header::parse(&v).is_err());
        let mut s = buf.clone();
        s[3] = 42; // scheme
        assert!(Header::parse(&s).is_err());
    }

    #[test]
    fn table4_per_group_budgets() {
        // 128 groups of 32 over 4096 values (Table 4).
        let groups = 128;
        assert_eq!(groups * scale_zero_bytes_per_group(0), 512);
        assert_eq!(groups * scale_zero_bytes_per_group(1), 256);
        assert_eq!(groups * spike_bytes_per_group(0), 1024);
        assert_eq!(groups * spike_bytes_per_group(1), 768);
    }
}
