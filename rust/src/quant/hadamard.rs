//! Hadamard-transform quantization baseline (Table 3; QuaRot-style).
//!
//! Each group is rotated by a normalized Walsh–Hadamard transform before
//! RTN quantization and rotated back after dequantization. The rotation
//! spreads outliers across the group (flattening the distribution), which
//! helps at INT4 but — as the paper observes — *hurts* at INT2 because the
//! inverse transform re-accumulates the per-element quantization errors.
//!
//! Group sizes must be powers of two (32 and 128 both are).

use super::rtn::{self, GroupMeta};

/// In-place normalized fast Walsh–Hadamard transform (orthonormal: applying
/// it twice is the identity).
pub fn fwht_normalized(xs: &mut [f32]) {
    let n = xs.len();
    assert!(n.is_power_of_two(), "FWHT needs a power-of-two length, got {n}");
    let mut h = 1;
    while h < n {
        for chunk in xs.chunks_mut(h * 2) {
            let (a, b) = chunk.split_at_mut(h);
            for (x, y) in a.iter_mut().zip(b.iter_mut()) {
                let (u, v) = (*x, *y);
                *x = u + v;
                *y = u - v;
            }
        }
        h *= 2;
    }
    let norm = 1.0 / (n as f32).sqrt();
    for x in xs.iter_mut() {
        *x *= norm;
    }
}

/// Quantize a tensor with per-group Hadamard rotation + RTN.
///
/// The tail group (if `data.len() % group_size != 0`) falls back to plain
/// RTN since it is not a power of two.
pub fn quantize(
    data: &[f32],
    bits: u8,
    group_size: usize,
    codes: &mut Vec<u8>,
    metas: &mut Vec<GroupMeta>,
) {
    assert!(group_size.is_power_of_two());
    codes.clear();
    codes.resize(data.len(), 0);
    metas.clear();
    let mut scratch = vec![0f32; group_size];
    for (xs, cs) in data.chunks(group_size).zip(codes.chunks_mut(group_size)) {
        if xs.len() == group_size {
            scratch.copy_from_slice(xs);
            fwht_normalized(&mut scratch);
            metas.push(rtn::quantize_group(&scratch, bits, cs));
        } else {
            metas.push(rtn::quantize_group(xs, bits, cs));
        }
    }
}

/// Dequantize + inverse rotation.
pub fn dequantize(codes: &[u8], metas: &[GroupMeta], group_size: usize, out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    for ((cs, &meta), xs) in codes.chunks(group_size).zip(metas).zip(out.chunks_mut(group_size)) {
        rtn::dequantize_group(cs, meta, xs);
        if xs.len() == group_size {
            fwht_normalized(xs); // orthonormal: same transform inverts
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::sqnr_db;
    use crate::util::Prng;

    fn roundtrip(data: &[f32], bits: u8, gs: usize) -> Vec<f32> {
        let (mut codes, mut metas) = (Vec::new(), Vec::new());
        quantize(data, bits, gs, &mut codes, &mut metas);
        let mut out = vec![0f32; data.len()];
        dequantize(&codes, &metas, gs, &mut out);
        out
    }

    fn rtn_roundtrip(data: &[f32], bits: u8, gs: usize) -> Vec<f32> {
        let (mut codes, mut metas) = (Vec::new(), Vec::new());
        rtn::quantize(data, bits, gs, &mut codes, &mut metas);
        let mut out = vec![0f32; data.len()];
        rtn::dequantize(&codes, &metas, gs, &mut out);
        out
    }

    #[test]
    fn fwht_is_involutive() {
        let mut rng = Prng::new(31);
        let mut xs = vec![0f32; 128];
        rng.fill_normal(&mut xs, 0.0, 3.0);
        let orig = xs.clone();
        fwht_normalized(&mut xs);
        fwht_normalized(&mut xs);
        for (a, b) in orig.iter().zip(&xs) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn fwht_preserves_energy() {
        let mut rng = Prng::new(32);
        let mut xs = vec![0f32; 32];
        rng.fill_normal(&mut xs, 1.0, 2.0);
        let e0: f32 = xs.iter().map(|x| x * x).sum();
        fwht_normalized(&mut xs);
        let e1: f32 = xs.iter().map(|x| x * x).sum();
        assert!((e0 - e1).abs() / e0 < 1e-5);
    }

    #[test]
    fn fwht_flattens_a_spike() {
        // A single outlier spreads to amplitude outlier/sqrt(n) everywhere.
        let mut xs = vec![0f32; 32];
        xs[5] = 32.0;
        fwht_normalized(&mut xs);
        for &x in &xs {
            assert!((x.abs() - 32.0 / (32f32).sqrt()).abs() < 1e-4);
        }
    }

    #[test]
    fn int4_roundtrip_reasonable() {
        let mut rng = Prng::new(33);
        let mut data = vec![0f32; 4096];
        rng.fill_activations(&mut data, 1.0);
        let s = sqnr_db(&data, &roundtrip(&data, 4, 32));
        assert!(s > 10.0, "INT4 Hadamard SQNR {s}");
    }

    #[test]
    fn collapses_relative_to_sr_at_int2() {
        // The paper's Table 3 ordering at INT2: SR >> RTN >= Hadamard-ish.
        // At minimum, Hadamard must not beat spike reserving at INT2.
        let mut rng = Prng::new(34);
        let mut data = vec![0f32; 1 << 14];
        rng.fill_activations(&mut data, 1.0);
        let had = sqnr_db(&data, &roundtrip(&data, 2, 32));
        let (mut c, mut m, mut s) = (Vec::new(), Vec::new(), Vec::new());
        super::super::spike::quantize(
            &data,
            2,
            32,
            super::super::spike::ScaleMode::Bf16,
            &mut c,
            &mut m,
            &mut s,
        );
        let mut sr = vec![0f32; data.len()];
        super::super::spike::dequantize(&c, &m, &s, 32, &mut sr);
        let srq = sqnr_db(&data, &sr);
        assert!(srq > had, "SR {srq} dB must beat Hadamard {had} dB at INT2");
    }

    #[test]
    fn tail_group_falls_back_to_rtn() {
        let mut rng = Prng::new(35);
        let mut data = vec![0f32; 100]; // 3 full groups of 32 + tail of 4
        rng.fill_normal(&mut data, 0.0, 1.0);
        let out = roundtrip(&data, 8, 32);
        let plain = rtn_roundtrip(&data[96..], 8, 32);
        for (a, b) in out[96..].iter().zip(&plain) {
            assert!((a - b).abs() < 1e-6, "tail must match plain RTN");
        }
    }
}
