//! Spike Reserving (paper §Spike Reserving, Fig. 5): per quantization group,
//! the minimum and maximum ("spikes") are stored exactly in float precision
//! together with their positions; the remaining values are RTN-quantized in
//! the *shrunken* range [second-min, second-max]. After dequantization the
//! spikes are restored to their original places.
//!
//! Two metadata encodings (Table 4):
//! - [`ScaleMode::Bf16`]: scale, zero, spike values and spike indices all in
//!   BF16 — 4 + 8 bytes per group.
//! - [`ScaleMode::IntLog`]: Eq. 1 `scale_int = floor(log2(scale) · θ)` (θ=10)
//!   in i8, an i8 integer zero-point, BF16 spike values and u8 spike
//!   indices — 2 + 6 bytes per group (~20 % smaller overall).
//!
//! The integer zero-point is our resolution of the paper's underspecified
//! "zeros as integers": `zp = round(-zero / scale)` stored in i8, giving
//! `zero ≈ -zp · scale` with error ≤ scale/2 whenever the group straddles
//! zero (always true for the post-norm activations being communicated), and
//! saturating gracefully otherwise. See DESIGN.md §6.

use super::rtn::{self, GroupMeta};
use crate::util::bf16::bf16_round;

/// Eq. 1 upscaling factor θ.
pub const THETA: f32 = 10.0;

/// Metadata precision for scales/zeros/indices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScaleMode {
    /// BF16 scale & zero, BF16 spike values & indices.
    Bf16,
    /// i8 log-scale (Eq. 1), i8 zero-point, BF16 spikes, u8 indices.
    IntLog,
}

/// Per-group spike record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpikeMeta {
    pub min_val: f32,
    pub max_val: f32,
    pub min_idx: u16,
    pub max_idx: u16,
}

impl SpikeMeta {
    /// Placeholder used to pre-size scratch before group analysis fills it.
    pub const EMPTY: SpikeMeta = SpikeMeta { min_val: 0.0, max_val: 0.0, min_idx: 0, max_idx: 0 };
}

/// Largest group size spike reserving supports on the wire: spike indices
/// travel as BF16 (exact only for integers up to 256) in [`ScaleMode::Bf16`]
/// and as u8 in [`ScaleMode::IntLog`] — beyond 256 elements per group the
/// positions would silently corrupt. Enforced by `Codec::validate`.
pub const MAX_GROUP: usize = 256;

/// Encode a scale via Eq. 1 and decode it back (lossy, factor ≤ 2^(1/θ)).
#[inline]
pub fn scale_to_int(scale: f32) -> i8 {
    debug_assert!(scale > 0.0);
    let code = (scale.log2() * THETA).floor();
    code.max(i8::MIN as f32).min(i8::MAX as f32) as i8
}

#[inline]
pub fn scale_from_int(code: i8) -> f32 {
    // §Perf: 256-entry LUT instead of a powf per group on the decode path.
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    let lut = LUT.get_or_init(|| {
        let mut t = [0f32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            *slot = (2.0f32).powf((i as i64 - 128) as f32 / THETA);
        }
        t
    });
    lut[(code as i16 + 128) as usize]
}

/// Round a group meta to what the IntLog wire actually carries.
pub fn meta_through_intlog(meta: GroupMeta) -> GroupMeta {
    let scale = scale_from_int(scale_to_int(meta.scale));
    let zp = (-meta.zero / scale).round().max(i8::MIN as f32).min(i8::MAX as f32) as i8;
    GroupMeta { scale, zero: -(zp as f32) * scale }
}

/// Round a group meta to the chosen wire precision.
pub fn meta_through_wire(meta: GroupMeta, mode: ScaleMode) -> GroupMeta {
    match mode {
        ScaleMode::Bf16 => GroupMeta { scale: bf16_round(meta.scale), zero: bf16_round(meta.zero) },
        ScaleMode::IntLog => meta_through_intlog(meta),
    }
}

/// The analysis half of [`quantize_group`]: locate the spikes and compute
/// the (wire-precision) shrunken-range meta for one group. Shared with the
/// fused encoder (`quant::fused`) so both produce identical metadata.
pub fn analyze_group(xs: &[f32], bits: u8, mode: ScaleMode) -> (GroupMeta, SpikeMeta) {
    debug_assert!(!xs.is_empty() && xs.len() <= u16::MAX as usize + 1);

    // Pass 1: locate the spikes (first occurrence of min and max).
    let (mut min_i, mut max_i) = (0usize, 0usize);
    for (i, &x) in xs.iter().enumerate() {
        debug_assert!(x.is_finite());
        if x < xs[min_i] {
            min_i = i;
        }
        if x > xs[max_i] {
            max_i = i;
        }
    }
    let spikes = SpikeMeta {
        min_val: bf16_round(xs[min_i]),
        max_val: bf16_round(xs[max_i]),
        min_idx: min_i as u16,
        max_idx: max_i as u16,
    };

    // Pass 2: shrunken range over the remaining elements.
    let mut min2 = f32::INFINITY;
    let mut max2 = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if i != min_i && i != max_i {
            min2 = min2.min(x);
            max2 = max2.max(x);
        }
    }
    if !min2.is_finite() {
        // Group of <= 2 elements: everything is a spike; codes are unused.
        min2 = 0.0;
        max2 = 0.0;
    }

    let meta = meta_through_wire(rtn::meta_from_minmax(min2, max2, bits), mode);
    (meta, spikes)
}

/// Quantize one group with spike reserving.
///
/// `codes` receives one code per element (spike positions hold clamped
/// filler — they are overwritten on decode). Returns the (wire-precision)
/// group meta for the shrunken range plus the spike record.
pub fn quantize_group(
    xs: &[f32],
    bits: u8,
    mode: ScaleMode,
    codes: &mut [u8],
) -> (GroupMeta, SpikeMeta) {
    debug_assert_eq!(xs.len(), codes.len());
    let (meta, spikes) = analyze_group(xs, bits, mode);
    rtn::quantize_group_with_meta(xs, bits, meta, codes);
    (meta, spikes)
}

/// Dequantize one group and restore its spikes.
///
/// Index bounds are checked (not trusted): a corrupted or adversarial
/// payload must not crash the receiving rank — see the fuzz test in
/// `tests/robustness.rs`.
pub fn dequantize_group(codes: &[u8], meta: GroupMeta, spikes: &SpikeMeta, out: &mut [f32]) {
    rtn::dequantize_group(codes, meta, out);
    if let Some(slot) = out.get_mut(spikes.min_idx as usize) {
        *slot = spikes.min_val;
    }
    if let Some(slot) = out.get_mut(spikes.max_idx as usize) {
        *slot = spikes.max_val;
    }
}

/// Quantize a full tensor with spike reserving.
pub fn quantize(
    data: &[f32],
    bits: u8,
    group_size: usize,
    mode: ScaleMode,
    codes: &mut Vec<u8>,
    metas: &mut Vec<GroupMeta>,
    spikes: &mut Vec<SpikeMeta>,
) {
    assert!(group_size > 1, "spike reserving needs groups of >= 2");
    assert!(group_size <= MAX_GROUP, "spike reserving caps group_size at {MAX_GROUP}");
    codes.clear();
    codes.resize(data.len(), 0);
    metas.clear();
    spikes.clear();
    for (xs, cs) in data.chunks(group_size).zip(codes.chunks_mut(group_size)) {
        let (m, s) = quantize_group(xs, bits, mode, cs);
        metas.push(m);
        spikes.push(s);
    }
}

/// Dequantize a full tensor with spike restoration.
pub fn dequantize(
    codes: &[u8],
    metas: &[GroupMeta],
    spikes: &[SpikeMeta],
    group_size: usize,
    out: &mut [f32],
) {
    assert_eq!(codes.len(), out.len());
    for (g, (cs, xs)) in codes.chunks(group_size).zip(out.chunks_mut(group_size)).enumerate() {
        dequantize_group(cs, metas[g], &spikes[g], xs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{arb_tensor, cases};
    use crate::util::stats::{sqnr_db, DistSummary};
    use crate::util::Prng;

    fn roundtrip(data: &[f32], bits: u8, gs: usize, mode: ScaleMode) -> Vec<f32> {
        let (mut codes, mut metas, mut spikes) = (Vec::new(), Vec::new(), Vec::new());
        quantize(data, bits, gs, mode, &mut codes, &mut metas, &mut spikes);
        let mut out = vec![0f32; data.len()];
        dequantize(&codes, &metas, &spikes, gs, &mut out);
        out
    }

    fn rtn_roundtrip(data: &[f32], bits: u8, gs: usize) -> Vec<f32> {
        let (mut codes, mut metas) = (Vec::new(), Vec::new());
        rtn::quantize(data, bits, gs, &mut codes, &mut metas);
        let mut out = vec![0f32; data.len()];
        rtn::dequantize(&codes, &metas, gs, &mut out);
        out
    }

    #[test]
    fn spikes_reconstruct_to_bf16_exactly() {
        let mut data = vec![0.5f32; 32];
        data[7] = -100.0;
        data[21] = 250.0;
        let out = roundtrip(&data, 2, 32, ScaleMode::Bf16);
        assert_eq!(out[7], -100.0);
        assert_eq!(out[21], 250.0);
        // The body, freed of spikes, quantizes the constant 0.5 exactly.
        for (i, &x) in out.iter().enumerate() {
            if i != 7 && i != 21 {
                assert!((x - 0.5).abs() < 1e-3, "body[{i}]={x}");
            }
        }
    }

    #[test]
    fn shrinks_dynamic_range_fig4() {
        // The paper's Fig. 4: removing spikes narrows the distribution.
        let mut rng = Prng::new(21);
        let mut data = vec![0f32; 4096];
        rng.fill_activations(&mut data, 1.0);
        let before = DistSummary::of(&data).range();
        let mut shrunk = Vec::new();
        for g in data.chunks(32) {
            let (mut codes, _) = (vec![0u8; g.len()], ());
            let (_, s) = quantize_group(g, 2, ScaleMode::Bf16, &mut codes);
            for (i, &x) in g.iter().enumerate() {
                if i != s.min_idx as usize && i != s.max_idx as usize {
                    shrunk.push(x);
                }
            }
        }
        let after = DistSummary::of(&shrunk).range();
        assert!(after < before * 0.5, "range {before} -> {after}");
    }

    #[test]
    fn sr_beats_rtn_at_int2_on_activations() {
        // The core claim (Table 3): at INT2/gs32 on heavy-tailed data, SR
        // reconstructs much better than plain RTN.
        let mut rng = Prng::new(22);
        let mut data = vec![0f32; 1 << 15];
        rng.fill_activations(&mut data, 1.0);
        let rtn_s = sqnr_db(&data, &rtn_roundtrip(&data, 2, 32));
        let sr_s = sqnr_db(&data, &roundtrip(&data, 2, 32, ScaleMode::Bf16));
        assert!(sr_s > rtn_s + 6.0, "SR {sr_s} dB should beat RTN {rtn_s} dB by >6 dB");
    }

    #[test]
    fn intlog_close_to_bf16_mode() {
        let mut rng = Prng::new(23);
        let mut data = vec![0f32; 8192];
        rng.fill_activations(&mut data, 0.5);
        let b = sqnr_db(&data, &roundtrip(&data, 2, 32, ScaleMode::Bf16));
        let i = sqnr_db(&data, &roundtrip(&data, 2, 32, ScaleMode::IntLog));
        assert!(i > b - 3.0, "IntLog {i} dB within 3 dB of Bf16 {b} dB");
    }

    #[test]
    fn eq1_scale_codec() {
        for &s in &[1e-3f32, 0.01, 0.1, 0.5, 1.0, 3.7, 100.0] {
            let rec = scale_from_int(scale_to_int(s));
            // floor() always rounds the scale down, by at most 2^(1/θ).
            assert!(rec <= s * 1.0001 && rec >= s / 2f32.powf(1.0 / THETA) * 0.999, "{s} -> {rec}");
        }
    }

    #[test]
    fn degenerate_groups() {
        // len 1: the single value is both spikes.
        let out = roundtrip(&[42.0f32], 2, 32, ScaleMode::Bf16);
        assert_eq!(out[0], 42.0);
        // len 2: both values are spikes, exact.
        let out = roundtrip(&[-3.0f32, 9.0], 2, 32, ScaleMode::Bf16);
        assert_eq!(out, vec![-3.0, 9.0]);
        // constant group.
        let out = roundtrip(&[5.0f32; 32], 2, 32, ScaleMode::IntLog);
        for &x in &out {
            assert!((x - 5.0).abs() < 0.05, "{x}");
        }
        // all zeros.
        let out = roundtrip(&[0f32; 64], 2, 32, ScaleMode::IntLog);
        assert!(out.iter().all(|&x| x.abs() < 1e-6));
    }

    #[test]
    fn property_error_bounded_by_shrunken_range() {
        cases(300, 128, |rng| {
            let data = arb_tensor(rng, 400);
            let bits = [2u8, 3, 4][rng.below(3)];
            let gs = 32;
            let out = roundtrip(&data, bits, gs, ScaleMode::Bf16);
            for (xs, rec) in data.chunks(gs).zip(out.chunks(gs)) {
                // Bound: half-step of the shrunken range + bf16 meta error.
                let mut v: Vec<f32> = xs.to_vec();
                v.sort_by(f32::total_cmp);
                let (min2, max2) = if v.len() > 2 {
                    (v[1], v[v.len() - 2])
                } else {
                    (0.0, 0.0)
                };
                let meta = rtn::meta_from_minmax(min2, max2, bits);
                let bound = rtn::error_bound(meta, bits, min2, max2)
                    + (min2.abs() + max2.abs()) / 128.0; // extra bf16 slack
                for (a, b) in xs.iter().zip(rec) {
                    let tol = bound.max(a.abs() / 128.0); // spikes: bf16-exact
                    assert!((a - b).abs() <= tol, "|{a}-{b}| > {tol} (bits {bits})");
                }
            }
        });
    }

    #[test]
    fn intlog_zero_point_saturates_gracefully() {
        // Groups far from zero exceed the i8 zero-point range; the decoded
        // body shifts but stays finite and within 128 steps of the truth.
        let data: Vec<f32> = (0..32).map(|i| 1000.0 + i as f32 * 0.01).collect();
        let out = roundtrip(&data, 4, 32, ScaleMode::IntLog);
        assert!(out.iter().all(|x| x.is_finite()));
        // Spikes still land exactly (bf16) even when the body saturates.
        let mx = data.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(out.iter().any(|&x| (x - mx).abs() <= mx / 128.0));
    }

    #[test]
    fn property_spike_positions_exact() {
        cases(301, 64, |rng| {
            let data = arb_tensor(rng, 256);
            let out = roundtrip(&data, 2, 32, ScaleMode::Bf16);
            for (xs, rec) in data.chunks(32).zip(out.chunks(32)) {
                let mn = xs.iter().cloned().fold(f32::INFINITY, f32::min);
                let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                // Min and max of every group survive at bf16 precision
                // (plus bf16 slack on the body's scale/zero metadata).
                let slack = (mx - mn) / 200.0 + 1e-6;
                let rmn = rec.iter().cloned().fold(f32::INFINITY, f32::min);
                let rmx = rec.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                assert!((rmn - mn).abs() <= mn.abs() / 128.0 + slack, "min {mn} vs {rmn}");
                assert!((rmx - mx).abs() <= mx.abs() / 128.0 + slack, "max {mx} vs {rmx}");
            }
        });
    }
}
