//! LogFMT quantization baseline (Table 3; DeepSeek-V3 insights paper).
//!
//! Values are quantized in the log domain: one sign bit plus `bits - 1`
//! magnitude bits that linearly quantize `log2|x|` over the group's
//! exponent range. Magnitude code 0 is reserved for exact zero / underflow.
//! Dequantization exponentiates, which — as the paper notes — *amplifies*
//! quantization error multiplicatively, collapsing at INT2 (where a single
//! magnitude bit remains).
//!
//! Per-group metadata: `emin`, `emax` (log2 range endpoints) as BF16.

use crate::util::bf16::bf16_round;

/// Per-group log-domain metadata.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LogMeta {
    /// log2 of the smallest retained magnitude.
    pub emin: f32,
    /// log2 of the largest magnitude.
    pub emax: f32,
}

/// Smallest magnitude treated as nonzero (below it values snap to 0).
pub const MIN_MAG: f32 = 1e-30;

/// Number of magnitude levels for a bit width (code 0 reserved for zero).
#[inline]
fn mag_levels(bits: u8) -> u32 {
    debug_assert!((2..=8).contains(&bits));
    (1u32 << (bits - 1)) - 1
}

/// The analysis half of [`quantize_group`]: scan one group's exponent
/// range. Shared with the fused encoder (`quant::fused`).
pub fn analyze_group(xs: &[f32]) -> LogMeta {
    let mut emin = f32::INFINITY;
    let mut emax = f32::NEG_INFINITY;
    for &x in xs {
        let m = x.abs();
        if m > MIN_MAG {
            let e = m.log2();
            emin = emin.min(e);
            emax = emax.max(e);
        }
    }
    if !emin.is_finite() {
        // All zeros.
        return LogMeta { emin: 0.0, emax: 0.0 };
    }
    LogMeta { emin: bf16_round(emin), emax: bf16_round(emax) }
}

/// Emit one code per element against a fixed (wire-precision) meta. Codes
/// are `sign << (bits-1) | mag` with mag in [0, 2^(bits-1) - 1]; codes
/// 1..=levels linearly span [emin, emax] in log space.
pub fn quantize_group_with_meta(xs: &[f32], bits: u8, meta: LogMeta, mut emit: impl FnMut(u8)) {
    let levels = mag_levels(bits);
    let span = (meta.emax - meta.emin).max(1e-6);
    let inv = if levels > 1 { (levels - 1) as f32 / span } else { 0.0 };
    let sign_bit = 1u8 << (bits - 1);
    for &x in xs {
        let m = x.abs();
        if m <= MIN_MAG {
            emit(0);
            continue;
        }
        let q = ((m.log2() - meta.emin) * inv).round();
        let mag = 1 + (q.max(0.0) as u32).min(levels - 1) as u8;
        emit(if x < 0.0 { mag | sign_bit } else { mag });
    }
}

/// Quantize one group into `codes`.
pub fn quantize_group(xs: &[f32], bits: u8, codes: &mut [u8]) -> LogMeta {
    debug_assert_eq!(xs.len(), codes.len());
    let meta = analyze_group(xs);
    let mut slots = codes.iter_mut();
    // lint: allow(panic, "the emitter yields exactly xs.len() codes, matching the slots iterator")
    quantize_group_with_meta(xs, bits, meta, |c| *slots.next().unwrap() = c);
    meta
}

/// Per-group decode state with the span/step math hoisted out of the
/// element loop. Both [`dequantize_group`] and the fused decoder use this,
/// so their outputs are bit-identical by construction.
pub(crate) struct GroupDecoder {
    emin: f32,
    step: f32,
    sign_bit: u8,
    mag_mask: u8,
}

impl GroupDecoder {
    pub(crate) fn new(meta: LogMeta, bits: u8) -> GroupDecoder {
        let levels = mag_levels(bits);
        let span = (meta.emax - meta.emin).max(1e-6);
        let step = if levels > 1 { span / (levels - 1) as f32 } else { 0.0 };
        let sign_bit = 1u8 << (bits - 1);
        GroupDecoder { emin: meta.emin, step, sign_bit, mag_mask: sign_bit - 1 }
    }

    #[inline(always)]
    pub(crate) fn decode(&self, c: u8) -> f32 {
        let mag = c & self.mag_mask;
        if mag == 0 {
            return 0.0;
        }
        let e = self.emin + (mag - 1) as f32 * self.step; // code 1 -> emin
        let v = e.exp2();
        if c & self.sign_bit != 0 {
            -v
        } else {
            v
        }
    }
}

/// Dequantize one group.
pub fn dequantize_group(codes: &[u8], meta: LogMeta, bits: u8, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    let dec = GroupDecoder::new(meta, bits);
    for (x, &c) in out.iter_mut().zip(codes) {
        *x = dec.decode(c);
    }
}

/// Full-tensor quantize.
pub fn quantize(
    data: &[f32],
    bits: u8,
    group_size: usize,
    codes: &mut Vec<u8>,
    metas: &mut Vec<LogMeta>,
) {
    codes.clear();
    codes.resize(data.len(), 0);
    metas.clear();
    for (xs, cs) in data.chunks(group_size).zip(codes.chunks_mut(group_size)) {
        metas.push(quantize_group(xs, bits, cs));
    }
}

/// Full-tensor dequantize.
pub fn dequantize(codes: &[u8], metas: &[LogMeta], bits: u8, group_size: usize, out: &mut [f32]) {
    assert_eq!(codes.len(), out.len());
    for ((cs, &meta), xs) in codes.chunks(group_size).zip(metas).zip(out.chunks_mut(group_size)) {
        dequantize_group(cs, meta, bits, xs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::sqnr_db;
    use crate::util::Prng;

    fn roundtrip(data: &[f32], bits: u8, gs: usize) -> Vec<f32> {
        let (mut codes, mut metas) = (Vec::new(), Vec::new());
        quantize(data, bits, gs, &mut codes, &mut metas);
        let mut out = vec![0f32; data.len()];
        dequantize(&codes, &metas, bits, gs, &mut out);
        out
    }

    #[test]
    fn zeros_and_signs_roundtrip() {
        let data = vec![0.0f32, -1.0, 1.0, -4.0, 4.0, 0.0, 0.25, -0.25];
        let out = roundtrip(&data, 8, 8);
        for (a, b) in data.iter().zip(&out) {
            assert_eq!(a.signum() * (a.abs() > 0.0) as i32 as f32,
                       b.signum() * (b.abs() > 0.0) as i32 as f32,
                       "sign/zero mismatch {a} vs {b}");
        }
    }

    #[test]
    fn powers_of_two_near_exact_at_int8() {
        let data: Vec<f32> = (0..32).map(|i| 2f32.powi(i % 8 - 4)).collect();
        let out = roundtrip(&data, 8, 32);
        for (a, b) in data.iter().zip(&out) {
            assert!(((a - b) / a).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn relative_error_bounded_at_high_bits() {
        let mut rng = Prng::new(41);
        let data: Vec<f32> =
            (0..4096).map(|_| rng.normal_f32(0.0, 1.0)).filter(|x| x.abs() > 1e-3).collect();
        let out = roundtrip(&data, 8, 128);
        for (a, b) in data.iter().zip(&out) {
            // 127 levels over the group's log range: generous bound.
            assert!(((a - b) / a).abs() < 0.25, "{a} vs {b}");
        }
    }

    #[test]
    fn collapses_at_int2() {
        // One magnitude bit: everything snaps to a single magnitude per sign.
        let mut rng = Prng::new(42);
        let mut data = vec![0f32; 8192];
        rng.fill_activations(&mut data, 1.0);
        let s2 = sqnr_db(&data, &roundtrip(&data, 2, 32));
        let s4 = sqnr_db(&data, &roundtrip(&data, 4, 32));
        assert!(s4 > s2 + 6.0, "INT4 {s4} dB must be far above INT2 {s2} dB");
        // And INT2 LogFMT must be clearly bad in absolute terms (collapse).
        assert!(s2 < 8.0, "INT2 LogFMT should collapse, got {s2} dB");
    }

    #[test]
    fn codes_fit_bit_width() {
        let mut rng = Prng::new(43);
        let mut data = vec![0f32; 1024];
        rng.fill_normal(&mut data, 0.0, 5.0);
        for bits in 2..=8u8 {
            let (mut codes, mut metas) = (Vec::new(), Vec::new());
            quantize(&data, bits, 32, &mut codes, &mut metas);
            let max = (1u16 << bits) - 1;
            assert!(codes.iter().all(|&c| (c as u16) <= max), "bits={bits}");
        }
    }
}
