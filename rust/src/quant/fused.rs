//! Fused single-pass codec kernels (paper §Kernel Fusion; Flash
//! Communication V1's "fast packing" taken one step further).
//!
//! The unfused pipeline materializes a byte-per-value `codes` buffer
//! between quantization and bit-split packing (and again between unpacking
//! and dequantization) — 2x the memory traffic of the payload on each side.
//! These kernels remove it:
//!
//! - **encode**: each group is quantized and its code bits are scattered
//!   straight into the bit-split plane regions of the wire buffer. Plane
//!   offsets are precomputable from [`packed_len`], so quantize+pack is one
//!   pass over `data` with no intermediate buffer.
//! - **decode / decode-sum**: a SWAR plane gather (the inverse of
//!   `pack_plane`'s u64 folds) streams 8 codes at a time out of the planes,
//!   feeding straight into per-group dequantize or dequantize-accumulate.
//!   The reduce step of every collective runs scratch-free for every
//!   scheme (RTN, Spike, Hadamard, LogFMT — Hadamard needs one group-sized
//!   rotation buffer, owned by [`CodecBuffers`]).
//!
//! Payloads of at least [`PAR_MIN_ELEMS`] elements can additionally be
//! chunked across scoped worker threads ([`std::thread::scope`]). Chunks
//! are cut at `lcm(group_size, 8)` element boundaries so quantization
//! groups and plane *bytes* never straddle workers: every worker owns a
//! disjoint byte range of each plane and a disjoint slice of the per-group
//! metadata, making the parallel wire bytes identical to the serial ones.
//!
//! Bit-identity with the scalar path is pinned by `tests/codec_fused.rs`
//! (against [`super::reference`]) and by the golden wire hashes in
//! `tests/robustness.rs`.

use anyhow::Result;

use super::bitsplit::{
    fold1, fold2, fold4, load_le, packed_len, plane_len, planes_for, spread1, spread2, spread4,
};
use super::hadamard;
use super::logfmt::{self, LogMeta};
use super::rtn::{self, GroupMeta};
use super::scheme::{Codec, CodecBuffers};
use super::spike::{self, ScaleMode, SpikeMeta};
use super::wire;

/// Minimum payload (elements) before the chunk-parallel path engages; below
/// this the spawn cost dwarfs the win. Re-exported as
/// `quant::PAR_MIN_ELEMS` so callers (benches, thread-budget tuning) can
/// tell whether a payload is parallel-eligible.
pub const PAR_MIN_ELEMS: usize = 1 << 16;

/// Hard cap on codec worker threads regardless of what a caller asks for.
/// Re-exported as `quant::MAX_CODEC_THREADS`;
/// `Communicator::set_codec_threads` clamps to it.
pub const MAX_CODEC_THREADS: usize = 32;

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Element alignment for parallel chunk cuts: a multiple of the group size
/// (metas stay per-worker) and of 8 (plane bytes stay per-worker).
pub(crate) fn chunk_align(group_size: usize) -> usize {
    group_size / gcd(group_size, 8) * 8
}

/// Fewest elements a worker is worth spawning for: below this the scoped
/// spawn+join overhead exceeds the kernel work it parallelizes.
const MIN_ELEMS_PER_WORKER: usize = PAR_MIN_ELEMS / 8;

/// Decide (worker count, elements per worker) for a payload. `per` is
/// `chunk_align`-aligned; the last worker takes the remainder. The worker
/// count is bounded by the thread budget AND by per-worker work, so a
/// large `--codec-threads` on a barely-above-threshold payload does not
/// drown the kernels in spawn overhead.
fn plan(n: usize, group_size: usize, threads: usize) -> (usize, usize) {
    if threads <= 1 || n < PAR_MIN_ELEMS {
        return (1, n);
    }
    let align = chunk_align(group_size);
    let max_workers = threads
        .min(MAX_CODEC_THREADS)
        .min(n / MIN_ELEMS_PER_WORKER)
        .min(n.div_ceil(align))
        .max(1);
    let per = n.div_ceil(max_workers).div_ceil(align) * align;
    (n.div_ceil(per), per)
}

// --- Streaming plane scatter (encode) ------------------------------------

#[derive(Default)]
struct PlaneOut<'a> {
    w: u8,
    shift: u8,
    out: &'a mut [u8],
    cur: usize,
}

/// Accepts one code per value and writes each 8-code block straight into
/// the per-plane output slices, using the same SWAR folds as
/// `bitsplit::pack_plane` — the wire bytes are identical by construction.
pub(crate) struct PlaneSink<'a> {
    planes: [PlaneOut<'a>; 3],
    n_planes: usize,
    buf: u64,
    count: u32,
}

impl<'a> PlaneSink<'a> {
    fn empty() -> Self {
        PlaneSink { planes: Default::default(), n_planes: 0, buf: 0, count: 0 }
    }

    fn add_plane(&mut self, w: u8, shift: u8, out: &'a mut [u8]) {
        self.planes[self.n_planes] = PlaneOut { w, shift, out, cur: 0 };
        self.n_planes += 1;
    }

    /// Sink over the full packed `section` for `n` codes of width `bits`.
    pub(crate) fn new(bits: u8, n: usize, section: &'a mut [u8]) -> Self {
        debug_assert_eq!(section.len(), packed_len(bits, n));
        let mut sink = PlaneSink::empty();
        let mut rest = section;
        let mut shift = 0u8;
        for &w in planes_for(bits) {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(plane_len(w, n));
            sink.add_plane(w, shift, head);
            rest = tail;
            shift += w;
        }
        sink
    }

    #[inline(always)]
    pub(crate) fn push(&mut self, code: u8) {
        self.buf |= (code as u64) << (8 * self.count);
        self.count += 1;
        if self.count == 8 {
            self.flush();
        }
    }

    /// Scatter the pending block (up to 8 codes, zero-padded) into every
    /// plane. Writes exactly the bytes the block's codes occupy, so the
    /// tail block produces the same bytes as `pack_plane`'s scalar tail.
    fn flush(&mut self) {
        let v = self.buf;
        let valid = self.count as usize;
        for p in self.planes[..self.n_planes].iter_mut() {
            let s = v >> p.shift;
            match p.w {
                4 => {
                    let f = fold4(s);
                    let bytes = [f as u8, (f >> 16) as u8, (f >> 32) as u8, (f >> 48) as u8];
                    let k = valid.div_ceil(2);
                    p.out[p.cur..p.cur + k].copy_from_slice(&bytes[..k]);
                    p.cur += k;
                }
                2 => {
                    let f = fold2(s);
                    let bytes = [f as u8, (f >> 32) as u8];
                    let k = valid.div_ceil(4);
                    p.out[p.cur..p.cur + k].copy_from_slice(&bytes[..k]);
                    p.cur += k;
                }
                _ => {
                    // Zero-padded codes make `pack_plane`'s tail mask a
                    // no-op: the bits beyond `valid` are already zero.
                    p.out[p.cur] = fold1(s);
                    p.cur += 1;
                }
            }
        }
        self.buf = 0;
        self.count = 0;
    }

    /// Flush the trailing partial block; call exactly once after the last
    /// `push`.
    pub(crate) fn finish(mut self) {
        if self.count > 0 {
            self.flush();
        }
        for p in &self.planes[..self.n_planes] {
            debug_assert_eq!(p.cur, p.out.len(), "plane {}b not fully written", p.w);
        }
    }
}

// --- Streaming plane gather (decode) -------------------------------------

#[derive(Default)]
struct PlaneIn<'a> {
    w: u8,
    shift: u8,
    bytes: &'a [u8],
    cur: usize,
}

/// Streams codes back out of the bit-split planes, 8 at a time, using the
/// `spread*` inverses of the pack folds.
pub(crate) struct PlaneSource<'a> {
    planes: [PlaneIn<'a>; 3],
    n_planes: usize,
    buf: u64,
    left: u32,
}

impl<'a> PlaneSource<'a> {
    /// Source over the full packed `section` for `n` codes, positioned at
    /// element `start` (must be a multiple of 8 so every plane cursor lands
    /// on a byte boundary).
    pub(crate) fn new_at(bits: u8, n: usize, section: &'a [u8], start: usize) -> Self {
        debug_assert_eq!(section.len(), packed_len(bits, n));
        debug_assert_eq!(start % 8, 0, "plane source must start byte-aligned");
        let mut src = PlaneSource { planes: Default::default(), n_planes: 0, buf: 0, left: 0 };
        let mut off = 0usize;
        let mut shift = 0u8;
        for &w in planes_for(bits) {
            let len = plane_len(w, n);
            src.planes[src.n_planes] = PlaneIn {
                w,
                shift,
                bytes: &section[off..off + len],
                cur: start * w as usize / 8,
            };
            src.n_planes += 1;
            off += len;
            shift += w;
        }
        src
    }

    #[inline(always)]
    pub(crate) fn next(&mut self) -> u8 {
        if self.left == 0 {
            self.refill();
        }
        let c = self.buf as u8;
        self.buf >>= 8;
        self.left -= 1;
        c
    }

    #[inline(always)]
    fn refill(&mut self) {
        let mut v = 0u64;
        for p in self.planes[..self.n_planes].iter_mut() {
            // One block consumes `w` plane bytes (8 codes × w bits / 8);
            // `load_le` zero-pads past the end of the tail block.
            let x = match p.w {
                4 => spread4(load_le(p.bytes, p.cur, 4)),
                2 => spread2(load_le(p.bytes, p.cur, 2)),
                _ => spread1(load_le(p.bytes, p.cur, 1)),
            };
            v |= x << p.shift;
            p.cur += p.w as usize;
        }
        self.buf = v;
        self.left = 8;
    }
}

// --- Fused encode ---------------------------------------------------------

/// Wire-precision meta for one RTN group: one minmax pass, then the rounding
/// the chosen metadata mode applies. This replaces the duplicated group loop
/// the pre-fusion `quantize_rtn_mode` carried for the IntLog case.
#[inline]
fn rtn_group_meta(xs: &[f32], bits: u8, mode: ScaleMode) -> GroupMeta {
    let (mn, mx) = rtn::minmax(xs);
    let meta = rtn::meta_from_minmax(mn, mx, bits);
    match mode {
        ScaleMode::Bf16 => meta,
        ScaleMode::IntLog => spike::meta_through_intlog(meta),
    }
}

/// Quantize one group straight into the sink — the same expression, in the
/// same order, as `rtn::quantize_group_with_meta`, so the codes (and hence
/// the wire bytes) match the scalar path bit-for-bit.
#[inline]
fn quantize_group_into(xs: &[f32], bits: u8, meta: GroupMeta, sink: &mut PlaneSink) {
    let inv = 1.0 / meta.scale;
    let qm = rtn::qmax(bits) as f32;
    for &x in xs {
        sink.push(((x - meta.zero) * inv + 0.5).min(qm) as u8);
    }
}

/// One worker's share of a fused encode: a contiguous, chunk-aligned run of
/// groups with the matching slices of every output.
struct EncJob<'a> {
    data: &'a [f32],
    metas: &'a mut [GroupMeta],
    spikes: &'a mut [SpikeMeta],
    logmetas: &'a mut [LogMeta],
    scratch: &'a mut [f32],
    sink: PlaneSink<'a>,
}

fn run_encode(codec: &Codec, job: EncJob<'_>) {
    let EncJob { data, metas, spikes, logmetas, scratch, mut sink } = job;
    match *codec {
        // lint: allow(panic, "encode_with/decode_with route Bf16 away before dispatching here")
        Codec::Bf16 => unreachable!("bf16 bypasses the fused kernels"),
        Codec::Rtn { bits, group_size, scale_mode } => {
            let gs = group_size as usize;
            for (xs, m) in data.chunks(gs).zip(metas.iter_mut()) {
                let meta = rtn_group_meta(xs, bits, scale_mode);
                quantize_group_into(xs, bits, meta, &mut sink);
                *m = meta;
            }
        }
        Codec::Spike { bits, group_size, scale_mode } => {
            let gs = group_size as usize;
            for ((xs, m), sp) in data.chunks(gs).zip(metas.iter_mut()).zip(spikes.iter_mut()) {
                let (meta, spike_rec) = spike::analyze_group(xs, bits, scale_mode);
                quantize_group_into(xs, bits, meta, &mut sink);
                *m = meta;
                *sp = spike_rec;
            }
        }
        Codec::Hadamard { bits, group_size } => {
            let gs = group_size as usize;
            for (xs, m) in data.chunks(gs).zip(metas.iter_mut()) {
                *m = if xs.len() == gs {
                    let rot = &mut scratch[..gs];
                    rot.copy_from_slice(xs);
                    hadamard::fwht_normalized(rot);
                    let meta = rtn_group_meta(rot, bits, ScaleMode::Bf16);
                    quantize_group_into(rot, bits, meta, &mut sink);
                    meta
                } else {
                    // Tail group is not a power of two: plain RTN.
                    let meta = rtn_group_meta(xs, bits, ScaleMode::Bf16);
                    quantize_group_into(xs, bits, meta, &mut sink);
                    meta
                };
            }
        }
        Codec::LogFmt { bits, group_size } => {
            let gs = group_size as usize;
            for (xs, m) in data.chunks(gs).zip(logmetas.iter_mut()) {
                let meta = logfmt::analyze_group(xs);
                logfmt::quantize_group_with_meta(xs, bits, meta, |c| sink.push(c));
                *m = meta;
            }
        }
    }
    sink.finish();
}

/// Fused encode of everything after the wire header: quantized planes
/// (scattered in a single pass over `data`), then the metadata sections.
/// `threads > 1` enables chunk parallelism above [`PAR_MIN_ELEMS`].
pub(crate) fn encode_body(
    codec: &Codec,
    data: &[f32],
    bufs: &mut CodecBuffers,
    out: &mut Vec<u8>,
    threads: usize,
) {
    let n = data.len();
    let bits = codec.bits();
    let gs = codec.group_size();
    let g = rtn::num_groups(n, gs);
    let qlen = packed_len(bits, n);
    let qoff = out.len();
    out.resize(qoff + qlen, 0);

    // Pre-size the per-group metadata stores so workers can fill disjoint
    // sub-slices; the serialization below reads them back in group order.
    match codec {
        // lint: allow(panic, "encode_with/decode_with route Bf16 away before dispatching here")
        Codec::Bf16 => unreachable!("bf16 bypasses the fused kernels"),
        Codec::Rtn { .. } | Codec::Hadamard { .. } => {
            bufs.metas.clear();
            bufs.metas.resize(g, GroupMeta::IDENTITY);
        }
        Codec::Spike { .. } => {
            bufs.metas.clear();
            bufs.metas.resize(g, GroupMeta::IDENTITY);
            bufs.spikes.clear();
            bufs.spikes.resize(g, SpikeMeta::EMPTY);
        }
        Codec::LogFmt { .. } => {
            bufs.logmetas.clear();
            bufs.logmetas.resize(g, LogMeta { emin: 0.0, emax: 0.0 });
        }
    }
    let (workers, per) = plan(n, gs, threads);
    if matches!(codec, Codec::Hadamard { .. }) {
        bufs.scratch.clear();
        bufs.scratch.resize(workers * gs, 0.0);
    }

    {
        let section = &mut out[qoff..];
        if workers <= 1 {
            run_encode(
                codec,
                EncJob {
                    data,
                    metas: &mut bufs.metas,
                    spikes: &mut bufs.spikes,
                    logmetas: &mut bufs.logmetas,
                    scratch: &mut bufs.scratch,
                    sink: PlaneSink::new(bits, n, section),
                },
            );
        } else {
            let jobs = split_enc_jobs(bits, gs, data, bufs, section, workers, per);
            let codec = *codec;
            std::thread::scope(|s| {
                for job in jobs {
                    s.spawn(move || run_encode(&codec, job));
                }
            });
        }
    }

    // Metadata sections (small; serialized on the calling thread).
    match *codec {
        // lint: allow(panic, "encode_with/decode_with route Bf16 away before dispatching here")
        Codec::Bf16 => unreachable!(),
        Codec::Rtn { scale_mode, .. } => wire::write_group_metas(&bufs.metas, scale_mode, out),
        Codec::Spike { scale_mode, .. } => {
            wire::write_group_metas(&bufs.metas, scale_mode, out);
            wire::write_spikes(&bufs.spikes, scale_mode, out);
        }
        Codec::Hadamard { .. } => wire::write_group_metas(&bufs.metas, ScaleMode::Bf16, out),
        Codec::LogFmt { .. } => wire::write_log_metas(&bufs.logmetas, out),
    }
}

/// Detach the first `k.min(len)` elements of `*rest` with the full
/// lifetime (the `mem::take` split idiom), advancing `*rest` past them.
fn carve<'a, T>(rest: &mut &'a mut [T], k: usize) -> &'a mut [T] {
    let tmp = std::mem::take(rest);
    let k = k.min(tmp.len());
    let (head, tail) = tmp.split_at_mut(k);
    *rest = tail;
    head
}

/// Carve the inputs and outputs of a parallel encode into per-worker jobs.
/// Every boundary is a multiple of `chunk_align(gs)`, so group metadata and
/// plane bytes split exactly.
fn split_enc_jobs<'a>(
    bits: u8,
    gs: usize,
    data: &'a [f32],
    bufs: &'a mut CodecBuffers,
    section: &'a mut [u8],
    workers: usize,
    per: usize,
) -> Vec<EncJob<'a>> {
    let n = data.len();
    // Planes first, then per-worker byte ranges of each plane.
    let mut plane_rest: Vec<(u8, u8, &'a mut [u8])> = Vec::with_capacity(3);
    {
        let mut rest = section;
        let mut shift = 0u8;
        for &w in planes_for(bits) {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(plane_len(w, n));
            plane_rest.push((w, shift, head));
            rest = tail;
            shift += w;
        }
    }
    let mut data_rest = data;
    let mut metas_rest = bufs.metas.as_mut_slice();
    let mut spikes_rest = bufs.spikes.as_mut_slice();
    let mut logs_rest = bufs.logmetas.as_mut_slice();
    let mut scratch_rest = bufs.scratch.as_mut_slice();
    let mut jobs = Vec::with_capacity(workers);
    for wi in 0..workers {
        let a = wi * per;
        let take = per.min(n - a);
        let (chunk, r) = data_rest.split_at(take);
        data_rest = r;
        let g_take = take.div_ceil(gs);
        let metas = carve(&mut metas_rest, g_take);
        let spikes = carve(&mut spikes_rest, g_take);
        let logmetas = carve(&mut logs_rest, g_take);
        let scratch = carve(&mut scratch_rest, gs);
        let mut sink = PlaneSink::empty();
        for p in plane_rest.iter_mut() {
            // `a` is a multiple of 8, so this worker's plane bytes are a
            // whole, disjoint range of exactly plane_len(w, take) bytes.
            sink.add_plane(p.0, p.1, carve(&mut p.2, plane_len(p.0, take)));
        }
        jobs.push(EncJob { data: chunk, metas, spikes, logmetas, scratch, sink });
    }
    jobs
}

// --- Fused decode / decode-accumulate -------------------------------------

/// One worker's share of a fused decode.
struct DecJob<'a> {
    out: &'a mut [f32],
    src: PlaneSource<'a>,
    metas: &'a [GroupMeta],
    spikes: &'a [SpikeMeta],
    logmetas: &'a [LogMeta],
    scratch: &'a mut [f32],
}

fn run_decode(codec: &Codec, job: DecJob<'_>, sum: bool) {
    let DecJob { out, mut src, metas, spikes, logmetas, scratch } = job;
    match *codec {
        // lint: allow(panic, "encode_with/decode_with route Bf16 away before dispatching here")
        Codec::Bf16 => unreachable!("bf16 bypasses the fused kernels"),
        Codec::Rtn { group_size, .. } => {
            let gs = group_size as usize;
            if sum {
                for (xs, &meta) in out.chunks_mut(gs).zip(metas) {
                    for x in xs {
                        *x += src.next() as f32 * meta.scale + meta.zero;
                    }
                }
            } else {
                for (xs, &meta) in out.chunks_mut(gs).zip(metas) {
                    for x in xs {
                        *x = src.next() as f32 * meta.scale + meta.zero;
                    }
                }
            }
        }
        Codec::Spike { group_size, .. } => {
            let gs = group_size as usize;
            for ((xs, &meta), sp) in out.chunks_mut(gs).zip(metas).zip(spikes) {
                if sum {
                    // Accumulate the restored image directly: spike slots
                    // contribute their exact values, the body its dequant.
                    // Out-of-range indices (corrupt wire) match no slot —
                    // same outcome as the bounds-checked restore below.
                    let (mn, mx) = (sp.min_idx as usize, sp.max_idx as usize);
                    for (i, x) in xs.iter_mut().enumerate() {
                        let body = src.next() as f32 * meta.scale + meta.zero;
                        let v = if i == mx {
                            sp.max_val
                        } else if i == mn {
                            sp.min_val
                        } else {
                            body
                        };
                        *x += v;
                    }
                } else {
                    for x in xs.iter_mut() {
                        *x = src.next() as f32 * meta.scale + meta.zero;
                    }
                    // Index bounds are checked (not trusted): corrupted
                    // payloads must not crash the receiving rank.
                    if let Some(slot) = xs.get_mut(sp.min_idx as usize) {
                        *slot = sp.min_val;
                    }
                    if let Some(slot) = xs.get_mut(sp.max_idx as usize) {
                        *slot = sp.max_val;
                    }
                }
            }
        }
        Codec::Hadamard { group_size, .. } => {
            let gs = group_size as usize;
            for (xs, &meta) in out.chunks_mut(gs).zip(metas) {
                if sum {
                    let rot = &mut scratch[..xs.len()];
                    for v in rot.iter_mut() {
                        *v = src.next() as f32 * meta.scale + meta.zero;
                    }
                    if rot.len() == gs {
                        hadamard::fwht_normalized(rot);
                    }
                    for (a, v) in xs.iter_mut().zip(rot.iter()) {
                        *a += *v;
                    }
                } else {
                    for x in xs.iter_mut() {
                        *x = src.next() as f32 * meta.scale + meta.zero;
                    }
                    if xs.len() == gs {
                        hadamard::fwht_normalized(xs); // orthonormal inverse
                    }
                }
            }
        }
        Codec::LogFmt { bits, group_size } => {
            let gs = group_size as usize;
            for (xs, &meta) in out.chunks_mut(gs).zip(logmetas) {
                let dec = logfmt::GroupDecoder::new(meta, bits);
                if sum {
                    for x in xs {
                        *x += dec.decode(src.next());
                    }
                } else {
                    for x in xs {
                        *x = dec.decode(src.next());
                    }
                }
            }
        }
    }
}

/// Fused decode (`sum == false`) or decode-accumulate (`sum == true`) of a
/// payload body (everything after the wire header). The caller has already
/// validated the total length against `wire_len`, so every section slice
/// below is in range; the metadata parsers still validate their own sizes.
///
/// All metadata is parsed *before* the first element is touched, so an
/// error leaves `out` unmodified.
pub(crate) fn decode_body(
    codec: &Codec,
    n: usize,
    body: &[u8],
    bufs: &mut CodecBuffers,
    out: &mut [f32],
    threads: usize,
    sum: bool,
) -> Result<()> {
    let bits = codec.bits();
    let gs = codec.group_size();
    let g = rtn::num_groups(n, gs);
    let qlen = packed_len(bits, n);
    let section = &body[..qlen];
    let meta_bytes = &body[qlen..];
    match *codec {
        // lint: allow(panic, "encode_with/decode_with route Bf16 away before dispatching here")
        Codec::Bf16 => unreachable!("bf16 bypasses the fused kernels"),
        Codec::Rtn { scale_mode, .. } => {
            wire::read_group_metas(meta_bytes, g, scale_mode, &mut bufs.metas)?;
        }
        Codec::Spike { scale_mode, .. } => {
            let mode = if scale_mode == ScaleMode::IntLog { 1 } else { 0 };
            let sz = g * wire::scale_zero_bytes_per_group(mode);
            wire::read_group_metas(&meta_bytes[..sz], g, scale_mode, &mut bufs.metas)?;
            wire::read_spikes(&meta_bytes[sz..], g, scale_mode, &mut bufs.spikes)?;
        }
        Codec::Hadamard { .. } => {
            wire::read_group_metas(meta_bytes, g, ScaleMode::Bf16, &mut bufs.metas)?;
        }
        Codec::LogFmt { .. } => {
            wire::read_log_metas(meta_bytes, g, &mut bufs.logmetas)?;
        }
    }
    let (workers, per) = plan(n, gs, threads);
    if sum && matches!(codec, Codec::Hadamard { .. }) {
        bufs.scratch.clear();
        bufs.scratch.resize(workers * gs, 0.0);
    }
    if workers <= 1 {
        run_decode(
            codec,
            DecJob {
                out,
                src: PlaneSource::new_at(bits, n, section, 0),
                metas: &bufs.metas,
                spikes: &bufs.spikes,
                logmetas: &bufs.logmetas,
                scratch: &mut bufs.scratch,
            },
            sum,
        );
        return Ok(());
    }
    let metas = &bufs.metas;
    let spikes = &bufs.spikes;
    let logmetas = &bufs.logmetas;
    let mut out_rest = out;
    let mut scratch_rest = bufs.scratch.as_mut_slice();
    let codec = *codec;
    std::thread::scope(|s| {
        for wi in 0..workers {
            let a = wi * per;
            let take = per.min(n - a);
            let chunk = carve(&mut out_rest, take);
            let g0 = a / gs;
            let g_take = take.div_ceil(gs);
            let scratch = carve(&mut scratch_rest, gs);
            let job = DecJob {
                out: chunk,
                src: PlaneSource::new_at(bits, n, section, a),
                metas: sub(metas, g0, g_take),
                spikes: sub(spikes, g0, g_take),
                logmetas: sub(logmetas, g0, g_take),
                scratch,
            };
            s.spawn(move || run_decode(&codec, job, sum));
        }
    });
    Ok(())
}

/// Clamped subslice: the store a codec does not use may hold stale lengths
/// from an earlier call with a different scheme; its contents are never
/// read, so an empty/short slice is fine.
fn sub<T>(v: &[T], start: usize, len: usize) -> &[T] {
    let a = start.min(v.len());
    &v[a..(a + len).min(v.len())]
}

#[cfg(test)]
mod tests {
    use super::super::bitsplit;
    use super::*;
    use crate::util::Prng;

    #[test]
    fn sink_matches_pack_for_all_widths_and_tails() {
        let mut rng = Prng::new(90);
        for bits in 1..=8u8 {
            let mask = ((1u16 << bits) - 1) as u8;
            for n in [1usize, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127] {
                let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() as u8) & mask).collect();
                let mut packed = Vec::new();
                bitsplit::pack(&codes, bits, &mut packed);
                let mut fused = vec![0u8; packed.len()];
                let mut sink = PlaneSink::new(bits, n, &mut fused);
                for &c in &codes {
                    sink.push(c);
                }
                sink.finish();
                assert_eq!(fused, packed, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn source_matches_unpack_for_all_widths_and_tails() {
        let mut rng = Prng::new(91);
        for bits in 1..=8u8 {
            let mask = ((1u16 << bits) - 1) as u8;
            for n in [1usize, 7, 8, 9, 16, 17, 33, 64, 65, 128, 129] {
                let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() as u8) & mask).collect();
                let mut packed = Vec::new();
                bitsplit::pack(&codes, bits, &mut packed);
                let mut src = PlaneSource::new_at(bits, n, &packed, 0);
                let streamed: Vec<u8> = (0..n).map(|_| src.next()).collect();
                assert_eq!(streamed, codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn source_offset_start_matches_suffix() {
        let mut rng = Prng::new(92);
        for bits in [2u8, 5, 7] {
            let mask = ((1u16 << bits) - 1) as u8;
            let n = 100;
            let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() as u8) & mask).collect();
            let mut packed = Vec::new();
            bitsplit::pack(&codes, bits, &mut packed);
            for start in [8usize, 16, 64, 96] {
                let mut src = PlaneSource::new_at(bits, n, &packed, start);
                let streamed: Vec<u8> = (start..n).map(|_| src.next()).collect();
                assert_eq!(streamed, &codes[start..], "bits={bits} start={start}");
            }
        }
    }

    #[test]
    fn chunk_align_is_lcm_of_group_and_8() {
        assert_eq!(chunk_align(32), 32);
        assert_eq!(chunk_align(128), 128);
        assert_eq!(chunk_align(12), 24);
        assert_eq!(chunk_align(7), 56);
        assert_eq!(chunk_align(1), 8);
        assert_eq!(chunk_align(96), 96);
    }

    #[test]
    fn plan_respects_threshold_and_alignment() {
        let (w, _) = plan(1000, 32, 8);
        assert_eq!(w, 1, "below PAR_MIN_ELEMS stays serial");
        let (w, per) = plan(PAR_MIN_ELEMS, 32, 4);
        assert!(w > 1 && w <= 4);
        assert_eq!(per % chunk_align(32), 0);
        assert!((w - 1) * per < PAR_MIN_ELEMS && w * per >= PAR_MIN_ELEMS);
        let (w, _) = plan(1 << 20, 32, 1);
        assert_eq!(w, 1, "threads=1 stays serial");
    }
}
