//! Bit splitting (paper §Bit Splitting, Fig. 3): decompose an irregular bit
//! width into regular planes — 4-bit and 2-bit units plus a standalone
//! extra bit — so every plane packs word-aligned:
//!
//! ```text
//!   8 = 4+4     7 = 4+2+1     6 = 4+2     5 = 4+1
//!   4 = 4       3 = 2+1       2 = 2       1 = 1
//! ```
//!
//! Planes are assigned from the LSB up (an INT5 code `q` stores `q & 0xF`
//! in the 4-bit plane and `q >> 4` in the 1-bit plane, matching Fig. 3's
//! "first 4 bits and an extra singular bit"). All values of one plane are
//! stored contiguously ("all 4-bit parts are saved together, so are the
//! extra bits"), each plane padded to a byte boundary.
//!
//! The packers use the "fast packing" strategy of Flash Communication V1:
//! branch-free u64 gathers of 8 codes at a time.

/// Plane widths (in bits, LSB-first) for each supported width.
pub fn planes_for(bits: u8) -> &'static [u8] {
    match bits {
        1 => &[1],
        2 => &[2],
        3 => &[2, 1],
        4 => &[4],
        5 => &[4, 1],
        6 => &[4, 2],
        7 => &[4, 2, 1],
        8 => &[4, 4],
        _ => panic!("unsupported bit width {bits}"),
    }
}

/// Bytes one plane of width `w` needs for `n` values.
#[inline]
pub fn plane_len(w: u8, n: usize) -> usize {
    match w {
        4 => n.div_ceil(2),
        2 => n.div_ceil(4),
        1 => n.div_ceil(8),
        _ => unreachable!("plane width {w}"),
    }
}

/// Total packed length for `n` codes of `bits` width (sum over planes).
pub fn packed_len(bits: u8, n: usize) -> usize {
    planes_for(bits).iter().map(|&w| plane_len(w, n)).sum()
}

#[inline(always)]
fn load8(codes: &[u8], i: usize) -> u64 {
    // Load up to 8 codes starting at i as a little-endian u64 (tail-safe).
    let rem = codes.len() - i;
    if rem >= 8 {
        u64::from_le_bytes(codes[i..i + 8].try_into().unwrap())
    } else {
        let mut b = [0u8; 8];
        b[..rem].copy_from_slice(&codes[i..]);
        u64::from_le_bytes(b)
    }
}

/// Pack one plane: extract `w` bits at `shift` from each code.
fn pack_plane(codes: &[u8], w: u8, shift: u8, out: &mut Vec<u8>) {
    let n = codes.len();
    match w {
        4 => {
            // 2 codes/byte: out = lo | hi<<4.
            let mut i = 0;
            while i + 8 <= n {
                let v = (load8(codes, i) >> shift) & 0x0F0F_0F0F_0F0F_0F0F;
                // Fold adjacent nibble pairs: byte k = nib(2k) | nib(2k+1)<<4.
                let folded = (v | (v >> 4)) & 0x00FF_00FF_00FF_00FF;
                let b = folded | (folded >> 8);
                out.push(b as u8);
                out.push((b >> 16) as u8);
                out.push((b >> 32) as u8);
                out.push((b >> 48) as u8);
                i += 8;
            }
            while i < n {
                let lo = (codes[i] >> shift) & 0xF;
                let hi = if i + 1 < n { (codes[i + 1] >> shift) & 0xF } else { 0 };
                out.push(lo | (hi << 4));
                i += 2;
            }
        }
        2 => {
            // 4 codes/byte.
            let mut i = 0;
            while i + 8 <= n {
                let v = (load8(codes, i) >> shift) & 0x0303_0303_0303_0303;
                let p1 = (v | (v >> 6)) & 0x000F_000F_000F_000F; // pairs per u16
                let b = p1 | (p1 >> 12); // byte per u32
                out.push(b as u8);
                out.push((b >> 32) as u8);
                i += 8;
            }
            while i < n {
                let mut byte = 0u8;
                for k in 0..4 {
                    if i + k < n {
                        byte |= ((codes[i + k] >> shift) & 0x3) << (2 * k);
                    }
                }
                out.push(byte);
                i += 4;
            }
        }
        1 => {
            // 8 codes/byte.
            let mut i = 0;
            while i < n {
                let v = (load8(codes, i) >> shift) & 0x0101_0101_0101_0101;
                // Gather the 8 lsbs into one byte (bit i of the result is
                // the lsb of byte i — the classic 0x0102…80 multiply).
                let byte = (v.wrapping_mul(0x0102_0408_1020_4080) >> 56) as u8;
                let valid = (n - i).min(8);
                out.push(byte & (0xFFu16 >> (8 - valid)) as u8);
                i += 8;
            }
        }
        _ => unreachable!(),
    }
}

/// Unpack one plane, OR-ing `w` bits at `shift` into each code slot.
fn unpack_plane(bytes: &[u8], w: u8, shift: u8, codes: &mut [u8]) {
    let n = codes.len();
    match w {
        4 => {
            for (i, c) in codes.iter_mut().enumerate() {
                let b = bytes[i / 2];
                let nib = if i % 2 == 0 { b & 0xF } else { b >> 4 };
                *c |= nib << shift;
            }
        }
        2 => {
            for (i, c) in codes.iter_mut().enumerate() {
                let b = bytes[i / 4];
                *c |= ((b >> (2 * (i % 4))) & 0x3) << shift;
            }
        }
        1 => {
            for (i, c) in codes.iter_mut().enumerate() {
                let b = bytes[i / 8];
                *c |= ((b >> (i % 8)) & 0x1) << shift;
            }
        }
        _ => unreachable!(),
    }
    let _ = n;
}

/// Pack `codes` (each < 2^bits) into bit-split planes appended to `out`.
pub fn pack(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    out.reserve(packed_len(bits, codes.len()));
    let mut shift = 0u8;
    for &w in planes_for(bits) {
        pack_plane(codes, w, shift, out);
        shift += w;
    }
}

/// Unpack `n` codes of width `bits` from `bytes` (must be `packed_len` long).
pub fn unpack(bytes: &[u8], bits: u8, n: usize, codes: &mut Vec<u8>) {
    assert_eq!(bytes.len(), packed_len(bits, n), "packed buffer length mismatch");
    codes.clear();
    codes.resize(n, 0);
    let mut shift = 0u8;
    let mut off = 0usize;
    for &w in planes_for(bits) {
        let len = plane_len(w, n);
        unpack_plane(&bytes[off..off + len], w, shift, codes);
        off += len;
        shift += w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::cases;
    use crate::util::Prng;

    #[test]
    fn plane_decomposition_sums_to_bits() {
        for bits in 1..=8u8 {
            let total: u8 = planes_for(bits).iter().sum();
            assert_eq!(total, bits, "planes for {bits}");
        }
    }

    #[test]
    fn packed_len_matches_paper_int5() {
        // Fig. 3: INT5 over 4096 values = 2048 B (4-bit) + 512 B (1-bit).
        assert_eq!(packed_len(5, 4096), 2048 + 512);
        // INT2 over 4096 = 1024 B (Table 4 "Quantized" column).
        assert_eq!(packed_len(2, 4096), 1024);
    }

    #[test]
    fn compression_ratio_is_bits_over_8() {
        for bits in 1..=8u8 {
            let n = 4096;
            let expect = (bits as usize * n).div_ceil(8);
            assert_eq!(packed_len(bits, n), expect, "bits={bits}");
        }
    }

    #[test]
    fn roundtrip_exhaustive_small() {
        // Every code value, every bit width, every small length.
        for bits in 1..=8u8 {
            let qmax = 1u16 << bits;
            for n in 1..=33usize {
                let codes: Vec<u8> = (0..n).map(|i| (i as u16 % qmax) as u8).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                assert_eq!(packed.len(), packed_len(bits, n));
                let mut back = Vec::new();
                unpack(&packed, bits, n, &mut back);
                assert_eq!(codes, back, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn roundtrip_property_random() {
        cases(200, 200, |rng| {
            let bits = 1 + rng.below(8) as u8;
            let n = 1 + rng.below(5000);
            let mask = ((1u16 << bits) - 1) as u8;
            let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() as u8) & mask).collect();
            let mut packed = Vec::new();
            pack(&codes, bits, &mut packed);
            let mut back = Vec::new();
            unpack(&packed, bits, n, &mut back);
            assert_eq!(codes, back, "bits={bits} n={n}");
        });
    }

    #[test]
    fn planes_are_contiguous_per_fig3() {
        // For INT5, flipping a value's high bit must only change the 1-bit
        // plane region (after the 4-bit plane region).
        let n = 64;
        let a = vec![0u8; n];
        let mut b = vec![0u8; n];
        b[10] = 0b10000; // only bit 4 set
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        pack(&a, 5, &mut pa);
        pack(&b, 5, &mut pb);
        let four_bit_region = plane_len(4, n);
        assert_eq!(pa[..four_bit_region], pb[..four_bit_region], "4-bit plane must not change");
        assert_ne!(pa[four_bit_region..], pb[four_bit_region..], "1-bit plane must change");
    }

    #[test]
    fn fast_path_matches_scalar_tail_path() {
        // Lengths straddling the 8-wide fast path boundary.
        let mut rng = Prng::new(77);
        for bits in [2u8, 4, 5, 7] {
            let mask = ((1u16 << bits) - 1) as u8;
            for n in [7usize, 8, 9, 15, 16, 17, 23, 64, 65] {
                let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() as u8) & mask).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                let mut back = Vec::new();
                unpack(&packed, bits, n, &mut back);
                assert_eq!(codes, back, "bits={bits} n={n}");
            }
        }
    }
}
