//! Bit splitting (paper §Bit Splitting, Fig. 3): decompose an irregular bit
//! width into regular planes — 4-bit and 2-bit units plus a standalone
//! extra bit — so every plane packs word-aligned:
//!
//! ```text
//!   8 = 4+4     7 = 4+2+1     6 = 4+2     5 = 4+1
//!   4 = 4       3 = 2+1       2 = 2       1 = 1
//! ```
//!
//! Planes are assigned from the LSB up (an INT5 code `q` stores `q & 0xF`
//! in the 4-bit plane and `q >> 4` in the 1-bit plane, matching Fig. 3's
//! "first 4 bits and an extra singular bit"). All values of one plane are
//! stored contiguously ("all 4-bit parts are saved together, so are the
//! extra bits"), each plane padded to a byte boundary.
//!
//! The packers use the "fast packing" strategy of Flash Communication V1:
//! branch-free u64 gathers of 8 codes at a time.

/// Plane widths (in bits, LSB-first) for each supported width.
pub fn planes_for(bits: u8) -> &'static [u8] {
    match bits {
        1 => &[1],
        2 => &[2],
        3 => &[2, 1],
        4 => &[4],
        5 => &[4, 1],
        6 => &[4, 2],
        7 => &[4, 2, 1],
        8 => &[4, 4],
        // lint: allow(panic, "Codec::validate rejects bits outside 1..=8 before any kernel runs")
        _ => panic!("unsupported bit width {bits}"),
    }
}

/// Bytes one plane of width `w` needs for `n` values.
#[inline]
pub fn plane_len(w: u8, n: usize) -> usize {
    match w {
        4 => n.div_ceil(2),
        2 => n.div_ceil(4),
        1 => n.div_ceil(8),
        // lint: allow(panic, "planes_for only ever yields widths 4, 2, and 1")
        _ => unreachable!("plane width {w}"),
    }
}

/// Total packed length for `n` codes of `bits` width (sum over planes).
pub fn packed_len(bits: u8, n: usize) -> usize {
    planes_for(bits).iter().map(|&w| plane_len(w, n)).sum()
}

/// Load up to `k` little-endian bytes starting at `off` (tail-safe: short
/// or out-of-range reads zero-pad). The one u64 loader shared by
/// `pack_plane`, `unpack_plane`, and the fused kernels.
#[inline(always)]
pub(crate) fn load_le(bytes: &[u8], off: usize, k: usize) -> u64 {
    if k == 8 && bytes.len() >= off + 8 {
        // lint: allow(panic, "the length check above guarantees an 8-byte slice")
        return u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap());
    }
    if off >= bytes.len() {
        return 0;
    }
    let avail = (bytes.len() - off).min(k);
    let mut b = [0u8; 8];
    b[..avail].copy_from_slice(&bytes[off..off + avail]);
    u64::from_le_bytes(b)
}

// --- SWAR block primitives ----------------------------------------------
//
// One u64 holds 8 codes, one per byte (little-endian element order). The
// `fold*` functions compress the plane bits of those 8 codes into the
// plane's wire bytes; the `spread*` functions are their exact inverses.
// They are shared by `pack_plane`/`unpack_plane` here and by the fused
// single-pass kernels in [`super::fused`], which is what guarantees the
// fused encoder stays bit-identical to this packer.

/// Fold the low nibble of each of 8 code bytes into 4 wire bytes
/// (returned at bit offsets 0, 16, 32, 48 of the result).
#[inline(always)]
pub(crate) fn fold4(v: u64) -> u64 {
    let v = v & 0x0F0F_0F0F_0F0F_0F0F;
    // Fold adjacent nibble pairs: byte k = nib(2k) | nib(2k+1)<<4.
    let folded = (v | (v >> 4)) & 0x00FF_00FF_00FF_00FF;
    folded | (folded >> 8)
}

/// Fold the low 2 bits of each of 8 code bytes into 2 wire bytes
/// (returned at bit offsets 0 and 32).
#[inline(always)]
pub(crate) fn fold2(v: u64) -> u64 {
    let v = v & 0x0303_0303_0303_0303;
    let p1 = (v | (v >> 6)) & 0x000F_000F_000F_000F; // pairs per u16
    p1 | (p1 >> 12) // byte per u32
}

/// Gather the lsb of each of 8 code bytes into one wire byte (bit i of the
/// result is the lsb of byte i — the classic 0x0102…80 multiply).
#[inline(always)]
pub(crate) fn fold1(v: u64) -> u8 {
    ((v & 0x0101_0101_0101_0101).wrapping_mul(0x0102_0408_1020_4080) >> 56) as u8
}

/// Spread 4 wire bytes (8 packed nibbles, passed as the low 32 bits) back
/// to one nibble per byte. Inverse of [`fold4`].
#[inline(always)]
pub(crate) fn spread4(x: u64) -> u64 {
    let y = (x | (x << 16)) & 0x0000_FFFF_0000_FFFF;
    let y = (y | (y << 8)) & 0x00FF_00FF_00FF_00FF;
    (y | (y << 4)) & 0x0F0F_0F0F_0F0F_0F0F
}

/// Spread 2 wire bytes (8 packed 2-bit fields, low 16 bits) back to one
/// field per byte. Inverse of [`fold2`].
#[inline(always)]
pub(crate) fn spread2(x: u64) -> u64 {
    let y = (x | (x << 24)) & 0x0000_00FF_0000_00FF;
    let y = (y | (y << 12)) & 0x000F_000F_000F_000F;
    (y | (y << 6)) & 0x0303_0303_0303_0303
}

/// Spread 1 wire byte (8 packed bits, low 8 bits) back to one bit per
/// byte. Inverse of [`fold1`].
#[inline(always)]
pub(crate) fn spread1(x: u64) -> u64 {
    let y = (x | (x << 28)) & 0x0000_000F_0000_000F;
    let y = (y | (y << 14)) & 0x0003_0003_0003_0003;
    (y | (y << 7)) & 0x0101_0101_0101_0101
}

/// Pack one plane: extract `w` bits at `shift` from each code.
fn pack_plane(codes: &[u8], w: u8, shift: u8, out: &mut Vec<u8>) {
    let n = codes.len();
    match w {
        4 => {
            // 2 codes/byte: out = lo | hi<<4.
            let mut i = 0;
            while i + 8 <= n {
                let b = fold4(load_le(codes, i, 8) >> shift);
                out.push(b as u8);
                out.push((b >> 16) as u8);
                out.push((b >> 32) as u8);
                out.push((b >> 48) as u8);
                i += 8;
            }
            while i < n {
                let lo = (codes[i] >> shift) & 0xF;
                let hi = if i + 1 < n { (codes[i + 1] >> shift) & 0xF } else { 0 };
                out.push(lo | (hi << 4));
                i += 2;
            }
        }
        2 => {
            // 4 codes/byte.
            let mut i = 0;
            while i + 8 <= n {
                let b = fold2(load_le(codes, i, 8) >> shift);
                out.push(b as u8);
                out.push((b >> 32) as u8);
                i += 8;
            }
            while i < n {
                let mut byte = 0u8;
                for k in 0..4 {
                    if i + k < n {
                        byte |= ((codes[i + k] >> shift) & 0x3) << (2 * k);
                    }
                }
                out.push(byte);
                i += 4;
            }
        }
        1 => {
            // 8 codes/byte.
            let mut i = 0;
            while i < n {
                let byte = fold1(load_le(codes, i, 8) >> shift);
                let valid = (n - i).min(8);
                out.push(byte & (0xFFu16 >> (8 - valid)) as u8);
                i += 8;
            }
        }
        // lint: allow(panic, "planes_for only ever yields widths 4, 2, and 1")
        _ => unreachable!(),
    }
}

/// OR a u64 of 8 spread codes into 8 consecutive code slots.
#[inline(always)]
fn or_store8(codes: &mut [u8], i: usize, v: u64) {
    // lint: allow(panic, "callers only pass i with i + 8 <= codes.len(); see the unpack loops")
    let cur = u64::from_le_bytes(codes[i..i + 8].try_into().unwrap());
    codes[i..i + 8].copy_from_slice(&(cur | v).to_le_bytes());
}

/// Unpack one plane, OR-ing `w` bits at `shift` into each code slot.
///
/// Mirrors `pack_plane`'s u64 fast paths: full 8-code blocks go through
/// the branch-free `spread*` gathers, only the tail runs per-element.
fn unpack_plane(bytes: &[u8], w: u8, shift: u8, codes: &mut [u8]) {
    let n = codes.len();
    let mut i = 0;
    match w {
        4 => {
            while i + 8 <= n {
                let x = load_le(bytes, i / 2, 4);
                or_store8(codes, i, spread4(x) << shift);
                i += 8;
            }
            for (k, c) in codes.iter_mut().enumerate().skip(i) {
                let b = bytes[k / 2];
                let nib = if k % 2 == 0 { b & 0xF } else { b >> 4 };
                *c |= nib << shift;
            }
        }
        2 => {
            while i + 8 <= n {
                let x = load_le(bytes, i / 4, 2);
                or_store8(codes, i, spread2(x) << shift);
                i += 8;
            }
            for (k, c) in codes.iter_mut().enumerate().skip(i) {
                let b = bytes[k / 4];
                *c |= ((b >> (2 * (k % 4))) & 0x3) << shift;
            }
        }
        1 => {
            while i + 8 <= n {
                or_store8(codes, i, spread1(bytes[i / 8] as u64) << shift);
                i += 8;
            }
            for (k, c) in codes.iter_mut().enumerate().skip(i) {
                let b = bytes[k / 8];
                *c |= ((b >> (k % 8)) & 0x1) << shift;
            }
        }
        // lint: allow(panic, "planes_for only ever yields widths 4, 2, and 1")
        _ => unreachable!(),
    }
}

/// Pack `codes` (each < 2^bits) into bit-split planes appended to `out`.
pub fn pack(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    out.reserve(packed_len(bits, codes.len()));
    let mut shift = 0u8;
    for &w in planes_for(bits) {
        pack_plane(codes, w, shift, out);
        shift += w;
    }
}

/// Unpack `n` codes of width `bits` from `bytes` (must be `packed_len` long).
pub fn unpack(bytes: &[u8], bits: u8, n: usize, codes: &mut Vec<u8>) {
    assert_eq!(bytes.len(), packed_len(bits, n), "packed buffer length mismatch");
    codes.clear();
    codes.resize(n, 0);
    let mut shift = 0u8;
    let mut off = 0usize;
    for &w in planes_for(bits) {
        let len = plane_len(w, n);
        unpack_plane(&bytes[off..off + len], w, shift, codes);
        off += len;
        shift += w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::cases;
    use crate::util::Prng;

    #[test]
    fn plane_decomposition_sums_to_bits() {
        for bits in 1..=8u8 {
            let total: u8 = planes_for(bits).iter().sum();
            assert_eq!(total, bits, "planes for {bits}");
        }
    }

    #[test]
    fn packed_len_matches_paper_int5() {
        // Fig. 3: INT5 over 4096 values = 2048 B (4-bit) + 512 B (1-bit).
        assert_eq!(packed_len(5, 4096), 2048 + 512);
        // INT2 over 4096 = 1024 B (Table 4 "Quantized" column).
        assert_eq!(packed_len(2, 4096), 1024);
    }

    #[test]
    fn compression_ratio_is_bits_over_8() {
        for bits in 1..=8u8 {
            let n = 4096;
            let expect = (bits as usize * n).div_ceil(8);
            assert_eq!(packed_len(bits, n), expect, "bits={bits}");
        }
    }

    #[test]
    fn roundtrip_exhaustive_small() {
        // Every code value, every bit width, every small length.
        for bits in 1..=8u8 {
            let qmax = 1u16 << bits;
            for n in 1..=33usize {
                let codes: Vec<u8> = (0..n).map(|i| (i as u16 % qmax) as u8).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                assert_eq!(packed.len(), packed_len(bits, n));
                let mut back = Vec::new();
                unpack(&packed, bits, n, &mut back);
                assert_eq!(codes, back, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn roundtrip_property_random() {
        cases(200, 200, |rng| {
            let bits = 1 + rng.below(8) as u8;
            let n = 1 + rng.below(5000);
            let mask = ((1u16 << bits) - 1) as u8;
            let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() as u8) & mask).collect();
            let mut packed = Vec::new();
            pack(&codes, bits, &mut packed);
            let mut back = Vec::new();
            unpack(&packed, bits, n, &mut back);
            assert_eq!(codes, back, "bits={bits} n={n}");
        });
    }

    #[test]
    fn planes_are_contiguous_per_fig3() {
        // For INT5, flipping a value's high bit must only change the 1-bit
        // plane region (after the 4-bit plane region).
        let n = 64;
        let a = vec![0u8; n];
        let mut b = vec![0u8; n];
        b[10] = 0b10000; // only bit 4 set
        let (mut pa, mut pb) = (Vec::new(), Vec::new());
        pack(&a, 5, &mut pa);
        pack(&b, 5, &mut pb);
        let four_bit_region = plane_len(4, n);
        assert_eq!(pa[..four_bit_region], pb[..four_bit_region], "4-bit plane must not change");
        assert_ne!(pa[four_bit_region..], pb[four_bit_region..], "1-bit plane must change");
    }

    #[test]
    fn spread_inverts_fold_through_the_wire_layout() {
        // fold* return wire bytes at the offsets pack_plane extracts them
        // from (0/16/32/48 for 4-bit, 0/32 for 2-bit); spread* consume the
        // *contiguous* wire bytes a decoder loads. Compact through the wire
        // layout, exactly as PlaneSink writes and PlaneSource reads.
        let mut rng = Prng::new(78);
        for _ in 0..2000 {
            let v = (rng.next_u64()) & 0x0F0F_0F0F_0F0F_0F0F;
            let f = fold4(v);
            let wire = (f & 0xFF)
                | ((f >> 16) & 0xFF) << 8
                | ((f >> 32) & 0xFF) << 16
                | ((f >> 48) & 0xFF) << 24;
            assert_eq!(spread4(wire), v);
            let v = v & 0x0303_0303_0303_0303;
            let f = fold2(v);
            let wire = (f & 0xFF) | ((f >> 32) & 0xFF) << 8;
            assert_eq!(spread2(wire), v);
            let v = v & 0x0101_0101_0101_0101;
            assert_eq!(spread1(fold1(v) as u64), v);
        }
    }

    #[test]
    fn fast_path_matches_scalar_tail_path() {
        // Lengths straddling the 8-wide fast path boundary.
        let mut rng = Prng::new(77);
        for bits in [2u8, 4, 5, 7] {
            let mask = ((1u16 << bits) - 1) as u8;
            for n in [7usize, 8, 9, 15, 16, 17, 23, 64, 65] {
                let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() as u8) & mask).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                let mut back = Vec::new();
                unpack(&packed, bits, n, &mut back);
                assert_eq!(codes, back, "bits={bits} n={n}");
            }
        }
    }
}
