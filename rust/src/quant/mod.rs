//! The paper's compression stack: any-bit group quantization with bit
//! splitting (Fig. 3), spike reserving (Fig. 5), the Hadamard / LogFMT
//! baselines it is compared against (Table 3), and the self-describing wire
//! format that carries the payloads through the collectives.

pub mod bitsplit;
pub mod hadamard;
pub mod logfmt;
pub mod rtn;
pub mod scheme;
pub mod spike;
pub mod wire;

pub use rtn::GroupMeta;
pub use scheme::{Codec, CodecBuffers};
pub use spike::{ScaleMode, SpikeMeta};
pub use wire::SectionSizes;
