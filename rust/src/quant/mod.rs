//! The paper's compression stack: any-bit group quantization with bit
//! splitting (Fig. 3), spike reserving (Fig. 5), the Hadamard / LogFMT
//! baselines it is compared against (Table 3), and the self-describing wire
//! format that carries the payloads through the collectives.
//!
//! The hot path is the fused single-pass kernel layer (`fused`, reached
//! through [`Codec`]): quantize→pack and unpack→dequantize(-accumulate)
//! without materializing a byte-per-value codes buffer, with optional
//! chunk parallelism for large payloads. [`reference`] keeps the scalar
//! pre-fusion pipeline alive as the bit-identity oracle
//! (`tests/codec_fused.rs`).

pub mod bitsplit;
pub(crate) mod fused;
pub mod hadamard;
pub mod logfmt;
pub mod reference;
pub mod rtn;
pub mod scheme;
pub mod spike;
pub mod wire;

pub use fused::{MAX_CODEC_THREADS, PAR_MIN_ELEMS};
pub use rtn::GroupMeta;
pub use scheme::{Codec, CodecBuffers, MAX_WIRE_ELEMS};
pub use spike::{ScaleMode, SpikeMeta};
pub use wire::SectionSizes;
