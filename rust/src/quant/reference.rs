//! Scalar reference codec — the oracle the fused kernels are pinned
//! against.
//!
//! This is the pre-fusion pipeline kept alive on purpose: quantize into a
//! byte-per-value codes buffer, pack it with a naive per-element bit loop
//! (no SWAR), unpack the same way, dequantize group by group. It shares
//! the per-group *math* (`rtn`, `spike`, `hadamard`, `logfmt`) and the
//! metadata serializers (`wire`) with the hot path, but none of the plane
//! scatter/gather machinery — so `tests/codec_fused.rs` can require the
//! fused wire bytes and decoded values to match this path bit-for-bit
//! across every codec spec and awkward length.
//!
//! Not a hot path: everything here allocates freely and runs one element
//! at a time.

use anyhow::{ensure, Result};

use super::bitsplit::{plane_len, planes_for};
use super::hadamard;
use super::logfmt;
use super::rtn;
use super::scheme::{codec_from_header, Codec};
use super::spike::{self, ScaleMode};
use super::wire::{self, Header, HEADER_LEN};
use crate::util::bf16;

/// Naive bit-split packer: one element, one plane at a time.
fn pack_scalar(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    let mut shift = 0u8;
    for &w in planes_for(bits) {
        let base = out.len();
        out.resize(base + plane_len(w, codes.len()), 0);
        for (i, &c) in codes.iter().enumerate() {
            let bit = i * w as usize;
            let field = (c >> shift) & ((1u16 << w) - 1) as u8;
            out[base + bit / 8] |= field << (bit % 8);
        }
        shift += w;
    }
}

/// Naive bit-split unpacker (inverse of [`pack_scalar`]).
fn unpack_scalar(bytes: &[u8], bits: u8, n: usize) -> Vec<u8> {
    let mut codes = vec![0u8; n];
    let mut shift = 0u8;
    let mut off = 0usize;
    for &w in planes_for(bits) {
        let plane = &bytes[off..off + plane_len(w, n)];
        for (i, c) in codes.iter_mut().enumerate() {
            let bit = i * w as usize;
            let field = (plane[bit / 8] >> (bit % 8)) & ((1u16 << w) - 1) as u8;
            *c |= field << shift;
        }
        off += plane_len(w, n);
        shift += w;
    }
    codes
}

/// Reference encode: header, quantize-to-codes, scalar pack, metadata.
pub fn encode(codec: &Codec, data: &[f32]) -> Vec<u8> {
    // lint: allow(panic, "reference path mirrors Codec::encode: invalid codecs die loudly")
    codec.validate().expect("invalid codec");
    let n = data.len();
    let mut out = Vec::with_capacity(codec.wire_len(n));
    codec.header(n).write(&mut out);
    let mut codes = Vec::new();
    let mut metas = Vec::new();
    match *codec {
        Codec::Bf16 => bf16::encode_slice(data, &mut out),
        Codec::Rtn { bits, group_size, scale_mode } => {
            let gs = group_size as usize;
            codes.resize(n, 0);
            for (xs, cs) in data.chunks(gs).zip(codes.chunks_mut(gs)) {
                let (mn, mx) = rtn::minmax(xs);
                let meta = match scale_mode {
                    ScaleMode::Bf16 => rtn::meta_from_minmax(mn, mx, bits),
                    ScaleMode::IntLog => {
                        spike::meta_through_intlog(rtn::meta_from_minmax(mn, mx, bits))
                    }
                };
                rtn::quantize_group_with_meta(xs, bits, meta, cs);
                metas.push(meta);
            }
            pack_scalar(&codes, bits, &mut out);
            wire::write_group_metas(&metas, scale_mode, &mut out);
        }
        Codec::Spike { bits, group_size, scale_mode } => {
            let mut spikes = Vec::new();
            spike::quantize(
                data,
                bits,
                group_size as usize,
                scale_mode,
                &mut codes,
                &mut metas,
                &mut spikes,
            );
            pack_scalar(&codes, bits, &mut out);
            wire::write_group_metas(&metas, scale_mode, &mut out);
            wire::write_spikes(&spikes, scale_mode, &mut out);
        }
        Codec::Hadamard { bits, group_size } => {
            hadamard::quantize(data, bits, group_size as usize, &mut codes, &mut metas);
            pack_scalar(&codes, bits, &mut out);
            wire::write_group_metas(&metas, ScaleMode::Bf16, &mut out);
        }
        Codec::LogFmt { bits, group_size } => {
            let mut logmetas = Vec::new();
            logfmt::quantize(data, bits, group_size as usize, &mut codes, &mut logmetas);
            pack_scalar(&codes, bits, &mut out);
            wire::write_log_metas(&logmetas, &mut out);
        }
    }
    assert_eq!(out.len(), codec.wire_len(n), "reference wire_len mismatch");
    out
}

/// Reference decode into a fresh Vec.
pub fn decode(wire_bytes: &[u8]) -> Result<Vec<f32>> {
    let h = Header::parse(wire_bytes)?;
    let n = h.n as usize;
    let codec = codec_from_header(&h)?;
    ensure!(
        wire_bytes.len() == codec.wire_len(n),
        "payload length {} != expected {}",
        wire_bytes.len(),
        codec.wire_len(n)
    );
    let body = &wire_bytes[HEADER_LEN..];
    let mut out = vec![0f32; n];
    let mut metas = Vec::new();
    match codec {
        Codec::Bf16 => bf16::decode_slice(body, &mut out),
        Codec::Rtn { bits, group_size, scale_mode } => {
            let gs = group_size as usize;
            let g = rtn::num_groups(n, gs);
            let qlen = super::bitsplit::packed_len(bits, n);
            let codes = unpack_scalar(&body[..qlen], bits, n);
            wire::read_group_metas(&body[qlen..], g, scale_mode, &mut metas)?;
            rtn::dequantize(&codes, &metas, gs, &mut out);
        }
        Codec::Spike { bits, group_size, scale_mode } => {
            let gs = group_size as usize;
            let g = rtn::num_groups(n, gs);
            let qlen = super::bitsplit::packed_len(bits, n);
            let codes = unpack_scalar(&body[..qlen], bits, n);
            let mode = if scale_mode == ScaleMode::IntLog { 1 } else { 0 };
            let sz = g * wire::scale_zero_bytes_per_group(mode);
            wire::read_group_metas(&body[qlen..qlen + sz], g, scale_mode, &mut metas)?;
            let mut spikes = Vec::new();
            wire::read_spikes(&body[qlen + sz..], g, scale_mode, &mut spikes)?;
            spike::dequantize(&codes, &metas, &spikes, gs, &mut out);
        }
        Codec::Hadamard { bits, group_size } => {
            let gs = group_size as usize;
            let g = rtn::num_groups(n, gs);
            let qlen = super::bitsplit::packed_len(bits, n);
            let codes = unpack_scalar(&body[..qlen], bits, n);
            wire::read_group_metas(&body[qlen..], g, ScaleMode::Bf16, &mut metas)?;
            hadamard::dequantize(&codes, &metas, gs, &mut out);
        }
        Codec::LogFmt { bits, group_size } => {
            let gs = group_size as usize;
            let g = rtn::num_groups(n, gs);
            let qlen = super::bitsplit::packed_len(bits, n);
            let codes = unpack_scalar(&body[..qlen], bits, n);
            let mut logmetas = Vec::new();
            wire::read_log_metas(&body[qlen..], g, &mut logmetas)?;
            logfmt::dequantize(&codes, &logmetas, bits, gs, &mut out);
        }
    }
    Ok(out)
}

/// Reference decode-accumulate: decode into scratch, then element-wise add
/// (the shape of the pre-fusion fallback path — one add per element, so
/// values are bit-identical to the fused dequantize-accumulate).
pub fn decode_sum(wire_bytes: &[u8], acc: &mut [f32]) -> Result<()> {
    let decoded = decode(wire_bytes)?;
    ensure!(decoded.len() == acc.len(), "decode_sum length mismatch");
    for (a, d) in acc.iter_mut().zip(&decoded) {
        *a += *d;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn scalar_pack_roundtrips() {
        let mut rng = Prng::new(81);
        for bits in 1..=8u8 {
            let mask = ((1u16 << bits) - 1) as u8;
            for n in [1usize, 7, 8, 9, 33, 100] {
                let codes: Vec<u8> = (0..n).map(|_| (rng.next_u32() as u8) & mask).collect();
                let mut packed = Vec::new();
                pack_scalar(&codes, bits, &mut packed);
                assert_eq!(packed.len(), super::super::bitsplit::packed_len(bits, n));
                assert_eq!(unpack_scalar(&packed, bits, n), codes, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn reference_roundtrips_all_schemes() {
        let mut rng = Prng::new(82);
        let mut data = vec![0f32; 200];
        rng.fill_activations(&mut data, 1.0);
        for spec in ["bf16", "int8", "int5", "int2-sr@32", "int2-sr@32!", "int4-had@32",
            "int3-log@32"]
        {
            let c = Codec::parse(spec).unwrap();
            let wire = encode(&c, &data);
            assert_eq!(wire.len(), c.wire_len(200), "{spec}");
            let out = decode(&wire).unwrap();
            assert!(out.iter().all(|x| x.is_finite()), "{spec}");
        }
    }
}
