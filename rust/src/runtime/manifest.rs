//! Parser for `artifacts/manifest.txt` — the line-based contract between
//! `python/compile/aot.py` and the rust runtime (no serde in the offline
//! vendor set, so the format is deliberately trivial):
//!
//! ```text
//! # comment
//! config tiny vocab=2048 d_model=256 ... n_params=3674624
//! artifact tiny_embed kind=piece config=tiny
//! corpus vocab=2048 file=corpus_v2048.bin tokens=600000
//! ```

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One `key=value` record.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Record {
    pub name: String,
    pub fields: HashMap<String, String>,
}

impl Record {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.fields.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .with_context(|| format!("missing field {key} in {}", self.name))?
            .parse()
            .with_context(|| format!("field {key} in {}", self.name))
    }
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub configs: Vec<Record>,
    pub artifacts: Vec<Record>,
    pub corpora: Vec<Record>,
    /// Part-of-speech vocabulary pools (Table 7 task definitions).
    pub pools: Vec<Record>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kind = parts.next().unwrap();
            let mut rec = Record::default();
            let mut rest: Vec<&str> = parts.collect();
            if kind != "corpus" {
                if rest.is_empty() {
                    bail!("line {}: missing name", lineno + 1);
                }
                rec.name = rest.remove(0).to_string();
            }
            for kv in rest {
                match kv.split_once('=') {
                    Some((k, v)) => {
                        rec.fields.insert(k.to_string(), v.to_string());
                    }
                    None => bail!("line {}: bad field '{kv}'", lineno + 1),
                }
            }
            match kind {
                "config" => m.configs.push(rec),
                "artifact" => m.artifacts.push(rec),
                "corpus" => m.corpora.push(rec),
                "pool" => m.pools.push(rec),
                other => bail!("line {}: unknown record kind '{other}'", lineno + 1),
            }
        }
        Ok(m)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        Manifest::parse(&text)
    }

    pub fn config(&self, name: &str) -> Result<&Record> {
        self.configs
            .iter()
            .find(|c| c.name == name)
            .with_context(|| format!("config '{name}' not in manifest"))
    }

    pub fn corpus_for_vocab(&self, vocab: usize) -> Option<&Record> {
        self.corpora.iter().find(|c| c.get("vocab") == Some(vocab.to_string().as_str()))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.iter().any(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line
config tiny vocab=2048 d_model=256 n_params=3674624
artifact tiny_embed kind=piece config=tiny
artifact qdq_rtn_b8_gs128 kind=qdq n=4096 bits=8 gs=128 scheme=rtn
corpus vocab=2048 file=corpus_v2048.bin tokens=600000
";

    #[test]
    fn parses_all_record_kinds() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.configs.len(), 1);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.corpora.len(), 1);
        let c = m.config("tiny").unwrap();
        assert_eq!(c.get_usize("vocab").unwrap(), 2048);
        assert_eq!(c.get_usize("n_params").unwrap(), 3674624);
        assert!(m.has_artifact("tiny_embed"));
        assert!(!m.has_artifact("missing"));
        assert_eq!(m.corpus_for_vocab(2048).unwrap().get("file").unwrap(), "corpus_v2048.bin");
        assert!(m.corpus_for_vocab(4096).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("bogus tiny a=1").is_err());
        assert!(Manifest::parse("config tiny novalue").is_err());
        assert!(Manifest::parse("config").is_err());
    }

    #[test]
    fn missing_config_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.config("100m").is_err());
    }
}
