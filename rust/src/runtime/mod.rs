//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Python never runs on this path — after `make artifacts` the rust binary
//! is self-contained. HLO *text* is the interchange format (xla_extension
//! 0.5.1 rejects jax≥0.5 serialized protos; the text parser reassigns
//! instruction ids — see /opt/xla-example/README.md).

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use manifest::Manifest;

/// A host-side f32 tensor (the coordinator's working representation).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Lower to an XLA literal (f32).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        // SAFETY: reinterpreting a live &[f32] as bytes — the pointer is
        // valid for len * 4 bytes, u8 has no alignment requirement, and
        // every f32 bit pattern is a valid byte sequence.
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.shape,
            bytes,
        )?)
    }

    /// Read back from an f32 literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(dims, data))
    }
}

/// Build an S32 literal from token ids (model inputs).
pub fn tokens_literal(tokens: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), shape.iter().product::<usize>());
    // SAFETY: reinterpreting a live &[i32] as bytes — the pointer is valid
    // for len * 4 bytes and u8 has no alignment requirement.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(tokens.as_ptr() as *const u8, tokens.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)?)
}

/// The PJRT runtime: client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
}

impl Runtime {
    /// Open the artifacts directory (must contain manifest.txt).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {dir:?}; run `make artifacts`"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir, cache: HashMap::new(), manifest })
    }

    /// Open ./artifacts relative to the repo root (the default layout).
    pub fn open_default() -> Result<Runtime> {
        Runtime::open(default_artifacts_dir())
    }

    /// Compile (or fetch from cache) an artifact by name, e.g.
    /// `tiny_grad_step`.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute an artifact; returns the flattened output tuple as literals.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the single
    /// output buffer is a tuple literal we explode here.
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let result = exe.execute::<xla::Literal>(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute and convert every output to a host [`Tensor`].
    pub fn execute_t(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<Tensor>> {
        self.execute(name, args)?.iter().map(Tensor::from_literal).collect()
    }

    /// Artifacts directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// `<repo>/artifacts` (works from `cargo test`/`run` and the binary).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = default_artifacts_dir();
        d.join("manifest.txt").exists().then_some(d)
    }

    #[test]
    fn tensor_roundtrip_through_literal() {
        let t = Tensor::new(vec![2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e6, -1e-6]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_and_zeros() {
        let s = Tensor::scalar(4.25);
        let back = Tensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(back.data, vec![4.25]);
        assert!(back.shape.is_empty());
        assert_eq!(Tensor::zeros(&[3, 4]).len(), 12);
    }

    #[test]
    fn qdq_artifact_matches_rust_codec() {
        // Cross-layer integration: the lowered L1 Pallas RTN kernel and the
        // rust wire codec must implement the same transformation.
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let mut rng = crate::util::Prng::new(99);
        let mut x = vec![0f32; 4096];
        rng.fill_activations(&mut x, 1.0);
        for (art, spec) in [
            ("qdq_rtn_b8_gs128", "int8@128"),
            ("qdq_rtn_b4_gs32", "int4@32"),
            ("qdq_rtn_b2_gs32", "int2@32"),
            ("qdq_spike_b2_gs32", "int2-sr@32"),
        ] {
            let input = Tensor::new(vec![4096], x.clone());
            let out = rt.execute_t(art, &[input.to_literal().unwrap()]).unwrap();
            let pallas = &out[0].data;
            let mut rust = x.clone();
            let codec = crate::quant::Codec::parse(spec).unwrap();
            let mut bufs = crate::quant::CodecBuffers::default();
            codec.qdq(&mut rust, &mut bufs);
            let mut max_err = 0f32;
            let mut worst = 0usize;
            for (i, (a, b)) in pallas.iter().zip(rust.iter()).enumerate() {
                if (a - b).abs() > max_err {
                    max_err = (a - b).abs();
                    worst = i;
                }
            }
            assert!(
                max_err < 2e-3,
                "{art} vs {spec}: max err {max_err} at {worst} (pallas {} rust {})",
                pallas[worst],
                rust[worst]
            );
        }
    }

    #[test]
    fn execute_reports_missing_artifact() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        assert!(rt.execute("no_such_artifact", &[]).is_err());
    }
}
