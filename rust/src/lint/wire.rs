//! R1 — wire-constant drift.
//!
//! The frame protocol's magic, flag bits, and header byte layout are
//! defined once, in `transport/frame.rs` (`flags`, `offsets`). Any
//! respelling of those literals elsewhere in the transport/session/comm
//! layers is drift waiting to happen: the golden wire tests pin the
//! bytes, but only if every writer actually goes through the named
//! constants. This rule flags, in non-test code outside `frame.rs`:
//!
//! - the magic string `FCT2` (checked against `code`, since the real
//!   offense is a string literal);
//! - a flag-bit hex literal (`0x01`/`0x02`/`0x04`/`0x08`) on a line that
//!   also talks about flags;
//! - a two-sided literal byte range matching a known frame/sub-header
//!   field (`[0..4]`, `[12..16]`, …).

use super::lexer::{literal_ranges, LexLine};
use super::{Finding, Rule};

/// Header/sub-header byte ranges that may only be spelled in
/// `transport/frame.rs::offsets`.
const PINNED_RANGES: [(u64, u64); 10] =
    [(0, 4), (4, 6), (6, 8), (8, 10), (10, 12), (8, 12), (12, 16), (16, 20), (20, 24), (24, 28)];

const FLAG_LITERALS: [&str; 4] = ["0x01", "0x02", "0x04", "0x08"];

fn in_scope(path: &str) -> bool {
    if path == "transport/frame.rs" {
        return false;
    }
    path.starts_with("transport/") || path.starts_with("session/") || path.starts_with("comm/")
}

pub fn check(path: &str, lines: &[LexLine], out: &mut Vec<Finding>) {
    if !in_scope(path) {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let n = i + 1;
        if line.code.contains("FCT2") {
            out.push(Finding::new(
                Rule::Wire,
                path,
                n,
                "frame magic respelled; use transport::frame::FRAME_MAGIC",
            ));
        }
        if has_flag_literal(&line.blanked) {
            out.push(Finding::new(
                Rule::Wire,
                path,
                n,
                "frame flag bit spelled as a hex literal; use transport::frame::flags",
            ));
        }
        for r in literal_ranges(&line.blanked) {
            if PINNED_RANGES.contains(&(r.lo, r.hi)) {
                let msg = format!(
                    "literal frame byte range [{}..{}]; use transport::frame::offsets",
                    r.lo, r.hi
                );
                out.push(Finding::new(Rule::Wire, path, n, msg));
            }
        }
    }
}

/// A flag-bit hex literal on a line that mentions flags. The literal must
/// end at a token boundary (`0x010` is not `0x01`; type suffixes like
/// `0x02u8` still count).
fn has_flag_literal(blanked: &str) -> bool {
    if !blanked.to_ascii_lowercase().contains("flag") {
        return false;
    }
    let bytes = blanked.as_bytes();
    for lit in FLAG_LITERALS {
        let mut from = 0;
        while let Some(p) = blanked[from..].find(lit) {
            let at = from + p;
            let end = at + lit.len();
            let after_ok = match bytes.get(end) {
                Some(&b) => !(b as char).is_ascii_hexdigit() && b != b'_',
                None => true,
            };
            if after_ok {
                return true;
            }
            from = end;
        }
    }
    false
}
