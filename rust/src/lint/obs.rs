//! R5 — observability completeness.
//!
//! Every counter the transports and the session fabric maintain must
//! reach the telemetry registry's JSON export: a counter that exists but
//! never leaves the process is a debugging session waiting to be lost.
//! The rule extracts the public field names of `TransportStats`
//! (`transport/mod.rs`), `SessionStats` (`session/mod.rs`),
//! `ClockSyncStats` (`telemetry/trace.rs`), and `StragglerReport`
//! (`telemetry/analyze.rs`) and requires each to appear, quoted, in
//! `telemetry/registry.rs` — the one snapshot/export path. Skipped
//! entirely when the registry source is not part of the scanned set
//! (fixture runs).

use super::lexer::LexLine;
use super::{Finding, Rule};

const REGISTRY: &str = "telemetry/registry.rs";
const STRUCTS: [(&str, &str); 4] = [
    ("transport/mod.rs", "TransportStats"),
    ("session/mod.rs", "SessionStats"),
    ("telemetry/trace.rs", "ClockSyncStats"),
    ("telemetry/analyze.rs", "StragglerReport"),
];

pub fn check(files: &[(String, Vec<LexLine>)], out: &mut Vec<Finding>) {
    let Some((_, reg_lines)) = files.iter().find(|(p, _)| p == REGISTRY) else {
        return;
    };
    let reg_text: String =
        reg_lines.iter().map(|l| l.code.as_str()).collect::<Vec<_>>().join("\n");
    for (file, name) in STRUCTS {
        let Some((_, lines)) = files.iter().find(|(p, _)| p == file) else {
            continue;
        };
        for (field, line_no) in struct_fields(lines, name) {
            // The registry spells keys either as a plain string literal
            // (`"messages"`) or escaped inside a JSON format string
            // (`\"messages\"`); accept both.
            let plain = format!("\"{field}\"");
            let escaped = format!("\\\"{field}\\\"");
            if !reg_text.contains(&plain) && !reg_text.contains(&escaped) {
                let msg = format!(
                    "counter `{field}` of {name} is missing from the telemetry registry export"
                );
                out.push(Finding::new(Rule::Obs, file, line_no, msg));
            }
        }
    }
}

/// Public field names (with their 1-based lines) of `struct <name>`.
fn struct_fields(lines: &[LexLine], name: &str) -> Vec<(String, usize)> {
    let header = format!("struct {name}");
    let mut out = Vec::new();
    let Some(start) = lines.iter().position(|l| !l.in_test && l.code.contains(&header)) else {
        return out;
    };
    for (j, line) in lines.iter().enumerate().skip(start + 1) {
        let t = line.code.trim();
        if t == "}" {
            break;
        }
        if let Some(rest) = t.strip_prefix("pub ") {
            if let Some(colon) = rest.find(':') {
                let field = rest[..colon].trim().to_string();
                if !field.is_empty() && field.chars().all(crate::lint::lexer::is_ident_char) {
                    out.push((field, j + 1));
                }
            }
        }
    }
    out
}
