//! R4 — unsafe audit trail.
//!
//! Every `unsafe` block in the tree must carry a `SAFETY:` comment — on
//! the same line or in the contiguous comment block immediately above —
//! stating why the invariants hold. The rule applies to the whole crate
//! (non-test code); there is no path scoping, because an unaudited cast
//! in `model/` corrupts checkpoints just as surely as one in `quant/`
//! corrupts the wire.

use super::lexer::{has_word, LexLine};
use super::{Finding, Rule};

/// Spelled as data so this module never contains the keyword as a code
/// token (the lexer blanks string contents, so flashlint's own sources
/// pass flashlint).
const UNSAFE_WORD: &str = "unsafe";
const SAFETY_TAG: &str = "SAFETY:";

pub fn check(path: &str, lines: &[LexLine], out: &mut Vec<Finding>) {
    for (i, line) in lines.iter().enumerate() {
        if line.in_test || !has_word(&line.blanked, UNSAFE_WORD) {
            continue;
        }
        if line.comment.contains(SAFETY_TAG) || preceded_by_safety(lines, i) {
            continue;
        }
        let msg = format!("`{UNSAFE_WORD}` without a `{SAFETY_TAG}` comment justifying it");
        out.push(Finding::new(Rule::Unsafe, path, i + 1, msg));
    }
}

/// Walk the contiguous run of comment-only lines directly above `i`.
fn preceded_by_safety(lines: &[LexLine], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let prev = &lines[j];
        if !prev.code.trim().is_empty() {
            return false; // a code line ends the comment block
        }
        if prev.comment.contains(SAFETY_TAG) {
            return true;
        }
        if prev.comment.trim().is_empty() {
            return false; // blank line ends the comment block
        }
    }
    false
}
