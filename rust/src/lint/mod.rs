//! flashlint — repo-native static analysis for the invariants the type
//! system can't see.
//!
//! Five rules, each over comment/string-aware lexed source (never raw
//! text), each skipping `#[cfg(test)]` / `mod tests` code:
//!
//! | rule     | invariant |
//! |----------|-----------|
//! | `wire`   | frame magic/flags/offsets spelled only in `transport/frame.rs` |
//! | `panic`  | no panic paths in transport/session/comm/quant/plan |
//! | `lock`   | no blocking call while a lock guard is live |
//! | `unsafe` | every `unsafe` block carries a `SAFETY:` comment |
//! | `obs`    | every transport/session counter reaches the telemetry export |
//!
//! A justified exception is written at the site, on the offending line or
//! the comment-only line directly above it:
//!
//! ```text
//! // lint: allow(<rule>, "<why>")
//! ```
//!
//! The reason string is mandatory — a directive without one is malformed
//! and suppresses nothing. Run as `flashcomm lint` or the standalone
//! `flashlint` binary; both exit non-zero on findings. DESIGN.md §14 has
//! the rule catalogue and the how-to-add-a-rule recipe.

pub mod lexer;
mod lock;
mod obs;
mod panic;
mod unsafety;
mod wire;

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use lexer::LexLine;

/// The rule a finding belongs to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Rule {
    Wire,
    Panic,
    Lock,
    Unsafe,
    Obs,
}

impl Rule {
    pub const ALL: [Rule; 5] = [Rule::Wire, Rule::Panic, Rule::Lock, Rule::Unsafe, Rule::Obs];

    /// The key used in allow directives and the JSON report.
    pub fn key(self) -> &'static str {
        match self {
            Rule::Wire => "wire",
            Rule::Panic => "panic",
            Rule::Lock => "lock",
            Rule::Unsafe => "unsafe",
            Rule::Obs => "obs",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.key())
    }
}

/// One lint violation at a source line.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    /// Path relative to `src/`, unix separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

impl Finding {
    fn new(rule: Rule, file: &str, line: usize, message: impl Into<String>) -> Finding {
        Finding { rule, file: file.to_string(), line, message: message.into() }
    }
}

/// A full run's results.
pub struct Report {
    pub findings: Vec<Finding>,
    /// Number of source files scanned.
    pub files: usize,
}

impl Report {
    pub fn count(&self, rule: Rule) -> usize {
        self.findings.iter().filter(|f| f.rule == rule).count()
    }

    /// Human-readable listing, one finding per line, then a summary.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("src/{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        if self.findings.is_empty() {
            out.push_str(&format!(
                "flashlint: clean ({} rules over {} files)\n",
                Rule::ALL.len(),
                self.files
            ));
        } else {
            out.push_str(&format!("flashlint: {} finding(s)\n", self.findings.len()));
        }
        out
    }

    /// Machine-readable report (the CI artifact).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
                f.rule,
                json_escape(&f.file),
                f.line,
                json_escape(&f.message)
            ));
        }
        if !self.findings.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("],\n  \"counts\": {");
        for (i, r) in Rule::ALL.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{}\": {}", r, self.count(*r)));
        }
        s.push_str("},\n");
        let total = self.findings.len();
        s.push_str(&format!("  \"files\": {},\n  \"total\": {}\n}}\n", self.files, total));
        s
    }
}

/// Lint the crate rooted at `root` (the directory holding `src/`).
pub fn run(root: &Path) -> Result<Report> {
    let src = root.join("src");
    ensure!(src.is_dir(), "no src/ directory under {}", root.display());
    let mut paths = Vec::new();
    collect_rs(&src, &src, &mut paths)?;
    paths.sort();
    let mut sources = Vec::with_capacity(paths.len());
    for rel in paths {
        let text = fs::read_to_string(src.join(&rel))
            .with_context(|| format!("reading src/{rel}"))?;
        sources.push((rel, text));
    }
    let files = sources.len();
    Ok(Report { findings: check_sources(&sources), files })
}

/// Lint an in-memory source set — the fixture entry point for tests.
/// Paths follow the same `src/`-relative convention (`transport/udp.rs`).
pub fn run_on_sources(files: &[(&str, &str)]) -> Vec<Finding> {
    let owned: Vec<(String, String)> =
        files.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
    check_sources(&owned)
}

fn collect_rs(base: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in fs::read_dir(dir).with_context(|| format!("reading {}", dir.display()))? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(base, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(relative_unix(base, &path));
        }
    }
    Ok(())
}

fn relative_unix(base: &Path, path: &Path) -> String {
    let rel: PathBuf = path.strip_prefix(base).unwrap_or(path).to_path_buf();
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

fn check_sources(files: &[(String, String)]) -> Vec<Finding> {
    let lexed: Vec<(String, Vec<LexLine>)> =
        files.iter().map(|(p, s)| (p.clone(), lexer::lex(s))).collect();
    let mut findings = Vec::new();
    for (path, lines) in &lexed {
        wire::check(path, lines, &mut findings);
        panic::check(path, lines, &mut findings);
        lock::check(path, lines, &mut findings);
        unsafety::check(path, lines, &mut findings);
    }
    obs::check(&lexed, &mut findings);
    findings.retain(|f| !is_allowed(f, &lexed));
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    findings
}

/// A finding is suppressed by a well-formed allow directive for its rule
/// on the same line or on the comment-only line directly above.
fn is_allowed(f: &Finding, lexed: &[(String, Vec<LexLine>)]) -> bool {
    let Some((_, lines)) = lexed.iter().find(|(p, _)| *p == f.file) else {
        return false;
    };
    let Some(idx) = f.line.checked_sub(1) else {
        return false;
    };
    let key = f.rule.key();
    let here = lines.get(idx).map(|l| parse_allow(&l.comment) == Some(key)).unwrap_or(false);
    if here {
        return true;
    }
    idx.checked_sub(1)
        .and_then(|p| lines.get(p))
        .map(|prev| prev.code.trim().is_empty() && parse_allow(&prev.comment) == Some(key))
        .unwrap_or(false)
}

/// Parse `lint: allow(<rule>, "<why>")` out of comment text. Returns the
/// rule key, or `None` for anything malformed — an unknown rule or a
/// missing quoted reason suppresses nothing.
pub fn parse_allow(comment: &str) -> Option<&'static str> {
    let start = comment.find("lint: allow(")?;
    let rest = &comment[start + "lint: allow(".len()..];
    let rule_end = rest.find(|c: char| !lexer::is_ident_char(c))?;
    let rule = Rule::ALL.iter().find(|r| r.key() == &rest[..rule_end])?.key();
    let rest = rest[rule_end..].trim_start();
    let rest = rest.strip_prefix(',')?.trim_start();
    let rest = rest.strip_prefix('"')?;
    let close = rest.find('"')?;
    rest[close + 1..].trim_start().strip_prefix(')')?;
    Some(rule)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
