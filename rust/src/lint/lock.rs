//! R3 — lock discipline.
//!
//! A blocking call (socket I/O, channel recv, thread join, sleep) while a
//! mutex/rwlock guard is live stalls every other thread contending for
//! that lock — the exact failure mode behind a heartbeat ticker queueing
//! behind a slow peer's writer. This rule tracks guard *bindings* (`let g
//! = x.lock()…;` where the rest of the statement is only benign adapters,
//! so the guard outlives the statement) through brace depth and
//! `drop(g)`, and flags any blocking call made while one is live.
//!
//! The analysis is deliberately conservative in the *miss* direction:
//! method-chained temporaries (`x.lock().unwrap().push(..)`) die at the
//! end of their statement and are not tracked; a guard bound inside a
//! single-line block body is not tracked; mpsc `send` never blocks and is
//! not in the blocking set. Deliberate holds carry
//! `// lint: allow(lock, "<why>")`.

use super::lexer::{is_ident_char, LexLine};
use super::{Finding, Rule};

/// Blocking calls that must not run under a live guard. Dotted patterns
/// anchor on `.`; bare ones just need a non-identifier char before them
/// (so `thread::sleep(` counts but `reconnect(` does not).
const DOTTED: [&str; 8] = [
    ".write_all(",
    ".read_exact(",
    ".read_line(",
    ".recv_timeout(",
    ".recv(",
    ".join()",
    ".accept(",
    ".wait(",
];
const BARE: [&str; 3] = ["send_to(", "connect(", "sleep("];

const LOCK_CALLS: [&str; 4] = [".lock()", ".try_lock()", ".read()", ".write()"];

fn in_scope(path: &str) -> bool {
    ["transport/", "session/", "comm/"].iter().any(|p| path.starts_with(p))
}

struct Guard {
    name: String,
    /// The guard is live while brace depth >= this.
    depth: i64,
    /// Line (1-based) where it was bound, for the diagnostic.
    bound_at: usize,
}

pub fn check(path: &str, lines: &[LexLine], out: &mut Vec<Finding>) {
    if !in_scope(path) {
        return;
    }
    let mut depth: i64 = 0;
    let mut guards: Vec<Guard> = Vec::new();
    let mut stmt: Vec<usize> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        stmt.push(i);
        let t = line.blanked.trim_end();
        if !(t.ends_with(';') || t.ends_with('{') || t.ends_with('}')) {
            continue;
        }
        process_stmt(path, lines, &stmt, &mut depth, &mut guards, out);
        stmt.clear();
    }
    if !stmt.is_empty() {
        process_stmt(path, lines, &stmt, &mut depth, &mut guards, out);
    }
}

fn process_stmt(
    path: &str,
    lines: &[LexLine],
    stmt: &[usize],
    depth: &mut i64,
    guards: &mut Vec<Guard>,
    out: &mut Vec<Finding>,
) {
    let in_test = stmt.first().map(|&i| lines[i].in_test).unwrap_or(false);
    let joined: String =
        stmt.iter().map(|&i| lines[i].blanked.as_str()).collect::<Vec<_>>().join(" ");

    // 1) Blocking calls while a guard is live (line-accurate).
    if !in_test && !guards.is_empty() {
        for &i in stmt {
            if let Some(tok) = blocking_token(&lines[i].blanked) {
                let held: Vec<&str> = guards.iter().map(|g| g.name.as_str()).collect();
                let msg = format!(
                    "blocking `{}` while lock guard `{}` (bound line {}) is live; \
                     drop or scope the guard first",
                    tok.trim_end_matches('('),
                    held.join("`, `"),
                    guards.iter().map(|g| g.bound_at.to_string()).collect::<Vec<_>>().join(", "),
                );
                out.push(Finding::new(Rule::Lock, path, i + 1, msg));
            }
        }
    }

    // 2) An explicit drop(g) retires the guard mid-scope.
    guards.retain(|g| !joined.contains(&format!("drop({})", g.name)));

    // 3) Does this statement bind a new guard?
    let new_guard = if in_test { None } else { guard_binding(&joined) };

    // 4) Brace depth; guards die when their scope closes.
    for c in joined.chars() {
        match c {
            '{' => *depth += 1,
            '}' => {
                *depth -= 1;
                guards.retain(|g| g.depth <= *depth);
            }
            _ => {}
        }
    }
    if let Some(name) = new_guard {
        let bound_at = stmt.first().map(|&i| i + 1).unwrap_or(0);
        guards.push(Guard { name, depth: *depth, bound_at });
    }
}

/// First blocking token on the line.
fn blocking_token(blanked: &str) -> Option<&'static str> {
    for pat in DOTTED {
        if blanked.contains(pat) {
            return Some(pat);
        }
    }
    let bytes = blanked.as_bytes();
    for pat in BARE {
        let mut from = 0;
        while let Some(p) = blanked[from..].find(pat) {
            let at = from + p;
            if at == 0 || !is_ident_char(bytes[at - 1] as char) {
                return Some(pat);
            }
            from = at + pat.len();
        }
    }
    None
}

/// `let <binding> = <expr>.lock()<benign suffix>` — a guard that outlives
/// its statement. Returns the bound name.
fn guard_binding(joined: &str) -> Option<String> {
    let let_pos = find_let(joined)?;
    for pat in LOCK_CALLS {
        let mut from = let_pos;
        while let Some(p) = joined[from..].find(pat) {
            let at = from + p;
            if benign_suffix(&joined[at + pat.len()..]) {
                return extract_name(&joined[let_pos + 4..]);
            }
            from = at + pat.len();
        }
    }
    None
}

/// Byte offset of the first `let ` token.
fn find_let(s: &str) -> Option<usize> {
    let bytes = s.as_bytes();
    let mut from = 0;
    while let Some(p) = s[from..].find("let ") {
        let at = from + p;
        if at == 0 || !is_ident_char(bytes[at - 1] as char) {
            return Some(at);
        }
        from = at + 4;
    }
    None
}

/// After the lock call, only error-adapters and statement/block plumbing
/// may follow — anything else (`.pop_front()`, `.push(..)`) means the
/// guard is a method-chain temporary that dies with the statement.
fn benign_suffix(mut s: &str) -> bool {
    loop {
        s = s.trim_start();
        if s.is_empty() {
            return true;
        }
        if let Some(r) = s.strip_prefix(".unwrap()") {
            s = r;
        } else if let Some(r) = strip_call(s, ".expect(") {
            s = r;
        } else if let Some(r) = strip_call(s, ".unwrap_or_else(") {
            s = r;
        } else if let Some(r) = strip_call(s, ".map_err(") {
            s = r;
        } else if let Some(r) = s.strip_prefix('?') {
            s = r;
        } else if let Some(r) = s.strip_prefix(';') {
            s = r;
        } else if let Some(r) = s.strip_prefix('{') {
            s = r;
        } else if let Some(r) = s.strip_prefix("else") {
            s = r;
        } else {
            return false;
        }
    }
}

/// Strip `pat` (which ends in `(`) plus its balanced argument list.
fn strip_call<'a>(s: &'a str, pat: &str) -> Option<&'a str> {
    let rest = s.strip_prefix(pat)?;
    let mut depth = 1;
    for (i, c) in rest.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&rest[i + 1..]);
                }
            }
            _ => {}
        }
    }
    None
}

/// The bound name after `let `, seeing through `mut` and the common
/// destructuring wrappers (`Ok(..)`, `Some(..)`).
fn extract_name(s: &str) -> Option<String> {
    let mut t = s.trim_start();
    loop {
        if let Some(r) = t.strip_prefix("mut ") {
            t = r.trim_start();
        } else if let Some(r) = t.strip_prefix("Ok(") {
            t = r.trim_start();
        } else if let Some(r) = t.strip_prefix("Some(") {
            t = r.trim_start();
        } else {
            break;
        }
    }
    let name: String = t.chars().take_while(|&c| is_ident_char(c)).collect();
    (!name.is_empty() && name != "_").then_some(name)
}
