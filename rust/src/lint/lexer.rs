//! Comment/string-aware source lexing for the flashlint rules.
//!
//! Rules never look at raw source. Each line is pre-chewed into three
//! views — `code` (comments stripped, string contents kept), `blanked`
//! (comments stripped, string/char contents blanked to spaces), and
//! `comment` (the comment text alone) — so a forbidden token inside a
//! doc comment or a log message can never fire a rule, and an allow
//! directive inside a string can never suppress one. A brace tracker
//! marks `#[cfg(test)]` / `mod tests` regions so test code is exempt
//! from the production-only rules.

/// One source line, pre-chewed for the rules.
pub struct LexLine {
    /// The line as written (diagnostics only).
    pub raw: String,
    /// Comments stripped (replaced by a space); string contents kept.
    pub code: String,
    /// Comments stripped; string/char-literal contents blanked to spaces
    /// (the delimiting quotes survive, so columns stay aligned with
    /// `code` for the simple scans the rules do).
    pub blanked: String,
    /// Concatenated comment text on the line, without `//` / `/* */`.
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]` or `mod tests`
    /// block (the opening line itself is not marked; everything after
    /// its `{` is).
    pub in_test: bool,
}

#[derive(Clone, Copy)]
enum State {
    Normal,
    /// `//` comment; dies at end of line.
    Line,
    /// `/* */` comment at a nesting depth.
    Block(u32),
    /// `"…"` string literal (escapes honored).
    Str,
    /// `r#"…"#` raw string with N hashes.
    RawStr(u8),
    /// `'…'` char literal.
    CharLit,
}

/// Lex a whole source file into per-line views.
pub fn lex(src: &str) -> Vec<LexLine> {
    let mut out = Vec::new();
    let mut state = State::Normal;
    for raw in src.lines() {
        if matches!(state, State::Line) {
            state = State::Normal;
        }
        let chars: Vec<char> = raw.chars().collect();
        let mut code = String::new();
        let mut blanked = String::new();
        let mut comment = String::new();
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            let raw_start = if c == 'r' { raw_str_hashes(&chars, i) } else { None };
            match state {
                State::Normal => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        state = State::Line;
                        code.push(' ');
                        blanked.push(' ');
                        i += 2;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::Block(1);
                        code.push(' ');
                        blanked.push(' ');
                        i += 2;
                    } else if c == '"' {
                        state = State::Str;
                        code.push('"');
                        blanked.push('"');
                        i += 1;
                    } else if let Some(hashes) = raw_start {
                        code.push('r');
                        blanked.push('r');
                        for _ in 0..hashes {
                            code.push('#');
                            blanked.push('#');
                        }
                        code.push('"');
                        blanked.push('"');
                        state = State::RawStr(hashes);
                        i += 2 + hashes as usize;
                    } else if c == '\'' && is_char_literal(&chars, i) {
                        state = State::CharLit;
                        code.push('\'');
                        blanked.push('\'');
                        i += 1;
                    } else {
                        code.push(c);
                        blanked.push(c);
                        i += 1;
                    }
                }
                State::Line => {
                    comment.push(c);
                    i += 1;
                }
                State::Block(depth) => {
                    if c == '*' && chars.get(i + 1) == Some(&'/') {
                        state = if depth <= 1 { State::Normal } else { State::Block(depth - 1) };
                        i += 2;
                    } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                        state = State::Block(depth + 1);
                        i += 2;
                    } else {
                        comment.push(c);
                        i += 1;
                    }
                }
                State::Str => {
                    if c == '\\' {
                        code.push(c);
                        blanked.push(' ');
                        if let Some(&e) = chars.get(i + 1) {
                            code.push(e);
                            blanked.push(' ');
                            i += 2;
                        } else {
                            i += 1;
                        }
                    } else if c == '"' {
                        code.push('"');
                        blanked.push('"');
                        state = State::Normal;
                        i += 1;
                    } else {
                        code.push(c);
                        blanked.push(' ');
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    if c == '"' && raw_str_closes(&chars, i, hashes) {
                        code.push('"');
                        blanked.push('"');
                        for _ in 0..hashes {
                            code.push('#');
                            blanked.push('#');
                        }
                        state = State::Normal;
                        i += 1 + hashes as usize;
                    } else {
                        code.push(c);
                        blanked.push(' ');
                        i += 1;
                    }
                }
                State::CharLit => {
                    if c == '\\' {
                        code.push(c);
                        blanked.push(' ');
                        if let Some(&e) = chars.get(i + 1) {
                            code.push(e);
                            blanked.push(' ');
                            i += 2;
                        } else {
                            i += 1;
                        }
                    } else if c == '\'' {
                        code.push('\'');
                        blanked.push('\'');
                        state = State::Normal;
                        i += 1;
                    } else {
                        code.push(c);
                        blanked.push(' ');
                        i += 1;
                    }
                }
            }
        }
        out.push(LexLine { raw: raw.to_string(), code, blanked, comment, in_test: false });
    }
    mark_test_regions(&mut out);
    out
}

/// `r` at `i` starts a raw string (`r"…"` / `r#"…"#`)? Returns the hash
/// count. The preceding char must not be an identifier char (so `for r`
/// or `hdr"` never false-trigger) — except `b`, for `br"…"` byte strings.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<u8> {
    if i > 0 {
        let p = chars[i - 1];
        if (p.is_ascii_alphanumeric() || p == '_') && p != 'b' {
            return None;
        }
    }
    let mut hashes = 0u8;
    let mut j = i + 1;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(hashes)
}

/// A closing `"` of a raw string must be followed by exactly its hashes.
fn raw_str_closes(chars: &[char], i: usize, hashes: u8) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// `'` at `i`: char literal or lifetime? After a quote, `\` or a
/// char-then-quote means a literal; anything else (`'a>`, `'static`) is
/// a lifetime and stays plain code.
fn is_char_literal(chars: &[char], i: usize) -> bool {
    match chars.get(i + 1) {
        Some('\\') => true,
        Some(_) => chars.get(i + 2) == Some(&'\''),
        None => false,
    }
}

/// Mark lines inside `#[cfg(test)]` / `mod tests` blocks. A pending
/// marker attaches to the next `{` (recording its depth); a `;` first
/// means the attribute named a non-block item and the marker dies.
fn mark_test_regions(lines: &mut [LexLine]) {
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut stack: Vec<i64> = Vec::new();
    for line in lines.iter_mut() {
        line.in_test = !stack.is_empty();
        if line.code.contains("#[cfg(test)]") || has_mod_tests(&line.code) {
            pending = true;
        }
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending {
                        stack.push(depth);
                        pending = false;
                    }
                }
                '}' => {
                    if stack.last() == Some(&depth) {
                        stack.pop();
                    }
                    depth -= 1;
                }
                ';' => pending = false,
                _ => {}
            }
        }
    }
}

/// `mod tests` as whole tokens (not e.g. `mod tests_util`).
fn has_mod_tests(code: &str) -> bool {
    let pat = "mod tests";
    let mut from = 0;
    while let Some(p) = code[from..].find(pat) {
        let at = from + p;
        let before_ok = at == 0 || !is_ident_char(code.as_bytes()[at - 1] as char);
        let after = at + pat.len();
        let after_ok = after >= code.len() || !is_ident_char(code.as_bytes()[after] as char);
        if before_ok && after_ok {
            return true;
        }
        from = at + pat.len();
    }
    false
}

/// Identifier-ish char (for token-boundary checks).
pub fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Does `haystack` contain `word` as a whole token?
pub fn has_word(haystack: &str, word: &str) -> bool {
    find_word(haystack, word).is_some()
}

/// Byte offset of the first whole-token occurrence of `word`.
pub fn find_word(haystack: &str, word: &str) -> Option<usize> {
    let bytes = haystack.as_bytes();
    let mut from = 0;
    while let Some(p) = haystack[from..].find(word) {
        let at = from + p;
        let before_ok = at == 0 || !is_ident_char(bytes[at - 1] as char);
        let end = at + word.len();
        let after_ok = end >= bytes.len() || !is_ident_char(bytes[end] as char);
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + word.len();
    }
    None
}

/// A two-sided literal slice range found in a line: `[<lo>..<hi>]`.
pub struct LiteralRange {
    pub lo: u64,
    pub hi: u64,
    /// Is the `[` preceded by an identifier char or `)` — i.e. is this
    /// an indexing expression rather than an array/range literal?
    pub indexed: bool,
}

/// Scan `blanked` text for `[<digits>..<digits>]` occurrences.
pub fn literal_ranges(blanked: &str) -> Vec<LiteralRange> {
    let chars: Vec<char> = blanked.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        if chars[i] == '[' {
            if let Some((lo, hi, end)) = parse_range(&chars, i + 1) {
                let indexed = i > 0 && (is_ident_char(chars[i - 1]) || chars[i - 1] == ')');
                out.push(LiteralRange { lo, hi, indexed });
                i = end;
                continue;
            }
        }
        i += 1;
    }
    out
}

fn parse_range(chars: &[char], i: usize) -> Option<(u64, u64, usize)> {
    let (lo, j) = parse_num(chars, i)?;
    if chars.get(j) != Some(&'.') || chars.get(j + 1) != Some(&'.') {
        return None;
    }
    let (hi, k) = parse_num(chars, j + 2)?;
    if chars.get(k) != Some(&']') {
        return None;
    }
    Some((lo, hi, k + 1))
}

fn parse_num(chars: &[char], start: usize) -> Option<(u64, usize)> {
    let mut i = start;
    while i < chars.len() && chars[i].is_ascii_digit() {
        i += 1;
    }
    if i == start {
        return None;
    }
    chars[start..i].iter().collect::<String>().parse().ok().map(|v| (v, i))
}

/// Does the line contain a literal index expression `ident[<digits>]`?
pub fn has_literal_index(blanked: &str) -> bool {
    let chars: Vec<char> = blanked.chars().collect();
    for i in 1..chars.len() {
        if chars[i] == '[' && is_ident_char(chars[i - 1]) {
            if let Some((_, j)) = parse_num(&chars, i + 1) {
                if chars.get(j) == Some(&']') {
                    return true;
                }
            }
        }
    }
    false
}
