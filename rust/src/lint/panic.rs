//! R2 — panic-path discipline.
//!
//! Production code in the transport/session/comm/quant/plan layers must
//! not be able to take down a rank over a recoverable condition: a
//! poisoned lock, a short buffer, or a malformed peer frame should
//! surface as a typed error, not a panic that the other ranks observe as
//! a silent peer death. This rule flags, in non-test code:
//!
//! - `.unwrap()` / `.expect(` and the panicking macros (`panic!`,
//!   `unreachable!`, `todo!`, `unimplemented!` — asserts are exempt:
//!   they state invariants, not error handling);
//! - literal two-sided slice ranges used as indexes (`buf[4..6]` — the
//!   classic short-buffer panic; single-element indexes are too common
//!   and too often loop-bounded to flag);
//! - `from_le_bytes`/`from_be_bytes` built from literal indexes
//!   (`[wire[0], wire[1]]`), the unchecked-parse pattern.
//!
//! Genuinely unreachable sites carry `// lint: allow(panic, "<why>")`.

use super::lexer::{has_literal_index, is_ident_char, literal_ranges, LexLine};
use super::{Finding, Rule};

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

fn in_scope(path: &str) -> bool {
    ["transport/", "session/", "comm/", "quant/", "plan/"]
        .iter()
        .any(|p| path.starts_with(p))
}

pub fn check(path: &str, lines: &[LexLine], out: &mut Vec<Finding>) {
    if !in_scope(path) {
        return;
    }
    for (i, line) in lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let n = i + 1;
        let b = &line.blanked;
        if b.contains(".unwrap()") {
            out.push(Finding::new(Rule::Panic, path, n, "`.unwrap()` on a production path"));
        }
        if b.contains(".expect(") {
            out.push(Finding::new(Rule::Panic, path, n, "`.expect(..)` on a production path"));
        }
        for m in PANIC_MACROS {
            if has_macro(b, m) {
                let msg = format!("`{m}!` on a production path");
                out.push(Finding::new(Rule::Panic, path, n, msg));
            }
        }
        for r in literal_ranges(b) {
            if r.indexed {
                let msg = format!(
                    "literal slice range [{}..{}] can panic on a short buffer; check the length",
                    r.lo, r.hi
                );
                out.push(Finding::new(Rule::Panic, path, n, msg));
            }
        }
        let bytes_ctor = b.contains("from_le_bytes([") || b.contains("from_be_bytes([");
        if bytes_ctor && has_literal_index(b) {
            out.push(Finding::new(
                Rule::Panic,
                path,
                n,
                "from_*_bytes over literal indexes can panic on a short buffer",
            ));
        }
    }
}

/// `m!` invoked as a macro: the name must start at a token boundary
/// (`debug_panic!` would not count as `panic!`).
fn has_macro(b: &str, m: &str) -> bool {
    let pat = format!("{m}!");
    let bytes = b.as_bytes();
    let mut from = 0;
    while let Some(p) = b[from..].find(&pat) {
        let at = from + p;
        if at == 0 || !is_ident_char(bytes[at - 1] as char) {
            return true;
        }
        from = at + pat.len();
    }
    false
}
