//! Deterministic fault injection over any transport.
//!
//! [`FaultInjector`] wraps a [`Transport`] endpoint and fires one scripted
//! [`Fault`] at a fixed point in the endpoint's send stream, so every
//! failure path the session layer promises — a rank dying mid-collective,
//! a frame delayed, a frame dropped — is reproducible in-process without a
//! socket or a signal in play. The injectors of one mesh share a
//! [`FaultMesh`]: when one endpoint "dies", every other endpoint's blocked
//! `recv` notices within its poll interval and surfaces the same typed
//! [`PeerLost`] a real heartbeat deadline would (the shared dead-flags
//! stand in for the heartbeat channel, which needs a real wire to exist).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::{PeerLost, PeerState, SessionCounters, SessionStats};
use crate::transport::{Transport, TransportStats};

/// What to inject, scripted against this endpoint's 0-based send counter
/// (all destinations share one counter, so a collective's send schedule
/// addresses any hop deterministically).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Healthy endpoint (the control run).
    None,
    /// Silently drop the `nth` send: the payload never reaches the peer,
    /// whose `recv` starves into its deadline error.
    Drop { nth: usize },
    /// Delay the `nth` send by `by` before delivering it (reordering
    /// across *links*; per-link order is still preserved).
    Delay { nth: usize, by: Duration },
    /// Kill this endpoint at its `nth` send: the send fails with
    /// [`PeerLost`] naming this rank, and every other endpoint of the
    /// mesh sees the death on its next `recv` poll.
    KillAtSend { nth: usize },
}

/// Shared death registry of one fault-injected mesh.
#[derive(Debug)]
pub struct FaultMesh {
    dead: Vec<AtomicBool>,
    /// Losses the owner has re-planned around ([`FaultInjector::acknowledge_loss`]):
    /// no longer surfaced as fresh [`PeerLost`] errors by the cascade check.
    acked: Vec<AtomicBool>,
    epoch: u16,
    counters: SessionCounters,
}

impl FaultMesh {
    fn new(n: usize, epoch: u16) -> FaultMesh {
        FaultMesh {
            dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
            acked: (0..n).map(|_| AtomicBool::new(false)).collect(),
            epoch,
            counters: SessionCounters::default(),
        }
    }

    fn is_dead(&self, rank: usize) -> bool {
        self.dead[rank].load(Ordering::Relaxed)
    }

    fn mark_dead(&self, rank: usize) {
        if !self.dead[rank].swap(true, Ordering::Relaxed) {
            self.counters.losses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The lowest-numbered unacknowledged dead rank, if any.
    fn fresh_loss(&self) -> Option<usize> {
        (0..self.dead.len())
            .find(|&r| self.is_dead(r) && !self.acked[r].load(Ordering::Relaxed))
    }
}

/// A [`Transport`] wrapper executing one scripted [`Fault`]. Build a mesh
/// of them with [`wrap_mesh`].
pub struct FaultInjector<T: Transport> {
    inner: T,
    mesh: Arc<FaultMesh>,
    fault: Fault,
    sends: AtomicUsize,
    /// Wall-clock guard on `recv`: a starved receive (e.g. after a
    /// dropped frame) errors out instead of spinning forever. Plays the
    /// role the TCP deadline plays on a real wire.
    deadline: Duration,
}

/// Wrap a pre-connected mesh (endpoint `i` is rank `i`) with one fault
/// script per rank. `deadline` bounds how long a `recv` may starve before
/// it errors (the in-process stand-in for the session receive deadline).
pub fn wrap_mesh<T: Transport>(
    endpoints: Vec<T>,
    faults: Vec<Fault>,
    deadline: Duration,
) -> Vec<FaultInjector<T>> {
    assert_eq!(endpoints.len(), faults.len(), "one fault script per rank");
    let mesh = Arc::new(FaultMesh::new(endpoints.len(), 0));
    endpoints
        .into_iter()
        .zip(faults)
        .map(|(inner, fault)| FaultInjector {
            inner,
            mesh: mesh.clone(),
            fault,
            sends: AtomicUsize::new(0),
            deadline,
        })
        .collect()
}

impl<T: Transport> FaultInjector<T> {
    /// Liveness view of the whole mesh, the in-process analogue of the
    /// TCP session states: dead ranks read Lost, everyone else Healthy.
    pub fn health(&self) -> Vec<PeerState> {
        (0..self.inner.n())
            .map(|r| if self.mesh.is_dead(r) { PeerState::Lost } else { PeerState::Healthy })
            .collect()
    }

    /// Stop surfacing `rank`'s death as a fresh [`PeerLost`]: the owner
    /// has re-planned over the survivors (see
    /// [`DegradedMesh`](super::degraded::DegradedMesh)) and polls must no
    /// longer abort on the already-handled loss.
    pub fn acknowledge_loss(&self, rank: usize) {
        self.mesh.acked[rank].store(true, Ordering::Relaxed);
    }

    /// The wrapped endpoint.
    pub fn inner(&self) -> &T {
        &self.inner
    }

    fn peer_lost(&self, rank: usize) -> anyhow::Error {
        anyhow::Error::new(PeerLost { rank, epoch: self.mesh.epoch })
    }
}

impl<T: Transport> Transport for FaultInjector<T> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn n(&self) -> usize {
        self.inner.n()
    }

    fn send(&self, dst: usize, payload: Vec<u8>) -> Result<()> {
        if self.mesh.is_dead(self.rank()) {
            return Err(self.peer_lost(self.rank()));
        }
        let nth = self.sends.fetch_add(1, Ordering::Relaxed);
        match self.fault {
            Fault::KillAtSend { nth: k } if nth == k => {
                self.mesh.mark_dead(self.rank());
                return Err(self.peer_lost(self.rank()));
            }
            Fault::Drop { nth: k } if nth == k => return Ok(()),
            Fault::Delay { nth: k, by } if nth == k => std::thread::sleep(by),
            _ => {}
        }
        if self.mesh.is_dead(dst) && !self.mesh.acked[dst].load(Ordering::Relaxed) {
            // Sending into a corpse fails fast, like a TCP RST would.
            return Err(self.peer_lost(dst));
        }
        self.inner.send(dst, payload)
    }

    fn recv(&self, src: usize) -> Result<Vec<u8>> {
        let start = Instant::now();
        loop {
            if self.mesh.is_dead(self.rank()) {
                return Err(self.peer_lost(self.rank()));
            }
            // Deliver anything already in flight first — data that made it
            // out before a death still counts (TCP flushes before FIN).
            if let Some(payload) = self.inner.try_recv(src)? {
                return Ok(payload);
            }
            if self.mesh.is_dead(src) && !self.mesh.acked[src].load(Ordering::Relaxed) {
                return Err(self.peer_lost(src));
            }
            // Cascade: blocked on a healthy peer that itself aborted on
            // the real loss. Name the actually-dead rank, the way a
            // heartbeat deadline would.
            if let Some(dead) = self.mesh.fresh_loss() {
                return Err(self.peer_lost(dead));
            }
            if start.elapsed() > self.deadline {
                bail!(
                    "recv from rank {src} starved past the {:?} deadline (dropped frame?)",
                    self.deadline
                );
            }
            std::thread::sleep(Duration::from_micros(100));
        }
    }

    fn try_recv(&self, src: usize) -> Result<Option<Vec<u8>>> {
        if self.mesh.is_dead(self.rank()) {
            return Err(self.peer_lost(self.rank()));
        }
        if let Some(payload) = self.inner.try_recv(src)? {
            return Ok(Some(payload));
        }
        if self.mesh.is_dead(src) && !self.mesh.acked[src].load(Ordering::Relaxed) {
            return Err(self.peer_lost(src));
        }
        Ok(None)
    }

    fn stats(&self) -> TransportStats {
        self.inner.stats()
    }

    fn session_stats(&self) -> Option<SessionStats> {
        Some(SessionStats {
            epoch: self.mesh.epoch,
            heartbeats_sent: 0,
            heartbeats_received: 0,
            suspects: 0,
            losses: self.mesh.counters.losses.load(Ordering::Relaxed),
            epoch_bumps: self.mesh.counters.epoch_bumps.load(Ordering::Relaxed),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::find_peer_lost;
    use crate::transport::inproc;

    fn mesh2(f0: Fault, f1: Fault) -> Vec<FaultInjector<inproc::InProcTransport>> {
        wrap_mesh(inproc::mesh(2), vec![f0, f1], Duration::from_millis(200))
    }

    #[test]
    fn no_fault_is_transparent() {
        let m = mesh2(Fault::None, Fault::None);
        m[0].send(1, vec![1, 2, 3]).unwrap();
        assert_eq!(m[1].recv(0).unwrap(), vec![1, 2, 3]);
        assert_eq!(m[0].health(), vec![PeerState::Healthy; 2]);
    }

    #[test]
    fn kill_at_send_surfaces_peer_lost_on_both_sides() {
        let m = mesh2(Fault::KillAtSend { nth: 1 }, Fault::None);
        m[0].send(1, vec![0]).unwrap(); // send 0 still healthy
        assert_eq!(m[1].recv(0).unwrap(), vec![0], "pre-death data is delivered");
        let e = m[0].send(1, vec![1]).unwrap_err();
        assert_eq!(find_peer_lost(&e).unwrap().rank, 0, "the dying rank names itself");
        let e = m[1].recv(0).unwrap_err();
        assert_eq!(find_peer_lost(&e).unwrap().rank, 0, "the survivor names the dead rank");
        assert_eq!(m[1].health(), vec![PeerState::Lost, PeerState::Healthy]);
        assert_eq!(m[1].session_stats().unwrap().losses, 1);
    }

    #[test]
    fn cascade_names_the_truly_dead_rank() {
        // Rank 2 dies; rank 1 is blocked on rank 0, which is healthy but
        // will never send (it aborted on the real loss). The poll loop
        // must still name rank 2, not starve.
        let m = wrap_mesh(
            inproc::mesh(3),
            vec![Fault::None, Fault::None, Fault::KillAtSend { nth: 0 }],
            Duration::from_secs(5),
        );
        assert!(m[2].send(0, vec![9]).is_err());
        let e = m[1].recv(0).unwrap_err();
        assert_eq!(find_peer_lost(&e).unwrap().rank, 2);
    }

    #[test]
    fn dropped_frame_starves_into_the_deadline() {
        let m = mesh2(Fault::Drop { nth: 0 }, Fault::None);
        m[0].send(1, vec![7]).unwrap(); // silently dropped
        let e = m[1].recv(0).unwrap_err();
        assert!(e.to_string().contains("starved"), "{e}");
        assert!(find_peer_lost(&e).is_none(), "a drop is not a death");
    }

    #[test]
    fn delayed_frame_is_late_but_intact() {
        let m = mesh2(Fault::Delay { nth: 0, by: Duration::from_millis(20) }, Fault::None);
        let t0 = Instant::now();
        m[0].send(1, vec![5; 4]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(20));
        assert_eq!(m[1].recv(0).unwrap(), vec![5; 4]);
    }

    #[test]
    fn acknowledged_loss_stops_aborting_polls() {
        let m = wrap_mesh(
            inproc::mesh(3),
            vec![Fault::None, Fault::None, Fault::KillAtSend { nth: 0 }],
            Duration::from_millis(200),
        );
        assert!(m[2].send(0, vec![0]).is_err());
        assert!(m[1].recv(0).is_err(), "unacked loss aborts");
        m[0].acknowledge_loss(2);
        m[1].acknowledge_loss(2);
        m[0].send(1, vec![3]).unwrap();
        assert_eq!(m[1].recv(0).unwrap(), vec![3], "survivor links work after the ack");
    }
}
