//! Degraded-mode membership: dense rank remapping over the survivors.
//!
//! After the session fabric declares ranks lost, the surviving membership
//! continues as a smaller, densely-numbered mesh: [`DegradedMesh`] wraps
//! the original endpoint and translates between the *degraded* rank space
//! `0..survivors` the collectives see and the original rank space the
//! wire still speaks. Per-link frame sequence spaces are untouched —
//! every surviving (src, dst) pair keeps its socket/channel and its seq
//! counter, so no reset handshake is needed; only the dead links are cut
//! out of the schedule. The shrunk [`Topology`] from
//! [`survivor_topology`](super::survivor_topology) has a different
//! fingerprint, so [`crate::plan::compile`]'s cached plans for the full
//! membership are never replayed against the degraded mesh.

use anyhow::{ensure, Result};

use super::SessionStats;
use crate::comm::CommError;
use crate::transport::{Transport, TransportStats};

/// A transport endpoint renumbered over the surviving membership.
pub struct DegradedMesh<T: Transport> {
    inner: T,
    /// Degraded rank → original rank (ascending, so original group blocks
    /// survive the remap when losses are group-uniform).
    old_of_new: Vec<usize>,
    /// This endpoint's degraded rank.
    rank: usize,
}

impl<T: Transport> DegradedMesh<T> {
    /// Shrink `inner` to the survivors of `lost`. Errors if this endpoint
    /// is itself listed lost, a lost rank is out of range, or fewer than
    /// two ranks survive.
    pub fn new(inner: T, lost: &[usize]) -> Result<DegradedMesh<T>, CommError> {
        let n = inner.n();
        let mut dead = vec![false; n];
        for &r in lost {
            if r >= n {
                return Err(CommError::shape(format!(
                    "lost rank {r} out of range for a {n}-rank mesh"
                )));
            }
            dead[r] = true;
        }
        if dead[inner.rank()] {
            return Err(CommError::shape(format!(
                "rank {} cannot degrade a mesh it was lost from",
                inner.rank()
            )));
        }
        let old_of_new: Vec<usize> = (0..n).filter(|&r| !dead[r]).collect();
        if old_of_new.len() < 2 {
            return Err(CommError::shape(format!(
                "{} survivor(s): no degraded mesh is possible",
                old_of_new.len()
            )));
        }
        // lint: allow(panic, "self is a survivor: the dead[rank] check above guarantees it")
        let rank = old_of_new.iter().position(|&r| r == inner.rank()).expect("survivor");
        Ok(DegradedMesh { inner, old_of_new, rank })
    }

    /// The original rank behind a degraded rank.
    pub fn original_rank(&self, new: usize) -> usize {
        self.old_of_new[new]
    }

    /// The wrapped full-membership endpoint.
    pub fn inner(&self) -> &T {
        &self.inner
    }

    fn map(&self, new: usize, role: &str) -> Result<usize> {
        ensure!(
            new < self.old_of_new.len(),
            "{role} rank {new} out of range for the {}-survivor mesh",
            self.old_of_new.len()
        );
        Ok(self.old_of_new[new])
    }
}

impl<T: Transport> Transport for DegradedMesh<T> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n(&self) -> usize {
        self.old_of_new.len()
    }

    fn send(&self, dst: usize, payload: Vec<u8>) -> Result<()> {
        self.inner.send(self.map(dst, "dst")?, payload)
    }

    fn recv(&self, src: usize) -> Result<Vec<u8>> {
        self.inner.recv(self.map(src, "src")?)
    }

    fn try_recv(&self, src: usize) -> Result<Option<Vec<u8>>> {
        self.inner.try_recv(self.map(src, "src")?)
    }

    fn stats(&self) -> TransportStats {
        self.inner.stats()
    }

    fn session_stats(&self) -> Option<SessionStats> {
        self.inner.session_stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::inproc;

    #[test]
    fn remap_is_dense_and_ascending() {
        // 4 ranks, rank 2 lost: survivors 0,1,3 become 0,1,2.
        let mut endpoints = inproc::mesh(4);
        let t3 = DegradedMesh::new(endpoints.pop().unwrap(), &[2]).unwrap();
        endpoints.pop(); // drop the dead rank's endpoint
        let t1 = DegradedMesh::new(endpoints.pop().unwrap(), &[2]).unwrap();
        let t0 = DegradedMesh::new(endpoints.pop().unwrap(), &[2]).unwrap();
        assert_eq!((t0.rank(), t1.rank(), t3.rank()), (0, 1, 2));
        assert_eq!(t3.n(), 3);
        assert_eq!(t3.original_rank(2), 3);
        // Degraded rank 2 is original rank 3; the link works both ways.
        t0.send(2, vec![42]).unwrap();
        assert_eq!(t3.recv(0).unwrap(), vec![42]);
        t3.send(0, vec![7]).unwrap();
        assert_eq!(t0.recv(2).unwrap(), vec![7]);
    }

    #[test]
    fn seq_spaces_survive_the_remap() {
        // Traffic before the loss, then degraded traffic on the same
        // links: per-link sequence numbers continue without a reset.
        let mut endpoints = inproc::mesh(3);
        let t2 = endpoints.pop().unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        t0.send(2, vec![1]).unwrap();
        assert_eq!(t2.recv(0).unwrap(), vec![1]);
        drop(t1); // rank 1 "dies"
        let d0 = DegradedMesh::new(t0, &[1]).unwrap();
        let d2 = DegradedMesh::new(t2, &[1]).unwrap();
        d0.send(1, vec![2]).unwrap(); // degraded rank 1 == original rank 2
        assert_eq!(d2.recv(0).unwrap(), vec![2], "seq continues past the pre-loss frame");
    }

    #[test]
    fn hostile_inputs_are_typed_errors() {
        let mut endpoints = inproc::mesh(3);
        let t0 = endpoints.remove(0);
        assert!(matches!(
            DegradedMesh::new(t0, &[7]).unwrap_err(),
            CommError::Shape { .. }
        ));
        let t0 = endpoints.remove(0); // rank 1 endpoint
        assert!(DegradedMesh::new(t0, &[1]).is_err(), "self-lost is rejected");
        let t2 = endpoints.remove(0);
        assert!(DegradedMesh::new(t2, &[0, 1]).is_err(), "one survivor is rejected");
    }
}
