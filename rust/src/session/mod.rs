//! Session fabric: liveness, membership, and epochs over the transports.
//!
//! The transports ([`crate::transport`]) guarantee that bytes which *do*
//! arrive are intact; this layer guarantees that bytes which *don't* arrive
//! fail loudly. It owns three concerns the frame layer cannot see:
//!
//! 1. **Liveness** — per-peer heartbeats and receive deadlines, enforced
//!    by the TCP reader threads and the UDP engine thread alike. A rank
//!    that stops sending (crash, SIGKILL, network partition) is moved
//!    through the per-peer state machine
//!    `Healthy → Suspect → Lost` and every survivor's pending `recv`
//!    surfaces [`CommError::PeerLost`] within the configured deadline
//!    instead of blocking forever.
//! 2. **Epochs** — a session generation number carried in every frame
//!    header (bytes 10..12; see [`crate::transport::frame`]). A restarted
//!    rank re-rendezvouses against the root under `epoch + 1`
//!    ([`rejoin`]), so frames from its previous incarnation are rejected
//!    by the epoch check instead of silently poisoning the per-link
//!    sequence spaces (state `Rejoined`).
//! 3. **Degraded membership** — [`degraded::DegradedMesh`] densely remaps
//!    the surviving ranks so the plan compiler ([`crate::plan`]) can
//!    re-plan the collective over the shrunk [`Topology`] returned by
//!    [`survivor_topology`] (the topology fingerprint changes with the
//!    membership, so cached plans are never reused across a loss).
//!
//! Failure paths are deterministically testable in-process through
//! [`fault::FaultInjector`], a transport wrapper that drops, delays, or
//! kills an endpoint at its N-th send without any real socket in play.
//! See `DESIGN.md` §12 for the state machine and the per-backend
//! failure/rejoin matrix.

pub mod degraded;
pub mod fault;

use std::fmt;
use std::net::{IpAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::time::Duration;

use crate::comm::CommError;
use crate::telemetry::{ClockSync, ClockSyncStats, ProbeSample, MAX_PROBES};
use crate::topo::Topology;
use crate::transport::{frame, TcpTransport, Transport};

pub use degraded::DegradedMesh;
pub use fault::{Fault, FaultInjector};

/// Default rendezvous handshake deadline (dead-root detection; satellite of
/// the session work — a dead `--root` must fail `bootstrap`, not hang it).
pub const DEFAULT_RENDEZVOUS_TIMEOUT: Duration = Duration::from_secs(20);

/// Per-peer liveness state. Transitions (see `DESIGN.md` §12):
/// `Healthy → Suspect` when nothing arrived for half the deadline,
/// `Suspect → Healthy` when traffic resumes, `Suspect|Healthy → Lost` when
/// the deadline expires or the socket dies (sticky), and `Rejoined` for a
/// rank readmitted under a bumped epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum PeerState {
    Healthy = 0,
    Suspect = 1,
    Lost = 2,
    Rejoined = 3,
}

impl PeerState {
    pub fn name(self) -> &'static str {
        match self {
            PeerState::Healthy => "healthy",
            PeerState::Suspect => "suspect",
            PeerState::Lost => "lost",
            PeerState::Rejoined => "rejoined",
        }
    }

    fn from_u8(v: u8) -> PeerState {
        match v {
            1 => PeerState::Suspect,
            2 => PeerState::Lost,
            3 => PeerState::Rejoined,
            _ => PeerState::Healthy,
        }
    }
}

/// Liveness/epoch knobs for a session-enabled bootstrap.
#[derive(Debug, Clone)]
pub struct SessionConfig {
    /// Heartbeat send period per peer; `None` disables the session layer
    /// (bare transport semantics: a dead peer blocks `recv` until its
    /// socket closes).
    pub heartbeat: Option<Duration>,
    /// Receive deadline per peer: nothing (data or heartbeat) for this
    /// long ⇒ the peer is declared [`PeerState::Lost`]. Suspect at half.
    pub deadline: Option<Duration>,
    /// Session epoch this endpoint speaks (0 for a fresh job; bumped by
    /// [`rejoin`]). The root is the epoch authority during rendezvous.
    pub epoch: u16,
    /// Deadline for the rendezvous handshake itself (dead-root detection).
    pub rendezvous_timeout: Duration,
}

impl SessionConfig {
    /// No liveness tracking: bare transport semantics, epoch 0. This is
    /// what the plain `bootstrap` entry points use.
    pub fn disabled() -> SessionConfig {
        SessionConfig {
            heartbeat: None,
            deadline: None,
            epoch: 0,
            rendezvous_timeout: DEFAULT_RENDEZVOUS_TIMEOUT,
        }
    }

    /// Build from the CLI's `--heartbeat-ms` / `--comm-timeout-ms` pair.
    /// Both 0 disables the session layer; a lone zero or a deadline under
    /// 2× the heartbeat is a typed argument error (one missed heartbeat
    /// must never look like a death).
    pub fn from_millis(heartbeat_ms: u64, timeout_ms: u64) -> Result<SessionConfig, CommError> {
        match (heartbeat_ms, timeout_ms) {
            (0, 0) => Ok(SessionConfig::disabled()),
            (0, _) | (_, 0) => Err(CommError::shape(
                "--heartbeat-ms and --comm-timeout-ms must both be set, or both 0 to disable \
                 the session layer",
            )),
            (hb, to) if to < 2 * hb => Err(CommError::shape(format!(
                "--comm-timeout-ms {to} must be at least twice --heartbeat-ms {hb}: a single \
                 delayed heartbeat must not be declared a death"
            ))),
            (hb, to) => Ok(SessionConfig {
                heartbeat: Some(Duration::from_millis(hb)),
                deadline: Some(Duration::from_millis(to)),
                epoch: 0,
                rendezvous_timeout: DEFAULT_RENDEZVOUS_TIMEOUT,
            }),
        }
    }

    /// Whether liveness tracking (heartbeats + deadlines) is on.
    pub fn enabled(&self) -> bool {
        self.heartbeat.is_some()
    }

    /// This config under a different epoch.
    pub fn with_epoch(mut self, epoch: u16) -> SessionConfig {
        self.epoch = epoch;
        self
    }

    /// This config with a different rendezvous handshake deadline.
    pub fn with_rendezvous_timeout(mut self, timeout: Duration) -> SessionConfig {
        self.rendezvous_timeout = timeout;
        self
    }
}

/// Monotone session counters, shared between the heartbeat thread, the
/// reader threads, and the owning endpoint. Individually relaxed-atomic.
#[derive(Debug, Default)]
pub struct SessionCounters {
    pub heartbeats_sent: AtomicU64,
    pub heartbeats_received: AtomicU64,
    /// `Healthy → Suspect` transitions (a peer can be suspected, recover,
    /// and be suspected again — each transition counts).
    pub suspects: AtomicU64,
    /// `→ Lost` transitions (at most one per peer per session).
    pub losses: AtomicU64,
    /// Epoch bumps this endpoint performed (one per [`rejoin`]).
    pub epoch_bumps: AtomicU64,
}

/// A point-in-time copy of [`SessionCounters`] plus the session epoch —
/// what [`crate::transport::Transport::session_stats`] returns and the
/// metrics JSON exports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    pub epoch: u16,
    pub heartbeats_sent: u64,
    pub heartbeats_received: u64,
    pub suspects: u64,
    pub losses: u64,
    pub epoch_bumps: u64,
}

/// Shared session state for one endpoint: the epoch, one liveness state
/// per peer, and the counters. Reader threads, the heartbeat thread, and
/// the owning rank all hold an `Arc` of this.
#[derive(Debug)]
pub struct SessionShared {
    /// The epoch every frame of this session carries and expects.
    pub epoch: u16,
    states: Vec<AtomicU8>,
    pub counters: SessionCounters,
    /// Set by the endpoint's `Drop` so the heartbeat thread exits.
    pub(crate) shutdown: AtomicBool,
}

impl SessionShared {
    pub fn new(n: usize, epoch: u16) -> SessionShared {
        SessionShared {
            epoch,
            states: (0..n).map(|_| AtomicU8::new(PeerState::Healthy as u8)).collect(),
            counters: SessionCounters::default(),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Liveness state of one peer.
    pub fn state(&self, rank: usize) -> PeerState {
        PeerState::from_u8(self.states[rank].load(Ordering::Relaxed))
    }

    /// Liveness state of every rank (self index reads Healthy).
    pub fn states(&self) -> Vec<PeerState> {
        (0..self.states.len()).map(|r| self.state(r)).collect()
    }

    pub fn is_lost(&self, rank: usize) -> bool {
        self.state(rank) == PeerState::Lost
    }

    /// The lowest-numbered lost rank, if any.
    pub fn any_lost(&self) -> Option<usize> {
        (0..self.states.len()).find(|&r| self.is_lost(r))
    }

    /// `Healthy → Suspect`. Returns true on the transition (counted once).
    pub fn mark_suspect(&self, rank: usize) -> bool {
        let flipped = self.states[rank]
            .compare_exchange(
                PeerState::Healthy as u8,
                PeerState::Suspect as u8,
                Ordering::Relaxed,
                Ordering::Relaxed,
            )
            .is_ok();
        if flipped {
            self.counters.suspects.fetch_add(1, Ordering::Relaxed);
        }
        flipped
    }

    /// Traffic arrived from `rank`: a Suspect peer recovers to Healthy.
    /// Lost stays Lost — late frames from a declared-dead peer don't
    /// resurrect it inside the same epoch (that is what [`rejoin`] is for).
    pub fn mark_alive(&self, rank: usize) {
        let _ = self.states[rank].compare_exchange(
            PeerState::Suspect as u8,
            PeerState::Healthy as u8,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
    }

    /// `* → Lost` (sticky). Returns true the first time (counted once).
    pub fn mark_lost(&self, rank: usize) -> bool {
        let prev = self.states[rank].swap(PeerState::Lost as u8, Ordering::Relaxed);
        let flipped = prev != PeerState::Lost as u8;
        if flipped {
            self.counters.losses.fetch_add(1, Ordering::Relaxed);
        }
        flipped
    }

    /// Annotate `rank` as readmitted under this (bumped) epoch.
    pub fn mark_rejoined(&self, rank: usize) {
        self.states[rank].store(PeerState::Rejoined as u8, Ordering::Relaxed);
    }

    /// Counters + epoch, materialized.
    pub fn stats(&self) -> SessionStats {
        SessionStats {
            epoch: self.epoch,
            heartbeats_sent: self.counters.heartbeats_sent.load(Ordering::Relaxed),
            heartbeats_received: self.counters.heartbeats_received.load(Ordering::Relaxed),
            suspects: self.counters.suspects.load(Ordering::Relaxed),
            losses: self.counters.losses.load(Ordering::Relaxed),
            epoch_bumps: self.counters.epoch_bumps.load(Ordering::Relaxed),
        }
    }
}

/// The typed peer-loss fault, carried through `anyhow` error chains from
/// the transport layer up to [`crate::comm::fabric::RankHandle`], which
/// downcasts it into [`CommError::PeerLost`]. Keeping it a concrete type
/// (not a string) is what lets every layer in between stay
/// `anyhow`-oblivious while the top still matches on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PeerLost {
    pub rank: usize,
    pub epoch: u16,
}

impl fmt::Display for PeerLost {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PeerLost: rank {} declared lost by the session fabric (epoch {})",
            self.rank, self.epoch
        )
    }
}

impl std::error::Error for PeerLost {}

/// Find a typed [`PeerLost`] anywhere in an `anyhow` chain.
pub fn find_peer_lost(e: &anyhow::Error) -> Option<PeerLost> {
    e.chain().find_map(|c| c.downcast_ref::<PeerLost>()).copied()
}

/// The topology of the surviving membership after `lost` ranks died: the
/// degraded-mode re-plan input. Survivors keep the original group
/// structure when every group loses the same number of ranks (the dense
/// remap of [`degraded::DegradedMesh`] then preserves group blocks);
/// otherwise the survivors collapse to one flat group — a conservative
/// model that keeps every algorithm admissible. Fewer than 2 survivors is
/// a typed error: there is no collective to degrade to.
pub fn survivor_topology(topo: &Topology, lost: &[usize]) -> Result<Topology, CommError> {
    let mut dead = vec![false; topo.n_gpus];
    for &r in lost {
        if r >= topo.n_gpus {
            return Err(CommError::shape(format!(
                "lost rank {r} out of range for a {}-rank topology",
                topo.n_gpus
            )));
        }
        if dead[r] {
            return Err(CommError::shape(format!("rank {r} listed lost twice")));
        }
        dead[r] = true;
    }
    let survivors = topo.n_gpus - lost.len();
    if survivors < 2 {
        return Err(CommError::shape(format!(
            "{survivors} survivor(s) of {} ranks: no degraded collective is possible",
            topo.n_gpus
        )));
    }
    let per_group: Vec<usize> = (0..topo.numa_groups)
        .map(|g| {
            let s = topo.group_size();
            (g * s..(g + 1) * s).filter(|&r| !dead[r]).count()
        })
        .collect();
    let uniform = per_group.iter().all(|&c| c == per_group[0]) && per_group[0] > 0;
    let t = if uniform && topo.numa_groups > 1 {
        Topology::try_custom(topo.spec.clone(), survivors, topo.numa_groups, topo.inter_bw())?
    } else {
        Topology::try_custom(topo.spec.clone(), survivors, 1, None)?
    };
    Ok(t)
}

/// Session-aware TCP bootstrap: [`TcpTransport::bootstrap_session`] with
/// every failure mapped to the typed [`CommError::Rendezvous`] — a dead
/// root, a refused connection, or a handshake that exceeded
/// [`SessionConfig::rendezvous_timeout`] all surface here instead of
/// hanging bootstrap forever.
pub fn establish(
    rank: usize,
    n: usize,
    root: &str,
    root_listener: Option<TcpListener>,
    bind: IpAddr,
    config: &SessionConfig,
) -> Result<TcpTransport, CommError> {
    TcpTransport::bootstrap_session(rank, n, root, root_listener, bind, config)
        .map_err(|e| CommError::rendezvous(format!("{e:#}")))
}

/// Session-aware UDP bootstrap: [`UdpTransport::bootstrap_session`] under
/// the same typed-error contract as [`establish`]. The rendezvous control
/// plane is still the bounded TCP handshake (rank 0 is the root); only
/// the data plane is datagrams. `fault` attaches a deterministic
/// [`crate::transport::WireFault`] program to this endpoint's outgoing
/// packets — chaos drills only, `None` in production.
pub fn establish_udp(
    rank: usize,
    n: usize,
    root: &str,
    root_listener: Option<TcpListener>,
    bind: IpAddr,
    config: &SessionConfig,
    fault: Option<crate::transport::WireFault>,
) -> Result<crate::transport::UdpTransport, CommError> {
    crate::transport::UdpTransport::bootstrap_session(
        rank,
        n,
        root,
        root_listener,
        bind,
        config,
        fault,
    )
    .map_err(|e| CommError::rendezvous(format!("{e:#}")))
}

/// NTP-style clock synchronization against rank 0 (DESIGN.md §15): the
/// collective that makes per-rank flight-recorder timelines comparable
/// for the fabric trace merge ([`crate::telemetry::merge_traces`]).
///
/// Rank 0 is the reference: it services ranks `1..n` in ascending order,
/// echoing each [`flags::PROBE`](crate::transport::frame::flags::PROBE)
/// request back with its receive (`t2`) and reply (`t3`) timestamps
/// filled in. Every other rank fires `probes` round-trips (clamped to
/// `1..=`[`MAX_PROBES`]) and estimates its offset from the minimum-RTT
/// sample via [`ClockSync`]. Probe frames travel *nested* as payloads of
/// ordinary [`Transport::send`]s, so the exchange works identically over
/// TCP, UDP, and InProc, and per-link FIFO keeps requests paired with
/// replies even when a rank reaches its turn early (its requests just
/// queue at rank 0).
///
/// `now` supplies nanoseconds on this rank's recorder clock (pass
/// `|| recorder.now_nanos()`); the exchange itself records **no**
/// telemetry events, so the closed-form per-rank event counts pinned in
/// `tests/telemetry.rs` are unaffected. Runs at session establish /
/// rejoin and again each `--iters` refresh — the estimate is cheap
/// (`probes` round-trips per non-reference rank, rank 0 linear in `n`).
pub fn sync_clocks<T: Transport + ?Sized>(
    transport: &T,
    epoch: u16,
    probes: usize,
    now: &dyn Fn() -> u64,
) -> anyhow::Result<ClockSyncStats> {
    use anyhow::{bail, ensure, Context};

    let (rank, n) = (transport.rank(), transport.n());
    let probes = probes.clamp(1, MAX_PROBES);
    if rank == 0 {
        for peer in 1..n {
            for _ in 0..probes {
                let req = transport
                    .recv(peer)
                    .with_context(|| format!("clock probe from rank {peer}"))?;
                let t2 = now();
                let hdr = frame::FrameHeader::parse(&req)?;
                ensure!(
                    hdr.flags == frame::flags::PROBE,
                    "expected a clock probe from rank {peer}, got flags {:#04x}",
                    hdr.flags
                );
                hdr.check_payload(&req[frame::FRAME_HEADER_LEN..])?;
                let (t1, _, _) = frame::decode_probe(&req[frame::FRAME_HEADER_LEN..])?;
                let t3 = now();
                transport
                    .send(
                        peer,
                        frame::encode_probe(0, peer as u16, epoch, hdr.seq, t1, t2, t3),
                    )
                    .with_context(|| format!("clock probe reply to rank {peer}"))?;
            }
        }
        return Ok(ClockSyncStats::reference(0));
    }

    let mut sync = ClockSync::new();
    for k in 0..probes {
        let t1 = now();
        transport
            .send(0, frame::encode_probe(rank as u16, 0, epoch, k as u32, t1, 0, 0))
            .context("clock probe request")?;
        let reply = transport.recv(0).context("clock probe reply")?;
        let t4 = now();
        let hdr = frame::FrameHeader::parse(&reply)?;
        ensure!(
            hdr.flags == frame::flags::PROBE,
            "expected a clock probe reply, got flags {:#04x}",
            hdr.flags
        );
        hdr.check_payload(&reply[frame::FRAME_HEADER_LEN..])?;
        let (t1_echo, t2, t3) = frame::decode_probe(&reply[frame::FRAME_HEADER_LEN..])?;
        ensure!(
            t1_echo == t1 && hdr.seq == k as u32,
            "clock probe reply mismatched: echoed t1 {t1_echo} (sent {t1}), seq {} (sent {k})",
            hdr.seq
        );
        sync.add(ProbeSample { t1, t2, t3, t4 });
    }
    match sync.stats(rank as u16) {
        Some(stats) => Ok(stats),
        None => bail!("no clock probe completed against rank 0"),
    }
}

/// Re-rendezvous under `config.epoch + 1`: the whole surviving membership
/// (plus the restarted rank) bootstraps a fresh mesh whose frames carry
/// the bumped epoch, so anything a previous incarnation still emits is
/// rejected by the epoch check. Counts one epoch bump on the new session.
pub fn rejoin(
    rank: usize,
    n: usize,
    root: &str,
    root_listener: Option<TcpListener>,
    bind: IpAddr,
    config: &SessionConfig,
) -> Result<TcpTransport, CommError> {
    let bumped = config.clone().with_epoch(config.epoch.wrapping_add(1));
    let t = establish(rank, n, root, root_listener, bind, &bumped)?;
    if let Some(s) = t.session_shared() {
        s.counters.epoch_bumps.fetch_add(1, Ordering::Relaxed);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::presets;

    #[test]
    fn config_from_millis_validates_the_pair() {
        assert!(!SessionConfig::from_millis(0, 0).unwrap().enabled());
        let c = SessionConfig::from_millis(250, 1000).unwrap();
        assert!(c.enabled());
        assert_eq!(c.heartbeat, Some(Duration::from_millis(250)));
        assert_eq!(c.deadline, Some(Duration::from_millis(1000)));
        assert_eq!(c.epoch, 0);
        for (hb, to) in [(250, 0), (0, 1000), (250, 499)] {
            let e = SessionConfig::from_millis(hb, to).unwrap_err();
            assert!(matches!(e, CommError::Shape { .. }), "{hb}/{to}: {e}");
        }
    }

    #[test]
    fn state_machine_transitions_and_counters() {
        let s = SessionShared::new(4, 3);
        assert_eq!(s.states(), vec![PeerState::Healthy; 4]);
        assert!(s.mark_suspect(1));
        assert!(!s.mark_suspect(1), "suspect is counted once per transition");
        s.mark_alive(1);
        assert_eq!(s.state(1), PeerState::Healthy);
        assert!(s.mark_suspect(1), "recovered peers can be suspected again");
        assert!(s.mark_lost(1));
        assert!(!s.mark_lost(1), "lost is sticky and counted once");
        s.mark_alive(1);
        assert_eq!(s.state(1), PeerState::Lost, "late traffic does not resurrect a lost peer");
        assert_eq!(s.any_lost(), Some(1));
        s.mark_rejoined(2);
        assert_eq!(s.state(2), PeerState::Rejoined);
        let st = s.stats();
        assert_eq!((st.epoch, st.suspects, st.losses), (3, 2, 1));
    }

    #[test]
    fn peer_lost_travels_through_anyhow() {
        let e = anyhow::Error::new(PeerLost { rank: 5, epoch: 2 }).context("recv failed");
        assert_eq!(find_peer_lost(&e), Some(PeerLost { rank: 5, epoch: 2 }));
        assert!(find_peer_lost(&anyhow::anyhow!("plain")).is_none());
    }

    #[test]
    fn survivor_topology_keeps_uniform_groups() {
        // 8 ranks in 2 groups; one loss per group keeps the grouping.
        let t = Topology::try_with_groups(presets::l40(), 8, 2).unwrap();
        let s = survivor_topology(&t, &[1, 6]).unwrap();
        assert_eq!((s.n_gpus, s.numa_groups), (6, 2));
        assert_eq!(s.inter_bw(), t.inter_bw());
        assert_ne!(s.fingerprint(), t.fingerprint(), "cached plans must not be reused");
    }

    #[test]
    fn survivor_topology_flattens_uneven_losses() {
        let t = Topology::try_with_groups(presets::l40(), 8, 2).unwrap();
        let s = survivor_topology(&t, &[3]).unwrap();
        assert_eq!((s.n_gpus, s.numa_groups), (7, 1));
        assert_eq!(s.inter_bw(), None);
    }

    #[test]
    fn establish_against_a_dead_root_is_a_typed_rendezvous_error() {
        // Nothing listens on the discard port: bootstrap must fail as a
        // typed CommError::Rendezvous within the handshake timeout.
        let config = SessionConfig::disabled().with_rendezvous_timeout(Duration::from_millis(200));
        let e = establish(1, 2, "127.0.0.1:9", None, crate::transport::tcp::DEFAULT_BIND, &config)
            .unwrap_err();
        assert!(matches!(e, CommError::Rendezvous { .. }), "{e}");
        assert!(e.to_string().contains("dead root"), "{e}");
    }

    #[test]
    fn sync_clocks_estimates_within_the_rtt_bound() {
        // 3-rank InProc mesh: every clock is literally the same Instant
        // epoch here (the closure fakes skew), so the true offsets are
        // known exactly and the NTP bound is checkable.
        let mut mesh = crate::transport::inproc::mesh(3);
        let (t2, t1, t0) = (mesh.pop().unwrap(), mesh.pop().unwrap(), mesh.pop().unwrap());
        let base = std::time::Instant::now();
        let clock = move |skew: i64| {
            let t = base.elapsed().as_nanos() as i64 + skew;
            t.max(0) as u64
        };
        let h1 = std::thread::spawn(move || {
            // Rank 1's clock runs 2 ms ahead of rank 0's.
            sync_clocks(&t1, 0, 8, &move || clock(2_000_000)).unwrap()
        });
        let h2 = std::thread::spawn(move || {
            // Rank 2's clock runs 5 ms behind.
            sync_clocks(&t2, 0, 8, &move || clock(-5_000_000)).unwrap()
        });
        let s0 = sync_clocks(&t0, 0, 8, &move || clock(0)).unwrap();
        assert_eq!(s0, ClockSyncStats::reference(0));
        let (s1, s2) = (h1.join().unwrap(), h2.join().unwrap());
        assert_eq!((s1.rank, s2.rank), (1, 2));
        assert_eq!((s1.probes, s2.probes), (8, 8));
        // offset maps local → reference: rank 1 ahead ⇒ negative offset,
        // rank 2 behind ⇒ positive, each within rtt/2 of the truth.
        let bound1 = (s1.rtt_nanos / 2) as i64;
        let bound2 = (s2.rtt_nanos / 2) as i64;
        assert!((s1.offset_nanos + 2_000_000).abs() <= bound1, "{s1:?}");
        assert!((s2.offset_nanos - 5_000_000).abs() <= bound2, "{s2:?}");
    }

    #[test]
    fn survivor_topology_rejects_hostile_inputs() {
        let t = Topology::try_with_groups(presets::l40(), 4, 2).unwrap();
        for lost in [vec![9], vec![1, 1], vec![0, 1, 2]] {
            let e = survivor_topology(&t, &lost).unwrap_err();
            assert!(matches!(e, CommError::Shape { .. }), "{lost:?}: {e}");
        }
    }
}
