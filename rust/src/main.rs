//! `flashcomm` — the FlashCommunication V2 coordinator CLI.
//!
//! ```text
//! flashcomm table <1..10|all> [--quick] [--steps N] [--batches N] [--size 64M]
//! flashcomm figure <1|2|4|5|8|all> [--quick] [--codec spec] [--chunks K]
//! flashcomm train   [--config tiny] [--steps N] [--dp N] [--codec spec]
//!                   [--algo ring|twostep|hier|hierpp|auto] [--groups G]
//!                   [--out ckpt.bin]
//! flashcomm eval    [--config tiny] [--ckpt path] [--codec spec]
//!                   [--algo twostep|hier|auto] [--groups G] [--batches N]
//! flashcomm ttft    [--prompt N] [--batch N]
//! flashcomm worker  [--world N] [--algo hier|auto] [--groups G]
//!                   [--codecs int4@32,int2-sr@32] [--len N]
//!                   [--root host:port] [--rank R] [--codec-threads T]
//! flashcomm info
//! ```
//!
//! Codec spec grammar: `bf16 | int<bits>[-rtn|-sr|-had|-log][@<gs>][!]`
//! (`!` = integer Eq.1 metadata), e.g. `int5`, `int2-sr@32`, `int2-sr@32!`.
//! `--algo auto` lets the cost model pick the algorithm per payload size.
//! `--groups G` shapes the rank-group topology: 1 = flat NVLink node,
//! `G >= 2` = G equal link-tier groups joined by NUMA bridges (the
//! generalized hierarchical family runs at any admissible G).

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use flashcomm::cli::Args;
use flashcomm::comm::{fabric, preset_topo_grouped, AlgoPolicy, Communicator};
use flashcomm::coordinator::{TpEngine, TrainOptions, Trainer};
use flashcomm::harness;
use flashcomm::model::{Corpus, ModelConfig, Sampler, Weights};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, Runtime};
use flashcomm::transport::{frame, TcpTransport, Transport};
use flashcomm::util::Prng;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table" => harness::run_table(args),
        "figure" => harness::run_figure(args),
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "ttft" => {
            let mut a = args.clone();
            a.positional = vec!["2".into()];
            harness::run_figure(&a)
        }
        "worker" => cmd_worker(args),
        "info" => cmd_info(),
        "" | "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `flashcomm help`)"),
    }
}

/// Parse the optional `--groups G` flag (link-tier group count for the
/// rank-group topology: 1 = flat NVLink node, G >= 2 = G-group NUMA box).
fn groups_flag(args: &Args) -> Result<Option<usize>> {
    match args.flag("groups") {
        None => Ok(None),
        Some(v) => {
            let g: usize = v.parse().with_context(|| format!("--groups {v}"))?;
            Ok(Some(g))
        }
    }
}

const HELP: &str = "\
flashcomm — FlashCommunication V2 (bit splitting + spike reserving) reproduction

commands:
  table <1..10|all>   regenerate a paper table (see DESIGN.md §5)
  figure <1|2|4|5|8>  regenerate a paper figure
  train               DP-train a model with quantized gradient AllReduce
  eval                TP-inference perplexity under a wire codec
  ttft                Fig.2 TTFT sweep
  worker              multi-process quantized AllReduce over the TCP fabric
                      (spawns one OS process per rank; verifies bit-identical
                      results vs the in-process backend)
  info                artifacts / manifest / device presets

common flags: --quick (small sweep), --steps N, --batches N, --codec SPEC
codec SPEC: bf16 | int<b>[-sr|-had|-log][@gs][!]   e.g. int2-sr@32!
algo: --algo ring|twostep|hier|hierpp|auto — `auto` consults the cost
      model per payload (hier above the crossover size, two-step below)
groups: --groups G — link-tier groups of the rank-group topology
      (1 = flat NVLink, G >= 2 = G NUMA groups; hier runs at any G >= 2)
";

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.flag_or("config", "tiny");
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(&config)?)?;
    let init = match args.flag("ckpt") {
        Some(p) => Weights::load(p)?,
        None => Weights::load(
            default_artifacts_dir().join(format!("{config}_init_weights.bin")),
        )?,
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (train, eval) = corpus.split();
    let mut sampler = Sampler::new(train, args.flag_usize("seed", 7)? as u64);
    let eval_batches = Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len);
    let opts = TrainOptions {
        steps: args.flag_usize("steps", 200)?,
        dp: args.flag_usize("dp", 4)?,
        codec: Codec::parse(&args.flag_or("codec", "bf16"))?,
        algo: args.flag_or("algo", "twostep").parse()?,
        groups: groups_flag(args)?,
        log_every: args.flag_usize("log-every", 10)?,
        eval_every: args.flag_usize("eval-every", 50)?,
        eval_batches: args.flag_usize("eval-batches", 8)?,
        seed: args.flag_usize("seed", 7)? as u64,
    };
    println!(
        "training {config} ({} params) for {} steps, dp={}, grads over {} [{}]",
        cfg.n_params,
        opts.steps,
        opts.dp,
        opts.codec.name(),
        args.flag_or("algo", "twostep"),
    );
    let mut trainer = Trainer::new(rt, cfg, &init)?;
    let t0 = std::time::Instant::now();
    let recs = trainer.train(&mut sampler, &eval_batches, &opts)?;
    let total = t0.elapsed().as_secs_f64();
    let final_ppl = trainer.eval_ppl(&eval_batches[..eval_batches.len().min(8)])?;
    println!(
        "done: {} steps in {:.1}s ({:.2}s/step), final loss {:.4}, eval ppl {:.3}",
        recs.len(),
        total,
        total / recs.len() as f64,
        recs.last().map(|r| r.loss).unwrap_or(f32::NAN),
        final_ppl
    );
    if let Some(out) = args.flag("out") {
        trainer.export_weights()?.save(out).with_context(|| format!("saving {out}"))?;
        println!("checkpoint saved to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let config = args.flag_or("config", "tiny");
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(&config)?)?;
    let weights = match args.flag("ckpt") {
        Some(p) => Weights::load(p)?,
        None => {
            let (_, w, _) = flashcomm::coordinator::pretrain::ensure_trained(
                &config,
                flashcomm::coordinator::pretrain::ACCURACY_STEPS,
            )?;
            w
        }
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let n = args.flag_usize("batches", 6)?;
    let batches: Vec<_> =
        Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len).into_iter().take(n).collect();
    let codec = Codec::parse(&args.flag_or("codec", "bf16"))?;
    if let Some(style) = args.flag("style") {
        bail!("--style was replaced by --algo (try `--algo {style}`, or `--algo auto`)");
    }
    let policy: AlgoPolicy = args.flag_or("algo", "twostep").parse()?;
    let mut engine = TpEngine::new_grouped(rt, cfg, &weights, codec, policy, groups_flag(args)?)?;
    let t0 = std::time::Instant::now();
    let ppl = engine.perplexity(&batches)?;
    println!(
        "{config} perplexity under {} (--algo {policy}): {:.4}   [{} batches, {:.2}s]",
        codec.name(),
        ppl,
        batches.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `worker` — the multi-process TCP fabric demo.
///
/// Without `--rank` this is the *launcher*: it reserves a rendezvous port,
/// spawns one OS process per rank (re-invoking this binary with `--rank R`),
/// and fails if any rank fails. With `--rank` it is one rank: it bootstraps
/// the TCP mesh, runs the quantized AllReduce for each requested codec, and
/// verifies the result is bit-identical to the in-process backend on the
/// same inputs.
fn cmd_worker(args: &Args) -> Result<()> {
    let world = args.flag_usize("world", 4)?;
    ensure!(world >= 2, "worker demo needs at least 2 ranks (got --world {world})");
    let len = args.flag_usize("len", 4096)?;
    let algo = args.flag_or("algo", "hier");
    let groups = groups_flag(args)?;
    // Validate once here rather than erroring in every spawned process:
    // the topology must construct (world divisible into --groups) and a
    // fixed algorithm must be admissible on it (`Algo::admissible`).
    let policy: AlgoPolicy = algo.parse()?;
    preset_topo_grouped(world, groups, policy)?;
    let codecs = args.flag_or("codecs", "int4@32,int2-sr@32");
    // Codec worker threads per rank: each rank owns its process here, so
    // large payloads may fan the fused quantize/pack kernels out (the
    // in-process reference always runs 1 to avoid oversubscription).
    let codec_threads = args.flag_usize("codec-threads", 1)?;
    match args.flag("rank") {
        Some(r) => {
            let rank: usize = r.parse().with_context(|| format!("--rank {r}"))?;
            let root = args.require("root")?;
            worker_rank(rank, world, len, &algo, groups, &codecs, root, codec_threads)
        }
        None => {
            worker_launch(world, len, &algo, groups, &codecs, args.flag("root"), codec_threads)
        }
    }
}

fn worker_launch(
    world: usize,
    len: usize,
    algo: &str,
    groups: Option<usize>,
    codecs: &str,
    root: Option<&str>,
    codec_threads: usize,
) -> Result<()> {
    let root = match root {
        Some(r) => r.to_string(),
        None => {
            // Reserve an ephemeral rendezvous port; rank 0 rebinds it after
            // the probe is dropped.
            let probe = std::net::TcpListener::bind(("127.0.0.1", 0))
                .context("probing for a free rendezvous port")?;
            let addr = probe.local_addr()?.to_string();
            drop(probe);
            addr
        }
    };
    let exe = std::env::current_exe().context("resolving the worker binary path")?;
    let grouping = match groups {
        Some(g) => format!(", {g} groups"),
        None => String::new(),
    };
    println!(
        "spawning {world} worker processes: rendezvous {root}, algo {algo}{grouping}, \
         codecs {codecs}, {len} elems/rank"
    );
    let mut children = Vec::with_capacity(world);
    for rank in 0..world {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .args(["--rank", &rank.to_string()])
            .args(["--world", &world.to_string()])
            .args(["--root", &root])
            .args(["--len", &len.to_string()])
            .args(["--algo", algo])
            .args(["--codecs", codecs])
            .args(["--codec-threads", &codec_threads.to_string()]);
        if let Some(g) = groups {
            cmd.args(["--groups", &g.to_string()]);
        }
        let child =
            cmd.spawn().with_context(|| format!("spawning worker rank {rank}"))?;
        children.push((rank, child));
    }
    let mut failed = false;
    for (rank, mut child) in children {
        let status = child.wait().with_context(|| format!("waiting for rank {rank}"))?;
        if !status.success() {
            eprintln!("worker rank {rank} failed: {status}");
            failed = true;
        }
    }
    ensure!(!failed, "one or more worker ranks failed");
    println!("all {world} worker processes agree with the InProc backend bit-for-bit");
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn worker_rank(
    rank: usize,
    world: usize,
    len: usize,
    algo_str: &str,
    groups: Option<usize>,
    codecs: &str,
    root: &str,
    codec_threads: usize,
) -> Result<()> {
    let policy: AlgoPolicy = algo_str.parse()?;
    let topo = preset_topo_grouped(world, groups, policy)?;
    let tcp = TcpTransport::bootstrap(rank, world, root)
        .with_context(|| format!("rank {rank} bootstrapping the TCP mesh at {root}"))?;
    let mut comm =
        Communicator::new(tcp, topo.clone(), Arc::new(fabric::ByteCounters::default()))?;
    comm.set_codec_threads(codec_threads);

    // Deterministic heavy-tailed inputs, identical in every process (and in
    // the in-process reference below).
    let inputs: Vec<Vec<f32>> = (0..world)
        .map(|r| {
            let mut rng = Prng::new(1000 + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect();

    for spec in codecs.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let codec = Codec::parse(spec)?;

        // The real thing: this process is one rank of the TCP mesh.
        let mut mine = inputs[rank].clone();
        let used = comm.allreduce(&mut mine, &codec, policy)?;

        // Reference: the same collective over the in-process backend. The
        // policy resolves per (topology, codec, size), so both backends
        // pick the same algorithm without coordination.
        let inputs_ref = &inputs;
        let (reference, _) = fabric::run_ranks(&topo, |rh| {
            let mut c = Communicator::from_handle(rh);
            let mut d = inputs_ref[c.rank()].clone();
            let ref_used =
                c.allreduce(&mut d, &codec, policy).expect("in-process reference failed");
            assert_eq!(ref_used, used, "backends resolved different algorithms");
            d
        });
        let expect = &reference[rank];
        ensure!(mine.len() == expect.len(), "{spec}: length mismatch");
        for (i, (a, b)) in mine.iter().zip(expect).enumerate() {
            ensure!(
                a.to_bits() == b.to_bits(),
                "[rank {rank}] {spec}: TCP diverges from InProc at element {i}: {a} vs {b}"
            );
        }
        println!(
            "[rank {rank}] {spec} {used} AllReduce (--algo {algo_str}) over TCP == InProc \
             bit-for-bit ({len} elems)"
        );
    }

    let stats = comm.transport().stats();
    println!(
        "[rank {rank}] sent {} messages, {} payload B, {} wire B ({} B framing)",
        stats.messages,
        stats.payload_bytes,
        stats.wire_bytes,
        stats.wire_bytes - stats.payload_bytes
    );

    if rank == 0 {
        // Demonstrate the frame guard: a corrupted payload must be rejected
        // with a CRC error, never decoded.
        let payload = Codec::parse("int4@32")?.encode(&inputs[0]);
        let mut framed = frame::encode(0, 1, 0, &payload);
        let last = framed.len() - 1;
        framed[last] ^= 0x01;
        match frame::decode(framed) {
            Err(e) => println!("[rank 0] corrupted frame correctly rejected: {e}"),
            Ok(_) => bail!("corrupted frame was not rejected"),
        }
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::open(default_artifacts_dir())?;
    println!("artifacts: {:?}", rt.dir());
    println!("configs:");
    for c in &rt.manifest.configs {
        println!(
            "  {} — {} params, vocab {}, tp {}",
            c.name,
            c.get("n_params").unwrap_or("?"),
            c.get("vocab").unwrap_or("?"),
            c.get("tp").unwrap_or("?")
        );
    }
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for a in &rt.manifest.artifacts {
        println!("  {}", a.name);
    }
    println!("device presets (Table 6):");
    for s in flashcomm::topo::presets::all() {
        println!(
            "  {:>5}: {} SMs, {} GB/s nominal, {} TFLOPs bf16 (CUDA), comm SMs {}",
            s.name, s.sms, s.nominal_bw_gbps, s.bf16_tflops, s.comm_sms
        );
    }
    Ok(())
}
