//! `flashcomm` — the FlashCommunication V2 coordinator CLI.
//!
//! ```text
//! flashcomm table <1..10|all> [--quick] [--steps N] [--batches N] [--size 64M]
//! flashcomm figure <1|2|4|5|8|all> [--quick] [--codec spec] [--chunks K]
//! flashcomm train   [--config tiny] [--steps N] [--dp N] [--codec spec]
//!                   [--algo ring|twostep|hier|hierpp|auto] [--groups G]
//!                   [--plan auto|spec] [--chunks K] [--window W]
//!                   [--out ckpt.bin] [--trace-out path]
//! flashcomm eval    [--config tiny] [--ckpt path] [--codec spec]
//!                   [--algo twostep|hier|auto] [--groups G] [--batches N]
//!                   [--plan auto|spec] [--chunks K] [--window W]
//!                   [--trace-out path]
//! flashcomm ttft    [--prompt N] [--batch N]
//! flashcomm worker  [--world N] [--algo hier|auto] [--groups G]
//!                   [--codecs int4@32,int2-sr@32] [--len N] [--iters K]
//!                   [--root host:port] [--rank R] [--codec-threads T]
//!                   [--plan auto|spec] [--chunks K] [--window W]
//!                   [--bind ip] [--inter-gbps F] [--trace-out path]
//!                   [--transport tcp|udp]
//!                   [--wire-fault-pct P [--wire-fault-seed S]]
//!                   [--heartbeat-ms H] [--comm-timeout-ms T]
//!                   [--kill-rank R [--kill-after-ms M]] [--rejoin-rank R]
//! flashcomm metrics [--ranks N] [--groups G] [--codec spec] [--len N]
//!                   [--iters K] [--plan auto|spec] [--out path]
//!                   [--trace-out path] [--serve addr [--serve-max N]]
//! flashcomm trace merge <file...> [--out path]
//! flashcomm info
//! ```
//!
//! Codec spec grammar: `bf16 | int<bits>[-rtn|-sr|-had|-log][@<gs>][!]`
//! (`!` = integer Eq.1 metadata), e.g. `int5`, `int2-sr@32`, `int2-sr@32!`.
//! `--algo auto` lets the cost model pick the algorithm per payload size.
//! `--groups G` shapes the rank-group topology: 1 = flat NVLink node,
//! `G >= 2` = G equal link-tier groups joined by NUMA bridges (the
//! generalized hierarchical family runs at any admissible G).
//! `--plan auto` compiles a full communication plan per payload —
//! algorithm, per-stage codecs (a tier-asymmetric cluster gets a more
//! aggressive cross-group codec), micro-chunk count — while
//! `--plan <algo>[:intra=c][:cross=c][:ag=c][:chunks=K][:window=W][:threads=T]`
//! pins one. `--chunks`/`--window` pin those knobs in either mode.
//! `--inter-gbps F` models G NVLink nodes joined by an F GB/s link;
//! `--bind ip` lets worker data sockets leave loopback (DESIGN.md §4).
//! `--trace-out p` turns on the flight recorder and writes one JSON trace
//! per rank to `p.rankR` (schema: DESIGN.md §11); `--trace-capacity N`
//! sizes the per-rank event ring (0 is rejected). The worker launcher
//! additionally clock-aligns and merges the per-rank traces into one
//! Chrome-trace `p.merged.json` with send→recv flow arrows, prints the
//! fabric critical-path / straggler report, and recalibrates the cost
//! model from the *fabric* view (DESIGN.md §15); `trace merge` does the
//! same merge offline from saved trace files. `metrics` runs a small
//! recorded in-process demo and prints the aggregated metrics snapshot;
//! `--serve addr` then serves it as a Prometheus text-exposition scrape
//! endpoint for `--serve-max` requests.
//! `--heartbeat-ms H` / `--comm-timeout-ms T` configure the session fabric
//! (DESIGN.md §12): heartbeats every `H` ms, a silent peer is declared
//! Lost at `T` ms and every survivor gets a typed `PeerLost` instead of
//! hanging. The launcher's `--kill-rank` / `--rejoin-rank` modes turn the
//! worker demo into end-to-end failure drills over real processes.
//! `--transport udp` swaps the worker data plane for the loss-tolerant
//! datagram backend (NACK reassembly + retransmit, DESIGN.md §13);
//! `--wire-fault-pct P [--wire-fault-seed S]` runs it over a seeded chaos
//! wire that drops/duplicates/corrupts/reorders `P`% of datagrams each —
//! the results must *still* be bit-identical to InProc. The chaos knobs
//! are UDP-only and rejected loudly on any other backend.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use flashcomm::cli::{self, Args, TransportSel};
use flashcomm::comm::{fabric, preset_topo_custom, AlgoPolicy, CommError, Communicator, LocalGroup};
use flashcomm::coordinator::{TpEngine, TrainOptions, Trainer};
use flashcomm::harness;
use flashcomm::model::{Corpus, ModelConfig, Sampler, Weights};
use flashcomm::plan::{CommPlan, PlanPins, PlanPolicy};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, Runtime};
use flashcomm::session::{self, SessionConfig};
use flashcomm::telemetry::{self, MetricsSnapshot};
use flashcomm::transport::{frame, tcp, Transport, WireFault};
use flashcomm::util::Prng;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table" => harness::run_table(args),
        "figure" => harness::run_figure(args),
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "ttft" => {
            let mut a = args.clone();
            a.positional = vec!["2".into()];
            harness::run_figure(&a)
        }
        "worker" => cmd_worker(args),
        "metrics" => cmd_metrics(args),
        "trace" => cmd_trace(args),
        "lint" => cmd_lint(args),
        "info" => cmd_info(),
        "" | "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `flashcomm help`)"),
    }
}

/// Parse the optional `--groups G` flag (link-tier group count for the
/// rank-group topology: 1 = flat NVLink node, G >= 2 = G-group NUMA box).
fn groups_flag(args: &Args) -> Result<Option<usize>> {
    match args.flag("groups") {
        None => Ok(None),
        Some(v) => {
            let g: usize = v.parse().with_context(|| format!("--groups {v}"))?;
            Ok(Some(g))
        }
    }
}

/// Parse the optional `--inter-gbps F` flag (effective inter-group link
/// bandwidth override: models multi-node NVLink clusters; see
/// [`preset_topo_custom`]).
fn inter_gbps_flag(args: &Args) -> Result<Option<f64>> {
    match args.flag("inter-gbps") {
        None => Ok(None),
        Some(v) => {
            let gbps: f64 = v.parse().with_context(|| format!("--inter-gbps {v}"))?;
            Ok(Some(gbps))
        }
    }
}

/// Parse the session-fabric pair `--heartbeat-ms` / `--comm-timeout-ms`
/// (defaults 250 / 1000; both 0 disables liveness tracking). The pair is
/// validated by [`SessionConfig::from_millis`] — a lone zero or a timeout
/// under twice the heartbeat is a typed argument error. Every
/// fabric-driving command parses this; only the TCP fabric has sockets to
/// attach the deadlines to (DESIGN.md §12), so for the in-process
/// backends a valid pair is inert.
fn session_flags(args: &Args) -> Result<SessionConfig> {
    let hb = args.flag_usize("heartbeat-ms", 250)? as u64;
    let to = args.flag_usize("comm-timeout-ms", 1000)? as u64;
    Ok(SessionConfig::from_millis(hb, to)?)
}

/// Parse the `--chunks N` / `--window N` plan-knob pins (clean error on
/// `--chunks 0` / `--window 0`).
fn pins_flags(args: &Args) -> Result<PlanPins> {
    let parse = |name: &str| -> Result<Option<usize>> {
        match args.flag(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().with_context(|| format!("--{name} {v}"))?)),
        }
    };
    let pins = PlanPins { chunks: parse("chunks")?, window: parse("window")? };
    pins.validate()?;
    Ok(pins)
}

/// Resolve the plan policy for one base codec from `--plan` (auto or a
/// spec) plus the `--chunks`/`--window` pins. With no `--plan`, pins
/// alone still enter the plan layer: a fixed `--algo` becomes a pinned
/// uniform plan, `--algo auto` a pinned `Auto` search. Returns `None`
/// only when nothing plan-related was requested (the legacy `AlgoPolicy`
/// path).
fn plan_policy_for(
    plan: Option<&str>,
    pins: PlanPins,
    algo: AlgoPolicy,
    base: &Codec,
) -> Result<Option<PlanPolicy>> {
    match plan {
        Some(spec) if spec.eq_ignore_ascii_case("auto") => Ok(Some(PlanPolicy::Auto(pins))),
        Some(spec) => {
            let plan = pins.apply(CommPlan::parse(spec, base)?);
            plan.validate_shape().with_context(|| format!("--plan {spec}"))?;
            Ok(Some(PlanPolicy::Fixed(plan)))
        }
        None if pins.is_empty() => Ok(None),
        None => Ok(Some(match algo {
            AlgoPolicy::Auto => PlanPolicy::Auto(pins),
            AlgoPolicy::Fixed(a) => {
                let plan = pins.apply(CommPlan::uniform(a, *base));
                plan.validate_shape().context("--chunks/--window")?;
                PlanPolicy::Fixed(plan)
            }
        })),
    }
}

const HELP: &str = "\
flashcomm — FlashCommunication V2 (bit splitting + spike reserving) reproduction

commands:
  table <1..10|all>   regenerate a paper table (see DESIGN.md §5)
  figure <1|2|4|5|8>  regenerate a paper figure
  train               DP-train a model with quantized gradient AllReduce
  eval                TP-inference perplexity under a wire codec
  ttft                Fig.2 TTFT sweep
  worker              multi-process quantized AllReduce over the TCP fabric
                      (spawns one OS process per rank; verifies bit-identical
                      results vs the in-process backend)
  metrics             recorded in-process AllReduce demo; prints the
                      aggregated metrics snapshot as JSON on stdout;
                      --serve ADDR serves it as a Prometheus text scrape
                      endpoint for --serve-max requests (default 1)
  trace merge <f...>  clock-align per-rank trace files into one Chrome-trace
                      JSON (--out path, else stdout) and print the fabric
                      critical-path / straggler report on stderr
  lint                flashlint static analysis over this repo's sources
                      (wire/panic/lock/unsafe/obs rules, DESIGN.md §14);
                      [--root DIR] [--json]; exits non-zero on findings
  info                artifacts / manifest / device presets

common flags: --quick (small sweep), --steps N, --batches N, --codec SPEC
codec SPEC: bf16 | int<b>[-sr|-had|-log][@gs][!]   e.g. int2-sr@32!
algo: --algo ring|twostep|hier|hierpp|auto — `auto` consults the cost
      model per payload (hier above the crossover size, two-step below)
groups: --groups G — link-tier groups of the rank-group topology
      (1 = flat NVLink, G >= 2 = G NUMA groups; hier runs at any G >= 2)
plan: --plan auto — compile a full communication plan per payload
      (algorithm + per-stage codecs + tuned chunking, cached by shape);
      --plan <algo>[:intra=c][:cross=c][:ag=c][:chunks=K][:window=W][:threads=T]
      runs a fixed plan, e.g. `hier:cross=int2-sr@32!` under --codec
      int4@32. --chunks K / --window W pin those knobs (error if 0).
worker: --bind IP — bind data listeners beyond loopback (multi-node);
      --inter-gbps F — model G NVLink nodes joined by an F GB/s link
      (the tier-asymmetric shape where auto plans mix stage codecs);
      --iters K — repeat each codec's AllReduce K times
transport: --transport tcp|udp — the worker data plane (default tcp).
      udp shreds each frame into <= 1200 B datagrams and recovers loss
      with receiver NACKs + sender retransmit (DESIGN.md §13);
      --wire-fault-pct P [--wire-fault-seed S] (udp only) runs it over a
      seeded chaos wire — P% of datagrams dropped, duplicated, corrupted,
      and reordered each — and still requires bit-identity vs InProc.
      train/eval are in-process only and reject any other --transport.
session: --heartbeat-ms H / --comm-timeout-ms T — liveness fabric for the
      TCP backend (DESIGN.md §12): heartbeats every H ms, a silent peer is
      Suspect at T/2 and Lost at T, surfacing a typed PeerLost on every
      survivor instead of a hang. Defaults 250/1000; both 0 disables the
      fabric (rejected when --bind leaves loopback).
faults: --kill-rank R [--kill-after-ms M] — launcher-only drill: SIGKILL
      rank R mid-run and require every survivor to exit with PeerLost
      within 2x the timeout (runs on either transport, including a lossy
      udp wire); --rejoin-rank R — epoch drill (tcp only): R drops after
      one collective, survivors see PeerLost, everyone re-rendezvouses at
      epoch 1 and the post-rejoin AllReduce must match InProc bit-for-bit
trace: --trace-out P — flight-record every collective and write one JSON
      trace per rank to P.rankR (train / eval / worker / metrics;
      schema + recalibration formula in DESIGN.md §11);
      --trace-capacity N — events per rank in the recorder ring (default
      4096; 0 rejected). The worker launcher also clock-syncs the ranks
      (NTP-style probes over the data plane), merges the traces into
      P.merged.json with send->recv flow arrows, prints the straggler
      report, and recalibrates from the fabric critical path
      (DESIGN.md §15)
";

/// `flashcomm trace merge <file...> [--out path]` — clock-align saved
/// per-rank trace files into one fabric-wide Chrome-trace JSON
/// (`chrome://tracing` / Perfetto), plus the critical-path / straggler
/// report on stderr. The merged JSON goes to `--out` or stdout, so the
/// report never pollutes a piped merge.
fn cmd_trace(args: &Args) -> Result<()> {
    let sub = args.pos(0).context("usage: flashcomm trace merge <file...> [--out path]")?;
    ensure!(
        sub == "merge",
        "unknown trace subcommand '{sub}' (try `flashcomm trace merge <file...>`)"
    );
    let files = &args.positional[1..];
    ensure!(
        !files.is_empty(),
        "trace merge: pass the per-rank trace files (e.g. `flashcomm trace merge t.json.rank*`)"
    );
    let mut traces = Vec::with_capacity(files.len());
    for f in files {
        let text = std::fs::read_to_string(f).with_context(|| format!("reading {f}"))?;
        traces.push(telemetry::parse_trace(&text).with_context(|| format!("parsing {f}"))?);
    }
    let merged = telemetry::merge_traces(&traces)?;
    for w in &merged.warnings {
        eprintln!("warning: {w}");
    }
    let report = telemetry::analyze(&traces);
    for line in report.summary_lines() {
        eprintln!("{line}");
    }
    if report.is_clean() {
        eprintln!("straggler report: clean");
    }
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, &merged.json).with_context(|| format!("writing {path}"))?;
            eprintln!(
                "merged fabric trace written to {path} ({} ranks, {} spans, {} flow arrows)",
                merged.ranks, merged.spans, merged.flows
            );
        }
        None => println!("{}", merged.json),
    }
    Ok(())
}

/// The worker launcher's post-run merge: read back every rank's trace
/// file, clock-align and merge them to `{path}.merged.json`, and print
/// the fabric critical-path / straggler report plus the fabric-wide
/// recalibration (the straggler-robust per-tier medians of DESIGN.md
/// §15, vs each rank's pooled local estimate).
fn merge_worker_traces(path: &str, world: usize) -> Result<()> {
    let mut traces = Vec::with_capacity(world);
    for r in 0..world {
        let file = format!("{path}.rank{r}");
        let text =
            std::fs::read_to_string(&file).with_context(|| format!("reading trace {file}"))?;
        traces.push(telemetry::parse_trace(&text).with_context(|| format!("parsing trace {file}"))?);
    }
    let merged = telemetry::merge_traces(&traces)?;
    for w in &merged.warnings {
        eprintln!("warning: {w}");
    }
    let out = format!("{path}.merged.json");
    std::fs::write(&out, &merged.json).with_context(|| format!("writing {out}"))?;
    println!(
        "merged fabric trace written to {out} ({} ranks, {} spans, {} flow arrows)",
        merged.ranks, merged.spans, merged.flows
    );
    let report = telemetry::analyze(&traces);
    for line in report.summary_lines() {
        println!("{line}");
    }
    if report.is_clean() {
        println!("straggler report: clean");
    }
    let fabric = telemetry::distill_fabric_profile(&traces);
    if !fabric.is_empty() {
        println!("fabric recalibration: {}", fabric.summary());
    }
    Ok(())
}

/// `metrics --serve ADDR [--serve-max N]` — the zero-dependency scrape
/// endpoint: serve the snapshot's Prometheus text exposition over bare
/// `std::net::TcpListener` HTTP for `max_requests` connections, then
/// return. Any request gets the one snapshot (the demo has already run;
/// there is nothing fresher to compute).
fn serve_metrics(addr: &str, snap: &MetricsSnapshot, max_requests: usize) -> Result<()> {
    use std::io::{Read as _, Write as _};
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding the metrics endpoint at {addr}"))?;
    let local = listener.local_addr()?;
    let body = snap.to_prometheus();
    eprintln!("serving Prometheus metrics on http://{local}/metrics ({max_requests} scrape(s))");
    for _ in 0..max_requests {
        let (mut stream, _) = listener.accept().context("accepting a scrape connection")?;
        // Best-effort request drain: a scraper sends one small GET; the
        // response is the same snapshot whatever the path or method.
        let mut buf = [0u8; 1024];
        let _ = stream.read(&mut buf);
        let resp = format!(
            "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        );
        stream.write_all(resp.as_bytes()).context("writing the scrape response")?;
    }
    Ok(())
}

/// `flashcomm lint [--root DIR] [--json]` — run flashlint over the crate
/// at `--root` (default: the current directory, falling back to `rust/`
/// when invoked from the repo root). Exits non-zero on findings so CI
/// can gate on it directly.
fn cmd_lint(args: &Args) -> Result<()> {
    let root = match args.flag("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => {
            let cwd = std::path::PathBuf::from(".");
            if cwd.join("src").is_dir() {
                cwd
            } else {
                std::path::PathBuf::from("rust")
            }
        }
    };
    let report = flashcomm::lint::run(&root)?;
    if args.flag_bool("json") {
        print!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    ensure!(
        report.findings.is_empty(),
        "flashlint: {} finding(s); see the listing above (or run with --json)",
        report.findings.len()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.flag_or("config", "tiny");
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(&config)?)?;
    let init = match args.flag("ckpt") {
        Some(p) => Weights::load(p)?,
        None => Weights::load(
            default_artifacts_dir().join(format!("{config}_init_weights.bin")),
        )?,
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (train, eval) = corpus.split();
    let mut sampler = Sampler::new(train, args.flag_usize("seed", 7)? as u64);
    let eval_batches = Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len);
    let codec = Codec::parse(&args.flag_or("codec", "bf16"))?;
    session_flags(args)?; // validate the liveness pair (inert in-process)
    // train drives the in-process fabric only: any other `--transport`
    // (or a wire-fault knob) is a loud error, never a silent no-op.
    cli::wire_fault_flags(args, cli::transport_flag(args, &[TransportSel::InProc])?)?;
    let algo: AlgoPolicy = args.flag_or("algo", "twostep").parse()?;
    let plan = plan_policy_for(args.flag("plan"), pins_flags(args)?, algo, &codec)?;
    let opts = TrainOptions {
        steps: args.flag_usize("steps", 200)?,
        dp: args.flag_usize("dp", 4)?,
        codec,
        algo,
        plan,
        groups: groups_flag(args)?,
        log_every: args.flag_usize("log-every", 10)?,
        eval_every: args.flag_usize("eval-every", 50)?,
        eval_batches: args.flag_usize("eval-batches", 8)?,
        seed: args.flag_usize("seed", 7)? as u64,
        trace_out: args.flag("trace-out").map(str::to_string),
        trace_capacity: cli::trace_capacity_flag(args)?,
    };
    let policy_label = match &opts.plan {
        Some(p) => format!("plan {p}"),
        None => format!("algo {algo}"),
    };
    println!(
        "training {config} ({} params) for {} steps, dp={}, grads over {} [{policy_label}]",
        cfg.n_params,
        opts.steps,
        opts.dp,
        opts.codec.name(),
    );
    let mut trainer = Trainer::new(rt, cfg, &init)?;
    let t0 = std::time::Instant::now();
    let recs = trainer.train(&mut sampler, &eval_batches, &opts)?;
    let total = t0.elapsed().as_secs_f64();
    let final_ppl = trainer.eval_ppl(&eval_batches[..eval_batches.len().min(8)])?;
    println!(
        "done: {} steps in {:.1}s ({:.2}s/step), final loss {:.4}, eval ppl {:.3}",
        recs.len(),
        total,
        total / recs.len() as f64,
        recs.last().map(|r| r.loss).unwrap_or(f32::NAN),
        final_ppl
    );
    if let Some(out) = args.flag("out") {
        trainer.export_weights()?.save(out).with_context(|| format!("saving {out}"))?;
        println!("checkpoint saved to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let config = args.flag_or("config", "tiny");
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(&config)?)?;
    let weights = match args.flag("ckpt") {
        Some(p) => Weights::load(p)?,
        None => {
            let (_, w, _) = flashcomm::coordinator::pretrain::ensure_trained(
                &config,
                flashcomm::coordinator::pretrain::ACCURACY_STEPS,
            )?;
            w
        }
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let n = args.flag_usize("batches", 6)?;
    let batches: Vec<_> =
        Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len).into_iter().take(n).collect();
    let codec = Codec::parse(&args.flag_or("codec", "bf16"))?;
    session_flags(args)?; // validate the liveness pair (inert in-process)
    // eval, like train, runs in-process only (see cmd_train).
    cli::wire_fault_flags(args, cli::transport_flag(args, &[TransportSel::InProc])?)?;
    if let Some(style) = args.flag("style") {
        bail!("--style was replaced by --algo (try `--algo {style}`, or `--algo auto`)");
    }
    let policy: AlgoPolicy = args.flag_or("algo", "twostep").parse()?;
    let plan = plan_policy_for(args.flag("plan"), pins_flags(args)?, policy, &codec)?;
    let mut engine =
        TpEngine::new_grouped(rt, cfg, &weights, codec, policy, groups_flag(args)?, plan)?;
    let trace_out = args.flag("trace-out").map(str::to_string);
    if trace_out.is_some() {
        engine.enable_recording(cli::trace_capacity_flag(args)?);
    }
    let policy_label = match &plan {
        Some(p) => format!("--plan {p}"),
        None => format!("--algo {policy}"),
    };
    let t0 = std::time::Instant::now();
    let ppl = engine.perplexity(&batches)?;
    println!(
        "{config} perplexity under {} ({policy_label}): {:.4}   [{} batches, {:.2}s]",
        codec.name(),
        ppl,
        batches.len(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(path) = &trace_out {
        match engine.recalibrate_from_recorders() {
            Some(p) => println!("recalibration: {}", p.summary()),
            None => println!("recalibration: no measurable spans"),
        }
        write_traces(path, &engine.trace_jsons())?;
    }
    Ok(())
}

/// Write one flight-recorder trace JSON per rank to `{path}.rank{r}`
/// (status lines go to stderr so `metrics` output stays pipeable).
fn write_traces(path: &str, traces: &[String]) -> Result<()> {
    ensure!(!traces.is_empty(), "--trace-out: no rank recorded a trace");
    for (r, json) in traces.iter().enumerate() {
        let file = format!("{path}.rank{r}");
        std::fs::write(&file, json).with_context(|| format!("writing trace {file}"))?;
    }
    eprintln!("wrote {} flight-recorder traces to {path}.rank*", traces.len());
    Ok(())
}

/// `worker` — the multi-process socket fabric demo (`--transport tcp|udp`).
///
/// Without `--rank` this is the *launcher*: it reserves a rendezvous port,
/// spawns one OS process per rank (re-invoking this binary with `--rank R`),
/// and fails if any rank fails. With `--rank` it is one rank: it bootstraps
/// the selected mesh (both backends rendezvous over TCP), runs the quantized
/// AllReduce for each requested codec, and verifies the result is
/// bit-identical to the in-process backend on the same inputs — on UDP,
/// optionally through a seeded chaos wire (`--wire-fault-pct`).
fn cmd_worker(args: &Args) -> Result<()> {
    let opts = WorkerOpts::parse(args)?;
    match args.flag("rank") {
        Some(r) => {
            let rank: usize = r.parse().with_context(|| format!("--rank {r}"))?;
            let root = args.require("root")?;
            match opts.rejoin_rank {
                Some(rejoining) => worker_rank_rejoin(rank, &opts, root, rejoining),
                None => worker_rank(rank, &opts, root),
            }
        }
        None => worker_launch(&opts, args),
    }
}

/// Everything a worker job is parameterized by (identical in the launcher
/// and every spawned rank).
struct WorkerOpts {
    world: usize,
    len: usize,
    algo: String,
    groups: Option<usize>,
    inter_gbps: Option<f64>,
    codecs: String,
    codec_threads: usize,
    /// Data-plane backend (`--transport tcp|udp`; default tcp — the
    /// in-process backend has no sockets, so the multi-process demo
    /// rejects it at parse).
    transport: TransportSel,
    /// Seeded wire-fault program for the UDP data plane
    /// (`--wire-fault-pct P [--wire-fault-seed S]`, UDP-only — see
    /// [`cli::wire_fault_flags`]). Each rank salts the seed with its own
    /// id so the per-endpoint chaos programs are independent.
    wire_fault: Option<cli::WireFaultSpec>,
    /// Data-listener bind address (`--bind`; loopback by default — set a
    /// routable interface IP to let the data plane leave the host).
    bind: std::net::IpAddr,
    /// Raw `--plan` value (`auto` or a spec, resolved per base codec).
    plan: Option<String>,
    pins: PlanPins,
    /// When set, every rank flight-records its collectives and writes the
    /// trace JSON to `{trace_out}.rank{R}` before exiting; the launcher
    /// then merges them into `{trace_out}.merged.json`.
    trace_out: Option<String>,
    /// Recorder ring size per rank (`--trace-capacity`; 0 rejected).
    trace_capacity: usize,
    /// Session-fabric pair (`--heartbeat-ms` / `--comm-timeout-ms`; both 0
    /// disables liveness, which is rejected once `--bind` leaves loopback
    /// — a multi-host run with no deadline hangs forever when a host dies).
    heartbeat_ms: u64,
    comm_timeout_ms: u64,
    /// AllReduce repetitions per codec (`--iters`; keeps the fabric busy
    /// long enough for the `--kill-rank` drill to land mid-collective).
    iters: usize,
    /// `--rejoin-rank R`: run the epoch-rejoin drill instead of the plain
    /// bit-identity demo (see [`worker_rank_rejoin`]).
    rejoin_rank: Option<usize>,
}

impl WorkerOpts {
    fn parse(args: &Args) -> Result<WorkerOpts> {
        let world = args.flag_usize("world", 4)?;
        ensure!(world >= 2, "worker demo needs at least 2 ranks (got --world {world})");
        let transport = cli::transport_flag(args, &[TransportSel::Tcp, TransportSel::Udp])?;
        let opts = WorkerOpts {
            world,
            transport,
            wire_fault: cli::wire_fault_flags(args, transport)?,
            len: args.flag_usize("len", 4096)?,
            algo: args.flag_or("algo", "hier"),
            groups: groups_flag(args)?,
            inter_gbps: inter_gbps_flag(args)?,
            codecs: args.flag_or("codecs", "int4@32,int2-sr@32"),
            // Codec worker threads per rank: each rank owns its process
            // here, so large payloads may fan the fused quantize/pack
            // kernels out (the in-process reference always runs 1 to
            // avoid oversubscription).
            codec_threads: args.flag_usize("codec-threads", 1)?,
            bind: match args.flag("bind") {
                None => tcp::DEFAULT_BIND,
                Some(v) => v.parse().with_context(|| format!("--bind {v}"))?,
            },
            plan: args.flag("plan").map(str::to_string),
            pins: pins_flags(args)?,
            trace_out: args.flag("trace-out").map(str::to_string),
            trace_capacity: cli::trace_capacity_flag(args)?,
            heartbeat_ms: args.flag_usize("heartbeat-ms", 250)? as u64,
            comm_timeout_ms: args.flag_usize("comm-timeout-ms", 1000)? as u64,
            iters: args.flag_usize("iters", 1)?,
            rejoin_rank: match args.flag("rejoin-rank") {
                None => None,
                Some(v) => Some(v.parse().with_context(|| format!("--rejoin-rank {v}"))?),
            },
        };
        ensure!(opts.iters >= 1, "--iters must be at least 1");
        let session = opts.session()?; // validates the heartbeat/timeout pair
        ensure!(
            session.enabled() || opts.bind.is_loopback(),
            "--heartbeat-ms 0 / --comm-timeout-ms 0 disables peer-loss detection, which is \
             only sane on loopback: a multi-host run (--bind {}) would hang forever when a \
             host dies",
            opts.bind
        );
        if let Some(r) = opts.rejoin_rank {
            ensure!(r < opts.world, "--rejoin-rank {r} out of range for --world {}", opts.world);
            ensure!(
                session.enabled(),
                "--rejoin-rank needs the session fabric (non-zero --heartbeat-ms and \
                 --comm-timeout-ms): without deadlines the survivors never see the loss"
            );
            ensure!(
                opts.transport == TransportSel::Tcp,
                "--rejoin-rank is a TCP-only drill: the UDP backend has no epoch-rejoin \
                 path yet (the --kill-rank drill does run over UDP)"
            );
        }
        // Validate once here rather than erroring in every spawned
        // process: the topology must construct (world divisible into
        // --groups, --inter-gbps sane), a fixed algorithm must be
        // admissible on it (`Algo::admissible`), and the plan policy —
        // including a fixed plan's own algorithm — must resolve and be
        // admissible against every requested codec.
        let policy: AlgoPolicy = opts.algo.parse()?;
        let topo = opts.topology(policy)?;
        for spec in opts.codec_list() {
            let base = Codec::parse(spec)?;
            if let Some(PlanPolicy::Fixed(plan)) =
                plan_policy_for(opts.plan.as_deref(), opts.pins, policy, &base)?
            {
                plan.validate(&topo)
                    .with_context(|| format!("--plan for codec {spec} on this topology"))?;
            }
        }
        Ok(opts)
    }

    fn codec_list(&self) -> impl Iterator<Item = &str> {
        self.codecs.split(',').map(str::trim).filter(|s| !s.is_empty())
    }

    /// The session config the flag pair denotes (validated at parse time,
    /// so later calls cannot fail in practice).
    fn session(&self) -> Result<SessionConfig> {
        Ok(SessionConfig::from_millis(self.heartbeat_ms, self.comm_timeout_ms)?)
    }

    fn topology(&self, policy: AlgoPolicy) -> Result<flashcomm::topo::Topology> {
        Ok(preset_topo_custom(self.world, self.groups, self.inter_gbps, policy)?)
    }
}

fn worker_launch(opts: &WorkerOpts, args: &Args) -> Result<()> {
    // `--kill-rank R [--kill-after-ms M]`: launcher-only failure drill.
    // SIGKILL rank R after M ms and require every survivor to exit
    // non-zero with a typed PeerLost within twice the session deadline —
    // the liveness guarantee of DESIGN.md §12, enforced over real
    // processes and real sockets.
    let kill = match args.flag("kill-rank") {
        None => None,
        Some(v) => {
            let victim: usize = v.parse().with_context(|| format!("--kill-rank {v}"))?;
            ensure!(
                victim < opts.world,
                "--kill-rank {victim} out of range for --world {}",
                opts.world
            );
            ensure!(
                opts.rejoin_rank.is_none(),
                "--kill-rank and --rejoin-rank are mutually exclusive drills"
            );
            ensure!(
                opts.session()?.enabled(),
                "--kill-rank needs the session fabric (non-zero --heartbeat-ms and \
                 --comm-timeout-ms): without deadlines the survivors would hang, not fail"
            );
            let after = Duration::from_millis(args.flag_usize("kill-after-ms", 500)? as u64);
            Some((victim, after))
        }
    };
    let root = match args.flag("root") {
        Some(r) => r.to_string(),
        None => {
            // Reserve an ephemeral rendezvous port; rank 0 rebinds it after
            // the probe is dropped.
            let probe = std::net::TcpListener::bind(("127.0.0.1", 0))
                .context("probing for a free rendezvous port")?;
            let addr = probe.local_addr()?.to_string();
            drop(probe);
            addr
        }
    };
    let exe = std::env::current_exe().context("resolving the worker binary path")?;
    let grouping = match opts.groups {
        Some(g) => format!(", {g} groups"),
        None => String::new(),
    };
    let policy_label = match &opts.plan {
        Some(p) => format!("plan {p}"),
        None => format!("algo {}", opts.algo),
    };
    let chaos = match opts.wire_fault {
        Some(f) => format!(", wire chaos {:.1}% (seed {})", f.rate * 100.0, f.seed),
        None => String::new(),
    };
    println!(
        "spawning {} worker processes over {}: rendezvous {root}, {policy_label}{grouping}, \
         codecs {}, {} elems/rank{chaos}",
        opts.world, opts.transport, opts.codecs, opts.len
    );
    let mut children = Vec::with_capacity(opts.world);
    for rank in 0..opts.world {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .args(["--rank", &rank.to_string()])
            .args(["--world", &opts.world.to_string()])
            .args(["--root", &root])
            .args(["--len", &opts.len.to_string()])
            .args(["--algo", &opts.algo])
            .args(["--codecs", &opts.codecs])
            .args(["--codec-threads", &opts.codec_threads.to_string()])
            .args(["--transport", opts.transport.name()])
            .args(["--bind", &opts.bind.to_string()])
            .args(["--heartbeat-ms", &opts.heartbeat_ms.to_string()])
            .args(["--comm-timeout-ms", &opts.comm_timeout_ms.to_string()])
            .args(["--iters", &opts.iters.to_string()]);
        if let Some(r) = opts.rejoin_rank {
            cmd.args(["--rejoin-rank", &r.to_string()]);
        }
        if let Some(f) = opts.wire_fault {
            // Every rank receives the same flag string, so the fault
            // programs stay deterministic across the job even if the
            // pct <-> rate scaling is not bit-exact.
            cmd.args(["--wire-fault-pct", &format!("{}", f.rate * 100.0)])
                .args(["--wire-fault-seed", &f.seed.to_string()]);
        }
        if let Some(g) = opts.groups {
            cmd.args(["--groups", &g.to_string()]);
        }
        if let Some(gbps) = opts.inter_gbps {
            cmd.args(["--inter-gbps", &gbps.to_string()]);
        }
        if let Some(p) = &opts.plan {
            cmd.args(["--plan", p]);
        }
        if let Some(t) = &opts.trace_out {
            cmd.args(["--trace-out", t]);
            cmd.args(["--trace-capacity", &opts.trace_capacity.to_string()]);
        }
        if let Some(c) = opts.pins.chunks {
            cmd.args(["--chunks", &c.to_string()]);
        }
        if let Some(w) = opts.pins.window {
            cmd.args(["--window", &w.to_string()]);
        }
        if kill.is_some() {
            // Survivor stderr is asserted on below ("PeerLost" must appear).
            cmd.stderr(std::process::Stdio::piped());
        }
        let child = cmd.spawn().with_context(|| format!("spawning worker rank {rank}"))?;
        children.push((rank, child));
    }
    if let Some((victim, after)) = kill {
        let deadline = Duration::from_millis(opts.comm_timeout_ms);
        return reap_kill_smoke(children, victim, after, deadline);
    }
    let mut failed = false;
    for (rank, mut child) in children {
        let status = child.wait().with_context(|| format!("waiting for rank {rank}"))?;
        if !status.success() {
            eprintln!("worker rank {rank} failed: {status}");
            failed = true;
        }
    }
    ensure!(!failed, "one or more worker ranks failed");
    if let (Some(path), None) = (&opts.trace_out, opts.rejoin_rank) {
        merge_worker_traces(path, opts.world)?;
    }
    match opts.rejoin_rank {
        Some(r) => println!(
            "all {} ranks rejoined at epoch 1 after rank {r} restarted; the post-rejoin \
             AllReduce matches the InProc backend bit-for-bit",
            opts.world
        ),
        None => println!(
            "all {} worker processes agree with the InProc backend bit-for-bit",
            opts.world
        ),
    }
    Ok(())
}

/// The `--kill-rank` drill's reaper half: SIGKILL `victim` after `after`,
/// then require every survivor to exit non-zero with a typed `PeerLost` on
/// stderr within `2 * comm_timeout` of the kill. A survivor still running
/// past that budget means the liveness deadline did not fire — the drill
/// kills the stragglers (no leaked processes) and fails loudly.
fn reap_kill_smoke(
    mut children: Vec<(usize, std::process::Child)>,
    victim: usize,
    after: Duration,
    comm_timeout: Duration,
) -> Result<()> {
    std::thread::sleep(after);
    children[victim].1.kill().with_context(|| format!("SIGKILLing rank {victim}"))?;
    let budget = 2 * comm_timeout;
    println!(
        "launcher: killed rank {victim} after {after:?}; every survivor must exit with a \
         typed PeerLost within {budget:?}"
    );
    // Drain each child's piped stderr on its own thread: a full pipe would
    // deadlock the child against the wait loop below.
    let mut drains = Vec::with_capacity(children.len());
    for (rank, child) in &mut children {
        let mut pipe = child.stderr.take().expect("stderr is piped in kill mode");
        drains.push((
            *rank,
            std::thread::spawn(move || {
                use std::io::Read as _;
                let mut s = String::new();
                let _ = pipe.read_to_string(&mut s);
                s
            }),
        ));
    }
    let deadline = Instant::now() + budget;
    let mut statuses: Vec<Option<std::process::ExitStatus>> = vec![None; children.len()];
    loop {
        for (rank, child) in &mut children {
            if statuses[*rank].is_none() {
                statuses[*rank] = child.try_wait().with_context(|| format!("polling rank {rank}"))?;
            }
        }
        if statuses.iter().all(Option::is_some) {
            break;
        }
        if Instant::now() >= deadline {
            for (rank, child) in &mut children {
                if statuses[*rank].is_none() {
                    eprintln!("rank {rank} is still running past the PeerLost deadline");
                    let _ = child.kill();
                }
            }
            bail!(
                "kill drill failed: survivors still running {budget:?} after rank {victim} \
                 was killed (the session deadline did not fire)"
            );
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    for (rank, drain) in drains {
        let stderr = drain.join().unwrap_or_default();
        if rank == victim {
            continue;
        }
        let status = statuses[rank].expect("every status was collected above");
        ensure!(
            !status.success(),
            "survivor rank {rank} exited cleanly — it should have failed with PeerLost \
             (was the run long enough to still be in flight at kill time? raise --iters)"
        );
        ensure!(
            stderr.contains("PeerLost"),
            "survivor rank {rank} failed without a typed PeerLost:\n{stderr}"
        );
        // Surface the survivors' typed failure lines in the drill log.
        eprint!("{stderr}");
    }
    println!(
        "kill drill passed: all {} survivors exited with a typed PeerLost within {budget:?}",
        children.len() - 1
    );
    Ok(())
}

fn worker_rank(rank: usize, opts: &WorkerOpts, root: &str) -> Result<()> {
    let policy: AlgoPolicy = opts.algo.parse()?;
    let topo = opts.topology(policy)?;
    // Session-aware bootstrap: a dead or silent root fails within the
    // rendezvous timeout as a typed CommError::Rendezvous, and (unless the
    // pair was zeroed out) the mesh runs under heartbeats + receive
    // deadlines, so a peer death surfaces as PeerLost instead of a hang.
    // Both backends share the TCP rendezvous control plane; only the data
    // plane differs (framed streams vs NACK-recovered datagrams).
    match opts.transport {
        TransportSel::Udp => {
            // Per-rank seed salt: each endpoint draws an independent
            // deterministic fault program (the `udp::local_mesh_faulty`
            // convention).
            let fault = opts
                .wire_fault
                .map(|f| WireFault::chaos(f.seed.wrapping_add(rank as u64), f.rate));
            let udp = session::establish_udp(
                rank,
                opts.world,
                root,
                None,
                opts.bind,
                &opts.session()?,
                fault,
            )
            .with_context(|| format!("rank {rank} joining the UDP session at {root}"))?;
            worker_rank_run(udp, rank, opts, policy, topo, "UDP")
        }
        TransportSel::Tcp => {
            let tcp = session::establish(rank, opts.world, root, None, opts.bind, &opts.session()?)
                .with_context(|| format!("rank {rank} joining the TCP session at {root}"))?;
            worker_rank_run(tcp, rank, opts, policy, topo, "TCP")
        }
        TransportSel::InProc => unreachable!("WorkerOpts::parse rejects --transport inproc"),
    }
}

/// One rank's demo body, generic over the connected data plane: run the
/// quantized AllReduce for every requested codec, verify each result is
/// bit-identical to the in-process reference, allgather the resolved-plan
/// fingerprint, and dump transport/session stats plus optional traces.
fn worker_rank_run<T: Transport>(
    transport: T,
    rank: usize,
    opts: &WorkerOpts,
    policy: AlgoPolicy,
    topo: flashcomm::topo::Topology,
    backend: &str,
) -> Result<()> {
    let world = opts.world;
    let len = opts.len;
    // One origin for the recorder clock *and* the sync probes, so the
    // offsets installed below translate this rank's timestamps straight
    // onto rank 0's timeline at merge time (DESIGN.md §15).
    let origin = Instant::now();
    let now = move || origin.elapsed().as_nanos() as u64;
    let recording = opts.trace_out.is_some();
    // Piggyback the clock sync on session establish, on the raw data
    // plane: probes record no telemetry events, so the closed-form
    // per-rank event counts stay exact.
    let clock = if recording {
        Some(session::sync_clocks(&transport, 0, 8, &now).context("clock sync at establish")?)
    } else {
        None
    };
    let mut comm =
        Communicator::new(transport, topo.clone(), Arc::new(fabric::ByteCounters::default()))?;
    comm.set_codec_threads(opts.codec_threads);
    if recording {
        comm.enable_recording_from(opts.trace_capacity, origin);
        if let (Some(rec), Some(c)) = (comm.recorder(), &clock) {
            rec.set_clock(c.offset_nanos, c.rtt_nanos, c.probes);
        }
    }

    // Deterministic heavy-tailed inputs, identical in every process (and in
    // the in-process reference below).
    let inputs: Vec<Vec<f32>> = (0..world)
        .map(|r| {
            let mut rng = Prng::new(1000 + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect();

    for iter in 0..opts.iters {
        for spec in opts.codec_list() {
            let codec = Codec::parse(spec)?;
            let plan_policy = plan_policy_for(opts.plan.as_deref(), opts.pins, policy, &codec)?;

            // The real thing: this process is one rank of the socket mesh.
            let mut mine = inputs[rank].clone();
            let (used_label, used_algo, used_plan) = match &plan_policy {
                Some(pp) => {
                    let plan = comm.allreduce_planned(&mut mine, &codec, pp)?;
                    (plan.to_string(), plan.algo, Some(plan))
                }
                None => {
                    let algo = comm.allreduce(&mut mine, &codec, policy)?;
                    (algo.to_string(), algo, None)
                }
            };

            // Reference: the same collective over the in-process backend.
            // The policy (algorithm or full plan) resolves per (topology,
            // codec, size) deterministically, so both backends pick the
            // same schedule without coordination.
            let inputs_ref = &inputs;
            let pp_ref = &plan_policy;
            let (reference, _) = fabric::run_ranks(&topo, |rh| {
                let mut c = Communicator::from_handle(rh);
                let mut d = inputs_ref[c.rank()].clone();
                match pp_ref {
                    Some(pp) => {
                        let ref_plan = c
                            .allreduce_planned(&mut d, &codec, pp)
                            .expect("in-process reference failed");
                        assert_eq!(Some(ref_plan), used_plan, "backends resolved different plans");
                    }
                    None => {
                        let ref_used = c
                            .allreduce(&mut d, &codec, policy)
                            .expect("in-process reference failed");
                        assert_eq!(ref_used, used_algo, "backends resolved different algorithms");
                    }
                }
                d
            });
            let expect = &reference[rank];
            ensure!(mine.len() == expect.len(), "{spec}: length mismatch");
            for (i, (a, b)) in mine.iter().zip(expect).enumerate() {
                ensure!(
                    a.to_bits() == b.to_bits(),
                    "[rank {rank}] {spec}: {backend} diverges from InProc at element {i}: \
                     {a} vs {b}"
                );
            }
            if iter == 0 {
                println!(
                    "[rank {rank}] {spec} [{used_label}] AllReduce over {backend} == InProc \
                     bit-for-bit ({len} elems)"
                );
            }
        }
        // Refresh the clock estimate between iterations: every rank has
        // fully drained its collectives at this point (program order +
        // per-link FIFO keep the probe frames from interleaving with
        // data), drift shrinks, and a fresher minimum-RTT sample only
        // tightens the NTP bound.
        if recording {
            let c = session::sync_clocks(comm.transport(), 0, 8, &now)
                .with_context(|| format!("clock refresh after iteration {iter}"))?;
            if let Some(rec) = comm.recorder() {
                rec.set_clock(c.offset_nanos, c.rtt_nanos, c.probes);
            }
        }
    }
    if opts.iters > 1 {
        println!(
            "[rank {rank}] {} AllReduce iterations per codec, all bit-identical to InProc",
            opts.iters
        );
    }

    // Every rank must have resolved the *same* plan for the last
    // collective (the compiler is deterministic, so this holds without
    // coordination): allgather the 8-byte plan fingerprint over the mesh
    // and require unanimity.
    {
        let fp = comm.last_plan().map(|(_, f)| *f).unwrap_or(0);
        let h = comm.handle();
        for peer in (0..world).filter(|&p| p != rank) {
            h.send(peer, fp.to_le_bytes().to_vec())?;
        }
        for peer in (0..world).filter(|&p| p != rank) {
            let bytes = h.recv(peer)?;
            ensure!(bytes.len() == 8, "fingerprint allgather: bad frame from rank {peer}");
            let theirs = u64::from_le_bytes(bytes.try_into().expect("length checked"));
            ensure!(
                theirs == fp,
                "[rank {rank}] resolved-plan fingerprint diverges from rank {peer}: \
                 {fp:#018x} vs {theirs:#018x}"
            );
        }
        println!("[rank {rank}] resolved-plan fingerprint {fp:#018x} matches all {world} ranks");
    }

    match comm.recalibrate_from_recorder() {
        Some(p) => println!("[rank {rank}] recalibration: {}", p.summary()),
        None => println!("[rank {rank}] recalibration: no measurable spans"),
    }
    if let Some(path) = &opts.trace_out {
        let json = comm.trace_json().expect("recording was enabled");
        let file = format!("{path}.rank{rank}");
        std::fs::write(&file, &json).with_context(|| format!("writing trace {file}"))?;
        println!("[rank {rank}] wrote trace {file}");
    }

    let stats = comm.transport().stats();
    println!(
        "[rank {rank}] sent {} messages, {} payload B, {} wire B ({} B framing)",
        stats.messages,
        stats.payload_bytes,
        stats.wire_bytes,
        stats.wire_bytes - stats.payload_bytes
    );
    // The UDP robustness block, printed whenever recovery machinery fired
    // (always zero on TCP, and on UDP over a clean loopback wire the only
    // nonzero term is the forward-redundancy tail).
    let recovered = stats.nacks_sent + stats.retransmitted_chunks + stats.duplicate_drops;
    if recovered + stats.corrupt_drops + stats.redundancy_bytes > 0 {
        println!(
            "[rank {rank}] recovery: {} NACKs sent / {} received, {} chunks retransmitted, \
             {} dup + {} corrupt + {} stale drops, {} redundancy B, {} paced stalls",
            stats.nacks_sent,
            stats.nacks_received,
            stats.retransmitted_chunks,
            stats.duplicate_drops,
            stats.corrupt_drops,
            stats.stale_epoch_drops,
            stats.redundancy_bytes,
            stats.paced_stalls
        );
    }
    if let Some(s) = comm.transport().session_stats() {
        println!(
            "[rank {rank}] session epoch {}: {} heartbeats sent, {} received, {} suspects, \
             {} losses",
            s.epoch, s.heartbeats_sent, s.heartbeats_received, s.suspects, s.losses
        );
    }

    if rank == 0 {
        // Demonstrate the frame guard: a corrupted payload must be rejected
        // with a CRC error, never decoded.
        let payload = Codec::parse("int4@32")?.encode(&inputs[0]);
        let mut framed = frame::encode(0, 1, 0, 0, &payload);
        let last = framed.len() - 1;
        framed[last] ^= 0x01;
        match frame::decode(framed) {
            Err(e) => println!("[rank 0] corrupted frame correctly rejected: {e}"),
            Ok(_) => bail!("corrupted frame was not rejected"),
        }
    }
    Ok(())
}

/// `worker --rejoin-rank R` — the epoch-rejoin drill, one process per rank
/// (state machine and epoch layout: DESIGN.md §12):
///
/// 1. everyone establishes the session at epoch 0 and one AllReduce
///    completes bit-identically to the InProc backend;
/// 2. rank `R` "dies" — it drops its endpoint, so the survivors see its
///    sockets close and their next collective surfaces a typed
///    [`CommError::PeerLost`] instead of hanging;
/// 3. everyone — including the restarted `R` — re-rendezvouses through
///    [`session::rejoin`], which bumps the epoch to 1 so any straggler
///    frame from the epoch-0 incarnation is rejected before it can poison
///    the new per-link sequence spaces;
/// 4. a post-rejoin AllReduce over fresh inputs must again be
///    bit-identical to InProc, and the session counters must show exactly
///    one epoch bump.
fn worker_rank_rejoin(rank: usize, opts: &WorkerOpts, root: &str, rejoining: usize) -> Result<()> {
    let policy: AlgoPolicy = opts.algo.parse()?;
    let topo = opts.topology(policy)?;
    let world = opts.world;
    let len = opts.len;
    let config = opts.session()?;
    let spec = opts.codec_list().next().context("--codecs must name at least one codec")?;
    let codec = Codec::parse(spec)?;

    // Deterministic inputs, salted per phase so epoch-1 traffic is
    // distinguishable from anything epoch 0 ever carried.
    let inputs = |salt: u64| -> Vec<Vec<f32>> {
        (0..world)
            .map(|r| {
                let mut rng = Prng::new(salt + r as u64);
                let mut v = vec![0f32; len];
                rng.fill_activations(&mut v, 1.0);
                v
            })
            .collect()
    };
    let reference = |data: &[Vec<f32>]| -> Vec<f32> {
        let (all, _) = fabric::run_ranks(&topo, |rh| {
            let mut c = Communicator::from_handle(rh);
            let mut d = data[c.rank()].clone();
            c.allreduce(&mut d, &codec, policy).expect("in-process reference failed");
            d
        });
        all[rank].clone()
    };
    let check = |mine: &[f32], expect: &[f32], label: &str| -> Result<()> {
        ensure!(mine.len() == expect.len(), "{label}: length mismatch");
        for (i, (a, b)) in mine.iter().zip(expect).enumerate() {
            ensure!(
                a.to_bits() == b.to_bits(),
                "[rank {rank}] {label}: TCP diverges from InProc at element {i}: {a} vs {b}"
            );
        }
        Ok(())
    };

    // Phase 1 — epoch 0: healthy mesh, one bit-identical collective.
    let t0 = session::establish(rank, world, root, None, opts.bind, &config)
        .with_context(|| format!("rank {rank} joining the epoch-0 session at {root}"))?;
    ensure!(t0.epoch() == 0, "a fresh session must start at epoch 0 (got {})", t0.epoch());
    let mut comm = Communicator::new(t0, topo.clone(), Arc::new(fabric::ByteCounters::default()))?;
    let in0 = inputs(1000);
    let mut mine = in0[rank].clone();
    comm.allreduce(&mut mine, &codec, policy)?;
    check(&mine, &reference(&in0), "epoch 0")?;
    println!("[rank {rank}] epoch 0: {spec} AllReduce == InProc bit-for-bit");

    // Phase 2 — the loss. The rejoining rank drops its endpoint (its
    // sockets close, which is exactly what a crash looks like to the
    // survivors); every survivor's next collective must fail typed.
    if rank == rejoining {
        drop(comm);
        println!("[rank {rank}] simulating a restart: epoch-0 endpoint dropped");
    } else {
        let mut doomed = in0[rank].clone();
        let err = match comm.allreduce(&mut doomed, &codec, policy) {
            Err(e) => e,
            Ok(_) => bail!(
                "[rank {rank}] the collective after rank {rejoining} died must fail, \
                 but it completed"
            ),
        };
        ensure!(
            matches!(err, CommError::PeerLost { .. }),
            "[rank {rank}] expected a typed PeerLost after rank {rejoining} dropped, \
             got: {err}"
        );
        println!("[rank {rank}] survivor saw the typed loss: {err}");
        drop(comm);
    }

    // Phase 3 — rejoin under epoch 1. Rank 0 rebinds the rendezvous
    // address (the epoch-0 listener closed after bootstrap) and everyone
    // else retries connects within the rendezvous timeout, so the ranks
    // may arrive here in any order.
    let t1 = session::rejoin(rank, world, root, None, opts.bind, &config)
        .with_context(|| format!("rank {rank} rejoining the session at {root}"))?;
    ensure!(t1.epoch() == 1, "rejoin must bump the epoch to 1 (got {})", t1.epoch());
    if rank != rejoining {
        if let Some(s) = t1.session_shared() {
            s.mark_rejoined(rejoining);
        }
    }

    // Phase 4 — epoch 1: fresh inputs, bit-identical again, counters sane.
    let mut comm = Communicator::new(t1, topo.clone(), Arc::new(fabric::ByteCounters::default()))?;
    let in1 = inputs(2000);
    let mut mine = in1[rank].clone();
    comm.allreduce(&mut mine, &codec, policy)?;
    check(&mine, &reference(&in1), "epoch 1 (post-rejoin)")?;
    let stats = comm.transport().session_stats().context("the session fabric is enabled")?;
    ensure!(
        stats.epoch == 1 && stats.epoch_bumps == 1,
        "[rank {rank}] rejoin accounting is off: {stats:?}"
    );
    println!(
        "[rank {rank}] epoch 1: rejoined and {spec} AllReduce == InProc bit-for-bit \
         ({len} elems)"
    );
    Ok(())
}

/// `metrics` — run a small flight-recorded in-process AllReduce demo and
/// print the aggregated metrics snapshot as JSON on stdout (schema:
/// DESIGN.md §11). Human-oriented status lines go to stderr so the JSON
/// stays pipeable. Defaults to `--plan auto`, so the snapshot also
/// exercises the plan cache (first iteration misses, the rest hit) and
/// reports the last resolved plan.
fn cmd_metrics(args: &Args) -> Result<()> {
    let ranks = args.flag_usize("ranks", 8)?;
    ensure!(ranks >= 2, "metrics demo needs at least 2 ranks (got --ranks {ranks})");
    let len = args.flag_usize("len", 1 << 16)?;
    let iters = args.flag_usize("iters", 4)?;
    ensure!(iters >= 1, "metrics demo needs at least 1 iteration (got --iters {iters})");
    let codec = Codec::parse(&args.flag_or("codec", "int4@32"))?;
    let policy: AlgoPolicy = args.flag_or("algo", "auto").parse()?;
    let plan_spec = args.flag_or("plan", "auto");
    let plan = plan_policy_for(Some(plan_spec.as_str()), pins_flags(args)?, policy, &codec)?
        .expect("an explicit --plan always resolves to a policy");
    let mut group = LocalGroup::for_plan_grouped(ranks, groups_flag(args)?, plan)?;
    group.enable_recording(cli::trace_capacity_flag(args)?);
    let mut data: Vec<Vec<f32>> = (0..ranks)
        .map(|r| {
            let mut rng = Prng::new(4000 + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect();
    for _ in 0..iters {
        group.allreduce(&mut data, &codec)?;
    }
    match group.recalibrate_from_recorders() {
        Some(p) => eprintln!("recalibration: {}", p.summary()),
        None => eprintln!("recalibration: no measurable spans"),
    }
    if let Some(path) = args.flag("trace-out") {
        write_traces(path, &group.trace_jsons())?;
    }
    let snap = group.metrics_snapshot();
    let json = snap.to_json();
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, &json).with_context(|| format!("writing {path}"))?;
            eprintln!("metrics snapshot written to {path}");
        }
        None => println!("{json}"),
    }
    if let Some(addr) = args.flag("serve") {
        let max = args.flag_usize("serve-max", 1)?;
        ensure!(max >= 1, "--serve-max must be at least 1 (got {max})");
        serve_metrics(addr, &snap, max)?;
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::open(default_artifacts_dir())?;
    println!("artifacts: {:?}", rt.dir());
    println!("configs:");
    for c in &rt.manifest.configs {
        println!(
            "  {} — {} params, vocab {}, tp {}",
            c.name,
            c.get("n_params").unwrap_or("?"),
            c.get("vocab").unwrap_or("?"),
            c.get("tp").unwrap_or("?")
        );
    }
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for a in &rt.manifest.artifacts {
        println!("  {}", a.name);
    }
    println!("device presets (Table 6):");
    for s in flashcomm::topo::presets::all() {
        println!(
            "  {:>5}: {} SMs, {} GB/s nominal, {} TFLOPs bf16 (CUDA), comm SMs {}",
            s.name, s.sms, s.nominal_bw_gbps, s.bf16_tflops, s.comm_sms
        );
    }
    Ok(())
}
