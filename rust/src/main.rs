//! `flashcomm` — the FlashCommunication V2 coordinator CLI.
//!
//! ```text
//! flashcomm table <1..10|all> [--quick] [--steps N] [--batches N] [--size 64M]
//! flashcomm figure <1|2|4|5|8|all> [--quick] [--codec spec] [--chunks K]
//! flashcomm train   [--config tiny] [--steps N] [--dp N] [--codec spec]
//!                   [--algo ring|twostep|hier|hierpp|auto] [--groups G]
//!                   [--plan auto|spec] [--chunks K] [--window W]
//!                   [--out ckpt.bin] [--trace-out path]
//! flashcomm eval    [--config tiny] [--ckpt path] [--codec spec]
//!                   [--algo twostep|hier|auto] [--groups G] [--batches N]
//!                   [--plan auto|spec] [--chunks K] [--window W]
//!                   [--trace-out path]
//! flashcomm ttft    [--prompt N] [--batch N]
//! flashcomm worker  [--world N] [--algo hier|auto] [--groups G]
//!                   [--codecs int4@32,int2-sr@32] [--len N]
//!                   [--root host:port] [--rank R] [--codec-threads T]
//!                   [--plan auto|spec] [--chunks K] [--window W]
//!                   [--bind ip] [--inter-gbps F] [--trace-out path]
//! flashcomm metrics [--ranks N] [--groups G] [--codec spec] [--len N]
//!                   [--iters K] [--plan auto|spec] [--out path]
//!                   [--trace-out path]
//! flashcomm info
//! ```
//!
//! Codec spec grammar: `bf16 | int<bits>[-rtn|-sr|-had|-log][@<gs>][!]`
//! (`!` = integer Eq.1 metadata), e.g. `int5`, `int2-sr@32`, `int2-sr@32!`.
//! `--algo auto` lets the cost model pick the algorithm per payload size.
//! `--groups G` shapes the rank-group topology: 1 = flat NVLink node,
//! `G >= 2` = G equal link-tier groups joined by NUMA bridges (the
//! generalized hierarchical family runs at any admissible G).
//! `--plan auto` compiles a full communication plan per payload —
//! algorithm, per-stage codecs (a tier-asymmetric cluster gets a more
//! aggressive cross-group codec), micro-chunk count — while
//! `--plan <algo>[:intra=c][:cross=c][:ag=c][:chunks=K][:window=W][:threads=T]`
//! pins one. `--chunks`/`--window` pin those knobs in either mode.
//! `--inter-gbps F` models G NVLink nodes joined by an F GB/s link;
//! `--bind ip` lets worker data sockets leave loopback (DESIGN.md §4).
//! `--trace-out p` turns on the flight recorder and writes one JSON trace
//! per rank to `p.rankR` (schema: DESIGN.md §11); `metrics` runs a small
//! recorded in-process demo and prints the aggregated metrics snapshot.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use flashcomm::cli::Args;
use flashcomm::comm::{fabric, preset_topo_custom, AlgoPolicy, Communicator, LocalGroup};
use flashcomm::coordinator::{TpEngine, TrainOptions, Trainer};
use flashcomm::harness;
use flashcomm::model::{Corpus, ModelConfig, Sampler, Weights};
use flashcomm::plan::{CommPlan, PlanPins, PlanPolicy};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, Runtime};
use flashcomm::telemetry::DEFAULT_CAPACITY;
use flashcomm::transport::{frame, tcp, TcpTransport, Transport};
use flashcomm::util::Prng;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table" => harness::run_table(args),
        "figure" => harness::run_figure(args),
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "ttft" => {
            let mut a = args.clone();
            a.positional = vec!["2".into()];
            harness::run_figure(&a)
        }
        "worker" => cmd_worker(args),
        "metrics" => cmd_metrics(args),
        "info" => cmd_info(),
        "" | "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `flashcomm help`)"),
    }
}

/// Parse the optional `--groups G` flag (link-tier group count for the
/// rank-group topology: 1 = flat NVLink node, G >= 2 = G-group NUMA box).
fn groups_flag(args: &Args) -> Result<Option<usize>> {
    match args.flag("groups") {
        None => Ok(None),
        Some(v) => {
            let g: usize = v.parse().with_context(|| format!("--groups {v}"))?;
            Ok(Some(g))
        }
    }
}

/// Parse the optional `--inter-gbps F` flag (effective inter-group link
/// bandwidth override: models multi-node NVLink clusters; see
/// [`preset_topo_custom`]).
fn inter_gbps_flag(args: &Args) -> Result<Option<f64>> {
    match args.flag("inter-gbps") {
        None => Ok(None),
        Some(v) => {
            let gbps: f64 = v.parse().with_context(|| format!("--inter-gbps {v}"))?;
            Ok(Some(gbps))
        }
    }
}

/// Parse the `--chunks N` / `--window N` plan-knob pins (clean error on
/// `--chunks 0` / `--window 0`).
fn pins_flags(args: &Args) -> Result<PlanPins> {
    let parse = |name: &str| -> Result<Option<usize>> {
        match args.flag(name) {
            None => Ok(None),
            Some(v) => Ok(Some(v.parse().with_context(|| format!("--{name} {v}"))?)),
        }
    };
    let pins = PlanPins { chunks: parse("chunks")?, window: parse("window")? };
    pins.validate()?;
    Ok(pins)
}

/// Resolve the plan policy for one base codec from `--plan` (auto or a
/// spec) plus the `--chunks`/`--window` pins. With no `--plan`, pins
/// alone still enter the plan layer: a fixed `--algo` becomes a pinned
/// uniform plan, `--algo auto` a pinned `Auto` search. Returns `None`
/// only when nothing plan-related was requested (the legacy `AlgoPolicy`
/// path).
fn plan_policy_for(
    plan: Option<&str>,
    pins: PlanPins,
    algo: AlgoPolicy,
    base: &Codec,
) -> Result<Option<PlanPolicy>> {
    match plan {
        Some(spec) if spec.eq_ignore_ascii_case("auto") => Ok(Some(PlanPolicy::Auto(pins))),
        Some(spec) => {
            let plan = pins.apply(CommPlan::parse(spec, base)?);
            plan.validate_shape().with_context(|| format!("--plan {spec}"))?;
            Ok(Some(PlanPolicy::Fixed(plan)))
        }
        None if pins.is_empty() => Ok(None),
        None => Ok(Some(match algo {
            AlgoPolicy::Auto => PlanPolicy::Auto(pins),
            AlgoPolicy::Fixed(a) => {
                let plan = pins.apply(CommPlan::uniform(a, *base));
                plan.validate_shape().context("--chunks/--window")?;
                PlanPolicy::Fixed(plan)
            }
        })),
    }
}

const HELP: &str = "\
flashcomm — FlashCommunication V2 (bit splitting + spike reserving) reproduction

commands:
  table <1..10|all>   regenerate a paper table (see DESIGN.md §5)
  figure <1|2|4|5|8>  regenerate a paper figure
  train               DP-train a model with quantized gradient AllReduce
  eval                TP-inference perplexity under a wire codec
  ttft                Fig.2 TTFT sweep
  worker              multi-process quantized AllReduce over the TCP fabric
                      (spawns one OS process per rank; verifies bit-identical
                      results vs the in-process backend)
  metrics             recorded in-process AllReduce demo; prints the
                      aggregated metrics snapshot as JSON on stdout
  info                artifacts / manifest / device presets

common flags: --quick (small sweep), --steps N, --batches N, --codec SPEC
codec SPEC: bf16 | int<b>[-sr|-had|-log][@gs][!]   e.g. int2-sr@32!
algo: --algo ring|twostep|hier|hierpp|auto — `auto` consults the cost
      model per payload (hier above the crossover size, two-step below)
groups: --groups G — link-tier groups of the rank-group topology
      (1 = flat NVLink, G >= 2 = G NUMA groups; hier runs at any G >= 2)
plan: --plan auto — compile a full communication plan per payload
      (algorithm + per-stage codecs + tuned chunking, cached by shape);
      --plan <algo>[:intra=c][:cross=c][:ag=c][:chunks=K][:window=W][:threads=T]
      runs a fixed plan, e.g. `hier:cross=int2-sr@32!` under --codec
      int4@32. --chunks K / --window W pin those knobs (error if 0).
worker: --bind IP — bind data listeners beyond loopback (multi-node);
      --inter-gbps F — model G NVLink nodes joined by an F GB/s link
      (the tier-asymmetric shape where auto plans mix stage codecs)
trace: --trace-out P — flight-record every collective and write one JSON
      trace per rank to P.rankR (train / eval / worker / metrics;
      schema + recalibration formula in DESIGN.md §11)
";

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.flag_or("config", "tiny");
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(&config)?)?;
    let init = match args.flag("ckpt") {
        Some(p) => Weights::load(p)?,
        None => Weights::load(
            default_artifacts_dir().join(format!("{config}_init_weights.bin")),
        )?,
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (train, eval) = corpus.split();
    let mut sampler = Sampler::new(train, args.flag_usize("seed", 7)? as u64);
    let eval_batches = Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len);
    let codec = Codec::parse(&args.flag_or("codec", "bf16"))?;
    let algo: AlgoPolicy = args.flag_or("algo", "twostep").parse()?;
    let plan = plan_policy_for(args.flag("plan"), pins_flags(args)?, algo, &codec)?;
    let opts = TrainOptions {
        steps: args.flag_usize("steps", 200)?,
        dp: args.flag_usize("dp", 4)?,
        codec,
        algo,
        plan,
        groups: groups_flag(args)?,
        log_every: args.flag_usize("log-every", 10)?,
        eval_every: args.flag_usize("eval-every", 50)?,
        eval_batches: args.flag_usize("eval-batches", 8)?,
        seed: args.flag_usize("seed", 7)? as u64,
        trace_out: args.flag("trace-out").map(str::to_string),
    };
    let policy_label = match &opts.plan {
        Some(p) => format!("plan {p}"),
        None => format!("algo {algo}"),
    };
    println!(
        "training {config} ({} params) for {} steps, dp={}, grads over {} [{policy_label}]",
        cfg.n_params,
        opts.steps,
        opts.dp,
        opts.codec.name(),
    );
    let mut trainer = Trainer::new(rt, cfg, &init)?;
    let t0 = std::time::Instant::now();
    let recs = trainer.train(&mut sampler, &eval_batches, &opts)?;
    let total = t0.elapsed().as_secs_f64();
    let final_ppl = trainer.eval_ppl(&eval_batches[..eval_batches.len().min(8)])?;
    println!(
        "done: {} steps in {:.1}s ({:.2}s/step), final loss {:.4}, eval ppl {:.3}",
        recs.len(),
        total,
        total / recs.len() as f64,
        recs.last().map(|r| r.loss).unwrap_or(f32::NAN),
        final_ppl
    );
    if let Some(out) = args.flag("out") {
        trainer.export_weights()?.save(out).with_context(|| format!("saving {out}"))?;
        println!("checkpoint saved to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let config = args.flag_or("config", "tiny");
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(&config)?)?;
    let weights = match args.flag("ckpt") {
        Some(p) => Weights::load(p)?,
        None => {
            let (_, w, _) = flashcomm::coordinator::pretrain::ensure_trained(
                &config,
                flashcomm::coordinator::pretrain::ACCURACY_STEPS,
            )?;
            w
        }
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let n = args.flag_usize("batches", 6)?;
    let batches: Vec<_> =
        Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len).into_iter().take(n).collect();
    let codec = Codec::parse(&args.flag_or("codec", "bf16"))?;
    if let Some(style) = args.flag("style") {
        bail!("--style was replaced by --algo (try `--algo {style}`, or `--algo auto`)");
    }
    let policy: AlgoPolicy = args.flag_or("algo", "twostep").parse()?;
    let plan = plan_policy_for(args.flag("plan"), pins_flags(args)?, policy, &codec)?;
    let mut engine =
        TpEngine::new_grouped(rt, cfg, &weights, codec, policy, groups_flag(args)?, plan)?;
    let trace_out = args.flag("trace-out").map(str::to_string);
    if trace_out.is_some() {
        engine.enable_recording(DEFAULT_CAPACITY);
    }
    let policy_label = match &plan {
        Some(p) => format!("--plan {p}"),
        None => format!("--algo {policy}"),
    };
    let t0 = std::time::Instant::now();
    let ppl = engine.perplexity(&batches)?;
    println!(
        "{config} perplexity under {} ({policy_label}): {:.4}   [{} batches, {:.2}s]",
        codec.name(),
        ppl,
        batches.len(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(path) = &trace_out {
        match engine.recalibrate_from_recorders() {
            Some(p) => println!("recalibration: {}", p.summary()),
            None => println!("recalibration: no measurable spans"),
        }
        write_traces(path, &engine.trace_jsons())?;
    }
    Ok(())
}

/// Write one flight-recorder trace JSON per rank to `{path}.rank{r}`
/// (status lines go to stderr so `metrics` output stays pipeable).
fn write_traces(path: &str, traces: &[String]) -> Result<()> {
    ensure!(!traces.is_empty(), "--trace-out: no rank recorded a trace");
    for (r, json) in traces.iter().enumerate() {
        let file = format!("{path}.rank{r}");
        std::fs::write(&file, json).with_context(|| format!("writing trace {file}"))?;
    }
    eprintln!("wrote {} flight-recorder traces to {path}.rank*", traces.len());
    Ok(())
}

/// `worker` — the multi-process TCP fabric demo.
///
/// Without `--rank` this is the *launcher*: it reserves a rendezvous port,
/// spawns one OS process per rank (re-invoking this binary with `--rank R`),
/// and fails if any rank fails. With `--rank` it is one rank: it bootstraps
/// the TCP mesh, runs the quantized AllReduce for each requested codec, and
/// verifies the result is bit-identical to the in-process backend on the
/// same inputs.
fn cmd_worker(args: &Args) -> Result<()> {
    let opts = WorkerOpts::parse(args)?;
    match args.flag("rank") {
        Some(r) => {
            let rank: usize = r.parse().with_context(|| format!("--rank {r}"))?;
            let root = args.require("root")?;
            worker_rank(rank, &opts, root)
        }
        None => worker_launch(&opts, args.flag("root")),
    }
}

/// Everything a worker job is parameterized by (identical in the launcher
/// and every spawned rank).
struct WorkerOpts {
    world: usize,
    len: usize,
    algo: String,
    groups: Option<usize>,
    inter_gbps: Option<f64>,
    codecs: String,
    codec_threads: usize,
    /// Data-listener bind address (`--bind`; loopback by default — set a
    /// routable interface IP to let the data plane leave the host).
    bind: std::net::IpAddr,
    /// Raw `--plan` value (`auto` or a spec, resolved per base codec).
    plan: Option<String>,
    pins: PlanPins,
    /// When set, every rank flight-records its collectives and writes the
    /// trace JSON to `{trace_out}.rank{R}` before exiting.
    trace_out: Option<String>,
}

impl WorkerOpts {
    fn parse(args: &Args) -> Result<WorkerOpts> {
        let world = args.flag_usize("world", 4)?;
        ensure!(world >= 2, "worker demo needs at least 2 ranks (got --world {world})");
        let opts = WorkerOpts {
            world,
            len: args.flag_usize("len", 4096)?,
            algo: args.flag_or("algo", "hier"),
            groups: groups_flag(args)?,
            inter_gbps: inter_gbps_flag(args)?,
            codecs: args.flag_or("codecs", "int4@32,int2-sr@32"),
            // Codec worker threads per rank: each rank owns its process
            // here, so large payloads may fan the fused quantize/pack
            // kernels out (the in-process reference always runs 1 to
            // avoid oversubscription).
            codec_threads: args.flag_usize("codec-threads", 1)?,
            bind: match args.flag("bind") {
                None => tcp::DEFAULT_BIND,
                Some(v) => v.parse().with_context(|| format!("--bind {v}"))?,
            },
            plan: args.flag("plan").map(str::to_string),
            pins: pins_flags(args)?,
            trace_out: args.flag("trace-out").map(str::to_string),
        };
        // Validate once here rather than erroring in every spawned
        // process: the topology must construct (world divisible into
        // --groups, --inter-gbps sane), a fixed algorithm must be
        // admissible on it (`Algo::admissible`), and the plan policy —
        // including a fixed plan's own algorithm — must resolve and be
        // admissible against every requested codec.
        let policy: AlgoPolicy = opts.algo.parse()?;
        let topo = opts.topology(policy)?;
        for spec in opts.codec_list() {
            let base = Codec::parse(spec)?;
            if let Some(PlanPolicy::Fixed(plan)) =
                plan_policy_for(opts.plan.as_deref(), opts.pins, policy, &base)?
            {
                plan.validate(&topo)
                    .with_context(|| format!("--plan for codec {spec} on this topology"))?;
            }
        }
        Ok(opts)
    }

    fn codec_list(&self) -> impl Iterator<Item = &str> {
        self.codecs.split(',').map(str::trim).filter(|s| !s.is_empty())
    }

    fn topology(&self, policy: AlgoPolicy) -> Result<flashcomm::topo::Topology> {
        Ok(preset_topo_custom(self.world, self.groups, self.inter_gbps, policy)?)
    }
}

fn worker_launch(opts: &WorkerOpts, root: Option<&str>) -> Result<()> {
    let root = match root {
        Some(r) => r.to_string(),
        None => {
            // Reserve an ephemeral rendezvous port; rank 0 rebinds it after
            // the probe is dropped.
            let probe = std::net::TcpListener::bind(("127.0.0.1", 0))
                .context("probing for a free rendezvous port")?;
            let addr = probe.local_addr()?.to_string();
            drop(probe);
            addr
        }
    };
    let exe = std::env::current_exe().context("resolving the worker binary path")?;
    let grouping = match opts.groups {
        Some(g) => format!(", {g} groups"),
        None => String::new(),
    };
    let policy_label = match &opts.plan {
        Some(p) => format!("plan {p}"),
        None => format!("algo {}", opts.algo),
    };
    println!(
        "spawning {} worker processes: rendezvous {root}, {policy_label}{grouping}, \
         codecs {}, {} elems/rank",
        opts.world, opts.codecs, opts.len
    );
    let mut children = Vec::with_capacity(opts.world);
    for rank in 0..opts.world {
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("worker")
            .args(["--rank", &rank.to_string()])
            .args(["--world", &opts.world.to_string()])
            .args(["--root", &root])
            .args(["--len", &opts.len.to_string()])
            .args(["--algo", &opts.algo])
            .args(["--codecs", &opts.codecs])
            .args(["--codec-threads", &opts.codec_threads.to_string()])
            .args(["--bind", &opts.bind.to_string()]);
        if let Some(g) = opts.groups {
            cmd.args(["--groups", &g.to_string()]);
        }
        if let Some(gbps) = opts.inter_gbps {
            cmd.args(["--inter-gbps", &gbps.to_string()]);
        }
        if let Some(p) = &opts.plan {
            cmd.args(["--plan", p]);
        }
        if let Some(t) = &opts.trace_out {
            cmd.args(["--trace-out", t]);
        }
        if let Some(c) = opts.pins.chunks {
            cmd.args(["--chunks", &c.to_string()]);
        }
        if let Some(w) = opts.pins.window {
            cmd.args(["--window", &w.to_string()]);
        }
        let child =
            cmd.spawn().with_context(|| format!("spawning worker rank {rank}"))?;
        children.push((rank, child));
    }
    let mut failed = false;
    for (rank, mut child) in children {
        let status = child.wait().with_context(|| format!("waiting for rank {rank}"))?;
        if !status.success() {
            eprintln!("worker rank {rank} failed: {status}");
            failed = true;
        }
    }
    ensure!(!failed, "one or more worker ranks failed");
    println!("all {} worker processes agree with the InProc backend bit-for-bit", opts.world);
    Ok(())
}

fn worker_rank(rank: usize, opts: &WorkerOpts, root: &str) -> Result<()> {
    let policy: AlgoPolicy = opts.algo.parse()?;
    let topo = opts.topology(policy)?;
    let world = opts.world;
    let len = opts.len;
    let tcp = TcpTransport::bootstrap_bound(rank, world, root, opts.bind)
        .with_context(|| format!("rank {rank} bootstrapping the TCP mesh at {root}"))?;
    let mut comm =
        Communicator::new(tcp, topo.clone(), Arc::new(fabric::ByteCounters::default()))?;
    comm.set_codec_threads(opts.codec_threads);
    if opts.trace_out.is_some() {
        comm.enable_recording(DEFAULT_CAPACITY);
    }

    // Deterministic heavy-tailed inputs, identical in every process (and in
    // the in-process reference below).
    let inputs: Vec<Vec<f32>> = (0..world)
        .map(|r| {
            let mut rng = Prng::new(1000 + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect();

    for spec in opts.codec_list() {
        let codec = Codec::parse(spec)?;
        let plan_policy = plan_policy_for(opts.plan.as_deref(), opts.pins, policy, &codec)?;

        // The real thing: this process is one rank of the TCP mesh.
        let mut mine = inputs[rank].clone();
        let (used_label, used_algo, used_plan) = match &plan_policy {
            Some(pp) => {
                let plan = comm.allreduce_planned(&mut mine, &codec, pp)?;
                (plan.to_string(), plan.algo, Some(plan))
            }
            None => {
                let algo = comm.allreduce(&mut mine, &codec, policy)?;
                (algo.to_string(), algo, None)
            }
        };

        // Reference: the same collective over the in-process backend. The
        // policy (algorithm or full plan) resolves per (topology, codec,
        // size) deterministically, so both backends pick the same schedule
        // without coordination.
        let inputs_ref = &inputs;
        let pp_ref = &plan_policy;
        let (reference, _) = fabric::run_ranks(&topo, |rh| {
            let mut c = Communicator::from_handle(rh);
            let mut d = inputs_ref[c.rank()].clone();
            match pp_ref {
                Some(pp) => {
                    let ref_plan = c
                        .allreduce_planned(&mut d, &codec, pp)
                        .expect("in-process reference failed");
                    assert_eq!(Some(ref_plan), used_plan, "backends resolved different plans");
                }
                None => {
                    let ref_used =
                        c.allreduce(&mut d, &codec, policy).expect("in-process reference failed");
                    assert_eq!(ref_used, used_algo, "backends resolved different algorithms");
                }
            }
            d
        });
        let expect = &reference[rank];
        ensure!(mine.len() == expect.len(), "{spec}: length mismatch");
        for (i, (a, b)) in mine.iter().zip(expect).enumerate() {
            ensure!(
                a.to_bits() == b.to_bits(),
                "[rank {rank}] {spec}: TCP diverges from InProc at element {i}: {a} vs {b}"
            );
        }
        println!(
            "[rank {rank}] {spec} [{used_label}] AllReduce over TCP == InProc \
             bit-for-bit ({len} elems)"
        );
    }

    // Every rank must have resolved the *same* plan for the last
    // collective (the compiler is deterministic, so this holds without
    // coordination): allgather the 8-byte plan fingerprint over the mesh
    // and require unanimity.
    {
        let fp = comm.last_plan().map(|(_, f)| *f).unwrap_or(0);
        let h = comm.handle();
        for peer in (0..world).filter(|&p| p != rank) {
            h.send(peer, fp.to_le_bytes().to_vec())?;
        }
        for peer in (0..world).filter(|&p| p != rank) {
            let bytes = h.recv(peer)?;
            ensure!(bytes.len() == 8, "fingerprint allgather: bad frame from rank {peer}");
            let theirs = u64::from_le_bytes(bytes.try_into().expect("length checked"));
            ensure!(
                theirs == fp,
                "[rank {rank}] resolved-plan fingerprint diverges from rank {peer}: \
                 {fp:#018x} vs {theirs:#018x}"
            );
        }
        println!("[rank {rank}] resolved-plan fingerprint {fp:#018x} matches all {world} ranks");
    }

    match comm.recalibrate_from_recorder() {
        Some(p) => println!("[rank {rank}] recalibration: {}", p.summary()),
        None => println!("[rank {rank}] recalibration: no measurable spans"),
    }
    if let Some(path) = &opts.trace_out {
        let json = comm.trace_json().expect("recording was enabled");
        let file = format!("{path}.rank{rank}");
        std::fs::write(&file, &json).with_context(|| format!("writing trace {file}"))?;
        println!("[rank {rank}] wrote trace {file}");
    }

    let stats = comm.transport().stats();
    println!(
        "[rank {rank}] sent {} messages, {} payload B, {} wire B ({} B framing)",
        stats.messages,
        stats.payload_bytes,
        stats.wire_bytes,
        stats.wire_bytes - stats.payload_bytes
    );

    if rank == 0 {
        // Demonstrate the frame guard: a corrupted payload must be rejected
        // with a CRC error, never decoded.
        let payload = Codec::parse("int4@32")?.encode(&inputs[0]);
        let mut framed = frame::encode(0, 1, 0, &payload);
        let last = framed.len() - 1;
        framed[last] ^= 0x01;
        match frame::decode(framed) {
            Err(e) => println!("[rank 0] corrupted frame correctly rejected: {e}"),
            Ok(_) => bail!("corrupted frame was not rejected"),
        }
    }
    Ok(())
}

/// `metrics` — run a small flight-recorded in-process AllReduce demo and
/// print the aggregated metrics snapshot as JSON on stdout (schema:
/// DESIGN.md §11). Human-oriented status lines go to stderr so the JSON
/// stays pipeable. Defaults to `--plan auto`, so the snapshot also
/// exercises the plan cache (first iteration misses, the rest hit) and
/// reports the last resolved plan.
fn cmd_metrics(args: &Args) -> Result<()> {
    let ranks = args.flag_usize("ranks", 8)?;
    ensure!(ranks >= 2, "metrics demo needs at least 2 ranks (got --ranks {ranks})");
    let len = args.flag_usize("len", 1 << 16)?;
    let iters = args.flag_usize("iters", 4)?;
    ensure!(iters >= 1, "metrics demo needs at least 1 iteration (got --iters {iters})");
    let codec = Codec::parse(&args.flag_or("codec", "int4@32"))?;
    let policy: AlgoPolicy = args.flag_or("algo", "auto").parse()?;
    let plan_spec = args.flag_or("plan", "auto");
    let plan = plan_policy_for(Some(plan_spec.as_str()), pins_flags(args)?, policy, &codec)?
        .expect("an explicit --plan always resolves to a policy");
    let mut group = LocalGroup::for_plan_grouped(ranks, groups_flag(args)?, plan)?;
    group.enable_recording(DEFAULT_CAPACITY);
    let mut data: Vec<Vec<f32>> = (0..ranks)
        .map(|r| {
            let mut rng = Prng::new(4000 + r as u64);
            let mut v = vec![0f32; len];
            rng.fill_activations(&mut v, 1.0);
            v
        })
        .collect();
    for _ in 0..iters {
        group.allreduce(&mut data, &codec)?;
    }
    match group.recalibrate_from_recorders() {
        Some(p) => eprintln!("recalibration: {}", p.summary()),
        None => eprintln!("recalibration: no measurable spans"),
    }
    if let Some(path) = args.flag("trace-out") {
        write_traces(path, &group.trace_jsons())?;
    }
    let json = group.metrics_snapshot().to_json();
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, &json).with_context(|| format!("writing {path}"))?;
            eprintln!("metrics snapshot written to {path}");
        }
        None => println!("{json}"),
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::open(default_artifacts_dir())?;
    println!("artifacts: {:?}", rt.dir());
    println!("configs:");
    for c in &rt.manifest.configs {
        println!(
            "  {} — {} params, vocab {}, tp {}",
            c.name,
            c.get("n_params").unwrap_or("?"),
            c.get("vocab").unwrap_or("?"),
            c.get("tp").unwrap_or("?")
        );
    }
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for a in &rt.manifest.artifacts {
        println!("  {}", a.name);
    }
    println!("device presets (Table 6):");
    for s in flashcomm::topo::presets::all() {
        println!(
            "  {:>5}: {} SMs, {} GB/s nominal, {} TFLOPs bf16 (CUDA), comm SMs {}",
            s.name, s.sms, s.nominal_bw_gbps, s.bf16_tflops, s.comm_sms
        );
    }
    Ok(())
}
