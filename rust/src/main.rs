//! `flashcomm` — the FlashCommunication V2 coordinator CLI.
//!
//! ```text
//! flashcomm table <1..10|all> [--quick] [--steps N] [--batches N] [--size 64M]
//! flashcomm figure <1|2|4|5|8|all> [--quick] [--codec spec] [--chunks K]
//! flashcomm train   [--config tiny] [--steps N] [--dp N] [--codec spec]
//!                   [--algo ring|twostep|hier|hierpp] [--out ckpt.bin]
//! flashcomm eval    [--config tiny] [--ckpt path] [--codec spec]
//!                   [--style twostep|hier] [--batches N]
//! flashcomm ttft    [--prompt N] [--batch N]
//! flashcomm info
//! ```
//!
//! Codec spec grammar: `bf16 | int<bits>[-rtn|-sr|-had|-log][@<gs>][!]`
//! (`!` = integer Eq.1 metadata), e.g. `int5`, `int2-sr@32`, `int2-sr@32!`.

use anyhow::{bail, Context, Result};

use flashcomm::cli::Args;
use flashcomm::coordinator::{CollectiveStyle, TpEngine, TrainOptions, Trainer};
use flashcomm::harness;
use flashcomm::model::{Corpus, ModelConfig, Sampler, Weights};
use flashcomm::quant::Codec;
use flashcomm::runtime::{default_artifacts_dir, Runtime};
use flashcomm::sim::Algo;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "table" => harness::run_table(args),
        "figure" => harness::run_figure(args),
        "train" => cmd_train(args),
        "eval" => cmd_eval(args),
        "ttft" => {
            let mut a = args.clone();
            a.positional = vec!["2".into()];
            harness::run_figure(&a)
        }
        "info" => cmd_info(),
        "" | "help" | "--help" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `flashcomm help`)"),
    }
}

const HELP: &str = "\
flashcomm — FlashCommunication V2 (bit splitting + spike reserving) reproduction

commands:
  table <1..10|all>   regenerate a paper table (see DESIGN.md §5)
  figure <1|2|4|5|8>  regenerate a paper figure
  train               DP-train a model with quantized gradient AllReduce
  eval                TP-inference perplexity under a wire codec
  ttft                Fig.2 TTFT sweep
  info                artifacts / manifest / device presets

common flags: --quick (small sweep), --steps N, --batches N, --codec SPEC
codec SPEC: bf16 | int<b>[-sr|-had|-log][@gs][!]   e.g. int2-sr@32!
";

fn parse_algo(s: &str) -> Result<Algo> {
    Ok(match s {
        "ring" => Algo::Ring,
        "twostep" => Algo::TwoStep,
        "hier" => Algo::Hier,
        "hierpp" => Algo::HierPipelined,
        other => bail!("unknown algo '{other}'"),
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.flag_or("config", "tiny");
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(&config)?)?;
    let init = match args.flag("ckpt") {
        Some(p) => Weights::load(p)?,
        None => Weights::load(
            default_artifacts_dir().join(format!("{config}_init_weights.bin")),
        )?,
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (train, eval) = corpus.split();
    let mut sampler = Sampler::new(train, args.flag_usize("seed", 7)? as u64);
    let eval_batches = Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len);
    let opts = TrainOptions {
        steps: args.flag_usize("steps", 200)?,
        dp: args.flag_usize("dp", 4)?,
        codec: Codec::parse(&args.flag_or("codec", "bf16"))?,
        algo: parse_algo(&args.flag_or("algo", "twostep"))?,
        log_every: args.flag_usize("log-every", 10)?,
        eval_every: args.flag_usize("eval-every", 50)?,
        eval_batches: args.flag_usize("eval-batches", 8)?,
        seed: args.flag_usize("seed", 7)? as u64,
    };
    println!(
        "training {config} ({} params) for {} steps, dp={}, grads over {} [{}]",
        cfg.n_params,
        opts.steps,
        opts.dp,
        opts.codec.name(),
        args.flag_or("algo", "twostep"),
    );
    let mut trainer = Trainer::new(rt, cfg, &init)?;
    let t0 = std::time::Instant::now();
    let recs = trainer.train(&mut sampler, &eval_batches, &opts)?;
    let total = t0.elapsed().as_secs_f64();
    let final_ppl = trainer.eval_ppl(&eval_batches[..eval_batches.len().min(8)])?;
    println!(
        "done: {} steps in {:.1}s ({:.2}s/step), final loss {:.4}, eval ppl {:.3}",
        recs.len(),
        total,
        total / recs.len() as f64,
        recs.last().map(|r| r.loss).unwrap_or(f32::NAN),
        final_ppl
    );
    if let Some(out) = args.flag("out") {
        trainer.export_weights()?.save(out).with_context(|| format!("saving {out}"))?;
        println!("checkpoint saved to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let config = args.flag_or("config", "tiny");
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(&config)?)?;
    let weights = match args.flag("ckpt") {
        Some(p) => Weights::load(p)?,
        None => {
            let (_, w, _) = flashcomm::coordinator::pretrain::ensure_trained(
                &config,
                flashcomm::coordinator::pretrain::ACCURACY_STEPS,
            )?;
            w
        }
    };
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let n = args.flag_usize("batches", 6)?;
    let batches: Vec<_> =
        Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len).into_iter().take(n).collect();
    let codec = Codec::parse(&args.flag_or("codec", "bf16"))?;
    let style = match args.flag_or("style", "twostep").as_str() {
        "hier" => CollectiveStyle::Hier,
        _ => CollectiveStyle::TwoStep,
    };
    let mut engine = TpEngine::new(rt, cfg, &weights, codec, style)?;
    let t0 = std::time::Instant::now();
    let ppl = engine.perplexity(&batches)?;
    println!(
        "{config} perplexity under {} ({:?}): {:.4}   [{} batches, {:.2}s]",
        codec.name(),
        style,
        ppl,
        batches.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::open(default_artifacts_dir())?;
    println!("artifacts: {:?}", rt.dir());
    println!("configs:");
    for c in &rt.manifest.configs {
        println!(
            "  {} — {} params, vocab {}, tp {}",
            c.name,
            c.get("n_params").unwrap_or("?"),
            c.get("vocab").unwrap_or("?"),
            c.get("tp").unwrap_or("?")
        );
    }
    println!("artifacts ({}):", rt.manifest.artifacts.len());
    for a in &rt.manifest.artifacts {
        println!("  {}", a.name);
    }
    println!("device presets (Table 6):");
    for s in flashcomm::topo::presets::all() {
        println!(
            "  {:>5}: {} SMs, {} GB/s nominal, {} TFLOPs bf16 (CUDA), comm SMs {}",
            s.name, s.sms, s.nominal_bw_gbps, s.bf16_tflops, s.comm_sms
        );
    }
    Ok(())
}
