//! Table regenerators (paper Tables 1–10). See DESIGN.md §5.

use anyhow::{Context, Result};

use super::{f2, print_table};
use crate::cli::Args;
use crate::comm::{Algo, AlgoPolicy};
use crate::coordinator::pretrain::{ensure_trained, ACCURACY_STEPS, TEST_STEPS};
use crate::coordinator::{MoeEngine, TpEngine};
use crate::model::{Batch, Corpus, Sampler};
use crate::quant::Codec;
use crate::runtime::{default_artifacts_dir, tokens_literal, Runtime};
use crate::sim;
use crate::topo::{presets, Topology};

/// The fixed two-step policy the accuracy tables evaluate under (the
/// paper's default QDQ chain).
const TWOSTEP: AlgoPolicy = AlgoPolicy::Fixed(Algo::TwoStep);

fn steps_for(args: &Args) -> usize {
    if args.flag_bool("quick") {
        TEST_STEPS
    } else {
        args.flag_usize("steps", ACCURACY_STEPS).unwrap_or(ACCURACY_STEPS)
    }
}

fn eval_batches_for(args: &Args, cfg: &crate::model::ModelConfig) -> Result<Vec<Batch>> {
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let n = args.flag_usize("batches", if args.flag_bool("quick") { 2 } else { 6 })?;
    Ok(Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len).into_iter().take(n).collect())
}

/// Shared dense perplexity sweep over codecs.
fn dense_ppl(args: &Args, specs: &[&str]) -> Result<Vec<(String, f64)>> {
    let (cfg, weights, _) = ensure_trained("tiny", steps_for(args))?;
    let batches = eval_batches_for(args, &cfg)?;
    let rt = Runtime::open(default_artifacts_dir())?;
    let mut engine = TpEngine::new(rt, cfg, &weights, Codec::Bf16, TWOSTEP)?;
    let mut out = Vec::new();
    for spec in specs {
        let codec =
            if *spec == "bf16" { Codec::Bf16 } else { Codec::parse(spec)? };
        engine.set_codec(codec, TWOSTEP)?;
        let ppl = engine.perplexity(&batches)?;
        eprintln!("  [tp-eval] {spec}: ppl {ppl:.3}");
        out.push((spec.to_string(), ppl));
    }
    Ok(out)
}

/// Shared MoE dispatch perplexity sweep.
fn moe_ppl(args: &Args, specs: &[&str]) -> Result<Vec<(String, f64)>> {
    let (cfg, weights, _) = ensure_trained("moe-tiny", steps_for(args))?;
    let batches = eval_batches_for(args, &cfg)?;
    let rt = Runtime::open(default_artifacts_dir())?;
    let mut engine = MoeEngine::new(rt, cfg, &weights, Codec::Bf16, Codec::Bf16)?;
    let mut out = Vec::new();
    for spec in specs {
        let codec =
            if *spec == "bf16" { Codec::Bf16 } else { Codec::parse(spec)? };
        engine.set_dispatch_codec(codec);
        let ppl = engine.perplexity(&batches)?;
        eprintln!("  [moe-eval] {spec}: ppl {ppl:.3}");
        out.push((spec.to_string(), ppl));
    }
    Ok(out)
}

/// Table 1: dense perplexity vs AllReduce RTN bitwidth (gs 128).
pub fn table1(args: &Args) -> Result<()> {
    let specs =
        ["bf16", "int8@128", "int6@128", "int5@128", "int4@128", "int3@128", "int2@128"];
    let ours = dense_ppl(args, &specs)?;
    let mut rows = vec![];
    let mut row = vec!["ours (tiny, trained here)".to_string()];
    row.extend(ours.iter().map(|(_, p)| f2(*p)));
    rows.push(row);
    for (name, vals) in [
        ("paper Llama-3-8B", ["8.88", "8.89", "8.94", "9.07", "9.67", "13.72", "7e5"]),
        ("paper Llama-3-70B", ["6.74", "6.74", "6.75", "6.81", "7.05", "8.40", "1e2"]),
        ("paper Qwen-3-8B", ["13.3", "13.30", "13.33", "13.42", "13.81", "16.04", "3e2"]),
    ] {
        rows.push(std::iter::once(name.to_string()).chain(vals.iter().map(|s| s.to_string())).collect());
    }
    print_table(
        "Table 1: C4-style perplexity vs AllReduce RTN bits (gs=128)",
        &["model", "BF16", "INT8", "INT6", "INT5", "INT4", "INT3", "INT2"],
        &rows,
    );
    println!("shape check: INT8≈INT6≈INT5 ≲ INT4 < INT3 << INT2 (collapse)");
    Ok(())
}

/// Table 2: MoE perplexity vs All2All dispatch RTN bitwidth (gs 128).
pub fn table2(args: &Args) -> Result<()> {
    let specs =
        ["bf16", "int8@128", "int6@128", "int5@128", "int4@128", "int3@128", "int2@128"];
    let ours = moe_ppl(args, &specs)?;
    let mut rows = vec![];
    let mut row = vec!["ours (moe-tiny, trained here)".to_string()];
    row.extend(ours.iter().map(|(_, p)| f2(*p)));
    rows.push(row);
    rows.push(vec![
        "paper Qwen3-30B-A3B".into(),
        "9.65".into(), "9.65".into(), "9.66".into(), "9.7".into(), "9.88".into(),
        "10.61".into(), "19.71".into(),
    ]);
    rows.push(vec![
        "paper Qwen1.5-MoE-A2.7B".into(),
        "9.3".into(), "9.3".into(), "9.31".into(), "9.35".into(), "9.5".into(),
        "10.62".into(), "30.54".into(),
    ]);
    print_table(
        "Table 2: MoE perplexity vs All2All dispatch RTN bits (gs=128)",
        &["model", "BF16", "INT8", "INT6", "INT5", "INT4", "INT3", "INT2"],
        &rows,
    );
    println!("shape check: graceful degradation; All2All INT2 does NOT collapse like AllReduce");
    Ok(())
}

/// Table 3: RTN vs Hadamard vs LogFMT vs SpikeReserving at gs 32.
pub fn table3(args: &Args) -> Result<()> {
    let mut rows = Vec::new();
    for (label, fmt) in [
        ("RTN", "int{b}@32"),
        ("Hadamard", "int{b}-had@32"),
        ("LogFMT", "int{b}-log@32"),
        ("SpikeReserving", "int{b}-sr@32"),
    ] {
        let specs: Vec<String> =
            [4, 3, 2].iter().map(|b| fmt.replace("{b}", &b.to_string())).collect();
        let refs: Vec<&str> = specs.iter().map(|s| s.as_str()).collect();
        let ours = dense_ppl(args, &refs)?;
        let mut row = vec![label.to_string()];
        row.extend(ours.iter().map(|(_, p)| f2(*p)));
        rows.push(row);
    }
    print_table(
        "Table 3: dense ppl by method, gs=32 (ours, trained tiny)",
        &["method", "INT4", "INT3", "INT2"],
        &rows,
    );
    println!("paper (Llama-3-8B): RTN 9.2/10.54/40.59  Hadamard 9.18/10.47/91.23");
    println!("                    LogFMT 9.3/11.53/1e3  SpikeReserving 9.01/9.57/14.39");
    println!("shape check: SR best at every width; Hadamard/LogFMT collapse at INT2");
    Ok(())
}

/// Table 4: spike-reserving memory footprint, BF16 vs integer metadata.
pub fn table4() -> Result<()> {
    let n = 4096;
    let mut rows = Vec::new();
    for (label, spec) in [("scale (bf16 meta)", "int2-sr@32"), ("scale_int (Eq.1)", "int2-sr@32!")] {
        let codec = Codec::parse(spec)?;
        let s = codec.sections(n);
        rows.push(vec![
            label.to_string(),
            (2 * n).to_string(),
            s.quantized.to_string(),
            s.scale_zero.to_string(),
            s.spikes.to_string(),
            s.meta().to_string(),
            (s.total() - crate::quant::wire::HEADER_LEN).to_string(),
        ]);
    }
    print_table(
        "Table 4: INT2+SR footprint for 4096 BF16 values (bytes, header excl.)",
        &["scheme", "data", "quantized", "scale&zero", "spikes", "meta", "total"],
        &rows,
    );
    println!("paper: 2560 total with bf16 meta, 2048 with integer scales+indices (-20%)");
    Ok(())
}

/// Table 5: AllReduce volume accounting.
pub fn table5() -> Result<()> {
    let rows: Vec<Vec<String>> = [Algo::Ring, Algo::TwoStep, Algo::Hier]
        .iter()
        .map(|&a| {
            vec![
                a.name().to_string(),
                format!("{}M", sim::volume::total_volume(a, 8, 1.0)),
                format!("{}M", sim::volume::cross_numa_volume(a, 8, 2, 1.0)),
            ]
        })
        .collect();
    print_table(
        "Table 5: volume per AllReduce (N=8, 2 NUMA groups, M per GPU)",
        &["method", "total", "cross-NUMA"],
        &rows,
    );
    println!("paper: NCCL 14M / 7M/4 (=1.75M);  Two-step 14M / 4M;  Hier 14M / M");
    Ok(())
}

/// Table 6: device constants.
pub fn table6() -> Result<()> {
    let rows: Vec<Vec<String>> = presets::all()
        .iter()
        .map(|s| {
            vec![
                s.name.to_string(),
                s.sms.to_string(),
                if s.is_numa() { "PCIe".into() } else { "NVLINK".into() },
                format!("{}", s.nominal_bw_gbps),
                format!("{}", s.bf16_tflops),
                s.comm_sms.to_string(),
            ]
        })
        .collect();
    print_table(
        "Table 6: GPU interconnect + CUDA-core BF16 compute (paper constants)",
        &["GPU", "SM", "interconnect", "BW (GB/s)", "BF16 (TFlops)", "comm SMs"],
        &rows,
    );
    Ok(())
}

/// Table 7: downstream accuracy by synthetic task suite.
pub fn table7(args: &Args) -> Result<()> {
    let (cfg, weights, _) = ensure_trained("tiny", steps_for(args))?;
    let batches = eval_batches_for(args, &cfg)?;
    let rt = Runtime::open(default_artifacts_dir())?;
    // Task definitions: per-POS-pool prediction accuracy (manifest pools).
    let pools: Vec<(String, usize, usize)> = rt
        .manifest
        .pools
        .iter()
        .filter(|p| p.get("vocab") == Some(cfg.vocab.to_string().as_str()))
        .filter(|p| ["noun", "verb", "adj", "prep"].contains(&p.name.as_str()))
        .map(|p| {
            Ok((p.name.clone(), p.get_usize("start")?, p.get_usize("n")?))
        })
        .collect::<Result<_>>()?;
    anyhow::ensure!(pools.len() == 4, "expected 4 task pools, got {}", pools.len());

    let mut engine = TpEngine::new(rt, cfg.clone(), &weights, Codec::Bf16, TWOSTEP)?;
    let specs = [
        "bf16", "int8@128", "int6@128", "int5@128", "int4@128", "int3@32", "int3-sr@32",
        "int2@32", "int2-sr@32",
    ];
    let mut rows = Vec::new();
    for spec in specs {
        let codec = if spec == "bf16" { Codec::Bf16 } else { Codec::parse(spec)? };
        engine.set_codec(codec, TWOSTEP)?;
        // Tasks: per-pool *pool-match* accuracy (the prediction lands in
        // the target's part-of-speech pool — the syntactic structure the
        // model has learned and quantization noise erodes), plus overall
        // exact top-1 accuracy.
        let mut hits = vec![0f64; pools.len() + 1];
        let mut totals = vec![0f64; pools.len() + 1];
        for b in &batches {
            let h = engine.forward_h(b)?;
            let tgts = tokens_literal(&b.targets, &[b.batch, b.seq])?;
            let name = cfg.art("head_acc");
            let mut lits = vec![h.to_literal()?];
            lits.extend(engine_head(&engine));
            lits.push(tgts);
            let out = engine.rt.execute_t(&name, &lits)?;
            let correct = &out[0].data;
            let preds = &out[1].data;
            for (i, &t) in b.targets.iter().enumerate() {
                let (t, pred) = (t as usize, preds[i] as usize);
                for (p, (_, start, n)) in pools.iter().enumerate() {
                    if t >= *start && t < start + n {
                        totals[p] += 1.0;
                        if pred >= *start && pred < start + n {
                            hits[p] += 1.0;
                        }
                    }
                }
                hits[pools.len()] += correct[i] as f64;
                totals[pools.len()] += 1.0;
            }
        }
        let name = codec_label(spec);
        let mut row = vec![name];
        let mut sum = 0.0;
        for p in 0..pools.len() {
            let acc = 100.0 * hits[p] / totals[p].max(1.0);
            sum += acc;
            row.push(f2(acc));
        }
        let overall = 100.0 * hits[pools.len()] / totals[pools.len()].max(1.0);
        row.push(f2(overall));
        row.push(f2((sum + overall) / (pools.len() + 1) as f64));
        eprintln!("  [acc-eval] {spec} done");
        rows.push(row);
    }
    print_table(
        "Table 7: downstream accuracy (%) on the synthetic task suite",
        &["Comm BitW", "NOUN*", "VERB*", "ADJ*", "PREP*", "EXACT", "Avg"],
        &rows,
    );
    println!("(*pool-match accuracy; EXACT = top-1. Stands in for PIQA/ARC/HS/WG — DESIGN §2)");
    println!("shape check: INT6/5≈INT8; SR gives a large boost at INT3/INT2");
    Ok(())
}

fn engine_head(e: &TpEngine) -> Vec<xla::Literal> {
    e.head_literals()
}

fn codec_label(spec: &str) -> String {
    if spec == "bf16" {
        "FP16/BF16".into()
    } else {
        Codec::parse(spec).map(|c| {
            let gs = c.group_size();
            format!("{} gs{gs}", c.name())
        }).unwrap_or_else(|_| spec.into())
    }
}

/// Table 8: MoE dispatch ppl, RTN vs SR, gs128 vs gs32.
pub fn table8(args: &Args) -> Result<()> {
    let rtn128 = moe_ppl(args, &["bf16", "int8@128", "int5@128", "int3@128", "int2@128"])?;
    let sr128 = moe_ppl(args, &["int3-sr@128", "int2-sr@128"])?;
    let g32 = moe_ppl(args, &["int4@32", "int3@32", "int2@32", "int3-sr@32", "int2-sr@32"])?;
    let g = |v: &[(String, f64)], i: usize| f2(v[i].1);
    let rows = vec![
        vec!["RTN gs128".to_string(), g(&rtn128, 1), g(&rtn128, 2), g(&rtn128, 3), g(&rtn128, 4)],
        vec!["SR gs128".to_string(), "-".into(), "-".into(), g(&sr128, 0), g(&sr128, 1)],
        vec!["RTN gs32".to_string(), "-".into(), g(&g32, 0), g(&g32, 1), g(&g32, 2)],
        vec!["SR gs32".to_string(), "-".into(), "-".into(), g(&g32, 3), g(&g32, 4)],
    ];
    print_table(
        &format!(
            "Table 8: MoE dispatch ppl, RTN vs SpikeReserving (BF16 baseline {})",
            f2(rtn128[0].1)
        ),
        &["method", "INT8/4*", "INT5/3*", "INT3", "INT2"],
        &rows,
    );
    println!("(columns marked * hold INT4/INT3 for the gs32 rows, matching the paper's layout)");
    println!("paper Qwen3-30B-A3B: RTN INT2 19.71 -> SR 11.55; gs32 RTN INT2 11.67");
    println!("shape check: SR < RTN at low bits; finer gs32 recovers most of the loss");
    Ok(())
}

/// Table 9: AllReduce algorithmic bandwidth (simulator; see DESIGN §2).
pub fn table9(args: &Args) -> Result<()> {
    let m = parse_size(&args.flag_or("size", "64M"))?;
    let specs =
        ["bf16", "int8", "int6", "int5", "int4@32", "int3@32", "int2-sr@32"];
    let headers =
        ["device/algo", "BF16(NCCL)", "INT8", "INT6", "INT5", "INT4", "INT3", "INT2_SR"];
    let mut rows = Vec::new();
    fn push_row(
        rows: &mut Vec<Vec<String>>,
        specs: &[&str],
        m: f64,
        label: String,
        topo: &Topology,
        algo: Option<Algo>,
    ) {
        let mut row = vec![label];
        for (i, s) in specs.iter().enumerate() {
            let codec = if i == 0 { Codec::Bf16 } else { Codec::parse(s).unwrap() };
            let a = match algo {
                // Column 0 is definitionally the NCCL baseline — every row
                // (the Auto row included) pins it to the ring. The other
                // cells of the Auto row report what the policy resolves.
                _ if i == 0 => Algo::Ring,
                None => AlgoPolicy::Auto.resolve(topo, &codec, (m / 2.0) as usize),
                Some(a) => a,
            };
            if algo.is_some() && a == Algo::Ring && i != 0 {
                row.push("-".into());
                continue;
            }
            let t = sim::allreduce_time(topo, a, &codec, m);
            let bw = f2(sim::algbw_gbps(m, &t));
            row.push(if algo.is_none() { format!("{bw} [{a}]") } else { bw });
        }
        rows.push(row);
    }
    let l40 = Topology::new(presets::l40(), 8);
    push_row(&mut rows, &specs, m, "L40 (Two-step)".into(), &l40, Some(Algo::TwoStep));
    push_row(&mut rows, &specs, m, "L40 (Hier)".into(), &l40, Some(Algo::Hier));
    push_row(&mut rows, &specs, m, "L40 (HierPP)".into(), &l40, Some(Algo::HierPipelined));
    push_row(&mut rows, &specs, m, "L40 (--algo auto)".into(), &l40, None);
    for spec in [presets::a100(), presets::h800(), presets::h20()] {
        let name = spec.name;
        let topo = Topology::new(spec, 8);
        push_row(&mut rows, &specs, m, name.into(), &topo, Some(Algo::TwoStep));
    }
    print_table(
        &format!("Table 9: AllReduce algorithmic bandwidth (GB/s), {} per GPU", args.flag_or("size", "64M")),
        &headers,
        &rows,
    );
    println!("([algo] cells: what AlgoPolicy::Auto resolves to at this size)");
    println!("paper: L40 10.43/9.17..16.19 | Hier ..28.8 | HierPP ..33.39 | A100 89->153 |");
    println!("       H800 94->187 | H20 209->260 (INT2_SR 202 — loses)");
    println!("shape check: hier>two-step on L40; HierPP best (max ~3.2x NCCL); INT2_SR");
    println!("             never optimal on NVLink; H20 gains least");
    Ok(())
}

/// Table 10: All2All dispatch algorithmic bandwidth.
pub fn table10(args: &Args) -> Result<()> {
    let m = parse_size(&args.flag_or("size", "64M"))?;
    let specs = ["bf16", "int8", "int6", "int5", "int4@32", "int3@32", "int2-sr@32"];
    let mut rows = Vec::new();
    for spec in [presets::a100(), presets::h800(), presets::h20()] {
        let name = spec.name;
        let topo = Topology::new(spec, 8);
        let mut row = vec![name.to_string()];
        for s in specs {
            let codec = if s == "bf16" { Codec::Bf16 } else { Codec::parse(s)? };
            let t = sim::all2all::all2all_time(&topo, &codec, m);
            row.push(f2(sim::all2all::algbw_gbps(m, &t)));
        }
        rows.push(row);
    }
    print_table(
        "Table 10: All2All dispatch algorithmic bandwidth (GB/s)",
        &["GPU", "BF16", "INT8", "INT6", "INT5", "INT4", "INT3", "INT2_SR"],
        &rows,
    );
    println!("paper (H800 row): 169.76 | 230.51 | 276.82 | 300.20 | 341.87 | 290.50 | 249.53");
    println!("shape check: INT4 best (~2x H800, ~1.3x A100); no benefit on H20");
    Ok(())
}

/// Parse `64M`, `1G`, `4096` into bytes.
pub fn parse_size(s: &str) -> Result<f64> {
    let s = s.trim();
    let (num, mult) = match s.chars().last() {
        Some('K') | Some('k') => (&s[..s.len() - 1], 1024.0),
        Some('M') | Some('m') => (&s[..s.len() - 1], 1024.0 * 1024.0),
        Some('G') | Some('g') => (&s[..s.len() - 1], 1024.0 * 1024.0 * 1024.0),
        _ => (s, 1.0),
    };
    let v: f64 = num.parse().with_context(|| format!("bad size '{s}'"))?;
    Ok(v * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("4096").unwrap(), 4096.0);
        assert_eq!(parse_size("64M").unwrap(), 64.0 * 1024.0 * 1024.0);
        assert_eq!(parse_size("1G").unwrap(), 1073741824.0);
        assert!(parse_size("x").is_err());
    }

    #[test]
    fn sim_tables_run_without_artifacts() {
        // Tables 4, 5, 6, 9, 10 depend only on the simulator/codec.
        table4().unwrap();
        table5().unwrap();
        table6().unwrap();
        let args = crate::cli::Args::parse(["table".to_string(), "9".to_string()]).unwrap();
        table9(&args).unwrap();
        table10(&args).unwrap();
    }
}
