//! Figure regenerators (paper Figs. 1, 2, 4, 5, 8). ASCII renderings —
//! the series/values are what matters for the shape comparison.

use anyhow::Result;

use super::{f2, print_table};
use crate::cli::Args;
use crate::comm::{Algo, AlgoPolicy};
use crate::coordinator::pretrain::{ensure_trained, ACCURACY_STEPS, TEST_STEPS};
use crate::coordinator::ttft::{algo_for, ttft_s, PrefillWorkload};
use crate::coordinator::TpEngine;
use crate::model::{Corpus, Sampler};
use crate::quant::Codec;
use crate::runtime::{default_artifacts_dir, Runtime};
use crate::sim;
use crate::topo::{presets, Topology};
use crate::util::stats::{ascii_histogram, DistSummary};

/// The fixed two-step policy the accuracy figures evaluate under.
const TWOSTEP: AlgoPolicy = AlgoPolicy::Fixed(Algo::TwoStep);

/// Fig. 1: perplexity across bit widths for the quantization schemes.
pub fn figure1(args: &Args) -> Result<()> {
    let steps = if args.flag_bool("quick") { TEST_STEPS } else { ACCURACY_STEPS };
    let (cfg, weights, _) = ensure_trained("tiny", steps)?;
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let n = args.flag_usize("batches", if args.flag_bool("quick") { 2 } else { 4 })?;
    let batches: Vec<_> =
        Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len).into_iter().take(n).collect();
    let rt = Runtime::open(default_artifacts_dir())?;
    let mut engine = TpEngine::new(rt, cfg, &weights, Codec::Bf16, TWOSTEP)?;
    let baseline = engine.perplexity(&batches)?;

    let schemes: &[(&str, &str)] = &[
        ("RTN gs128", "int{b}@128"),
        ("RTN gs32", "int{b}@32"),
        ("SpikeReserve gs32", "int{b}-sr@32"),
        ("Hadamard gs32", "int{b}-had@32"),
        ("LogFMT gs32", "int{b}-log@32"),
    ];
    let bits = [8usize, 6, 5, 4, 3, 2];
    let mut rows = Vec::new();
    for (label, fmt) in schemes {
        let mut row = vec![label.to_string()];
        for b in bits {
            let spec = fmt.replace("{b}", &b.to_string());
            engine.set_codec(Codec::parse(&spec)?, TWOSTEP)?;
            let ppl = engine.perplexity(&batches)?;
            eprintln!("  [fig1] {spec}: {ppl:.3}");
            row.push(f2(ppl));
        }
        rows.push(row);
    }
    print_table(
        &format!("Figure 1: perplexity vs comm bitwidth (BF16 baseline {})", f2(baseline)),
        &["scheme", "INT8", "INT6", "INT5", "INT4", "INT3", "INT2"],
        &rows,
    );
    println!("shape check (paper Fig.1): SR flattest to 2-bit; RTN ok to 3; others collapse");
    Ok(())
}

/// Fig. 2: TTFT across devices and precisions (TP=8 prefill).
pub fn figure2(args: &Args) -> Result<()> {
    let wl = PrefillWorkload {
        prompt_len: args.flag_usize("prompt", 1024)?,
        batch: args.flag_usize("batch", 1)?,
        ..Default::default()
    };
    let specs = ["bf16", "int8", "int6", "int5", "int4@32", "int2-sr@32"];
    let mut rows = Vec::new();
    for dev in presets::all() {
        let name = dev.name;
        let topo = Topology::new(dev, 8);
        let base = ttft_s(&topo, &wl, &Codec::Bf16, algo_for(&topo, &wl, &Codec::Bf16));
        let mut row = vec![name.to_string()];
        for s in specs {
            let codec = if s == "bf16" { Codec::Bf16 } else { Codec::parse(s)? };
            let t = ttft_s(&topo, &wl, &codec, algo_for(&topo, &wl, &codec));
            row.push(format!("{:.1}ms ({:.2}x)", t * 1e3, base / t));
        }
        rows.push(row);
    }
    print_table(
        &format!(
            "Figure 2: Llama-3-8B-class TTFT, TP=8, prompt {} (model; see DESIGN §2)",
            wl.prompt_len
        ),
        &["GPU", "BF16", "INT8", "INT6", "INT5", "INT4", "INT2_SR"],
        &rows,
    );
    println!("paper: 2.28x best on L40, 1.24x A100, 1.3x H800, ~1x H20");
    Ok(())
}

/// Fig. 4: activation distribution before/after spike removal.
pub fn figure4(args: &Args) -> Result<()> {
    let steps = if args.flag_bool("quick") { TEST_STEPS } else { ACCURACY_STEPS };
    let (cfg, weights, _) = ensure_trained("tiny", steps)?;
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (_, eval) = corpus.split();
    let batch = &Sampler::eval_batches(eval, cfg.eval_batch, cfg.seq_len)[0];
    let rt = Runtime::open(default_artifacts_dir())?;
    let last = cfg.n_layers - 1;
    let mut engine = TpEngine::new(rt, cfg, &weights, Codec::Bf16, TWOSTEP)?;
    engine.capture_layer = Some(last);
    engine.forward_h(batch)?;
    let acts = engine.last_partial.clone();
    anyhow::ensure!(!acts.is_empty(), "no activations captured");

    // Remove per-group (gs=32) min/max — exactly what spike reserving does.
    let mut body = Vec::with_capacity(acts.len());
    for g in acts.chunks(32) {
        let mn = g.iter().cloned().fold(f32::INFINITY, f32::min);
        let mx = g.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let (mut took_min, mut took_max) = (false, false);
        for &x in g {
            if !took_min && x == mn {
                took_min = true;
            } else if !took_max && x == mx {
                took_max = true;
            } else {
                body.push(x);
            }
        }
    }
    let before = DistSummary::of(&acts);
    let after = DistSummary::of(&body);
    println!("== Figure 4: last-layer MLP partial-sum distribution (the AllReduce volume) ==");
    println!("before spike removal:  range {:>9.3}  std {:>7.3}  kurtosis {:>7.2}",
             before.range(), before.std, before.kurtosis);
    println!("{}", ascii_histogram(&acts, 15, 48));
    println!("after removing per-group (gs=32) min/max spikes:");
    println!("                       range {:>9.3}  std {:>7.3}  kurtosis {:>7.2}",
             after.range(), after.std, after.kurtosis);
    println!("{}", ascii_histogram(&body, 15, 48));
    println!(
        "shape check: range shrinks {:.1}x (paper: 'numerical range substantially narrowed')",
        before.range() / after.range()
    );
    // Reference: the same operation on heavy-tailed activations with
    // massive outliers (the regime of the paper's Llama-3-8B down_proj —
    // our 4M-param model's activations are benign by comparison).
    let mut rng = crate::util::Prng::new(4);
    let mut heavy = vec![0f32; 1 << 15];
    rng.fill_activations(&mut heavy, 1.0);
    let hb = DistSummary::of(&heavy);
    let mut hbody = Vec::new();
    for g in heavy.chunks(32) {
        let mn = g.iter().cloned().fold(f32::INFINITY, f32::min);
        let mx = g.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let (mut tm, mut tx) = (false, false);
        for &x in g {
            if !tm && x == mn { tm = true; } else if !tx && x == mx { tx = true; }
            else { hbody.push(x); }
        }
    }
    let ha = DistSummary::of(&hbody);
    println!(
        "reference (heavy-tailed synthetic, massive-outlier regime): {:.1}x shrink, \
         kurtosis {:.1} -> {:.1}",
        hb.range() / ha.range(), hb.kurtosis, ha.kurtosis
    );
    Ok(())
}

/// Fig. 5: the INT2+SR wire layout for one group.
pub fn figure5() -> Result<()> {
    let mut rng = crate::util::Prng::new(2024);
    let mut data = vec![0f32; 32];
    rng.fill_normal(&mut data, 0.0, 1.0);
    data[5] = -8.5; // min spike
    data[19] = 12.25; // max spike
    let codec = Codec::parse("int2-sr@32!")?;
    let wire = codec.encode(&data);
    let s = codec.sections(32);
    println!("== Figure 5: spike reserving wire layout, one group of 32, INT2 ==");
    println!("input: 32 f32 values with spikes at [5]=-8.5 (min) and [19]=12.25 (max)");
    println!("wire ({} bytes total):", wire.len());
    let mut off = 0;
    for (label, len) in [
        ("header", s.header),
        ("quantized 2-bit codes (bit-split plane)", s.quantized),
        ("scale_int(i8) + zero-point(i8)", s.scale_zero),
        ("spikes: min,max (bf16) + min_idx,max_idx (u8)", s.spikes),
    ] {
        let bytes: Vec<String> =
            wire[off..off + len].iter().map(|b| format!("{b:02x}")).collect();
        println!("  [{off:>3}..{:>3}] {label:<45} {}", off + len, bytes.join(" "));
        off += len;
    }
    let mut out = vec![0f32; 32];
    Codec::decode(&wire, &mut out)?;
    println!("decoded spikes: out[5] = {} out[19] = {}", out[5], out[19]);
    println!("(indices stored as u8, scale via Eq.1 scale_int = floor(log2(scale)*10))");
    Ok(())
}

/// Fig. 8: serial vs pipelined hierarchical execution timeline.
pub fn figure8(args: &Args) -> Result<()> {
    let m = super::tables::parse_size(&args.flag_or("size", "64M"))?;
    let codec = Codec::parse(&args.flag_or("codec", "int5"))?;
    let topo = Topology::new(presets::l40(), 8);
    let chunks = args.flag_usize("chunks", 8)?;
    let tasks = sim::allreduce::hier_pipeline_tasks(&topo, &codec, m, chunks);
    let sched = sim::events::schedule(&tasks, 3);
    let serial = sim::events::serial_makespan(&tasks);
    println!("== Figure 8: hierarchical AllReduce, serial vs pipelined ({} chunks) ==", chunks);
    println!("resources: R/A = intra-NUMA PCIe (RS/AG), X = NUMA bridge, q/d = comm SMs");
    println!("{}", sim::events::render_timeline(&tasks, &sched, &["PCIe", "bridge", "SMs"], 72));
    println!("serial makespan:    {:.3} ms", serial * 1e3);
    println!("pipelined makespan: {:.3} ms  ({:.1}% time saving)",
             sched.makespan * 1e3, (1.0 - sched.makespan / serial) * 100.0);
    for (r, b) in sched.bubbles.iter().enumerate() {
        println!("  bubbles on {}: {:.3} ms", ["PCIe", "bridge", "SMs"][r], b * 1e3);
    }
    println!("\nchunk-count sweep (algorithmic bandwidth, GB/s):");
    let mut rows = Vec::new();
    for k in [1usize, 2, 4, 8, 16, 32] {
        let tasks = sim::allreduce::hier_pipeline_tasks(&topo, &codec, m, k);
        let sched = sim::events::schedule(&tasks, 3);
        rows.push(vec![
            k.to_string(),
            f2(m / sched.makespan / 1e9),
            f2((1.0 - sched.makespan / sim::events::serial_makespan(&tasks)) * 100.0),
        ]);
    }
    print_table("", &["chunks", "algbw GB/s", "saving %"], &rows);
    println!("paper: 'measured to have up to 20% time saving'");
    Ok(())
}
