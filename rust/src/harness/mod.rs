//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (see DESIGN.md §5 for the index). Each entry prints
//! our measured/simulated values next to the paper's reference numbers —
//! the acceptance criterion is *shape* (ordering, approximate factors,
//! crossovers), not absolute equality, since the substrate is a simulator
//! and the models are our own trained checkpoints.

pub mod figures;
pub mod tables;

use anyhow::{bail, Result};

use crate::cli::Args;

/// Dispatch `flashcomm table <n>`.
pub fn run_table(args: &Args) -> Result<()> {
    match args.pos(0)? {
        "1" => tables::table1(args),
        "2" => tables::table2(args),
        "3" => tables::table3(args),
        "4" => tables::table4(),
        "5" => tables::table5(),
        "6" => tables::table6(),
        "7" => tables::table7(args),
        "8" => tables::table8(args),
        "9" => tables::table9(args),
        "10" => tables::table10(args),
        "all" => {
            for t in ["4", "5", "6", "9", "10", "1", "2", "3", "7", "8"] {
                let mut a = args.clone();
                a.positional = vec![t.to_string()];
                run_table(&a)?;
                println!();
            }
            Ok(())
        }
        other => bail!("unknown table '{other}' (1-10 or all)"),
    }
}

/// Dispatch `flashcomm figure <n>`.
pub fn run_figure(args: &Args) -> Result<()> {
    match args.pos(0)? {
        "1" => figures::figure1(args),
        "2" => figures::figure2(args),
        "4" => figures::figure4(args),
        "5" => figures::figure5(),
        "8" => figures::figure8(args),
        "all" => {
            for f in ["5", "8", "2", "4", "1"] {
                let mut a = args.clone();
                a.positional = vec![f.to_string()];
                run_figure(&a)?;
                println!();
            }
            Ok(())
        }
        other => bail!("unknown figure '{other}' (1, 2, 4, 5, 8 or all)"),
    }
}

/// Fixed-width table printer shared by all harnesses.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        s
    };
    println!("{}", line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", line(row));
    }
}

/// Format helper: f64 with sensible precision.
pub fn f2(x: f64) -> String {
    if !x.is_finite() {
        return "-".into();
    }
    if x.abs() >= 1000.0 {
        format!("{:.0}", x)
    } else {
        format!("{:.2}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_printer_does_not_panic() {
        print_table(
            "demo",
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }

    #[test]
    fn f2_formats() {
        assert_eq!(f2(9.666), "9.67");
        assert_eq!(f2(1234.6), "1235");
        assert_eq!(f2(f64::INFINITY), "-");
    }
}
