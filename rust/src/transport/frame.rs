//! Versioned frame codec shared by every transport backend.
//!
//! Each point-to-point payload travels inside one frame:
//!
//! ```text
//! ┌───────────────────── header, 28 B ─────────────────────┐
//! │ magic u32 | ver u8 | flags u8 | src u16 | dst u16      │
//! │ epoch u16 | seq u32 | len u32 | crc32(payload) u32     │
//! │ crc32(header bytes 0..24) u32                          │
//! ├───────────────────── payload ──────────────────────────┤
//! │ len bytes (a `quant::wire` payload for the collectives)│
//! └────────────────────────────────────────────────────────┘
//! ```
//!
//! Everything little-endian. The frame exists so that transport faults fail
//! loudly instead of silently desyncing a collective: a corrupted payload is
//! caught by the payload CRC, a corrupted header by the header CRC (so a
//! flipped `len` bit is an immediate error, not a forever-blocked read of
//! bytes that never come), a cross-version peer by the version byte, and a
//! lost or reordered message by the per-link sequence number (checked by
//! the backends). This is the same versioned-framing discipline as the
//! quant wire header ([`crate::quant::wire`]), one layer down: that header
//! describes *what* the bytes mean, this one guards *that they arrived
//! intact*.
//!
//! This module is the **single source of truth** for every wire constant:
//! flag bits live in [`flags`], byte offsets in [`offsets`], and the
//! `flashcomm lint` R1 rule (wire-constant drift) rejects literal
//! duplicates of any of them elsewhere in the tree. A drifted `0x02` or a
//! restated `10..12` is exactly the kind of silent reassembly corruption
//! the linter exists to make impossible.

use std::ops::Range;

use anyhow::{ensure, Result};

/// Frame magic ("FCT2" on the wire, little-endian).
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"FCT2");
/// Transport protocol version. Bump on any layout change; peers reject
/// mismatches during [`parse`](FrameHeader::parse). Version 2 repurposed
/// the reserved bytes 10..12 as the session **epoch** (see
/// [`crate::session`]): a restarted rank rejoins under a bumped epoch, so a
/// frame from a pre-restart incarnation is rejected instead of silently
/// poisoning the per-link sequence space.
pub const FRAME_VERSION: u8 = 2;

/// Header `flags` bits. These five values are the only place in the tree
/// where the bit assignments may be spelled as literals; everything else
/// (including the reserved-bit check in [`FrameHeader::parse`]) goes
/// through the named constants.
pub mod flags {
    /// Session heartbeat frame (zero-length payload, liveness only —
    /// never delivered to `recv`, never counted).
    pub const HEARTBEAT: u8 = 0x01;
    /// UDP datagram carrying one chunk of a shredded frame: the payload
    /// starts with a segment sub-header (layout in
    /// [`super::offsets`]), and `seq`/`len`/`crc` guard the *datagram*,
    /// not the logical frame it belongs to.
    pub const SEGMENT: u8 = 0x02;
    /// UDP NACK control datagram (receiver → sender: "re-send these
    /// chunks of this frame").
    pub const NACK: u8 = 0x04;
    /// UDP ACK control datagram (receiver → sender: "this frame is fully
    /// delivered — retire it and take an RTT sample").
    pub const ACK: u8 = 0x08;
    /// Clock-sync probe frame (DESIGN.md §15): a 24-byte payload of
    /// three `u64` nanosecond timestamps (layout in
    /// [`super::offsets`]). A request carries `t1` (requester's send
    /// time); the reference rank echoes it back with `t2`/`t3` (its
    /// recv/reply times) filled in. Probe frames travel *nested* as the
    /// payload of an ordinary transport send (`session::sync_clocks`),
    /// so every backend — including InProc, which has no wire frames —
    /// carries them unchanged; the flag bit marks the inner frame so a
    /// mis-routed probe fails parse instead of decoding as data.
    pub const PROBE: u8 = 0x10;
    /// All flag bits this build understands;
    /// [`FrameHeader::parse`](super::FrameHeader::parse) rejects anything
    /// outside this mask so a future layout change fails loudly.
    pub const MASK: u8 = HEARTBEAT | SEGMENT | NACK | ACK | PROBE;
}

/// Compat alias for [`flags::HEARTBEAT`].
pub const FLAG_HEARTBEAT: u8 = flags::HEARTBEAT;
/// Compat alias for [`flags::SEGMENT`].
pub const FLAG_SEGMENT: u8 = flags::SEGMENT;
/// Compat alias for [`flags::NACK`].
pub const FLAG_NACK: u8 = flags::NACK;
/// Compat alias for [`flags::ACK`].
pub const FLAG_ACK: u8 = flags::ACK;
/// Compat alias for [`flags::PROBE`].
pub const FLAG_PROBE: u8 = flags::PROBE;
/// Compat alias for [`flags::MASK`].
pub const FLAG_MASK: u8 = flags::MASK;

/// Byte layout of the frame header and the UDP control payloads. Each
/// constant is a half-open byte range (or a single byte index) into the
/// buffer it describes; [`read_u16`]/[`read_u32`] take them directly.
/// The header ranges must tile `0..FRAME_HEADER_LEN`; the golden tests
/// pin every one of them against the wire bytes.
pub mod offsets {
    use std::ops::Range;

    /// `magic: u32` — [`FRAME_MAGIC`](super::FRAME_MAGIC).
    pub const MAGIC: Range<usize> = 0..4;
    /// `ver: u8` — [`FRAME_VERSION`](super::FRAME_VERSION).
    pub const VERSION: usize = 4;
    /// `flags: u8` — bits from [`flags`](super::flags).
    pub const FLAGS: usize = 5;
    /// `src: u16` — sending rank.
    pub const SRC: Range<usize> = 6..8;
    /// `dst: u16` — destination rank.
    pub const DST: Range<usize> = 8..10;
    /// `epoch: u16` — session epoch (v2 repurposed the reserved bytes).
    pub const EPOCH: Range<usize> = 10..12;
    /// `seq: u32` — per-link sequence number.
    pub const SEQ: Range<usize> = 12..16;
    /// `len: u32` — payload length.
    pub const LEN: Range<usize> = 16..20;
    /// `crc32(payload): u32`.
    pub const PAYLOAD_CRC: Range<usize> = 20..24;
    /// `crc32(header bytes 0..24): u32`.
    pub const HEADER_CRC: Range<usize> = 24..28;
    /// The header prefix covered by [`HEADER_CRC`] (everything before it).
    pub const HEADER_CRC_COVERED: Range<usize> = 0..24;

    /// Segment sub-header (first [`SEG_HEADER_LEN`](super::SEG_HEADER_LEN)
    /// bytes of a [`flags::SEGMENT`](super::flags::SEGMENT) datagram's
    /// payload): `frame_seq: u32`.
    pub const SEG_FRAME_SEQ: Range<usize> = 0..4;
    /// Segment sub-header: `chunk_index: u16`.
    pub const SEG_CHUNK_INDEX: Range<usize> = 4..6;
    /// Segment sub-header: `chunk_count: u16`.
    pub const SEG_CHUNK_COUNT: Range<usize> = 6..8;
    /// Segment sub-header: `frame_len: u32` (whole logical frame).
    pub const SEG_FRAME_LEN: Range<usize> = 8..12;
    /// Segment sub-header: `frame_crc: u32` (whole logical frame).
    pub const SEG_FRAME_CRC: Range<usize> = 12..16;

    /// NACK payload: `frame_seq: u32` being complained about.
    pub const NACK_FRAME_SEQ: Range<usize> = 0..4;
    /// NACK payload: `n: u16` missing-chunk indices follow, `u16` each.
    pub const NACK_COUNT: Range<usize> = 4..6;
    /// ACK payload: `frame_seq: u32` being retired.
    pub const ACK_FRAME_SEQ: Range<usize> = 0..4;

    /// Probe payload: `t1: u64` — the requester's send time, nanos on
    /// its recorder clock (echoed back verbatim by the reference).
    pub const PROBE_T1: Range<usize> = 0..8;
    /// Probe payload: `t2: u64` — the reference's receive time, nanos
    /// on its recorder clock (0 in a request).
    pub const PROBE_T2: Range<usize> = 8..16;
    /// Probe payload: `t3: u64` — the reference's reply time, nanos on
    /// its recorder clock (0 in a request).
    pub const PROBE_T3: Range<usize> = 16..24;
}

/// Fixed header length in bytes (24 B of fields + 4 B header CRC).
pub const FRAME_HEADER_LEN: usize = 28;
/// Segment sub-header length in bytes (see the `SEG_*` ranges in
/// [`offsets`]): `frame_seq u32 | chunk_index u16 | chunk_count u16 |
/// frame_len u32 | frame_crc u32`, prefixed to every chunk of a shredded
/// UDP frame.
pub const SEG_HEADER_LEN: usize = 16;
/// NACK payload fixed prefix length (`frame_seq u32 | n u16`).
pub const NACK_PREFIX_LEN: usize = 6;
/// Clock-probe payload length (`t1 u64 | t2 u64 | t3 u64`; see the
/// `PROBE_*` ranges in [`offsets`]).
pub const PROBE_PAYLOAD_LEN: usize = 24;
/// Upper bound on a single frame's payload (sanity check before the
/// receiver trusts `len` enough to allocate).
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// Read a little-endian `u16` field out of `buf`. `field` is one of the
/// 2-byte ranges in [`offsets`]; the caller must have bounds-checked
/// `buf` against the enclosing layout (every parse path here `ensure!`s
/// the full length before touching a field).
pub fn read_u16(buf: &[u8], field: Range<usize>) -> u16 {
    let mut b = [0u8; 2];
    b.copy_from_slice(&buf[field]);
    u16::from_le_bytes(b)
}

/// Read a little-endian `u32` field out of `buf` (see [`read_u16`]).
pub fn read_u32(buf: &[u8], field: Range<usize>) -> u32 {
    let mut b = [0u8; 4];
    b.copy_from_slice(&buf[field]);
    u32::from_le_bytes(b)
}

/// Read a little-endian `u64` field out of `buf` (see [`read_u16`]).
pub fn read_u64(buf: &[u8], field: Range<usize>) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[field]);
    u64::from_le_bytes(b)
}

/// Parsed frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// Frame flags ([`flags::HEARTBEAT`], [`flags::SEGMENT`],
    /// [`flags::NACK`], [`flags::ACK`]; remaining bits reserved, must
    /// be 0).
    pub flags: u8,
    /// Sending rank.
    pub src: u16,
    /// Destination rank.
    pub dst: u16,
    /// Session epoch the sender believes is current (0 until the first
    /// rejoin bumps it; see [`crate::session`]). Receivers reject frames
    /// whose epoch differs from their own — stale incarnations and
    /// too-new peers both fail loudly.
    pub epoch: u16,
    /// Per-(src→dst)-link sequence number, starting at 0.
    pub seq: u32,
    /// Payload length in bytes.
    pub len: u32,
    /// CRC32 (IEEE) of the payload.
    pub crc: u32,
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC32 (IEEE 802.3 polynomial, reflected), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

impl FrameHeader {
    /// Serialize to the fixed wire layout (including the header CRC).
    pub fn to_bytes(&self) -> [u8; FRAME_HEADER_LEN] {
        let mut hdr = [0u8; FRAME_HEADER_LEN];
        hdr[offsets::MAGIC].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        hdr[offsets::VERSION] = FRAME_VERSION;
        hdr[offsets::FLAGS] = self.flags;
        hdr[offsets::SRC].copy_from_slice(&self.src.to_le_bytes());
        hdr[offsets::DST].copy_from_slice(&self.dst.to_le_bytes());
        hdr[offsets::EPOCH].copy_from_slice(&self.epoch.to_le_bytes());
        hdr[offsets::SEQ].copy_from_slice(&self.seq.to_le_bytes());
        hdr[offsets::LEN].copy_from_slice(&self.len.to_le_bytes());
        hdr[offsets::PAYLOAD_CRC].copy_from_slice(&self.crc.to_le_bytes());
        let hcrc = crc32(&hdr[offsets::HEADER_CRC_COVERED]);
        hdr[offsets::HEADER_CRC].copy_from_slice(&hcrc.to_le_bytes());
        hdr
    }

    /// Serialize the fixed header into `out`.
    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bytes());
    }

    /// Parse and validate the fixed header (magic, version, header CRC,
    /// length bound). The header CRC makes a corrupted `len` an immediate
    /// error rather than a blocked read; the payload CRC is checked
    /// separately once the payload is in hand.
    pub fn parse(buf: &[u8]) -> Result<FrameHeader> {
        ensure!(
            buf.len() >= FRAME_HEADER_LEN,
            "frame truncated: {} bytes < {FRAME_HEADER_LEN}-byte header",
            buf.len()
        );
        let magic = read_u32(buf, offsets::MAGIC);
        ensure!(magic == FRAME_MAGIC, "bad frame magic {magic:#010x} (expected {FRAME_MAGIC:#010x})");
        ensure!(
            buf[offsets::VERSION] == FRAME_VERSION,
            "frame protocol version {} unsupported (this build speaks {FRAME_VERSION})",
            buf[offsets::VERSION]
        );
        let hcrc = read_u32(buf, offsets::HEADER_CRC);
        let got = crc32(&buf[offsets::HEADER_CRC_COVERED]);
        ensure!(
            got == hcrc,
            "frame header CRC mismatch: computed {got:#010x}, header says {hcrc:#010x} \
             (corrupt header rejected)"
        );
        ensure!(
            buf[offsets::FLAGS] & !flags::MASK == 0,
            "frame carries unknown flag bits {:#04x} (this build understands {:#04x})",
            buf[offsets::FLAGS],
            flags::MASK
        );
        let hdr = FrameHeader {
            flags: buf[offsets::FLAGS],
            src: read_u16(buf, offsets::SRC),
            dst: read_u16(buf, offsets::DST),
            epoch: read_u16(buf, offsets::EPOCH),
            seq: read_u32(buf, offsets::SEQ),
            len: read_u32(buf, offsets::LEN),
            crc: read_u32(buf, offsets::PAYLOAD_CRC),
        };
        ensure!(hdr.len <= MAX_PAYLOAD, "frame payload length {} exceeds {MAX_PAYLOAD}", hdr.len);
        Ok(hdr)
    }

    /// Verify `payload` against this header's length and CRC.
    pub fn check_payload(&self, payload: &[u8]) -> Result<()> {
        ensure!(
            payload.len() == self.len as usize,
            "frame length mismatch: header says {} payload bytes, got {}",
            self.len,
            payload.len()
        );
        let got = crc32(payload);
        ensure!(
            got == self.crc,
            "frame CRC mismatch from rank {}: computed {got:#010x}, header says {:#010x} \
             (corrupt payload rejected)",
            self.src,
            self.crc
        );
        Ok(())
    }
}

/// Encode one complete frame (header + payload) into a single buffer.
pub fn encode(src: u16, dst: u16, epoch: u16, seq: u32, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() as u64 <= MAX_PAYLOAD as u64, "payload {} too large", payload.len());
    let hdr = FrameHeader {
        flags: 0,
        src,
        dst,
        epoch,
        seq,
        len: payload.len() as u32,
        crc: crc32(payload),
    };
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    hdr.write(&mut out);
    out.extend_from_slice(payload);
    out
}

/// Encode a zero-payload heartbeat frame ([`flags::HEARTBEAT`] set). The
/// seq rides its own counter on the sender and is never checked by
/// receivers — heartbeats carry liveness and the current epoch, nothing
/// else.
pub fn encode_heartbeat(src: u16, dst: u16, epoch: u16, seq: u32) -> [u8; FRAME_HEADER_LEN] {
    FrameHeader { flags: flags::HEARTBEAT, src, dst, epoch, seq, len: 0, crc: crc32(b"") }
        .to_bytes()
}

/// Encode a clock-sync probe frame ([`flags::PROBE`] set): three `u64`
/// recorder-clock timestamps (DESIGN.md §15). A requester sets only
/// `t1`; the reference echoes `t1` back with `t2`/`t3` filled in. The
/// result travels as the payload of an ordinary transport send, and
/// `seq` counts probes per peer (independent of any link sequence).
pub fn encode_probe(
    src: u16,
    dst: u16,
    epoch: u16,
    seq: u32,
    t1: u64,
    t2: u64,
    t3: u64,
) -> Vec<u8> {
    let mut payload = [0u8; PROBE_PAYLOAD_LEN];
    payload[offsets::PROBE_T1].copy_from_slice(&t1.to_le_bytes());
    payload[offsets::PROBE_T2].copy_from_slice(&t2.to_le_bytes());
    payload[offsets::PROBE_T3].copy_from_slice(&t3.to_le_bytes());
    let hdr = FrameHeader {
        flags: flags::PROBE,
        src,
        dst,
        epoch,
        seq,
        len: PROBE_PAYLOAD_LEN as u32,
        crc: crc32(&payload),
    };
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + PROBE_PAYLOAD_LEN);
    hdr.write(&mut out);
    out.extend_from_slice(&payload);
    out
}

/// Decode a probe frame's timestamps `(t1, t2, t3)` from its bare
/// payload (after the usual header/CRC validation of [`decode`]).
pub fn decode_probe(payload: &[u8]) -> Result<(u64, u64, u64)> {
    ensure!(
        payload.len() == PROBE_PAYLOAD_LEN,
        "probe payload is {} bytes, expected {PROBE_PAYLOAD_LEN}",
        payload.len()
    );
    Ok((
        read_u64(payload, offsets::PROBE_T1),
        read_u64(payload, offsets::PROBE_T2),
        read_u64(payload, offsets::PROBE_T3),
    ))
}

/// Decode a complete frame buffer: validate the header, the exact length,
/// and the payload CRC. On success the buffer is shrunk in place to the
/// bare payload (the header is removed with a memmove of the payload —
/// no reallocation, but not free either; the TCP reader avoids even that
/// by reading header and payload separately).
pub fn decode(mut framed: Vec<u8>) -> Result<(FrameHeader, Vec<u8>)> {
    let hdr = FrameHeader::parse(&framed)?;
    hdr.check_payload(&framed[FRAME_HEADER_LEN..])?;
    framed.drain(..FRAME_HEADER_LEN);
    Ok((hdr, framed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        encode(3, 5, 7, 42, b"flashcomm payload bytes")
    }

    #[test]
    fn crc32_known_vector() {
        // The canonical IEEE CRC32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn wire_constants_pinned() {
        // The named constants ARE the protocol: pin every flag bit and
        // byte offset against the literal wire values so a refactor of
        // the `flags`/`offsets` modules can never silently shift the
        // layout. (Outside this file those literals are lint findings.)
        assert_eq!(flags::HEARTBEAT, 0x01);
        assert_eq!(flags::SEGMENT, 0x02);
        assert_eq!(flags::NACK, 0x04);
        assert_eq!(flags::ACK, 0x08);
        assert_eq!(flags::PROBE, 0x10);
        assert_eq!(flags::MASK, 0x1F);
        assert_eq!(FLAG_HEARTBEAT, flags::HEARTBEAT);
        assert_eq!(FLAG_PROBE, flags::PROBE);
        assert_eq!(FLAG_MASK, flags::MASK);
        assert_eq!(
            [
                offsets::MAGIC,
                offsets::SRC,
                offsets::DST,
                offsets::EPOCH,
                offsets::SEQ,
                offsets::LEN,
                offsets::PAYLOAD_CRC,
                offsets::HEADER_CRC,
            ],
            [0..4, 6..8, 8..10, 10..12, 12..16, 16..20, 20..24, 24..28]
        );
        assert_eq!((offsets::VERSION, offsets::FLAGS), (4, 5));
        assert_eq!(offsets::HEADER_CRC_COVERED, 0..24);
        assert_eq!(
            [
                offsets::SEG_FRAME_SEQ,
                offsets::SEG_CHUNK_INDEX,
                offsets::SEG_CHUNK_COUNT,
                offsets::SEG_FRAME_LEN,
                offsets::SEG_FRAME_CRC,
            ],
            [0..4, 4..6, 6..8, 8..12, 12..16]
        );
        assert_eq!(offsets::SEG_FRAME_CRC.end, SEG_HEADER_LEN);
        assert_eq!((offsets::NACK_FRAME_SEQ, offsets::NACK_COUNT), (0..4, 4..6));
        assert_eq!(offsets::NACK_COUNT.end, NACK_PREFIX_LEN);
        assert_eq!(offsets::ACK_FRAME_SEQ, 0..4);
        assert_eq!(
            [offsets::PROBE_T1, offsets::PROBE_T2, offsets::PROBE_T3],
            [0..8, 8..16, 16..24]
        );
        assert_eq!(offsets::PROBE_T3.end, PROBE_PAYLOAD_LEN);
        // Header field readout through the named offsets matches the
        // hand-assembled layout byte for byte.
        let hdr =
            FrameHeader { flags: 0, src: 3, dst: 5, epoch: 7, seq: 42, len: 9, crc: 0xDEAD_BEEF };
        let bytes = hdr.to_bytes();
        assert_eq!(&bytes[0..4], b"FCT2");
        assert_eq!(bytes[4], FRAME_VERSION);
        assert_eq!(bytes[5], 0);
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), 3);
        assert_eq!(u16::from_le_bytes([bytes[8], bytes[9]]), 5);
        assert_eq!(u16::from_le_bytes([bytes[10], bytes[11]]), 7);
        assert_eq!(u32::from_le_bytes([bytes[12], bytes[13], bytes[14], bytes[15]]), 42);
        assert_eq!(u32::from_le_bytes([bytes[16], bytes[17], bytes[18], bytes[19]]), 9);
        assert_eq!(
            u32::from_le_bytes([bytes[20], bytes[21], bytes[22], bytes[23]]),
            0xDEAD_BEEF
        );
    }

    #[test]
    fn roundtrip() {
        let framed = sample();
        assert_eq!(framed.len(), FRAME_HEADER_LEN + 23);
        let (hdr, payload) = decode(framed).unwrap();
        assert_eq!(payload, b"flashcomm payload bytes");
        assert_eq!(
            hdr,
            FrameHeader {
                flags: 0,
                src: 3,
                dst: 5,
                epoch: 7,
                seq: 42,
                len: 23,
                crc: crc32(b"flashcomm payload bytes"),
            }
        );
    }

    #[test]
    fn empty_payload_roundtrip() {
        let (hdr, payload) = decode(encode(0, 1, 0, 0, b"")).unwrap();
        assert_eq!(hdr.len, 0);
        assert_eq!(hdr.epoch, 0);
        assert!(payload.is_empty());
    }

    #[test]
    fn heartbeat_roundtrip() {
        let hb = encode_heartbeat(2, 6, 9, 1234);
        let hdr = FrameHeader::parse(&hb).unwrap();
        assert_eq!(hdr.flags, flags::HEARTBEAT);
        assert_eq!((hdr.src, hdr.dst, hdr.epoch, hdr.seq, hdr.len), (2, 6, 9, 1234, 0));
        hdr.check_payload(b"").unwrap();
    }

    #[test]
    fn unknown_flag_bits_rejected() {
        let mut bad = sample();
        bad[5] = 0x20; // reserved bit (0x01..0x10 are assigned; see flags::MASK)
        let hcrc = crc32(&bad[..24]);
        bad[24..28].copy_from_slice(&hcrc.to_le_bytes());
        let err = decode(bad).unwrap_err();
        assert!(err.to_string().contains("unknown flag"), "{err}");
    }

    #[test]
    fn probe_roundtrip_carries_three_timestamps() {
        let framed = encode_probe(1, 0, 3, 9, 111, 0, 0);
        let (hdr, payload) = decode(framed).unwrap();
        assert_eq!(hdr.flags, flags::PROBE);
        assert_eq!((hdr.src, hdr.dst, hdr.epoch, hdr.seq), (1, 0, 3, 9));
        assert_eq!(decode_probe(&payload).unwrap(), (111, 0, 0));
        // The reference's reply echoes t1 and fills in t2/t3.
        let reply = encode_probe(0, 1, 3, 9, 111, 222, 333);
        let (_, payload) = decode(reply).unwrap();
        assert_eq!(decode_probe(&payload).unwrap(), (111, 222, 333));
        // A truncated or oversized probe payload fails loudly.
        assert!(decode_probe(&payload[..16]).is_err());
        assert!(decode_probe(&[0u8; 32]).is_err());
    }

    #[test]
    fn every_truncation_is_a_clean_error() {
        let framed = sample();
        for cut in 0..framed.len() {
            assert!(decode(framed[..cut].to_vec()).is_err(), "cut {cut} must error");
        }
    }

    #[test]
    fn payload_corruption_is_a_crc_error() {
        let framed = sample();
        for i in FRAME_HEADER_LEN..framed.len() {
            let mut bad = framed.clone();
            bad[i] ^= 0x01;
            let err = decode(bad).unwrap_err();
            assert!(err.to_string().contains("CRC"), "byte {i}: {err}");
        }
    }

    #[test]
    fn crc_field_corruption_is_a_crc_error() {
        let mut bad = sample();
        bad[20] ^= 0xFF; // payload-crc field itself (caught by the header CRC)
        assert!(decode(bad).unwrap_err().to_string().contains("CRC"));
    }

    #[test]
    fn header_field_corruption_is_caught_by_header_crc() {
        // src, dst, epoch, seq, len, payload-crc: a single flipped bit in
        // any of them must error immediately — in particular a corrupted
        // `len` must never make a reader wait for bytes that don't exist.
        for i in [6usize, 8, 10, 11, 12, 16, 19, 20] {
            let mut bad = sample();
            bad[i] ^= 0x04;
            let err = decode(bad).unwrap_err();
            assert!(err.to_string().contains("header CRC"), "byte {i}: {err}");
        }
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut bad = sample();
        bad[4] = FRAME_VERSION + 1;
        let err = decode(bad).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn magic_mismatch_rejected() {
        let mut bad = sample();
        bad[0] ^= 0xFF;
        assert!(decode(bad).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn length_mismatch_rejected() {
        // Header says more bytes than present (a short write / split read).
        let framed = sample();
        let trimmed = framed[..framed.len() - 3].to_vec();
        assert!(decode(trimmed).unwrap_err().to_string().contains("length mismatch"));

        // Trailing garbage after the declared payload is also rejected.
        let mut long = sample();
        long.extend_from_slice(b"xx");
        assert!(decode(long).unwrap_err().to_string().contains("length mismatch"));
    }

    #[test]
    fn absurd_length_rejected_before_allocation() {
        // Even a header whose CRC *checks out* (a hostile or buggy peer,
        // not line noise) must not make the receiver allocate gigabytes.
        let mut framed = sample();
        framed[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let hcrc = crc32(&framed[..24]);
        framed[24..28].copy_from_slice(&hcrc.to_le_bytes());
        assert!(FrameHeader::parse(&framed).unwrap_err().to_string().contains("exceeds"));
    }
}
