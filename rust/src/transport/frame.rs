//! Versioned frame codec shared by every transport backend.
//!
//! Each point-to-point payload travels inside one frame:
//!
//! ```text
//! ┌───────────────────── header, 28 B ─────────────────────┐
//! │ magic u32 | ver u8 | flags u8 | src u16 | dst u16      │
//! │ epoch u16 | seq u32 | len u32 | crc32(payload) u32     │
//! │ crc32(header bytes 0..24) u32                          │
//! ├───────────────────── payload ──────────────────────────┤
//! │ len bytes (a `quant::wire` payload for the collectives)│
//! └────────────────────────────────────────────────────────┘
//! ```
//!
//! Everything little-endian. The frame exists so that transport faults fail
//! loudly instead of silently desyncing a collective: a corrupted payload is
//! caught by the payload CRC, a corrupted header by the header CRC (so a
//! flipped `len` bit is an immediate error, not a forever-blocked read of
//! bytes that never come), a cross-version peer by the version byte, and a
//! lost or reordered message by the per-link sequence number (checked by
//! the backends). This is the same versioned-framing discipline as the
//! quant wire header ([`crate::quant::wire`]), one layer down: that header
//! describes *what* the bytes mean, this one guards *that they arrived
//! intact*.

use anyhow::{ensure, Result};

/// Frame magic ("FCT2" on the wire, little-endian).
pub const FRAME_MAGIC: u32 = u32::from_le_bytes(*b"FCT2");
/// Transport protocol version. Bump on any layout change; peers reject
/// mismatches during [`parse`](FrameHeader::parse). Version 2 repurposed
/// the reserved bytes 10..12 as the session **epoch** (see
/// [`crate::session`]): a restarted rank rejoins under a bumped epoch, so a
/// frame from a pre-restart incarnation is rejected instead of silently
/// poisoning the per-link sequence space.
pub const FRAME_VERSION: u8 = 2;
/// Header `flags` bit marking a session heartbeat frame (zero-length
/// payload, liveness only — never delivered to `recv`, never counted).
pub const FLAG_HEARTBEAT: u8 = 0x01;
/// Header `flags` bit marking a UDP datagram that carries one chunk of a
/// shredded frame: the payload starts with a segment sub-header (see
/// `transport::udp`), and `seq`/`len`/`crc` guard the *datagram*, not the
/// logical frame it belongs to.
pub const FLAG_SEGMENT: u8 = 0x02;
/// Header `flags` bit marking a UDP NACK control datagram (receiver →
/// sender: "re-send these chunks of this frame").
pub const FLAG_NACK: u8 = 0x04;
/// Header `flags` bit marking a UDP ACK control datagram (receiver →
/// sender: "this frame is fully delivered — retire it and take an RTT
/// sample").
pub const FLAG_ACK: u8 = 0x08;
/// All flag bits this build understands; [`FrameHeader::parse`] rejects
/// anything outside this mask so a future layout change fails loudly.
pub const FLAG_MASK: u8 = FLAG_HEARTBEAT | FLAG_SEGMENT | FLAG_NACK | FLAG_ACK;
/// Fixed header length in bytes (24 B of fields + 4 B header CRC).
pub const FRAME_HEADER_LEN: usize = 28;
/// Upper bound on a single frame's payload (sanity check before the
/// receiver trusts `len` enough to allocate).
pub const MAX_PAYLOAD: u32 = 1 << 30;

/// Parsed frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameHeader {
    /// Frame flags ([`FLAG_HEARTBEAT`], [`FLAG_SEGMENT`], [`FLAG_NACK`],
    /// [`FLAG_ACK`]; remaining bits reserved, must be 0).
    pub flags: u8,
    /// Sending rank.
    pub src: u16,
    /// Destination rank.
    pub dst: u16,
    /// Session epoch the sender believes is current (0 until the first
    /// rejoin bumps it; see [`crate::session`]). Receivers reject frames
    /// whose epoch differs from their own — stale incarnations and
    /// too-new peers both fail loudly.
    pub epoch: u16,
    /// Per-(src→dst)-link sequence number, starting at 0.
    pub seq: u32,
    /// Payload length in bytes.
    pub len: u32,
    /// CRC32 (IEEE) of the payload.
    pub crc: u32,
}

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC32 (IEEE 802.3 polynomial, reflected), table-driven.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

impl FrameHeader {
    /// Serialize to the fixed wire layout (including the header CRC).
    pub fn to_bytes(&self) -> [u8; FRAME_HEADER_LEN] {
        let mut hdr = [0u8; FRAME_HEADER_LEN];
        hdr[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
        hdr[4] = FRAME_VERSION;
        hdr[5] = self.flags;
        hdr[6..8].copy_from_slice(&self.src.to_le_bytes());
        hdr[8..10].copy_from_slice(&self.dst.to_le_bytes());
        hdr[10..12].copy_from_slice(&self.epoch.to_le_bytes());
        hdr[12..16].copy_from_slice(&self.seq.to_le_bytes());
        hdr[16..20].copy_from_slice(&self.len.to_le_bytes());
        hdr[20..24].copy_from_slice(&self.crc.to_le_bytes());
        let hcrc = crc32(&hdr[..24]);
        hdr[24..28].copy_from_slice(&hcrc.to_le_bytes());
        hdr
    }

    /// Serialize the fixed header into `out`.
    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_bytes());
    }

    /// Parse and validate the fixed header (magic, version, header CRC,
    /// length bound). The header CRC makes a corrupted `len` an immediate
    /// error rather than a blocked read; the payload CRC is checked
    /// separately once the payload is in hand.
    pub fn parse(buf: &[u8]) -> Result<FrameHeader> {
        ensure!(
            buf.len() >= FRAME_HEADER_LEN,
            "frame truncated: {} bytes < {FRAME_HEADER_LEN}-byte header",
            buf.len()
        );
        let magic = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
        ensure!(magic == FRAME_MAGIC, "bad frame magic {magic:#010x} (expected {FRAME_MAGIC:#010x})");
        ensure!(
            buf[4] == FRAME_VERSION,
            "frame protocol version {} unsupported (this build speaks {FRAME_VERSION})",
            buf[4]
        );
        let hcrc = u32::from_le_bytes([buf[24], buf[25], buf[26], buf[27]]);
        let got = crc32(&buf[..24]);
        ensure!(
            got == hcrc,
            "frame header CRC mismatch: computed {got:#010x}, header says {hcrc:#010x} \
             (corrupt header rejected)"
        );
        ensure!(
            buf[5] & !FLAG_MASK == 0,
            "frame carries unknown flag bits {:#04x} (this build understands {FLAG_MASK:#04x})",
            buf[5]
        );
        let hdr = FrameHeader {
            flags: buf[5],
            src: u16::from_le_bytes([buf[6], buf[7]]),
            dst: u16::from_le_bytes([buf[8], buf[9]]),
            epoch: u16::from_le_bytes([buf[10], buf[11]]),
            seq: u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]),
            len: u32::from_le_bytes([buf[16], buf[17], buf[18], buf[19]]),
            crc: u32::from_le_bytes([buf[20], buf[21], buf[22], buf[23]]),
        };
        ensure!(hdr.len <= MAX_PAYLOAD, "frame payload length {} exceeds {MAX_PAYLOAD}", hdr.len);
        Ok(hdr)
    }

    /// Verify `payload` against this header's length and CRC.
    pub fn check_payload(&self, payload: &[u8]) -> Result<()> {
        ensure!(
            payload.len() == self.len as usize,
            "frame length mismatch: header says {} payload bytes, got {}",
            self.len,
            payload.len()
        );
        let got = crc32(payload);
        ensure!(
            got == self.crc,
            "frame CRC mismatch from rank {}: computed {got:#010x}, header says {:#010x} \
             (corrupt payload rejected)",
            self.src,
            self.crc
        );
        Ok(())
    }
}

/// Encode one complete frame (header + payload) into a single buffer.
pub fn encode(src: u16, dst: u16, epoch: u16, seq: u32, payload: &[u8]) -> Vec<u8> {
    assert!(payload.len() as u64 <= MAX_PAYLOAD as u64, "payload {} too large", payload.len());
    let hdr = FrameHeader {
        flags: 0,
        src,
        dst,
        epoch,
        seq,
        len: payload.len() as u32,
        crc: crc32(payload),
    };
    let mut out = Vec::with_capacity(FRAME_HEADER_LEN + payload.len());
    hdr.write(&mut out);
    out.extend_from_slice(payload);
    out
}

/// Encode a zero-payload heartbeat frame ([`FLAG_HEARTBEAT`] set). The seq
/// rides its own counter on the sender and is never checked by receivers —
/// heartbeats carry liveness and the current epoch, nothing else.
pub fn encode_heartbeat(src: u16, dst: u16, epoch: u16, seq: u32) -> [u8; FRAME_HEADER_LEN] {
    FrameHeader { flags: FLAG_HEARTBEAT, src, dst, epoch, seq, len: 0, crc: crc32(b"") }.to_bytes()
}

/// Decode a complete frame buffer: validate the header, the exact length,
/// and the payload CRC. On success the buffer is shrunk in place to the
/// bare payload (the header is removed with a memmove of the payload —
/// no reallocation, but not free either; the TCP reader avoids even that
/// by reading header and payload separately).
pub fn decode(mut framed: Vec<u8>) -> Result<(FrameHeader, Vec<u8>)> {
    let hdr = FrameHeader::parse(&framed)?;
    hdr.check_payload(&framed[FRAME_HEADER_LEN..])?;
    framed.drain(..FRAME_HEADER_LEN);
    Ok((hdr, framed))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<u8> {
        encode(3, 5, 7, 42, b"flashcomm payload bytes")
    }

    #[test]
    fn crc32_known_vector() {
        // The canonical IEEE CRC32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip() {
        let framed = sample();
        assert_eq!(framed.len(), FRAME_HEADER_LEN + 23);
        let (hdr, payload) = decode(framed).unwrap();
        assert_eq!(payload, b"flashcomm payload bytes");
        assert_eq!(
            hdr,
            FrameHeader {
                flags: 0,
                src: 3,
                dst: 5,
                epoch: 7,
                seq: 42,
                len: 23,
                crc: crc32(b"flashcomm payload bytes"),
            }
        );
    }

    #[test]
    fn empty_payload_roundtrip() {
        let (hdr, payload) = decode(encode(0, 1, 0, 0, b"")).unwrap();
        assert_eq!(hdr.len, 0);
        assert_eq!(hdr.epoch, 0);
        assert!(payload.is_empty());
    }

    #[test]
    fn heartbeat_roundtrip() {
        let hb = encode_heartbeat(2, 6, 9, 1234);
        let hdr = FrameHeader::parse(&hb).unwrap();
        assert_eq!(hdr.flags, FLAG_HEARTBEAT);
        assert_eq!((hdr.src, hdr.dst, hdr.epoch, hdr.seq, hdr.len), (2, 6, 9, 1234, 0));
        hdr.check_payload(b"").unwrap();
    }

    #[test]
    fn unknown_flag_bits_rejected() {
        let mut bad = sample();
        bad[5] = 0x10; // reserved bit (0x01..0x08 are assigned; see FLAG_MASK)
        let hcrc = crc32(&bad[..24]);
        bad[24..28].copy_from_slice(&hcrc.to_le_bytes());
        let err = decode(bad).unwrap_err();
        assert!(err.to_string().contains("unknown flag"), "{err}");
    }

    #[test]
    fn every_truncation_is_a_clean_error() {
        let framed = sample();
        for cut in 0..framed.len() {
            assert!(decode(framed[..cut].to_vec()).is_err(), "cut {cut} must error");
        }
    }

    #[test]
    fn payload_corruption_is_a_crc_error() {
        let framed = sample();
        for i in FRAME_HEADER_LEN..framed.len() {
            let mut bad = framed.clone();
            bad[i] ^= 0x01;
            let err = decode(bad).unwrap_err();
            assert!(err.to_string().contains("CRC"), "byte {i}: {err}");
        }
    }

    #[test]
    fn crc_field_corruption_is_a_crc_error() {
        let mut bad = sample();
        bad[20] ^= 0xFF; // payload-crc field itself (caught by the header CRC)
        assert!(decode(bad).unwrap_err().to_string().contains("CRC"));
    }

    #[test]
    fn header_field_corruption_is_caught_by_header_crc() {
        // src, dst, epoch, seq, len, payload-crc: a single flipped bit in
        // any of them must error immediately — in particular a corrupted
        // `len` must never make a reader wait for bytes that don't exist.
        for i in [6usize, 8, 10, 11, 12, 16, 19, 20] {
            let mut bad = sample();
            bad[i] ^= 0x04;
            let err = decode(bad).unwrap_err();
            assert!(err.to_string().contains("header CRC"), "byte {i}: {err}");
        }
    }

    #[test]
    fn version_mismatch_rejected() {
        let mut bad = sample();
        bad[4] = FRAME_VERSION + 1;
        let err = decode(bad).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn magic_mismatch_rejected() {
        let mut bad = sample();
        bad[0] ^= 0xFF;
        assert!(decode(bad).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn length_mismatch_rejected() {
        // Header says more bytes than present (a short write / split read).
        let framed = sample();
        let trimmed = framed[..framed.len() - 3].to_vec();
        assert!(decode(trimmed).unwrap_err().to_string().contains("length mismatch"));

        // Trailing garbage after the declared payload is also rejected.
        let mut long = sample();
        long.extend_from_slice(b"xx");
        assert!(decode(long).unwrap_err().to_string().contains("length mismatch"));
    }

    #[test]
    fn absurd_length_rejected_before_allocation() {
        // Even a header whose CRC *checks out* (a hostile or buggy peer,
        // not line noise) must not make the receiver allocate gigabytes.
        let mut framed = sample();
        framed[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let hcrc = crc32(&framed[..24]);
        framed[24..28].copy_from_slice(&hcrc.to_le_bytes());
        assert!(FrameHeader::parse(&framed).unwrap_err().to_string().contains("exceeds"));
    }
}
