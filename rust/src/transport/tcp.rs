//! Multi-process TCP transport with a root rendezvous bootstrap.
//!
//! Bootstrap ([`TcpTransport::bootstrap`]):
//!
//! 1. every rank binds a *data listener* on an ephemeral port;
//! 2. rank 0 binds the well-known *rendezvous* address; ranks `1..n`
//!    connect to it (retrying while worker processes race to start) and
//!    send one `hello <rank> <addr>\n` line advertising their data listener;
//! 3. the root replies to every rank (and itself) with the full
//!    rank→address map, `peers <n>\n` + `<rank> <addr>\n` lines;
//! 4. full-mesh setup: rank `r` *connects* to the data listener of every
//!    rank `< r` and *accepts* a connection from every rank `> r`; a
//!    fixed-size binary hello identifies the connecting rank — one socket
//!    per unordered pair, used bidirectionally;
//! 5. one reader thread per peer pulls frames off the socket, validates
//!    magic/version/route/sequence/CRC ([`super::frame`]), and queues the
//!    verified payloads for [`Transport::recv`].
//!
//! Because reader threads drain sockets independently of when the owning
//! rank calls `recv`, a rank can post all its sends before touching a
//! single receive (the collectives' one-shot exchange pattern) without
//! deadlocking on TCP buffer backpressure.
//!
//! The rendezvous control plane is line-oriented text (bootstrap only);
//! the data plane is exclusively framed binary. See `DESIGN.md` §4.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::{frame, Transport, TransportCounters, TransportStats};

/// How long bootstrap keeps retrying connects / polling accepts while the
/// other worker processes come up.
const BOOTSTRAP_TIMEOUT: Duration = Duration::from_secs(20);

/// Data-plane hello: magic + the connecting rank, sent once per connection.
const HELLO_MAGIC: u32 = u32::from_le_bytes(*b"FCHL");
const HELLO_LEN: usize = 6;

/// Default data-listener bind address: loopback (single-node jobs).
pub const DEFAULT_BIND: IpAddr = IpAddr::V4(Ipv4Addr::LOCALHOST);

/// A peer link's stream of frame-verified payloads (or the first error).
type Inbox = Receiver<Result<Vec<u8>>>;

/// One rank's endpoint of a multi-process TCP mesh.
pub struct TcpTransport {
    rank: usize,
    n: usize,
    /// Write half of the socket to each peer (None at the self index).
    writers: Vec<Option<Mutex<TcpStream>>>,
    /// Frame-verified payloads from each peer (None at the self index).
    inbox: Vec<Option<Inbox>>,
    send_seq: Vec<AtomicU32>,
    /// Shared with the per-peer reader threads, which account the
    /// receive-queue occupancy (`buffered_bytes`) they create.
    counters: Arc<TransportCounters>,
}

impl TcpTransport {
    /// Rendezvous + full-mesh bootstrap. `root` is the rank-0 rendezvous
    /// address (e.g. `127.0.0.1:29555`), identical across all ranks. Data
    /// listeners bind loopback; see [`TcpTransport::bootstrap_bound`] for
    /// the multi-node bind address.
    pub fn bootstrap(rank: usize, n: usize, root: &str) -> Result<TcpTransport> {
        TcpTransport::bootstrap_bound_with(rank, n, root, None, DEFAULT_BIND)
    }

    /// [`TcpTransport::bootstrap`] with an explicit *data-listener* bind
    /// address (the CLI's `--bind`, DESIGN.md §4's extension point): the
    /// per-rank data sockets bind `(bind, ephemeral)` and advertise that
    /// address through the rendezvous, so peers on other hosts can dial
    /// in when `bind` is a routable interface IP. The default stays
    /// loopback. An unspecified address (`0.0.0.0` / `::`) is rejected —
    /// it would be advertised verbatim and peers cannot dial it.
    pub fn bootstrap_bound(rank: usize, n: usize, root: &str, bind: IpAddr) -> Result<TcpTransport> {
        TcpTransport::bootstrap_bound_with(rank, n, root, None, bind)
    }

    /// Like [`TcpTransport::bootstrap`], but rank 0 may supply an
    /// already-bound rendezvous listener (lets tests pick an ephemeral
    /// port without a bind race).
    pub fn bootstrap_with(
        rank: usize,
        n: usize,
        root: &str,
        root_listener: Option<TcpListener>,
    ) -> Result<TcpTransport> {
        TcpTransport::bootstrap_bound_with(rank, n, root, root_listener, DEFAULT_BIND)
    }

    /// Full-control bootstrap: rendezvous listener override + data bind
    /// address (see [`TcpTransport::bootstrap_bound`]).
    pub fn bootstrap_bound_with(
        rank: usize,
        n: usize,
        root: &str,
        root_listener: Option<TcpListener>,
        bind: IpAddr,
    ) -> Result<TcpTransport> {
        ensure!(n >= 1, "world size must be at least 1");
        ensure!(rank < n, "rank {rank} out of range for world size {n}");
        ensure!(n <= u16::MAX as usize, "rank ids must fit the frame header");
        ensure!(
            !bind.is_unspecified(),
            "--bind {bind} is unspecified: peers would be told to dial {bind}, which no \
             host routes — bind a concrete interface IP instead"
        );

        // 1. Data listener for the full-mesh phase, on the requested
        // interface (loopback unless the job spans hosts). The advertised
        // address is exactly what was bound, so whatever `bind` names must
        // be reachable by every peer.
        let data_listener =
            TcpListener::bind((bind, 0)).with_context(|| format!("binding data listener on {bind}"))?;
        let my_addr = data_listener.local_addr().context("data listener addr")?;

        // 2+3. Rendezvous: learn every rank's data address.
        let addrs = if rank == 0 {
            let listener = match root_listener {
                Some(l) => l,
                None => TcpListener::bind(root)
                    .with_context(|| format!("rank 0 binding rendezvous address {root}"))?,
            };
            rendezvous_root(&listener, n, my_addr)?
        } else {
            rendezvous_client(rank, n, root, my_addr)?
        };

        // 4. Full mesh: connect down, accept up.
        let mut sockets: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for peer in 0..rank {
            let stream = connect_retry(addrs[peer])
                .with_context(|| format!("rank {rank} dialing rank {peer} at {}", addrs[peer]))?;
            write_hello(&stream, rank)?;
            sockets[peer] = Some(stream);
        }
        let deadline = Instant::now() + BOOTSTRAP_TIMEOUT;
        for _ in rank + 1..n {
            let (stream, _) = accept_deadline(&data_listener, deadline)
                .with_context(|| format!("rank {rank} waiting for higher-rank dials"))?;
            let peer = read_hello(&stream)?;
            ensure!(peer > rank && peer < n, "unexpected hello from rank {peer} at rank {rank}");
            ensure!(sockets[peer].is_none(), "rank {peer} connected twice");
            sockets[peer] = Some(stream);
        }

        // 5. Split each socket: reader thread (validates frames) + writer.
        let counters = Arc::new(TransportCounters::default());
        let mut writers: Vec<Option<Mutex<TcpStream>>> = (0..n).map(|_| None).collect();
        let mut inbox: Vec<Option<Inbox>> = (0..n).map(|_| None).collect();
        for (peer, slot) in sockets.into_iter().enumerate() {
            let Some(stream) = slot else { continue };
            stream.set_nodelay(true).context("setting TCP_NODELAY")?;
            let read_half = stream.try_clone().context("cloning socket for reader")?;
            let (tx, rx) = channel();
            let reader_counters = counters.clone();
            thread::Builder::new()
                .name(format!("tcp-rx-{rank}<-{peer}"))
                .spawn(move || reader_loop(read_half, peer, rank, tx, reader_counters))
                .context("spawning reader thread")?;
            writers[peer] = Some(Mutex::new(stream));
            inbox[peer] = Some(rx);
        }

        Ok(TcpTransport {
            rank,
            n,
            writers,
            inbox,
            send_seq: (0..n).map(|_| AtomicU32::new(0)).collect(),
            counters,
        })
    }
}

impl Drop for TcpTransport {
    /// Shut the sockets down (not just close this handle's fds): the
    /// reader threads hold dups of the same sockets and would otherwise
    /// block on `read` forever, leaking one thread + fd per peer. Shutdown
    /// still flushes written data (FIN follows it), so a peer mid-`recv`
    /// receives everything already sent.
    fn drop(&mut self) {
        for writer in self.writers.iter().flatten() {
            if let Ok(stream) = writer.lock() {
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, dst: usize, payload: Vec<u8>) -> Result<()> {
        ensure!(dst < self.n, "dst rank {dst} out of range (n = {})", self.n);
        ensure!(dst != self.rank, "self-send is a local copy, not a transfer");
        let seq = self.send_seq[dst].fetch_add(1, Ordering::Relaxed);
        self.counters.record_send(payload.len());
        let framed = frame::encode(self.rank as u16, dst as u16, seq, &payload);
        let writer = self.writers[dst].as_ref().expect("mesh invariant: peer socket exists");
        let mut stream = writer.lock().map_err(|_| anyhow!("writer to rank {dst} poisoned"))?;
        stream
            .write_all(&framed)
            .with_context(|| format!("sending {} wire bytes to rank {dst}", framed.len()))?;
        Ok(())
    }

    fn recv(&self, src: usize) -> Result<Vec<u8>> {
        ensure!(src < self.n, "src rank {src} out of range (n = {})", self.n);
        ensure!(src != self.rank, "self-recv is a local copy, not a transfer");
        let rx = self.inbox[src].as_ref().expect("mesh invariant: peer inbox exists");
        match rx.recv() {
            Ok(result) => {
                if let Ok(payload) = &result {
                    self.counters.record_drained(payload.len());
                }
                result
            }
            Err(_) => bail!("rank {src} disconnected"),
        }
    }

    fn stats(&self) -> TransportStats {
        self.counters.snapshot()
    }
}

/// Root side of the rendezvous: collect `hello` lines from ranks `1..n`,
/// then broadcast the full rank→address map.
fn rendezvous_root(listener: &TcpListener, n: usize, my_addr: SocketAddr) -> Result<Vec<SocketAddr>> {
    let mut addrs: Vec<Option<SocketAddr>> = vec![None; n];
    addrs[0] = Some(my_addr);
    let deadline = Instant::now() + BOOTSTRAP_TIMEOUT;
    let mut clients: Vec<(usize, TcpStream)> = Vec::with_capacity(n.saturating_sub(1));
    while clients.len() + 1 < n {
        let (stream, _) = accept_deadline(listener, deadline)
            .context("rendezvous root waiting for workers")?;
        let mut reader = BufReader::new(stream.try_clone().context("cloning rendezvous socket")?);
        let mut line = String::new();
        reader.read_line(&mut line).context("reading hello line")?;
        let mut parts = line.split_whitespace();
        ensure!(parts.next() == Some("hello"), "malformed rendezvous hello: {line:?}");
        let peer: usize = parts
            .next()
            .ok_or_else(|| anyhow!("hello missing rank: {line:?}"))?
            .parse()
            .with_context(|| format!("hello rank in {line:?}"))?;
        let addr: SocketAddr = parts
            .next()
            .ok_or_else(|| anyhow!("hello missing address: {line:?}"))?
            .parse()
            .with_context(|| format!("hello address in {line:?}"))?;
        ensure!(peer >= 1 && peer < n, "hello from out-of-range rank {peer} (n = {n})");
        ensure!(addrs[peer].is_none(), "two workers claim rank {peer}");
        addrs[peer] = Some(addr);
        clients.push((peer, stream));
    }
    let map: Vec<SocketAddr> = addrs.into_iter().map(|a| a.expect("all ranks seen")).collect();
    let mut reply = format!("peers {n}\n");
    for (r, a) in map.iter().enumerate() {
        reply.push_str(&format!("{r} {a}\n"));
    }
    for (peer, mut stream) in clients {
        stream
            .write_all(reply.as_bytes())
            .with_context(|| format!("sending peer map to rank {peer}"))?;
    }
    Ok(map)
}

/// Worker side of the rendezvous: announce our data address, receive the
/// full rank→address map.
fn rendezvous_client(
    rank: usize,
    n: usize,
    root: &str,
    my_addr: SocketAddr,
) -> Result<Vec<SocketAddr>> {
    // to_socket_addrs (not str::parse) so hostname roots like
    // `localhost:29555` work — TcpListener::bind on the root side accepts
    // them, so the client side must too.
    let root_addr: SocketAddr = root
        .to_socket_addrs()
        .with_context(|| format!("resolving rendezvous address {root:?}"))?
        .next()
        .ok_or_else(|| anyhow!("rendezvous address {root:?} resolved to no addresses"))?;
    let stream = connect_retry(root_addr)
        .with_context(|| format!("rank {rank} reaching rendezvous root {root}"))?;
    let mut writer = stream.try_clone().context("cloning rendezvous socket")?;
    writer
        .write_all(format!("hello {rank} {my_addr}\n").as_bytes())
        .context("sending hello")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading peer-map header")?;
    let mut parts = line.split_whitespace();
    ensure!(parts.next() == Some("peers"), "malformed peer map header: {line:?}");
    let got_n: usize = parts
        .next()
        .ok_or_else(|| anyhow!("peer map header missing count: {line:?}"))?
        .parse()
        .with_context(|| format!("peer count in {line:?}"))?;
    ensure!(got_n == n, "root says world size {got_n}, this worker was launched with {n}");
    let mut addrs: Vec<Option<SocketAddr>> = vec![None; n];
    for _ in 0..n {
        let mut entry = String::new();
        reader.read_line(&mut entry).context("reading peer map entry")?;
        let mut parts = entry.split_whitespace();
        let r: usize = parts
            .next()
            .ok_or_else(|| anyhow!("peer entry missing rank: {entry:?}"))?
            .parse()
            .with_context(|| format!("peer rank in {entry:?}"))?;
        let a: SocketAddr = parts
            .next()
            .ok_or_else(|| anyhow!("peer entry missing address: {entry:?}"))?
            .parse()
            .with_context(|| format!("peer address in {entry:?}"))?;
        ensure!(r < n && addrs[r].is_none(), "bad peer map entry {entry:?}");
        addrs[r] = Some(a);
    }
    ensure!(addrs[rank] == Some(my_addr), "root recorded a different address for rank {rank}");
    Ok(addrs.into_iter().map(|a| a.expect("map complete")).collect())
}

/// Connect with retry until [`BOOTSTRAP_TIMEOUT`] (peers race to bind).
fn connect_retry(addr: SocketAddr) -> Result<TcpStream> {
    let deadline = Instant::now() + BOOTSTRAP_TIMEOUT;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!(e)).context(format!("connecting to {addr} timed out"));
            }
            Err(_) => thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Accept with a deadline (the listener is switched to non-blocking polling
/// so a missing peer fails the bootstrap instead of hanging it).
fn accept_deadline(listener: &TcpListener, deadline: Instant) -> Result<(TcpStream, SocketAddr)> {
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let result = loop {
        match listener.accept() {
            Ok(pair) => break Ok(pair),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    break Err(anyhow!("timed out waiting for a peer to connect"));
                }
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => break Err(anyhow!(e)).context("accepting peer connection"),
        }
    };
    listener.set_nonblocking(false).context("listener blocking")?;
    let (stream, addr) = result?;
    stream.set_nonblocking(false).context("stream blocking")?;
    Ok((stream, addr))
}

fn write_hello(mut stream: &TcpStream, rank: usize) -> Result<()> {
    let mut hello = [0u8; HELLO_LEN];
    hello[..4].copy_from_slice(&HELLO_MAGIC.to_le_bytes());
    hello[4..].copy_from_slice(&(rank as u16).to_le_bytes());
    stream.write_all(&hello).context("sending data-plane hello")?;
    Ok(())
}

fn read_hello(mut stream: &TcpStream) -> Result<usize> {
    let mut hello = [0u8; HELLO_LEN];
    stream.read_exact(&mut hello).context("reading data-plane hello")?;
    let magic = u32::from_le_bytes([hello[0], hello[1], hello[2], hello[3]]);
    ensure!(magic == HELLO_MAGIC, "bad data-plane hello magic {magic:#010x}");
    Ok(u16::from_le_bytes([hello[4], hello[5]]) as usize)
}

/// Per-peer reader: pull frames off the socket, validate, queue payloads.
/// Exits on clean EOF (peer shut down), on a validation error (reported to
/// the owning rank through the inbox), or when the owner dropped the inbox.
/// Queued payloads are charged to the endpoint's `buffered_bytes` gauge
/// until `recv` pops them.
fn reader_loop(
    stream: TcpStream,
    src: usize,
    dst: usize,
    out: Sender<Result<Vec<u8>>>,
    counters: Arc<TransportCounters>,
) {
    let mut reader = BufReader::with_capacity(256 * 1024, stream);
    let mut expect_seq = 0u32;
    loop {
        match read_frame(&mut reader, src, dst, expect_seq) {
            Ok(Some(payload)) => {
                expect_seq = expect_seq.wrapping_add(1);
                counters.record_buffered(payload.len());
                if out.send(Ok(payload)).is_err() {
                    return; // owner gone
                }
            }
            Ok(None) => return, // clean EOF at a frame boundary
            Err(e) => {
                let _ = out.send(Err(e));
                return;
            }
        }
    }
}

/// Read and fully validate one frame. `Ok(None)` on clean EOF at a frame
/// boundary; EOF mid-frame is an error (a truncated frame never decodes).
fn read_frame<R: Read>(
    reader: &mut R,
    src: usize,
    dst: usize,
    expect_seq: u32,
) -> Result<Option<Vec<u8>>> {
    let mut hdr_buf = [0u8; frame::FRAME_HEADER_LEN];
    // First byte separately: EOF here is a clean shutdown, not corruption.
    loop {
        match reader.read(&mut hdr_buf[..1]) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(anyhow!(e)).context("reading frame header"),
        }
    }
    reader.read_exact(&mut hdr_buf[1..]).context("reading frame header (truncated frame)")?;
    let hdr = frame::FrameHeader::parse(&hdr_buf)?;
    ensure!(
        hdr.src as usize == src && hdr.dst as usize == dst,
        "misrouted frame: {}→{} arrived on the {src}→{dst} socket",
        hdr.src,
        hdr.dst
    );
    ensure!(
        hdr.seq == expect_seq,
        "sequence desync from rank {src}: got {}, expected {expect_seq}",
        hdr.seq
    );
    let mut payload = vec![0u8; hdr.len as usize];
    reader.read_exact(&mut payload).context("reading frame payload (truncated frame)")?;
    hdr.check_payload(&payload)?;
    Ok(Some(payload))
}

/// Bootstrap a complete `n`-rank TCP mesh inside this process (one thread
/// per rank) over an ephemeral loopback rendezvous port. Returns the
/// endpoints in rank order — the TCP analogue of [`super::inproc::mesh`],
/// used by tests and the backend-sweep bench.
pub fn local_mesh(n: usize) -> Result<Vec<TcpTransport>> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).context("binding rendezvous listener")?;
    let root = listener.local_addr().context("rendezvous addr")?.to_string();
    let mut root_listener = Some(listener);
    let results: Vec<Result<TcpTransport>> = thread::scope(|scope| {
        let joins: Vec<_> = (0..n)
            .map(|rank| {
                let root = root.clone();
                let l = if rank == 0 { root_listener.take() } else { None };
                scope.spawn(move || TcpTransport::bootstrap_with(rank, n, &root, l))
            })
            .collect();
        joins.into_iter().map(|j| j.join().expect("bootstrap thread panicked")).collect()
    });
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_bootstrap_advertises_the_bound_interface() {
        // --bind with an explicit loopback IP: the mesh forms and works
        // exactly like the default (the only loopback interface a test box
        // is guaranteed to have), and the advertised data addresses carry
        // the bound IP.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let root = listener.local_addr().unwrap().to_string();
        let mut root_listener = Some(listener);
        let bind: IpAddr = "127.0.0.1".parse().unwrap();
        let n = 3;
        let mut endpoints: Vec<TcpTransport> = {
            let results: Vec<Result<TcpTransport>> = thread::scope(|scope| {
                let joins: Vec<_> = (0..n)
                    .map(|rank| {
                        let root = root.clone();
                        let l = if rank == 0 { root_listener.take() } else { None };
                        scope.spawn(move || {
                            TcpTransport::bootstrap_bound_with(rank, n, &root, l, bind)
                        })
                    })
                    .collect();
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });
            results.into_iter().collect::<Result<Vec<_>>>().unwrap()
        };
        thread::scope(|scope| {
            for t in endpoints.drain(..) {
                scope.spawn(move || {
                    for d in 0..t.n() {
                        if d != t.rank() {
                            t.send(d, vec![t.rank() as u8; 2]).unwrap();
                        }
                    }
                    for s in 0..t.n() {
                        if s != t.rank() {
                            assert_eq!(t.recv(s).unwrap(), vec![s as u8; 2]);
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn unspecified_bind_rejected_up_front() {
        let e = TcpTransport::bootstrap_bound(0, 2, "127.0.0.1:1", "0.0.0.0".parse().unwrap())
            .unwrap_err();
        assert!(e.to_string().contains("unspecified"), "{e}");
    }

    #[test]
    fn local_mesh_pairwise_exchange() {
        let mut endpoints = local_mesh(4).unwrap();
        let results: Vec<Vec<u8>> = thread::scope(|scope| {
            let joins: Vec<_> = endpoints
                .drain(..)
                .map(|t| {
                    scope.spawn(move || {
                        for d in 0..t.n() {
                            if d != t.rank() {
                                t.send(d, vec![t.rank() as u8; 3]).unwrap();
                            }
                        }
                        (0..t.n())
                            .filter(|&s| s != t.rank())
                            .map(|s| t.recv(s).unwrap()[0])
                            .collect::<Vec<u8>>()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results[0], vec![1, 2, 3]);
        assert_eq!(results[3], vec![0, 1, 2]);
    }

    #[test]
    fn large_one_shot_exchange_does_not_deadlock() {
        // Every rank posts all sends before any recv, with payloads far
        // beyond socket buffers — only safe because readers drain eagerly.
        let n = 3;
        let payload = vec![0xA5u8; 4 << 20];
        let mut endpoints = local_mesh(n).unwrap();
        let p = &payload;
        thread::scope(|scope| {
            for t in endpoints.drain(..) {
                scope.spawn(move || {
                    for d in 0..t.n() {
                        if d != t.rank() {
                            t.send(d, p.clone()).unwrap();
                        }
                    }
                    for s in 0..t.n() {
                        if s != t.rank() {
                            let got = t.recv(s).unwrap();
                            assert_eq!(got.len(), p.len());
                            assert!(got == *p);
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn ordering_preserved_per_link() {
        let mut endpoints = local_mesh(2).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        let j = thread::spawn(move || {
            for i in 0..200u8 {
                t0.send(1, vec![i]).unwrap();
            }
            t0 // keep the socket alive until the receiver is done
        });
        for i in 0..200u8 {
            assert_eq!(t1.recv(0).unwrap(), vec![i]);
        }
        j.join().unwrap();
    }

    #[test]
    fn corrupted_frame_on_the_socket_is_rejected_with_crc_error() {
        // Hand-feed read_frame a corrupted frame through a real socket pair.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut framed = frame::encode(1, 0, 0, b"quantized chunk bytes");
            let last = framed.len() - 1;
            framed[last] ^= 0x80; // corrupt one payload bit in flight
            s.write_all(&framed).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream);
        let err = read_frame(&mut reader, 1, 0, 0).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        sender.join().unwrap();
    }

    #[test]
    fn version_mismatch_on_the_socket_is_rejected() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut framed = frame::encode(1, 0, 0, b"payload");
            framed[4] = frame::FRAME_VERSION + 7;
            s.write_all(&framed).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let err = read_frame(&mut BufReader::new(stream), 1, 0, 0).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        sender.join().unwrap();
    }

    #[test]
    fn sequence_gap_detected() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&frame::encode(1, 0, 5, b"skipped ahead")).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let err = read_frame(&mut BufReader::new(stream), 1, 0, 0).unwrap_err();
        assert!(err.to_string().contains("sequence"), "{err}");
        sender.join().unwrap();
    }

    #[test]
    fn recv_surfaces_reader_errors() {
        // End-to-end: corrupt bytes injected *after* bootstrap appear as a
        // recv error on the destination rank, not a silent bad decode.
        let mut endpoints = local_mesh(2).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        // Write garbage straight into rank 0's writer socket to rank 1,
        // bypassing frame encoding.
        {
            let mut w = t0.writers[1].as_ref().unwrap().lock().unwrap();
            w.write_all(b"not a frame at all, definitely garbage").unwrap();
        }
        let err = t1.recv(0).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }
}
