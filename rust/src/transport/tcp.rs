//! Multi-process TCP transport with a root rendezvous bootstrap.
//!
//! Bootstrap ([`TcpTransport::bootstrap`]):
//!
//! 1. every rank binds a *data listener* on an ephemeral port;
//! 2. rank 0 binds the well-known *rendezvous* address; ranks `1..n`
//!    connect to it (retrying while worker processes race to start) and
//!    send one `hello <rank> <addr>\n` line advertising their data listener;
//! 3. the root replies to every rank (and itself) with the full
//!    rank→address map, `peers <n>\n` + `<rank> <addr>\n` lines;
//! 4. full-mesh setup: rank `r` *connects* to the data listener of every
//!    rank `< r` and *accepts* a connection from every rank `> r`; a
//!    fixed-size binary hello identifies the connecting rank — one socket
//!    per unordered pair, used bidirectionally;
//! 5. one reader thread per peer pulls frames off the socket, validates
//!    magic/version/route/sequence/CRC ([`super::frame`]), and queues the
//!    verified payloads for [`Transport::recv`].
//!
//! Because reader threads drain sockets independently of when the owning
//! rank calls `recv`, a rank can post all its sends before touching a
//! single receive (the collectives' one-shot exchange pattern) without
//! deadlocking on TCP buffer backpressure.
//!
//! The rendezvous control plane is line-oriented text (bootstrap only);
//! the data plane is exclusively framed binary. See `DESIGN.md` §4.
//!
//! With a [`SessionConfig`] ([`TcpTransport::bootstrap_session`], usually
//! reached through [`crate::session::establish`]) the endpoint also runs
//! the session fabric: a heartbeat thread pings every peer each period,
//! the reader threads enforce a receive deadline (`Healthy → Suspect` at
//! half, `→ Lost` at the deadline or on an abrupt socket close), every
//! frame carries and must match the session epoch, and the rendezvous
//! handshake itself is bounded by
//! [`SessionConfig::rendezvous_timeout`] so a dead root fails bootstrap
//! instead of hanging it. See `DESIGN.md` §12.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::{frame, Transport, TransportCounters, TransportStats};
use crate::session::{PeerLost, SessionConfig, SessionShared, SessionStats};
use crate::util::Backoff;

/// How long bootstrap keeps retrying connects / polling accepts while the
/// other worker processes come up (the data-plane mesh phase; the
/// rendezvous phase uses [`SessionConfig::rendezvous_timeout`]).
const BOOTSTRAP_TIMEOUT: Duration = Duration::from_secs(20);

/// Data-plane hello: magic + the connecting rank, sent once per connection.
const HELLO_MAGIC: u32 = u32::from_le_bytes(*b"FCHL");
const HELLO_LEN: usize = 6;
/// Hello layout (bootstrap-only, not part of the frame protocol — the
/// frame header's own layout lives in [`frame::offsets`]).
const HELLO_MAGIC_RANGE: std::ops::Range<usize> = 0..4;
const HELLO_RANK_RANGE: std::ops::Range<usize> = 4..6;

/// Default data-listener bind address: loopback (single-node jobs).
pub const DEFAULT_BIND: IpAddr = IpAddr::V4(Ipv4Addr::LOCALHOST);

/// A peer link's stream of frame-verified payloads (or the first error).
type Inbox = Receiver<Result<Vec<u8>>>;

/// One rank's endpoint of a multi-process TCP mesh.
pub struct TcpTransport {
    rank: usize,
    n: usize,
    /// Write half of the socket to each peer (None at the self index).
    /// Behind an `Arc` so the heartbeat thread can ping every peer while
    /// the owning rank writes data frames (writes interleave at frame
    /// granularity under each per-peer mutex).
    writers: Arc<Vec<Option<Mutex<TcpStream>>>>,
    /// Frame-verified payloads from each peer (None at the self index).
    inbox: Vec<Option<Inbox>>,
    send_seq: Vec<AtomicU32>,
    /// Shared with the per-peer reader threads, which account the
    /// receive-queue occupancy (`buffered_bytes`) they create.
    counters: Arc<TransportCounters>,
    /// Session liveness state; `None` when bootstrapped without a session.
    session: Option<Arc<SessionShared>>,
    /// The epoch every frame carries and expects (0 without a session).
    epoch: u16,
}

impl TcpTransport {
    /// Rendezvous + full-mesh bootstrap. `root` is the rank-0 rendezvous
    /// address (e.g. `127.0.0.1:29555`), identical across all ranks. Data
    /// listeners bind loopback; see [`TcpTransport::bootstrap_bound`] for
    /// the multi-node bind address.
    pub fn bootstrap(rank: usize, n: usize, root: &str) -> Result<TcpTransport> {
        TcpTransport::bootstrap_bound_with(rank, n, root, None, DEFAULT_BIND)
    }

    /// [`TcpTransport::bootstrap`] with an explicit *data-listener* bind
    /// address (the CLI's `--bind`, DESIGN.md §4's extension point): the
    /// per-rank data sockets bind `(bind, ephemeral)` and advertise that
    /// address through the rendezvous, so peers on other hosts can dial
    /// in when `bind` is a routable interface IP. The default stays
    /// loopback. An unspecified address (`0.0.0.0` / `::`) is rejected —
    /// it would be advertised verbatim and peers cannot dial it.
    pub fn bootstrap_bound(rank: usize, n: usize, root: &str, bind: IpAddr) -> Result<TcpTransport> {
        TcpTransport::bootstrap_bound_with(rank, n, root, None, bind)
    }

    /// Like [`TcpTransport::bootstrap`], but rank 0 may supply an
    /// already-bound rendezvous listener (lets tests pick an ephemeral
    /// port without a bind race).
    pub fn bootstrap_with(
        rank: usize,
        n: usize,
        root: &str,
        root_listener: Option<TcpListener>,
    ) -> Result<TcpTransport> {
        TcpTransport::bootstrap_bound_with(rank, n, root, root_listener, DEFAULT_BIND)
    }

    /// Full-control bootstrap: rendezvous listener override + data bind
    /// address (see [`TcpTransport::bootstrap_bound`]), without a session.
    pub fn bootstrap_bound_with(
        rank: usize,
        n: usize,
        root: &str,
        root_listener: Option<TcpListener>,
        bind: IpAddr,
    ) -> Result<TcpTransport> {
        let config = SessionConfig::disabled();
        TcpTransport::bootstrap_session(rank, n, root, root_listener, bind, &config)
    }

    /// Session-aware bootstrap: everything
    /// [`TcpTransport::bootstrap_bound_with`] does, plus the session
    /// fabric of `config` — epoch-stamped frames, per-peer heartbeats and
    /// receive deadlines when enabled, and a bounded rendezvous handshake.
    /// Prefer [`crate::session::establish`], which maps failures to the
    /// typed [`CommError::Rendezvous`](crate::comm::CommError::Rendezvous).
    pub fn bootstrap_session(
        rank: usize,
        n: usize,
        root: &str,
        root_listener: Option<TcpListener>,
        bind: IpAddr,
        config: &SessionConfig,
    ) -> Result<TcpTransport> {
        ensure!(n >= 1, "world size must be at least 1");
        ensure!(rank < n, "rank {rank} out of range for world size {n}");
        ensure!(n <= u16::MAX as usize, "rank ids must fit the frame header");
        ensure!(
            !bind.is_unspecified(),
            "--bind {bind} is unspecified: peers would be told to dial {bind}, which no \
             host routes — bind a concrete interface IP instead"
        );

        // 1. Data listener for the full-mesh phase, on the requested
        // interface (loopback unless the job spans hosts). The advertised
        // address is exactly what was bound, so whatever `bind` names must
        // be reachable by every peer.
        let data_listener =
            TcpListener::bind((bind, 0)).with_context(|| format!("binding data listener on {bind}"))?;
        let my_addr = data_listener.local_addr().context("data listener addr")?;

        // 2+3. Rendezvous: learn every rank's data address and agree on
        // the session epoch (rank 0 is the authority; a rank announcing a
        // different epoch — a stale incarnation, or a survivor that missed
        // the bump — is rejected loudly). Bounded by the rendezvous
        // timeout so a dead root fails bootstrap instead of hanging it.
        let rdv = config.rendezvous_timeout;
        let epoch = config.epoch;
        let addrs = if rank == 0 {
            let listener = match root_listener {
                Some(l) => l,
                None => TcpListener::bind(root)
                    .with_context(|| format!("rank 0 binding rendezvous address {root}"))?,
            };
            rendezvous_root(&listener, n, my_addr, epoch, rdv)?
        } else {
            rendezvous_client(rank, n, root, my_addr, epoch, rdv)?
        };

        // 4. Full mesh: connect down, accept up.
        let mut sockets: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        for peer in 0..rank {
            let stream = connect_retry(addrs[peer])
                .with_context(|| format!("rank {rank} dialing rank {peer} at {}", addrs[peer]))?;
            write_hello(&stream, rank)?;
            sockets[peer] = Some(stream);
        }
        let deadline = Instant::now() + BOOTSTRAP_TIMEOUT;
        for _ in rank + 1..n {
            let (stream, _) = accept_deadline(&data_listener, deadline)
                .with_context(|| format!("rank {rank} waiting for higher-rank dials"))?;
            let peer = read_hello(&stream)?;
            ensure!(peer > rank && peer < n, "unexpected hello from rank {peer} at rank {rank}");
            ensure!(sockets[peer].is_none(), "rank {peer} connected twice");
            sockets[peer] = Some(stream);
        }

        // 5. Split each socket: reader thread (validates frames) + writer.
        // With a session, readers poll with a short read timeout so they
        // can tick the receive deadline between frames instead of parking
        // in `read` forever.
        let session = config.enabled().then(|| Arc::new(SessionShared::new(n, epoch)));
        let deadline = config.deadline;
        let tick = deadline
            .map(|d| (d / 10).clamp(Duration::from_millis(5), Duration::from_millis(100)));
        let counters = Arc::new(TransportCounters::default());
        let mut writers: Vec<Option<Mutex<TcpStream>>> = (0..n).map(|_| None).collect();
        let mut inbox: Vec<Option<Inbox>> = (0..n).map(|_| None).collect();
        for (peer, slot) in sockets.into_iter().enumerate() {
            let Some(stream) = slot else { continue };
            stream.set_nodelay(true).context("setting TCP_NODELAY")?;
            let read_half = stream.try_clone().context("cloning socket for reader")?;
            read_half.set_read_timeout(tick).context("setting reader deadline tick")?;
            let (tx, rx) = channel();
            let reader_counters = counters.clone();
            let reader_session = session.clone();
            thread::Builder::new()
                .name(format!("tcp-rx-{rank}<-{peer}"))
                .spawn(move || {
                    reader_loop(
                        read_half,
                        peer,
                        rank,
                        tx,
                        reader_counters,
                        epoch,
                        reader_session,
                        deadline,
                    )
                })
                .context("spawning reader thread")?;
            writers[peer] = Some(Mutex::new(stream));
            inbox[peer] = Some(rx);
        }
        let writers = Arc::new(writers);

        // 6. Heartbeat thread: one liveness ping per peer per period.
        if let (Some(s), Some(period)) = (&session, config.heartbeat) {
            let hb_writers = writers.clone();
            let hb_session = s.clone();
            thread::Builder::new()
                .name(format!("tcp-hb-{rank}"))
                .spawn(move || heartbeat_loop(hb_writers, rank, hb_session, period))
                .context("spawning heartbeat thread")?;
        }

        Ok(TcpTransport {
            rank,
            n,
            writers,
            inbox,
            send_seq: (0..n).map(|_| AtomicU32::new(0)).collect(),
            counters,
            session,
            epoch,
        })
    }

    /// The session epoch this endpoint speaks (0 without a session).
    pub fn epoch(&self) -> u16 {
        self.epoch
    }

    /// The shared session state, when bootstrapped with one (per-peer
    /// liveness states, counters).
    pub fn session_shared(&self) -> Option<&Arc<SessionShared>> {
        self.session.as_ref()
    }
}

impl Drop for TcpTransport {
    /// Shut the sockets down (not just close this handle's fds): the
    /// reader threads hold dups of the same sockets and would otherwise
    /// block on `read` forever, leaking one thread + fd per peer. Shutdown
    /// still flushes written data (FIN follows it), so a peer mid-`recv`
    /// receives everything already sent.
    fn drop(&mut self) {
        if let Some(s) = &self.session {
            s.shutdown.store(true, Ordering::Relaxed);
        }
        for writer in self.writers.iter().flatten() {
            if let Ok(stream) = writer.lock() {
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Transport for TcpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, dst: usize, payload: Vec<u8>) -> Result<()> {
        ensure!(dst < self.n, "dst rank {dst} out of range (n = {})", self.n);
        ensure!(dst != self.rank, "self-send is a local copy, not a transfer");
        if let Some(s) = &self.session {
            if s.is_lost(dst) {
                return Err(anyhow::Error::new(PeerLost { rank: dst, epoch: self.epoch }));
            }
        }
        let seq = self.send_seq[dst].fetch_add(1, Ordering::Relaxed);
        self.counters.record_send(payload.len());
        let framed = frame::encode(self.rank as u16, dst as u16, self.epoch, seq, &payload);
        // lint: allow(panic, "mesh invariant: every non-self rank has a connected writer")
        let writer = self.writers[dst].as_ref().expect("mesh invariant: peer socket exists");
        let mut stream = writer.lock().map_err(|_| anyhow!("writer to rank {dst} poisoned"))?;
        // lint: allow(lock, "the per-peer writer mutex serializes whole frames on one socket")
        match stream.write_all(&framed) {
            Ok(()) => Ok(()),
            Err(e) => {
                // A write error means the socket is gone. Under a session
                // that is a peer loss, typed so survivors can react.
                if let Some(s) = &self.session {
                    s.mark_lost(dst);
                    return Err(anyhow::Error::new(PeerLost { rank: dst, epoch: self.epoch })
                        .context(format!("writing {} wire bytes: {e}", framed.len())));
                }
                Err(anyhow!(e)).with_context(|| {
                    format!("sending {} wire bytes to rank {dst}", framed.len())
                })
            }
        }
    }

    fn recv(&self, src: usize) -> Result<Vec<u8>> {
        ensure!(src < self.n, "src rank {src} out of range (n = {})", self.n);
        ensure!(src != self.rank, "self-recv is a local copy, not a transfer");
        // lint: allow(panic, "mesh invariant: every non-self rank has an inbox")
        let rx = self.inbox[src].as_ref().expect("mesh invariant: peer inbox exists");
        match rx.recv() {
            Ok(result) => {
                if let Ok(payload) = &result {
                    self.counters.record_drained(payload.len());
                }
                result
            }
            // The reader exited and its queue is drained. Under a session
            // the loss is already recorded — keep surfacing it typed (the
            // first PeerLost was consumed by an earlier recv).
            Err(_) => match &self.session {
                Some(s) if s.is_lost(src) => {
                    Err(anyhow::Error::new(PeerLost { rank: src, epoch: self.epoch }))
                }
                _ => bail!("rank {src} disconnected"),
            },
        }
    }

    fn try_recv(&self, src: usize) -> Result<Option<Vec<u8>>> {
        ensure!(src < self.n, "src rank {src} out of range (n = {})", self.n);
        ensure!(src != self.rank, "self-recv is a local copy, not a transfer");
        // lint: allow(panic, "mesh invariant: every non-self rank has an inbox")
        let rx = self.inbox[src].as_ref().expect("mesh invariant: peer inbox exists");
        match rx.try_recv() {
            Ok(result) => {
                if let Ok(payload) = &result {
                    self.counters.record_drained(payload.len());
                }
                result.map(Some)
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => match &self.session {
                Some(s) if s.is_lost(src) => {
                    Err(anyhow::Error::new(PeerLost { rank: src, epoch: self.epoch }))
                }
                _ => bail!("rank {src} disconnected"),
            },
        }
    }

    fn stats(&self) -> TransportStats {
        self.counters.snapshot()
    }

    fn session_stats(&self) -> Option<SessionStats> {
        self.session.as_ref().map(|s| s.stats())
    }
}

/// Root side of the rendezvous: collect `hello <rank> <addr> <epoch>`
/// lines from ranks `1..n`, reject epoch conflicts (the root is the epoch
/// authority — a stale incarnation dialing a bumped session fails here),
/// then broadcast the full rank→address map. Every accept and read is
/// bounded by `timeout`. `pub(crate)` so the UDP backend can run the same
/// control plane with its datagram-socket address as `my_addr`.
pub(crate) fn rendezvous_root(
    listener: &TcpListener,
    n: usize,
    my_addr: SocketAddr,
    epoch: u16,
    timeout: Duration,
) -> Result<Vec<SocketAddr>> {
    let mut addrs: Vec<Option<SocketAddr>> = vec![None; n];
    addrs[0] = Some(my_addr);
    let deadline = Instant::now() + timeout;
    let mut clients: Vec<(usize, TcpStream)> = Vec::with_capacity(n.saturating_sub(1));
    while clients.len() + 1 < n {
        let (stream, _) = accept_deadline(listener, deadline)
            .context("rendezvous root waiting for workers")?;
        stream.set_read_timeout(Some(timeout)).context("setting rendezvous read deadline")?;
        let mut reader = BufReader::new(stream.try_clone().context("cloning rendezvous socket")?);
        let mut line = String::new();
        reader
            .read_line(&mut line)
            .context("reading hello line (worker went silent mid-handshake?)")?;
        let mut parts = line.split_whitespace();
        ensure!(parts.next() == Some("hello"), "malformed rendezvous hello: {line:?}");
        let peer: usize = parts
            .next()
            .ok_or_else(|| anyhow!("hello missing rank: {line:?}"))?
            .parse()
            .with_context(|| format!("hello rank in {line:?}"))?;
        let addr: SocketAddr = parts
            .next()
            .ok_or_else(|| anyhow!("hello missing address: {line:?}"))?
            .parse()
            .with_context(|| format!("hello address in {line:?}"))?;
        let peer_epoch: u16 = parts
            .next()
            .ok_or_else(|| anyhow!("hello missing epoch: {line:?}"))?
            .parse()
            .with_context(|| format!("hello epoch in {line:?}"))?;
        ensure!(peer >= 1 && peer < n, "hello from out-of-range rank {peer} (n = {n})");
        ensure!(
            peer_epoch == epoch,
            "epoch conflict: rank {peer} speaks epoch {peer_epoch}, this session is epoch {epoch} \
             (stale incarnation, or a rank that missed the rejoin bump)"
        );
        ensure!(addrs[peer].is_none(), "two workers claim rank {peer}");
        addrs[peer] = Some(addr);
        clients.push((peer, stream));
    }
    let map: Vec<SocketAddr> = addrs
        .into_iter()
        .enumerate()
        .map(|(r, a)| a.ok_or_else(|| anyhow!("rendezvous ended with no hello from rank {r}")))
        .collect::<Result<_>>()?;
    let mut reply = format!("peers {n} {epoch}\n");
    for (r, a) in map.iter().enumerate() {
        reply.push_str(&format!("{r} {a}\n"));
    }
    for (peer, mut stream) in clients {
        stream
            .write_all(reply.as_bytes())
            .with_context(|| format!("sending peer map to rank {peer}"))?;
    }
    Ok(map)
}

/// Worker side of the rendezvous: announce our data address and epoch,
/// receive the full rank→address map. Connect retries and every read are
/// bounded by `timeout`, so a dead root is a typed failure, not a hang.
pub(crate) fn rendezvous_client(
    rank: usize,
    n: usize,
    root: &str,
    my_addr: SocketAddr,
    epoch: u16,
    timeout: Duration,
) -> Result<Vec<SocketAddr>> {
    // to_socket_addrs (not str::parse) so hostname roots like
    // `localhost:29555` work — TcpListener::bind on the root side accepts
    // them, so the client side must too.
    let root_addr: SocketAddr = root
        .to_socket_addrs()
        .with_context(|| format!("resolving rendezvous address {root:?}"))?
        .next()
        .ok_or_else(|| anyhow!("rendezvous address {root:?} resolved to no addresses"))?;
    let stream = connect_retry_within(root_addr, timeout)
        .with_context(|| format!("rank {rank} reaching rendezvous root {root} (dead root?)"))?;
    stream.set_read_timeout(Some(timeout)).context("setting rendezvous read deadline")?;
    let mut writer = stream.try_clone().context("cloning rendezvous socket")?;
    writer
        .write_all(format!("hello {rank} {my_addr} {epoch}\n").as_bytes())
        .context("sending hello")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader
        .read_line(&mut line)
        .with_context(|| format!("reading peer-map header (root silent for {timeout:?}?)"))?;
    let mut parts = line.split_whitespace();
    ensure!(parts.next() == Some("peers"), "malformed peer map header: {line:?}");
    let got_n: usize = parts
        .next()
        .ok_or_else(|| anyhow!("peer map header missing count: {line:?}"))?
        .parse()
        .with_context(|| format!("peer count in {line:?}"))?;
    ensure!(got_n == n, "root says world size {got_n}, this worker was launched with {n}");
    let got_epoch: u16 = parts
        .next()
        .ok_or_else(|| anyhow!("peer map header missing epoch: {line:?}"))?
        .parse()
        .with_context(|| format!("peer map epoch in {line:?}"))?;
    ensure!(
        got_epoch == epoch,
        "epoch conflict: root runs epoch {got_epoch}, this rank speaks epoch {epoch}"
    );
    let mut addrs: Vec<Option<SocketAddr>> = vec![None; n];
    for _ in 0..n {
        let mut entry = String::new();
        reader.read_line(&mut entry).context("reading peer map entry")?;
        let mut parts = entry.split_whitespace();
        let r: usize = parts
            .next()
            .ok_or_else(|| anyhow!("peer entry missing rank: {entry:?}"))?
            .parse()
            .with_context(|| format!("peer rank in {entry:?}"))?;
        let a: SocketAddr = parts
            .next()
            .ok_or_else(|| anyhow!("peer entry missing address: {entry:?}"))?
            .parse()
            .with_context(|| format!("peer address in {entry:?}"))?;
        ensure!(r < n && addrs[r].is_none(), "bad peer map entry {entry:?}");
        addrs[r] = Some(a);
    }
    ensure!(addrs[rank] == Some(my_addr), "root recorded a different address for rank {rank}");
    addrs
        .into_iter()
        .enumerate()
        .map(|(r, a)| a.ok_or_else(|| anyhow!("root's peer map has no entry for rank {r}")))
        .collect()
}

/// Connect with retry until [`BOOTSTRAP_TIMEOUT`] (peers race to bind).
fn connect_retry(addr: SocketAddr) -> Result<TcpStream> {
    connect_retry_within(addr, BOOTSTRAP_TIMEOUT)
}

/// Connect with retry under an explicit deadline (the rendezvous phase
/// uses the session's handshake timeout here). Retries follow the shared
/// [`Backoff`] schedule — jittered-exponential from 5 ms up to 200 ms, so
/// a whole world of workers hammering one slow root decorrelates instead
/// of dialing in lockstep every 20 ms. The jitter seed is the target port:
/// deterministic for tests, distinct per destination.
fn connect_retry_within(addr: SocketAddr, timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    let mut backoff =
        Backoff::new(Duration::from_millis(5), Duration::from_millis(200), addr.port() as u64);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow!(e)).context(format!("connecting to {addr} timed out"));
            }
            Err(_) => thread::sleep(backoff.next_delay()),
        }
    }
}

/// Accept with a deadline (the listener is switched to non-blocking polling
/// so a missing peer fails the bootstrap instead of hanging it).
fn accept_deadline(listener: &TcpListener, deadline: Instant) -> Result<(TcpStream, SocketAddr)> {
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let result = loop {
        match listener.accept() {
            Ok(pair) => break Ok(pair),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    break Err(anyhow!("timed out waiting for a peer to connect"));
                }
                thread::sleep(Duration::from_millis(10));
            }
            Err(e) => break Err(anyhow!(e)).context("accepting peer connection"),
        }
    };
    listener.set_nonblocking(false).context("listener blocking")?;
    let (stream, addr) = result?;
    stream.set_nonblocking(false).context("stream blocking")?;
    Ok((stream, addr))
}

fn write_hello(mut stream: &TcpStream, rank: usize) -> Result<()> {
    let mut hello = [0u8; HELLO_LEN];
    hello[HELLO_MAGIC_RANGE].copy_from_slice(&HELLO_MAGIC.to_le_bytes());
    hello[HELLO_RANK_RANGE].copy_from_slice(&(rank as u16).to_le_bytes());
    stream.write_all(&hello).context("sending data-plane hello")?;
    Ok(())
}

fn read_hello(mut stream: &TcpStream) -> Result<usize> {
    let mut hello = [0u8; HELLO_LEN];
    stream.read_exact(&mut hello).context("reading data-plane hello")?;
    let magic = frame::read_u32(&hello, HELLO_MAGIC_RANGE);
    ensure!(magic == HELLO_MAGIC, "bad data-plane hello magic {magic:#010x}");
    Ok(frame::read_u16(&hello, HELLO_RANK_RANGE) as usize)
}

/// One observation of the link by [`read_frame`].
enum ReadEvent {
    /// A verified data payload.
    Payload(Vec<u8>),
    /// A verified heartbeat frame (liveness only; never queued).
    Heartbeat,
    /// Nothing arrived within the read-timeout tick (session mode only).
    Idle,
    /// Clean EOF at a frame boundary.
    Eof,
}

/// Per-peer reader: pull frames off the socket, validate, queue payloads.
/// Exits on EOF, on a validation error (reported to the owning rank
/// through the inbox), or when the owner dropped the inbox. Queued
/// payloads are charged to the endpoint's `buffered_bytes` gauge until
/// `recv` pops them.
///
/// With a session, this thread is also the liveness monitor for `src`:
/// the socket carries a read-timeout tick, and each idle tick checks the
/// receive deadline — `Suspect` at half, `Lost` at the full deadline (or
/// immediately on EOF / a reset socket, the SIGKILL signature), surfaced
/// to the owner as a typed [`PeerLost`] through the inbox.
#[allow(clippy::too_many_arguments)]
fn reader_loop(
    stream: TcpStream,
    src: usize,
    dst: usize,
    out: Sender<Result<Vec<u8>>>,
    counters: Arc<TransportCounters>,
    epoch: u16,
    session: Option<Arc<SessionShared>>,
    deadline: Option<Duration>,
) {
    let mut reader = BufReader::with_capacity(256 * 1024, stream);
    let mut expect_seq = 0u32;
    let mut last_seen = Instant::now();
    let lost = |session: &Option<Arc<SessionShared>>, out: &Sender<Result<Vec<u8>>>| {
        if let Some(s) = session {
            if s.mark_lost(src) {
                let _ = out.send(Err(anyhow::Error::new(PeerLost { rank: src, epoch })));
            }
        }
    };
    loop {
        match read_frame(&mut reader, src, dst, expect_seq, epoch, deadline) {
            Ok(ReadEvent::Payload(payload)) => {
                last_seen = Instant::now();
                if let Some(s) = &session {
                    s.mark_alive(src);
                }
                expect_seq = expect_seq.wrapping_add(1);
                counters.record_buffered(payload.len());
                if out.send(Ok(payload)).is_err() {
                    return; // owner gone
                }
            }
            Ok(ReadEvent::Heartbeat) => {
                last_seen = Instant::now();
                if let Some(s) = &session {
                    s.mark_alive(src);
                    s.counters.heartbeats_received.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(ReadEvent::Idle) => {
                if let (Some(s), Some(d)) = (&session, deadline) {
                    let quiet = last_seen.elapsed();
                    if quiet >= d {
                        lost(&session, &out);
                        return;
                    }
                    if quiet >= d / 2 {
                        s.mark_suspect(src);
                    }
                }
            }
            Ok(ReadEvent::Eof) => {
                // Under a session, a closed socket *is* a death: SIGKILL
                // sends FIN/RST immediately, long before any deadline.
                lost(&session, &out);
                return;
            }
            Err(e) => {
                if session.is_some() && is_disconnect(&e) {
                    lost(&session, &out);
                } else {
                    let _ = out.send(Err(e));
                }
                return;
            }
        }
    }
}

/// Whether an error chain bottoms out in a connection-level io failure
/// (reset, aborted, broken pipe, EOF mid-frame) — a death under a session,
/// as opposed to a validation failure (CRC, version, epoch, seq).
fn is_disconnect(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(
                io.kind(),
                std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::UnexpectedEof
            )
        })
    })
}

/// Read and fully validate one frame-or-heartbeat. `Eof` on clean EOF at a
/// frame boundary; EOF mid-frame is an error (a truncated frame never
/// decodes). `Idle` when the socket's read timeout expired at a frame
/// boundary (session mode); a timeout *mid-frame* keeps reading until
/// `stall` elapses — a slow peer is fine, a half-written frame from a dead
/// one is not.
fn read_frame<R: Read>(
    reader: &mut R,
    src: usize,
    dst: usize,
    expect_seq: u32,
    epoch: u16,
    stall: Option<Duration>,
) -> Result<ReadEvent> {
    let mut hdr_buf = [0u8; frame::FRAME_HEADER_LEN];
    // First byte separately: EOF here is a clean shutdown, not corruption,
    // and a read-timeout here is an idle link, not a stalled frame.
    loop {
        match reader.read(&mut hdr_buf[..1]) {
            Ok(0) => return Ok(ReadEvent::Eof),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => return Ok(ReadEvent::Idle),
            Err(e) => return Err(anyhow!(e)).context("reading frame header"),
        }
    }
    read_full(reader, &mut hdr_buf[1..], stall).context("reading frame header (truncated frame)")?;
    let hdr = frame::FrameHeader::parse(&hdr_buf)?;
    if hdr.epoch != epoch {
        let age = if hdr.epoch < epoch { "stale" } else { "future" };
        bail!(
            "{age} epoch from rank {src}: frame carries epoch {}, session is epoch {epoch} \
             (frame rejected before it could poison the seq space)",
            hdr.epoch
        );
    }
    ensure!(
        hdr.src as usize == src && hdr.dst as usize == dst,
        "misrouted frame: {}→{} arrived on the {src}→{dst} socket",
        hdr.src,
        hdr.dst
    );
    if hdr.flags & frame::FLAG_HEARTBEAT != 0 {
        ensure!(hdr.len == 0, "heartbeat from rank {src} carries a payload ({} bytes)", hdr.len);
        // Heartbeats ride their own seq counter — deliberately unchecked,
        // so liveness pings never desync the data seq space.
        return Ok(ReadEvent::Heartbeat);
    }
    ensure!(
        hdr.seq == expect_seq,
        "sequence desync from rank {src}: got {}, expected {expect_seq}",
        hdr.seq
    );
    let mut payload = vec![0u8; hdr.len as usize];
    read_full(reader, &mut payload, stall).context("reading frame payload (truncated frame)")?;
    hdr.check_payload(&payload)?;
    Ok(ReadEvent::Payload(payload))
}

/// `read_exact` that tolerates read-timeout ticks up to `stall` total —
/// the socket may carry a short read timeout (the session's deadline
/// tick), and a frame mid-flight must not be abandoned on the first tick.
fn read_full<R: Read>(reader: &mut R, buf: &mut [u8], stall: Option<Duration>) -> Result<()> {
    let start = Instant::now();
    let mut filled = 0;
    while filled < buf.len() {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => bail!(std::io::Error::from(std::io::ErrorKind::UnexpectedEof)),
            Ok(k) => filled += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                if let Some(d) = stall {
                    if start.elapsed() >= d {
                        bail!("peer stalled mid-frame for {d:?} ({filled}/{} bytes)", buf.len());
                    }
                }
            }
            Err(e) => return Err(anyhow!(e)),
        }
    }
    Ok(())
}

/// A socket read-timeout expiry (reported as WouldBlock on Unix, TimedOut
/// on Windows).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// The session heartbeat thread: one liveness ping per peer per `period`,
/// interleaving with data frames under the per-peer writer mutex. A link
/// whose writer is busy is *skipped* for the round (`try_lock`), never
/// waited on: a long data write on one link must not stall liveness
/// pings to every other peer — and a mid-flight frame is itself proof
/// the link is alive. Exits when the owning endpoint drops (shutdown
/// flag). Write failures are left to the reader threads to diagnose —
/// the socket is shared, and the reader owns the loss verdict.
fn heartbeat_loop(
    writers: Arc<Vec<Option<Mutex<TcpStream>>>>,
    rank: usize,
    session: Arc<SessionShared>,
    period: Duration,
) {
    let mut seq = 0u32;
    while !session.shutdown.load(Ordering::Relaxed) {
        for (peer, writer) in writers.iter().enumerate() {
            let Some(writer) = writer else { continue };
            if session.is_lost(peer) {
                continue;
            }
            let hb = frame::encode_heartbeat(rank as u16, peer as u16, session.epoch, seq);
            if let Ok(mut stream) = writer.try_lock() {
                // lint: allow(lock, "one heartbeat write; try_lock cannot stall the ticker")
                if stream.write_all(&hb).is_ok() {
                    session.counters.heartbeats_sent.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        seq = seq.wrapping_add(1);
        thread::sleep(period);
    }
}

/// Bootstrap a complete `n`-rank TCP mesh inside this process (one thread
/// per rank) over an ephemeral loopback rendezvous port. Returns the
/// endpoints in rank order — the TCP analogue of [`super::inproc::mesh`],
/// used by tests and the backend-sweep bench.
pub fn local_mesh(n: usize) -> Result<Vec<TcpTransport>> {
    local_mesh_with(n, &SessionConfig::disabled())
}

/// [`local_mesh`] with a session fabric: every rank bootstraps under
/// `config` (shared epoch, heartbeats, receive deadlines). The in-process
/// harness for session behavior that needs a real wire — heartbeat flow,
/// EOF-as-death, epoch agreement.
pub fn local_mesh_with(n: usize, config: &SessionConfig) -> Result<Vec<TcpTransport>> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).context("binding rendezvous listener")?;
    let root = listener.local_addr().context("rendezvous addr")?.to_string();
    let mut root_listener = Some(listener);
    let results: Vec<Result<TcpTransport>> = thread::scope(|scope| {
        let joins: Vec<_> = (0..n)
            .map(|rank| {
                let root = root.clone();
                let l = if rank == 0 { root_listener.take() } else { None };
                scope.spawn(move || {
                    TcpTransport::bootstrap_session(rank, n, &root, l, DEFAULT_BIND, config)
                })
            })
            .collect();
        joins
            .into_iter()
            .map(|j| j.join().unwrap_or_else(|_| Err(anyhow!("bootstrap thread panicked"))))
            .collect()
    });
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_bootstrap_advertises_the_bound_interface() {
        // --bind with an explicit loopback IP: the mesh forms and works
        // exactly like the default (the only loopback interface a test box
        // is guaranteed to have), and the advertised data addresses carry
        // the bound IP.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let root = listener.local_addr().unwrap().to_string();
        let mut root_listener = Some(listener);
        let bind: IpAddr = "127.0.0.1".parse().unwrap();
        let n = 3;
        let mut endpoints: Vec<TcpTransport> = {
            let results: Vec<Result<TcpTransport>> = thread::scope(|scope| {
                let joins: Vec<_> = (0..n)
                    .map(|rank| {
                        let root = root.clone();
                        let l = if rank == 0 { root_listener.take() } else { None };
                        scope.spawn(move || {
                            TcpTransport::bootstrap_bound_with(rank, n, &root, l, bind)
                        })
                    })
                    .collect();
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });
            results.into_iter().collect::<Result<Vec<_>>>().unwrap()
        };
        thread::scope(|scope| {
            for t in endpoints.drain(..) {
                scope.spawn(move || {
                    for d in 0..t.n() {
                        if d != t.rank() {
                            t.send(d, vec![t.rank() as u8; 2]).unwrap();
                        }
                    }
                    for s in 0..t.n() {
                        if s != t.rank() {
                            assert_eq!(t.recv(s).unwrap(), vec![s as u8; 2]);
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn unspecified_bind_rejected_up_front() {
        let e = TcpTransport::bootstrap_bound(0, 2, "127.0.0.1:1", "0.0.0.0".parse().unwrap())
            .unwrap_err();
        assert!(e.to_string().contains("unspecified"), "{e}");
    }

    #[test]
    fn local_mesh_pairwise_exchange() {
        let mut endpoints = local_mesh(4).unwrap();
        let results: Vec<Vec<u8>> = thread::scope(|scope| {
            let joins: Vec<_> = endpoints
                .drain(..)
                .map(|t| {
                    scope.spawn(move || {
                        for d in 0..t.n() {
                            if d != t.rank() {
                                t.send(d, vec![t.rank() as u8; 3]).unwrap();
                            }
                        }
                        (0..t.n())
                            .filter(|&s| s != t.rank())
                            .map(|s| t.recv(s).unwrap()[0])
                            .collect::<Vec<u8>>()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results[0], vec![1, 2, 3]);
        assert_eq!(results[3], vec![0, 1, 2]);
    }

    #[test]
    fn large_one_shot_exchange_does_not_deadlock() {
        // Every rank posts all sends before any recv, with payloads far
        // beyond socket buffers — only safe because readers drain eagerly.
        let n = 3;
        let payload = vec![0xA5u8; 4 << 20];
        let mut endpoints = local_mesh(n).unwrap();
        let p = &payload;
        thread::scope(|scope| {
            for t in endpoints.drain(..) {
                scope.spawn(move || {
                    for d in 0..t.n() {
                        if d != t.rank() {
                            t.send(d, p.clone()).unwrap();
                        }
                    }
                    for s in 0..t.n() {
                        if s != t.rank() {
                            let got = t.recv(s).unwrap();
                            assert_eq!(got.len(), p.len());
                            assert!(got == *p);
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn ordering_preserved_per_link() {
        let mut endpoints = local_mesh(2).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        let j = thread::spawn(move || {
            for i in 0..200u8 {
                t0.send(1, vec![i]).unwrap();
            }
            t0 // keep the socket alive until the receiver is done
        });
        for i in 0..200u8 {
            assert_eq!(t1.recv(0).unwrap(), vec![i]);
        }
        j.join().unwrap();
    }

    #[test]
    fn corrupted_frame_on_the_socket_is_rejected_with_crc_error() {
        // Hand-feed read_frame a corrupted frame through a real socket pair.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut framed = frame::encode(1, 0, 0, 0, b"quantized chunk bytes");
            let last = framed.len() - 1;
            framed[last] ^= 0x80; // corrupt one payload bit in flight
            s.write_all(&framed).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(stream);
        let err = read_frame(&mut reader, 1, 0, 0, 0, None).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        sender.join().unwrap();
    }

    #[test]
    fn version_mismatch_on_the_socket_is_rejected() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut framed = frame::encode(1, 0, 0, 0, b"payload");
            framed[4] = frame::FRAME_VERSION + 7;
            s.write_all(&framed).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let err = read_frame(&mut BufReader::new(stream), 1, 0, 0, 0, None).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        sender.join().unwrap();
    }

    #[test]
    fn sequence_gap_detected() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&frame::encode(1, 0, 0, 5, b"skipped ahead")).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let err = read_frame(&mut BufReader::new(stream), 1, 0, 0, 0, None).unwrap_err();
        assert!(err.to_string().contains("sequence"), "{err}");
        sender.join().unwrap();
    }

    #[test]
    fn stale_and_future_epoch_frames_rejected_loudly() {
        // A frame from a previous incarnation (stale) and one from a
        // bumped session this rank missed (future) must both be rejected
        // before route/seq checks could be poisoned.
        for (frame_epoch, session_epoch, age) in [(2u16, 5u16, "stale"), (9, 5, "future")] {
            let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
            let addr = listener.local_addr().unwrap();
            let sender = thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                s.write_all(&frame::encode(1, 0, frame_epoch, 0, b"ghost")).unwrap();
            });
            let (stream, _) = listener.accept().unwrap();
            let err =
                read_frame(&mut BufReader::new(stream), 1, 0, 0, session_epoch, None).unwrap_err();
            let msg = err.to_string();
            assert!(msg.contains(&format!("{age} epoch")), "{msg}");
            assert!(msg.contains(&format!("epoch {frame_epoch}")), "{msg}");
            sender.join().unwrap();
        }
    }

    #[test]
    fn dead_root_rendezvous_times_out_instead_of_hanging() {
        // Nobody listens on the root address: bootstrap must fail within
        // the rendezvous timeout, not retry forever.
        let config = SessionConfig::disabled()
            .with_rendezvous_timeout(Duration::from_millis(300));
        let t0 = Instant::now();
        let err = TcpTransport::bootstrap_session(
            1, 2, "127.0.0.1:9", None, DEFAULT_BIND, &config, // port 9: discard, never bound
        )
        .unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(10), "gave up promptly");
        assert!(format!("{err:#}").contains("dead root"), "{err:#}");
    }

    #[test]
    fn silent_root_read_times_out_instead_of_hanging() {
        // The root accepts but never replies (wedged process): the worker's
        // peer-map read must hit its deadline, not block forever.
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let root = listener.local_addr().unwrap().to_string();
        let hold = thread::spawn(move || listener.accept().map(|(s, _)| s));
        let config = SessionConfig::disabled()
            .with_rendezvous_timeout(Duration::from_millis(300));
        let err =
            TcpTransport::bootstrap_session(1, 2, &root, None, DEFAULT_BIND, &config).unwrap_err();
        assert!(format!("{err:#}").contains("root silent"), "{err:#}");
        drop(hold.join().unwrap());
    }

    #[test]
    fn heartbeats_flow_and_peers_stay_healthy_while_idle() {
        use crate::session::PeerState;
        let config = SessionConfig::from_millis(20, 400).unwrap();
        let mut endpoints = local_mesh_with(2, &config).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        // No data traffic at all: liveness must come from heartbeats.
        thread::sleep(Duration::from_millis(150));
        for t in [&t0, &t1] {
            let stats = t.session_stats().unwrap();
            assert!(stats.heartbeats_sent > 0, "{stats:?}");
            assert!(stats.heartbeats_received > 0, "{stats:?}");
            assert_eq!(stats.losses, 0, "{stats:?}");
            let peer = 1 - t.rank();
            assert_eq!(t.session_shared().unwrap().state(peer), PeerState::Healthy);
        }
        // Data still flows interleaved with the heartbeats.
        t0.send(1, vec![42]).unwrap();
        assert_eq!(t1.recv(0).unwrap(), vec![42]);
    }

    #[test]
    fn busy_writer_does_not_stall_heartbeats_to_other_peers() {
        use crate::session::PeerState;
        // Regression for the R3 (lock-discipline) finding: the heartbeat
        // ticker used to take `writer.lock()` and could queue behind a
        // long data write on ONE link, starving liveness pings to every
        // OTHER peer. With `try_lock` the busy link is skipped for the
        // round. Hold rank 0's writer-to-rank-1 mutex well past the
        // session deadline and require that rank 2 still sees rank 0 as
        // healthy (its heartbeats kept flowing on the unheld link).
        let config = SessionConfig::from_millis(5, 150).unwrap();
        let mut endpoints = local_mesh_with(3, &config).unwrap();
        let t2 = endpoints.pop().unwrap();
        let _t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        let held = t0.writers[1].as_ref().unwrap().lock().unwrap();
        thread::sleep(Duration::from_millis(400)); // well past the deadline
        assert_eq!(
            t2.session_shared().unwrap().state(0),
            PeerState::Healthy,
            "rank 0's heartbeats to rank 2 stalled behind the held rank-1 writer"
        );
        drop(held);
    }

    #[test]
    fn killed_peer_surfaces_typed_peer_lost_within_the_deadline() {
        use crate::session::find_peer_lost;
        let config = SessionConfig::from_millis(20, 400).unwrap();
        let mut endpoints = local_mesh_with(2, &config).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        drop(t0); // socket shutdown = the FIN/RST a SIGKILLed process emits
        let t_start = Instant::now();
        let err = t1.recv(0).unwrap_err();
        let lost = find_peer_lost(&err).expect("typed PeerLost, not a string error");
        assert_eq!(lost.rank, 0);
        assert!(t_start.elapsed() < Duration::from_secs(5), "no hang");
        assert_eq!(t1.session_stats().unwrap().losses, 1);
        // The loss is sticky: later recvs keep reporting it typed.
        let again = t1.recv(0).unwrap_err();
        assert_eq!(find_peer_lost(&again).unwrap().rank, 0);
        // And sends to the corpse fail typed instead of buffering.
        let send_err = t0_send_probe(&t1);
        assert_eq!(find_peer_lost(&send_err).unwrap().rank, 0);
    }

    /// Send toward the dead rank 0 until the loss gate trips (the first
    /// write may succeed into the kernel buffer before the reader marks
    /// the loss).
    fn t0_send_probe(t1: &TcpTransport) -> anyhow::Error {
        for _ in 0..50 {
            if let Err(e) = t1.send(0, vec![0]) {
                return e;
            }
            thread::sleep(Duration::from_millis(10));
        }
        panic!("send to a lost peer never failed");
    }

    #[test]
    fn recv_surfaces_reader_errors() {
        // End-to-end: corrupt bytes injected *after* bootstrap appear as a
        // recv error on the destination rank, not a silent bad decode.
        let mut endpoints = local_mesh(2).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        // Write garbage straight into rank 0's writer socket to rank 1,
        // bypassing frame encoding.
        {
            let mut w = t0.writers[1].as_ref().unwrap().lock().unwrap();
            w.write_all(b"not a frame at all, definitely garbage").unwrap();
        }
        let err = t1.recv(0).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }
}
