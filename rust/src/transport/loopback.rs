//! Single-rank loopback transport: a self-queue test stub.
//!
//! Unlike the mesh backends, loopback permits rank-0→rank-0 transfers so
//! the framing path (encode → queue → decode/verify) can be exercised
//! without a peer, and `recv` on an empty queue errors instead of blocking
//! (there is no peer to wait for — a documented divergence from the trait
//! contract). It is deliberately *not* wireable into the comm fabric:
//! `Topology` starts at 2 GPUs and `RankHandle` forbids self-links, so
//! this backend's one job is exercising `Transport` plumbing in tests.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use anyhow::{bail, ensure, Result};

use super::{frame, Transport, TransportCounters, TransportStats};

/// A one-rank transport whose only link is itself.
#[derive(Default)]
pub struct Loopback {
    queue: Mutex<VecDeque<Vec<u8>>>,
    send_seq: AtomicU32,
    recv_seq: AtomicU32,
    counters: TransportCounters,
}

impl Loopback {
    pub fn new() -> Loopback {
        Loopback::default()
    }
}

impl Transport for Loopback {
    fn rank(&self) -> usize {
        0
    }

    fn n(&self) -> usize {
        1
    }

    fn send(&self, dst: usize, payload: Vec<u8>) -> Result<()> {
        ensure!(dst == 0, "loopback has a single rank; dst {dst} does not exist");
        let seq = self.send_seq.fetch_add(1, Ordering::Relaxed);
        self.counters.record_send(payload.len());
        self.counters.record_buffered(payload.len());
        let framed = frame::encode(0, 0, 0, seq, &payload);
        // Poisoned-lock recovery: queue mutations are panic-free, so the
        // data is valid even if another holder panicked.
        self.queue.lock().unwrap_or_else(|p| p.into_inner()).push_back(framed);
        Ok(())
    }

    fn recv(&self, src: usize) -> Result<Vec<u8>> {
        ensure!(src == 0, "loopback has a single rank; src {src} does not exist");
        let Some(framed) = self.queue.lock().unwrap_or_else(|p| p.into_inner()).pop_front() else {
            bail!("loopback queue empty: nothing was sent");
        };
        let (hdr, payload) = frame::decode(framed)?;
        self.counters.record_drained(payload.len());
        let expect = self.recv_seq.fetch_add(1, Ordering::Relaxed);
        ensure!(
            hdr.seq == expect,
            "sequence desync on loopback: got {}, expected {expect}",
            hdr.seq
        );
        Ok(payload)
    }

    fn try_recv(&self, src: usize) -> Result<Option<Vec<u8>>> {
        ensure!(src == 0, "loopback has a single rank; src {src} does not exist");
        if self.queue.lock().unwrap_or_else(|p| p.into_inner()).is_empty() {
            return Ok(None);
        }
        self.recv(src).map(Some)
    }

    fn stats(&self) -> TransportStats {
        self.counters.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_roundtrip_through_the_self_queue() {
        let t = Loopback::new();
        t.send(0, b"alpha".to_vec()).unwrap();
        t.send(0, b"beta".to_vec()).unwrap();
        assert_eq!(t.recv(0).unwrap(), b"alpha");
        assert_eq!(t.recv(0).unwrap(), b"beta");
        assert!(t.recv(0).is_err(), "empty queue must error, not block");
        assert_eq!(t.stats().messages, 2);
        assert_eq!(t.stats().payload_bytes, 9);
    }

    #[test]
    fn nonexistent_ranks_rejected() {
        let t = Loopback::new();
        assert!(t.send(1, Vec::new()).is_err());
        assert!(t.recv(1).is_err());
    }

    #[test]
    fn corruption_in_the_queue_is_caught_on_recv() {
        let t = Loopback::new();
        t.send(0, b"payload".to_vec()).unwrap();
        if let Some(b) = t.queue.lock().unwrap()[0].last_mut() {
            *b ^= 0x20;
        }
        let err = t.recv(0).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
    }
}
