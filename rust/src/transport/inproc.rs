//! In-process transport: ranks are threads, links are mpsc channels.
//!
//! This absorbs the original `comm::fabric` channel mesh behind the
//! [`Transport`] trait. Payloads still travel framed ([`super::frame`]) so
//! the backend exercises exactly the wire discipline the TCP backend does —
//! magic/version/route/sequence/CRC are all built and verified per message.
//! The frame travels as a `(header bytes, payload)` pair rather than one
//! concatenated buffer, so the owned payload moves through the channel
//! without being copied.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::{anyhow, ensure, Result};

use super::{frame, Transport, TransportCounters, TransportStats};

/// A frame in flight: serialized header + untouched payload.
type Framed = ([u8; frame::FRAME_HEADER_LEN], Vec<u8>);

/// One rank's endpoint into an in-process mesh built by [`mesh`].
pub struct InProcTransport {
    rank: usize,
    n: usize,
    /// tx[d]: sender for the rank→d link (unused at d == rank).
    tx: Vec<Sender<Framed>>,
    /// rx[s]: receiver for the s→rank link (unused at s == rank).
    rx: Vec<Receiver<Framed>>,
    send_seq: Vec<AtomicU32>,
    recv_seq: Vec<AtomicU32>,
    counters: Arc<TransportCounters>,
}

/// Build a fully connected `n`-rank in-process mesh. Endpoint `i` is rank
/// `i`; all endpoints share one [`TransportCounters`] instance.
pub fn mesh(n: usize) -> Vec<InProcTransport> {
    assert!(n >= 1, "mesh needs at least one rank");
    assert!(n <= u16::MAX as usize, "rank ids must fit the frame header");
    let counters = Arc::new(TransportCounters::default());
    // chan[s][d]: sender kept by s, receiver kept by d (self links unused).
    let mut senders: Vec<Vec<Option<Sender<Framed>>>> = (0..n).map(|_| Vec::new()).collect();
    let mut receivers: Vec<Vec<Option<Receiver<Framed>>>> =
        (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
    for s in 0..n {
        for d in 0..n {
            let (tx, rx) = channel();
            senders[s].push(Some(tx));
            receivers[d][s] = Some(rx);
        }
    }
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, rxs)| InProcTransport {
            rank,
            n,
            // lint: allow(panic, "mesh construction: the channel matrix is complete by the loop above")
            tx: (0..n).map(|d| senders[rank][d].take().unwrap()).collect(),
            rx: rxs
                .into_iter()
                .enumerate()
                // lint: allow(panic, "mesh construction: the channel matrix is complete by the loop above")
                .map(|(s, r)| r.unwrap_or_else(|| panic!("missing channel {s}->{rank}")))
                .collect(),
            send_seq: (0..n).map(|_| AtomicU32::new(0)).collect(),
            recv_seq: (0..n).map(|_| AtomicU32::new(0)).collect(),
            counters: counters.clone(),
        })
        .collect()
}

impl Transport for InProcTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, dst: usize, payload: Vec<u8>) -> Result<()> {
        ensure!(dst < self.n, "dst rank {dst} out of range (n = {})", self.n);
        ensure!(dst != self.rank, "self-send is a local copy, not a transfer");
        ensure!(payload.len() as u64 <= frame::MAX_PAYLOAD as u64, "payload too large");
        let seq = self.send_seq[dst].fetch_add(1, Ordering::Relaxed);
        self.counters.record_send(payload.len());
        // Mesh-shared counters: the buffered gauge nets sends against
        // receives across every link, i.e. total in-flight payload bytes.
        self.counters.record_buffered(payload.len());
        let hdr = frame::FrameHeader {
            flags: 0,
            src: self.rank as u16,
            dst: dst as u16,
            epoch: 0,
            seq,
            len: payload.len() as u32,
            crc: frame::crc32(&payload),
        };
        self.tx[dst].send((hdr.to_bytes(), payload)).map_err(|_| anyhow!("rank {dst} hung up"))?;
        Ok(())
    }

    fn recv(&self, src: usize) -> Result<Vec<u8>> {
        ensure!(src < self.n, "src rank {src} out of range (n = {})", self.n);
        ensure!(src != self.rank, "self-recv is a local copy, not a transfer");
        let (hbuf, payload) =
            self.rx[src].recv().map_err(|_| anyhow!("rank {src} hung up"))?;
        self.verify(src, &hbuf, &payload)?;
        Ok(payload)
    }

    fn try_recv(&self, src: usize) -> Result<Option<Vec<u8>>> {
        ensure!(src < self.n, "src rank {src} out of range (n = {})", self.n);
        ensure!(src != self.rank, "self-recv is a local copy, not a transfer");
        let (hbuf, payload) = match self.rx[src].try_recv() {
            Ok(framed) => framed,
            Err(std::sync::mpsc::TryRecvError::Empty) => return Ok(None),
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                return Err(anyhow!("rank {src} hung up"))
            }
        };
        self.verify(src, &hbuf, &payload)?;
        Ok(Some(payload))
    }

    fn stats(&self) -> TransportStats {
        self.counters.snapshot()
    }
}

impl InProcTransport {
    /// Shared frame verification for `recv`/`try_recv`: parse, CRC, route,
    /// and strict per-link sequence. Counts the payload as drained.
    fn verify(
        &self,
        src: usize,
        hbuf: &[u8; frame::FRAME_HEADER_LEN],
        payload: &[u8],
    ) -> Result<()> {
        self.counters.record_drained(payload.len());
        let hdr = frame::FrameHeader::parse(hbuf)?;
        hdr.check_payload(payload)?;
        ensure!(
            hdr.src as usize == src && hdr.dst as usize == self.rank,
            "misrouted frame: {}→{} delivered on the {src}→{} link",
            hdr.src,
            hdr.dst,
            self.rank
        );
        let expect = self.recv_seq[src].fetch_add(1, Ordering::Relaxed);
        ensure!(
            hdr.seq == expect,
            "sequence desync from rank {src}: got {}, expected {expect}",
            hdr.seq
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::FRAME_HEADER_LEN;

    #[test]
    fn pairwise_exchange_delivers() {
        let mut endpoints = mesh(4);
        let results: Vec<Vec<u8>> = std::thread::scope(|scope| {
            let joins: Vec<_> = endpoints
                .drain(..)
                .map(|t| {
                    scope.spawn(move || {
                        for d in 0..t.n() {
                            if d != t.rank() {
                                t.send(d, vec![t.rank() as u8]).unwrap();
                            }
                        }
                        (0..t.n())
                            .filter(|&s| s != t.rank())
                            .map(|s| t.recv(s).unwrap()[0])
                            .collect::<Vec<u8>>()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results[0], vec![1, 2, 3]);
        assert_eq!(results[3], vec![0, 1, 2]);
    }

    #[test]
    fn messages_arrive_in_order_with_shared_stats() {
        let mut e = mesh(2);
        let t1 = e.pop().unwrap();
        let t0 = e.pop().unwrap();
        for i in 0..100u8 {
            t0.send(1, vec![i; 3]).unwrap();
        }
        for i in 0..100u8 {
            assert_eq!(t1.recv(0).unwrap(), vec![i; 3]);
        }
        // Counters are mesh-shared: both endpoints see the same totals.
        assert_eq!(t0.stats(), t1.stats());
        assert_eq!(t0.stats().messages, 100);
        assert_eq!(t0.stats().payload_bytes, 300);
        assert_eq!(t0.stats().wire_bytes, 300 + 100 * FRAME_HEADER_LEN as u64);
    }

    #[test]
    fn self_and_out_of_range_links_rejected() {
        let mut e = mesh(2);
        let t0 = e.remove(0);
        assert!(t0.send(0, vec![1]).is_err());
        assert!(t0.send(2, vec![1]).is_err());
        assert!(t0.recv(0).is_err());
        assert!(t0.recv(9).is_err());
    }

    #[test]
    fn try_recv_is_nonblocking_and_ordered() {
        let mut e = mesh(2);
        let t1 = e.pop().unwrap();
        let t0 = e.pop().unwrap();
        assert!(t1.try_recv(0).unwrap().is_none(), "idle link yields None");
        t0.send(1, vec![7]).unwrap();
        t0.send(1, vec![8]).unwrap();
        assert_eq!(t1.try_recv(0).unwrap(), Some(vec![7]));
        assert_eq!(t1.recv(0).unwrap(), vec![8], "try_recv and recv share the seq space");
        drop(t0);
        assert!(t1.try_recv(0).is_err(), "hung-up link errors instead of None");
    }

    #[test]
    fn empty_payload_roundtrips() {
        let mut e = mesh(2);
        let t1 = e.pop().unwrap();
        let t0 = e.pop().unwrap();
        t0.send(1, Vec::new()).unwrap();
        assert!(t1.recv(0).unwrap().is_empty());
    }
}
