//! Loss-tolerant UDP datagram transport: NACK reassembly, retransmit with
//! backoff, BBR-lite pacing, and deterministic wire-fault injection.
//!
//! TCP's per-stream congestion control and head-of-line blocking fight the
//! micro-chunk pipelining the plan compiler schedules; this backend trades
//! them for explicit loss recovery in the SFP spirit: each CRC32 v2 frame
//! is shredded into MTU-sized datagrams, the receiver reassembles them in
//! any order, and recovery is *receiver-driven* — only the missing chunks
//! are requested, on a jittered-exponential [`Backoff`] timer.
//!
//! Datagram layout (everything little-endian; see `DESIGN.md` §13):
//!
//! ```text
//! ┌──────────── frame v2 header, 28 B (see super::frame) ────────────┐
//! │ magic | ver | flags(FLAG_SEGMENT) | src | dst | epoch            │
//! │ seq = per-link datagram counter | len | crc32(payload) | hcrc    │
//! ├──────────────────── segment sub-header, 16 B ────────────────────┤
//! │ frame_seq u32 | chunk_index u16 | chunk_count u16                │
//! │ frame_len u32 | frame_crc u32                                    │
//! ├──────────────────────── chunk bytes ─────────────────────────────┤
//! │ ≤ 1200 B slice of the logical frame payload                      │
//! └──────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Every datagram is individually CRC-guarded, so a corrupted packet is
//! dropped at parse (and recovered via NACK) instead of poisoning the
//! frame. Control traffic rides the same header with its own flag bits:
//! `FLAG_NACK` (payload: `frame_seq u32 | n u16 | n × chunk_index u16`,
//! `n == 0` meaning "resend everything"), `FLAG_ACK` (payload:
//! `frame_seq u32`, retires the sender's window entry and yields the RTT /
//! delivered-bytes sample the pacer feeds on), and `FLAG_HEARTBEAT`.
//!
//! Loss recovery, end to end:
//!
//! - the **receiver** NACKs the missing chunks of every incomplete frame
//!   on a per-frame jittered-exponential backoff, bounded rounds;
//! - the **sender** keeps a bounded per-peer retransmit window and probes
//!   unacknowledged frames past an RTO derived from the smoothed RTT
//!   (re-sending chunk 0 — enough to let the receiver learn the frame
//!   exists and drive precise recovery even when *every* datagram of the
//!   first transmission was lost);
//! - the frame tail is sent twice up front (**forward redundancy**), so
//!   the common single-packet tail loss heals without a NACK round-trip;
//! - a **BBR-lite pacer** throttles the send rate to `gain × btlbw`, where
//!   `btlbw` is the windowed-max delivered-bytes/RTT over ACK samples;
//! - persistent silence is converted into the typed
//!   [`PeerLost`] by the session receive deadline (datagrams from a
//!   non-current epoch are dropped at parse), so there are no infinite
//!   NACK loops — a lost peer's reassembly and window state is cleared.
//!
//! The seeded [`WireFault`] injector is the datagram analogue of the
//! session layer's `FaultInjector`: it drops, duplicates, corrupts, and
//! reorders *outgoing* packets under a deterministic [`Prng`] program, so
//! the chaos harness in `tests/transport.rs` can prove bit-identical
//! collectives under 5% injected loss.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::net::{IpAddr, SocketAddr, TcpListener, UdpSocket};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::{frame, tcp, Transport, TransportCounters, TransportStats};
use crate::session::{PeerLost, SessionConfig, SessionShared, SessionStats};
use crate::util::{Backoff, Prng};

/// Chunk payload per datagram — conservative "MTU minus headers" so one
/// datagram never fragments on a standard 1500 B path.
pub const CHUNK_BYTES: usize = 1200;
/// Segment sub-header length — the layout (and this length) live in
/// [`frame`] with the rest of the wire constants; re-exported here for
/// the reassembly code and its tests.
pub use super::frame::SEG_HEADER_LEN;
/// Receive buffer: comfortably above header + sub-header + chunk.
const RECV_BUF: usize = 2048;
/// Engine socket read-timeout tick: bounds NACK/probe/deadline latency.
const ENGINE_TICK: Duration = Duration::from_millis(2);
/// Timer-scan period inside the engine (heartbeats, NACKs, probes).
const SCAN_PERIOD: Duration = Duration::from_millis(1);
/// Bounded retransmit window: unacknowledged frames per peer. `send`
/// blocks (briefly — ACKs come from the peer's engine, not its `recv`
/// calls) when full, and fails after [`WINDOW_FULL_TIMEOUT`].
const MAX_WINDOW_FRAMES: usize = 256;
const WINDOW_FULL_TIMEOUT: Duration = Duration::from_secs(10);
/// Receiver gives up on an incomplete frame after this many NACK rounds
/// (each round jitter-backed-off up to [`NACK_CAP`]) and surfaces an
/// error — no infinite NACK loop even without a session deadline.
const MAX_NACK_ROUNDS: u32 = 40;
/// Sender stops probing an unacknowledged frame after this many rounds.
const MAX_PROBE_ROUNDS: u32 = 24;
/// Missing-chunk ids per NACK datagram (the rest go next round).
const MAX_NACK_IDS: usize = 512;
/// NACK backoff schedule: base and cap of the jittered exponential.
const NACK_BASE: Duration = Duration::from_millis(2);
const NACK_CAP: Duration = Duration::from_millis(128);
/// Probe backoff cap (base is the live RTO).
const PROBE_CAP: Duration = Duration::from_millis(500);
/// How long the fault injector may hold a reordered datagram before the
/// engine flushes it (bounds reorder-in-the-tail latency).
const HOLDBACK_MAX_AGE: Duration = Duration::from_millis(3);
/// Pacer: initial rate, floor/ceiling, BBR-lite gain, bw-window decay.
const PACE_INIT: f64 = 256.0 * (1 << 20) as f64;
const PACE_FLOOR: f64 = 64.0 * (1 << 20) as f64;
const PACE_CEIL: f64 = 32.0 * (1 << 30) as f64;
const PACE_GAIN: f64 = 1.25;
const PACE_DECAY: f64 = 0.98;
/// Stalls shorter than this are absorbed into the token-bucket debt
/// instead of a sleep syscall.
const PACE_MIN_SLEEP: Duration = Duration::from_micros(100);

/// A peer link's stream of reassembled, validated frame payloads.
type Inbox = Receiver<Result<Vec<u8>>>;
/// The engine's sending half of a peer inbox (None for self / hung up).
type InboxTx = Option<Sender<Result<Vec<u8>>>>;
/// Per-peer bounded retransmit windows, shared between `send` (admission,
/// new entries) and the engine (NACK re-sends, probes, ACK retirement).
type Windows = Arc<Vec<Mutex<VecDeque<WindowEntry>>>>;
/// A datagram the fault injector is holding back to reorder.
type Holdback = Option<(SocketAddr, Vec<u8>, Instant)>;

/// The 16-byte segment sub-header every data datagram carries after the
/// frame header: which logical frame this chunk belongs to, where it
/// lands, and the whole-frame length/CRC the reassembled payload must
/// match.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SegHeader {
    frame_seq: u32,
    chunk_index: u16,
    chunk_count: u16,
    frame_len: u32,
    frame_crc: u32,
}

impl SegHeader {
    fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.frame_seq.to_le_bytes());
        out.extend_from_slice(&self.chunk_index.to_le_bytes());
        out.extend_from_slice(&self.chunk_count.to_le_bytes());
        out.extend_from_slice(&self.frame_len.to_le_bytes());
        out.extend_from_slice(&self.frame_crc.to_le_bytes());
    }

    fn parse(buf: &[u8]) -> Result<SegHeader> {
        ensure!(buf.len() >= SEG_HEADER_LEN, "segment sub-header truncated: {} bytes", buf.len());
        let h = SegHeader {
            frame_seq: frame::read_u32(buf, frame::offsets::SEG_FRAME_SEQ),
            chunk_index: frame::read_u16(buf, frame::offsets::SEG_CHUNK_INDEX),
            chunk_count: frame::read_u16(buf, frame::offsets::SEG_CHUNK_COUNT),
            frame_len: frame::read_u32(buf, frame::offsets::SEG_FRAME_LEN),
            frame_crc: frame::read_u32(buf, frame::offsets::SEG_FRAME_CRC),
        };
        ensure!(h.chunk_count > 0, "segment declares zero chunks");
        ensure!(
            (h.chunk_index as usize) < h.chunk_count as usize,
            "chunk index {} out of range for {} chunks",
            h.chunk_index,
            h.chunk_count
        );
        Ok(h)
    }
}

/// Chunk count for a payload of `len` bytes (an empty payload still
/// travels as one empty chunk).
fn chunk_count(len: usize) -> usize {
    len.div_ceil(CHUNK_BYTES).max(1)
}

/// The exact chunk length reassembly expects at `idx` of `count` chunks
/// of a `frame_len`-byte frame.
fn expected_chunk_len(frame_len: usize, count: usize, idx: usize) -> usize {
    if idx + 1 < count {
        CHUNK_BYTES
    } else {
        frame_len - CHUNK_BYTES * (count - 1)
    }
}

/// NACK payload: `frame_seq | n | n × chunk_index` (`n == 0` = all).
fn encode_nack_payload(frame_seq: u32, missing: &[u16]) -> Vec<u8> {
    assert!(missing.len() <= u16::MAX as usize);
    let mut out = Vec::with_capacity(frame::NACK_PREFIX_LEN + 2 * missing.len());
    out.extend_from_slice(&frame_seq.to_le_bytes());
    out.extend_from_slice(&(missing.len() as u16).to_le_bytes());
    for &m in missing {
        out.extend_from_slice(&m.to_le_bytes());
    }
    out
}

fn parse_nack_payload(buf: &[u8]) -> Result<(u32, Vec<u16>)> {
    let prefix = frame::NACK_PREFIX_LEN;
    ensure!(buf.len() >= prefix, "NACK payload truncated: {} bytes", buf.len());
    let frame_seq = frame::read_u32(buf, frame::offsets::NACK_FRAME_SEQ);
    let n = frame::read_u16(buf, frame::offsets::NACK_COUNT) as usize;
    ensure!(buf.len() == prefix + 2 * n, "NACK declares {n} ids in {} bytes", buf.len());
    let ids = (0..n).map(|i| frame::read_u16(buf, prefix + 2 * i..prefix + 2 * i + 2)).collect();
    Ok((frame_seq, ids))
}

/// One control datagram: frame header (`flags`, datagram-CRC-guarded) +
/// payload.
fn control_datagram(flags: u8, src: u16, dst: u16, epoch: u16, payload: &[u8]) -> Vec<u8> {
    let hdr = frame::FrameHeader {
        flags,
        src,
        dst,
        epoch,
        seq: 0, // control traffic rides outside the data datagram counter
        len: payload.len() as u32,
        crc: frame::crc32(payload),
    };
    let mut out = Vec::with_capacity(frame::FRAME_HEADER_LEN + payload.len());
    hdr.write(&mut out);
    out.extend_from_slice(payload);
    out
}

/// What the seeded wire decided to do with one outgoing datagram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct FaultDecision {
    drop: bool,
    dup: bool,
    /// Byte offset to flip, when corrupting.
    corrupt: Option<usize>,
    reorder: bool,
}

/// Deterministic seeded packet-level fault injector — the datagram
/// analogue of [`crate::session::FaultInjector`], applied to every
/// *outgoing* datagram of the endpoint it is attached to. Under one seed
/// the drop/duplicate/corrupt/reorder program is a pure function of the
/// send sequence, so chaos runs replay exactly.
#[derive(Debug)]
pub struct WireFault {
    drop_rate: f64,
    dup_rate: f64,
    corrupt_rate: f64,
    reorder_rate: f64,
    rng: Mutex<Prng>,
    /// At most one datagram held back for reordering; released after the
    /// next send, or flushed by the engine after [`HOLDBACK_MAX_AGE`].
    holdback: Mutex<Holdback>,
}

impl WireFault {
    /// Independent per-datagram fault rates, each in `[0, 1)`.
    pub fn new(seed: u64, drop: f64, dup: f64, corrupt: f64, reorder: f64) -> WireFault {
        for (name, r) in [("drop", drop), ("dup", dup), ("corrupt", corrupt), ("reorder", reorder)]
        {
            assert!((0.0..1.0).contains(&r), "{name} rate {r} outside [0, 1)");
        }
        WireFault {
            drop_rate: drop,
            dup_rate: dup,
            corrupt_rate: corrupt,
            reorder_rate: reorder,
            rng: Mutex::new(Prng::new(seed)),
            holdback: Mutex::new(None),
        }
    }

    /// The acceptance-criteria chaos program: `pct` rate for each of
    /// drop, duplicate, corrupt, and reorder.
    pub fn chaos(seed: u64, pct: f64) -> WireFault {
        WireFault::new(seed, pct, pct, pct, pct)
    }

    /// Draw this datagram's fate from the seeded program.
    fn decide(&self, len: usize) -> FaultDecision {
        // Poisoned-lock recovery: a panicked holder cannot leave the PRNG
        // or holdback slot torn (their mutations are panic-free), so the
        // fault program keeps running instead of cascading the panic.
        let mut rng = self.rng.lock().unwrap_or_else(|p| p.into_inner());
        FaultDecision {
            drop: rng.next_f64() < self.drop_rate,
            dup: rng.next_f64() < self.dup_rate,
            corrupt: (rng.next_f64() < self.corrupt_rate).then(|| rng.below(len.max(1))),
            reorder: rng.next_f64() < self.reorder_rate,
        }
    }

    /// Put `bytes` on the wire through the fault program.
    fn transmit(&self, socket: &UdpSocket, addr: SocketAddr, bytes: &[u8]) -> std::io::Result<()> {
        let d = self.decide(bytes.len());
        if d.drop {
            return Ok(()); // the wire ate it; NACK/probe recovery takes over
        }
        let corrupted;
        let wire: &[u8] = match d.corrupt {
            Some(i) => {
                let mut owned = bytes.to_vec();
                owned[i.min(owned.len().saturating_sub(1))] ^= 0x20;
                corrupted = owned;
                &corrupted
            }
            None => bytes,
        };
        if d.reorder {
            // Hold this one back; anything already held goes out now, so
            // at most one datagram is ever in the holdback slot.
            let prev = {
                let mut slot = self.holdback.lock().unwrap_or_else(|p| p.into_inner());
                slot.replace((addr, wire.to_vec(), Instant::now()))
            };
            if let Some((a, b, _)) = prev {
                socket.send_to(&b, a)?;
            }
            return Ok(());
        }
        socket.send_to(wire, addr)?;
        if d.dup {
            socket.send_to(wire, addr)?;
        }
        // The held-back datagram ships *after* this one: that is the swap.
        let held = self.holdback.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some((a, b, _)) = held {
            socket.send_to(&b, a)?;
        }
        Ok(())
    }

    /// Flush a held-back datagram older than `max_age` (called from the
    /// engine tick so a reorder on the last datagram of a burst cannot
    /// stall recovery).
    fn flush_stale(&self, socket: &UdpSocket, max_age: Duration) {
        let held = {
            let mut slot = self.holdback.lock().unwrap_or_else(|p| p.into_inner());
            match &*slot {
                Some((_, _, at)) if at.elapsed() >= max_age => slot.take(),
                _ => None,
            }
        };
        if let Some((a, b, _)) = held {
            let _ = socket.send_to(&b, a);
        }
    }
}

/// One unacknowledged frame in the sender's retransmit window.
struct WindowEntry {
    frame_seq: u32,
    /// The fully built datagrams of the first transmission, kept verbatim
    /// so NACK-requested chunks are re-sent bit-identically.
    datagrams: Arc<Vec<Vec<u8>>>,
    wire_bytes: usize,
    sent_at: Instant,
    next_probe: Instant,
    backoff: Backoff,
    rounds: u32,
}

/// BBR-lite: pace at `gain × btlbw` where `btlbw` is a decaying max of
/// delivered-bytes/RTT samples from ACKs; the RTO for sender probes is
/// `4 × srtt`, clamped. (RTT samples from probed frames are inflated by
/// the retransmit — acceptable for a pacer, noted in `DESIGN.md` §13.)
struct Pacer {
    rate: f64,
    btlbw: f64,
    srtt_s: f64,
    next_free: Instant,
}

impl Pacer {
    fn new() -> Pacer {
        Pacer {
            rate: PACE_INIT,
            btlbw: PACE_INIT / PACE_GAIN,
            srtt_s: 0.002,
            next_free: Instant::now(),
        }
    }

    /// Reserve a pacing slot for `bytes`; returns (delay before the slot,
    /// current probe RTO).
    fn reserve(&mut self, bytes: usize) -> (Duration, Duration) {
        let now = Instant::now();
        let start = self.next_free.max(now);
        self.next_free = start + Duration::from_secs_f64(bytes as f64 / self.rate);
        (start.saturating_duration_since(now), self.rto())
    }

    fn on_ack(&mut self, bytes: usize, rtt: Duration) {
        let rtt_s = rtt.as_secs_f64().max(1e-6);
        self.srtt_s = 0.875 * self.srtt_s + 0.125 * rtt_s;
        let sample = bytes as f64 / rtt_s;
        self.btlbw = (self.btlbw * PACE_DECAY).max(sample);
        self.rate = (PACE_GAIN * self.btlbw).clamp(PACE_FLOOR, PACE_CEIL);
    }

    fn rto(&self) -> Duration {
        Duration::from_secs_f64((4.0 * self.srtt_s).clamp(0.008, 0.25))
    }
}

/// One logical frame mid-reassembly on the receiver.
struct Reassembly {
    chunk_count: u16,
    frame_len: u32,
    frame_crc: u32,
    chunks: Vec<Option<Vec<u8>>>,
    received: usize,
    next_nack: Instant,
    backoff: Backoff,
    rounds: u32,
}

/// One rank's endpoint of a multi-process UDP mesh. See the module docs
/// for the protocol; see [`UdpTransport::bootstrap_session`] to build one.
pub struct UdpTransport {
    rank: usize,
    n: usize,
    epoch: u16,
    socket: Arc<UdpSocket>,
    /// Peer data addresses from the rendezvous (None at the self index).
    addrs: Vec<Option<SocketAddr>>,
    inbox: Vec<Option<Inbox>>,
    /// Per-dst logical frame counter (drives delivery order).
    frame_seq: Vec<AtomicU32>,
    /// Per-dst datagram counter (reorder diagnostics only).
    dgram_seq: Vec<AtomicU32>,
    windows: Windows,
    pacer: Arc<Mutex<Pacer>>,
    counters: Arc<TransportCounters>,
    session: Option<Arc<SessionShared>>,
    fault: Option<Arc<WireFault>>,
    shutdown: Arc<AtomicBool>,
}

impl UdpTransport {
    /// Rendezvous + engine bootstrap, optionally under a session fabric
    /// and a wire-fault program. The rendezvous control plane is the same
    /// bounded TCP handshake the TCP backend runs (rank 0 is the root and
    /// epoch authority) — only the advertised per-rank address is this
    /// endpoint's UDP socket. Prefer [`crate::session::establish_udp`],
    /// which maps failures to the typed `CommError::Rendezvous`.
    pub fn bootstrap_session(
        rank: usize,
        n: usize,
        root: &str,
        root_listener: Option<TcpListener>,
        bind: IpAddr,
        config: &SessionConfig,
        fault: Option<WireFault>,
    ) -> Result<UdpTransport> {
        ensure!(n >= 1, "world size must be at least 1");
        ensure!(rank < n, "rank {rank} out of range for world size {n}");
        ensure!(n <= u16::MAX as usize, "rank ids must fit the frame header");
        ensure!(
            !bind.is_unspecified(),
            "--bind {bind} is unspecified: peers would be told to dial {bind}, which no \
             host routes — bind a concrete interface IP instead"
        );
        let socket =
            UdpSocket::bind((bind, 0)).with_context(|| format!("binding UDP socket on {bind}"))?;
        let my_addr = socket.local_addr().context("UDP socket addr")?;

        // Same rendezvous control plane as TCP, advertising the UDP addr.
        // The socket is bound before the handshake completes, so datagrams
        // from fast peers land in the kernel buffer until the engine runs.
        let rdv = config.rendezvous_timeout;
        let epoch = config.epoch;
        let all_addrs = if rank == 0 {
            let listener = match root_listener {
                Some(l) => l,
                None => TcpListener::bind(root)
                    .with_context(|| format!("rank 0 binding rendezvous address {root}"))?,
            };
            tcp::rendezvous_root(&listener, n, my_addr, epoch, rdv)?
        } else {
            tcp::rendezvous_client(rank, n, root, my_addr, epoch, rdv)?
        };

        socket.set_read_timeout(Some(ENGINE_TICK)).context("setting engine tick")?;
        let socket = Arc::new(socket);
        let session = config.enabled().then(|| Arc::new(SessionShared::new(n, epoch)));
        let counters = Arc::new(TransportCounters::default());
        let windows: Windows = Arc::new((0..n).map(|_| Mutex::new(VecDeque::new())).collect());
        let pacer = Arc::new(Mutex::new(Pacer::new()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let fault = fault.map(Arc::new);
        let addrs: Vec<Option<SocketAddr>> =
            all_addrs.iter().enumerate().map(|(i, a)| (i != rank).then_some(*a)).collect();

        let mut inbox: Vec<Option<Inbox>> = (0..n).map(|_| None).collect();
        let mut inbox_tx: Vec<InboxTx> = (0..n).map(|_| None).collect();
        for peer in 0..n {
            if peer == rank {
                continue;
            }
            let (tx, rx) = channel();
            inbox_tx[peer] = Some(tx);
            inbox[peer] = Some(rx);
        }

        let engine = Engine {
            rank,
            n,
            epoch,
            socket: socket.clone(),
            addrs: addrs.clone(),
            inbox_tx,
            windows: windows.clone(),
            pacer: pacer.clone(),
            counters: counters.clone(),
            session: session.clone(),
            deadline: config.deadline,
            heartbeat: config.heartbeat,
            fault: fault.clone(),
            shutdown: shutdown.clone(),
            reasm: (0..n).map(|_| HashMap::new()).collect(),
            complete: (0..n).map(|_| BTreeMap::new()).collect(),
            next_deliver: vec![0; n],
            highest_seq: vec![None; n],
            last_seen: vec![Instant::now(); n],
            hb_seq: 0,
            last_hb: Instant::now(),
            last_scan: Instant::now(),
        };
        thread::Builder::new()
            .name(format!("udp-rx-{rank}"))
            .spawn(move || engine.run())
            .context("spawning UDP engine thread")?;

        Ok(UdpTransport {
            rank,
            n,
            epoch,
            socket,
            addrs,
            inbox,
            frame_seq: (0..n).map(|_| AtomicU32::new(0)).collect(),
            dgram_seq: (0..n).map(|_| AtomicU32::new(0)).collect(),
            windows,
            pacer,
            counters,
            session,
            fault,
            shutdown,
        })
    }

    /// The session epoch this endpoint speaks (0 without a session).
    pub fn epoch(&self) -> u16 {
        self.epoch
    }

    /// The shared session state, when bootstrapped with one.
    pub fn session_shared(&self) -> Option<&Arc<SessionShared>> {
        self.session.as_ref()
    }

    /// One datagram through the fault program (if any) to `dst`.
    fn wire_send(&self, dst: usize, bytes: &[u8]) -> Result<()> {
        let Some(addr) = self.addrs[dst] else {
            bail!("mesh invariant violated: no peer address for rank {dst}");
        };
        let res = match &self.fault {
            Some(f) => f.transmit(&self.socket, addr, bytes),
            None => self.socket.send_to(bytes, addr).map(|_| ()),
        };
        if let Err(e) = res {
            // A send error (ICMP-refused port: the peer's socket is gone)
            // is a death under a session, typed so survivors can react.
            if let Some(s) = &self.session {
                s.mark_lost(dst);
                return Err(anyhow::Error::new(PeerLost { rank: dst, epoch: self.epoch })
                    .context(format!("sending {} datagram bytes: {e}", bytes.len())));
            }
            return Err(anyhow!(e))
                .with_context(|| format!("sending {} datagram bytes to rank {dst}", bytes.len()));
        }
        Ok(())
    }
}

impl Drop for UdpTransport {
    /// Stop the engine (it notices within one tick); in-flight state is
    /// abandoned — peers recover via their own deadlines.
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(s) = &self.session {
            s.shutdown.store(true, Ordering::Relaxed);
        }
    }
}

impl Transport for UdpTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, dst: usize, payload: Vec<u8>) -> Result<()> {
        ensure!(dst < self.n, "dst rank {dst} out of range (n = {})", self.n);
        ensure!(dst != self.rank, "self-send is a local copy, not a transfer");
        ensure!(
            payload.len() <= CHUNK_BYTES * u16::MAX as usize,
            "payload of {} bytes exceeds the UDP segmentation bound ({} chunks × {CHUNK_BYTES} B)",
            payload.len(),
            u16::MAX
        );
        if let Some(s) = &self.session {
            if s.is_lost(dst) {
                return Err(anyhow::Error::new(PeerLost { rank: dst, epoch: self.epoch }));
            }
        }
        let frame_seq = self.frame_seq[dst].fetch_add(1, Ordering::Relaxed);
        let count = chunk_count(payload.len());
        let frame_len = payload.len() as u32;
        let frame_crc = frame::crc32(&payload);
        let mut datagrams = Vec::with_capacity(count);
        for idx in 0..count {
            let lo = idx * CHUNK_BYTES;
            let hi = ((idx + 1) * CHUNK_BYTES).min(payload.len());
            let chunk = &payload[lo..hi];
            let mut body = Vec::with_capacity(SEG_HEADER_LEN + chunk.len());
            SegHeader {
                frame_seq,
                chunk_index: idx as u16,
                chunk_count: count as u16,
                frame_len,
                frame_crc,
            }
            .write(&mut body);
            body.extend_from_slice(chunk);
            let hdr = frame::FrameHeader {
                flags: frame::FLAG_SEGMENT,
                src: self.rank as u16,
                dst: dst as u16,
                epoch: self.epoch,
                seq: self.dgram_seq[dst].fetch_add(1, Ordering::Relaxed),
                len: body.len() as u32,
                crc: frame::crc32(&body),
            };
            let mut dg = Vec::with_capacity(frame::FRAME_HEADER_LEN + body.len());
            hdr.write(&mut dg);
            dg.extend_from_slice(&body);
            datagrams.push(dg);
        }
        let datagrams = Arc::new(datagrams);
        let wire: usize = datagrams.iter().map(Vec::len).sum();

        // Pace, then claim a window slot (bounded: the peer's engine ACKs
        // independently of its recv calls, so waiting here cannot deadlock
        // a live mesh — and a dead peer trips the session gate).
        let (delay, rto) = {
            // Poisoned-lock recovery (see WireFault::decide): pacer and
            // window mutations are panic-free, so a peer thread's panic
            // never cascades into this send path.
            let mut pacer = self.pacer.lock().unwrap_or_else(|p| p.into_inner());
            pacer.reserve(wire)
        };
        if delay >= PACE_MIN_SLEEP {
            self.counters.record_paced_stall();
            thread::sleep(delay);
        }
        let admission_deadline = Instant::now() + WINDOW_FULL_TIMEOUT;
        loop {
            {
                let mut w = self.windows[dst].lock().unwrap_or_else(|p| p.into_inner());
                if w.len() < MAX_WINDOW_FRAMES {
                    let now = Instant::now();
                    let mut backoff = Backoff::new(rto, PROBE_CAP, u64::from(frame_seq) + 1);
                    let first_probe = now + backoff.next_delay() * 2;
                    w.push_back(WindowEntry {
                        frame_seq,
                        datagrams: datagrams.clone(),
                        wire_bytes: wire,
                        sent_at: now,
                        next_probe: first_probe,
                        backoff,
                        rounds: 0,
                    });
                    break;
                }
            }
            if let Some(s) = &self.session {
                if s.is_lost(dst) {
                    return Err(anyhow::Error::new(PeerLost { rank: dst, epoch: self.epoch }));
                }
            }
            if Instant::now() >= admission_deadline {
                bail!(
                    "retransmit window to rank {dst} full ({MAX_WINDOW_FRAMES} frames) for \
                     {WINDOW_FULL_TIMEOUT:?}: peer not acknowledging"
                );
            }
            thread::sleep(Duration::from_micros(200));
        }
        for dg in datagrams.iter() {
            self.wire_send(dst, dg)?;
        }
        // Forward redundancy: the tail ships twice up front, so the common
        // single-packet tail loss heals without a NACK round-trip.
        // lint: allow(panic, "chunk_count() >= 1: an empty payload still ships one chunk")
        let tail = datagrams.last().expect("at least one chunk");
        self.wire_send(dst, tail)?;
        self.counters.record_redundancy_bytes(tail.len() as u64);
        self.counters.record_extra_wire(tail.len());
        self.counters.record_datagram_send(payload.len(), wire);
        Ok(())
    }

    fn recv(&self, src: usize) -> Result<Vec<u8>> {
        ensure!(src < self.n, "src rank {src} out of range (n = {})", self.n);
        ensure!(src != self.rank, "self-recv is a local copy, not a transfer");
        // lint: allow(panic, "mesh invariant: every non-self rank has an inbox")
        let rx = self.inbox[src].as_ref().expect("mesh invariant: peer inbox exists");
        match rx.recv() {
            Ok(result) => {
                if let Ok(payload) = &result {
                    self.counters.record_drained(payload.len());
                }
                result
            }
            Err(_) => match &self.session {
                Some(s) if s.is_lost(src) => {
                    Err(anyhow::Error::new(PeerLost { rank: src, epoch: self.epoch }))
                }
                _ => bail!("rank {src} disconnected"),
            },
        }
    }

    fn try_recv(&self, src: usize) -> Result<Option<Vec<u8>>> {
        ensure!(src < self.n, "src rank {src} out of range (n = {})", self.n);
        ensure!(src != self.rank, "self-recv is a local copy, not a transfer");
        // lint: allow(panic, "mesh invariant: every non-self rank has an inbox")
        let rx = self.inbox[src].as_ref().expect("mesh invariant: peer inbox exists");
        match rx.try_recv() {
            Ok(result) => {
                if let Ok(payload) = &result {
                    self.counters.record_drained(payload.len());
                }
                result.map(Some)
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => match &self.session {
                Some(s) if s.is_lost(src) => {
                    Err(anyhow::Error::new(PeerLost { rank: src, epoch: self.epoch }))
                }
                _ => bail!("rank {src} disconnected"),
            },
        }
    }

    fn stats(&self) -> TransportStats {
        self.counters.snapshot()
    }

    fn session_stats(&self) -> Option<SessionStats> {
        self.session.as_ref().map(|s| s.stats())
    }
}

/// The per-endpoint engine thread: drains the socket (reassembly, NACK and
/// ACK handling), and on every scan tick sends heartbeats, NACKs missing
/// chunks, probes unacknowledged window entries, and enforces the session
/// receive deadline. One thread per endpoint — not per peer — because a
/// datagram socket is one demultiplexing point.
struct Engine {
    rank: usize,
    n: usize,
    epoch: u16,
    socket: Arc<UdpSocket>,
    addrs: Vec<Option<SocketAddr>>,
    inbox_tx: Vec<InboxTx>,
    windows: Windows,
    pacer: Arc<Mutex<Pacer>>,
    counters: Arc<TransportCounters>,
    session: Option<Arc<SessionShared>>,
    deadline: Option<Duration>,
    heartbeat: Option<Duration>,
    fault: Option<Arc<WireFault>>,
    shutdown: Arc<AtomicBool>,
    /// Per-src in-flight reassemblies, keyed by frame_seq.
    reasm: Vec<HashMap<u32, Reassembly>>,
    /// Per-src completed frames awaiting in-order delivery.
    complete: Vec<BTreeMap<u32, Vec<u8>>>,
    /// Per-src next frame_seq to deliver.
    next_deliver: Vec<u32>,
    /// Per-src highest data-datagram seq seen (reorder diagnostics).
    highest_seq: Vec<Option<u32>>,
    last_seen: Vec<Instant>,
    hb_seq: u32,
    last_hb: Instant,
    last_scan: Instant,
}

impl Engine {
    fn run(mut self) {
        let mut buf = vec![0u8; RECV_BUF];
        loop {
            if self.shutdown.load(Ordering::Relaxed) {
                return;
            }
            match self.socket.recv_from(&mut buf) {
                Ok((len, _)) => self.handle(&buf[..len]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
                // Transient (ICMP port-unreachable surfacing on a later
                // call): peer death is the deadline's verdict, not ours.
                Err(_) => {}
            }
            self.tick();
        }
    }

    /// Fire-and-forget engine send (retransmits, control): errors are
    /// deliberately swallowed — the receive deadline owns the loss verdict.
    fn engine_send(&self, peer: usize, bytes: &[u8]) {
        let Some(addr) = self.addrs[peer] else { return };
        let _ = match &self.fault {
            Some(f) => f.transmit(&self.socket, addr, bytes),
            None => self.socket.send_to(bytes, addr).map(|_| ()),
        };
    }

    fn handle(&mut self, buf: &[u8]) {
        let Ok(hdr) = frame::FrameHeader::parse(buf) else {
            self.counters.record_corrupt_drop();
            return;
        };
        let body = &buf[frame::FRAME_HEADER_LEN..];
        if hdr.check_payload(body).is_err() {
            self.counters.record_corrupt_drop();
            return;
        }
        if hdr.epoch != self.epoch {
            self.counters.record_stale_epoch_drop();
            return;
        }
        let src = hdr.src as usize;
        if src >= self.n || src == self.rank || hdr.dst as usize != self.rank {
            self.counters.record_corrupt_drop();
            return;
        }
        if let Some(s) = &self.session {
            if s.is_lost(src) {
                return; // lost is sticky inside an epoch; ignore stragglers
            }
            s.mark_alive(src);
        }
        self.last_seen[src] = Instant::now();
        match hdr.flags {
            frame::FLAG_HEARTBEAT => {
                if let Some(s) = &self.session {
                    s.counters.heartbeats_received.fetch_add(1, Ordering::Relaxed);
                }
            }
            frame::FLAG_SEGMENT => self.on_segment(src, hdr.seq, body),
            frame::FLAG_NACK => self.on_nack(src, body),
            frame::FLAG_ACK => self.on_ack(src, body),
            _ => self.counters.record_corrupt_drop(),
        }
    }

    /// One data chunk: dedup, reassemble, ACK + deliver on completion.
    fn on_segment(&mut self, src: usize, dgram_seq: u32, body: &[u8]) {
        let Ok(sub) = SegHeader::parse(body) else {
            self.counters.record_corrupt_drop();
            return;
        };
        let chunk = &body[SEG_HEADER_LEN..];
        match self.highest_seq[src] {
            Some(h) if dgram_seq < h => self.counters.record_reorder_event(),
            Some(h) if dgram_seq > h => self.highest_seq[src] = Some(dgram_seq),
            None => self.highest_seq[src] = Some(dgram_seq),
            _ => {}
        }
        // Already delivered (or complete and queued): duplicate. Re-ACK so
        // a sender whose ACK was lost still retires the window entry.
        if sub.frame_seq < self.next_deliver[src] || self.complete[src].contains_key(&sub.frame_seq)
        {
            self.counters.record_duplicate_drop();
            self.send_ack(src, sub.frame_seq);
            return;
        }
        let count = sub.chunk_count as usize;
        let entry = self.reasm[src].entry(sub.frame_seq).or_insert_with(|| {
            let now = Instant::now();
            let mut backoff =
                Backoff::new(NACK_BASE, NACK_CAP, ((src as u64) << 32) | u64::from(sub.frame_seq));
            // First NACK waits ~2 backoff steps: the rest of the burst is
            // probably still in flight.
            let first = now + backoff.next_delay() + NACK_BASE;
            Reassembly {
                chunk_count: sub.chunk_count,
                frame_len: sub.frame_len,
                frame_crc: sub.frame_crc,
                chunks: (0..count).map(|_| None).collect(),
                received: 0,
                next_nack: first,
                backoff,
                rounds: 0,
            }
        });
        // Sub-headers of one frame must agree with each other; a mismatch
        // is a corrupt datagram that slipped past its CRC (or a sender
        // bug) — drop it, recovery re-sends the real chunk.
        let want = expected_chunk_len(sub.frame_len as usize, count, sub.chunk_index as usize);
        if entry.chunk_count != sub.chunk_count
            || entry.frame_len != sub.frame_len
            || entry.frame_crc != sub.frame_crc
            || chunk.len() != want
        {
            self.counters.record_corrupt_drop();
            return;
        }
        let slot = &mut entry.chunks[sub.chunk_index as usize];
        if slot.is_some() {
            self.counters.record_duplicate_drop();
            return;
        }
        *slot = Some(chunk.to_vec());
        entry.received += 1;
        if entry.received < count {
            return;
        }
        // Complete: validate the reassembled frame against the sub-header's
        // whole-frame length/CRC, then ACK and deliver in frame_seq order.
        let Some(entry) = self.reasm[src].remove(&sub.frame_seq) else {
            return; // unreachable: the entry was touched just above
        };
        let mut payload = Vec::with_capacity(entry.frame_len as usize);
        // `received == count` ⇒ every slot is Some; if that invariant ever
        // broke, flatten() would skip the hole and the length/CRC check
        // below rejects the short payload instead of panicking the engine.
        for c in entry.chunks.iter().flatten() {
            payload.extend_from_slice(c);
        }
        if payload.len() != entry.frame_len as usize || frame::crc32(&payload) != entry.frame_crc {
            // Sender probes will re-ship it; rebuild from scratch.
            self.counters.record_corrupt_drop();
            return;
        }
        self.send_ack(src, sub.frame_seq);
        self.complete[src].insert(sub.frame_seq, payload);
        while let Some(ready) = self.complete[src].remove(&self.next_deliver[src]) {
            self.next_deliver[src] = self.next_deliver[src].wrapping_add(1);
            self.counters.record_buffered(ready.len());
            if let Some(tx) = &self.inbox_tx[src] {
                let _ = tx.send(Ok(ready));
            }
        }
    }

    /// The peer asks for chunks of a frame we sent it.
    fn on_nack(&mut self, src: usize, body: &[u8]) {
        self.counters.record_nack_received();
        let Ok((frame_seq, ids)) = parse_nack_payload(body) else {
            self.counters.record_corrupt_drop();
            return;
        };
        let to_send: Vec<Vec<u8>> = {
            let mut w = self.windows[src].lock().unwrap_or_else(|p| p.into_inner());
            let Some(entry) = w.iter_mut().find(|e| e.frame_seq == frame_seq) else {
                return; // already ACKed or given up on — stale NACK
            };
            entry.next_probe = Instant::now() + entry.backoff.next_delay();
            if ids.is_empty() {
                entry.datagrams.iter().cloned().collect()
            } else {
                ids.iter()
                    .filter_map(|&i| entry.datagrams.get(i as usize).cloned())
                    .collect()
            }
        };
        let bytes: usize = to_send.iter().map(Vec::len).sum();
        self.counters.record_retransmitted_chunks(to_send.len() as u64);
        self.counters.record_extra_wire(bytes);
        for dg in &to_send {
            self.engine_send(src, dg);
        }
    }

    /// The peer fully received a frame: retire it, feed the pacer.
    fn on_ack(&mut self, src: usize, body: &[u8]) {
        if body.len() != frame::offsets::ACK_FRAME_SEQ.end {
            self.counters.record_corrupt_drop();
            return;
        }
        let frame_seq = frame::read_u32(body, frame::offsets::ACK_FRAME_SEQ);
        let retired = {
            let mut w = self.windows[src].lock().unwrap_or_else(|p| p.into_inner());
            w.iter().position(|e| e.frame_seq == frame_seq).and_then(|i| w.remove(i))
        };
        if let Some(entry) = retired {
            let rtt = entry.sent_at.elapsed();
            self.pacer.lock().unwrap_or_else(|p| p.into_inner()).on_ack(entry.wire_bytes, rtt);
        }
    }

    fn send_ack(&self, src: usize, frame_seq: u32) {
        let dg = control_datagram(
            frame::FLAG_ACK,
            self.rank as u16,
            src as u16,
            self.epoch,
            &frame_seq.to_le_bytes(),
        );
        self.counters.record_extra_wire(dg.len());
        self.engine_send(src, &dg);
    }

    /// Periodic work: heartbeats, deadline enforcement, NACK rounds,
    /// window probes, fault-holdback flush. Rate-limited to [`SCAN_PERIOD`].
    fn tick(&mut self) {
        let now = Instant::now();
        if now.saturating_duration_since(self.last_scan) < SCAN_PERIOD {
            return;
        }
        self.last_scan = now;
        self.heartbeats(now);
        self.deadline_scan(now);
        self.nack_scan(now);
        self.probe_scan(now);
        if let Some(f) = &self.fault {
            f.flush_stale(&self.socket, HOLDBACK_MAX_AGE);
        }
    }

    fn heartbeats(&mut self, now: Instant) {
        let (Some(session), Some(period)) = (&self.session, self.heartbeat) else { return };
        if now.saturating_duration_since(self.last_hb) < period {
            return;
        }
        self.last_hb = now;
        let hb_seq = self.hb_seq;
        self.hb_seq = self.hb_seq.wrapping_add(1);
        for peer in 0..self.n {
            if peer == self.rank || session.is_lost(peer) {
                continue;
            }
            let hb = frame::encode_heartbeat(self.rank as u16, peer as u16, self.epoch, hb_seq);
            self.counters.record_extra_wire(hb.len());
            self.engine_send(peer, &hb);
            session.counters.heartbeats_sent.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Enforce the session receive deadline: `Suspect` at half, `Lost` at
    /// the deadline — surfaced typed through the inbox, with all pending
    /// recovery state for that peer torn down (no busy NACK loop against
    /// a corpse).
    fn deadline_scan(&mut self, now: Instant) {
        let (Some(session), Some(d)) = (&self.session, self.deadline) else { return };
        for peer in 0..self.n {
            if peer == self.rank || session.is_lost(peer) {
                continue;
            }
            let quiet = now.saturating_duration_since(self.last_seen[peer]);
            if quiet >= d {
                if session.mark_lost(peer) {
                    if let Some(tx) = &self.inbox_tx[peer] {
                        let lost = PeerLost { rank: peer, epoch: self.epoch };
                        let _ = tx.send(Err(anyhow::Error::new(lost)));
                    }
                    // Hang up the inbox: after the queued error drains,
                    // further recvs see a disconnect and re-derive the
                    // typed loss from the session instead of blocking.
                    self.inbox_tx[peer] = None;
                }
                self.reasm[peer].clear();
                self.complete[peer].clear();
                self.windows[peer].lock().unwrap_or_else(|p| p.into_inner()).clear();
            } else if quiet >= d / 2 {
                session.mark_suspect(peer);
            }
        }
    }

    /// Receiver-driven recovery: one NACK round per due incomplete frame,
    /// listing only the missing chunk indices. Bounded rounds convert a
    /// frame that never completes into an inbox error instead of an
    /// infinite loop.
    fn nack_scan(&mut self, now: Instant) {
        let mut outbox: Vec<(usize, Vec<u8>)> = Vec::new();
        for src in 0..self.n {
            if src == self.rank {
                continue;
            }
            if self.session.as_ref().is_some_and(|s| s.is_lost(src)) {
                continue;
            }
            let mut dead: Vec<u32> = Vec::new();
            for (&fseq, r) in self.reasm[src].iter_mut() {
                if now < r.next_nack {
                    continue;
                }
                if r.rounds >= MAX_NACK_ROUNDS {
                    dead.push(fseq);
                    continue;
                }
                let missing: Vec<u16> = r
                    .chunks
                    .iter()
                    .enumerate()
                    .filter(|(_, c)| c.is_none())
                    .map(|(i, _)| i as u16)
                    .take(MAX_NACK_IDS)
                    .collect();
                let payload = encode_nack_payload(fseq, &missing);
                outbox.push((
                    src,
                    control_datagram(
                        frame::FLAG_NACK,
                        self.rank as u16,
                        src as u16,
                        self.epoch,
                        &payload,
                    ),
                ));
                r.rounds += 1;
                r.next_nack = now + r.backoff.next_delay();
            }
            for fseq in dead {
                self.reasm[src].remove(&fseq);
                if let Some(tx) = &self.inbox_tx[src] {
                    let _ = tx.send(Err(anyhow!(
                        "frame {fseq} from rank {src} unrecoverable after {MAX_NACK_ROUNDS} \
                         NACK rounds"
                    )));
                }
            }
        }
        for (src, dg) in outbox {
            self.counters.record_nack_sent();
            self.counters.record_extra_wire(dg.len());
            self.engine_send(src, &dg);
        }
    }

    /// Sender-side probe: re-send chunk 0 of frames unacknowledged past
    /// their RTO — enough for the receiver to learn the frame exists (and
    /// NACK precisely) even when the entire first transmission was lost.
    fn probe_scan(&mut self, now: Instant) {
        let mut outbox: Vec<(usize, Vec<u8>)> = Vec::new();
        for dst in 0..self.n {
            if dst == self.rank {
                continue;
            }
            let mut w = self.windows[dst].lock().unwrap_or_else(|p| p.into_inner());
            w.retain_mut(|e| {
                if now < e.next_probe {
                    return true;
                }
                if e.rounds >= MAX_PROBE_ROUNDS {
                    return false; // give up; the receiver/deadline owns the rest
                }
                e.rounds += 1;
                e.next_probe = now + e.backoff.next_delay();
                outbox.push((dst, e.datagrams[0].clone()));
                true
            });
        }
        for (dst, dg) in outbox {
            self.counters.record_retransmitted_chunks(1);
            self.counters.record_extra_wire(dg.len());
            self.engine_send(dst, &dg);
        }
    }
}

/// Bootstrap a complete `n`-rank UDP mesh inside this process (one thread
/// per rank) over an ephemeral loopback rendezvous. The UDP analogue of
/// [`super::tcp::local_mesh`].
pub fn local_mesh(n: usize) -> Result<Vec<UdpTransport>> {
    local_mesh_inner(n, &SessionConfig::disabled(), |_| None)
}

/// [`local_mesh`] with a session fabric (heartbeats, deadlines, epochs).
pub fn local_mesh_with(n: usize, config: &SessionConfig) -> Result<Vec<UdpTransport>> {
    local_mesh_inner(n, config, |_| None)
}

/// [`local_mesh_with`] under a seeded chaos program: every endpoint's
/// outgoing datagrams run through [`WireFault::chaos`]`(seed + rank, pct)`.
pub fn local_mesh_faulty(
    n: usize,
    config: &SessionConfig,
    seed: u64,
    pct: f64,
) -> Result<Vec<UdpTransport>> {
    local_mesh_inner(n, config, |rank| Some(WireFault::chaos(seed.wrapping_add(rank as u64), pct)))
}

fn local_mesh_inner(
    n: usize,
    config: &SessionConfig,
    fault: impl Fn(usize) -> Option<WireFault>,
) -> Result<Vec<UdpTransport>> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).context("binding rendezvous listener")?;
    let root = listener.local_addr().context("rendezvous addr")?.to_string();
    let mut root_listener = Some(listener);
    let mut faults: Vec<Option<WireFault>> = (0..n).map(&fault).collect();
    let results: Vec<Result<UdpTransport>> = thread::scope(|scope| {
        let joins: Vec<_> = (0..n)
            .map(|rank| {
                let root = root.clone();
                let l = if rank == 0 { root_listener.take() } else { None };
                let f = faults[rank].take();
                scope.spawn(move || {
                    UdpTransport::bootstrap_session(rank, n, &root, l, tcp::DEFAULT_BIND, config, f)
                })
            })
            .collect();
        joins
            .into_iter()
            .map(|j| j.join().unwrap_or_else(|_| Err(anyhow!("bootstrap thread panicked"))))
            .collect()
    });
    results.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::find_peer_lost;

    #[test]
    fn seg_header_roundtrip_and_bounds() {
        let h = SegHeader {
            frame_seq: 7,
            chunk_index: 3,
            chunk_count: 9,
            frame_len: 10_000,
            frame_crc: 0xDEAD_BEEF,
        };
        let mut buf = Vec::new();
        h.write(&mut buf);
        assert_eq!(buf.len(), SEG_HEADER_LEN);
        assert_eq!(SegHeader::parse(&buf).unwrap(), h);
        assert!(SegHeader::parse(&buf[..SEG_HEADER_LEN - 1]).is_err(), "truncated");
        let mut oob = buf.clone();
        oob[4..6].copy_from_slice(&9u16.to_le_bytes()); // index == count
        assert!(SegHeader::parse(&oob).is_err(), "chunk index out of range");
        let mut zero = buf;
        zero[6..8].copy_from_slice(&0u16.to_le_bytes());
        assert!(SegHeader::parse(&zero).is_err(), "zero chunks");
    }

    #[test]
    fn nack_payload_roundtrip() {
        let (fseq, ids) = parse_nack_payload(&encode_nack_payload(42, &[0, 5, 17])).unwrap();
        assert_eq!((fseq, ids), (42, vec![0, 5, 17]));
        let (fseq, ids) = parse_nack_payload(&encode_nack_payload(7, &[])).unwrap();
        assert_eq!((fseq, ids), (7, vec![]), "empty list = resend everything");
        assert!(parse_nack_payload(&[1, 2, 3]).is_err(), "truncated");
        let mut lying = encode_nack_payload(1, &[2, 3]);
        lying.truncate(8); // claims 2 ids, carries 1
        assert!(parse_nack_payload(&lying).is_err());
    }

    #[test]
    fn chunk_math_covers_the_edges() {
        assert_eq!(chunk_count(0), 1, "empty payload is one empty chunk");
        assert_eq!(chunk_count(1), 1);
        assert_eq!(chunk_count(CHUNK_BYTES), 1);
        assert_eq!(chunk_count(CHUNK_BYTES + 1), 2);
        assert_eq!(expected_chunk_len(0, 1, 0), 0);
        assert_eq!(expected_chunk_len(CHUNK_BYTES + 1, 2, 0), CHUNK_BYTES);
        assert_eq!(expected_chunk_len(CHUNK_BYTES + 1, 2, 1), 1);
        assert_eq!(expected_chunk_len(3 * CHUNK_BYTES, 3, 2), CHUNK_BYTES);
    }

    #[test]
    fn wire_fault_program_is_deterministic_under_a_seed() {
        let a = WireFault::chaos(99, 0.05);
        let b = WireFault::chaos(99, 0.05);
        let da: Vec<FaultDecision> = (0..500).map(|_| a.decide(1244)).collect();
        let db: Vec<FaultDecision> = (0..500).map(|_| b.decide(1244)).collect();
        assert_eq!(da, db, "same seed, same program");
        let c = WireFault::chaos(100, 0.05);
        let dc: Vec<FaultDecision> = (0..500).map(|_| c.decide(1244)).collect();
        assert_ne!(da, dc, "different seed, different program");
        // ~5% per fault over 500 draws: expect some of each, far from all.
        let drops = da.iter().filter(|d| d.drop).count();
        assert!(drops > 0 && drops < 100, "drop count {drops} looks wrong for 5%");
        let clean = WireFault::chaos(7, 0.0);
        assert!((0..100).all(|_| clean.decide(100) == FaultDecision {
            drop: false,
            dup: false,
            corrupt: None,
            reorder: false
        }));
    }

    #[test]
    fn local_mesh_pairwise_exchange() {
        let mut endpoints = local_mesh(4).unwrap();
        let results: Vec<Vec<u8>> = thread::scope(|scope| {
            let joins: Vec<_> = endpoints
                .drain(..)
                .map(|t| {
                    scope.spawn(move || {
                        for d in 0..t.n() {
                            if d != t.rank() {
                                t.send(d, vec![t.rank() as u8; 3]).unwrap();
                            }
                        }
                        (0..t.n())
                            .filter(|&s| s != t.rank())
                            .map(|s| t.recv(s).unwrap()[0])
                            .collect::<Vec<u8>>()
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        assert_eq!(results[0], vec![1, 2, 3]);
        assert_eq!(results[3], vec![0, 1, 2]);
    }

    #[test]
    fn multi_chunk_frames_reassemble_in_order() {
        // Payloads spanning several chunks, sent back to back: delivery
        // must be whole-frame, in-order, bit-identical.
        let mut endpoints = local_mesh(2).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        let payloads: Vec<Vec<u8>> = (0..20u8)
            .map(|i| (0..3 * CHUNK_BYTES + i as usize).map(|j| (j as u8).wrapping_add(i)).collect())
            .collect();
        let sender = {
            let ps = payloads.clone();
            thread::spawn(move || {
                for p in ps {
                    t0.send(1, p).unwrap();
                }
                t0
            })
        };
        for p in &payloads {
            assert_eq!(&t1.recv(0).unwrap(), p);
        }
        let t0 = sender.join().unwrap();
        assert_eq!(t0.stats().messages, 20);
        assert!(t0.stats().redundancy_bytes > 0, "tail redundancy always ships");
    }

    #[test]
    fn empty_payload_roundtrips() {
        let mut endpoints = local_mesh(2).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        let j = thread::spawn(move || {
            t0.send(1, Vec::new()).unwrap();
            t0
        });
        assert!(t1.recv(0).unwrap().is_empty());
        j.join().unwrap();
    }

    #[test]
    fn chaos_wire_delivers_bit_identical_in_order() {
        // 5% drop + dup + corrupt + reorder on every outgoing datagram of
        // both endpoints: every frame still arrives exactly once, intact,
        // in order — and the robustness counters show the machinery fired.
        let mut endpoints =
            local_mesh_faulty(2, &SessionConfig::disabled(), 0xC0FFEE, 0.05).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        let payloads: Vec<Vec<u8>> = (0..60u32)
            .map(|i| {
                let mut rng = Prng::new(1000 + i as u64);
                (0..2500 + (i as usize % 3) * CHUNK_BYTES)
                    .map(|_| rng.next_u64() as u8)
                    .collect()
            })
            .collect();
        let sender = {
            let ps = payloads.clone();
            thread::spawn(move || {
                for p in ps {
                    t0.send(1, p).unwrap();
                }
                t0
            })
        };
        for p in &payloads {
            assert_eq!(&t1.recv(0).unwrap(), p, "bit-identical in-order delivery under chaos");
        }
        let t0 = sender.join().unwrap();
        let tx = t0.stats();
        let rx = t1.stats();
        assert!(
            tx.retransmitted_chunks > 0,
            "5% loss over {} chunks must trigger retransmits: {tx:?}",
            60 * 4
        );
        assert!(rx.corrupt_drops > 0, "injected corruption must be dropped at parse: {rx:?}");
        assert!(rx.duplicate_drops > 0, "dups and redundancy must be deduped: {rx:?}");
        assert!(rx.nacks_sent > 0 || tx.nacks_received > 0, "receiver-driven NACKs: {rx:?}");
    }

    #[test]
    fn silent_peer_surfaces_typed_peer_lost_within_twice_the_deadline() {
        let config = SessionConfig::from_millis(20, 250).unwrap();
        let mut endpoints = local_mesh_with(2, &config).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        drop(t0); // engine stops: true datagram silence, no FIN to lean on
        let t_start = Instant::now();
        let err = t1.recv(0).unwrap_err();
        let lost = find_peer_lost(&err).expect("typed PeerLost, not a string error");
        assert_eq!(lost.rank, 0);
        assert!(
            t_start.elapsed() < 2 * Duration::from_millis(250),
            "PeerLost within 2x the comm deadline, got {:?}",
            t_start.elapsed()
        );
        assert_eq!(t1.session_stats().unwrap().losses, 1);
        // Sticky and fast afterwards: no busy NACK loop against a corpse.
        let again_start = Instant::now();
        let again = t1.recv(0).unwrap_err();
        assert_eq!(find_peer_lost(&again).unwrap().rank, 0);
        assert!(again_start.elapsed() < Duration::from_millis(100), "loss is cached");
        let send_err = t1.send(0, vec![1]).unwrap_err();
        assert_eq!(find_peer_lost(&send_err).unwrap().rank, 0);
    }

    #[test]
    fn heartbeats_keep_an_idle_mesh_healthy() {
        use crate::session::PeerState;
        let config = SessionConfig::from_millis(20, 400).unwrap();
        let mut endpoints = local_mesh_with(2, &config).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        thread::sleep(Duration::from_millis(150));
        for t in [&t0, &t1] {
            let stats = t.session_stats().unwrap();
            assert!(stats.heartbeats_sent > 0, "{stats:?}");
            assert!(stats.heartbeats_received > 0, "{stats:?}");
            assert_eq!(stats.losses, 0, "{stats:?}");
            let peer = 1 - t.rank();
            assert_eq!(t.session_shared().unwrap().state(peer), PeerState::Healthy);
        }
        let j = thread::spawn(move || {
            t0.send(1, vec![42]).unwrap();
            t0
        });
        assert_eq!(t1.recv(0).unwrap(), vec![42]);
        j.join().unwrap();
    }

    #[test]
    fn stale_epoch_datagrams_dropped_at_parse() {
        // A datagram stamped with a different epoch must be counted and
        // ignored, not delivered and not an error.
        let mut endpoints = local_mesh(2).unwrap();
        let t1 = endpoints.pop().unwrap();
        let t0 = endpoints.pop().unwrap();
        // Forge a segment datagram from rank 0 under epoch 7 (session is 0).
        let mut body = Vec::new();
        SegHeader {
            frame_seq: 0,
            chunk_index: 0,
            chunk_count: 1,
            frame_len: 3,
            frame_crc: frame::crc32(b"abc"),
        }
        .write(&mut body);
        body.extend_from_slice(b"abc");
        let hdr = frame::FrameHeader {
            flags: frame::FLAG_SEGMENT,
            src: 0,
            dst: 1,
            epoch: 7,
            seq: 0,
            len: body.len() as u32,
            crc: frame::crc32(&body),
        };
        let mut dg = hdr.to_bytes().to_vec();
        dg.extend_from_slice(&body);
        t0.socket.send_to(&dg, t0.addrs[1].unwrap()).unwrap();
        // Give the engine a moment, then check: nothing delivered, drop counted.
        thread::sleep(Duration::from_millis(50));
        assert!(t1.try_recv(0).unwrap().is_none());
        assert_eq!(t1.stats().stale_epoch_drops, 1);
        // The link still works for the real epoch.
        let j = thread::spawn(move || {
            t0.send(1, vec![9]).unwrap();
            t0
        });
        assert_eq!(t1.recv(0).unwrap(), vec![9]);
        j.join().unwrap();
    }

    #[test]
    fn oversized_payload_rejected_up_front() {
        let mut endpoints = local_mesh(2).unwrap();
        let t0 = endpoints.remove(0);
        let e = t0.send(1, vec![0; CHUNK_BYTES * (u16::MAX as usize) + 1]).unwrap_err();
        assert!(e.to_string().contains("segmentation bound"), "{e}");
    }
}
