//! Pluggable point-to-point transport under the collectives.
//!
//! [`Transport`] is the seam between the collective algorithms
//! ([`crate::comm`]) and the bytes' physical journey. Every payload travels
//! inside a versioned, CRC-guarded frame ([`frame`]) regardless of backend,
//! so corruption, truncation, reordering, and cross-version peers fail
//! loudly instead of silently desyncing a collective.
//!
//! | backend                    | ranks are…            | used for                          |
//! |----------------------------|-----------------------|-----------------------------------|
//! | [`inproc::InProcTransport`]| threads, mpsc mesh    | tests, benches, single-node runs  |
//! | [`tcp::TcpTransport`]      | OS processes, sockets | `flashcomm worker`, multi-process |
//! | [`udp::UdpTransport`]      | OS processes, datagrams | lossy links, NACK + pacing      |
//! | [`loopback::Loopback`]     | one rank, self-queue  | frame-path unit tests             |
//!
//! Backends deliver *bit-identical* payloads for the same collective and
//! codec (asserted in `tests/transport.rs`), so numerics results transfer
//! between them; only latency/throughput differ. See `DESIGN.md` §4 for the
//! frame layout, the TCP rendezvous handshake, and the backend matrix.

pub mod frame;
pub mod inproc;
pub mod loopback;
pub mod tcp;
pub mod udp;

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::Result;

pub use frame::{FrameHeader, FRAME_HEADER_LEN, FRAME_VERSION};
pub use inproc::InProcTransport;
pub use loopback::Loopback;
pub use tcp::TcpTransport;
pub use udp::{UdpTransport, WireFault};

/// A connected point-to-point endpoint: rank `rank()` of a `n()`-rank mesh.
///
/// Semantics every backend guarantees (and the collectives rely on):
///
/// - `send` is non-blocking with respect to the peer's progress (frames are
///   drained off the link by the receiving side independently of when the
///   peer calls `recv`), so one-shot exchange patterns cannot deadlock;
/// - messages on one (src→dst) link arrive in send order, enforced by the
///   frame sequence number;
/// - `recv` returns the *payload* exactly as passed to `send` — framing is
///   invisible to callers — or an error if the link saw corruption, a
///   version mismatch, a sequence gap, or a disconnect. (One documented
///   divergence: the single-rank [`loopback::Loopback`] errors on an empty
///   queue instead of blocking — there is no peer to wait for.)
pub trait Transport: Send {
    /// This endpoint's rank in `0..n()`.
    fn rank(&self) -> usize;

    /// World size of the mesh this endpoint belongs to.
    fn n(&self) -> usize;

    /// Send `payload` to rank `dst` (framed on the wire; see [`frame`]).
    fn send(&self, dst: usize, payload: Vec<u8>) -> Result<()>;

    /// Block until the next payload from rank `src` arrives and passes
    /// frame verification.
    fn recv(&self, src: usize) -> Result<Vec<u8>>;

    /// Non-blocking [`recv`](Transport::recv): `Ok(Some(payload))` if a
    /// verified payload from `src` was already pending, `Ok(None)` if the
    /// link is healthy but idle, `Err` on the same conditions `recv` errors
    /// on. The session layer's fault injector polls through this so a
    /// survivor blocked on a dead peer can notice the loss instead of
    /// parking forever on a queue that will never fill.
    fn try_recv(&self, src: usize) -> Result<Option<Vec<u8>>>;

    /// Counters for traffic sent through this endpoint's scope: the whole
    /// mesh for [`InProcTransport`] (shared process-wide), this endpoint
    /// for [`TcpTransport`] (each process only sees its own sends).
    fn stats(&self) -> TransportStats;

    /// Session-fabric counters (heartbeats, suspects, losses, epoch bumps)
    /// for backends with a live session ([`TcpTransport`] bootstrapped via
    /// [`crate::session::establish`]); `None` where no session runs.
    fn session_stats(&self) -> Option<crate::session::SessionStats> {
        None
    }
}

/// Send-side counters each backend embeds. Individually relaxed-atomic;
/// read a coherent set via [`TransportCounters::snapshot`] only while no
/// transfer is in flight.
#[derive(Debug, Default)]
pub struct TransportCounters {
    payload_bytes: AtomicU64,
    wire_bytes: AtomicU64,
    messages: AtomicU64,
    /// Payload bytes the transport is currently holding (accepted by
    /// `send`/the reader but not yet handed to `recv`).
    buffered_bytes: AtomicU64,
    /// High-water mark of `buffered_bytes` — the backend's peak memory
    /// commitment for undelivered payloads.
    peak_buffered_bytes: AtomicU64,
    // Datagram robustness counters (UDP backend; zero elsewhere).
    /// NACK control datagrams sent (receiver side asking for chunks).
    nacks_sent: AtomicU64,
    /// NACK control datagrams received (sender side asked for chunks).
    nacks_received: AtomicU64,
    /// Chunks re-sent from the retransmit window (NACK- or probe-driven).
    retransmitted_chunks: AtomicU64,
    /// Datagrams dropped as duplicates of already-delivered data.
    duplicate_drops: AtomicU64,
    /// Datagrams that arrived out of per-link datagram order (delivered
    /// anyway — reassembly handles it — but counted as a wire diagnostic).
    reorder_events: AtomicU64,
    /// Datagrams dropped for CRC/parse failures (line noise or injected
    /// corruption — the data is recovered via NACK, never trusted).
    corrupt_drops: AtomicU64,
    /// Datagrams dropped for carrying a non-current session epoch.
    stale_epoch_drops: AtomicU64,
    /// Bytes sent as forward redundancy (frame-tail duplicates that let a
    /// receiver survive single-packet loss without a NACK round-trip).
    redundancy_bytes: AtomicU64,
    /// Times the pacer made a sender sleep before putting bytes on the wire.
    paced_stalls: AtomicU64,
}

impl TransportCounters {
    /// Record one sent payload (wire bytes = payload + frame header).
    pub fn record_send(&self, payload_len: usize) {
        self.payload_bytes.fetch_add(payload_len as u64, Ordering::Relaxed);
        self.wire_bytes.fetch_add((payload_len + FRAME_HEADER_LEN) as u64, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    /// A payload entered the backend's buffering scope (queued for a
    /// receiver). Updates the in-flight gauge and its high-water mark.
    pub fn record_buffered(&self, payload_len: usize) {
        let now = self.buffered_bytes.fetch_add(payload_len as u64, Ordering::Relaxed)
            + payload_len as u64;
        self.peak_buffered_bytes.fetch_max(now, Ordering::Relaxed);
    }

    /// A payload left the buffering scope (delivered through `recv`).
    pub fn record_drained(&self, payload_len: usize) {
        self.buffered_bytes.fetch_sub(payload_len as u64, Ordering::Relaxed);
    }

    /// Record one logical message sent as datagrams: `payload_len` is the
    /// application payload, `wire_len` the actual bytes put on the wire for
    /// its first transmission (chunk sub-headers and per-datagram frame
    /// headers included). Retransmissions and control traffic account
    /// their wire bytes via [`record_extra_wire`](Self::record_extra_wire).
    pub fn record_datagram_send(&self, payload_len: usize, wire_len: usize) {
        self.payload_bytes.fetch_add(payload_len as u64, Ordering::Relaxed);
        self.wire_bytes.fetch_add(wire_len as u64, Ordering::Relaxed);
        self.messages.fetch_add(1, Ordering::Relaxed);
    }

    /// Wire bytes beyond first-transmission data: retransmits, forward
    /// redundancy, NACK/ACK control datagrams, heartbeats.
    pub fn record_extra_wire(&self, wire_len: usize) {
        self.wire_bytes.fetch_add(wire_len as u64, Ordering::Relaxed);
    }

    /// A NACK control datagram left this endpoint.
    pub fn record_nack_sent(&self) {
        self.nacks_sent.fetch_add(1, Ordering::Relaxed);
    }

    /// A NACK control datagram arrived at this endpoint.
    pub fn record_nack_received(&self) {
        self.nacks_received.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` chunks were re-sent from the retransmit window.
    pub fn record_retransmitted_chunks(&self, n: u64) {
        self.retransmitted_chunks.fetch_add(n, Ordering::Relaxed);
    }

    /// A datagram duplicating already-delivered data was dropped.
    pub fn record_duplicate_drop(&self) {
        self.duplicate_drops.fetch_add(1, Ordering::Relaxed);
    }

    /// A datagram arrived out of per-link order.
    pub fn record_reorder_event(&self) {
        self.reorder_events.fetch_add(1, Ordering::Relaxed);
    }

    /// A datagram failed parse/CRC validation and was dropped.
    pub fn record_corrupt_drop(&self) {
        self.corrupt_drops.fetch_add(1, Ordering::Relaxed);
    }

    /// A datagram from a non-current session epoch was dropped.
    pub fn record_stale_epoch_drop(&self) {
        self.stale_epoch_drops.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` bytes of forward redundancy were sent.
    pub fn record_redundancy_bytes(&self, n: u64) {
        self.redundancy_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// The pacer stalled a send to respect the modeled bandwidth.
    pub fn record_paced_stall(&self) {
        self.paced_stalls.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> TransportStats {
        TransportStats {
            payload_bytes: self.payload_bytes.load(Ordering::Relaxed),
            wire_bytes: self.wire_bytes.load(Ordering::Relaxed),
            messages: self.messages.load(Ordering::Relaxed),
            buffered_bytes: self.buffered_bytes.load(Ordering::Relaxed),
            peak_buffered_bytes: self.peak_buffered_bytes.load(Ordering::Relaxed),
            nacks_sent: self.nacks_sent.load(Ordering::Relaxed),
            nacks_received: self.nacks_received.load(Ordering::Relaxed),
            retransmitted_chunks: self.retransmitted_chunks.load(Ordering::Relaxed),
            duplicate_drops: self.duplicate_drops.load(Ordering::Relaxed),
            reorder_events: self.reorder_events.load(Ordering::Relaxed),
            corrupt_drops: self.corrupt_drops.load(Ordering::Relaxed),
            stale_epoch_drops: self.stale_epoch_drops.load(Ordering::Relaxed),
            redundancy_bytes: self.redundancy_bytes.load(Ordering::Relaxed),
            paced_stalls: self.paced_stalls.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of a backend's counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Application payload bytes sent (what the collectives account).
    pub payload_bytes: u64,
    /// Bytes actually put on the link, including frame headers.
    pub wire_bytes: u64,
    /// Point-to-point messages sent.
    pub messages: u64,
    /// Payload bytes currently buffered awaiting `recv` (0 at rest). Scope
    /// is mesh-wide for InProc (shared counters: sent-not-yet-received
    /// across all links) and per-endpoint receive queue for TCP.
    pub buffered_bytes: u64,
    /// High-water mark of `buffered_bytes` over the endpoint's lifetime —
    /// how the collectives' in-flight memory bounds (e.g. the pipelined
    /// hierarchical send window) are pinned in tests.
    pub peak_buffered_bytes: u64,
    /// NACK control datagrams sent (UDP; zero on other backends).
    pub nacks_sent: u64,
    /// NACK control datagrams received.
    pub nacks_received: u64,
    /// Chunks re-sent from the retransmit window.
    pub retransmitted_chunks: u64,
    /// Duplicate datagrams dropped.
    pub duplicate_drops: u64,
    /// Out-of-order datagram arrivals observed.
    pub reorder_events: u64,
    /// Datagrams dropped for parse/CRC failures.
    pub corrupt_drops: u64,
    /// Datagrams dropped for carrying a stale or future session epoch.
    pub stale_epoch_drops: u64,
    /// Forward-redundancy bytes sent (frame-tail duplicates).
    pub redundancy_bytes: u64,
    /// Sends the pacer stalled to respect the modeled bandwidth.
    pub paced_stalls: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_payload_and_framing() {
        let c = TransportCounters::default();
        c.record_send(100);
        c.record_send(0);
        let s = c.snapshot();
        assert_eq!(s.payload_bytes, 100);
        assert_eq!(s.wire_bytes, 100 + 2 * FRAME_HEADER_LEN as u64);
        assert_eq!(s.messages, 2);
    }

    #[test]
    fn buffered_gauge_tracks_peak_and_drains_to_zero() {
        let c = TransportCounters::default();
        c.record_buffered(100);
        c.record_buffered(50);
        c.record_drained(100);
        c.record_buffered(20);
        let s = c.snapshot();
        assert_eq!(s.buffered_bytes, 70);
        assert_eq!(s.peak_buffered_bytes, 150, "peak is the high-water mark");
        c.record_drained(50);
        c.record_drained(20);
        assert_eq!(c.snapshot().buffered_bytes, 0, "at rest everything drained");
        assert_eq!(c.snapshot().peak_buffered_bytes, 150, "peak is sticky");
    }

    #[test]
    fn robustness_counters_accumulate_independently() {
        let c = TransportCounters::default();
        c.record_datagram_send(1000, 1100);
        c.record_extra_wire(64);
        c.record_nack_sent();
        c.record_nack_sent();
        c.record_nack_received();
        c.record_retransmitted_chunks(3);
        c.record_duplicate_drop();
        c.record_reorder_event();
        c.record_corrupt_drop();
        c.record_stale_epoch_drop();
        c.record_redundancy_bytes(1200);
        c.record_paced_stall();
        let s = c.snapshot();
        assert_eq!((s.payload_bytes, s.wire_bytes, s.messages), (1000, 1164, 1));
        assert_eq!((s.nacks_sent, s.nacks_received), (2, 1));
        assert_eq!(s.retransmitted_chunks, 3);
        assert_eq!(
            (s.duplicate_drops, s.reorder_events, s.corrupt_drops, s.stale_epoch_drops),
            (1, 1, 1, 1)
        );
        assert_eq!((s.redundancy_bytes, s.paced_stalls), (1200, 1));
    }
}
