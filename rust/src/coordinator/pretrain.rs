//! Shared "get me a trained model" helper.
//!
//! The paper's accuracy tables evaluate *pretrained* checkpoints; here the
//! checkpoint comes from our own rust trainer (DESIGN.md §2). This helper
//! trains the named config on its corpus for `steps` optimizer steps and
//! caches the result under `checkpoints/`, so the accuracy harnesses and
//! integration tests share one model instead of retraining.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::comm::{Algo, AlgoPolicy};
use crate::coordinator::trainer::{TrainOptions, Trainer};
use crate::model::{Corpus, ModelConfig, Sampler, Weights};
use crate::quant::Codec;
use crate::runtime::{default_artifacts_dir, Runtime};

/// Directory for rust-side checkpoints (created on demand).
pub fn checkpoints_dir() -> PathBuf {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("checkpoints");
    std::fs::create_dir_all(&d).ok();
    d
}

/// Load the cached checkpoint for `(config, steps)` or train it now with
/// BF16 gradient collectives. Returns (config, weights, final train loss).
pub fn ensure_trained(config: &str, steps: usize) -> Result<(ModelConfig, Weights, f32)> {
    let rt = Runtime::open(default_artifacts_dir())?;
    let cfg = ModelConfig::from_record(rt.manifest.config(config)?)?;
    let path = checkpoints_dir().join(format!("{config}_s{steps}.bin"));
    if path.exists() {
        let w = Weights::load(&path)?;
        return Ok((cfg, w, f32::NAN));
    }
    let init = Weights::load(default_artifacts_dir().join(format!("{config}_init_weights.bin")))
        .context("init weights; run `make artifacts`")?;
    let corpus =
        Corpus::load(default_artifacts_dir().join(format!("corpus_v{}.bin", cfg.vocab)))?;
    let (train, _) = corpus.split();
    let mut sampler = Sampler::new(train, 0xF1A5);
    let mut trainer = Trainer::new(rt, cfg.clone(), &init)?;
    let opts = TrainOptions {
        steps,
        dp: 2,
        codec: Codec::Bf16,
        algo: AlgoPolicy::Fixed(Algo::TwoStep),
        log_every: 20,
        ..Default::default()
    };
    eprintln!("[pretrain] training {config} for {steps} steps (cached at {path:?})");
    let recs = trainer.train(&mut sampler, &[], &opts)?;
    let loss = recs.last().map(|r| r.loss).unwrap_or(f32::NAN);
    let w = trainer.export_weights()?;
    w.save(&path)?;
    Ok((cfg, w, loss))
}

/// Default pretraining depth for the accuracy harnesses: enough for the
/// model to have real structure (loss well below ln V) while staying
/// tractable on one CPU core.
pub const ACCURACY_STEPS: usize = 120;
/// Cheaper depth used by the integration tests.
pub const TEST_STEPS: usize = 40;
