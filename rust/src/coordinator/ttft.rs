//! Time-to-first-token model (Fig. 2).
//!
//! TTFT for a TP=N prefill is compute + communication:
//!   - compute: `2 · P · T / N` FLOPs per device over the device's usable
//!     BF16 throughput (CUDA-core figure from Table 6 scaled by an MFU
//!     factor — prefill GEMMs on these parts run well under peak),
//!   - communication: 2 AllReduces per layer of the `B·S·D` BF16 hidden
//!     state, timed by the calibrated simulator with the chosen codec and
//!     algorithm (hier+PP on the PCIe box, two-step on NVLink).
//!
//! Reproduced quantity: the *relative* TTFT across precisions per device
//! (the paper's 2.28x on L40, ~1.2-1.3x on A100/H800, ~1x on H20).

use crate::comm::{Algo, AlgoPolicy};
use crate::plan::{self, CommPlan};
use crate::quant::Codec;
use crate::sim;
use crate::topo::Topology;

/// Workload: a dense LLM prefill (defaults ≈ Llama-3-8B, TP=8).
#[derive(Debug, Clone)]
pub struct PrefillWorkload {
    pub n_params: f64,
    pub n_layers: usize,
    pub d_model: usize,
    pub batch: usize,
    pub prompt_len: usize,
}

impl Default for PrefillWorkload {
    fn default() -> Self {
        // Llama-3-8B: 32 layers, d=4096.
        PrefillWorkload {
            n_params: 8.03e9,
            n_layers: 32,
            d_model: 4096,
            batch: 1,
            prompt_len: 1024,
        }
    }
}

/// Model FLOPs utilization a prefill realizes on the tensor cores.
const PREFILL_MFU: f64 = 0.40;

/// TTFT (seconds) for a workload on a topology with a given codec.
pub fn ttft_s(topo: &Topology, wl: &PrefillWorkload, codec: &Codec, algo: Algo) -> f64 {
    let tokens = (wl.batch * wl.prompt_len) as f64;
    let flops = 2.0 * wl.n_params * tokens / topo.n_gpus as f64;
    let compute = flops / (topo.spec.tensor_bf16_tflops * 1e12 * PREFILL_MFU);
    // Two AllReduces per layer over the bf16 hidden state.
    let m_bytes = tokens * wl.d_model as f64 * 2.0;
    let per_ar = sim::allreduce_time(topo, algo, codec, m_bytes).total();
    compute + 2.0 * wl.n_layers as f64 * per_ar
}

/// The algorithm Fig. 2 runs for a workload: the BF16 baseline is always
/// NCCL's ring (that is the paper's comparison point); quantized codecs
/// go through [`AlgoPolicy::Auto`], which at prefill payload sizes picks
/// the hierarchical family on PCIe/NUMA boxes and the two-step on NVLink —
/// the same per-device choice the paper makes by hand.
pub fn algo_for(topo: &Topology, wl: &PrefillWorkload, codec: &Codec) -> Algo {
    if matches!(codec, Codec::Bf16) {
        return Algo::Ring;
    }
    let elems = wl.batch * wl.prompt_len * wl.d_model;
    AlgoPolicy::Auto.resolve(topo, codec, elems)
}

/// The *full* communication plan the plan compiler would run for a
/// workload: algorithm plus per-stage codecs plus tuned chunking. The
/// BF16 baseline stays NCCL's ring (the paper's comparison point, and a
/// lossless budget the compiler never quantizes); quantized codecs go
/// through [`plan::compile`] at the prefill AllReduce payload size — on a
/// tier-asymmetric cluster this is where the cross-group stage picks up a
/// more aggressive codec than the intra stages.
pub fn plan_for(topo: &Topology, wl: &PrefillWorkload, codec: &Codec) -> CommPlan {
    if matches!(codec, Codec::Bf16) {
        return CommPlan::uniform(Algo::Ring, *codec);
    }
    let elems = wl.batch * wl.prompt_len * wl.d_model;
    plan::compile(topo, elems, codec)
}

/// [`ttft_s`] under an explicit [`CommPlan`] (per-stage pricing via
/// [`sim::plan_time`]).
pub fn ttft_s_planned(topo: &Topology, wl: &PrefillWorkload, plan: &CommPlan) -> f64 {
    let tokens = (wl.batch * wl.prompt_len) as f64;
    let flops = 2.0 * wl.n_params * tokens / topo.n_gpus as f64;
    let compute = flops / (topo.spec.tensor_bf16_tflops * 1e12 * PREFILL_MFU);
    let m_bytes = tokens * wl.d_model as f64 * 2.0;
    let per_ar = sim::plan_time(topo, plan, m_bytes).total();
    compute + 2.0 * wl.n_layers as f64 * per_ar
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::presets;

    fn speedup(spec: crate::topo::GpuSpec, codec: &str) -> f64 {
        let topo = Topology::new(spec, 8);
        let wl = PrefillWorkload::default();
        let base = ttft_s(&topo, &wl, &Codec::Bf16, algo_for(&topo, &wl, &Codec::Bf16));
        let c = Codec::parse(codec).unwrap();
        let t = ttft_s(&topo, &wl, &c, algo_for(&topo, &wl, &c));
        base / t
    }

    #[test]
    fn l40_gains_most_fig2() {
        // Paper: 2.28x TTFT gain on L40 with low-bit + hier + PP.
        let s = speedup(presets::l40(), "int4@32");
        assert!((1.6..=3.2).contains(&s), "L40 speedup {s}");
    }

    #[test]
    fn nvlink_gains_modest() {
        let a100 = speedup(presets::a100(), "int5");
        let h800 = speedup(presets::h800(), "int5");
        assert!((1.02..=1.6).contains(&a100), "A100 {a100}");
        assert!((1.02..=1.7).contains(&h800), "H800 {h800}");
    }

    #[test]
    fn h20_no_benefit_fig2() {
        // Paper: "we don't find any benefit using low-bit on H20".
        let s = speedup(presets::h20(), "int4@32");
        assert!(s < 1.15, "H20 speedup {s} should be ~none");
    }

    #[test]
    fn plan_for_mixes_stages_on_asymmetric_clusters_only() {
        let wl = PrefillWorkload::default();
        let c = Codec::parse("int4@32").unwrap();
        // The balanced L40 box: full plan, uniform codecs.
        let l40 = Topology::new(presets::l40(), 8);
        let p = plan_for(&l40, &wl, &c);
        assert!(p.stage_codecs.is_uniform(), "{p}");
        assert!(ttft_s_planned(&l40, &wl, &p) > 0.0);
        // Two NVLink nodes over a slow link: the cross stage goes
        // aggressive and planned TTFT beats the uniform plan's.
        let duo = presets::dual_nvlink_node(16).unwrap();
        let p = plan_for(&duo, &wl, &c);
        assert!(!p.stage_codecs.is_uniform(), "{p}");
        let uniform = crate::plan::CommPlan::uniform(p.algo, c);
        assert!(
            ttft_s_planned(&duo, &wl, &p) < ttft_s_planned(&duo, &wl, &uniform),
            "the compiled plan must not lose to its uniform counterpart"
        );
        // BF16 stays the ring baseline, lossless.
        let pb = plan_for(&duo, &wl, &Codec::Bf16);
        assert_eq!(pb.algo, Algo::Ring);
        assert!(pb.stage_codecs.is_uniform());
    }

    #[test]
    fn compute_dominates_on_strong_gpus() {
        let topo = Topology::new(presets::h800(), 8);
        let wl = PrefillWorkload::default();
        let t = ttft_s(&topo, &wl, &Codec::Bf16, Algo::Ring);
        // 8B model, 1k tokens, 8 GPUs: sub-second prefill.
        assert!(t > 0.01 && t < 2.0, "H800 TTFT {t}");
    }
}
