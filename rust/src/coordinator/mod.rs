//! L3 coordination: the request-path orchestration the paper's system
//! needs — a TP inference engine with quantized AllReduce between HLO
//! pieces, a DP trainer with quantized gradient collectives, an EP
//! dispatcher with quantized All2All dispatch, and the TTFT model.
//!
//! Every engine's collective traffic goes through the one
//! [`crate::comm::Communicator`] implementation (via
//! [`crate::comm::LocalGroup`]) — there is no engine-private QDQ chain.

pub mod ep;
pub mod pretrain;
pub mod tp;
pub mod trainer;
pub mod ttft;

pub use ep::MoeEngine;
pub use tp::TpEngine;
pub use trainer::{StepRecord, TrainOptions, Trainer};
