//! Expert-parallel MoE engine with quantized dispatch (Tables 2, 8).
//!
//! For MoE layers the engine mirrors a real EP serving stack: the router
//! piece produces expert logits + the normalized activations, rust makes
//! the top-1 routing decision, groups tokens per expert under a fixed
//! capacity (tokens over capacity fall back to the residual path, exactly
//! like capacity-factor MoE serving), sends the *dispatch volume through
//! the wire codec* (DeepSeek-V3 quantizes dispatch only), runs the expert
//! HLO on the padded batch, and combines at BF16.
//!
//! Attention and the dense-FFN layers reuse the TP boundary machinery —
//! the same [`LocalGroup`] of Communicators the TP engine drives, so the
//! boundary QDQ chain has exactly one implementation; the dispatch wire
//! applies the codec's canonical QDQ transform to the routed token batch.

use anyhow::{ensure, Result};

use crate::comm::{Algo, AlgoPolicy, LocalGroup};
use crate::coordinator::tp::tp_group;
use crate::model::{shard_param, Batch, ModelConfig, Weights};
use crate::quant::{Codec, CodecBuffers};
use crate::runtime::{tokens_literal, Runtime, Tensor};

/// The EP engine (dense layers run TP; MoE layers run quantized dispatch).
pub struct MoeEngine {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    /// Wire codec for the TP AllReduce boundaries (attention / dense MLP).
    pub ar_codec: Codec,
    /// Wire codec for the MoE dispatch volume.
    pub dispatch_codec: Codec,
    /// TP rank group for the boundary AllReduce (two-step policy; `None`
    /// when `tp == 1` and nothing crosses a wire).
    group: Option<LocalGroup>,
    embed: xla::Literal,
    head: Vec<xla::Literal>,
    attn: Vec<Vec<Vec<xla::Literal>>>,  // [layer][shard]
    mlp: Vec<Vec<Vec<xla::Literal>>>,   // [layer][shard] (dense layers)
    router: Vec<Vec<xla::Literal>>,     // [layer] (ln2_g, ln2_b, router)
    experts: Vec<Vec<(xla::Literal, xla::Literal)>>, // [layer][expert] (w1, w2)
    bufs: CodecBuffers,
    /// Tokens dropped to the residual path by the capacity limit (stat).
    pub dropped_tokens: usize,
    /// Total dispatch wire bytes (what the All2All would carry).
    pub dispatch_wire_bytes: u64,
}

impl MoeEngine {
    pub fn new(
        rt: Runtime,
        cfg: ModelConfig,
        weights: &Weights,
        ar_codec: Codec,
        dispatch_codec: Codec,
    ) -> Result<MoeEngine> {
        ensure!(cfg.n_experts > 0, "config {} has no experts", cfg.name);
        let tp = cfg.tp;
        let group = tp_group(tp, AlgoPolicy::Fixed(Algo::TwoStep))?;
        let embed = weights.get("embed")?.to_literal()?;
        let head = vec![
            weights.get("lnf_g")?.to_literal()?,
            weights.get("lnf_b")?.to_literal()?,
            weights.get("embed")?.to_literal()?,
        ];
        let mut attn = Vec::new();
        let mut mlp = Vec::new();
        let mut router = Vec::new();
        let mut experts = Vec::new();
        for l in 0..cfg.n_layers {
            let get = |b: &str| weights.get(&format!("l{l}.{b}"));
            let mut a_sh = Vec::new();
            for k in 0..tp {
                let mut args = vec![get("ln1_g")?.to_literal()?, get("ln1_b")?.to_literal()?];
                for w in ["wq", "wk", "wv", "wo"] {
                    let name = format!("l{l}.{w}");
                    args.push(shard_param(&name, weights.get(&name)?, tp, k).to_literal()?);
                }
                a_sh.push(args);
            }
            attn.push(a_sh);
            if cfg.is_moe_layer(l) {
                mlp.push(Vec::new());
                router.push(vec![
                    get("ln2_g")?.to_literal()?,
                    get("ln2_b")?.to_literal()?,
                    get("router")?.to_literal()?,
                ]);
                let we1 = get("we1")?;
                let we2 = get("we2")?;
                let (e, d, f) = (cfg.n_experts, cfg.d_model, cfg.d_expert);
                ensure!(we1.shape == vec![e, d, f], "we1 shape {:?}", we1.shape);
                let mut per_expert = Vec::with_capacity(e);
                for x in 0..e {
                    let w1 = Tensor::new(vec![d, f], we1.data[x * d * f..(x + 1) * d * f].to_vec());
                    let w2 = Tensor::new(vec![f, d], we2.data[x * d * f..(x + 1) * d * f].to_vec());
                    per_expert.push((w1.to_literal()?, w2.to_literal()?));
                }
                experts.push(per_expert);
            } else {
                let mut m_sh = Vec::new();
                for k in 0..tp {
                    let mut args =
                        vec![get("ln2_g")?.to_literal()?, get("ln2_b")?.to_literal()?];
                    for w in ["w1", "w2"] {
                        let name = format!("l{l}.{w}");
                        args.push(shard_param(&name, weights.get(&name)?, tp, k).to_literal()?);
                    }
                    m_sh.push(args);
                }
                mlp.push(m_sh);
                router.push(Vec::new());
                experts.push(Vec::new());
            }
        }
        Ok(MoeEngine {
            rt,
            cfg,
            ar_codec,
            dispatch_codec,
            group,
            embed,
            head,
            attn,
            mlp,
            router,
            experts,
            bufs: CodecBuffers::default(),
            dropped_tokens: 0,
            dispatch_wire_bytes: 0,
        })
    }

    fn tp_boundary(&mut self, piece: &str, h: &Tensor, shards: usize, layer: usize, is_mlp: bool) -> Result<Tensor> {
        let h_lit = h.to_literal()?;
        let mut partials = Vec::with_capacity(shards);
        for k in 0..shards {
            let shard_args =
                if is_mlp { &self.mlp[layer][k] } else { &self.attn[layer][k] };
            let mut args: Vec<xla::Literal> = vec![h_lit.clone()];
            args.extend(shard_args.iter().cloned());
            let out = self.rt.execute_t(piece, &args)?;
            partials.push(out.into_iter().next().unwrap().data);
        }
        let reduced = match &mut self.group {
            Some(group) => {
                group.allreduce(&mut partials, &self.ar_codec)?;
                std::mem::take(&mut partials[0])
            }
            None => partials.pop().unwrap(),
        };
        let mut out = h.clone();
        for (o, r) in out.data.iter_mut().zip(&reduced) {
            *o += *r;
        }
        Ok(out)
    }

    /// The MoE FFN: route -> quantized dispatch -> expert HLO -> combine.
    fn moe_layer(&mut self, h: &Tensor, layer: usize) -> Result<Tensor> {
        let cfg = self.cfg.clone();
        let d = cfg.d_model;
        let e = cfg.n_experts;
        let cap = cfg.capacity;
        // Router piece: logits [B,S,E] + normalized activations [B,S,D].
        let mut args = vec![h.to_literal()?];
        args.extend(self.router[layer].iter().cloned());
        let out = self.rt.execute_t(&cfg.art("router"), &args)?;
        let (logits, xnorm) = (&out[0], &out[1]);
        let n_tokens = h.len() / d;

        // Top-1 routing + softmax gate, host-side (the router's job).
        let mut assignment = vec![0usize; n_tokens];
        let mut gate = vec![0f32; n_tokens];
        for t in 0..n_tokens {
            let row = &logits.data[t * e..(t + 1) * e];
            let (mut best, mut best_v) = (0, f32::NEG_INFINITY);
            let mut denom = 0f32;
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            for (i, &v) in row.iter().enumerate() {
                denom += (v - max).exp();
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }
            assignment[t] = best;
            gate[t] = (best_v - max).exp() / denom;
        }

        // Group tokens per expert under the capacity limit.
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); e];
        for (t, &x) in assignment.iter().enumerate() {
            if groups[x].len() < cap {
                groups[x].push(t);
            } else {
                self.dropped_tokens += 1;
            }
        }

        // Dispatch: quantize each expert's token batch (the All2All wire),
        // run the expert on the padded capacity batch, combine at BF16.
        let mut mixed = vec![0f32; h.len()];
        for (x, toks) in groups.iter().enumerate() {
            if toks.is_empty() {
                continue;
            }
            let mut payload = vec![0f32; toks.len() * d];
            for (row, &t) in toks.iter().enumerate() {
                payload[row * d..(row + 1) * d]
                    .copy_from_slice(&xnorm.data[t * d..(t + 1) * d]);
            }
            self.dispatch_wire_bytes += self.dispatch_codec.wire_len(payload.len()) as u64;
            self.dispatch_codec.qdq(&mut payload, &mut self.bufs); // the wire
            let mut padded = vec![0f32; cap * d];
            padded[..payload.len()].copy_from_slice(&payload);
            let (w1, w2) = &self.experts[layer][x];
            let xin = Tensor::new(vec![cap, d], padded);
            let out = self
                .rt
                .execute_t(&cfg.art("expert"), &[xin.to_literal()?, w1.clone(), w2.clone()])?;
            let mut y = out.into_iter().next().unwrap().data;
            // Combine direction stays BF16 (dispatch-only quantization).
            Codec::Bf16.qdq(&mut y[..toks.len() * d], &mut self.bufs);
            for (row, &t) in toks.iter().enumerate() {
                let g = gate[t];
                for i in 0..d {
                    mixed[t * d + i] = g * y[row * d + i];
                }
            }
        }
        let mut out = h.clone();
        for (o, m) in out.data.iter_mut().zip(&mixed) {
            *o += *m;
        }
        Ok(out)
    }

    /// Full forward to the pre-head hidden state.
    pub fn forward_h(&mut self, batch: &Batch) -> Result<Tensor> {
        let cfg = self.cfg.clone();
        let toks = tokens_literal(&batch.tokens, &[batch.batch, batch.seq])?;
        let mut h = self
            .rt
            .execute_t(&cfg.art("embed"), &[toks, self.embed.clone()])?
            .into_iter()
            .next()
            .unwrap();
        let attn_piece = cfg.art(&format!("attn_part_tp{}", cfg.tp));
        let mlp_piece = cfg.art(&format!("mlp_part_tp{}", cfg.tp));
        for l in 0..cfg.n_layers {
            h = self.tp_boundary(&attn_piece, &h, cfg.tp, l, false)?;
            if cfg.is_moe_layer(l) {
                h = self.moe_layer(&h, l)?;
            } else {
                h = self.tp_boundary(&mlp_piece, &h, cfg.tp, l, true)?;
            }
        }
        Ok(h)
    }

    /// Perplexity over eval batches (same head as the TP engine).
    pub fn perplexity(&mut self, batches: &[Batch]) -> Result<f64> {
        let cfg = self.cfg.clone();
        let mut sum = 0f64;
        let mut count = 0usize;
        for b in batches {
            let h = self.forward_h(b)?;
            let tgts = tokens_literal(&b.targets, &[b.batch, b.seq])?;
            let mut args = vec![h.to_literal()?];
            args.extend(self.head.iter().cloned());
            args.push(tgts);
            let out = self.rt.execute_t(&cfg.art("head_nll"), &args)?;
            sum += out[0].data.iter().map(|&x| x as f64).sum::<f64>();
            count += out[0].len();
        }
        Ok((sum / count as f64).exp())
    }

    pub fn set_dispatch_codec(&mut self, codec: Codec) {
        self.dispatch_codec = codec;
    }
}
