//! Data-parallel trainer with quantized gradient AllReduce.
//!
//! Each DP rank executes the whole-graph `grad_step` HLO on its own
//! micro-batch; the gradients then travel through the *real* collective —
//! a [`LocalGroup`] of Communicators over the thread fabric — with the
//! configured wire codec, exactly like ZeRO++-style quantized gradient
//! averaging; finally one `adamw` HLO execution updates the (replicated)
//! parameters. Because the collectives are bit-deterministic across ranks,
//! a single parameter copy is faithful DP semantics. The rank group (and
//! its codec scratch) persists across optimizer steps, so the per-step
//! gradient AllReduce is allocation-free after the first step.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::comm::{Algo, AlgoPolicy, LocalGroup};
use crate::model::{Batch, ModelConfig, Sampler, Weights};
use crate::plan::PlanPolicy;
use crate::quant::Codec;
use crate::runtime::{tokens_literal, Runtime, Tensor};

/// Trainer options.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub dp: usize,
    pub codec: Codec,
    /// Gradient AllReduce algorithm: a fixed [`Algo`] or `Auto` against
    /// the cost model (`--algo auto` on the CLI).
    pub algo: AlgoPolicy,
    /// When set (`--plan` on the CLI), the gradient AllReduce runs
    /// through the plan layer with this policy — per-stage codecs and
    /// tuned chunking — and `algo` only shapes the preset topology.
    pub plan: Option<PlanPolicy>,
    /// Link-tier group count of the DP rank-group topology (`--groups`);
    /// `None` lets the policy pick the preset shape.
    pub groups: Option<usize>,
    pub seed: u64,
    pub log_every: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    /// When set (`--trace-out` on the CLI), the DP rank group flight-records
    /// every gradient AllReduce and [`Trainer::train`] writes one trace JSON
    /// per rank to `{trace_out}.rank{r}` after the last step.
    pub trace_out: Option<String>,
    /// Recorder ring size per rank (`--trace-capacity` on the CLI; the
    /// CLI layer rejects 0 before it gets here).
    pub trace_capacity: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 200,
            dp: 4,
            codec: Codec::Bf16,
            algo: AlgoPolicy::Fixed(Algo::TwoStep),
            plan: None,
            groups: None,
            seed: 7,
            log_every: 10,
            eval_every: 0,
            eval_batches: 8,
            trace_out: None,
            trace_capacity: crate::telemetry::DEFAULT_CAPACITY,
        }
    }
}

/// One point of the training record.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub grad_wire_bytes: u64,
    pub step_time_s: f64,
    /// Held-out perplexity, when evaluated this step.
    pub eval_ppl: Option<f64>,
}

/// The DP trainer. Owns the runtime, the replicated parameter state, and
/// the DP rank group whose Communicators carry the gradient AllReduce.
pub struct Trainer {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    names: Vec<String>,
    shapes: Vec<Vec<usize>>,
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    step: usize,
    /// Persistent DP rank group, keyed by the (dp, groups, policy, plan)
    /// it was built for; rebuilt lazily when the options change between
    /// calls.
    group: Option<(GroupKey, LocalGroup)>,
}

/// What the persistent DP rank group was built for.
type GroupKey = (usize, Option<usize>, AlgoPolicy, Option<PlanPolicy>);

impl Trainer {
    pub fn new(rt: Runtime, cfg: ModelConfig, init: &Weights) -> Result<Trainer> {
        let names = cfg.param_names();
        let mut params = Vec::with_capacity(names.len());
        let mut shapes = Vec::with_capacity(names.len());
        let mut m = Vec::with_capacity(names.len());
        let mut v = Vec::with_capacity(names.len());
        for n in &names {
            let t = init.get(n)?;
            shapes.push(t.shape.clone());
            params.push(t.to_literal()?);
            m.push(Tensor::zeros(&t.shape).to_literal()?);
            v.push(Tensor::zeros(&t.shape).to_literal()?);
        }
        Ok(Trainer { rt, cfg, names, shapes, params, m, v, step: 0, group: None })
    }

    /// Flatten per-tensor grads into one contiguous f32 buffer (the
    /// collective's payload), and back.
    fn flatten(tensors: &[Tensor]) -> Vec<f32> {
        let total: usize = tensors.iter().map(|t| t.len()).sum();
        let mut out = Vec::with_capacity(total);
        for t in tensors {
            out.extend_from_slice(&t.data);
        }
        out
    }

    fn unflatten(&self, flat: &[f32]) -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(self.shapes.len());
        let mut off = 0;
        for shape in &self.shapes {
            let n: usize = shape.iter().product();
            let t = Tensor::new(shape.clone(), flat[off..off + n].to_vec());
            lits.push(t.to_literal()?);
            off += n;
        }
        Ok(lits)
    }

    /// Run the quantized gradient AllReduce through the persistent DP rank
    /// group (dp = 1 short-circuits: nothing crosses a wire).
    fn allreduce_grads(
        &mut self,
        mut per_rank: Vec<Vec<f32>>,
        opts: &TrainOptions,
    ) -> Result<(Vec<f32>, u64)> {
        if opts.dp == 1 {
            return Ok((per_rank.swap_remove(0), 0));
        }
        let key = (opts.dp, opts.groups, opts.algo, opts.plan);
        if self.group.as_ref().map(|(k, _)| *k != key).unwrap_or(true) {
            let mut group = match opts.plan {
                Some(plan) => LocalGroup::for_plan_grouped(opts.dp, opts.groups, plan)?,
                None => LocalGroup::for_policy_grouped(opts.dp, opts.groups, opts.algo)?,
            };
            if opts.trace_out.is_some() {
                group.enable_recording(opts.trace_capacity);
            }
            self.group = Some((key, group));
        }
        let (_, group) = self.group.as_mut().unwrap();
        let before = group.counters().total_bytes();
        group.allreduce(&mut per_rank, &opts.codec)?;
        let wire = group.counters().total_bytes() - before;
        let mut reduced = per_rank.swap_remove(0);
        let scale = 1.0 / opts.dp as f32;
        for x in reduced.iter_mut() {
            *x *= scale;
        }
        Ok((reduced, wire))
    }

    /// One optimizer step over `dp` micro-batches. Returns the record.
    pub fn train_step(&mut self, sampler: &mut Sampler, opts: &TrainOptions) -> Result<StepRecord> {
        let t0 = Instant::now();
        let cfg = self.cfg.clone();
        let grad_art = cfg.art("grad_step");
        let mut loss_sum = 0f32;
        let mut per_rank: Vec<Vec<f32>> = Vec::with_capacity(opts.dp);
        for _ in 0..opts.dp {
            let b = sampler.next_batch(cfg.train_batch, cfg.seq_len);
            let mut args: Vec<xla::Literal> = self.params.to_vec();
            args.push(tokens_literal(&b.tokens, &[b.batch, b.seq])?);
            args.push(tokens_literal(&b.targets, &[b.batch, b.seq])?);
            let out = self.rt.execute_t(&grad_art, &args).context("grad_step")?;
            loss_sum += out[0].data[0];
            per_rank.push(Self::flatten(&out[1..]));
        }
        let (reduced, wire_bytes) = self.allreduce_grads(per_rank, opts)?;
        let grads = self.unflatten(&reduced)?;

        // AdamW update: (step, params, grads, m, v) -> (params', m', v').
        let mut args: Vec<xla::Literal> = vec![Tensor::scalar(self.step as f32).to_literal()?];
        args.extend(self.params.iter().cloned());
        args.extend(grads);
        args.extend(self.m.iter().cloned());
        args.extend(self.v.iter().cloned());
        let out = self.rt.execute(&cfg.art("adamw"), &args).context("adamw")?;
        let k = self.names.len();
        anyhow::ensure!(out.len() == 3 * k, "adamw returned {} outputs", out.len());
        let mut it = out.into_iter();
        self.params = (&mut it).take(k).collect();
        self.m = (&mut it).take(k).collect();
        self.v = (&mut it).take(k).collect();
        self.step += 1;

        Ok(StepRecord {
            step: self.step,
            loss: loss_sum / opts.dp as f32,
            grad_wire_bytes: wire_bytes,
            step_time_s: t0.elapsed().as_secs_f64(),
            eval_ppl: None,
        })
    }

    /// Held-out perplexity with the clean (no comm quantization) graph.
    pub fn eval_ppl(&mut self, batches: &[Batch]) -> Result<f64> {
        let cfg = self.cfg.clone();
        let art = cfg.art("eval_nll");
        let mut sum = 0f64;
        let mut count = 0f64;
        for b in batches {
            let mut args: Vec<xla::Literal> = self.params.to_vec();
            args.push(tokens_literal(&b.tokens, &[b.batch, b.seq])?);
            args.push(tokens_literal(&b.targets, &[b.batch, b.seq])?);
            let out = self.rt.execute_t(&art, &args)?;
            sum += out[0].data[0] as f64;
            count += out[1].data[0] as f64;
        }
        Ok((sum / count).exp())
    }

    /// Full training loop with logging; returns the loss-curve records.
    pub fn train(
        &mut self,
        sampler: &mut Sampler,
        eval: &[Batch],
        opts: &TrainOptions,
    ) -> Result<Vec<StepRecord>> {
        let mut records = Vec::with_capacity(opts.steps);
        for i in 0..opts.steps {
            let mut rec = self.train_step(sampler, opts)?;
            if opts.eval_every > 0 && (i + 1) % opts.eval_every == 0 {
                rec.eval_ppl = Some(self.eval_ppl(&eval[..eval.len().min(opts.eval_batches)])?);
            }
            if opts.log_every > 0 && (i % opts.log_every == 0 || i + 1 == opts.steps) {
                println!(
                    "step {:>5}  loss {:.4}  wire {:>12}  {:.2}s{}",
                    rec.step,
                    rec.loss,
                    crate::util::timer::fmt_bytes(rec.grad_wire_bytes as usize),
                    rec.step_time_s,
                    rec.eval_ppl.map(|p| format!("  eval_ppl {p:.3}")).unwrap_or_default()
                );
            }
            records.push(rec);
        }
        if let Some(path) = &opts.trace_out {
            self.dump_traces(path)?;
        }
        Ok(records)
    }

    /// Write one flight-recorder trace JSON per DP rank (`{path}.rank{r}`)
    /// and log the bandwidth profile distilled from the recorded spans —
    /// the live measurements `--plan auto` resolution recalibrates the
    /// static topology with (DESIGN.md §11).
    pub fn dump_traces(&mut self, path: &str) -> Result<()> {
        let Some((_, group)) = self.group.as_mut() else {
            println!("recalibration: no measurable spans (dp=1 runs no collective)");
            return Ok(());
        };
        match group.recalibrate_from_recorders() {
            Some(p) => println!("recalibration: {}", p.summary()),
            None => println!("recalibration: no measurable spans"),
        }
        let traces = group.trace_jsons();
        for (r, json) in traces.iter().enumerate() {
            let file = format!("{path}.rank{r}");
            std::fs::write(&file, json).with_context(|| format!("writing trace {file}"))?;
        }
        println!("wrote {} gradient-collective traces to {path}.rank*", traces.len());
        Ok(())
    }

    /// Export the current parameters as a weight bundle (checkpointing).
    pub fn export_weights(&self) -> Result<Weights> {
        let mut w = Weights::default();
        for (name, lit) in self.names.iter().zip(&self.params) {
            w.insert(name.clone(), Tensor::from_literal(lit)?);
        }
        Ok(w)
    }

    pub fn current_step(&self) -> usize {
        self.step
    }
}
