//! Tensor-parallel inference engine.
//!
//! Executes the per-shard HLO pieces (`attn_part`, `mlp_part`) and runs the
//! paper's quantized AllReduce on the partial outputs between pieces —
//! the real wire transformation (quantize → sum → re-quantize), applied to
//! the actual activation bytes. Residual adds happen host-side in rust,
//! exactly where a serving engine would fuse them.

use anyhow::{ensure, Result};

use crate::model::{shard_param, Batch, ModelConfig, Weights};
use crate::quant::{Codec, CodecBuffers};
use crate::runtime::{tokens_literal, Runtime, Tensor};

/// How the AllReduce chains its QDQ steps (the accuracy-relevant part of
/// the collective choice; timing lives in `sim`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollectiveStyle {
    /// Flash-Comm two-step: Q each partial, sum, Q the result (2 QDQs).
    TwoStep,
    /// Hierarchical: Q partials per NUMA group, Q the group sums across the
    /// bridge, Q the total for the all-gather (3 QDQs).
    Hier,
}

/// Apply the collective's QDQ chain to per-shard partial sums, in place on
/// the first buffer. Mirrors `comm::twostep` / `comm::hier` numerics.
pub fn allreduce_partials(
    partials: &mut [Vec<f32>],
    codec: &Codec,
    style: CollectiveStyle,
    bufs: &mut CodecBuffers,
) -> Vec<f32> {
    let n = partials.len();
    let len = partials[0].len();
    match style {
        CollectiveStyle::TwoStep => {
            let mut acc = vec![0f32; len];
            for p in partials.iter_mut() {
                codec.qdq(p, bufs);
                for (a, x) in acc.iter_mut().zip(p.iter()) {
                    *a += *x;
                }
            }
            codec.qdq(&mut acc, bufs);
            acc
        }
        CollectiveStyle::Hier => {
            let half = n.div_ceil(2);
            let mut total = vec![0f32; len];
            for group in [0..half, half..n] {
                if group.is_empty() {
                    continue;
                }
                let mut acc = vec![0f32; len];
                for p in partials[group].iter_mut() {
                    codec.qdq(p, bufs);
                    for (a, x) in acc.iter_mut().zip(p.iter()) {
                        *a += *x;
                    }
                }
                codec.qdq(&mut acc, bufs); // bridge hop
                for (t, x) in total.iter_mut().zip(&acc) {
                    *t += *x;
                }
            }
            codec.qdq(&mut total, bufs); // all-gather hop
            total
        }
    }
}

/// Per-layer, per-shard weight literals, prepared once.
struct LayerShards {
    /// [shard] -> (ln1_g, ln1_b, wq, wk, wv, wo)
    attn: Vec<Vec<xla::Literal>>,
    /// [shard] -> (ln2_g, ln2_b, w1, w2); empty for MoE layers.
    mlp: Vec<Vec<xla::Literal>>,
}

/// The TP engine: owns the runtime and the sharded weights.
pub struct TpEngine {
    pub rt: Runtime,
    pub cfg: ModelConfig,
    pub codec: Codec,
    pub style: CollectiveStyle,
    embed: xla::Literal,
    head: Vec<xla::Literal>, // lnf_g, lnf_b, embed (tied)
    layers: Vec<LayerShards>,
    bufs: CodecBuffers,
    /// If set, `last_partial` captures the raw (pre-QDQ) partial sum of
    /// this layer's MLP AllReduce — the Fig. 4 distribution.
    pub capture_layer: Option<usize>,
    pub last_partial: Vec<f32>,
}

impl TpEngine {
    /// Build from full weights, slicing TP shards per the python layout.
    pub fn new(
        rt: Runtime,
        cfg: ModelConfig,
        weights: &Weights,
        codec: Codec,
        style: CollectiveStyle,
    ) -> Result<TpEngine> {
        ensure!(cfg.n_heads % cfg.tp == 0, "heads {} % tp {}", cfg.n_heads, cfg.tp);
        let tp = cfg.tp;
        let embed = weights.get("embed")?.to_literal()?;
        let head = vec![
            weights.get("lnf_g")?.to_literal()?,
            weights.get("lnf_b")?.to_literal()?,
            weights.get("embed")?.to_literal()?,
        ];
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let g = |b: &str| -> Result<Tensor> { Ok(weights.get(&format!("l{l}.{b}"))?.clone()) };
            let mut attn = Vec::with_capacity(tp);
            for k in 0..tp {
                let mut args = Vec::new();
                args.push(g("ln1_g")?.to_literal()?);
                args.push(g("ln1_b")?.to_literal()?);
                for w in ["wq", "wk", "wv", "wo"] {
                    let name = format!("l{l}.{w}");
                    let sh = shard_param(&name, weights.get(&name)?, tp, k);
                    args.push(sh.to_literal()?);
                }
                attn.push(args);
            }
            let mut mlp = Vec::new();
            if !cfg.is_moe_layer(l) {
                for k in 0..tp {
                    let mut args = Vec::new();
                    args.push(g("ln2_g")?.to_literal()?);
                    args.push(g("ln2_b")?.to_literal()?);
                    for w in ["w1", "w2"] {
                        let name = format!("l{l}.{w}");
                        let sh = shard_param(&name, weights.get(&name)?, tp, k);
                        args.push(sh.to_literal()?);
                    }
                    mlp.push(args);
                }
            }
            layers.push(LayerShards { attn, mlp });
        }
        Ok(TpEngine {
            rt,
            cfg,
            codec,
            style,
            embed,
            head,
            layers,
            bufs: CodecBuffers::default(),
            capture_layer: None,
            last_partial: Vec::new(),
        })
    }

    /// Execute one boundary: run `piece` per shard, AllReduce the partials,
    /// residual-add into `h`.
    fn boundary(
        &mut self,
        piece: &str,
        h: &Tensor,
        layer: usize,
        is_mlp: bool,
    ) -> Result<Tensor> {
        let tp = self.cfg.tp;
        let h_lit = h.to_literal()?;
        let mut partials: Vec<Vec<f32>> = Vec::with_capacity(tp);
        for k in 0..tp {
            let shard_args = if is_mlp {
                &self.layers[layer].mlp[k]
            } else {
                &self.layers[layer].attn[k]
            };
            let mut args: Vec<xla::Literal> = vec![h_lit.clone()];
            args.extend(shard_args.iter().cloned());
            let out = self.rt.execute_t(piece, &args)?;
            partials.push(out.into_iter().next().unwrap().data);
        }
        if is_mlp && self.capture_layer == Some(layer) {
            // Fig. 4: the raw communicated volume (sum of shard partials).
            let mut raw = vec![0f32; partials[0].len()];
            for p in &partials {
                for (r, x) in raw.iter_mut().zip(p) {
                    *r += *x;
                }
            }
            self.last_partial = raw;
        }
        let reduced = allreduce_partials(&mut partials, &self.codec, self.style, &mut self.bufs);
        let mut out = h.clone();
        for (o, r) in out.data.iter_mut().zip(&reduced) {
            *o += *r;
        }
        Ok(out)
    }

    /// Full forward to the pre-head hidden state.
    pub fn forward_h(&mut self, batch: &Batch) -> Result<Tensor> {
        let cfg = self.cfg.clone();
        ensure!(
            batch.batch == cfg.eval_batch && batch.seq == cfg.seq_len,
            "batch {}x{} doesn't match lowered shapes {}x{}",
            batch.batch,
            batch.seq,
            cfg.eval_batch,
            cfg.seq_len
        );
        let toks = tokens_literal(&batch.tokens, &[batch.batch, batch.seq])?;
        let embed_name = cfg.art("embed");
        let mut h = self
            .rt
            .execute_t(&embed_name, &[toks, self.embed.clone()])?
            .into_iter()
            .next()
            .unwrap();
        let attn_piece = cfg.art(&format!("attn_part_tp{}", cfg.tp));
        let mlp_piece = cfg.art(&format!("mlp_part_tp{}", cfg.tp));
        for l in 0..cfg.n_layers {
            h = self.boundary(&attn_piece, &h, l, false)?;
            ensure!(!cfg.is_moe_layer(l), "TP engine is dense-only; use MoeEngine");
            h = self.boundary(&mlp_piece, &h, l, true)?;
        }
        Ok(h)
    }

    /// Mean next-token NLL over a batch (communication-quantized model).
    pub fn eval_nll(&mut self, batch: &Batch) -> Result<(f64, usize)> {
        let h = self.forward_h(batch)?;
        let tgts = tokens_literal(&batch.targets, &[batch.batch, batch.seq])?;
        let name = self.cfg.art("head_nll");
        let mut args = vec![h.to_literal()?];
        args.extend(self.head.iter().cloned());
        args.push(tgts);
        let out = self.rt.execute_t(&name, &args)?;
        let nll = &out[0];
        Ok((nll.data.iter().map(|&x| x as f64).sum(), nll.len()))
    }

    /// Perplexity over a set of eval batches.
    pub fn perplexity(&mut self, batches: &[Batch]) -> Result<f64> {
        let mut sum = 0.0;
        let mut count = 0usize;
        for b in batches {
            let (s, c) = self.eval_nll(b)?;
            sum += s;
            count += c;
        }
        Ok((sum / count as f64).exp())
    }

    /// Swap the codec (for sweep harnesses) without resharding weights.
    pub fn set_codec(&mut self, codec: Codec, style: CollectiveStyle) {
        self.codec = codec;
        self.style = style;
    }

    /// The head-piece weight literals (lnf_g, lnf_b, tied embedding) — used
    /// by harnesses that run alternative head artifacts (e.g. `head_acc`).
    pub fn head_literals(&self) -> Vec<xla::Literal> {
        self.head.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_partials_twostep_matches_manual() {
        let mut rng = crate::util::Prng::new(5);
        let mut parts: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let mut v = vec![0f32; 256];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();
        let exact: Vec<f32> =
            (0..256).map(|i| parts.iter().map(|p| p[i]).sum::<f32>()).collect();
        let mut bufs = CodecBuffers::default();
        let codec = Codec::parse("int8@32").unwrap();
        let out =
            allreduce_partials(&mut parts.clone(), &codec, CollectiveStyle::TwoStep, &mut bufs);
        let s = crate::util::stats::sqnr_db(&exact, &out);
        assert!(s > 25.0, "SQNR {s}");
        // Hier applies one extra QDQ: slightly worse, still close.
        let out_h = allreduce_partials(&mut parts, &codec, CollectiveStyle::Hier, &mut bufs);
        let sh = crate::util::stats::sqnr_db(&exact, &out_h);
        assert!(sh > 20.0 && sh <= s + 1.0, "hier {sh} vs two-step {s}");
    }

    #[test]
    fn bf16_passthrough_is_near_exact() {
        let mut parts = vec![vec![1.5f32; 64], vec![-0.25f32; 64]];
        let mut bufs = CodecBuffers::default();
        let out =
            allreduce_partials(&mut parts, &Codec::Bf16, CollectiveStyle::TwoStep, &mut bufs);
        for &x in &out {
            assert!((x - 1.25).abs() < 0.01, "{x}");
        }
    }
}
